//! Process-parallel equivalence suite (DESIGN.md §15,
//! docs/distributed.md): `extended_backward` through a
//! `Topology::Workers` coordinator against real `backpack-shard/v1`
//! workers (served on in-process threads, exactly like the serve
//! tests) must agree with the single-process engine to f32
//! summation-reordering error (≤ 1e-5), keep `Concat` rows bitwise,
//! and turn every worker failure into a named error instead of a
//! hang.
//!
//! Model scope: the full signature × worker-count matrix runs on
//! `logreg` (tiny wire payloads); `mlp` runs every signature at one
//! worker count plus a combined signature across counts, and the
//! conv coverage runs on `3c3d` in frame-sized signature groups —
//! `2c2d`'s 3,274,634 parameters serialize past the 64 MiB frame cap
//! before a single op completes, which is pinned below as a clean
//! coordinator error (chunked plans are `backpack-shard/v2`
//! material, not a silent fallback).

use backpack_rs::backend::extensions::{
    ExtensionSet, Quantities, ReducePlan, ReduceRule,
};
use backpack_rs::backend::model::{
    ExtractOptions, Model, Topology, NATIVE_EXTENSIONS,
};
use backpack_rs::data::Rng;
use backpack_rs::dist::{protocol, Worker};
use backpack_rs::runtime::Tensor;
use backpack_rs::wire::{read_frame, write_frame};

/// Stand up `count` shard workers on in-process threads (1 engine
/// thread each — the equivalence story is worker-count, not
/// thread-count) and return their ephemeral addresses.
fn spawn_workers(count: usize) -> Vec<String> {
    (0..count)
        .map(|_| {
            let w = Worker::bind("127.0.0.1:0", 1).unwrap();
            let addr = w.local_addr().to_string();
            std::thread::spawn(move || {
                let _ = w.run();
            });
            addr
        })
        .collect()
}

/// Send each worker the protocol's `shutdown` so its serving thread
/// exits; the coordinator never stops external workers itself.
fn shutdown_workers(addrs: &[String]) {
    for a in addrs {
        if let Ok(mut s) = std::net::TcpStream::connect(a.as_str()) {
            let _ = write_frame(&mut s, &protocol::shutdown());
            let _ = read_frame(&mut s);
        }
    }
}

fn worker_opts(
    addrs: &[String],
    key: Option<[u32; 2]>,
) -> ExtractOptions {
    ExtractOptions {
        topology: Topology::Workers {
            n: addrs.len(),
            addrs: addrs.to_vec(),
        },
        key,
        ..ExtractOptions::default()
    }
}

/// Small random parameters + batch for a registry model (same idiom
/// as tests/parallel_equiv.rs).
fn problem(
    m: &Model,
    n: usize,
    rng: &mut Rng,
) -> (Vec<Tensor>, Tensor, Tensor) {
    let params: Vec<Tensor> = m
        .param_specs()
        .iter()
        .map(|t| {
            let k: usize = t.shape.iter().product();
            Tensor::from_f32(
                &t.shape,
                (0..k).map(|_| rng.normal() * 0.05).collect(),
            )
        })
        .collect();
    let x: Vec<f32> = (0..n * m.in_dim).map(|_| rng.normal()).collect();
    let y: Vec<i32> =
        (0..n).map(|_| rng.below(m.classes) as i32).collect();
    (
        params,
        Tensor::from_f32(&[n, m.in_dim], x),
        Tensor::from_i32(&[n], y),
    )
}

fn assert_close(key: &str, want: &Tensor, got: &Tensor, tol: f32) {
    assert_eq!(
        want.shape, got.shape,
        "{key}: shape {:?} vs {:?}",
        want.shape, got.shape
    );
    let (a, b) = (want.f32s().unwrap(), got.f32s().unwrap());
    for (i, (u, v)) in a.iter().zip(b).enumerate() {
        assert!(
            (u - v).abs() <= tol * (1.0 + u.abs()),
            "{key}[{i}]: {u} vs {v}"
        );
    }
}

/// Serial (1 local thread) vs every worker count, for every
/// signature: same key sets, every tensor ≤ 1e-5.
fn sweep(
    m: &Model,
    n: usize,
    signatures: &[Vec<String>],
    worker_counts: &[usize],
) {
    let mut rng = Rng::new(0xD157 ^ m.name.len() as u64);
    let (params, x, y) = problem(m, n, &mut rng);
    let key = Some([9, 0xC0FE]);
    let serial_opts = ExtractOptions {
        topology: Topology::local(1),
        key,
        ..ExtractOptions::default()
    };
    let serials: Vec<Quantities> = signatures
        .iter()
        .map(|exts| {
            m.extended_backward(&params, &x, &y, exts, &serial_opts)
                .unwrap()
        })
        .collect();
    for &count in worker_counts {
        let addrs = spawn_workers(count);
        let opts = worker_opts(&addrs, key);
        for (exts, serial) in signatures.iter().zip(&serials) {
            let dist = m
                .extended_backward(&params, &x, &y, exts, &opts)
                .unwrap();
            assert_eq!(
                serial.len(),
                dist.len(),
                "{} {exts:?} workers={count}: key sets differ",
                m.name
            );
            for (k, want) in serial {
                let got = dist.get(k).unwrap_or_else(|| {
                    panic!(
                        "{} {exts:?} workers={count}: missing {k}",
                        m.name
                    )
                });
                assert_close(
                    &format!("{}/{exts:?}/{k} workers={count}", m.name),
                    want,
                    got,
                    1e-5,
                );
            }
        }
        shutdown_workers(&addrs);
    }
}

/// The tentpole acceptance matrix on logreg: plain grad plus every
/// builtin extension, 1 local vs 2, 3 and 5 worker processes
/// (11 samples: uneven slices at every count).
#[test]
fn logreg_all_signatures_agree_across_worker_counts() {
    let mut signatures: Vec<Vec<String>> = vec![Vec::new()];
    for ext in NATIVE_EXTENSIONS {
        signatures.push(vec![ext.to_string()]);
    }
    sweep(&Model::logreg(), 11, &signatures, &[2, 3, 5]);
}

/// mlp: every signature at 3 workers, plus a combined first+second
/// order signature across the full count sweep. (The full
/// signature × count matrix at mlp size would push several hundred
/// MB of JSON through the debug-build parser for no additional
/// coverage — logreg above runs the full matrix.)
#[test]
fn mlp_signatures_agree_across_worker_counts() {
    let m = Model::mlp();
    let mut signatures: Vec<Vec<String>> = vec![Vec::new()];
    for ext in NATIVE_EXTENSIONS {
        signatures.push(vec![ext.to_string()]);
    }
    sweep(&m, 11, &signatures, &[3]);
    sweep(
        &m,
        11,
        &[vec![
            "batch_grad".to_string(),
            "variance".to_string(),
            "diag_ggn".to_string(),
            "kfac".to_string(),
        ]],
        &[2, 5],
    );
}

/// Conv coverage on 3c3d (895,210 parameters — the largest registry
/// model whose per-op payloads fit `wire::MAX_FRAME`): all nine
/// conv-applicable builtins (kfra is fully-connected-only, paper
/// footnote 5), grouped so each worker reply stays frame-sized.
/// 3 samples on 2 workers: uneven slices (2, 1).
#[test]
fn conv_3c3d_signatures_agree_across_workers() {
    let s = |names: &[&str]| -> Vec<String> {
        names.iter().map(|e| e.to_string()).collect()
    };
    sweep(
        &Model::conv_3c3d(),
        3,
        &[
            s(&["batch_grad", "batch_l2"]),
            s(&["diag_ggn", "kfac", "diag_h"]),
            s(&["diag_ggn_mc", "variance", "sq_moment", "kflr"]),
        ],
        &[2],
    );
}

/// `Concat` rows cross the wire bitwise: per-sample quantities from
/// a 1-worker run (whole batch, pins the JSON round trip) and a
/// 3-worker run (slices, pins global-index addressing) must equal
/// the local serial rows bit for bit.
#[test]
fn concat_rows_are_bitwise_across_worker_counts() {
    let m = Model::mlp();
    let mut rng = Rng::new(0xB17);
    let (params, x, y) = problem(&m, 7, &mut rng);
    let exts =
        vec!["batch_grad".to_string(), "batch_l2".to_string()];
    let serial_opts = ExtractOptions {
        topology: Topology::local(1),
        ..ExtractOptions::default()
    };
    let serial = m
        .extended_backward(&params, &x, &y, &exts, &serial_opts)
        .unwrap();
    let plan = ReducePlan::of(&ExtensionSet::builtin());
    assert!(
        serial.keys().any(|k| plan.is_concat(k)),
        "no per-sample keys — the test would prove nothing"
    );
    for count in [1usize, 3] {
        let addrs = spawn_workers(count);
        let dist = m
            .extended_backward(
                &params,
                &x,
                &y,
                &exts,
                &worker_opts(&addrs, None),
            )
            .unwrap();
        shutdown_workers(&addrs);
        for (k, want) in &serial {
            if !plan.is_concat(k) {
                continue;
            }
            let got = &dist[k];
            assert_eq!(got.shape, want.shape, "{k} workers={count}");
            for (i, (u, v)) in want
                .f32s()
                .unwrap()
                .iter()
                .zip(got.f32s().unwrap())
                .enumerate()
            {
                assert_eq!(
                    u.to_bits(),
                    v.to_bits(),
                    "{k}[{i}] workers={count}: {u} vs {v}"
                );
            }
        }
    }
}

/// A TCP endpoint that accepts one connection and immediately drops
/// it — the shape of a worker process dying mid-protocol.
fn dead_worker_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = l.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        if let Ok((s, _)) = l.accept() {
            drop(s);
        }
    });
    addr
}

/// A worker that dies while a reply is owed surfaces as a
/// coordinator error naming that worker — never a hang, never a
/// partial result.
#[test]
fn dead_worker_is_a_named_error_not_a_hang() {
    let m = Model::logreg();
    let mut rng = Rng::new(5);
    let (params, x, y) = problem(&m, 6, &mut rng);
    let live = spawn_workers(1);
    let addrs = vec![live[0].clone(), dead_worker_addr()];
    let err = m
        .extended_backward(
            &params,
            &x,
            &y,
            &["batch_grad".to_string()],
            &worker_opts(&addrs, None),
        )
        .unwrap_err();
    let err = format!("{err:#}");
    assert!(err.contains("shard worker 1"), "{err}");
    assert!(
        err.contains("closed the connection")
            || err.contains("sending to"),
        "{err}"
    );
    shutdown_workers(&live);
}

/// An op a worker rejects (here: kfra on a conv model, which the
/// engine refuses) comes back as the worker's own error message
/// under a "rejected the request" context — the error-reply path,
/// end to end.
#[test]
fn worker_rejection_surfaces_the_workers_error() {
    let m = Model::conv_3c3d();
    let mut rng = Rng::new(11);
    let (params, x, y) = problem(&m, 2, &mut rng);
    let addrs = spawn_workers(1);
    let err = m
        .extended_backward(
            &params,
            &x,
            &y,
            &["kfra".to_string()],
            &worker_opts(&addrs, None),
        )
        .unwrap_err();
    let err = format!("{err:#}");
    assert!(err.contains("rejected the request"), "{err}");
    shutdown_workers(&addrs);
}

/// Topology misuse fails before any process is contacted: a custom
/// registry cannot cross the process boundary, and a non-empty
/// address list must match the worker count.
#[test]
fn coordinator_validates_before_contacting_workers() {
    let m = Model::logreg();
    let mut rng = Rng::new(3);
    let (params, x, y) = problem(&m, 4, &mut rng);
    let opts = ExtractOptions {
        registry: Some(ExtensionSet::builtin()),
        topology: Topology::workers(2),
        ..ExtractOptions::default()
    };
    let err = format!(
        "{:#}",
        m.extended_backward(&params, &x, &y, &[], &opts)
            .unwrap_err()
    );
    assert!(err.contains("cannot cross the process"), "{err}");
    let opts = ExtractOptions {
        topology: Topology::Workers {
            n: 3,
            addrs: vec!["127.0.0.1:1".to_string()],
        },
        ..ExtractOptions::default()
    };
    let err = format!(
        "{:#}",
        m.extended_backward(&params, &x, &y, &[], &opts)
            .unwrap_err()
    );
    assert!(err.contains("one address per worker"), "{err}");
}

/// 2c2d does not fit `backpack-shard/v1`: its 3,274,634 parameters
/// serialize past the 64 MiB frame cap in the plan op (and its
/// replies past it again). The coordinator must surface that as a
/// clean error — frame-limit or worker-side close — not a hang.
#[test]
fn conv_2c2d_overflows_the_frame_cap_with_a_clean_error() {
    let m = Model::conv_2c2d();
    let mut rng = Rng::new(7);
    let (params, x, y) = problem(&m, 1, &mut rng);
    let addrs = spawn_workers(1);
    let err = m
        .extended_backward(
            &params,
            &x,
            &y,
            &["batch_grad".to_string()],
            &worker_opts(&addrs, None),
        )
        .unwrap_err();
    let err = format!("{err:#}");
    assert!(
        err.contains("exceeds")
            || err.contains("closed the connection"),
        "{err}"
    );
    shutdown_workers(&addrs);
}

/// The public reduce authority, key by key: per-sample quantities
/// concatenate, everything else (including pre-finish moment
/// intermediates and the loss) sums.
#[test]
fn reduce_plan_rules_per_key() {
    let plan = ReducePlan::of(&ExtensionSet::builtin());
    for (key, rule) in [
        ("loss", ReduceRule::Sum),
        ("grad/0/w", ReduceRule::Sum),
        ("batch_grad/0/w", ReduceRule::Concat),
        ("batch_l2/2/b", ReduceRule::Concat),
        ("sq_moment/0/w", ReduceRule::Sum),
        ("variance/0/w", ReduceRule::Sum),
        ("diag_ggn/1/w", ReduceRule::Sum),
        ("diag_ggn_mc/1/b", ReduceRule::Sum),
        ("diag_h/0/w", ReduceRule::Sum),
        ("kfac/0/w", ReduceRule::Sum),
        ("kflr/0/w", ReduceRule::Sum),
        ("kfra/0/w", ReduceRule::Sum),
    ] {
        assert_eq!(plan.rule(key), rule, "{key}");
        assert_eq!(
            plan.is_concat(key),
            rule == ReduceRule::Concat,
            "{key}"
        );
    }
}

/// ReducePlan::merge is the coordinator's exact all-reduce: Sum keys
/// add elementwise, Concat keys stack rows in part order, and key
/// drift between parts is an error, not a silent union.
#[test]
fn reduce_plan_merges_sum_and_concat() {
    let plan = ReducePlan::of(&ExtensionSet::builtin());
    let part = |g: f32, rows: &[f32]| -> Quantities {
        let mut q = Quantities::new();
        q.insert(
            "grad/0/w".to_string(),
            Tensor::from_f32(&[2], vec![g, g * 2.0]),
        );
        q.insert(
            "batch_grad/0/w".to_string(),
            Tensor::from_f32(&[rows.len(), 1], rows.to_vec()),
        );
        q
    };
    let merged = plan
        .merge(vec![part(1.0, &[10.0, 20.0]), part(0.5, &[30.0])])
        .unwrap();
    assert_eq!(merged["grad/0/w"].f32s().unwrap(), &[1.5, 3.0]);
    assert_eq!(merged["batch_grad/0/w"].shape, vec![3, 1]);
    assert_eq!(
        merged["batch_grad/0/w"].f32s().unwrap(),
        &[10.0, 20.0, 30.0]
    );
    let mut drifted = part(1.0, &[1.0]);
    drifted.remove("grad/0/w");
    drifted.insert(
        "grad/0/b".to_string(),
        Tensor::from_f32(&[1], vec![0.0]),
    );
    assert!(plan
        .merge(vec![part(1.0, &[1.0]), drifted])
        .is_err());
}
