//! Integration tests for the walk-level span recorder (DESIGN.md
//! §12) against the live engine: span balance, thread-count
//! invariance of the recorded structure, a cold (disabled) recorder
//! staying silent, and the two output schemas.
//!
//! The recorder is process-global, so every test serializes on one
//! mutex; unit-level shape tests (metrics golden, counter names) live
//! next to the implementation in `src/obs/`.

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

use backpack_rs::backend::conv::Shape;
use backpack_rs::backend::layers::Layer;
use backpack_rs::backend::model::{Model, NATIVE_EXTENSIONS};
use backpack_rs::data::Rng;
use backpack_rs::json::Json;
use backpack_rs::obs;
use backpack_rs::runtime::Tensor;

/// One guard for the process-global recorder. Poisoning is harmless
/// here (each test starts with `obs::start()` or `obs::stop()`), so
/// a panicked neighbor must not cascade.
fn lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Seeded random parameters + batch for a registry model.
fn problem(
    m: &Model,
    n: usize,
    seed: u64,
) -> (Vec<Tensor>, Tensor, Tensor) {
    let mut rng = Rng::new(0x0B5 ^ seed);
    let params: Vec<Tensor> = m
        .param_specs()
        .iter()
        .map(|t| {
            let k: usize = t.shape.iter().product();
            Tensor::from_f32(
                &t.shape,
                (0..k).map(|_| rng.normal() * 0.05).collect(),
            )
        })
        .collect();
    let x: Vec<f32> = (0..n * m.in_dim).map(|_| rng.normal()).collect();
    let y: Vec<i32> =
        (0..n).map(|_| rng.below(m.classes) as i32).collect();
    (
        params,
        Tensor::from_f32(&[n, m.in_dim], x),
        Tensor::from_i32(&[n], y),
    )
}

/// The all-signature sweep: plain gradient plus every built-in
/// extension on its own.
fn signatures() -> Vec<Vec<String>> {
    let mut sigs: Vec<Vec<String>> = vec![Vec::new()];
    for ext in NATIVE_EXTENSIONS {
        sigs.push(vec![ext.to_string()]);
    }
    sigs
}

/// Per-lane multiset of `(cat, name)` work spans. Engine containers
/// (`fork_join`) and shard wall-clock spans are structural; `setup`,
/// `reduce` and `finish` run once on the caller lane only -- all are
/// excluded so the remaining multiset describes exactly the work one
/// shard executes, which must not depend on the thread count.
type SpanMultiset = BTreeMap<(String, String), usize>;

fn work_multisets(trace: &obs::Trace) -> BTreeMap<usize, SpanMultiset> {
    let mut lanes: BTreeMap<usize, SpanMultiset> = BTreeMap::new();
    for e in &trace.events {
        let structural = e.cat == obs::CAT_ENGINE
            || e.cat == obs::CAT_SHARD
            || matches!(e.name.as_str(), "setup" | "reduce" | "finish");
        if structural {
            continue;
        }
        *lanes
            .entry(e.lane)
            .or_default()
            .entry((e.cat.to_string(), e.name.clone()))
            .or_insert(0) += 1;
    }
    lanes
}

/// The tentpole invariance property: a 1-thread and a {2, 3, 5}-thread
/// run of the all-signature sweep record identical span name/count
/// multisets on every lane -- the traced structure is a function of
/// (model, signature), never of the sharding.
#[test]
fn span_multisets_are_thread_count_invariant() {
    let _g = lock();
    let m = Model::mlp();
    let n = 8; // uneven shards at 3 and 5 threads
    let (params, x, y) = problem(&m, n, 1);
    let key = Some([7u32, 0xC0FE]);
    let sweep = |threads: usize| -> obs::Trace {
        obs::start();
        for exts in &signatures() {
            m.extended_backward_threads(
                &params, &x, &y, exts, key, threads,
            )
            .unwrap();
        }
        obs::stop()
    };

    let serial = work_multisets(&sweep(1));
    assert_eq!(serial.len(), 1, "serial run must stay on lane 0");
    let reference = serial[&0].clone();
    assert!(
        reference.keys().any(|(cat, _)| cat == "phase"),
        "reference multiset records no phases: {reference:?}"
    );

    for threads in [2usize, 3, 5] {
        let lanes = work_multisets(&sweep(threads));
        assert_eq!(
            lanes.len(),
            threads,
            "threads={threads}: expected one lane per shard"
        );
        for (lane, multiset) in &lanes {
            assert_eq!(
                multiset, &reference,
                "threads={threads} lane={lane}: span multiset \
                 diverges from the serial run"
            );
        }
    }
}

/// Spans balance: every recorded event is a *complete* interval, and
/// the non-overlapping guarantee of `CAT_PHASE` holds per lane --
/// each phase closes (start + dur) before the next one on that lane
/// opens. This is what makes per-lane phase sums tile the run.
#[test]
fn phase_spans_are_complete_and_disjoint_per_lane() {
    let _g = lock();
    let m = Model::mlp();
    let (params, x, y) = problem(&m, 9, 2);
    let exts = vec!["diag_ggn".to_string(), "diag_ggn_mc".to_string()];
    obs::start();
    m.extended_backward_threads(&params, &x, &y, &exts, None, 3)
        .unwrap();
    let trace = obs::stop();
    assert!(!trace.is_empty());

    let mut by_lane: BTreeMap<usize, Vec<(u64, u64)>> = BTreeMap::new();
    for e in &trace.events {
        if e.cat == obs::CAT_PHASE {
            by_lane
                .entry(e.lane)
                .or_default()
                .push((e.start_ns, e.dur_ns));
        }
    }
    assert_eq!(by_lane.len(), 3);
    for (lane, mut phases) in by_lane {
        phases.sort_unstable();
        for w in phases.windows(2) {
            let (start, dur) = w[0];
            let (next_start, _) = w[1];
            assert!(
                start + dur <= next_start,
                "lane {lane}: phase [{start}, {}] overlaps the next \
                 phase starting at {next_start}",
                start + dur
            );
        }
    }
}

/// A disabled recorder must record nothing: no events, no counter
/// movement, no lingering thread-local buffers.
#[test]
fn disabled_recorder_emits_zero_events() {
    let _g = lock();
    let _ = obs::stop(); // make sure collection is off and drained
    assert!(!obs::enabled());
    let before = obs::mark();
    let m = Model::mlp();
    let (params, x, y) = problem(&m, 8, 3);
    let exts = vec!["diag_h".to_string(), "kfra".to_string()];
    m.extended_backward_threads(&params, &x, &y, &exts, None, 3)
        .unwrap();
    let delta = obs::since(&before);
    assert!(
        delta.events.is_empty(),
        "disabled run recorded {} events",
        delta.events.len()
    );
    assert_eq!(delta.counters, [0u64; obs::COUNTER_COUNT]);
}

/// With collection on, the per-lane phase spans must account for most
/// of the measured wall-clock of a serial `extended_backward` (the
/// release-build acceptance is >= 90%; debug builds spend more in
/// glue, so this asserts a lenient floor).
#[test]
fn phase_totals_cover_most_of_the_wall_clock() {
    let _g = lock();
    let m = Model::mlp();
    let (params, x, y) = problem(&m, 16, 4);
    let exts = vec!["diag_ggn".to_string()];
    obs::start();
    let started = Instant::now();
    m.extended_backward_threads(&params, &x, &y, &exts, None, 1)
        .unwrap();
    let wall_s = started.elapsed().as_secs_f64();
    let trace = obs::stop();
    let phase_s: f64 =
        trace.phase_totals().values().map(|(_, s)| s).sum();
    assert!(
        phase_s >= 0.5 * wall_s,
        "phases cover {phase_s:.6}s of {wall_s:.6}s wall"
    );
    assert!(
        phase_s <= 1.05 * wall_s,
        "serial phase total {phase_s:.6}s exceeds wall {wall_s:.6}s"
    );
}

/// The two output schemas, produced from a live parallel run: the
/// Chrome trace parses as JSON with complete (`ph: "X"`) events and
/// the `backpack-trace/v1` marker; the metrics summary carries the
/// aggregation keys docs/observability.md documents.
#[test]
fn chrome_trace_and_metrics_schemas_hold_on_a_live_run() {
    let _g = lock();
    let m = Model::mlp();
    let (params, x, y) = problem(&m, 8, 5);
    let exts = vec!["kfac".to_string()];
    obs::start();
    m.extended_backward_threads(
        &params,
        &x,
        &y,
        &exts,
        Some([1, 2]),
        2,
    )
    .unwrap();
    let trace = obs::stop();

    let chrome =
        Json::parse(&trace.chrome_trace().to_string_json()).unwrap();
    assert_eq!(
        chrome.get("otherData").unwrap().get("schema").unwrap(),
        &Json::Str(obs::TRACE_SCHEMA.to_string())
    );
    let events = chrome.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    for ev in events {
        assert_eq!(
            ev.get("ph").unwrap(),
            &Json::Str("X".to_string()),
            "only complete events are emitted"
        );
        for key in ["name", "cat", "pid", "tid", "ts", "dur"] {
            assert!(ev.opt(key).is_some(), "event missing {key:?}");
        }
    }

    let metrics =
        Json::parse(&trace.metrics(0.25).to_string_json()).unwrap();
    assert_eq!(
        metrics.get("schema").unwrap(),
        &Json::Str(obs::METRICS_SCHEMA.to_string())
    );
    for key in
        ["counters", "phases", "quantities", "overhead", "shards"]
    {
        assert!(
            metrics.opt(key).is_some(),
            "metrics summary missing {key:?}"
        );
    }
    let overhead = metrics.get("overhead").unwrap();
    assert!(overhead.get("vs_grad").unwrap().as_f64().unwrap() >= 1.0);
}

/// Kernel counters observe a convolutional backward: im2col
/// materialization bytes and matmul FLOPs are both nonzero, and the
/// extension hooks show up under their quantity names.
#[test]
fn conv_run_moves_kernel_counters() {
    let _g = lock();
    let m = Model::with_input(
        "obs_tiny_conv",
        Shape::new(2, 4, 4),
        vec![
            Layer::Conv2d {
                in_ch: 2,
                out_ch: 4,
                kernel: 3,
                stride: 2,
                pad: 1,
            },
            Layer::Relu,
            Layer::GlobalAvgPool,
        ],
    )
    .unwrap();
    let (params, x, y) = problem(&m, 6, 6);
    let exts = vec!["diag_ggn".to_string()];
    obs::start();
    m.extended_backward_threads(&params, &x, &y, &exts, None, 2)
        .unwrap();
    let trace = obs::stop();
    assert!(trace.counter(obs::Counter::Im2colBytes) > 0);
    assert!(trace.counter(obs::Counter::MatmulFlops) > 0);
    assert!(trace.counter(obs::Counter::ShardNs) > 0);
    let quantities = trace.quantity_totals();
    assert!(
        quantities.keys().any(|q| q == "diag_ggn"),
        "no diag_ggn hook spans in {quantities:?}"
    );
}

/// Shard lanes stay disjoint on the persistent worker pool
/// (DESIGN.md §14): lanes are keyed by *shard index*, not by worker
/// thread, so two traced runs back-to-back on the same warm pool --
/// where any worker may pick up any shard, in any order -- both
/// attribute work to exactly lanes {0..threads-1} with identical
/// per-lane span multisets. A leak of worker identity into lane
/// assignment (or a stale lane left by a previous job) shows up here
/// as an extra lane or a diverging multiset.
#[test]
fn persistent_pool_keeps_shard_lanes_disjoint_across_runs() {
    let _g = lock();
    backpack_rs::parallel::warm(3); // the pool outlives each call
    let m = Model::mlp();
    let (params, x, y) = problem(&m, 10, 7);
    let exts = vec!["variance".to_string(), "diag_ggn".to_string()];
    let run = || {
        obs::start();
        m.extended_backward_threads(&params, &x, &y, &exts, None, 3)
            .unwrap();
        obs::stop()
    };
    let first = work_multisets(&run());
    let second = work_multisets(&run());
    for (label, lanes) in [("first", &first), ("second", &second)] {
        let got: Vec<usize> = lanes.keys().copied().collect();
        assert_eq!(
            got,
            vec![0, 1, 2],
            "{label} run: work landed outside the shard lanes"
        );
    }
    assert_eq!(
        first, second,
        "a warm pool changed the traced structure between runs"
    );
}
