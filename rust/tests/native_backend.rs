//! Integration tests for the native execution backend: the full path
//! manifest-style name -> synthesized spec -> init -> execute ->
//! quantity extraction -> optimizer update, with no artifacts and no
//! XLA. The math checks mirror the paper's Table 1 identities and
//! finite-difference oracles (the role python/tests/ plays for the
//! PJRT artifacts).

use backpack_rs::backend::layers::Layer;
use backpack_rs::backend::model::Model;
use backpack_rs::backend::native::NativeBackend;
use backpack_rs::backend::{Backend, Exec, Outputs};
use backpack_rs::coordinator::train::{build_inputs, init_params};
use backpack_rs::coordinator::{problems, train, TrainConfig};
use backpack_rs::data::Rng;
use backpack_rs::optim::{Hyper, NamedParam};
use backpack_rs::runtime::Tensor;

/// Registry with a small sigmoid MLP (smooth: finite differences are
/// well-behaved) and a tiny linear model (GGN == Hessian exactly).
fn backend_with_test_models() -> NativeBackend {
    let mut be = NativeBackend::new();
    be.register(
        Model::new(
            "tinymlp",
            6,
            vec![
                Layer::Linear { in_dim: 6, out_dim: 5 },
                Layer::Sigmoid,
                Layer::Linear { in_dim: 5, out_dim: 3 },
            ],
        )
        .unwrap(),
    );
    be.register(
        Model::new(
            "tinylin",
            6,
            vec![Layer::Linear { in_dim: 6, out_dim: 4 }],
        )
        .unwrap(),
    );
    be
}

fn random_batch(n: usize, dim: usize, classes: usize, seed: u64)
    -> (Tensor, Tensor) {
    let mut rng = Rng::new(seed ^ 0xF00D);
    let x: Vec<f32> = (0..n * dim).map(|_| rng.normal()).collect();
    let y: Vec<i32> =
        (0..n).map(|_| rng.below(classes) as i32).collect();
    (Tensor::from_f32(&[n, dim], x), Tensor::from_i32(&[n], y))
}

fn run_at(
    exe: &dyn Exec,
    params: &[NamedParam],
    x: &Tensor,
    y: &Tensor,
) -> Outputs {
    exe.run(&build_inputs(params, x.clone(), y.clone(), None))
        .expect("execute")
}

/// Acceptance check: native `grad/*` matches central finite
/// differences of the loss within 1e-3 relative error on the test MLP.
#[test]
fn grad_matches_finite_differences_on_test_mlp() {
    let be = backend_with_test_models();
    let exe = be.load("tinymlp_grad_n8").unwrap();
    let mut params = init_params(exe.spec(), 1);
    let (x, y) = random_batch(8, 6, 3, 1);
    let out = run_at(exe.as_ref(), &params, &x, &y);
    let eps = 1e-2f32;
    for pi in 0..params.len() {
        let gname = params[pi].under("grad");
        let g = out.get(&gname).unwrap().f32s().unwrap().to_vec();
        for idx in 0..params[pi].tensor.numel() {
            let orig = params[pi].tensor.f32s().unwrap()[idx];
            params[pi].tensor.f32s_mut().unwrap()[idx] = orig + eps;
            let lp = run_at(exe.as_ref(), &params, &x, &y)
                .loss()
                .unwrap();
            params[pi].tensor.f32s_mut().unwrap()[idx] = orig - eps;
            let lm = run_at(exe.as_ref(), &params, &x, &y)
                .loss()
                .unwrap();
            params[pi].tensor.f32s_mut().unwrap()[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            let tol = 1e-3 * (1.0 + fd.abs().max(g[idx].abs()));
            assert!(
                (g[idx] - fd).abs() < tol,
                "{gname}[{idx}]: analytic {} vs fd {fd}",
                g[idx]
            );
        }
    }
}

/// For a linear model with cross-entropy, the GGN *is* the Hessian:
/// `diag_ggn` must match central finite differences of the gradient.
#[test]
fn diag_ggn_matches_hessian_diagonal_on_linear_model() {
    let be = backend_with_test_models();
    let exe = be.load("tinylin_diag_ggn_n8").unwrap();
    let mut params = init_params(exe.spec(), 2);
    let (x, y) = random_batch(8, 6, 4, 2);
    let out = run_at(exe.as_ref(), &params, &x, &y);
    let eps = 1e-2f32;
    for pi in 0..params.len() {
        let gname = params[pi].under("grad");
        let dname = params[pi].under("diag_ggn");
        let diag =
            out.get(&dname).unwrap().f32s().unwrap().to_vec();
        for idx in 0..params[pi].tensor.numel() {
            let orig = params[pi].tensor.f32s().unwrap()[idx];
            params[pi].tensor.f32s_mut().unwrap()[idx] = orig + eps;
            let gp = run_at(exe.as_ref(), &params, &x, &y);
            let gp = gp.get(&gname).unwrap().f32s().unwrap()[idx];
            params[pi].tensor.f32s_mut().unwrap()[idx] = orig - eps;
            let gm = run_at(exe.as_ref(), &params, &x, &y);
            let gm = gm.get(&gname).unwrap().f32s().unwrap()[idx];
            params[pi].tensor.f32s_mut().unwrap()[idx] = orig;
            let h = (gp - gm) / (2.0 * eps);
            let tol = 1e-3 + 3e-3 * h.abs().max(diag[idx].abs());
            assert!(
                (diag[idx] - h).abs() < tol,
                "{dname}[{idx}]: {} vs Hessian fd {h}",
                diag[idx]
            );
        }
    }
}

/// `diag_ggn` through nonlinear layers vs a brute-force GGN built from
/// a finite-difference network Jacobian and the exact softmax Hessian.
#[test]
fn diag_ggn_matches_brute_force_ggn_on_mlp() {
    let be = backend_with_test_models();
    let exe = be.load("tinymlp_diag_ggn_n4").unwrap();
    let mut params = init_params(exe.spec(), 3);
    let (x, y) = random_batch(4, 6, 3, 3);
    let out = run_at(exe.as_ref(), &params, &x, &y);
    let (n, c) = (4usize, 3usize);

    let model = Model::new(
        "tinymlp",
        6,
        vec![
            Layer::Linear { in_dim: 6, out_dim: 5 },
            Layer::Sigmoid,
            Layer::Linear { in_dim: 5, out_dim: 3 },
        ],
    )
    .unwrap();
    let tensors = |ps: &[NamedParam]| -> Vec<Tensor> {
        ps.iter().map(|p| p.tensor.clone()).collect()
    };
    let logits = model
        .forward(&tensors(&params), &x)
        .unwrap()
        .f32s()
        .unwrap()
        .to_vec();
    // Softmax probabilities -> per-sample Hessian diag(p) - p pᵀ.
    let mut p = vec![0.0f32; n * c];
    for s in 0..n {
        let row = &logits[s * c..(s + 1) * c];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = row.iter().map(|v| (v - m).exp()).sum();
        for j in 0..c {
            p[s * c + j] = (row[j] - m).exp() / z;
        }
    }

    let eps = 1e-2f32;
    for pi in 0..params.len() {
        let dname = params[pi].under("diag_ggn");
        let diag =
            out.get(&dname).unwrap().f32s().unwrap().to_vec();
        for idx in 0..params[pi].tensor.numel() {
            let orig = params[pi].tensor.f32s().unwrap()[idx];
            params[pi].tensor.f32s_mut().unwrap()[idx] = orig + eps;
            let fp = model
                .forward(&tensors(&params), &x)
                .unwrap()
                .f32s()
                .unwrap()
                .to_vec();
            params[pi].tensor.f32s_mut().unwrap()[idx] = orig - eps;
            let fm = model
                .forward(&tensors(&params), &x)
                .unwrap()
                .f32s()
                .unwrap()
                .to_vec();
            params[pi].tensor.f32s_mut().unwrap()[idx] = orig;
            // Jacobian column j[s][a] = ∂f_a/∂θ_idx per sample.
            // G_ii = (1/N) Σ_n jᵀ (diag(p) − p pᵀ) j.
            let mut want = 0.0f32;
            for s in 0..n {
                let j: Vec<f32> = (0..c)
                    .map(|a| {
                        (fp[s * c + a] - fm[s * c + a]) / (2.0 * eps)
                    })
                    .collect();
                let pj: f32 = (0..c)
                    .map(|a| p[s * c + a] * j[a])
                    .sum();
                for a in 0..c {
                    want += p[s * c + a] * j[a] * j[a];
                }
                want -= pj * pj;
            }
            want /= n as f32;
            let tol = 1e-4 + 3e-2 * want.abs().max(diag[idx].abs());
            assert!(
                (diag[idx] - want).abs() < tol,
                "{dname}[{idx}]: {} vs brute-force {want}",
                diag[idx]
            );
        }
    }
}

/// `diag_h` on the sigmoid MLP vs a brute-force Hessian diagonal from
/// an independent dense f64 recursion (per sample: exact softmax
/// Hessian at the logits, dense `Wᵀ H W` chain rule through the
/// layers, explicit `diag(σ'') ⊙ g` residual at the sigmoid — no
/// square-root factors, no column tricks). The engine's factored f32
/// walk must agree to ≤ 1e-5.
#[test]
fn diag_h_matches_brute_force_hessian_on_sigmoid_mlp() {
    let be = backend_with_test_models();
    let exe = be.load("tinymlp_diag_h_n8").unwrap();
    let params = init_params(exe.spec(), 11);
    let (x, y) = random_batch(8, 6, 3, 11);
    let out = run_at(exe.as_ref(), &params, &x, &y);
    let (n, din, hid, c) = (8usize, 6usize, 5usize, 3usize);

    let w0: Vec<f64> = params[0].tensor.f32s().unwrap().iter()
        .map(|&v| v as f64).collect(); // [5, 6]
    let b0: Vec<f64> = params[1].tensor.f32s().unwrap().iter()
        .map(|&v| v as f64).collect();
    let w1: Vec<f64> = params[2].tensor.f32s().unwrap().iter()
        .map(|&v| v as f64).collect(); // [3, 5]
    let b1: Vec<f64> = params[3].tensor.f32s().unwrap().iter()
        .map(|&v| v as f64).collect();
    let xs: Vec<f64> =
        x.f32s().unwrap().iter().map(|&v| v as f64).collect();
    let ys = y.i32s().unwrap();

    let mut want_w0 = vec![0.0f64; hid * din];
    let mut want_b0 = vec![0.0f64; hid];
    let mut want_w1 = vec![0.0f64; c * hid];
    let mut want_b1 = vec![0.0f64; c];
    for s in 0..n {
        let xv = &xs[s * din..(s + 1) * din];
        // Forward in f64.
        let z0: Vec<f64> = (0..hid)
            .map(|o| {
                b0[o]
                    + (0..din)
                        .map(|i| w0[o * din + i] * xv[i])
                        .sum::<f64>()
            })
            .collect();
        let a: Vec<f64> =
            z0.iter().map(|&v| 1.0 / (1.0 + (-v).exp())).collect();
        let f: Vec<f64> = (0..c)
            .map(|o| {
                b1[o]
                    + (0..hid)
                        .map(|i| w1[o * hid + i] * a[i])
                        .sum::<f64>()
            })
            .collect();
        let m = f.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let z: f64 = f.iter().map(|v| (v - m).exp()).sum();
        let p: Vec<f64> =
            f.iter().map(|v| (v - m).exp() / z).collect();
        // Exact softmax Hessian at the logits.
        let mut hl = vec![0.0f64; c * c];
        for aa in 0..c {
            for bb in 0..c {
                hl[aa * c + bb] = if aa == bb {
                    p[aa] - p[aa] * p[bb]
                } else {
                    -p[aa] * p[bb]
                };
            }
        }
        // Top linear layer: diag H_W1[o,i] = H_L[o,o] · a_i².
        for o in 0..c {
            want_b1[o] += hl[o * c + o];
            for i in 0..hid {
                want_w1[o * hid + i] += hl[o * c + o] * a[i] * a[i];
            }
        }
        // Dense chain rule to the sigmoid input: H_a = W1ᵀ H_L W1,
        // then H_z0 = σ' H_a σ' + diag(σ'' ⊙ g_a).
        let mut gl = p.clone();
        gl[ys[s] as usize] -= 1.0;
        let ga: Vec<f64> = (0..hid)
            .map(|i| (0..c).map(|o| w1[o * hid + i] * gl[o]).sum())
            .collect();
        let mut ha = vec![0.0f64; hid * hid];
        for i in 0..hid {
            for j in 0..hid {
                let mut acc = 0.0;
                for o in 0..c {
                    for q in 0..c {
                        acc += w1[o * hid + i]
                            * hl[o * c + q]
                            * w1[q * hid + j];
                    }
                }
                ha[i * hid + j] = acc;
            }
        }
        let d1: Vec<f64> =
            a.iter().map(|&s| s * (1.0 - s)).collect();
        let d2: Vec<f64> = a
            .iter()
            .map(|&s| s * (1.0 - s) * (1.0 - 2.0 * s))
            .collect();
        let mut hz0 = vec![0.0f64; hid * hid];
        for i in 0..hid {
            for j in 0..hid {
                hz0[i * hid + j] = d1[i] * ha[i * hid + j] * d1[j];
            }
            hz0[i * hid + i] += d2[i] * ga[i];
        }
        // Bottom linear layer: diag H_W0[o,i] = H_z0[o,o] · x_i².
        for o in 0..hid {
            want_b0[o] += hz0[o * hid + o];
            for i in 0..din {
                want_w0[o * din + i] +=
                    hz0[o * hid + o] * xv[i] * xv[i];
            }
        }
    }
    for (name, want) in [
        ("diag_h/0/w", &want_w0),
        ("diag_h/0/b", &want_b0),
        ("diag_h/2/w", &want_w1),
        ("diag_h/2/b", &want_b1),
    ] {
        let got = out.get(name).unwrap().f32s().unwrap();
        assert_eq!(got.len(), want.len(), "{name}");
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            let w = w / n as f64;
            assert!(
                ((*g as f64) - w).abs() <= 1e-5 * (1.0 + w.abs()),
                "{name}[{i}]: engine {g} vs brute-force {w}"
            );
        }
    }
}

/// Paper Table 1 identities on one combined first-order graph:
/// batch_grad rows sum to grad, sq_moment matches the per-sample
/// squares, variance = sq_moment − grad², batch_l2 = ‖row‖².
#[test]
fn first_order_identities() {
    let be = backend_with_test_models();
    let exe = be
        .load("tinymlp_batch_grad+batch_l2+sq_moment+variance_n8")
        .unwrap();
    let params = init_params(exe.spec(), 4);
    let (x, y) = random_batch(8, 6, 3, 4);
    let out = run_at(exe.as_ref(), &params, &x, &y);
    let n = 8usize;
    for p in &params {
        let d = p.tensor.numel();
        let g = out.get(&p.under("grad")).unwrap().f32s().unwrap();
        let bg = out
            .get(&p.under("batch_grad"))
            .unwrap()
            .f32s()
            .unwrap();
        let sq =
            out.get(&p.under("sq_moment")).unwrap().f32s().unwrap();
        let var =
            out.get(&p.under("variance")).unwrap().f32s().unwrap();
        let l2 =
            out.get(&p.under("batch_l2")).unwrap().f32s().unwrap();
        assert_eq!(bg.len(), n * d);
        for i in 0..d {
            // Individual gradients are 1/N-scaled: rows sum to grad.
            let sum: f32 = (0..n).map(|s| bg[s * d + i]).sum();
            assert!(
                (sum - g[i]).abs() <= 1e-6 + 1e-4 * g[i].abs(),
                "{}: Σ_n batch_grad {sum} != grad {}",
                p.name, g[i]
            );
            // 2nd moment = (1/N) Σ (∇ℓ_n)² = N Σ batch_grad².
            let want: f32 =
                (0..n).map(|s| bg[s * d + i].powi(2)).sum::<f32>()
                    * n as f32;
            assert!(
                (sq[i] - want).abs() <= 1e-6 + 1e-3 * want.abs(),
                "{}: sq_moment {} != {want}", p.name, sq[i]
            );
            // Variance identity (Table 1).
            let wantv = sq[i] - g[i] * g[i];
            assert!(
                (var[i] - wantv).abs() <= 1e-6 + 1e-3 * wantv.abs(),
                "{}: variance {} != {wantv}", p.name, var[i]
            );
            assert!(var[i] >= -1e-6, "variance must be >= 0");
        }
        for s in 0..n {
            let want: f32 =
                (0..d).map(|i| bg[s * d + i].powi(2)).sum();
            assert!(
                (l2[s] - want).abs() <= 1e-9 + 1e-3 * want.abs(),
                "{}: batch_l2[{s}] {} != {want}", p.name, l2[s]
            );
        }
    }
}

/// Kronecker factors: shapes, PSD diagonals, and (for the last linear
/// layer) B == bias_ggn == the exact output-Hessian average that KFRA
/// also produces there.
#[test]
fn kron_factors_are_consistent() {
    let be = backend_with_test_models();
    let exe = be.load("tinymlp_kflr+kfra_n16").unwrap();
    let params = init_params(exe.spec(), 5);
    let (x, y) = random_batch(16, 6, 3, 5);
    let out = run_at(exe.as_ref(), &params, &x, &y);
    for (layer, da, db) in [(0usize, 6usize, 5usize), (2, 5, 3)] {
        for ext in ["kflr", "kfra"] {
            let a = out.get(&format!("{ext}/{layer}/A")).unwrap();
            let b = out.get(&format!("{ext}/{layer}/B")).unwrap();
            assert_eq!(a.shape, vec![da, da], "{ext}/{layer}/A");
            assert_eq!(b.shape, vec![db, db], "{ext}/{layer}/B");
            let av = a.f32s().unwrap();
            for i in 0..da {
                assert!(av[i * da + i] >= -1e-6, "{ext} A diag");
                for j in 0..da {
                    assert!(
                        (av[i * da + j] - av[j * da + i]).abs() < 1e-4,
                        "{ext} A symmetric"
                    );
                }
            }
        }
    }
    // At the network's last linear layer KFLR's B (exact S Sᵀ average)
    // equals KFRA's Ḡ (exact Hessian average): both are
    // 1/N Σ diag(p) − p pᵀ.
    let kflr_b = out.get("kflr/2/B").unwrap().f32s().unwrap();
    let kfra_b = out.get("kfra/2/B").unwrap().f32s().unwrap();
    for (u, v) in kflr_b.iter().zip(kfra_b) {
        assert!((u - v).abs() < 1e-5, "KFLR B {u} vs KFRA Ḡ {v}");
    }
}

/// End-to-end training: every optimizer reduces the loss on
/// mnist_logreg through the native backend (no artifacts on disk).
#[test]
fn training_reduces_loss_for_every_optimizer_natively() {
    let be = NativeBackend::new();
    let problem = problems::by_name("mnist_logreg").unwrap();
    // The Kronecker optimizers' graphs pay a 784x784 A-factor per
    // step (and a 784 Cholesky on refresh), which is slow in debug
    // builds -- give them fewer, stronger steps; the cheap optimizers
    // get enough steps to clear inter-batch loss noise.
    for (opt, lr, damping, steps) in [
        ("sgd", 0.1, 0.0, 25),
        ("momentum", 0.02, 0.0, 25),
        ("adam", 0.003, 0.0, 25),
        ("diag_ggn", 0.01, 0.01, 25),
        ("diag_ggn_mc", 0.01, 0.01, 25),
        ("kfac", 0.01, 0.01, 8),
        ("kflr", 0.01, 0.01, 8),
        ("kfra", 0.01, 0.01, 8),
    ] {
        let cfg = TrainConfig {
            problem: problem.codename.into(),
            optimizer: opt.into(),
            hyper: Hyper { lr, damping, l2: 0.0 },
            steps,
            seed: 0,
            eval_every: steps - 1,
            inv_every: steps,
            log_every: steps - 1,
            verbose: false,
        };
        let log = train::train(&be, problem, &cfg).unwrap();
        assert!(!log.diverged, "{opt} diverged");
        let first = log.train_loss.first().unwrap().1;
        let last = log.final_train_loss();
        assert!(
            last < first,
            "{opt}: loss did not decrease ({first} -> {last})"
        );
    }
}

/// The mnist_mlp problem (full native layer set) also trains.
#[test]
fn mlp_problem_trains_with_diag_ggn() {
    let be = NativeBackend::new();
    let problem = problems::by_name("mnist_mlp").unwrap();
    let cfg = TrainConfig {
        problem: problem.codename.into(),
        optimizer: "diag_ggn".into(),
        hyper: Hyper { lr: 0.05, damping: 0.01, l2: 0.0 },
        steps: 15,
        seed: 0,
        eval_every: 14,
        inv_every: 1,
        log_every: 14,
        verbose: false,
    };
    let log = train::train(&be, problem, &cfg).unwrap();
    assert!(!log.diverged);
    let first = log.train_loss.first().unwrap().1;
    let last = log.final_train_loss();
    assert!(last < first, "mlp loss {first} -> {last}");
}

#[test]
fn seeds_are_reproducible_natively() {
    let be = NativeBackend::new();
    let problem = problems::by_name("mnist_logreg").unwrap();
    let cfg = TrainConfig {
        problem: problem.codename.into(),
        optimizer: "diag_ggn".into(),
        hyper: Hyper { lr: 0.01, damping: 0.01, l2: 0.0 },
        steps: 8,
        seed: 7,
        eval_every: 7,
        inv_every: 1,
        log_every: 1,
        verbose: false,
    };
    let a = train::train(&be, problem, &cfg).unwrap();
    let b = train::train(&be, problem, &cfg).unwrap();
    assert_eq!(a.train_loss, b.train_loss, "same seed, same curve");
    let mut cfg2 = cfg.clone();
    cfg2.seed = 8;
    let c = train::train(&be, problem, &cfg2).unwrap();
    assert_ne!(a.train_loss, c.train_loss, "different seed differs");
}

/// Regression test for the step-time accounting fix: when a run
/// diverges after a couple of steps, `step_time_s` must average over
/// the steps actually executed, not the configured step count.
#[test]
fn step_time_averages_over_executed_steps_on_divergence() {
    let be = NativeBackend::new();
    let problem = problems::by_name("mnist_logreg").unwrap();
    let cfg = TrainConfig {
        problem: problem.codename.into(),
        optimizer: "sgd".into(),
        hyper: Hyper { lr: f32::MAX, damping: 0.0, l2: 0.0 },
        steps: 1000,
        seed: 0,
        eval_every: 1_000_000,
        inv_every: 1,
        log_every: 1,
        verbose: false,
    };
    let log = train::train(&be, problem, &cfg).unwrap();
    assert!(log.diverged, "f32::MAX learning rate must diverge");
    assert!(
        (1..=4).contains(&log.steps_run),
        "diverged within a few steps, ran {}",
        log.steps_run
    );
    assert!(log.train_loss.len() <= log.steps_run);
    // Old bug: exec_total / cfg.steps -> ~500x too small. Averaging
    // over the ~2 executed steps keeps step_time within the same
    // order of magnitude as the wall clock per executed step. (The
    // ratio bound only holds where exec dominates: debug builds.)
    if cfg!(debug_assertions) {
        assert!(
            log.step_time_s > log.wall_time_s / 100.0,
            "step_time_s {} vs wall {}",
            log.step_time_s, log.wall_time_s
        );
    }
}
