//! Integration tests for the native convolution subsystem
//! (`backend/conv/`): finite-difference oracles for `Conv2d` /
//! `MaxPool2d` / `GlobalAvgPool`, brute-force GGN and full-Hessian
//! (`diag_h`, dense f64 residual recursion) checks through conv
//! stacks, the diag_h ≡ diag_ggn coincidence on piecewise-linear
//! models, the paper's Table-1 identities on a conv model, the
//! 1x1-conv ≡ Linear reduction of every extraction rule, the KFRA
//! fully-connected-only invariant, and one-step servability of all
//! registered problems on the native backend.
//!
//! Models here are tiny (debug-build test budget); the real 2c2d /
//! 3c3d / allcnnc registry models are exercised at the spec level and
//! with single gradient steps.

use backpack_rs::backend::conv::Shape;
use backpack_rs::backend::layers::Layer;
use backpack_rs::backend::model::{ExtractOptions, Model};
use backpack_rs::backend::native::NativeBackend;
use backpack_rs::backend::{Backend, Exec, Outputs};
use backpack_rs::coordinator::problems::PROBLEMS;
use backpack_rs::coordinator::train::{build_inputs, init_params};
use backpack_rs::data::Rng;
use backpack_rs::optim::NamedParam;
use backpack_rs::runtime::Tensor;

/// Conv + ceil-mode max-pool + dense, with a *smooth* activation so
/// finite differences are well-behaved away from the pool's argmax
/// routing.
fn tiny_conv() -> Model {
    Model::with_input(
        "tinyconv",
        Shape::new(2, 5, 5),
        vec![
            Layer::Conv2d {
                in_ch: 2, out_ch: 3, kernel: 3, stride: 1, pad: 1,
            },
            Layer::Sigmoid,
            Layer::MaxPool2d { kernel: 2, stride: 2, ceil: true },
            Layer::Flatten,
            Layer::Linear { in_dim: 27, out_dim: 4 },
        ],
    )
    .unwrap()
}

/// Stride-2 'same' conv + global average pool (the All-CNN-C shape
/// vocabulary) ending directly in pooled logits.
fn tiny_gap() -> Model {
    Model::with_input(
        "tinygap",
        Shape::new(2, 4, 4),
        vec![
            Layer::Conv2d {
                in_ch: 2, out_ch: 4, kernel: 3, stride: 2, pad: 1,
            },
            Layer::Sigmoid,
            Layer::Conv2d {
                in_ch: 4, out_ch: 3, kernel: 1, stride: 1, pad: 0,
            },
            Layer::GlobalAvgPool,
        ],
    )
    .unwrap()
}

fn backend_with_test_models() -> NativeBackend {
    let mut be = NativeBackend::new();
    be.register(tiny_conv());
    be.register(tiny_gap());
    be
}

fn random_batch(n: usize, dim: usize, classes: usize, seed: u64)
    -> (Tensor, Tensor) {
    let mut rng = Rng::new(seed ^ 0xF00D);
    let x: Vec<f32> = (0..n * dim).map(|_| rng.normal()).collect();
    let y: Vec<i32> =
        (0..n).map(|_| rng.below(classes) as i32).collect();
    (Tensor::from_f32(&[n, dim], x), Tensor::from_i32(&[n], y))
}

/// Random batch in the artifact's own `x` layout (`[n, c, h, w]` for
/// image models -- what the data pipeline ships and `Exec::run`
/// validates against).
fn spec_batch(spec: &backpack_rs::runtime::ArtifactSpec, seed: u64)
    -> (Tensor, Tensor) {
    let xsh = spec
        .inputs
        .iter()
        .find(|t| t.name == "x")
        .expect("x input")
        .shape
        .clone();
    let n = xsh[0];
    let dim: usize = xsh[1..].iter().product();
    let mut rng = Rng::new(seed ^ 0xF00D);
    let x: Vec<f32> = (0..n * dim).map(|_| rng.normal()).collect();
    let y: Vec<i32> = (0..n)
        .map(|_| rng.below(spec.num_classes) as i32)
        .collect();
    (Tensor::from_f32(&xsh, x), Tensor::from_i32(&[n], y))
}

fn run_at(
    exe: &dyn Exec,
    params: &[NamedParam],
    x: &Tensor,
    y: &Tensor,
) -> Outputs {
    exe.run(&build_inputs(params, x.clone(), y.clone(), None))
        .expect("execute")
}

/// Central finite differences of the loss against `grad/*` for every
/// parameter of `artifact`. `abs`/`rel` set the tolerance: the smooth
/// (pool-free) model uses the acceptance bound ≤ 1e-3 relative; the
/// max-pool model allows slightly more, because a parameter
/// perturbation can flip a window argmax inside the fd stencil (the
/// loss stays continuous, but the two-sided difference then averages
/// two routing branches the analytic gradient rightly does not).
fn check_grad_fd(be: &NativeBackend, artifact: &str, seed: u64,
                 abs: f32, rel: f32) {
    let exe = be.load(artifact).unwrap();
    let mut params = init_params(exe.spec(), seed);
    let (x, y) = spec_batch(exe.spec(), seed);
    let out = run_at(exe.as_ref(), &params, &x, &y);
    let eps = 5e-3f32;
    for pi in 0..params.len() {
        let gname = params[pi].under("grad");
        let g = out.get(&gname).unwrap().f32s().unwrap().to_vec();
        for idx in 0..params[pi].tensor.numel() {
            let orig = params[pi].tensor.f32s().unwrap()[idx];
            params[pi].tensor.f32s_mut().unwrap()[idx] = orig + eps;
            let lp =
                run_at(exe.as_ref(), &params, &x, &y).loss().unwrap();
            params[pi].tensor.f32s_mut().unwrap()[idx] = orig - eps;
            let lm =
                run_at(exe.as_ref(), &params, &x, &y).loss().unwrap();
            params[pi].tensor.f32s_mut().unwrap()[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            let tol = abs + rel * (1.0 + fd.abs().max(g[idx].abs()));
            assert!(
                (g[idx] - fd).abs() < tol,
                "{artifact} {gname}[{idx}]: analytic {} vs fd {fd}",
                g[idx]
            );
        }
    }
}

#[test]
fn conv_and_maxpool_grad_matches_finite_differences() {
    let be = backend_with_test_models();
    check_grad_fd(&be, "tinyconv_grad_n6", 1, 2e-3, 5e-3);
}

#[test]
fn strided_conv_and_gap_grad_matches_finite_differences() {
    // Smooth model (no max-pool): the strict ≤ 1e-3 acceptance bound.
    let be = backend_with_test_models();
    check_grad_fd(&be, "tinygap_grad_n5", 2, 0.0, 1e-3);
}

/// `diag_ggn` through conv + pool vs a brute-force GGN from a
/// finite-difference network Jacobian and the exact softmax Hessian
/// (the conv twin of the MLP check in `tests/native_backend.rs`).
#[test]
fn conv_diag_ggn_matches_brute_force_ggn() {
    let be = backend_with_test_models();
    let exe = be.load("tinyconv_diag_ggn_n3").unwrap();
    let mut params = init_params(exe.spec(), 3);
    let (x, y) = spec_batch(exe.spec(), 3);
    let out = run_at(exe.as_ref(), &params, &x, &y);
    let (n, c) = (3usize, 4usize);

    let model = tiny_conv();
    let tensors = |ps: &[NamedParam]| -> Vec<Tensor> {
        ps.iter().map(|p| p.tensor.clone()).collect()
    };
    let logits = model
        .forward(&tensors(&params), &x)
        .unwrap()
        .f32s()
        .unwrap()
        .to_vec();
    let mut p = vec![0.0f32; n * c];
    for s in 0..n {
        let row = &logits[s * c..(s + 1) * c];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = row.iter().map(|v| (v - m).exp()).sum();
        for j in 0..c {
            p[s * c + j] = (row[j] - m).exp() / z;
        }
    }

    let eps = 1e-2f32;
    for pi in 0..params.len() {
        let dname = params[pi].under("diag_ggn");
        let diag = out.get(&dname).unwrap().f32s().unwrap().to_vec();
        for idx in (0..params[pi].tensor.numel()).step_by(2) {
            let orig = params[pi].tensor.f32s().unwrap()[idx];
            params[pi].tensor.f32s_mut().unwrap()[idx] = orig + eps;
            let fp = model
                .forward(&tensors(&params), &x)
                .unwrap()
                .f32s()
                .unwrap()
                .to_vec();
            params[pi].tensor.f32s_mut().unwrap()[idx] = orig - eps;
            let fm = model
                .forward(&tensors(&params), &x)
                .unwrap()
                .f32s()
                .unwrap()
                .to_vec();
            params[pi].tensor.f32s_mut().unwrap()[idx] = orig;
            // G_ii = (1/N) Σ_n jᵀ (diag(p) − p pᵀ) j.
            let mut want = 0.0f32;
            for s in 0..n {
                let j: Vec<f32> = (0..c)
                    .map(|a| {
                        (fp[s * c + a] - fm[s * c + a]) / (2.0 * eps)
                    })
                    .collect();
                let pj: f32 =
                    (0..c).map(|a| p[s * c + a] * j[a]).sum();
                for a in 0..c {
                    want += p[s * c + a] * j[a] * j[a];
                }
                want -= pj * pj;
            }
            want /= n as f32;
            let tol = 1e-4 + 3e-2 * want.abs().max(diag[idx].abs());
            assert!(
                (diag[idx] - want).abs() < tol,
                "{dname}[{idx}]: {} vs brute-force {want}",
                diag[idx]
            );
        }
    }
}

/// `diag_h` through conv + sigmoid + 1x1-conv + GAP vs a brute-force
/// Hessian diagonal from an independent dense f64 recursion: exact
/// softmax Hessian at the logits, dense `Jᵀ H J` chain rule through
/// GAP and the 1x1 conv, an explicit `diag(σ'' ⊙ g)` residual at the
/// sigmoid, and the conv weight diagonal from an explicit-index
/// im2col double contraction — no square-root factors anywhere. The
/// engine's factored f32 walk must agree to ≤ 1e-5.
#[test]
fn conv_diag_h_matches_brute_force_hessian_on_conv_gap() {
    let be = backend_with_test_models();
    let exe = be.load("tinygap_diag_h_n3").unwrap();
    let params = init_params(exe.spec(), 13);
    let (x, y) = spec_batch(exe.spec(), 13);
    let out = run_at(exe.as_ref(), &params, &x, &y);

    // tiny_gap geometry: conv0 (2,4,4)->(4,2,2) k3 s2 p1 (J0=18,
    // P=4), sigmoid, conv1x1 (4,2,2)->(3,2,2) (J1=4), GAP -> 3.
    let (n, cin, hw, p_n) = (3usize, 2usize, 4usize, 4usize);
    let (c0, c1) = (4usize, 3usize);
    let (j0, f0) = (18usize, 16usize);
    let f64s = |t: &Tensor| -> Vec<f64> {
        t.f32s().unwrap().iter().map(|&v| v as f64).collect()
    };
    let w0 = f64s(&params[0].tensor); // [4, 2, 3, 3] -> [4, 18]
    let b0 = f64s(&params[1].tensor);
    let w1 = f64s(&params[2].tensor); // [3, 4, 1, 1] -> [3, 4]
    let b1 = f64s(&params[3].tensor);
    let xs = f64s(&x);
    let ys = y.i32s().unwrap();

    // Explicit-index im2col for conv0: U0[j, p] with j = ci·9 +
    // ky·3 + kx, p = oy·2 + ox, input pixel (oy·2+ky−1, ox·2+kx−1).
    let unfold0 = |xv: &[f64]| -> Vec<f64> {
        let mut u = vec![0.0f64; j0 * p_n];
        for ci in 0..cin {
            for ky in 0..3usize {
                for kx in 0..3usize {
                    let j = ci * 9 + ky * 3 + kx;
                    for oy in 0..2usize {
                        for ox in 0..2usize {
                            let (iy, ix) = (
                                (oy * 2 + ky) as isize - 1,
                                (ox * 2 + kx) as isize - 1,
                            );
                            if (0..hw as isize).contains(&iy)
                                && (0..hw as isize).contains(&ix)
                            {
                                u[j * p_n + oy * 2 + ox] = xv[ci
                                    * hw
                                    * hw
                                    + iy as usize * hw
                                    + ix as usize];
                            }
                        }
                    }
                }
            }
        }
        u
    };

    let mut want_w0 = vec![0.0f64; c0 * j0];
    let mut want_b0 = vec![0.0f64; c0];
    let mut want_w1 = vec![0.0f64; c1 * c0];
    let mut want_b1 = vec![0.0f64; c1];
    for s in 0..n {
        let xv = &xs[s * cin * hw * hw..(s + 1) * cin * hw * hw];
        let u0 = unfold0(xv);
        // Forward in f64.
        let mut z0 = vec![0.0f64; f0]; // [(o, p)]
        for o in 0..c0 {
            for p in 0..p_n {
                z0[o * p_n + p] = b0[o]
                    + (0..j0)
                        .map(|j| w0[o * j0 + j] * u0[j * p_n + p])
                        .sum::<f64>();
            }
        }
        let a: Vec<f64> =
            z0.iter().map(|&v| 1.0 / (1.0 + (-v).exp())).collect();
        let mut z1 = vec![0.0f64; c1 * p_n];
        for o in 0..c1 {
            for p in 0..p_n {
                z1[o * p_n + p] = b1[o]
                    + (0..c0)
                        .map(|i| w1[o * c0 + i] * a[i * p_n + p])
                        .sum::<f64>();
            }
        }
        let f: Vec<f64> = (0..c1)
            .map(|o| {
                z1[o * p_n..(o + 1) * p_n].iter().sum::<f64>()
                    / p_n as f64
            })
            .collect();
        let m = f.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let z: f64 = f.iter().map(|v| (v - m).exp()).sum();
        let prob: Vec<f64> =
            f.iter().map(|v| (v - m).exp() / z).collect();
        let mut hl = vec![0.0f64; c1 * c1];
        for aa in 0..c1 {
            for bb in 0..c1 {
                hl[aa * c1 + bb] = if aa == bb {
                    prob[aa] - prob[aa] * prob[bb]
                } else {
                    -prob[aa] * prob[bb]
                };
            }
        }
        let mut gf = prob.clone();
        gf[ys[s] as usize] -= 1.0;
        // GAP is linear: H at z1 and the gradient there.
        let hz1 = |o: usize, p: usize, o2: usize, p2: usize| -> f64 {
            let _ = (p, p2);
            hl[o * c1 + o2] / (p_n * p_n) as f64
        };
        // 1x1-conv weight diagonal (U1[i, p] = a[(i, p)]).
        for o in 0..c1 {
            for i in 0..c0 {
                let mut acc = 0.0;
                for p in 0..p_n {
                    for p2 in 0..p_n {
                        acc += a[i * p_n + p]
                            * a[i * p_n + p2]
                            * hz1(o, p, o, p2);
                    }
                }
                want_w1[o * c0 + i] += acc;
            }
            let mut acc = 0.0;
            for p in 0..p_n {
                for p2 in 0..p_n {
                    acc += hz1(o, p, o, p2);
                }
            }
            want_b1[o] += acc;
        }
        // Dense H and gradient at the sigmoid output a [(i, p)].
        let mut ha = vec![0.0f64; f0 * f0];
        for i in 0..c0 {
            for p in 0..p_n {
                for i2 in 0..c0 {
                    for p2 in 0..p_n {
                        let mut acc = 0.0;
                        for o in 0..c1 {
                            for o2 in 0..c1 {
                                acc += w1[o * c0 + i]
                                    * w1[o2 * c0 + i2]
                                    * hz1(o, p, o2, p2);
                            }
                        }
                        ha[(i * p_n + p) * f0 + i2 * p_n + p2] = acc;
                    }
                }
            }
        }
        // GAP broadcasts the logit gradient evenly: g_a is
        // position-independent per channel.
        let ga: Vec<f64> = (0..f0)
            .map(|up| {
                let i = up / p_n;
                (0..c1)
                    .map(|o| w1[o * c0 + i] * gf[o] / p_n as f64)
                    .sum()
            })
            .collect();
        // Sigmoid: PSD part plus the signed residual on the diagonal.
        let d1: Vec<f64> = a
            .iter()
            .map(|&s| s * (1.0 - s))
            .collect();
        let d2: Vec<f64> = a
            .iter()
            .map(|&s| s * (1.0 - s) * (1.0 - 2.0 * s))
            .collect();
        let mut hz0 = vec![0.0f64; f0 * f0];
        for u in 0..f0 {
            for v in 0..f0 {
                hz0[u * f0 + v] = d1[u] * ha[u * f0 + v] * d1[v];
            }
            hz0[u * f0 + u] += d2[u] * ga[u];
        }
        // conv0 weight/bias diagonal: double contraction against U0.
        for o in 0..c0 {
            for j in 0..j0 {
                let mut acc = 0.0;
                for p in 0..p_n {
                    for p2 in 0..p_n {
                        acc += u0[j * p_n + p]
                            * u0[j * p_n + p2]
                            * hz0[(o * p_n + p) * f0
                                + o * p_n
                                + p2];
                    }
                }
                want_w0[o * j0 + j] += acc;
            }
            let mut acc = 0.0;
            for p in 0..p_n {
                for p2 in 0..p_n {
                    acc +=
                        hz0[(o * p_n + p) * f0 + o * p_n + p2];
                }
            }
            want_b0[o] += acc;
        }
    }
    for (name, want) in [
        ("diag_h/0/w", &want_w0),
        ("diag_h/0/b", &want_b0),
        ("diag_h/2/w", &want_w1),
        ("diag_h/2/b", &want_b1),
    ] {
        let got = out.get(name).unwrap().f32s().unwrap();
        assert_eq!(got.len(), want.len(), "{name}");
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            let w = w / n as f64;
            assert!(
                ((*g as f64) - w).abs() <= 1e-5 * (1.0 + w.abs()),
                "{name}[{i}]: engine {g} vs brute-force {w}"
            );
        }
    }
}

/// Table-1-style identity: on a piecewise-linear conv stack (ReLU +
/// max-pool) every residual vanishes, so `diag_h` must coincide with
/// `diag_ggn` — and on the sigmoid model it must not (the residual
/// below the sigmoid is the whole point of Fig. 9).
#[test]
fn conv_diag_h_coincides_with_diag_ggn_exactly_when_relu() {
    let relu = Model::with_input(
        "tinyrelu",
        Shape::new(2, 5, 5),
        vec![
            Layer::Conv2d {
                in_ch: 2, out_ch: 3, kernel: 3, stride: 1, pad: 1,
            },
            Layer::Relu,
            Layer::MaxPool2d { kernel: 2, stride: 2, ceil: true },
            Layer::Flatten,
            Layer::Linear { in_dim: 27, out_dim: 4 },
        ],
    )
    .unwrap();
    let mut rng = Rng::new(31);
    let mk_params = |m: &Model| -> Vec<Tensor> {
        let mut rng = Rng::new(77);
        m.param_specs()
            .iter()
            .map(|t| {
                let k: usize = t.shape.iter().product();
                Tensor::from_f32(
                    &t.shape,
                    (0..k).map(|_| rng.normal() * 0.3).collect(),
                )
            })
            .collect()
    };
    let x: Vec<f32> = (0..6 * 50).map(|_| rng.normal()).collect();
    let y: Vec<i32> = (0..6).map(|_| rng.below(4) as i32).collect();
    let x = Tensor::from_f32(&[6, 50], x);
    let y = Tensor::from_i32(&[6], y);
    let exts = vec!["diag_h".to_string(), "diag_ggn".to_string()];
    let out = relu
        .extended_backward(
            &mk_params(&relu), &x, &y, &exts,
            &ExtractOptions::default(),
        )
        .unwrap();
    for li in [0usize, 4] {
        for part in ["w", "b"] {
            let h =
                out[&format!("diag_h/{li}/{part}")].f32s().unwrap();
            let g = out[&format!("diag_ggn/{li}/{part}")]
                .f32s()
                .unwrap();
            for (u, v) in h.iter().zip(g) {
                assert!(
                    (u - v).abs() <= 1e-7 * (1.0 + u.abs()),
                    "relu model diag_h/{li}/{part}: {u} vs {v}"
                );
            }
        }
    }
    // The sigmoid twin (tiny_conv) must disagree below the sigmoid.
    let sig = tiny_conv();
    let out = sig
        .extended_backward(
            &mk_params(&sig), &x, &y, &exts,
            &ExtractOptions::default(),
        )
        .unwrap();
    let h = out["diag_h/0/w"].f32s().unwrap();
    let g = out["diag_ggn/0/w"].f32s().unwrap();
    let max_rel = h
        .iter()
        .zip(g)
        .map(|(u, v)| (u - v).abs() / (1.0 + v.abs()))
        .fold(0.0f32, f32::max);
    assert!(
        max_rel > 1e-4,
        "sigmoid residual had no effect on the conv diagonal \
         (max rel diff {max_rel})"
    );
}

/// Paper Table 1 identities on one combined first-order conv graph:
/// batch_grad rows sum to grad, sq_moment matches the per-sample
/// squares, variance = sq_moment − grad², batch_l2 = ‖row‖².
#[test]
fn conv_first_order_identities() {
    let be = backend_with_test_models();
    let exe = be
        .load("tinyconv_batch_grad+batch_l2+sq_moment+variance_n8")
        .unwrap();
    let params = init_params(exe.spec(), 4);
    let (x, y) = spec_batch(exe.spec(), 4);
    let out = run_at(exe.as_ref(), &params, &x, &y);
    let n = 8usize;
    for p in &params {
        let d = p.tensor.numel();
        let g = out.get(&p.under("grad")).unwrap().f32s().unwrap();
        let bg = out
            .get(&p.under("batch_grad"))
            .unwrap()
            .f32s()
            .unwrap();
        let sq =
            out.get(&p.under("sq_moment")).unwrap().f32s().unwrap();
        let var =
            out.get(&p.under("variance")).unwrap().f32s().unwrap();
        let l2 =
            out.get(&p.under("batch_l2")).unwrap().f32s().unwrap();
        assert_eq!(bg.len(), n * d, "{}", p.name);
        for i in 0..d {
            let sum: f32 = (0..n).map(|s| bg[s * d + i]).sum();
            assert!(
                (sum - g[i]).abs() <= 1e-6 + 1e-4 * g[i].abs(),
                "{}: Σ_n batch_grad {sum} != grad {}", p.name, g[i]
            );
            let want: f32 =
                (0..n).map(|s| bg[s * d + i].powi(2)).sum::<f32>()
                    * n as f32;
            assert!(
                (sq[i] - want).abs() <= 1e-6 + 1e-3 * want.abs(),
                "{}: sq_moment {} != {want}", p.name, sq[i]
            );
            let wantv = sq[i] - g[i] * g[i];
            assert!(
                (var[i] - wantv).abs() <= 1e-6 + 1e-3 * wantv.abs(),
                "{}: variance {} != {wantv}", p.name, var[i]
            );
            assert!(var[i] >= -1e-6, "variance must be >= 0");
        }
        for s in 0..n {
            let want: f32 =
                (0..d).map(|i| bg[s * d + i].powi(2)).sum();
            assert!(
                (l2[s] - want).abs() <= 1e-9 + 1e-3 * want.abs(),
                "{}: batch_l2[{s}] {} != {want}", p.name, l2[s]
            );
        }
    }
}

/// The FC-limit soundness check for every conv extraction rule: a
/// stack of 1x1 convs on 1x1 "images" IS a fully-connected net, so
/// grads, batch quantities, DiagGGN(-MC) and KFAC/KFLR factors must
/// match a `Linear` twin sharing the same (reshaped) parameters.
#[test]
fn one_by_one_conv_model_matches_linear_twin() {
    let conv = Model::with_input(
        "conv1x1",
        Shape::new(6, 1, 1),
        vec![
            Layer::Conv2d {
                in_ch: 6, out_ch: 4, kernel: 1, stride: 1, pad: 0,
            },
            Layer::Sigmoid,
            Layer::Conv2d {
                in_ch: 4, out_ch: 3, kernel: 1, stride: 1, pad: 0,
            },
        ],
    )
    .unwrap();
    let lin = Model::new(
        "lin",
        6,
        vec![
            Layer::Linear { in_dim: 6, out_dim: 4 },
            Layer::Sigmoid,
            Layer::Linear { in_dim: 4, out_dim: 3 },
        ],
    )
    .unwrap();
    let mut rng = Rng::new(7);
    let mut mk = |shape: &[usize]| {
        let k: usize = shape.iter().product();
        (0..k).map(|_| rng.normal() * 0.4).collect::<Vec<f32>>()
    };
    let (w0, b0) = (mk(&[4, 6]), mk(&[4]));
    let (w1, b1) = (mk(&[3, 4]), mk(&[3]));
    let conv_params = vec![
        Tensor::from_f32(&[4, 6, 1, 1], w0.clone()),
        Tensor::from_f32(&[4], b0.clone()),
        Tensor::from_f32(&[3, 4, 1, 1], w1.clone()),
        Tensor::from_f32(&[3], b1.clone()),
    ];
    let lin_params = vec![
        Tensor::from_f32(&[4, 6], w0),
        Tensor::from_f32(&[4], b0),
        Tensor::from_f32(&[3, 4], w1),
        Tensor::from_f32(&[3], b1),
    ];
    let (x, y) = random_batch(9, 6, 3, 7);
    let exts: Vec<String> = [
        "batch_grad", "batch_l2", "variance", "diag_ggn",
        "diag_ggn_mc", "kfac", "kflr",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let opts = ExtractOptions {
        key: Some([11, 12]),
        ..ExtractOptions::default()
    };
    let a = conv
        .extended_backward(&conv_params, &x, &y, &exts, &opts)
        .unwrap();
    let b = lin
        .extended_backward(&lin_params, &x, &y, &exts, &opts)
        .unwrap();
    assert_eq!(
        a.keys().collect::<Vec<_>>(),
        b.keys().collect::<Vec<_>>()
    );
    for (k, want) in &b {
        let got = &a[k];
        // Weight-shaped tensors differ only by the trailing 1x1 dims.
        assert_eq!(
            got.numel(),
            want.numel(),
            "{k}: {:?} vs {:?}", got.shape, want.shape
        );
        for (i, (u, v)) in want
            .f32s()
            .unwrap()
            .iter()
            .zip(got.f32s().unwrap())
            .enumerate()
        {
            assert!(
                (u - v).abs() <= 1e-5 * (1.0 + u.abs()),
                "{k}[{i}]: linear {u} vs conv {v}"
            );
        }
    }
}

/// KFAC/KFLR conv factors: spec-consistent shapes, symmetry, PSD
/// diagonals on a spatial model (P > 1).
#[test]
fn conv_kron_factors_are_consistent() {
    let be = backend_with_test_models();
    let exe = be.load("tinyconv_kflr_n6").unwrap();
    let params = init_params(exe.spec(), 5);
    let (x, y) = spec_batch(exe.spec(), 5);
    let out = run_at(exe.as_ref(), &params, &x, &y);
    // Layer 0: conv (J = 2·3·3 = 18, c_out = 3); layer 4: linear.
    for (name, dim) in [
        ("kflr/0/A", 18usize),
        ("kflr/0/B", 3),
        ("kflr/0/bias_ggn", 3),
        ("kflr/4/A", 27),
        ("kflr/4/B", 4),
    ] {
        let t = out.get(name).unwrap();
        assert_eq!(t.shape, vec![dim, dim], "{name}");
        let v = t.f32s().unwrap();
        for i in 0..dim {
            assert!(v[i * dim + i] >= -1e-6, "{name} diag[{i}]");
            for j in 0..dim {
                assert!(
                    (v[i * dim + j] - v[j * dim + i]).abs()
                        <= 1e-5 * (1.0 + v[i * dim + j].abs()),
                    "{name} symmetry [{i},{j}]"
                );
            }
        }
    }
}

/// KFRA is fully-connected-only (paper footnote 5): the backend
/// refuses conv kfra artifacts end-to-end and the invariant test in
/// `coordinator/problems.rs` keeps the optimizer lists consistent.
#[test]
fn kfra_is_rejected_on_conv_models_end_to_end() {
    let be = backend_with_test_models();
    for artifact in
        ["tinyconv_kfra_n4", "2c2d_kfra_n4", "3c3d_kfra+kfac_n4"]
    {
        let err = be.spec(artifact).unwrap_err().to_string();
        assert!(err.contains("footnote 5"), "{artifact}: {err}");
        assert!(be.load(artifact).is_err(), "{artifact}");
    }
    assert!(be.spec("mlp_kfra_n4").is_ok());
}

/// Acceptance: every registered problem is servable on the native
/// backend -- one full gradient execution per problem with finite
/// outputs (allcnnc at side 16, per the registry).
#[test]
fn every_problem_runs_a_gradient_step_natively() {
    let be = NativeBackend::new();
    for p in PROBLEMS {
        let name = be
            .find_train(p.model, p.side, "grad", 2)
            .unwrap_or_else(|e| panic!("{}: {e}", p.codename));
        let exe = be.load(&name).unwrap();
        let spec = exe.spec().clone();
        let params = init_params(&spec, 0);
        let (x, y) = spec_batch(&spec, 9);
        let out = run_at(exe.as_ref(), &params, &x, &y);
        let loss = out.loss().unwrap();
        assert!(loss.is_finite(), "{}: loss {loss}", p.codename);
        for p2 in &params {
            let g = out.get(&p2.under("grad")).unwrap();
            assert_eq!(g.shape, p2.tensor.shape, "{}", p2.name);
            assert!(
                g.f32s().unwrap().iter().all(|v| v.is_finite()),
                "{}: non-finite grad {}", p.codename, p2.name
            );
        }
    }
}

/// End-to-end conv training: plain SGD on a fixed batch must overfit
/// (loss strictly decreases over a few steps) through the full
/// backend path, and the eval graph reports sane numbers.
#[test]
fn conv_training_reduces_loss_and_eval_runs() {
    let be = backend_with_test_models();
    let exe = be.load("tinyconv_grad_n16").unwrap();
    let mut params = init_params(exe.spec(), 6);
    let (x, y) = spec_batch(exe.spec(), 6);
    let mut losses = Vec::new();
    for _ in 0..8 {
        let out = run_at(exe.as_ref(), &params, &x, &y);
        losses.push(out.loss().unwrap());
        for p in params.iter_mut() {
            let g = out.get(&p.under("grad")).unwrap().f32s().unwrap()
                .to_vec();
            let t = p.tensor.f32s_mut().unwrap();
            for (w, gv) in t.iter_mut().zip(&g) {
                *w -= 0.5 * gv;
            }
        }
    }
    let (first, last) = (losses[0], *losses.last().unwrap());
    assert!(
        last < first,
        "SGD on a fixed batch must reduce the loss: {losses:?}"
    );
    let eval = be.load("tinyconv_eval_n32").unwrap();
    let (x, y) = spec_batch(eval.spec(), 8);
    let out = eval
        .run(&build_inputs(&params, x, y, None))
        .unwrap();
    assert!(out.loss().unwrap().is_finite());
    let acc = out.get("accuracy").unwrap().item_f32().unwrap();
    assert!((0.0..=1.0).contains(&acc));
}

// ---- fused unfold vs materialized im2col (DESIGN.md §14) -----------
//
// The conv drivers stream COL_TILE-wide position tiles through
// `im2col_range` instead of materializing the full unfolded input.
// These tests pin the fusion contract against hand-built materialized
// oracles (public `ConvGeom::im2col` + the linalg matmuls):
//
// * products whose contraction axis is untouched by tiling (forward,
//   the VJP's WᵀS product) are exact -- asserted bitwise (±0 folded);
// * accumulating reductions (grad, per-sample grads, diag, Kron A,
//   the col2im scatter) re-associate the position sum across tiles,
//   so multi-tile geometries agree to f32 round-off and single-tile
//   geometries (P <= COL_TILE) stay exact, because one tile IS the
//   materialized computation.

use backpack_rs::backend::conv::conv2d;
use backpack_rs::backend::conv::conv2d::COL_TILE;
use backpack_rs::backend::conv::ConvGeom;
use backpack_rs::linalg::{matmul, matmul_nt, matmul_tn};

struct ConvCase {
    geom: ConvGeom,
    w: Vec<f32>,
    b: Vec<f32>,
    x: Vec<f32>,
    g: Vec<f32>,
    s: Vec<f32>,
    signs: Vec<f32>,
    ns: usize,
    cols: usize,
}

fn conv_case(geom: ConvGeom, ns: usize, cols: usize, rng: &mut Rng)
    -> ConvCase {
    let (fin, fout) = (geom.in_shape.flat(), geom.out_shape.flat());
    let j = geom.patch_len();
    let c_out = geom.out_shape.c;
    let mut r = |len: usize| -> Vec<f32> {
        (0..len).map(|_| rng.normal()).collect()
    };
    let (w, b) = (r(c_out * j), r(c_out));
    let (x, g, s) = (r(ns * fin), r(ns * fout), r(ns * fout * cols));
    let signs: Vec<f32> = (0..ns * cols)
        .map(|i| if i % 3 == 0 { -1.0 } else { 1.0 })
        .collect();
    ConvCase { geom, w, b, x, g, s, signs, ns, cols }
}

/// Random geometry over stride/pad/kernel variety, 1x1 conv included;
/// the sampled dims keep P <= COL_TILE, so these are single-tile.
fn rand_geom(rng: &mut Rng) -> ConvGeom {
    let c_in = 1 + rng.below(3);
    let h = 3 + rng.below(8);
    let w = 3 + rng.below(8);
    let k = 1 + rng.below(3);
    let stride = 1 + rng.below(2);
    let pad = rng.below(k);
    let c_out = 1 + rng.below(3);
    ConvGeom::new(Shape::new(c_in, h, w), c_out, k, stride, pad)
        .unwrap()
}

/// Bitwise equality with ±0 folded together (an accumulate-into-zero
/// and a plain store differ only on the sign of an exact zero).
fn assert_same(label: &str, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{label}: length");
    for (i, (x, y)) in got.iter().zip(want).enumerate() {
        assert!(
            x.to_bits() == y.to_bits() || x == y,
            "{label}[{i}]: {x:?} vs {y:?}"
        );
    }
}

fn assert_close_abs_rel(label: &str, got: &[f32], want: &[f32], tol: f32) {
    assert_eq!(got.len(), want.len(), "{label}: length");
    for (i, (x, y)) in got.iter().zip(want).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + y.abs()),
            "{label}[{i}]: {x} vs {y}"
        );
    }
}

fn mat_forward(c: &ConvCase) -> Vec<f32> {
    let geom = &c.geom;
    let (fin, fout) = (geom.in_shape.flat(), geom.out_shape.flat());
    let (j, p) = (geom.patch_len(), geom.positions());
    let co = geom.out_shape.c;
    let mut z = vec![0.0f32; c.ns * fout];
    for smp in 0..c.ns {
        let u = geom.im2col(&c.x[smp * fin..(smp + 1) * fin]);
        let zs = matmul(&c.w, &u, co, j, p);
        let dst = &mut z[smp * fout..(smp + 1) * fout];
        for o in 0..co {
            for q in 0..p {
                dst[o * p + q] = zs[o * p + q] + c.b[o];
            }
        }
    }
    z
}

fn mat_vjp(c: &ConvCase) -> Vec<f32> {
    let geom = &c.geom;
    let (fin, fout) = (geom.in_shape.flat(), geom.out_shape.flat());
    let (j, p) = (geom.patch_len(), geom.positions());
    let co = geom.out_shape.c;
    let cols = c.cols;
    let mut out = vec![0.0f32; c.ns * fin * cols];
    for smp in 0..c.ns {
        let blk = &c.s[smp * fout * cols..(smp + 1) * fout * cols];
        let t = matmul_tn(&c.w, blk, co, j, p * cols);
        geom.col2im_acc(
            &t,
            cols,
            &mut out[smp * fin * cols..(smp + 1) * fin * cols],
        );
    }
    out
}

fn mat_grad(c: &ConvCase, norm: f32) -> (Vec<f32>, Vec<f32>) {
    let geom = &c.geom;
    let (fin, fout) = (geom.in_shape.flat(), geom.out_shape.flat());
    let (j, p) = (geom.patch_len(), geom.positions());
    let co = geom.out_shape.c;
    let mut gw = vec![0.0f32; co * j];
    let mut gb = vec![0.0f32; co];
    for smp in 0..c.ns {
        let u = geom.im2col(&c.x[smp * fin..(smp + 1) * fin]);
        let gs = &c.g[smp * fout..(smp + 1) * fout];
        let gwi = matmul_nt(gs, &u, co, p, j);
        for (acc, v) in gw.iter_mut().zip(&gwi) {
            *acc += v;
        }
        for o in 0..co {
            gb[o] += gs[o * p..(o + 1) * p].iter().sum::<f32>();
        }
    }
    for v in gw.iter_mut().chain(gb.iter_mut()) {
        *v /= norm;
    }
    (gw, gb)
}

fn mat_psg(c: &ConvCase) -> (Vec<f32>, Vec<f32>) {
    let geom = &c.geom;
    let (fin, fout) = (geom.in_shape.flat(), geom.out_shape.flat());
    let (j, p) = (geom.patch_len(), geom.positions());
    let co = geom.out_shape.c;
    let mut w = vec![0.0f32; c.ns * co * j];
    let mut b = Vec::with_capacity(c.ns * co);
    for smp in 0..c.ns {
        let u = geom.im2col(&c.x[smp * fin..(smp + 1) * fin]);
        let gs = &c.g[smp * fout..(smp + 1) * fout];
        let ws = matmul_nt(gs, &u, co, p, j);
        w[smp * co * j..(smp + 1) * co * j].copy_from_slice(&ws);
        for o in 0..co {
            b.push(gs[o * p..(o + 1) * p].iter().sum::<f32>());
        }
    }
    (w, b)
}

fn mat_diag(
    c: &ConvCase,
    norm: f32,
    signed: bool,
) -> (Vec<f32>, Vec<f32>) {
    let geom = &c.geom;
    let (fin, fout) = (geom.in_shape.flat(), geom.out_shape.flat());
    let (j, p) = (geom.patch_len(), geom.positions());
    let co = geom.out_shape.c;
    let cols = c.cols;
    let mut dw = vec![0.0f32; co * j];
    let mut db = vec![0.0f32; co];
    for smp in 0..c.ns {
        let u = geom.im2col(&c.x[smp * fin..(smp + 1) * fin]);
        let blk = &c.s[smp * fout * cols..(smp + 1) * fout * cols];
        let mut st = vec![0.0f32; co * cols * p];
        for o in 0..co {
            for q in 0..p {
                for cc in 0..cols {
                    st[(o * cols + cc) * p + q] =
                        blk[(o * p + q) * cols + cc];
                }
            }
        }
        let v = matmul_nt(&st, &u, co * cols, p, j);
        for o in 0..co {
            for cc in 0..cols {
                let wgt = if signed {
                    c.signs[smp * cols + cc]
                } else {
                    1.0
                };
                let row = &v[(o * cols + cc) * j..(o * cols + cc + 1) * j];
                let dst = &mut dw[o * j..(o + 1) * j];
                for (acc, x) in dst.iter_mut().zip(row) {
                    *acc += wgt * x * x;
                }
                let sbar: f32 = (0..p)
                    .map(|q| blk[(o * p + q) * cols + cc])
                    .sum();
                db[o] += wgt * sbar * sbar;
            }
        }
    }
    for v in dw.iter_mut().chain(db.iter_mut()) {
        *v /= norm;
    }
    (dw, db)
}

fn mat_kron(c: &ConvCase, norm: f32) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let geom = &c.geom;
    let (fin, fout) = (geom.in_shape.flat(), geom.out_shape.flat());
    let (j, p) = (geom.patch_len(), geom.positions());
    let co = geom.out_shape.c;
    let cols = c.cols;
    let mut a = vec![0.0f32; j * j];
    let mut bf = vec![0.0f32; co * co];
    let mut bias = vec![0.0f32; co * co];
    for smp in 0..c.ns {
        let u = geom.im2col(&c.x[smp * fin..(smp + 1) * fin]);
        let uut = matmul_nt(&u, &u, j, p, j);
        for (acc, v) in a.iter_mut().zip(&uut) {
            *acc += v;
        }
        let blk = &c.s[smp * fout * cols..(smp + 1) * fout * cols];
        let ss = matmul_nt(blk, blk, co, p * cols, co);
        for (acc, v) in bf.iter_mut().zip(&ss) {
            *acc += v;
        }
        let mut srow = vec![0.0f32; co * cols];
        for o in 0..co {
            for cc in 0..cols {
                srow[o * cols + cc] = (0..p)
                    .map(|q| blk[(o * p + q) * cols + cc])
                    .sum();
            }
        }
        let bb = matmul_nt(&srow, &srow, co, cols, co);
        for (acc, v) in bias.iter_mut().zip(&bb) {
            *acc += v;
        }
    }
    for v in a.iter_mut() {
        *v /= norm;
    }
    let pf = norm * p as f32;
    for v in bf.iter_mut() {
        *v /= pf;
    }
    for v in bias.iter_mut() {
        *v /= norm;
    }
    (a, bf, bias)
}

/// Single-tile geometries (P <= COL_TILE): the fused drivers ARE the
/// materialized computation (one tile spans every position), so all
/// six agree exactly with the hand-built oracles -- across randomized
/// stride/pad/kernel combinations, 1x1 convs included.
#[test]
fn fused_drivers_match_materialized_exactly_at_single_tile() {
    for seed in 0..12u64 {
        let mut rng = Rng::new(0xF05E ^ seed);
        let geom = rand_geom(&mut rng);
        assert!(geom.positions() <= COL_TILE, "{geom:?}");
        let ns = 1 + rng.below(3);
        let cols = 1 + rng.below(2);
        let norm = 2.0 + seed as f32;
        let c = conv_case(geom, ns, cols, &mut rng);
        let label = |d: &str| format!("seed {seed} {d} {:?}", c.geom);

        let z = conv2d::forward(&c.geom, &c.w, &c.b, &c.x, ns);
        assert_same(&label("forward"), &z, &mat_forward(&c));

        let dx = conv2d::mat_vjp_input(&c.geom, &c.w, &c.s, ns, cols);
        assert_same(&label("mat_vjp_input"), &dx, &mat_vjp(&c));

        let (gw, gb) = conv2d::grad(&c.geom, &c.x, &c.g, ns, norm);
        let (ow, ob) = mat_grad(&c, norm);
        assert_same(&label("grad/w"), &gw, &ow);
        assert_same(&label("grad/b"), &gb, &ob);

        let (pw, pb) = conv2d::per_sample_grads(&c.geom, &c.x, &c.g, ns);
        let (qw, qb) = mat_psg(&c);
        assert_same(&label("psg/w"), &pw, &qw);
        assert_same(&label("psg/b"), &pb, &qb);

        let (dw, db) =
            conv2d::diag_sqrt(&c.geom, &c.x, &c.s, ns, cols, norm);
        let (ew, eb) = mat_diag(&c, norm, false);
        assert_same(&label("diag/w"), &dw, &ew);
        assert_same(&label("diag/b"), &db, &eb);

        let (sw, sb) = conv2d::diag_sqrt_signed(
            &c.geom, &c.x, &c.s, ns, cols, norm, Some(&c.signs),
        );
        let (tw, tb) = mat_diag(&c, norm, true);
        assert_same(&label("diag_signed/w"), &sw, &tw);
        assert_same(&label("diag_signed/b"), &sb, &tb);

        let (a, bf, bias) =
            conv2d::kron_factors(&c.geom, &c.x, &c.s, ns, cols, norm);
        let (oa, obf, obias) = mat_kron(&c, norm);
        assert_same(&label("kron/A"), &a, &oa);
        assert_same(&label("kron/B"), &bf, &obf);
        assert_same(&label("kron/bias"), &bias, &obias);
    }
}

/// Multi-tile geometry (P = 484 > COL_TILE, so the position axis is
/// genuinely tiled): the forward product stays bitwise (its
/// contraction axis is never split, and COL_TILE is a multiple of
/// the 64-column cache block, so every column sees the same
/// vector-body/tail split as in the full-width call); the
/// accumulating reductions re-associate the position sum across
/// tiles and agree to f32 round-off.
#[test]
fn fused_drivers_match_materialized_across_tiles() {
    let geom =
        ConvGeom::new(Shape::new(2, 22, 22), 3, 3, 1, 1).unwrap();
    assert!(
        geom.positions() > COL_TILE,
        "geometry must span several tiles, got P = {}",
        geom.positions()
    );
    let mut rng = Rng::new(0x71);
    let (ns, cols, norm) = (2, 2, 3.0);
    let c = conv_case(geom, ns, cols, &mut rng);

    let z = conv2d::forward(&c.geom, &c.w, &c.b, &c.x, ns);
    assert_same("tiled forward", &z, &mat_forward(&c));

    let dx = conv2d::mat_vjp_input(&c.geom, &c.w, &c.s, ns, cols);
    assert_close_abs_rel("tiled mat_vjp_input", &dx, &mat_vjp(&c), 1e-5);

    let (gw, gb) = conv2d::grad(&c.geom, &c.x, &c.g, ns, norm);
    let (ow, ob) = mat_grad(&c, norm);
    assert_close_abs_rel("tiled grad/w", &gw, &ow, 1e-4);
    assert_same("tiled grad/b", &gb, &ob);

    let (pw, pb) = conv2d::per_sample_grads(&c.geom, &c.x, &c.g, ns);
    let (qw, qb) = mat_psg(&c);
    assert_close_abs_rel("tiled psg/w", &pw, &qw, 1e-4);
    assert_same("tiled psg/b", &pb, &qb);

    let (dw, db) =
        conv2d::diag_sqrt(&c.geom, &c.x, &c.s, ns, cols, norm);
    let (ew, eb) = mat_diag(&c, norm, false);
    assert_close_abs_rel("tiled diag/w", &dw, &ew, 1e-3);
    assert_close_abs_rel("tiled diag/b", &db, &eb, 1e-3);

    let (a, bf, bias) =
        conv2d::kron_factors(&c.geom, &c.x, &c.s, ns, cols, norm);
    let (oa, obf, obias) = mat_kron(&c, norm);
    assert_close_abs_rel("tiled kron/A", &a, &oa, 1e-3);
    // B and the bias GGN never touch the unfold: identical code on
    // both sides, so they stay exact even across tiles.
    assert_same("tiled kron/B", &bf, &obf);
    assert_same("tiled kron/bias", &bias, &obias);
}
