//! Property-based tests on coordinator invariants.
//!
//! The proptest crate is unavailable offline, so this file includes a
//! small seeded property-testing driver (`check`) that generates many
//! random cases per property and reports the failing seed -- same
//! discipline, in-repo.

use backpack_rs::coordinator::metrics::{aggregate, percentile, RunLog};
use backpack_rs::data::{Batcher, DatasetSpec, Rng, Synthetic};
use backpack_rs::json::Json;
use backpack_rs::linalg::{
    matmul, matmul_nt, matmul_nt_par, matmul_nt_scalar, matmul_par,
    matmul_scalar, matmul_tn, matmul_tn_par, matmul_tn_scalar,
    reference, Cholesky, SymMat,
};

/// Run `prop` for `cases` seeded cases; panic with the seed on failure.
fn check<F: Fn(&mut Rng) -> Result<(), String>>(
    name: &str,
    cases: u64,
    prop: F,
) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xBACC ^ seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name} failed at seed {seed}: {msg}");
        }
    }
}

fn random_spd(rng: &mut Rng, n: usize, jitter: f32) -> SymMat {
    let g: Vec<f32> = (0..n * n).map(|_| rng.normal()).collect();
    let mut a = vec![0.0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for k in 0..n {
                s += g[i * n + k] * g[j * n + k];
            }
            a[i * n + j] = s / n as f32;
        }
    }
    for i in 0..n {
        a[i * n + i] += jitter;
    }
    SymMat::new(n, a)
}

#[test]
fn prop_cholesky_solve_inverts_matvec() {
    check("cholesky_solve", 60, |rng| {
        let n = 1 + rng.below(24);
        let a = random_spd(rng, n, 0.4);
        let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut b = vec![0.0f32; n];
        for i in 0..n {
            for j in 0..n {
                b[i] += a.at(i, j) * x[j];
            }
        }
        let ch = Cholesky::factor(&a).map_err(|e| e.to_string())?;
        ch.solve_vec(&mut b);
        for i in 0..n {
            let err = (b[i] - x[i]).abs();
            if err > 1e-2 * (1.0 + x[i].abs()) {
                return Err(format!("x[{i}]: {} vs {}", b[i], x[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_solve_mat_left_right_compose_to_kron_solve() {
    // (B⁻¹ G A⁻¹) reconstructs G after B · ... · A.
    check("kron_solve", 30, |rng| {
        let (db, da) = (1 + rng.below(8), 1 + rng.below(8));
        let a = random_spd(rng, da, 0.5);
        let b = random_spd(rng, db, 0.5);
        let g: Vec<f32> = (0..db * da).map(|_| rng.normal()).collect();
        let mut v = g.clone();
        let cb = Cholesky::factor(&b).map_err(|e| e.to_string())?;
        let ca = Cholesky::factor(&a).map_err(|e| e.to_string())?;
        cb.solve_mat_left(&mut v, da);
        ca.solve_mat_right(&mut v, db);
        // reconstruct: B V A =? G
        let bv = matmul(&b.a, &v, db, db, da);
        let bva = matmul(&bv, &a.a, db, da, da);
        for i in 0..g.len() {
            if (bva[i] - g[i]).abs() > 2e-2 * (1.0 + g[i].abs()) {
                return Err(format!("[{i}]: {} vs {}", bva[i], g[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_percentile_monotone_and_bounded() {
    check("percentile", 100, |rng| {
        let n = 1 + rng.below(50);
        let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let lo = percentile(&mut v.clone(), 0.0);
        let q1 = percentile(&mut v.clone(), 0.25);
        let q2 = percentile(&mut v.clone(), 0.5);
        let q3 = percentile(&mut v.clone(), 0.75);
        let hi = percentile(&mut v.clone(), 1.0);
        if !(lo <= q1 && q1 <= q2 && q2 <= q3 && q3 <= hi) {
            return Err(format!("not monotone: {lo} {q1} {q2} {q3} {hi}"));
        }
        let min = v.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = v.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        if lo != min || hi != max {
            return Err("extremes mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_aggregate_median_between_extremes() {
    check("aggregate", 50, |rng| {
        let seeds = 1 + rng.below(6);
        let len = 1 + rng.below(10);
        let runs: Vec<RunLog> = (0..seeds)
            .map(|_| RunLog {
                train_loss: (0..len)
                    .map(|s| (s, rng.normal().abs()))
                    .collect(),
                ..Default::default()
            })
            .collect();
        let q = aggregate(&runs, |r| r.train_loss.clone());
        for i in 0..len {
            if !(q.q25[i] <= q.q50[i] && q.q50[i] <= q.q75[i]) {
                return Err(format!("quartiles out of order at {i}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_covers_every_sample_each_epoch() {
    check("batcher_coverage", 20, |rng| {
        let train = 8 + rng.below(40);
        let bs = 1 + rng.below(train.min(9));
        let spec = DatasetSpec {
            name: "t", channels: 1, height: 2, width: 2,
            classes: 3, train_size: train, test_size: 4, flat: false,
        };
        let ds = Synthetic::new(spec, rng.next_u64());
        let mut b = Batcher::new(ds, bs, rng.next_u64());
        // One epoch = floor(train/bs) full batches before wrap.
        let mut seen = std::collections::HashSet::new();
        let full = train / bs;
        let mut labels = Vec::new();
        for _ in 0..full {
            let (x, y) = b.next_batch();
            if x.shape[0] != bs {
                return Err("bad batch size".into());
            }
            labels.extend(y.i32s().unwrap().to_vec());
            for v in x.f32s().unwrap() {
                if !v.is_finite() {
                    return Err("non-finite sample".into());
                }
            }
            seen.insert(format!("{:?}", y.i32s().unwrap()));
        }
        if labels.iter().any(|l| *l < 0 || *l >= 3) {
            return Err("label out of range".into());
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.normal() * 100.0).round() as f64),
            3 => Json::Str(format!("s{}\"\\n{}", rng.below(10),
                                   rng.below(10))),
            4 => Json::Arr(
                (0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect()),
        }
    }
    check("json_roundtrip", 200, |rng| {
        let v = gen(rng, 3);
        let text = v.to_string_json();
        let back = Json::parse(&text)
            .map_err(|e| format!("{e} on {text}"))?;
        if back != v {
            return Err(format!("{text} reparsed differently"));
        }
        Ok(())
    });
}

#[test]
fn prop_rng_uniform_in_bounds() {
    check("uniform_in", 50, |rng| {
        let lo = rng.normal();
        let hi = lo + rng.uniform() + 1e-3;
        for _ in 0..100 {
            let u = rng.uniform_in(lo, hi);
            if !(lo..=hi).contains(&u) {
                return Err(format!("{u} outside [{lo}, {hi}]"));
            }
        }
        Ok(())
    });
}

// ---- kernel property suite (DESIGN.md §14) --------------------------
//
// The SIMD microkernels' numerical contract: the dispatched kernels
// (AVX2+FMA where the host has it, scalar elsewhere) agree with the
// retained scalar twins to 1e-5 relative error -- the only permitted
// divergence is FMA's single rounding per multiply-add -- and every
// kernel is deterministic across repeated calls. Shapes are drawn
// from an edge-stressing set (0, 1, and dims straddling the 8-wide
// SIMD lane and the 64-wide cache block) so both the vector body and
// the remainder tails are exercised.

/// Dims stressing lane (8) and tile (64) remainders, plus degenerate
/// 0/1 axes.
fn kdim(rng: &mut Rng) -> usize {
    const DIMS: [usize; 15] =
        [0, 1, 2, 3, 5, 7, 8, 9, 15, 16, 17, 31, 33, 63, 65];
    DIMS[rng.below(DIMS.len())]
}

fn kmat(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.normal()).collect()
}

/// 1e-5-relative agreement, elementwise.
fn close(a: &[f32], b: &[f32], what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: len {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > 1e-5 * (1.0 + y.abs()) {
            return Err(format!("{what}[{i}]: {x} vs {y}"));
        }
    }
    Ok(())
}

#[test]
fn prop_matmul_dispatched_matches_scalar_and_reference() {
    check("matmul_kernel", 120, |rng| {
        let (p, q, r) = (kdim(rng), kdim(rng), kdim(rng));
        let a = kmat(rng, p * q);
        let b = kmat(rng, q * r);
        let got = matmul(&a, &b, p, q, r);
        close(&got, &matmul_scalar(&a, &b, p, q, r), "vs scalar")?;
        close(&got, &reference::matmul(&a, &b, p, q, r), "vs naive")
    });
}

#[test]
fn prop_matmul_tn_dispatched_matches_scalar_and_reference() {
    check("matmul_tn_kernel", 120, |rng| {
        let (n, p, q) = (kdim(rng), kdim(rng), kdim(rng));
        let a = kmat(rng, n * p);
        let b = kmat(rng, n * q);
        let got = matmul_tn(&a, &b, n, p, q);
        close(&got, &matmul_tn_scalar(&a, &b, n, p, q), "vs scalar")?;
        close(&got, &reference::matmul_tn(&a, &b, n, p, q), "vs naive")
    });
}

#[test]
fn prop_matmul_nt_dispatched_matches_scalar_and_reference() {
    check("matmul_nt_kernel", 120, |rng| {
        let (p, n, q) = (kdim(rng), kdim(rng), kdim(rng));
        let a = kmat(rng, p * n);
        let b = kmat(rng, q * n);
        let got = matmul_nt(&a, &b, p, n, q);
        close(&got, &matmul_nt_scalar(&a, &b, p, n, q), "vs scalar")?;
        close(&got, &reference::matmul_nt(&a, &b, p, n, q), "vs naive")
    });
}

#[test]
fn prop_kernels_deterministic_across_repeated_calls() {
    // Bitwise, not approximate: runtime dispatch must pick the same
    // code path every call, and the persistent pool must not leak
    // nondeterminism into the serial kernels.
    check("kernel_determinism", 60, |rng| {
        let (n, p, q) = (kdim(rng), kdim(rng), kdim(rng));
        let a = kmat(rng, n * p);
        let b = kmat(rng, n * q);
        let once = matmul_tn(&a, &b, n, p, q);
        let twice = matmul_tn(&a, &b, n, p, q);
        for (i, (x, y)) in once.iter().zip(&twice).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("tn[{i}]: {x} vs {y}"));
            }
        }
        let a2 = kmat(rng, p * q);
        let b2 = kmat(rng, q * n.max(1));
        let once = matmul(&a2, &b2, p, q, n.max(1));
        let twice = matmul(&a2, &b2, p, q, n.max(1));
        for (i, (x, y)) in once.iter().zip(&twice).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("nn[{i}]: {x} vs {y}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_kernels_bitwise_match_serial() {
    // Both paths run the same dispatched microkernel on the same row
    // ranges, so par-vs-serial is exact equality, not tolerance.
    check("kernel_par_equiv", 40, |rng| {
        let (n, p, q) = (kdim(rng), kdim(rng), kdim(rng));
        let threads = 1 + rng.below(5);
        let a = kmat(rng, n * p);
        let b = kmat(rng, n * q);
        let ser = matmul_tn(&a, &b, n, p, q);
        let par = matmul_tn_par(&a, &b, n, p, q, threads);
        for (i, (x, y)) in par.iter().zip(&ser).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("tn[{i}]: {x} vs {y}"));
            }
        }
        let an = kmat(rng, p * n);
        let bn = kmat(rng, q * n);
        let ser = matmul_nt(&an, &bn, p, n, q);
        let par = matmul_nt_par(&an, &bn, p, n, q, threads);
        for (i, (x, y)) in par.iter().zip(&ser).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("nt[{i}]: {x} vs {y}"));
            }
        }
        let am = kmat(rng, p * q);
        let bm = kmat(rng, q * n);
        let ser = matmul(&am, &bm, p, q, n);
        let par = matmul_par(&am, &bm, p, q, n, threads);
        for (i, (x, y)) in par.iter().zip(&ser).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("nn[{i}]: {x} vs {y}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_simd_dispatch_is_stable() {
    // The runtime dispatch decision is cached: whatever the first
    // call decided, later calls agree (flipping mid-process would
    // break the determinism contract above).
    let first = backpack_rs::linalg::simd_active();
    for _ in 0..100 {
        assert_eq!(backpack_rs::linalg::simd_active(), first);
    }
}
