//! Integration tests over the real artifacts + PJRT runtime.
//!
//! These require `make artifacts` to have run (CI order: pytest ->
//! cargo test). They exercise the full Rust path: manifest parse ->
//! HLO compile -> execute -> quantity extraction -> optimizer update.

use backpack_rs::coordinator::train::{build_inputs, init_params};
use backpack_rs::coordinator::{problems, train, TrainConfig};
use backpack_rs::data::{DatasetSpec, Synthetic};
use backpack_rs::optim::Hyper;
use backpack_rs::runtime::{Runtime, Tensor};

fn runtime() -> Runtime {
    // Tests run from the workspace root.
    Runtime::open(std::path::Path::new("artifacts")).expect("runtime")
}

fn logreg_batch(n: usize, seed: u64) -> (Tensor, Tensor) {
    let ds = Synthetic::new(DatasetSpec::by_name("mnist").unwrap(), seed);
    let idx: Vec<usize> = (0..n).collect();
    let (x, y) = ds.batch(0, &idx);
    (Tensor::from_f32(&[n, 784], x), Tensor::from_i32(&[n], y))
}

#[test]
fn manifest_covers_all_problem_artifacts() {
    let rt = runtime();
    for p in problems::PROBLEMS {
        if p.native_only {
            continue; // no AOT artifacts exist for native-only problems
        }
        assert!(rt.manifest.get(p.eval_artifact).is_ok(), "{}",
                p.eval_artifact);
        for opt in p.optimizers {
            let sig = match *opt {
                "momentum" | "adam" | "sgd" => "grad",
                other => other,
            };
            rt.manifest
                .find_train(p.model, p.side, sig, p.train_batch)
                .unwrap_or_else(|e| panic!("{}/{opt}: {e}", p.codename));
        }
    }
}

#[test]
fn gradient_artifact_runs_and_loss_is_sane() {
    let rt = runtime();
    let exe = rt.load("logreg_grad_n64").unwrap();
    let params = init_params(&exe.spec, 0);
    let (x, y) = logreg_batch(64, 0);
    let out = exe.run(&build_inputs(&params, x, y, None)).unwrap();
    let loss = out.loss().unwrap();
    // Random init on 10 classes: loss near ln(10) ~ 2.30.
    assert!((1.8..3.2).contains(&loss), "loss {loss}");
    let grad = out.get("grad/0/w").unwrap();
    assert_eq!(grad.shape, vec![10, 784]);
    assert!(grad.f32s().unwrap().iter().all(|v| v.is_finite()));
}

#[test]
fn variance_and_moments_consistent_through_runtime() {
    // Table 1 identity: variance = 2nd moment - grad², elementwise,
    // checked on real artifact outputs (not the Python tests' oracles).
    let rt = runtime();
    let exe = rt
        .load("logreg_batch_grad+batch_l2+sq_moment+variance_n64")
        .unwrap();
    let params = init_params(&exe.spec, 1);
    let (x, y) = logreg_batch(64, 1);
    let out = exe.run(&build_inputs(&params, x, y, None)).unwrap();
    let g = out.get("grad/0/w").unwrap().f32s().unwrap();
    let sq = out.get("sq_moment/0/w").unwrap().f32s().unwrap();
    let var = out.get("variance/0/w").unwrap().f32s().unwrap();
    for i in 0..g.len() {
        let want = sq[i] - g[i] * g[i];
        assert!(
            (var[i] - want).abs() <= 1e-5 + 1e-3 * want.abs(),
            "var[{i}]={} want {want}",
            var[i]
        );
        assert!(var[i] >= -1e-6, "variance must be >= 0");
    }
    // batch_grad sums back to grad (both already 1/N-scaled).
    let bg = out.get("batch_grad/0/w").unwrap();
    assert_eq!(bg.shape, vec![64, 10, 784]);
    let bgv = bg.f32s().unwrap();
    let d = 10 * 784;
    for i in (0..d).step_by(997) {
        let sum: f32 = (0..64).map(|n| bgv[n * d + i]).sum();
        assert!(
            (sum - g[i]).abs() <= 1e-5 + 1e-3 * g[i].abs(),
            "sum of indiv grads {sum} != grad {}",
            g[i]
        );
    }
}

#[test]
fn mc_key_changes_mc_quantities_only() {
    let rt = runtime();
    let exe = rt.load("logreg_diag_ggn_mc_n64").unwrap();
    let params = init_params(&exe.spec, 2);
    let (x, y) = logreg_batch(64, 2);
    let out1 = exe
        .run(&build_inputs(&params, x.clone(), y.clone(), Some([1, 1])))
        .unwrap();
    let out2 = exe
        .run(&build_inputs(&params, x, y, Some([2, 2])))
        .unwrap();
    // Gradient is deterministic...
    assert_eq!(
        out1.get("grad/0/w").unwrap(),
        out2.get("grad/0/w").unwrap()
    );
    // ...the MC curvature estimate is not.
    assert_ne!(
        out1.get("diag_ggn_mc/0/w").unwrap(),
        out2.get("diag_ggn_mc/0/w").unwrap()
    );
}

#[test]
fn diag_ggn_mc_is_nonnegative_and_tracks_exact() {
    let rt = runtime();
    let exact_exe = rt.load("logreg_diag_ggn_n64").unwrap();
    let mc_exe = rt.load("logreg_diag_ggn_mc_n64").unwrap();
    let params = init_params(&exact_exe.spec, 3);
    let (x, y) = logreg_batch(64, 3);
    let exact = exact_exe
        .run(&build_inputs(&params, x.clone(), y.clone(), None))
        .unwrap();
    // Average a few MC draws to reduce noise.
    let mut acc = vec![0.0f64; 10 * 784];
    let draws = 8;
    for k in 0..draws {
        let out = mc_exe
            .run(&build_inputs(&params, x.clone(), y.clone(),
                               Some([k, 0])))
            .unwrap();
        for (a, v) in acc
            .iter_mut()
            .zip(out.get("diag_ggn_mc/0/w").unwrap().f32s().unwrap())
        {
            assert!(*v >= -1e-7, "MC diag must be >= 0");
            *a += *v as f64 / draws as f64;
        }
    }
    let ex = exact.get("diag_ggn/0/w").unwrap().f32s().unwrap();
    // Correlation between averaged MC and exact diagonal.
    let n = ex.len() as f64;
    let (mut sx, mut sy, mut sxx, mut syy, mut sxy) =
        (0.0, 0.0, 0.0, 0.0, 0.0);
    for i in 0..ex.len() {
        let (xv, yv) = (ex[i] as f64, acc[i]);
        sx += xv;
        sy += yv;
        sxx += xv * xv;
        syy += yv * yv;
        sxy += xv * yv;
    }
    let corr = (n * sxy - sx * sy)
        / ((n * sxx - sx * sx).sqrt() * (n * syy - sy * sy).sqrt());
    assert!(corr > 0.8, "MC/exact correlation too low: {corr}");
}

#[test]
fn eval_artifact_reports_chance_accuracy_at_init() {
    let rt = runtime();
    let problem = problems::by_name("mnist_logreg").unwrap();
    let exe = rt.load(problem.eval_artifact).unwrap();
    let train_spec = rt.load("logreg_grad_n64").unwrap();
    let params = init_params(&train_spec.spec, 4);
    let ds = problem.make_dataset(0xDA7A5E_u64).unwrap();
    let idx: Vec<usize> = (0..256).collect();
    let (x, y) = ds.batch(1, &idx);
    let out = exe
        .run(&build_inputs(
            &params,
            Tensor::from_f32(&[256, 784], x),
            Tensor::from_i32(&[256], y),
            None,
        ))
        .unwrap();
    let acc = out.get("accuracy").unwrap().item_f32().unwrap();
    assert!((0.0..0.35).contains(&acc), "chance-ish at init, got {acc}");
}

#[test]
fn training_reduces_loss_for_every_optimizer_on_logreg() {
    let rt = runtime();
    let problem = problems::by_name("mnist_logreg").unwrap();
    for (opt, lr, damping) in [
        ("sgd", 0.1, 0.0),
        ("momentum", 0.02, 0.0),
        ("adam", 0.003, 0.0),
        ("diag_ggn", 0.01, 0.01),
        ("diag_ggn_mc", 0.01, 0.01),
        ("kfac", 0.01, 0.01),
        ("kflr", 0.01, 0.01),
        ("kfra", 0.01, 0.01),
    ] {
        let cfg = TrainConfig {
            problem: problem.codename.into(),
            optimizer: opt.into(),
            hyper: Hyper { lr, damping, l2: 0.0 },
            steps: 30,
            seed: 0,
            eval_every: 29,
            inv_every: 1,
            log_every: 29,
            verbose: false,
        };
        let log = train::train(&rt, problem, &cfg).unwrap();
        assert!(!log.diverged, "{opt} diverged");
        let first = log.train_loss.first().unwrap().1;
        let last = log.final_train_loss();
        assert!(
            last < first,
            "{opt}: loss did not decrease ({first} -> {last})"
        );
    }
}

#[test]
fn seeds_are_reproducible() {
    let rt = runtime();
    let problem = problems::by_name("mnist_logreg").unwrap();
    let cfg = TrainConfig {
        problem: problem.codename.into(),
        optimizer: "diag_ggn".into(),
        hyper: Hyper { lr: 0.01, damping: 0.01, l2: 0.0 },
        steps: 10,
        seed: 7,
        eval_every: 9,
        inv_every: 1,
        log_every: 1,
        verbose: false,
    };
    let a = train::train(&rt, problem, &cfg).unwrap();
    let b = train::train(&rt, problem, &cfg).unwrap();
    assert_eq!(a.train_loss, b.train_loss, "same seed, same curve");
    let mut cfg2 = cfg.clone();
    cfg2.seed = 8;
    let c = train::train(&rt, problem, &cfg2).unwrap();
    assert_ne!(a.train_loss, c.train_loss, "different seed differs");
}

#[test]
fn wrong_input_shape_is_rejected() {
    let rt = runtime();
    let exe = rt.load("logreg_grad_n64").unwrap();
    let params = init_params(&exe.spec, 0);
    let (x, y) = logreg_batch(32, 0); // wrong batch size
    assert!(exe.run(&build_inputs(&params, x, y, None)).is_err());
}

#[test]
fn wrong_input_count_is_rejected() {
    let rt = runtime();
    let exe = rt.load("logreg_grad_n64").unwrap();
    let params = init_params(&exe.spec, 0);
    let inputs: Vec<Tensor> =
        params.iter().map(|p| p.tensor.clone()).collect();
    assert!(exe.run(&inputs).is_err());
}

#[test]
fn kfac_factors_have_matching_dimensions() {
    let rt = runtime();
    let exe = rt.load("logreg_kfac_n64").unwrap();
    let params = init_params(&exe.spec, 5);
    let (x, y) = logreg_batch(64, 5);
    let out = exe
        .run(&build_inputs(&params, x, y, Some([3, 4])))
        .unwrap();
    let a = out.get("kfac/0/A").unwrap();
    let b = out.get("kfac/0/B").unwrap();
    assert_eq!(a.shape, vec![784, 784]);
    assert_eq!(b.shape, vec![10, 10]);
    // PSD spot-check: diagonals non-negative.
    for i in 0..784 {
        assert!(a.f32s().unwrap()[i * 784 + i] >= -1e-6);
    }
}
