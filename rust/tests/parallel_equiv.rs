//! Seeded equivalence suite for the batch-parallel native engine:
//! 1-thread vs N-thread `extended_backward` must agree to f32
//! summation-reordering error (≤ 1e-5) for every native extension
//! signature on the paper's registry models (logreg, mlp), and the
//! per-sample quantities must keep their sample order. Same
//! proptests-style seeded driver as `tests/proptests.rs`: every case
//! is a pure function of its seed, and failures report it.

use backpack_rs::backend::conv::Shape;
use backpack_rs::backend::layers::Layer;
use backpack_rs::backend::model::{
    ExtractOptions, Model, NATIVE_EXTENSIONS,
};
use backpack_rs::backend::native::NativeBackend;
use backpack_rs::backend::Backend;
use backpack_rs::coordinator::train::{build_inputs, init_params};
use backpack_rs::data::Rng;
use backpack_rs::runtime::Tensor;

/// Run `prop` for `cases` seeded cases; panic with the seed on failure.
fn check<F: Fn(&mut Rng, u64) -> Result<(), String>>(
    name: &str,
    cases: u64,
    prop: F,
) {
    for seed in 0..cases {
        let mut rng = Rng::new(0x9A7A ^ seed);
        if let Err(msg) = prop(&mut rng, seed) {
            panic!("property {name} failed at seed {seed}: {msg}");
        }
    }
}

fn registry_model(name: &str) -> Model {
    match name {
        "logreg" => Model::logreg(),
        "mlp" => Model::mlp(),
        other => panic!("no registry model {other}"),
    }
}

/// Small random parameters + batch for a registry model.
fn problem(
    m: &Model,
    n: usize,
    rng: &mut Rng,
) -> (Vec<Tensor>, Tensor, Tensor) {
    let params: Vec<Tensor> = m
        .param_specs()
        .iter()
        .map(|t| {
            let k: usize = t.shape.iter().product();
            Tensor::from_f32(
                &t.shape,
                (0..k).map(|_| rng.normal() * 0.05).collect(),
            )
        })
        .collect();
    let x: Vec<f32> = (0..n * m.in_dim).map(|_| rng.normal()).collect();
    let y: Vec<i32> =
        (0..n).map(|_| rng.below(m.classes) as i32).collect();
    (
        params,
        Tensor::from_f32(&[n, m.in_dim], x),
        Tensor::from_i32(&[n], y),
    )
}

fn assert_close(
    key: &str,
    want: &Tensor,
    got: &Tensor,
    tol: f32,
) -> Result<(), String> {
    if want.shape != got.shape {
        return Err(format!(
            "{key}: shape {:?} vs {:?}",
            want.shape, got.shape
        ));
    }
    let (a, b) = (
        want.f32s().map_err(|e| e.to_string())?,
        got.f32s().map_err(|e| e.to_string())?,
    );
    for (i, (u, v)) in a.iter().zip(b).enumerate() {
        if (u - v).abs() > tol * (1.0 + u.abs()) {
            return Err(format!("{key}[{i}]: {u} vs {v}"));
        }
    }
    Ok(())
}

/// The tentpole acceptance property: every extension signature,
/// both registry models, 1 thread vs several (including counts that
/// do not divide the batch), agreement ≤ 1e-5.
#[test]
fn all_signatures_agree_across_thread_counts() {
    let mut signatures: Vec<Vec<String>> = vec![Vec::new()]; // "grad"
    for ext in NATIVE_EXTENSIONS {
        signatures.push(vec![ext.to_string()]);
    }
    for model_name in ["logreg", "mlp"] {
        let m = registry_model(model_name);
        check(&format!("thread_equiv_{model_name}"), 2, |rng, seed| {
            let n = 11 + rng.below(10); // odd sizes: uneven shards
            let (params, x, y) = problem(&m, n, rng);
            let key = Some([seed as u32, 0xC0FE]);
            let opts =
                ExtractOptions { key, ..ExtractOptions::default() };
            for exts in &signatures {
                let serial = m
                    .extended_backward(&params, &x, &y, exts, &opts)
                    .map_err(|e| e.to_string())?;
                for threads in [2usize, 3, 7] {
                    let par = m
                        .extended_backward_threads(
                            &params, &x, &y, exts, key, threads,
                        )
                        .map_err(|e| e.to_string())?;
                    if serial.len() != par.len() {
                        return Err(format!(
                            "{exts:?}: {} vs {} outputs",
                            serial.len(),
                            par.len()
                        ));
                    }
                    for (k, want) in &serial {
                        let got = par.get(k).ok_or_else(|| {
                            format!("threads={threads}: missing {k}")
                        })?;
                        assert_close(
                            &format!("{exts:?}/{k} threads={threads}"),
                            want,
                            got,
                            1e-5,
                        )?;
                    }
                }
            }
            Ok(())
        });
    }
}

/// Conv acceptance property: every signature `cifar10_3c3d`'s
/// optimizers use ("grad", diag_ggn, diag_ggn_mc, kfac, kflr) agrees
/// between 1 thread and several to ≤ 1e-5 on the real 3c3d model --
/// conv factors sum-reduce like linear ones, MC draws are keyed by
/// global sample index, max-pool routing is shard-independent. One
/// small odd batch keeps the exact-GGN signatures debug-test-sized.
#[test]
fn conv_3c3d_signatures_agree_across_thread_counts() {
    let m = Model::conv_3c3d();
    let mut rng = Rng::new(0xC07);
    let n = 4; // uneven shards at 3 threads (2, 1, 1)
    let (params, x, y) = problem(&m, n, &mut rng);
    let key = Some([21, 0xC0FE]);
    let signatures: Vec<Vec<String>> = vec![
        Vec::new(), // "grad"
        vec!["diag_ggn".into()],
        vec!["diag_ggn_mc".into()],
        vec!["kfac".into()],
        vec!["kflr".into()],
        vec!["batch_grad".into(), "batch_l2".into(),
             "variance".into()],
    ];
    let opts = ExtractOptions { key, ..ExtractOptions::default() };
    for exts in &signatures {
        let serial = m
            .extended_backward(&params, &x, &y, exts, &opts)
            .unwrap();
        for threads in [2usize, 3] {
            let par = m
                .extended_backward_threads(
                    &params, &x, &y, exts, key, threads,
                )
                .unwrap();
            assert_eq!(serial.len(), par.len(), "{exts:?}");
            for (k, want) in &serial {
                assert_close(
                    &format!("3c3d {exts:?}/{k} threads={threads}"),
                    want,
                    par.get(k).unwrap(),
                    1e-5,
                )
                .unwrap();
            }
        }
    }
}

/// `diag_h`'s residual factors are born per shard from shard-local
/// activations and gradients, normalized by the global batch size:
/// 1 thread vs several (uneven shards included) must agree ≤ 1e-5 on
/// a conv + sigmoid + GAP model where the factors propagate through
/// conv, pooling and linear layers. (The fully-connected diag_h case
/// is covered by the all-signature sweep above, which iterates
/// `NATIVE_EXTENSIONS` — diag_h included — on logreg and mlp.)
#[test]
fn diag_h_residual_factors_agree_across_thread_counts() {
    let m = Model::with_input(
        "tinysig",
        Shape::new(2, 4, 4),
        vec![
            Layer::Conv2d {
                in_ch: 2, out_ch: 4, kernel: 3, stride: 2, pad: 1,
            },
            Layer::Sigmoid,
            Layer::Conv2d {
                in_ch: 4, out_ch: 3, kernel: 1, stride: 1, pad: 0,
            },
            Layer::GlobalAvgPool,
        ],
    )
    .unwrap();
    check("diag_h_thread_equiv", 2, |rng, _seed| {
        let n = 5 + rng.below(5); // uneven shards at 3 threads
        let (params, x, y) = problem(&m, n, rng);
        let exts =
            vec!["diag_h".to_string(), "diag_ggn".to_string()];
        let serial = m
            .extended_backward(
                &params, &x, &y, &exts, &ExtractOptions::default(),
            )
            .map_err(|e| e.to_string())?;
        // Sanity: the residual actually fires (diag_h != diag_ggn
        // below the sigmoid), otherwise this test proves nothing.
        let h = serial["diag_h/0/w"]
            .f32s()
            .map_err(|e| e.to_string())?;
        let g = serial["diag_ggn/0/w"]
            .f32s()
            .map_err(|e| e.to_string())?;
        let max_rel = h
            .iter()
            .zip(g)
            .map(|(u, v)| (u - v).abs() / (1.0 + v.abs()))
            .fold(0.0f32, f32::max);
        if max_rel <= 1e-6 {
            return Err(format!(
                "residual term inert (max rel diff {max_rel})"
            ));
        }
        for threads in [2usize, 3, 5] {
            let par = m
                .extended_backward_threads(
                    &params, &x, &y, &exts, None, threads,
                )
                .map_err(|e| e.to_string())?;
            if serial.len() != par.len() {
                return Err(format!(
                    "{} vs {} outputs",
                    serial.len(),
                    par.len()
                ));
            }
            for (k, want) in &serial {
                let got = par.get(k).ok_or_else(|| {
                    format!("threads={threads}: missing {k}")
                })?;
                assert_close(
                    &format!("{k} threads={threads}"),
                    want,
                    got,
                    1e-5,
                )?;
            }
        }
        Ok(())
    });
}

/// `batch_grad` keeps sample order under sharding: row `s` of the
/// N-thread result must equal the gradient of sample `s` computed
/// alone (rescaled from its own batch-of-1 normalization to 1/N).
#[test]
fn batch_grad_sample_order_is_preserved() {
    let m = Model::mlp();
    check("batch_grad_order", 2, |rng, _seed| {
        let n = 9 + rng.below(4);
        let (params, x, y) = problem(&m, n, rng);
        let exts = vec!["batch_grad".to_string()];
        let par = m
            .extended_backward_threads(&params, &x, &y, &exts, None, 4)
            .map_err(|e| e.to_string())?;
        let xs = x.f32s().map_err(|e| e.to_string())?;
        let ys = y.i32s().map_err(|e| e.to_string())?;
        for s in [0usize, n / 2, n - 1] {
            let xi = Tensor::from_f32(
                &[1, m.in_dim],
                xs[s * m.in_dim..(s + 1) * m.in_dim].to_vec(),
            );
            let yi = Tensor::from_i32(&[1], vec![ys[s]]);
            let single = m
                .extended_backward(
                    &params, &xi, &yi, &exts,
                    &ExtractOptions::default(),
                )
                .map_err(|e| e.to_string())?;
            for (li, din, dout) in m.linear_dims() {
                for (part, d) in [("w", dout * din), ("b", dout)] {
                    let key = format!("batch_grad/{li}/{part}");
                    let full = par[&key]
                        .f32s()
                        .map_err(|e| e.to_string())?;
                    let one = single[&key]
                        .f32s()
                        .map_err(|e| e.to_string())?;
                    for i in 0..d {
                        // batch-of-1 rows carry 1/1; the full batch
                        // carries 1/N.
                        let want = one[i] / n as f32;
                        let got = full[s * d + i];
                        if (got - want).abs()
                            > 1e-5 * (1.0 + want.abs())
                        {
                            return Err(format!(
                                "{key} sample {s} [{i}]: {got} vs {want}"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// Persistent-pool sweep (DESIGN.md §14): thread counts that leave
/// remainders against the batch (3, 5), reuse pool workers across
/// counts, and overshoot the shard supply entirely (13 > n) must all
/// agree with serial ≤ 1e-5. Before the pool, each count got a fresh
/// set of scoped threads; now the same lazily-grown workers serve
/// every count, so this sweep pins that shard layout -- not worker
/// identity -- determines the numbers.
#[test]
fn pool_reuse_across_thread_counts_matches_serial() {
    let m = Model::mlp();
    check("pool_sweep", 2, |rng, seed| {
        let n = 9 + rng.below(4); // 9..=12, all below 13 threads
        let (params, x, y) = problem(&m, n, rng);
        let key = Some([seed as u32, 0xBEEF]);
        let exts: Vec<String> =
            ["batch_grad", "variance", "diag_ggn"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let serial = m
            .extended_backward_threads(&params, &x, &y, &exts, key, 1)
            .map_err(|e| e.to_string())?;
        for threads in [2usize, 3, 5, 13] {
            let par = m
                .extended_backward_threads(
                    &params, &x, &y, &exts, key, threads,
                )
                .map_err(|e| e.to_string())?;
            if serial.len() != par.len() {
                return Err(format!(
                    "threads={threads}: {} vs {} outputs",
                    serial.len(),
                    par.len()
                ));
            }
            for (k, want) in &serial {
                let got = par.get(k).ok_or_else(|| {
                    format!("threads={threads}: missing {k}")
                })?;
                assert_close(
                    &format!("{k} threads={threads}"),
                    want,
                    got,
                    1e-5,
                )?;
            }
        }
        Ok(())
    });
}

/// Fixed thread count => bit-for-bit identical outputs (shard
/// reduction order is deterministic, never scheduler-dependent).
#[test]
fn fixed_thread_count_is_bitwise_deterministic() {
    let m = Model::mlp();
    let mut rng = Rng::new(0xD37);
    let (params, x, y) = problem(&m, 13, &mut rng);
    let exts: Vec<String> = ["variance", "diag_ggn_mc", "kfra"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let key = Some([5, 6]);
    for threads in [1usize, 4] {
        let a = m
            .extended_backward_threads(&params, &x, &y, &exts, key, threads)
            .unwrap();
        let b = m
            .extended_backward_threads(&params, &x, &y, &exts, key, threads)
            .unwrap();
        for (k, va) in &a {
            assert_eq!(va, &b[k], "{k} threads={threads}");
        }
    }
}

/// The full backend path honors the configured worker count: a
/// 1-thread and an 8-thread backend produce ≤ 1e-5-equal training
/// graphs for the combined first-order signature.
#[test]
fn backend_thread_counts_agree_end_to_end() {
    let serial = NativeBackend::with_threads(1);
    let parallel = NativeBackend::with_threads(8);
    assert_eq!(serial.threads(), 1);
    assert_eq!(parallel.threads(), 8);
    let name = "mlp_batch_grad+batch_l2+sq_moment+variance_n24";
    let exe1 = serial.load(name).unwrap();
    let exe8 = parallel.load(name).unwrap();
    let params = init_params(exe1.spec(), 3);
    let mut rng = Rng::new(77);
    let x: Vec<f32> = (0..24 * 784).map(|_| rng.normal()).collect();
    let y: Vec<i32> = (0..24).map(|_| rng.below(10) as i32).collect();
    let inputs = build_inputs(
        &params,
        Tensor::from_f32(&[24, 784], x),
        Tensor::from_i32(&[24], y),
        None,
    );
    let o1 = exe1.run(&inputs).unwrap();
    let o8 = exe8.run(&inputs).unwrap();
    let names: Vec<&String> = o1.names().collect();
    assert_eq!(names, o8.names().collect::<Vec<_>>());
    for k in names {
        assert_close(k, o1.get(k).unwrap(), o8.get(k).unwrap(), 1e-5)
            .unwrap();
    }
}
