//! End-to-end tests for `backpack serve`: N concurrent clients
//! against one daemon, with the exactness contract pinned at
//! `threads = 1` -- every coalesced reply must be **bitwise** equal
//! to one serial `extended_backward` over the union batch (Concat
//! keys sliced to the client's rows, Sum keys broadcast).
//!
//! Determinism recipe: clients rendezvous on a barrier before
//! sending, `max_batch` is set to the exact union size so the
//! scheduler closes the batch as soon as every participant has
//! arrived, and a generous linger window is the flake guard.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier};

use backpack_rs::coordinator::train::{build_inputs, init_params};
use backpack_rs::data::{DatasetSpec, Synthetic};
use backpack_rs::runtime::Tensor;
use backpack_rs::serve::protocol::{
    read_frame, write_frame, ExtractReply, ExtractRequest,
};
use backpack_rs::serve::{
    AccessRecord, ServeConfig, Server, ServerHandle,
};
use backpack_rs::{
    ArtifactId, Backend, Exec, ExtensionSet, Json, NativeBackend,
    Reduce, METRICS_SCHEMA,
};

/// Samples each client contributes.
const PER: usize = 4;
/// logreg input size (mnist 28*28).
const IN: usize = 784;

fn start(
    cfg: ServeConfig,
) -> (SocketAddr, ServerHandle, std::thread::JoinHandle<()>) {
    let server = Server::bind(cfg).unwrap();
    let addr = server.local_addr();
    let handle = server.handle();
    let join = std::thread::spawn(move || server.run().unwrap());
    (addr, handle, join)
}

/// Client `i`'s deterministic synthetic-MNIST slice.
fn slice_of(i: usize) -> (Vec<f32>, Vec<i32>) {
    let ds =
        Synthetic::new(DatasetSpec::by_name("mnist").unwrap(), 0);
    let idx: Vec<usize> = (i * PER..(i + 1) * PER).collect();
    ds.batch(0, &idx)
}

fn request(i: usize, sig: &str, seed: u64) -> ExtractRequest {
    let (x, y) = slice_of(i);
    ExtractRequest {
        id: i as u64,
        model: "logreg".into(),
        sig: sig.parse().unwrap(),
        seed,
        x,
        y,
        key: Some([7, 9]),
        want_metrics: false,
    }
}

fn roundtrip(c: &mut TcpStream, frame: &str) -> ExtractReply {
    write_frame(c, frame).unwrap();
    ExtractReply::parse(&read_frame(c).unwrap().unwrap()).unwrap()
}

/// One serial library call over the union batch: the exactness
/// reference the daemon must reproduce bit-for-bit.
fn serial_reference(
    sig: &str,
    xs: Vec<f32>,
    ys: Vec<i32>,
    seed: u64,
    key: Option<[u32; 2]>,
) -> BTreeMap<String, Tensor> {
    let be = NativeBackend::with_threads(1);
    let n = ys.len();
    let id =
        ArtifactId::new("logreg", sig.parse().unwrap(), n).unwrap();
    let exe = be.load_id(&id).unwrap();
    let spec = exe.spec().clone();
    let params = init_params(&spec, seed);
    let mut x_shape = vec![n];
    x_shape.extend_from_slice(&spec.in_shape);
    let x = Tensor::from_f32(&x_shape, xs);
    let y = Tensor::from_i32(&[n], ys);
    let key = if spec.has_key { key } else { None };
    let out = exe.run(&build_inputs(&params, x, y, key)).unwrap();
    out.names()
        .map(|k| (k.clone(), out.get(k).unwrap().clone()))
        .collect()
}

/// Assert one client's reply equals its view of the union
/// reference: Concat-reduced keys sliced to its rows, everything
/// else broadcast -- bitwise.
fn assert_matches_reference(
    sig: &str,
    reply: &ExtractReply,
    reference: &BTreeMap<String, Tensor>,
    total: usize,
) {
    let exts = ExtensionSet::builtin();
    let meta = reply.meta.unwrap();
    assert_eq!(meta.batch_n, total, "{sig}");
    let (off, n) = (meta.offset, meta.n);
    assert_eq!(reply.results.len(), reference.len(), "{sig}");
    for (k, got) in &reply.results {
        let full = &reference[k];
        let per_sample = matches!(exts.reduce(k), Reduce::Concat)
            && full.shape.first() == Some(&total);
        let (want_shape, want) = if per_sample {
            let rows = full.numel() / total;
            let mut s = full.shape.clone();
            s[0] = n;
            (
                s,
                full.f32s().unwrap()[off * rows..(off + n) * rows]
                    .to_vec(),
            )
        } else {
            (full.shape.clone(), full.f32s().unwrap().to_vec())
        };
        assert_eq!(got.shape, want_shape, "{sig} {k}");
        for (a, b) in got.f32s().unwrap().iter().zip(&want) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{sig} {k}: {a} vs {b}"
            );
        }
    }
}

/// Fan `CLIENTS` concurrent requests at the daemon and collect
/// `(client, reply)` pairs. Each client opens its own connection,
/// rendezvouses on the barrier, then sends.
fn fan_out(
    addr: SocketAddr,
    reqs: Vec<ExtractRequest>,
) -> Vec<(usize, ExtractReply)> {
    let barrier = Arc::new(Barrier::new(reqs.len()));
    std::thread::scope(|s| {
        let handles: Vec<_> = reqs
            .into_iter()
            .enumerate()
            .map(|(i, req)| {
                let barrier = Arc::clone(&barrier);
                s.spawn(move || {
                    let mut c = TcpStream::connect(addr).unwrap();
                    barrier.wait();
                    (i, roundtrip(&mut c, &req.to_json()))
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Rebuild the union batch the daemon actually ran from the reply
/// offsets (arrival order is the daemon's choice, not ours).
fn union_from_offsets(
    placed: &[(usize, usize)], // (client, offset)
    total: usize,
) -> (Vec<f32>, Vec<i32>) {
    let mut xs = vec![0.0f32; total * IN];
    let mut ys = vec![0i32; total];
    for &(client, off) in placed {
        let (x, y) = slice_of(client);
        xs[off * IN..(off + PER) * IN].copy_from_slice(&x);
        ys[off..off + PER].copy_from_slice(&y);
    }
    (xs, ys)
}

#[test]
fn coalesced_daemon_matches_serial_for_every_builtin_signature() {
    const CLIENTS: usize = 4;
    let total = CLIENTS * PER;
    let (addr, handle, join) = start(ServeConfig {
        threads: 1,
        linger_ms: 2_000,
        max_batch: total,
        ..ServeConfig::default()
    });
    let sigs = [
        "eval",
        "grad",
        "batch_grad",
        "batch_l2",
        "sq_moment",
        "variance",
        "diag_ggn",
        "diag_ggn_mc",
        "diag_h",
        "kfac",
        "kflr",
        "kfra",
    ];
    for sig in sigs {
        let replies = fan_out(
            addr,
            (0..CLIENTS).map(|i| request(i, sig, 3)).collect(),
        );
        let mut placed = Vec::new();
        for (i, r) in &replies {
            assert!(r.ok, "sig {sig} client {i}: {:?}", r.error);
            let meta = r.meta.unwrap();
            assert_eq!(meta.coalesced, CLIENTS, "sig {sig}");
            placed.push((*i, meta.offset));
        }
        let mut offsets: Vec<usize> =
            placed.iter().map(|p| p.1).collect();
        offsets.sort_unstable();
        assert_eq!(offsets, vec![0, 4, 8, 12], "sig {sig}");
        let (xs, ys) = union_from_offsets(&placed, total);
        let reference =
            serial_reference(sig, xs, ys, 3, Some([7, 9]));
        for (_, r) in &replies {
            assert_matches_reference(sig, r, &reference, total);
        }
    }
    handle.shutdown();
    join.join().unwrap();
}

#[test]
fn interleaved_mixed_signature_traffic_batches_per_signature() {
    let total = 2 * PER;
    let (addr, handle, join) = start(ServeConfig {
        threads: 1,
        linger_ms: 2_000,
        max_batch: total,
        ..ServeConfig::default()
    });
    // Clients 0,2 ask for grad; clients 1,3 for diag_ggn+batch_l2 --
    // interleaved arrival, two independent batches.
    let sig_of = |i: usize| {
        if i % 2 == 0 {
            "grad"
        } else {
            "diag_ggn+batch_l2"
        }
    };
    let replies = fan_out(
        addr,
        (0..4).map(|i| request(i, sig_of(i), 11)).collect(),
    );
    for group in ["grad", "diag_ggn+batch_l2"] {
        let members: Vec<&(usize, ExtractReply)> = replies
            .iter()
            .filter(|(i, _)| sig_of(*i) == group)
            .collect();
        assert_eq!(members.len(), 2);
        let mut placed = Vec::new();
        for (i, r) in &members {
            assert!(r.ok, "{group} client {i}: {:?}", r.error);
            let meta = r.meta.unwrap();
            // Only same-signature requests coalesce.
            assert_eq!(meta.coalesced, 2, "{group}");
            assert_eq!(meta.batch_n, total, "{group}");
            placed.push((*i, meta.offset));
        }
        let (xs, ys) = union_from_offsets(&placed, total);
        let reference =
            serial_reference(group, xs, ys, 11, Some([7, 9]));
        for (_, r) in &members {
            assert_matches_reference(group, r, &reference, total);
        }
    }
    handle.shutdown();
    join.join().unwrap();
}

#[test]
fn client_disconnect_mid_batch_does_not_disturb_the_rest() {
    const CLIENTS: usize = 3;
    let total = CLIENTS * PER;
    let (addr, handle, join) = start(ServeConfig {
        threads: 1,
        linger_ms: 2_000,
        max_batch: total,
        ..ServeConfig::default()
    });
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let replies: Vec<Option<(usize, ExtractReply)>> =
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..CLIENTS)
                .map(|i| {
                    let barrier = Arc::clone(&barrier);
                    s.spawn(move || {
                        let req = request(i, "batch_grad", 5);
                        let mut c =
                            TcpStream::connect(addr).unwrap();
                        barrier.wait();
                        write_frame(&mut c, &req.to_json())
                            .unwrap();
                        if i == 0 {
                            // Vanish mid-batch: the daemon must
                            // tolerate the dead reply channel.
                            drop(c);
                            return None;
                        }
                        Some((
                            i,
                            ExtractReply::parse(
                                &read_frame(&mut c)
                                    .unwrap()
                                    .unwrap(),
                            )
                            .unwrap(),
                        ))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
    let survivors: Vec<&(usize, ExtractReply)> =
        replies.iter().flatten().collect();
    assert_eq!(survivors.len(), CLIENTS - 1);
    let mut placed = Vec::new();
    let mut seen = vec![false; CLIENTS];
    for (i, r) in &survivors {
        assert!(r.ok, "client {i}: {:?}", r.error);
        let meta = r.meta.unwrap();
        // The ghost still rode in the batch...
        assert_eq!(meta.coalesced, CLIENTS);
        assert_eq!(meta.batch_n, total);
        placed.push((*i, meta.offset));
        seen[meta.offset / PER] = true;
    }
    // ...at the one offset no survivor occupies.
    let ghost_off =
        seen.iter().position(|s| !s).unwrap() * PER;
    placed.push((0, ghost_off));
    let (xs, ys) = union_from_offsets(&placed, total);
    let reference = serial_reference("batch_grad", xs, ys, 5, None);
    for (_, r) in &survivors {
        assert_matches_reference("batch_grad", r, &reference, total);
    }
    handle.shutdown();
    join.join().unwrap();
}

#[test]
fn bounded_queue_drains_an_incompatible_flood() {
    // 8 concurrent clients with pairwise-different seeds: nothing
    // can coalesce, the queue (capacity 2) must cycle blocking
    // pushes, and every client still gets its exact solo result.
    let (addr, handle, join) = start(ServeConfig {
        threads: 1,
        queue_cap: 2,
        linger_ms: 1,
        max_batch: 64,
        ..ServeConfig::default()
    });
    let replies = fan_out(
        addr,
        (0..8)
            .map(|i| request(i % 4, "variance", i as u64))
            .collect(),
    );
    for (i, r) in &replies {
        assert!(r.ok, "client {i}: {:?}", r.error);
        let (xs, ys) = slice_of(i % 4);
        let reference = serial_reference(
            "variance",
            xs,
            ys,
            *i as u64,
            None,
        );
        assert_matches_reference("variance", r, &reference, PER);
    }
    handle.shutdown();
    join.join().unwrap();
}

#[test]
fn wire_errors_carry_nearest_match_suggestions() {
    let (addr, handle, join) = start(ServeConfig {
        threads: 1,
        linger_ms: 1,
        ..ServeConfig::default()
    });
    let mut c = TcpStream::connect(addr).unwrap();
    let expect_err = |c: &mut TcpStream, req: ExtractRequest| {
        let r = roundtrip(c, &req.to_json());
        assert!(!r.ok, "expected failure, got ok");
        r.error.unwrap()
    };

    // Misspelled model: nearest registered model suggested.
    let mut req = request(0, "grad", 0);
    req.model = "logrge".into();
    let e = expect_err(&mut c, req);
    assert!(e.contains("did you mean"), "{e}");
    assert!(e.contains("logreg"), "{e}");

    // Misspelled extension: nearest builtin suggested.
    let e = expect_err(&mut c, request(0, "diag_gnn", 0));
    assert!(e.contains("did you mean"), "{e}");
    assert!(e.contains("diag_ggn"), "{e}");

    // Monte-Carlo signature without a key.
    let mut req = request(0, "kfac", 0);
    req.key = None;
    let e = expect_err(&mut c, req);
    assert!(e.contains("key"), "{e}");

    // Wrong input volume.
    let mut req = request(0, "grad", 0);
    req.x.truncate(10);
    let e = expect_err(&mut c, req);
    assert!(e.contains("values"), "{e}");

    // Label out of range.
    let mut req = request(0, "grad", 0);
    req.y[0] = 99;
    let e = expect_err(&mut c, req);
    assert!(e.contains("outside"), "{e}");

    // A healthy request on the same connection still succeeds:
    // rejections are per-request, not per-session.
    let r = roundtrip(&mut c, &request(0, "grad", 0).to_json());
    assert!(r.ok, "{:?}", r.error);
    handle.shutdown();
    join.join().unwrap();
}

#[test]
fn metrics_are_schema_valid_per_request_and_aggregate() {
    let golden = [
        "counters",
        "details",
        "overhead",
        "phases",
        "quantities",
        "schema",
        "shards",
        "wall_s",
    ];
    let assert_metrics_shape = |m: &Json| {
        let keys: Vec<&str> = m
            .as_obj()
            .unwrap()
            .keys()
            .map(|k| k.as_str())
            .collect();
        assert_eq!(keys, golden);
        assert_eq!(
            m.get("schema").unwrap().as_str().unwrap(),
            METRICS_SCHEMA
        );
    };
    let (addr, handle, join) = start(ServeConfig {
        threads: 1,
        linger_ms: 1,
        ..ServeConfig::default()
    });
    let mut c = TcpStream::connect(addr).unwrap();

    // Per-request window: `"metrics": true` rides on the reply.
    let mut req = request(0, "diag_ggn", 0);
    req.want_metrics = true;
    let r = roundtrip(&mut c, &req.to_json());
    assert!(r.ok, "{:?}", r.error);
    assert_metrics_shape(r.metrics.as_ref().unwrap());

    // Aggregate endpoint: schema-pure metrics + serve counters.
    write_frame(&mut c, "{\"op\":\"metrics\",\"id\":42}").unwrap();
    let v =
        Json::parse(&read_frame(&mut c).unwrap().unwrap()).unwrap();
    assert!(v.get("ok").unwrap().as_bool().unwrap());
    assert_metrics_shape(v.get("metrics").unwrap());
    let s = v.get("serve").unwrap();
    assert_eq!(
        s.get("schema").unwrap().as_str().unwrap(),
        "backpack-serve/v1"
    );
    assert!(s.get("batches").unwrap().as_usize().unwrap() >= 1);
    assert!(s.get("extracts").unwrap().as_usize().unwrap() >= 1);
    handle.shutdown();
    join.join().unwrap();
}

#[test]
fn metrics_expose_the_per_stage_latency_section() {
    let (addr, handle, join) = start(ServeConfig {
        threads: 1,
        linger_ms: 1,
        ..ServeConfig::default()
    });
    let mut c = TcpStream::connect(addr).unwrap();
    for i in 0..3 {
        let r = roundtrip(&mut c, &request(i, "grad", 0).to_json());
        assert!(r.ok, "{:?}", r.error);
    }
    write_frame(&mut c, "{\"op\":\"metrics\",\"id\":1}").unwrap();
    let v =
        Json::parse(&read_frame(&mut c).unwrap().unwrap()).unwrap();
    let s = v.get("serve").unwrap();
    // New counters ride next to the existing ones.
    assert!(
        s.get("batched_requests").unwrap().as_usize().unwrap() >= 3
    );
    assert_eq!(
        s.get("conns_rejected").unwrap().as_usize().unwrap(),
        0
    );
    let lat = s.get("latency").unwrap();
    assert_eq!(lat.get("unit").unwrap().as_str().unwrap(), "us");
    // Every stage histogram saw traffic (replies are written, and
    // their records finished, before the next request is sent; the
    // in-flight third reply makes these >= rather than ==).
    for stage in ["queue", "linger", "extract", "reply"] {
        let h = lat.get("stages").unwrap().get(stage).unwrap();
        assert!(
            h.get("count").unwrap().as_usize().unwrap() >= 1,
            "stage {stage} saw no samples"
        );
    }
    let e2e = lat.get("e2e").unwrap();
    assert!(e2e.get("count").unwrap().as_usize().unwrap() >= 1);
    // Percentiles are present and ordered on a non-empty histogram.
    let p50 = e2e.get("p50").unwrap().as_f64().unwrap();
    let p99 = e2e.get("p99").unwrap().as_f64().unwrap();
    assert!(p50 <= p99, "{p50} > {p99}");
    // Three sequential solo requests: three engine calls of 4
    // samples each, no coalescing.
    let bs = lat.get("batch_size").unwrap();
    assert!(bs.get("count").unwrap().as_usize().unwrap() >= 3);
    assert_eq!(bs.get("min").unwrap().as_usize().unwrap(), PER);
    let co = lat.get("coalescing").unwrap();
    assert!(co.get("batches").unwrap().as_usize().unwrap() >= 3);
    assert_eq!(
        co.get("rate").unwrap().as_f64().unwrap(),
        0.0,
        "solo requests must not count as coalesced"
    );
    handle.shutdown();
    join.join().unwrap();
}

#[test]
fn access_log_records_every_request_outcome() {
    let dir = std::env::temp_dir().join("backpack_serve_access");
    std::fs::create_dir_all(&dir).unwrap();
    let log = dir.join(format!("access_{}.jsonl", std::process::id()));
    const CLIENTS: usize = 4;
    let total = CLIENTS * PER;
    let (addr, handle, join) = start(ServeConfig {
        threads: 1,
        linger_ms: 2_000,
        max_batch: total,
        access_log: Some(log.clone()),
        ..ServeConfig::default()
    });
    // One coalesced batch of 4, then one admission-rejected request.
    let replies = fan_out(
        addr,
        (0..CLIENTS).map(|i| request(i, "grad", 3)).collect(),
    );
    for (i, r) in &replies {
        assert!(r.ok, "client {i}: {:?}", r.error);
    }
    let mut c = TcpStream::connect(addr).unwrap();
    let mut bad = request(0, "grad", 3);
    bad.model = "logrge".into();
    bad.id = 99;
    assert!(!roundtrip(&mut c, &bad.to_json()).ok);

    // Records are finished on writer threads just after the reply
    // bytes land, so poll briefly for the expected line count.
    let want = CLIENTS + 1;
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs(10);
    let text = loop {
        let text =
            std::fs::read_to_string(&log).unwrap_or_default();
        if text.lines().count() >= want {
            break text;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "access log never reached {want} lines: {text:?}"
        );
        std::thread::sleep(std::time::Duration::from_millis(10));
    };

    let records: Vec<AccessRecord> = text
        .lines()
        .map(|l| AccessRecord::parse(l).unwrap())
        .collect();
    assert_eq!(records.len(), want);
    let oks: Vec<&AccessRecord> = records
        .iter()
        .filter(|r| r.outcome == "ok")
        .collect();
    assert_eq!(oks.len(), CLIENTS);
    for r in &oks {
        assert_eq!(r.model, "logreg");
        assert_eq!(r.sig, "grad");
        assert_eq!(r.n, PER);
        assert_eq!(r.batch_n, total);
        assert_eq!(r.batch_requests, CLIENTS);
        assert!(r.coalesced);
        assert_eq!(
            r.artifact.as_deref(),
            Some("logreg_grad_n16")
        );
        // Every stage of a served request is timed.
        assert!(r.queue_us.is_some());
        assert!(r.linger_us.is_some());
        assert!(r.extract_us.is_some());
        assert!(r.reply_us.is_some());
        let e2e = r.e2e_us.unwrap();
        assert!(
            e2e >= r.extract_us.unwrap(),
            "e2e {e2e} < extract alone"
        );
    }
    let rej = records
        .iter()
        .find(|r| r.outcome == "rejected")
        .expect("no rejected record");
    assert_eq!(rej.id, 99);
    assert_eq!(rej.artifact, None);
    assert_eq!(rej.batch_n, 0);
    assert!(!rej.coalesced);
    assert!(rej.extract_us.is_none());

    handle.shutdown();
    join.join().unwrap();
    let _ = std::fs::remove_file(&log);
}

#[test]
fn max_conns_rejects_overflow_with_a_server_busy_frame() {
    let (addr, handle, join) = start(ServeConfig {
        threads: 1,
        linger_ms: 1,
        max_conns: 1,
        ..ServeConfig::default()
    });
    // First connection occupies the single slot (the ping
    // round-trip guarantees its session is registered).
    let mut a = TcpStream::connect(addr).unwrap();
    let r = roundtrip(&mut a, "{\"op\":\"ping\",\"id\":1}");
    assert!(r.ok);

    // Second connection: one server_busy error frame, then EOF.
    let mut b = TcpStream::connect(addr).unwrap();
    let frame = read_frame(&mut b).unwrap().unwrap();
    let r = ExtractReply::parse(&frame).unwrap();
    assert!(!r.ok);
    let msg = r.error.unwrap();
    assert!(msg.contains("server_busy"), "{msg}");
    assert!(read_frame(&mut b).unwrap().is_none(), "expected EOF");
    drop(b);

    // Freeing the slot readmits new connections (the gauge drops
    // asynchronously when the session thread exits, so retry).
    drop(a);
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs(10);
    let mut c = loop {
        // While the slot is still taken this connection is rejected
        // (busy frame, or a reset once the ping hits the closed
        // socket) -- tolerate both and retry.
        let mut c = TcpStream::connect(addr).unwrap();
        let pong = write_frame(&mut c, "{\"op\":\"ping\",\"id\":2}")
            .and_then(|()| read_frame(&mut c));
        if let Ok(Some(f)) = pong {
            if ExtractReply::parse(&f).is_ok_and(|r| r.ok) {
                break c;
            }
        }
        assert!(
            std::time::Instant::now() < deadline,
            "slot never freed"
        );
        std::thread::sleep(std::time::Duration::from_millis(10));
    };
    write_frame(&mut c, "{\"op\":\"metrics\",\"id\":3}").unwrap();
    let v =
        Json::parse(&read_frame(&mut c).unwrap().unwrap()).unwrap();
    let s = v.get("serve").unwrap();
    assert!(
        s.get("conns_rejected").unwrap().as_usize().unwrap() >= 1
    );
    handle.shutdown();
    join.join().unwrap();
}

#[test]
fn param_cache_evictions_are_counted() {
    // A cache of one entry with alternating seeds evicts on every
    // seed change: 0 -> 1 -> 0 is two evictions.
    let (addr, handle, join) = start(ServeConfig {
        threads: 1,
        linger_ms: 1,
        param_cache: 1,
        ..ServeConfig::default()
    });
    let mut c = TcpStream::connect(addr).unwrap();
    for seed in [0u64, 1, 0] {
        let r =
            roundtrip(&mut c, &request(0, "grad", seed).to_json());
        assert!(r.ok, "seed {seed}: {:?}", r.error);
    }
    write_frame(&mut c, "{\"op\":\"metrics\",\"id\":1}").unwrap();
    let v =
        Json::parse(&read_frame(&mut c).unwrap().unwrap()).unwrap();
    let s = v.get("serve").unwrap();
    assert_eq!(
        s.get("param_cache_evictions")
            .unwrap()
            .as_usize()
            .unwrap(),
        2
    );
    handle.shutdown();
    join.join().unwrap();
}
