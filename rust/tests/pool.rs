//! Stress tests for the persistent worker pool behind
//! [`backpack_rs::parallel::par_map`] (DESIGN.md §14).
//!
//! The pool is a process-global: these tests deliberately hammer it
//! from many OS threads at once, panic inside shard closures, and
//! interleave nested calls, because any poisoning or lost wakeup
//! shows up here as a hang or a wrong sum. No test assumes it is the
//! pool's only client -- the unit tests in `src/parallel.rs` and the
//! engine suites share the same workers when the harness runs files
//! in parallel.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use backpack_rs::parallel::{par_map, pool_workers, shards, warm};

/// Reference sum for `0..n` shard ranges.
fn range_sum(n: usize) -> usize {
    n * n.saturating_sub(1) / 2
}

#[test]
fn many_concurrent_par_map_calls_all_complete() {
    // 8 caller threads x 40 calls each, every call sharded 4 ways.
    // Callers participate in their own jobs, so this also exercises
    // the steal path where a worker drains one caller's shards while
    // that caller drains another's.
    let callers = 8;
    let rounds = 40;
    let handles: Vec<_> = (0..callers)
        .map(|c| {
            std::thread::spawn(move || {
                for r in 0..rounds {
                    let n = 64 + (c * rounds + r) % 32;
                    let work = shards(n, 4);
                    let partial =
                        par_map(&work, |rg: Range<usize>| {
                            rg.sum::<usize>()
                        });
                    let total: usize = partial.iter().sum();
                    assert_eq!(total, range_sum(n), "caller {c} round {r}");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn results_come_back_in_shard_order() {
    let work = shards(100, 5);
    assert_eq!(work.len(), 5);
    let starts = par_map(&work, |rg: Range<usize>| rg.start);
    let expected: Vec<usize> =
        work.iter().map(|rg| rg.start).collect();
    assert_eq!(starts, expected);
}

#[test]
fn panic_in_a_shard_propagates_with_its_payload() {
    let work = shards(40, 4);
    let caught = std::panic::catch_unwind(|| {
        par_map(&work, |rg: Range<usize>| {
            if rg.contains(&25) {
                panic!("boom-25");
            }
            rg.len()
        })
    })
    .expect_err("shard panic must re-raise on the caller");
    let msg = caught
        .downcast_ref::<&str>()
        .copied()
        .map(str::to_string)
        .or_else(|| caught.downcast_ref::<String>().cloned())
        .unwrap_or_default();
    assert!(msg.contains("boom-25"), "original payload lost: {msg:?}");
}

#[test]
fn pool_survives_shard_panics() {
    // A panicking job must not poison the pool: the workers run user
    // code outside every pool lock, so later calls keep completing.
    for round in 0..10 {
        let work = shards(32, 4);
        let r = std::panic::catch_unwind(|| {
            par_map(&work, |rg: Range<usize>| {
                if rg.start == 0 {
                    panic!("round {round}");
                }
                rg.sum::<usize>()
            })
        });
        assert!(r.is_err());
        // Immediately after the panic, a clean call works.
        let ok = par_map(&work, |rg: Range<usize>| rg.sum::<usize>());
        assert_eq!(ok.iter().sum::<usize>(), range_sum(32));
    }
}

#[test]
fn all_panics_surface_even_with_multiple_failing_shards() {
    // Every shard runs to completion (the job is only released when
    // pending hits zero), and the first failing shard's payload is
    // the one re-raised.
    let work = shards(40, 4);
    let ran = Arc::new(AtomicUsize::new(0));
    let ran2 = Arc::clone(&ran);
    let r = std::panic::catch_unwind(move || {
        par_map(&work, move |rg: Range<usize>| -> usize {
            ran2.fetch_add(1, Ordering::SeqCst);
            panic!("shard {} failed", rg.start);
        })
    });
    assert!(r.is_err());
    assert_eq!(
        ran.load(Ordering::SeqCst),
        4,
        "remaining shards must still run after one panics"
    );
}

#[test]
fn serial_guard_runs_single_shard_work_inline() {
    // One shard (or none) never touches the pool: the closure runs on
    // the calling thread, so thread-local state is visible.
    let caller = std::thread::current().id();
    let ids = par_map(&shards(5, 1), |_rg: Range<usize>| {
        std::thread::current().id()
    });
    assert_eq!(ids, vec![caller]);
    let empty: Vec<std::thread::ThreadId> =
        par_map(&[], |_rg: Range<usize>| std::thread::current().id());
    assert!(empty.is_empty());
}

#[test]
fn nested_par_map_does_not_deadlock() {
    // An inner par_map issued from inside a shard closure must make
    // progress even when every worker is busy: the inner caller
    // participates in its own job, so the pool never self-starves.
    let outer = shards(4 * 50, 4);
    let totals = par_map(&outer, |rg: Range<usize>| {
        let inner = shards(rg.len(), 3);
        let offset = rg.start;
        par_map(&inner, |ir: Range<usize>| {
            ir.map(|i| i + offset).sum::<usize>()
        })
        .iter()
        .sum::<usize>()
    });
    assert_eq!(totals.iter().sum::<usize>(), range_sum(200));
}

#[test]
fn explicit_thread_counts_one_two_five_agree() {
    // The acceptance sweep: identical reductions at threads {1,2,5}.
    // Shard layout determines the split; the pool only supplies
    // hands.
    let n = 173; // prime-ish: every count leaves a remainder shard
    let expect = range_sum(n);
    for threads in [1usize, 2, 5] {
        let work = shards(n, threads);
        assert!(work.len() <= threads);
        let total: usize =
            par_map(&work, |rg: Range<usize>| rg.sum::<usize>())
                .iter()
                .sum();
        assert_eq!(total, expect, "threads={threads}");
    }
}

#[test]
fn warm_grows_the_pool_and_is_idempotent() {
    warm(3);
    let after = pool_workers();
    // warm(t) guarantees t-1 workers exist (the caller is the t-th
    // hand). Other tests share the pool, so >= not ==.
    assert!(after >= 2, "warm(3) left only {after} workers");
    warm(3);
    warm(1); // never shrinks, never blocks
    assert!(pool_workers() >= after);
}
