//! A user-defined extension registered through the public registry
//! must reproduce the built-in `batch_l2` to 1e-12 — serial, under
//! `--threads N` sharding (the `Reduce::Concat` rule), and through
//! the full backend artifact path.
//!
//! The custom module re-implements the Table 1 per-sample L2 rule
//! externally, exactly as a library user would: the rank-1 shortcut
//! for `Linear`, the shared per-sample gradient cache for `Conv2d`.
//! No engine code knows its name.

use backpack_rs::coordinator::train::{build_inputs, init_params};
use backpack_rs::data::Rng;
use backpack_rs::runtime::{Tensor, TensorSpec};
use backpack_rs::{
    Backend, Exec, Extension, ExtensionSet, Layer, LayerCtx, LayerOp,
    Model, NativeBackend, Quantities, Reduce, Walk,
};

/// External re-implementation of `batch_l2`: `‖(1/N) ∇ℓ_n‖²` per
/// sample and parameter block, under the name `custom_l2`.
struct CustomL2;

impl Extension for CustomL2 {
    fn name(&self) -> &str {
        "custom_l2"
    }

    fn walk(&self) -> Walk {
        Walk::Grad
    }

    fn first_order(
        &self,
        ctx: &LayerCtx,
        g: &[f32],
        out: &mut Quantities,
    ) {
        let (li, n, nf) = (ctx.li, ctx.n, ctx.norm);
        let (mut l2w, mut l2b) = (vec![0.0f32; n], vec![0.0f32; n]);
        match ctx.op {
            LayerOp::Linear { din, dout, .. } => {
                // ‖g_n x_nᵀ‖² = ‖g_n‖²·‖x_n‖² (rank-1 structure).
                for s in 0..n {
                    let g2: f32 = g[s * dout..(s + 1) * dout]
                        .iter()
                        .map(|v| v * v)
                        .sum();
                    let x2: f32 = ctx.input[s * din..(s + 1) * din]
                        .iter()
                        .map(|v| v * v)
                        .sum();
                    l2w[s] = g2 * x2 / (nf * nf);
                    l2b[s] = g2 / (nf * nf);
                }
            }
            LayerOp::Conv { .. } => {
                // No rank-1 shortcut for conv: consume the shared
                // per-sample G_n ⟦x⟧_nᵀ products.
                let ps = ctx.per_sample_grads(g);
                let (dout, j) = (ctx.op.dout(), ctx.op.a_dim());
                for s in 0..n {
                    let g2: f32 = ps.w
                        [s * dout * j..(s + 1) * dout * j]
                        .iter()
                        .map(|v| v * v)
                        .sum();
                    let b2: f32 = ps.b[s * dout..(s + 1) * dout]
                        .iter()
                        .map(|v| v * v)
                        .sum();
                    l2w[s] = g2 / (nf * nf);
                    l2b[s] = b2 / (nf * nf);
                }
            }
        }
        out.insert(
            format!("custom_l2/{li}/w"),
            Tensor::from_f32(&[n], l2w),
        );
        out.insert(
            format!("custom_l2/{li}/b"),
            Tensor::from_f32(&[n], l2b),
        );
    }

    /// Per-sample outputs concatenate across shards — the PR-2
    /// parallel semantics, declared by the module itself.
    fn reduce(&self, key: &str) -> Option<Reduce> {
        key.starts_with("custom_l2/").then_some(Reduce::Concat)
    }

    fn output_specs(&self, model: &Model, batch: usize) -> Vec<TensorSpec> {
        let mut specs = Vec::new();
        for blk in model.param_blocks() {
            for part in ["w", "b"] {
                specs.push(TensorSpec {
                    name: format!("custom_l2/{}/{part}", blk.li),
                    shape: vec![batch],
                    dtype: "f32".to_string(),
                    init: None,
                });
            }
        }
        specs
    }
}

fn fc_model() -> Model {
    Model::new(
        "tinyfc",
        12,
        vec![
            Layer::Linear { in_dim: 12, out_dim: 8 },
            Layer::Relu,
            Layer::Linear { in_dim: 8, out_dim: 5 },
            Layer::Sigmoid,
            Layer::Linear { in_dim: 5, out_dim: 3 },
        ],
    )
    .unwrap()
}

fn conv_model() -> Model {
    use backpack_rs::backend::conv::Shape;
    Model::with_input(
        "tinyconv",
        Shape::new(2, 6, 6),
        vec![
            Layer::Conv2d {
                in_ch: 2, out_ch: 3, kernel: 3, stride: 1, pad: 1,
            },
            Layer::Relu,
            Layer::MaxPool2d { kernel: 2, stride: 2, ceil: false },
            Layer::Flatten,
            Layer::Linear { in_dim: 27, out_dim: 4 },
        ],
    )
    .unwrap()
}

fn problem(m: &Model, n: usize, seed: u64) -> (Vec<Tensor>, Tensor, Tensor) {
    let mut rng = Rng::new(seed);
    let params: Vec<Tensor> = m
        .param_specs()
        .iter()
        .map(|t| {
            let k: usize = t.shape.iter().product();
            Tensor::from_f32(
                &t.shape,
                (0..k).map(|_| rng.normal() * 0.3).collect(),
            )
        })
        .collect();
    let x: Vec<f32> = (0..n * m.in_dim).map(|_| rng.normal()).collect();
    let y: Vec<i32> =
        (0..n).map(|_| rng.below(m.classes) as i32).collect();
    (
        params,
        Tensor::from_f32(&[n, m.in_dim], x),
        Tensor::from_i32(&[n], y),
    )
}

/// Every `custom_l2` output must match its `batch_l2` twin to 1e-12.
fn assert_matches_builtin(out: &Quantities, m: &Model, label: &str) {
    for blk in m.param_blocks() {
        for part in ["w", "b"] {
            let a = out[&format!("batch_l2/{}/{part}", blk.li)]
                .f32s()
                .unwrap();
            let b = out[&format!("custom_l2/{}/{part}", blk.li)]
                .f32s()
                .unwrap();
            assert_eq!(a.len(), b.len(), "{label} layer {}", blk.li);
            assert!(a.iter().all(|v| v.is_finite()), "{label}");
            for (i, (u, v)) in a.iter().zip(b).enumerate() {
                assert!(
                    (u - v).abs() <= 1e-12,
                    "{label} layer {} {part}[{i}]: {u} vs {v}",
                    blk.li
                );
            }
        }
    }
}

#[test]
fn custom_extension_matches_builtin_on_fc_and_conv() {
    let mut set = ExtensionSet::builtin();
    set.register(CustomL2);
    let exts =
        vec!["batch_l2".to_string(), "custom_l2".to_string()];
    for (m, seed) in [(fc_model(), 7), (conv_model(), 8)] {
        let (params, x, y) = problem(&m, 13, seed);
        let out = m
            .extended_backward_with(
                &set, &params, &x, &y, &exts, None, 1,
            )
            .unwrap();
        assert_matches_builtin(&out, &m, &m.name);
        // At least one l2 value is non-trivial.
        assert!(out[&format!(
            "custom_l2/{}/w",
            m.param_blocks()[0].li
        )]
        .f32s()
        .unwrap()
        .iter()
        .any(|v| *v > 0.0));
    }
}

#[test]
fn custom_extension_shards_like_the_builtin() {
    let mut set = ExtensionSet::builtin();
    set.register(CustomL2);
    let exts =
        vec!["batch_l2".to_string(), "custom_l2".to_string()];
    for (m, seed) in [(fc_model(), 17), (conv_model(), 18)] {
        // 13 samples: uneven shards at every thread count.
        let (params, x, y) = problem(&m, 13, seed);
        let serial = m
            .extended_backward_with(
                &set, &params, &x, &y, &exts, None, 1,
            )
            .unwrap();
        for threads in [2usize, 3, 5, 13] {
            let par = m
                .extended_backward_with(
                    &set, &params, &x, &y, &exts, None, threads,
                )
                .unwrap();
            assert_matches_builtin(
                &par,
                &m,
                &format!("{} threads={threads}", m.name),
            );
            // The concat reduction preserves sample order: sharded
            // custom output == serial custom output, bitwise.
            for blk in m.param_blocks() {
                for part in ["w", "b"] {
                    let k = format!("custom_l2/{}/{part}", blk.li);
                    assert_eq!(
                        serial[&k].f32s().unwrap(),
                        par[&k].f32s().unwrap(),
                        "{k} threads={threads}"
                    );
                }
            }
        }
    }
}

#[test]
fn custom_extension_serves_through_the_backend_path() {
    let mut be = NativeBackend::with_threads(4);
    be.register(fc_model());
    be.register_extension(CustomL2);

    // The custom name is a first-class signature part.
    let name = be
        .find_train("tinyfc", 0, "batch_l2+custom_l2", 12)
        .unwrap();
    assert_eq!(name, "tinyfc_batch_l2+custom_l2_n12");
    let spec = be.spec(&name).unwrap();
    // The module's own output_specs landed in the synthesized spec.
    let custom: Vec<_> = spec
        .outputs
        .iter()
        .filter(|t| t.name.starts_with("custom_l2/"))
        .collect();
    assert_eq!(custom.len(), 6); // 3 blocks x {w, b}
    assert!(custom.iter().all(|t| t.shape == vec![12]));

    let exe = be.load(&name).unwrap();
    let params = init_params(exe.spec(), 3);
    let m = fc_model();
    let (_, x, y) = problem(&m, 12, 3);
    let out = exe.run(&build_inputs(&params, x, y, None)).unwrap();
    for blk in m.param_blocks() {
        for part in ["w", "b"] {
            let a = out
                .get(&format!("batch_l2/{}/{part}", blk.li))
                .unwrap()
                .f32s()
                .unwrap();
            let b = out
                .get(&format!("custom_l2/{}/{part}", blk.li))
                .unwrap()
                .f32s()
                .unwrap();
            for (u, v) in a.iter().zip(b) {
                assert!((u - v).abs() <= 1e-12, "{u} vs {v}");
            }
        }
    }

    // Unregistered names still fail to resolve.
    assert!(be.spec("tinyfc_not_a_thing_n8").is_err());
}
