//! Bench: paper Fig. 8 -- KFLR/DiagGGN (exact C=100 propagation) vs
//! KFAC/DiagGGN-MC (rank-1 MC) on All-CNN-C; expect ~two orders of
//! magnitude. Run: `cargo bench --bench fig8_large_output`
use backpack_rs::figures::timing;
use backpack_rs::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open_default()?;
    let iters = std::env::var("BENCH_ITERS")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(3);
    timing::fig8(&rt, iters, std::path::Path::new("results"))
}
