//! Bench: paper Fig. 9 -- exact Hessian diagonal vs GGN diagonal when
//! the network contains a single sigmoid (residual-factor propagation,
//! Appendix A.3). Run: `cargo bench --bench fig9_hessian_diag`
use backpack_rs::figures::timing;
use backpack_rs::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open_default()?;
    let iters = std::env::var("BENCH_ITERS")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(3);
    timing::fig9(&rt, iters, std::path::Path::new("results"))
}
