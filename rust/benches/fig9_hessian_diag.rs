//! Bench: paper Fig. 9 -- exact Hessian diagonal vs GGN diagonal when
//! the network contains a single sigmoid (signed residual-factor
//! propagation, Appendix A.3 / DESIGN.md §11). Runs on the default
//! native backend; `BACKPACK_THREADS=1` gives the serial reference.
//! Run: `cargo bench --bench fig9_hessian_diag`
use backpack_rs::figures::timing;

fn main() -> anyhow::Result<()> {
    let be = backpack_rs::open("native")?;
    let iters = std::env::var("BENCH_ITERS")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(3);
    timing::fig9(be.as_ref(), iters, std::path::Path::new("results"))
}
