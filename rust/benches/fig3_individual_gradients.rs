//! Bench: paper Fig. 3 -- individual-gradient computation, for-loop vs
//! vectorized (BackPACK) vs plain gradient, 3c3d on CIFAR-10 shapes.
//! Run: `cargo bench --bench fig3_individual_gradients`
use backpack_rs::figures::timing;
use backpack_rs::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open_default()?;
    let iters = std::env::var("BENCH_ITERS")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(5);
    timing::fig3(&rt, iters, std::path::Path::new("results"))
}
