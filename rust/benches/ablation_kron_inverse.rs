//! Ablation bench (DESIGN.md §7): cost of the Kronecker-factor
//! inversion (paper Eq. 28) in the Rust coordinator, and the effect of
//! amortizing it over `inv_every` steps.
//!
//! Measures (a) raw Cholesky + solve cost at the paper networks' factor
//! sizes, (b) end-to-end KFAC step time on mnist_logreg at
//! inv_every ∈ {1, 5, 20}, through the native backend (runs on the
//! default feature set, no AOT artifacts needed).
//!
//! Run: `cargo bench --bench ablation_kron_inverse`

use std::time::Duration;

use backpack_rs::backend;
use backpack_rs::bench::bench;
use backpack_rs::coordinator::{problems, train, TrainConfig};
use backpack_rs::data::Rng;
use backpack_rs::linalg::{Cholesky, SymMat};
use backpack_rs::optim::Hyper;

fn random_spd(n: usize, seed: u64) -> SymMat {
    let mut rng = Rng::new(seed);
    let mut a = vec![0.0f32; n * n];
    // diagonally dominant: SPD without forming G Gᵀ (cheap to build)
    for i in 0..n {
        for j in 0..i {
            let v = rng.normal() * 0.01;
            a[i * n + j] = v;
            a[j * n + i] = v;
        }
        a[i * n + i] = 1.0 + rng.uniform();
    }
    SymMat::new(n, a)
}

fn main() -> anyhow::Result<()> {
    println!("== ablation: Kronecker inversion cost (Eq. 28) ==");
    // Factor sizes of the paper's networks: logreg A=784, 3c3d fc1
    // A=1152, All-CNN-C largest A=1728.
    for n in [784usize, 1152, 1728] {
        let m = random_spd(n, n as u64);
        bench(
            &format!("cholesky factor {n}x{n}"),
            1,
            10,
            Duration::from_secs(20),
            || {
                let _ = Cholesky::factor(&m).unwrap();
            },
        );
        let ch = Cholesky::factor(&m)?;
        let mut rhs = vec![0.5f32; n * 64];
        bench(
            &format!("solve [{n}x{n}] x 64 rhs"),
            1,
            10,
            Duration::from_secs(10),
            || {
                ch.solve_mat_left(&mut rhs, 64);
            },
        );
    }

    println!("\n== ablation: KFAC step time vs inv_every (logreg) ==");
    let be = backend::open("native")?;
    let problem = problems::by_name("mnist_logreg")?;
    for inv_every in [1usize, 5, 20] {
        let cfg = TrainConfig {
            problem: problem.codename.into(),
            optimizer: "kfac".into(),
            hyper: Hyper { lr: 0.01, damping: 0.01, l2: 0.0 },
            steps: 40,
            seed: 0,
            eval_every: 1000,
            inv_every,
            log_every: 40,
            verbose: false,
        };
        let start = std::time::Instant::now();
        let log = train::train(be.as_ref(), problem, &cfg)?;
        println!(
            "inv_every={inv_every:2}  total {:6.2}s  \
             ({:.1}ms/step exec)  final loss {:.4}",
            start.elapsed().as_secs_f64(),
            log.step_time_s * 1e3,
            log.final_train_loss()
        );
    }
    Ok(())
}
