//! Bench: paper Fig. 6 -- per-extension overhead vs the gradient on
//! 3c3d/CIFAR-10 (N=64) and All-CNN-C/CIFAR-100 (N=16, 32x32).
//! Run: `cargo bench --bench fig6_overhead`
use backpack_rs::figures::timing;
use backpack_rs::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open_default()?;
    let iters = std::env::var("BENCH_ITERS")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(5);
    timing::fig6(&rt, iters, std::path::Path::new("results"))
}
