//! A bounded MPMC queue with blocking push: the server's
//! backpressure valve.
//!
//! Connection threads `push` incoming extraction requests; the
//! scheduler thread `pop`s a leader and then `take_where`-scavenges
//! compatible requests to coalesce. When the queue is full, `push`
//! blocks the connection thread -- which stops reading frames from
//! its socket -- so backpressure propagates to clients as TCP flow
//! control instead of unbounded server memory.
//!
//! Time spent in here belongs to the *queue* stage of the request
//! lifecycle: requests are stamped before `push` and on `pop` /
//! `take_where`, so a full queue's blocking wait shows up in the
//! `serve.latency` queue histogram rather than disappearing.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
    /// Monotone push counter; lets waiters distinguish "a new item
    /// arrived" from "the queue is non-empty but unchanged" (e.g.
    /// only incompatible requests are parked) without spinning.
    pushes: u64,
}

/// Bounded blocking queue. All methods take `&self`; share it via
/// `Arc`.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    cap: usize,
    not_full: Condvar,
    not_empty: Condvar,
}

impl<T> BoundedQueue<T> {
    /// A queue holding at most `cap` items (`cap` is clamped to at
    /// least 1 so `push` can always make progress).
    pub fn new(cap: usize) -> BoundedQueue<T> {
        BoundedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::new(),
                closed: false,
                pushes: 0,
            }),
            cap: cap.max(1),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    /// Blocking push: waits while the queue is full. Returns the
    /// item back if the queue was closed (before or while waiting).
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        while !g.closed && g.items.len() >= self.cap {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return Err(item);
        }
        g.items.push_back(item);
        g.pushes += 1;
        self.not_empty.notify_all();
        Ok(())
    }

    /// Blocking pop: waits while the queue is empty and open.
    /// `None` means closed *and* drained -- the consumer's exit
    /// signal.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                self.not_full.notify_all();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Remove and return every queued item matching `pred`,
    /// preserving arrival order. Non-matching items stay queued in
    /// order.
    pub fn take_where<F: FnMut(&T) -> bool>(
        &self,
        mut pred: F,
    ) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let mut taken = Vec::new();
        let mut kept = VecDeque::with_capacity(g.items.len());
        for item in g.items.drain(..) {
            if pred(&item) {
                taken.push(item);
            } else {
                kept.push_back(item);
            }
        }
        g.items = kept;
        if !taken.is_empty() {
            self.not_full.notify_all();
        }
        taken
    }

    /// Block until a *new* item is pushed, the queue closes, or
    /// `deadline` passes. Returns true iff a push happened -- the
    /// scheduler's linger wait (a queue that is merely non-empty
    /// with incompatible requests does not wake it, so the wait
    /// never spins).
    pub fn wait_push_until(&self, deadline: Instant) -> bool {
        let mut g = self.inner.lock().unwrap();
        let seen = g.pushes;
        loop {
            if g.pushes != seen {
                return true;
            }
            if g.closed {
                return false;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, timeout) = self
                .not_empty
                .wait_timeout(g, deadline - now)
                .unwrap();
            g = guard;
            if timeout.timed_out() && g.pushes == seen {
                return false;
            }
        }
    }

    /// Close the queue: subsequent `push`es fail, blocked waiters
    /// wake, `pop` drains what remains then returns `None`.
    /// Idempotent.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    /// Remove and return everything queued (used to error-reply
    /// leftovers on shutdown).
    pub fn drain(&self) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let out: Vec<T> = g.items.drain(..).collect();
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    /// Current depth (racy; for metrics only).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    /// Whether the queue is currently empty (racy; for metrics
    /// only).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_and_take_where() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        // take_where preserves order on both sides of the split.
        assert_eq!(q.take_where(|i| i % 2 == 0), vec![0, 2, 4]);
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(3));
        assert!(q.is_empty());
    }

    #[test]
    fn push_blocks_when_full_until_pop() {
        let q = Arc::new(BoundedQueue::new(2));
        q.push(1).unwrap();
        q.push(2).unwrap();
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || q2.push(3).is_ok());
        // The pusher must be parked: depth stays at capacity.
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert!(t.join().unwrap());
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn close_wakes_blocked_pushers_and_drains_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(7).unwrap();
        let q2 = Arc::clone(&q);
        let pusher = std::thread::spawn(move || q2.push(8));
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        // The parked pusher gets its item back.
        assert_eq!(pusher.join().unwrap(), Err(8));
        // Pop drains the remaining item, then reports closed.
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
        // Pushing after close fails immediately.
        assert_eq!(q.push(9), Err(9));
    }

    #[test]
    fn wait_push_until_sees_new_items_not_stale_ones() {
        let q = Arc::new(BoundedQueue::new(8));
        // A parked (incompatible) item must NOT satisfy the wait.
        q.push(1).unwrap();
        let deadline = Instant::now() + Duration::from_millis(40);
        assert!(!q.wait_push_until(deadline));
        assert!(Instant::now() >= deadline);
        // A fresh push does.
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.push(2).unwrap();
        });
        assert!(q.wait_push_until(
            Instant::now() + Duration::from_secs(5)
        ));
        t.join().unwrap();
        // Close wakes the wait with false.
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.close();
        });
        assert!(!q.wait_push_until(
            Instant::now() + Duration::from_secs(5)
        ));
        t.join().unwrap();
    }
}
