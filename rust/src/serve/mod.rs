//! `backpack serve`: extraction-as-a-service.
//!
//! A long-running daemon that accepts extraction requests over a
//! length-prefixed JSON protocol ([`protocol`], `backpack-serve/v1`)
//! on TCP or stdin/stdout, and answers them through the typed
//! artifact API ([`crate::ArtifactId`] / [`crate::Signature`]).
//! Compatible requests -- same model, signature, seed and
//! Monte-Carlo key -- arriving from many clients within a short
//! linger window are **coalesced** into one sharded
//! `extended_backward` call (the scheduler thread); per-sample results
//! (`Concat`-reduced keys) are sliced back per client while
//! `Sum`-reduced aggregates are broadcast to every participant. A
//! bounded request queue ([`queue::BoundedQueue`]) provides
//! backpressure: when it fills, connection threads stop reading
//! frames and clients feel TCP flow control, not server OOM.
//!
//! A `metrics` request returns live `backpack-metrics/v1` aggregates
//! (accumulated per-batch via [`MetricsAgg`]) plus serve counters
//! and a `latency` section: per-stage [`Histogram`]s over the
//! request lifecycle (accept -> queue-pop -> linger-close ->
//! extract-done -> reply-written) and the batch-size distribution.
//! With `--access-log FILE` every request additionally appends one
//! `backpack-access/v1` JSON line ([`protocol::AccessRecord`]) --
//! the machine-readable channel that `--quiet` never silences.
//!
//! See `docs/serve.md` for the byte-level frame layout, the batching
//! and backpressure semantics, and an example session transcript.
//!
//! ```no_run
//! use backpack_rs::serve::{ServeConfig, Server};
//!
//! # fn main() -> anyhow::Result<()> {
//! let server = Server::bind(ServeConfig::default())?;
//! println!("listening on {}", server.local_addr());
//! server.run()?; // blocks until a shutdown request
//! # Ok(()) }
//! ```

pub mod loadgen;
pub mod protocol;
pub mod queue;

mod conn;
mod scheduler;

use std::fs::File;
use std::io::{BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant, SystemTime};

use anyhow::{Context, Result};

use crate::json::Json;
use crate::obs;
use crate::obs::{Histogram, MetricsAgg};

use queue::BoundedQueue;
use scheduler::Pending;

pub use loadgen::{LoadgenConfig, LoadgenReport, SERVEBENCH_SCHEMA};
pub use protocol::{
    AccessRecord, BatchMeta, ExtractReply, ExtractRequest, Request,
    ACCESS_SCHEMA, MAX_FRAME, PROTOCOL_SCHEMA,
};

/// Daemon configuration; `Default` is a sensible local setup
/// (ephemeral port, all cores, small linger).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Listen address; port 0 binds an ephemeral port (read it back
    /// from [`Server::local_addr`]).
    pub addr: String,
    /// Engine threads per extraction call (0 = all cores).
    pub threads: usize,
    /// Bounded request-queue capacity: the backpressure valve.
    pub queue_cap: usize,
    /// How long the scheduler lingers for compatible requests
    /// before running a batch.
    pub linger_ms: u64,
    /// Soft cap on coalesced union-batch samples: gathering stops
    /// once a batch reaches this many.
    pub max_batch: usize,
    /// True when the embedding process owns a running obs recorder
    /// (CLI `--trace`): per-batch windows then use non-draining
    /// mark/since so the final trace survives. When false the
    /// scheduler runs its own start/stop window per batch.
    pub retain_trace: bool,
    /// Concurrent-connection cap (0 = unlimited). Connections over
    /// the cap get a `server_busy` error frame and are closed, so
    /// one flood cannot exhaust threads.
    pub max_conns: usize,
    /// LRU capacity of the scheduler's `(model, seed)` parameter
    /// cache; evictions count into `param_cache_evictions`.
    pub param_cache: usize,
    /// Append one `backpack-access/v1` JSON line per request to
    /// this file (the `--quiet`-proof structured channel).
    pub access_log: Option<PathBuf>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            threads: 0,
            queue_cap: 64,
            linger_ms: 2,
            max_batch: 1024,
            retain_trace: false,
            max_conns: 0,
            param_cache: 16,
            access_log: None,
        }
    }
}

/// Monotone serve counters (all relaxed; they feed metrics, not
/// control flow).
#[derive(Default)]
pub(crate) struct Stats {
    /// Frames parsed as requests (any op).
    pub requests: AtomicU64,
    /// Extract requests accepted into the queue.
    pub extracts: AtomicU64,
    /// Engine calls run.
    pub batches: AtomicU64,
    /// Largest number of requests coalesced into one call.
    pub coalesced_max: AtomicU64,
    /// Error replies sent (bad frames, rejected requests, failures).
    pub errors: AtomicU64,
    /// Replies dropped because the client had disconnected.
    pub disconnects: AtomicU64,
    /// Connections refused over the `--max-conns` cap.
    pub conns_rejected: AtomicU64,
    /// `(model, seed)` parameter sets evicted from the scheduler's
    /// LRU cache.
    pub param_cache_evictions: AtomicU64,
    /// Extract requests that rode in some engine call (>= batches;
    /// the surplus is the coalescing win).
    pub batched_requests: AtomicU64,
    /// Live connection gauge (incremented at accept, decremented at
    /// session end); not monotone, feeds the `--max-conns` gate.
    pub conns_active: AtomicU64,
}

/// Lifecycle timestamps for one request: stamped at accept, then at
/// each stage boundary as the request moves through the daemon.
/// `None` means the request never reached that stage.
#[derive(Clone, Copy)]
pub(crate) struct Stamps {
    /// Frame fully read and parsed on the connection thread.
    pub accepted: Instant,
    /// Popped (or scavenged) from the queue by the scheduler.
    pub popped: Option<Instant>,
    /// Linger window closed; the union batch is final.
    pub closed: Option<Instant>,
    /// Engine call returned (ok or error).
    pub done: Option<Instant>,
}

impl Stamps {
    pub fn new() -> Stamps {
        Stamps {
            accepted: Instant::now(),
            popped: None,
            closed: None,
            done: None,
        }
    }
}

/// Everything needed to finish one request's telemetry once its
/// reply leaves (or fails to leave) the process: identity, batch
/// shape, outcome, and the stage stamps.
pub(crate) struct Access {
    pub id: u64,
    pub model: String,
    pub sig: String,
    pub n: usize,
    pub batch_n: usize,
    pub batch_requests: usize,
    /// `ok` | `error` | `rejected` | `disconnect`.
    pub outcome: &'static str,
    pub stamps: Stamps,
}

/// One frame travelling to a connection's writer thread, plus the
/// access record to close out once the write completes. Control
/// replies (ping, metrics, ...) carry no access record.
pub(crate) struct Reply {
    pub frame: String,
    pub access: Option<Access>,
}

/// Per-stage latency histograms (all in microseconds) plus batch
/// shape distributions; one merged view over the daemon's lifetime.
#[derive(Default)]
struct Latency {
    /// accept -> queue-pop (includes backpressure wait).
    queue: Histogram,
    /// queue-pop -> linger-close.
    linger: Histogram,
    /// linger-close -> extract-done.
    extract: Histogram,
    /// extract-done -> reply-written.
    reply: Histogram,
    /// accept -> last observed stage.
    e2e: Histogram,
    /// Union batch samples per engine call.
    batch_size: Histogram,
    /// Requests coalesced per engine call.
    batch_requests: Histogram,
}

struct Totals {
    agg: MetricsAgg,
    wall_s: f64,
}

/// State shared between the accept loop, connection threads, and
/// the scheduler.
pub(crate) struct Shared {
    pub cfg: ServeConfig,
    pub queue: BoundedQueue<Pending>,
    pub stats: Stats,
    shutdown: AtomicBool,
    boot: Instant,
    /// Bound TCP address, if any: shutdown pokes it to unblock the
    /// accept loop.
    addr: Mutex<Option<SocketAddr>>,
    totals: Mutex<Totals>,
    latency: Mutex<Latency>,
    /// Open access-log sink, when configured. Line-buffered by
    /// hand: each record is written and flushed whole.
    access_log: Option<Mutex<BufWriter<File>>>,
}

impl Shared {
    fn new(cfg: ServeConfig) -> Result<Arc<Shared>> {
        let access_log = match &cfg.access_log {
            Some(path) => {
                let f = File::create(path).with_context(|| {
                    format!(
                        "cannot open access log {}",
                        path.display()
                    )
                })?;
                Some(Mutex::new(BufWriter::new(f)))
            }
            None => None,
        };
        let queue = BoundedQueue::new(cfg.queue_cap);
        Ok(Arc::new(Shared {
            cfg,
            queue,
            stats: Stats::default(),
            shutdown: AtomicBool::new(false),
            boot: Instant::now(),
            addr: Mutex::new(None),
            totals: Mutex::new(Totals {
                agg: MetricsAgg::default(),
                wall_s: 0.0,
            }),
            latency: Mutex::new(Latency::default()),
            access_log,
        }))
    }

    /// Close out one request's telemetry: fold its stage durations
    /// into the latency histograms and append its access-log line.
    /// `written` is the reply-write completion instant (None when
    /// the reply never reached the wire).
    pub(crate) fn finish_request(
        &self,
        a: Access,
        written: Option<Instant>,
    ) {
        let s = &a.stamps;
        let us = |from: Instant, to: Instant| {
            to.saturating_duration_since(from).as_micros() as u64
        };
        let queue_us =
            s.popped.map(|p| us(s.accepted, p));
        let linger_us =
            s.popped.zip(s.closed).map(|(p, c)| us(p, c));
        let extract_us =
            s.closed.zip(s.done).map(|(c, d)| us(c, d));
        let reply_us =
            s.done.zip(written).map(|(d, w)| us(d, w));
        let last = written
            .or(s.done)
            .or(s.closed)
            .or(s.popped);
        let e2e_us = last.map(|t| us(s.accepted, t));
        {
            let mut l = self.latency.lock().unwrap();
            let put = |h: &mut Histogram, v: Option<u64>| {
                if let Some(v) = v {
                    h.record(v);
                }
            };
            put(&mut l.queue, queue_us);
            put(&mut l.linger, linger_us);
            put(&mut l.extract, extract_us);
            put(&mut l.reply, reply_us);
            put(&mut l.e2e, e2e_us);
        }
        let Some(log) = &self.access_log else { return };
        let artifact = (a.batch_n > 0).then(|| {
            format!("{}_{}_n{}", a.model, a.sig, a.batch_n)
        });
        let ts_ms = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        let rec = AccessRecord {
            id: a.id,
            artifact,
            model: a.model,
            sig: a.sig,
            n: a.n,
            batch_n: a.batch_n,
            batch_requests: a.batch_requests,
            coalesced: a.batch_requests > 1,
            outcome: a.outcome.to_string(),
            queue_us,
            linger_us,
            extract_us,
            reply_us,
            e2e_us,
            ts_ms,
        };
        let mut w = log.lock().unwrap();
        let _ = writeln!(w, "{}", rec.to_json());
        let _ = w.flush();
    }

    /// Record one engine call's batch shape (called by the
    /// scheduler once per `run_batch`).
    pub(crate) fn record_batch(
        &self,
        batch_n: usize,
        requests: usize,
    ) {
        let r = Ordering::Relaxed;
        self.stats.batches.fetch_add(1, r);
        self.stats
            .coalesced_max
            .fetch_max(requests as u64, r);
        self.stats
            .batched_requests
            .fetch_add(requests as u64, r);
        let mut l = self.latency.lock().unwrap();
        l.batch_size.record(batch_n as u64);
        l.batch_requests.record(requests as u64);
    }

    /// Fold one batch's metrics window into the live aggregates.
    pub(crate) fn absorb_window(&self, agg: &MetricsAgg, wall_s: f64) {
        let mut t = self.totals.lock().unwrap();
        t.agg.absorb(agg);
        t.wall_s += wall_s;
    }

    /// The `metrics` reply: a schema-pure `backpack-metrics/v1`
    /// object over everything served so far, plus serve counters.
    pub(crate) fn metrics_reply(&self, id: u64) -> String {
        let metrics = {
            let t = self.totals.lock().unwrap();
            t.agg.to_json(t.wall_s)
        };
        protocol::metrics_reply(id, metrics, self.serve_json())
    }

    fn serve_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        let num = |v: u64| Json::Num(v as f64);
        o.insert(
            "schema".into(),
            Json::Str(PROTOCOL_SCHEMA.to_string()),
        );
        o.insert(
            "uptime_s".into(),
            Json::Num(self.boot.elapsed().as_secs_f64()),
        );
        o.insert(
            "queue_depth".into(),
            num(self.queue.len() as u64),
        );
        o.insert(
            "queue_cap".into(),
            num(self.cfg.queue_cap as u64),
        );
        let s = &self.stats;
        let r = Ordering::Relaxed;
        o.insert("requests".into(), num(s.requests.load(r)));
        o.insert("extracts".into(), num(s.extracts.load(r)));
        o.insert("batches".into(), num(s.batches.load(r)));
        o.insert(
            "coalesced_max".into(),
            num(s.coalesced_max.load(r)),
        );
        o.insert("errors".into(), num(s.errors.load(r)));
        o.insert("disconnects".into(), num(s.disconnects.load(r)));
        o.insert(
            "conns_active".into(),
            num(s.conns_active.load(r)),
        );
        o.insert(
            "conns_rejected".into(),
            num(s.conns_rejected.load(r)),
        );
        o.insert(
            "param_cache_evictions".into(),
            num(s.param_cache_evictions.load(r)),
        );
        o.insert(
            "batched_requests".into(),
            num(s.batched_requests.load(r)),
        );
        o.insert("latency".into(), self.latency_json());
        Json::Obj(o)
    }

    /// The `serve.latency` section: per-stage and e2e histograms,
    /// batch shape distributions, and the coalescing rate (the
    /// fraction of batched requests that shared an engine call).
    fn latency_json(&self) -> Json {
        let l = self.latency.lock().unwrap();
        let mut o = std::collections::BTreeMap::new();
        o.insert("unit".into(), Json::Str("us".to_string()));
        let mut stages = std::collections::BTreeMap::new();
        stages.insert("queue".into(), l.queue.to_json());
        stages.insert("linger".into(), l.linger.to_json());
        stages.insert("extract".into(), l.extract.to_json());
        stages.insert("reply".into(), l.reply.to_json());
        o.insert("stages".into(), Json::Obj(stages));
        o.insert("e2e".into(), l.e2e.to_json());
        o.insert("batch_size".into(), l.batch_size.to_json());
        o.insert(
            "batch_requests".into(),
            l.batch_requests.to_json(),
        );
        let batches = l.batch_requests.count();
        let requests = l.batch_requests.sum();
        let mut c = std::collections::BTreeMap::new();
        c.insert("batches".into(), Json::Num(batches as f64));
        c.insert("requests".into(), Json::Num(requests as f64));
        c.insert(
            "rate".into(),
            if requests > 0 {
                Json::Num(1.0 - batches as f64 / requests as f64)
            } else {
                Json::Null
            },
        );
        o.insert("coalescing".into(), Json::Obj(c));
        Json::Obj(o)
    }

    /// Initiate graceful shutdown: refuse new work, let the
    /// scheduler drain what is queued, unblock the accept loop.
    /// Idempotent.
    pub(crate) fn begin_shutdown(&self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        self.queue.close();
        if let Some(addr) = *self.addr.lock().unwrap() {
            // Self-connect so the blocking accept() observes the
            // flag; the connection is dropped unused.
            let _ = TcpStream::connect_timeout(
                &addr,
                Duration::from_millis(200),
            );
        }
    }
}

/// Handle for stopping a running [`Server`] from another thread
/// (tests, signal bridges).
#[derive(Clone)]
pub struct ServerHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
}

impl ServerHandle {
    /// The bound address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Trigger the same graceful shutdown as a `shutdown` request.
    pub fn shutdown(&self) {
        self.shared.begin_shutdown();
    }
}

/// A bound-but-not-yet-running TCP server.
pub struct Server {
    listener: TcpListener,
    addr: SocketAddr,
    shared: Arc<Shared>,
}

impl Server {
    /// Bind `cfg.addr`. Also warms the worker pool to the configured
    /// extraction width: the scheduler's coalesced `extended_backward`
    /// calls inherit the persistent pool (`crate::parallel`), so the
    /// first request shouldn't pay thread-spawn latency.
    pub fn bind(cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("cannot bind {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        crate::parallel::warm(crate::parallel::resolve_threads(
            cfg.threads,
        ));
        let shared = Shared::new(cfg)?;
        *shared.addr.lock().unwrap() = Some(addr);
        Ok(Server { listener, addr, shared })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// A shutdown handle, cloneable across threads.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle {
            addr: self.addr,
            shared: Arc::clone(&self.shared),
        }
    }

    /// Run the accept loop until shutdown. Spawns the scheduler
    /// thread and one thread per connection; returns after the
    /// scheduler has drained every queued request.
    pub fn run(self) -> Result<()> {
        let sched_shared = Arc::clone(&self.shared);
        let scheduler = std::thread::Builder::new()
            .name("backpack-sched".to_string())
            .spawn(move || scheduler::run(sched_shared))?;
        for stream in self.listener.incoming() {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let mut stream = match stream {
                Ok(s) => s,
                Err(e) => {
                    obs::progress(format_args!(
                        "serve: accept failed: {e}"
                    ));
                    continue;
                }
            };
            let _ = stream.set_nodelay(true);
            let shared = Arc::clone(&self.shared);
            // `--max-conns` gate: over the cap the client gets one
            // wire-level `server_busy` error frame and the socket
            // closes, instead of a thread it could park forever.
            let r = Ordering::Relaxed;
            let max = shared.cfg.max_conns;
            if max > 0
                && shared.stats.conns_active.load(r) >= max as u64
            {
                shared.stats.conns_rejected.fetch_add(1, r);
                obs::progress(format_args!(
                    "serve: rejecting connection over \
                     --max-conns {max}"
                ));
                let _ = protocol::write_frame(
                    &mut stream,
                    &protocol::busy_reply(max),
                );
                continue;
            }
            shared.stats.conns_active.fetch_add(1, r);
            let spawned = std::thread::Builder::new()
                .name("backpack-conn".to_string())
                .spawn(move || {
                    if let Ok(rd) = stream.try_clone() {
                        conn::serve_session(
                            Arc::clone(&shared),
                            rd,
                            stream,
                        );
                    }
                    shared
                        .stats
                        .conns_active
                        .fetch_sub(1, Ordering::Relaxed);
                });
            if spawned.is_err() {
                // The gauge was optimistically incremented; undo it
                // so a failed spawn cannot wedge the gate shut.
                self.shared.stats.conns_active.fetch_sub(1, r);
            }
        }
        self.shared.queue.close();
        let _ = scheduler.join();
        Ok(())
    }
}

/// Serve a single session over stdin/stdout (the `--stdio` CLI
/// mode): same protocol, same scheduler, no socket. Warms the worker
/// pool like [`Server::bind`].
pub fn run_stdio(cfg: ServeConfig) -> Result<()> {
    crate::parallel::warm(crate::parallel::resolve_threads(
        cfg.threads,
    ));
    let shared = Shared::new(cfg)?;
    let sched_shared = Arc::clone(&shared);
    let scheduler = std::thread::Builder::new()
        .name("backpack-sched".to_string())
        .spawn(move || scheduler::run(sched_shared))?;
    conn::serve_session(
        Arc::clone(&shared),
        std::io::stdin().lock(),
        std::io::stdout(),
    );
    shared.queue.close();
    let _ = scheduler.join();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::protocol::{
        read_frame, write_frame, ExtractReply,
    };
    use super::*;

    /// Fast control-plane smoke: ping, metrics shape, graceful
    /// shutdown. The extraction/coalescing suite lives in
    /// `tests/serve.rs`.
    #[test]
    fn ping_metrics_and_shutdown_over_tcp() {
        let server = Server::bind(ServeConfig {
            linger_ms: 1,
            ..ServeConfig::default()
        })
        .unwrap();
        let addr = server.local_addr();
        let running =
            std::thread::spawn(move || server.run().unwrap());

        let mut c = TcpStream::connect(addr).unwrap();
        write_frame(&mut c, "{\"op\":\"ping\",\"id\":1}").unwrap();
        let r = ExtractReply::parse(
            &read_frame(&mut c).unwrap().unwrap(),
        )
        .unwrap();
        assert!(r.ok);
        assert_eq!(r.id, 1);

        write_frame(&mut c, "{\"op\":\"metrics\",\"id\":2}")
            .unwrap();
        let raw = read_frame(&mut c).unwrap().unwrap();
        let v = Json::parse(&raw).unwrap();
        assert!(v.get("ok").unwrap().as_bool().unwrap());
        // The metrics object is schema-pure backpack-metrics/v1
        // even before any batch has run.
        let m = v.get("metrics").unwrap();
        assert_eq!(
            m.get("schema").unwrap().as_str().unwrap(),
            crate::obs::METRICS_SCHEMA
        );
        let s = v.get("serve").unwrap();
        assert_eq!(
            s.get("schema").unwrap().as_str().unwrap(),
            PROTOCOL_SCHEMA
        );
        assert_eq!(
            s.get("queue_cap").unwrap().as_usize().unwrap(),
            64
        );

        write_frame(&mut c, "{\"op\":\"shutdown\",\"id\":3}")
            .unwrap();
        let r = ExtractReply::parse(
            &read_frame(&mut c).unwrap().unwrap(),
        )
        .unwrap();
        assert!(r.ok);
        running.join().unwrap();
    }
}
