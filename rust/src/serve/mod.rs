//! `backpack serve`: extraction-as-a-service.
//!
//! A long-running daemon that accepts extraction requests over a
//! length-prefixed JSON protocol ([`protocol`], `backpack-serve/v1`)
//! on TCP or stdin/stdout, and answers them through the typed
//! artifact API ([`crate::ArtifactId`] / [`crate::Signature`]).
//! Compatible requests -- same model, signature, seed and
//! Monte-Carlo key -- arriving from many clients within a short
//! linger window are **coalesced** into one sharded
//! `extended_backward` call (the scheduler thread); per-sample results
//! (`Concat`-reduced keys) are sliced back per client while
//! `Sum`-reduced aggregates are broadcast to every participant. A
//! bounded request queue ([`queue::BoundedQueue`]) provides
//! backpressure: when it fills, connection threads stop reading
//! frames and clients feel TCP flow control, not server OOM.
//!
//! A `metrics` request returns live `backpack-metrics/v1` aggregates
//! (accumulated per-batch via [`MetricsAgg`]) plus serve counters.
//!
//! See `docs/serve.md` for the byte-level frame layout, the batching
//! and backpressure semantics, and an example session transcript.
//!
//! ```no_run
//! use backpack_rs::serve::{ServeConfig, Server};
//!
//! # fn main() -> anyhow::Result<()> {
//! let server = Server::bind(ServeConfig::default())?;
//! println!("listening on {}", server.local_addr());
//! server.run()?; // blocks until a shutdown request
//! # Ok(()) }
//! ```

pub mod protocol;
pub mod queue;

mod conn;
mod scheduler;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::json::Json;
use crate::obs::MetricsAgg;

use queue::BoundedQueue;
use scheduler::Pending;

pub use protocol::{
    BatchMeta, ExtractReply, ExtractRequest, Request, MAX_FRAME,
    PROTOCOL_SCHEMA,
};

/// Daemon configuration; `Default` is a sensible local setup
/// (ephemeral port, all cores, small linger).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Listen address; port 0 binds an ephemeral port (read it back
    /// from [`Server::local_addr`]).
    pub addr: String,
    /// Engine threads per extraction call (0 = all cores).
    pub threads: usize,
    /// Bounded request-queue capacity: the backpressure valve.
    pub queue_cap: usize,
    /// How long the scheduler lingers for compatible requests
    /// before running a batch.
    pub linger_ms: u64,
    /// Soft cap on coalesced union-batch samples: gathering stops
    /// once a batch reaches this many.
    pub max_batch: usize,
    /// True when the embedding process owns a running obs recorder
    /// (CLI `--trace`): per-batch windows then use non-draining
    /// mark/since so the final trace survives. When false the
    /// scheduler runs its own start/stop window per batch.
    pub retain_trace: bool,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            threads: 0,
            queue_cap: 64,
            linger_ms: 2,
            max_batch: 1024,
            retain_trace: false,
        }
    }
}

/// Monotone serve counters (all relaxed; they feed metrics, not
/// control flow).
#[derive(Default)]
pub(crate) struct Stats {
    /// Frames parsed as requests (any op).
    pub requests: AtomicU64,
    /// Extract requests accepted into the queue.
    pub extracts: AtomicU64,
    /// Engine calls run.
    pub batches: AtomicU64,
    /// Largest number of requests coalesced into one call.
    pub coalesced_max: AtomicU64,
    /// Error replies sent (bad frames, rejected requests, failures).
    pub errors: AtomicU64,
    /// Replies dropped because the client had disconnected.
    pub disconnects: AtomicU64,
}

struct Totals {
    agg: MetricsAgg,
    wall_s: f64,
}

/// State shared between the accept loop, connection threads, and
/// the scheduler.
pub(crate) struct Shared {
    pub cfg: ServeConfig,
    pub queue: BoundedQueue<Pending>,
    pub stats: Stats,
    shutdown: AtomicBool,
    boot: Instant,
    /// Bound TCP address, if any: shutdown pokes it to unblock the
    /// accept loop.
    addr: Mutex<Option<SocketAddr>>,
    totals: Mutex<Totals>,
}

impl Shared {
    fn new(cfg: ServeConfig) -> Arc<Shared> {
        let queue = BoundedQueue::new(cfg.queue_cap);
        Arc::new(Shared {
            cfg,
            queue,
            stats: Stats::default(),
            shutdown: AtomicBool::new(false),
            boot: Instant::now(),
            addr: Mutex::new(None),
            totals: Mutex::new(Totals {
                agg: MetricsAgg::default(),
                wall_s: 0.0,
            }),
        })
    }

    /// Fold one batch's metrics window into the live aggregates.
    pub(crate) fn absorb_window(&self, agg: &MetricsAgg, wall_s: f64) {
        let mut t = self.totals.lock().unwrap();
        t.agg.absorb(agg);
        t.wall_s += wall_s;
    }

    /// The `metrics` reply: a schema-pure `backpack-metrics/v1`
    /// object over everything served so far, plus serve counters.
    pub(crate) fn metrics_reply(&self, id: u64) -> String {
        let metrics = {
            let t = self.totals.lock().unwrap();
            t.agg.to_json(t.wall_s)
        };
        protocol::metrics_reply(id, metrics, self.serve_json())
    }

    fn serve_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        let num = |v: u64| Json::Num(v as f64);
        o.insert(
            "schema".into(),
            Json::Str(PROTOCOL_SCHEMA.to_string()),
        );
        o.insert(
            "uptime_s".into(),
            Json::Num(self.boot.elapsed().as_secs_f64()),
        );
        o.insert(
            "queue_depth".into(),
            num(self.queue.len() as u64),
        );
        o.insert(
            "queue_cap".into(),
            num(self.cfg.queue_cap as u64),
        );
        let s = &self.stats;
        let r = Ordering::Relaxed;
        o.insert("requests".into(), num(s.requests.load(r)));
        o.insert("extracts".into(), num(s.extracts.load(r)));
        o.insert("batches".into(), num(s.batches.load(r)));
        o.insert(
            "coalesced_max".into(),
            num(s.coalesced_max.load(r)),
        );
        o.insert("errors".into(), num(s.errors.load(r)));
        o.insert("disconnects".into(), num(s.disconnects.load(r)));
        Json::Obj(o)
    }

    /// Initiate graceful shutdown: refuse new work, let the
    /// scheduler drain what is queued, unblock the accept loop.
    /// Idempotent.
    pub(crate) fn begin_shutdown(&self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        self.queue.close();
        if let Some(addr) = *self.addr.lock().unwrap() {
            // Self-connect so the blocking accept() observes the
            // flag; the connection is dropped unused.
            let _ = TcpStream::connect_timeout(
                &addr,
                Duration::from_millis(200),
            );
        }
    }
}

/// Handle for stopping a running [`Server`] from another thread
/// (tests, signal bridges).
#[derive(Clone)]
pub struct ServerHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
}

impl ServerHandle {
    /// The bound address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Trigger the same graceful shutdown as a `shutdown` request.
    pub fn shutdown(&self) {
        self.shared.begin_shutdown();
    }
}

/// A bound-but-not-yet-running TCP server.
pub struct Server {
    listener: TcpListener,
    addr: SocketAddr,
    shared: Arc<Shared>,
}

impl Server {
    /// Bind `cfg.addr`.
    pub fn bind(cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("cannot bind {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let shared = Shared::new(cfg);
        *shared.addr.lock().unwrap() = Some(addr);
        Ok(Server { listener, addr, shared })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// A shutdown handle, cloneable across threads.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle {
            addr: self.addr,
            shared: Arc::clone(&self.shared),
        }
    }

    /// Run the accept loop until shutdown. Spawns the scheduler
    /// thread and one thread per connection; returns after the
    /// scheduler has drained every queued request.
    pub fn run(self) -> Result<()> {
        let sched_shared = Arc::clone(&self.shared);
        let scheduler = std::thread::Builder::new()
            .name("backpack-sched".to_string())
            .spawn(move || scheduler::run(sched_shared))?;
        for stream in self.listener.incoming() {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            let _ = stream.set_nodelay(true);
            let shared = Arc::clone(&self.shared);
            let _ = std::thread::Builder::new()
                .name("backpack-conn".to_string())
                .spawn(move || {
                    let Ok(r) = stream.try_clone() else { return };
                    conn::serve_session(shared, r, stream);
                });
        }
        self.shared.queue.close();
        let _ = scheduler.join();
        Ok(())
    }
}

/// Serve a single session over stdin/stdout (the `--stdio` CLI
/// mode): same protocol, same scheduler, no socket.
pub fn run_stdio(cfg: ServeConfig) -> Result<()> {
    let shared = Shared::new(cfg);
    let sched_shared = Arc::clone(&shared);
    let scheduler = std::thread::Builder::new()
        .name("backpack-sched".to_string())
        .spawn(move || scheduler::run(sched_shared))?;
    conn::serve_session(
        Arc::clone(&shared),
        std::io::stdin().lock(),
        std::io::stdout(),
    );
    shared.queue.close();
    let _ = scheduler.join();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::protocol::{
        read_frame, write_frame, ExtractReply,
    };
    use super::*;

    /// Fast control-plane smoke: ping, metrics shape, graceful
    /// shutdown. The extraction/coalescing suite lives in
    /// `tests/serve.rs`.
    #[test]
    fn ping_metrics_and_shutdown_over_tcp() {
        let server = Server::bind(ServeConfig {
            linger_ms: 1,
            ..ServeConfig::default()
        })
        .unwrap();
        let addr = server.local_addr();
        let running =
            std::thread::spawn(move || server.run().unwrap());

        let mut c = TcpStream::connect(addr).unwrap();
        write_frame(&mut c, "{\"op\":\"ping\",\"id\":1}").unwrap();
        let r = ExtractReply::parse(
            &read_frame(&mut c).unwrap().unwrap(),
        )
        .unwrap();
        assert!(r.ok);
        assert_eq!(r.id, 1);

        write_frame(&mut c, "{\"op\":\"metrics\",\"id\":2}")
            .unwrap();
        let raw = read_frame(&mut c).unwrap().unwrap();
        let v = Json::parse(&raw).unwrap();
        assert!(v.get("ok").unwrap().as_bool().unwrap());
        // The metrics object is schema-pure backpack-metrics/v1
        // even before any batch has run.
        let m = v.get("metrics").unwrap();
        assert_eq!(
            m.get("schema").unwrap().as_str().unwrap(),
            crate::obs::METRICS_SCHEMA
        );
        let s = v.get("serve").unwrap();
        assert_eq!(
            s.get("schema").unwrap().as_str().unwrap(),
            PROTOCOL_SCHEMA
        );
        assert_eq!(
            s.get("queue_cap").unwrap().as_usize().unwrap(),
            64
        );

        write_frame(&mut c, "{\"op\":\"shutdown\",\"id\":3}")
            .unwrap();
        let r = ExtractReply::parse(
            &read_frame(&mut c).unwrap().unwrap(),
        )
        .unwrap();
        assert!(r.ok);
        running.join().unwrap();
    }
}
