//! Per-connection session loop, generic over the transport so TCP
//! sockets and stdin/stdout share one implementation.
//!
//! Each session runs a reader loop on the calling thread and a
//! writer thread draining an `mpsc` channel of [`Reply`] frames. The
//! channel sender is cloned into every queued request, so replies
//! for in-flight extractions still reach the client after its read
//! side hits EOF, and the writer thread only exits once every
//! pending reply has been delivered (or the socket has died -- a
//! mid-batch disconnect just makes the scheduler's send fail, which
//! is counted, tolerated, and does not disturb the rest of the
//! batch).
//!
//! The writer thread is also where the request lifecycle ends: a
//! reply carrying an [`Access`] record gets its `reply-written`
//! stamp the moment the frame hits the transport, and the record is
//! finished into the latency histograms and access log right there.

use std::io::{Read, Write};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::Instant;

use super::protocol::{
    self, error_reply, pong_reply, shutdown_reply, Request,
};
use super::scheduler::Pending;
use super::{Access, Reply, Shared, Stamps};

/// Serve one client session until EOF, a malformed frame, or
/// shutdown.
pub(crate) fn serve_session<R, W>(shared: Arc<Shared>, mut r: R, w: W)
where
    R: Read,
    W: Write + Send + 'static,
{
    let (tx, rx) = mpsc::channel::<Reply>();
    let wr_shared = Arc::clone(&shared);
    let writer = std::thread::spawn(move || {
        let mut w = w;
        // Once a write fails the client is gone, but the channel
        // must still drain so senders never see a full pipe and
        // every in-flight access record is finished (as a
        // `disconnect`) rather than lost.
        let mut dead = false;
        for reply in rx {
            if !dead
                && protocol::write_frame(&mut w, &reply.frame)
                    .is_err()
            {
                dead = true;
            }
            let Some(mut a) = reply.access else { continue };
            if dead {
                wr_shared
                    .stats
                    .disconnects
                    .fetch_add(1, Ordering::Relaxed);
                a.outcome = "disconnect";
                wr_shared.finish_request(a, None);
            } else {
                wr_shared.finish_request(a, Some(Instant::now()));
            }
        }
    });
    let control = |frame: String| Reply { frame, access: None };

    loop {
        let frame = match protocol::read_frame(&mut r) {
            Ok(Some(f)) => f,
            // Clean EOF between frames: session over.
            Ok(None) => break,
            Err(e) => {
                // Framing is broken; report once and hang up (no id
                // is recoverable from a bad frame).
                shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                let _ = tx
                    .send(control(error_reply(0, &format!("{e:#}"))));
                break;
            }
        };
        shared.stats.requests.fetch_add(1, Ordering::Relaxed);
        match Request::parse(&frame) {
            Err(e) => {
                shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                let _ = tx
                    .send(control(error_reply(0, &format!("{e:#}"))));
            }
            Ok(Request::Ping { id }) => {
                let _ = tx.send(control(pong_reply(id)));
            }
            Ok(Request::Metrics { id }) => {
                let _ = tx.send(control(shared.metrics_reply(id)));
            }
            Ok(Request::Shutdown { id }) => {
                let _ = tx.send(control(shutdown_reply(id)));
                shared.begin_shutdown();
                break;
            }
            Ok(Request::Extract(req)) => {
                shared.stats.extracts.fetch_add(1, Ordering::Relaxed);
                // Stamp *before* the blocking push so time spent
                // waiting on a full queue counts into the queue
                // stage -- backpressure is latency the client feels.
                let pending = Pending {
                    req,
                    reply: tx.clone(),
                    stamps: Stamps::new(),
                };
                if let Err(p) = shared.queue.push(pending) {
                    shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                    let access = Access {
                        id: p.req.id,
                        model: p.req.model.clone(),
                        sig: p.req.sig.to_string(),
                        n: p.req.y.len(),
                        batch_n: 0,
                        batch_requests: 0,
                        outcome: "rejected",
                        stamps: p.stamps,
                    };
                    let _ = tx.send(Reply {
                        frame: error_reply(
                            p.req.id,
                            "server is shutting down",
                        ),
                        access: Some(access),
                    });
                }
            }
        }
    }

    // Drop our sender; the writer exits once the scheduler has
    // delivered (and dropped) every clone held by in-flight
    // requests, flushing all outstanding replies first.
    drop(tx);
    let _ = writer.join();
}
