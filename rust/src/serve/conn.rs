//! Per-connection session loop, generic over the transport so TCP
//! sockets and stdin/stdout share one implementation.
//!
//! Each session runs a reader loop on the calling thread and a
//! writer thread draining an `mpsc` channel of reply frames. The
//! channel sender is cloned into every queued request, so replies
//! for in-flight extractions still reach the client after its read
//! side hits EOF, and the writer thread only exits once every
//! pending reply has been delivered (or the socket has died -- a
//! mid-batch disconnect just makes the scheduler's send fail, which
//! is counted, tolerated, and does not disturb the rest of the
//! batch).

use std::io::{Read, Write};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};

use super::protocol::{
    self, error_reply, pong_reply, shutdown_reply, Request,
};
use super::scheduler::Pending;
use super::Shared;

/// Serve one client session until EOF, a malformed frame, or
/// shutdown.
pub(crate) fn serve_session<R, W>(shared: Arc<Shared>, mut r: R, w: W)
where
    R: Read,
    W: Write + Send + 'static,
{
    let (tx, rx) = mpsc::channel::<String>();
    let writer = std::thread::spawn(move || {
        let mut w = w;
        for frame in rx {
            if protocol::write_frame(&mut w, &frame).is_err() {
                // Client gone; drain silently so senders never
                // block (mpsc sends are non-blocking anyway).
                break;
            }
        }
    });

    loop {
        let frame = match protocol::read_frame(&mut r) {
            Ok(Some(f)) => f,
            // Clean EOF between frames: session over.
            Ok(None) => break,
            Err(e) => {
                // Framing is broken; report once and hang up (no id
                // is recoverable from a bad frame).
                shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(error_reply(0, &format!("{e:#}")));
                break;
            }
        };
        shared.stats.requests.fetch_add(1, Ordering::Relaxed);
        match Request::parse(&frame) {
            Err(e) => {
                shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(error_reply(0, &format!("{e:#}")));
            }
            Ok(Request::Ping { id }) => {
                let _ = tx.send(pong_reply(id));
            }
            Ok(Request::Metrics { id }) => {
                let _ = tx.send(shared.metrics_reply(id));
            }
            Ok(Request::Shutdown { id }) => {
                let _ = tx.send(shutdown_reply(id));
                shared.begin_shutdown();
                break;
            }
            Ok(Request::Extract(req)) => {
                shared.stats.extracts.fetch_add(1, Ordering::Relaxed);
                let pending = Pending { req, reply: tx.clone() };
                // Blocking push: a full queue parks this thread,
                // which stops frame reads -- backpressure reaches
                // the client as TCP flow control.
                if let Err(p) = shared.queue.push(pending) {
                    shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send(error_reply(
                        p.req.id,
                        "server is shutting down",
                    ));
                }
            }
        }
    }

    // Drop our sender; the writer exits once the scheduler has
    // delivered (and dropped) every clone held by in-flight
    // requests, flushing all outstanding replies first.
    drop(tx);
    let _ = writer.join();
}
