//! The batching scheduler: one thread that owns the backend and
//! turns many queued client requests into few engine calls.
//!
//! The scheduler pops a *leader* request, then lingers briefly
//! (`linger_ms`) scavenging the queue for **compatible** requests --
//! same `(model, signature, seed, key)` -- and concatenates their
//! sample batches into one sharded `extended_backward` call. Results
//! split back per client: `Concat`-reduced keys (per-sample
//! quantities) are sliced to each client's rows, everything else
//! (`Sum`-reduced aggregates, Kronecker factors, the loss) is
//! broadcast to every participant, so a coalesced batch behaves as
//! one collective extraction over the union batch.
//!
//! Exactness: with matching seed the participants share parameters,
//! and Monte-Carlo draws are keyed by *global sample index* in the
//! union batch, so a coalesced call is bit-identical to one serial
//! `extended_backward` over the concatenated data (the equivalence
//! `tests/serve.rs` pins at `threads = 1`).
//!
//! The scheduler thread owns its `NativeBackend` and plan cache
//! outright (compiled plans are `Rc`, deliberately not `Send`);
//! replies travel back to connection threads over `mpsc` channels.

use std::collections::{BTreeMap, VecDeque};
use std::rc::Rc;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::backend::{Backend, Exec};
use crate::backend::api::{ArtifactId, Signature};
use crate::backend::native::NativeBackend;
use crate::coordinator::train::{build_inputs, init_params};
use crate::obs;
use crate::obs::MetricsAgg;
use crate::optim::NamedParam;
use crate::runtime::Tensor;

use super::protocol::{
    error_reply, extract_reply, BatchMeta, ExtractRequest,
};
use super::{Access, Reply, Shared, Stamps};

/// Soft cap on cached compiled plans; synthesis is cheap, so on
/// overflow the cache is simply cleared.
const PLAN_CACHE_CAP: usize = 64;

/// One admitted extraction waiting for (or riding in) a batch. The
/// sender is the owning connection's writer channel; `stamps`
/// carries the request's lifecycle timestamps (stamped at accept by
/// the connection thread, advanced here at queue-pop, linger-close
/// and extract-done).
pub(crate) struct Pending {
    pub req: ExtractRequest,
    pub reply: mpsc::Sender<Reply>,
    pub stamps: Stamps,
}

/// LRU-bounded `(model, seed) -> parameters` cache. Participants
/// sharing a seed share parameters, so the scheduler keeps recent
/// sets warm; past `cap` the least-recently-used set is evicted
/// (counted in `param_cache_evictions`). A linear scan is fine:
/// `cap` is small and each entry holds megabytes, not bytes.
struct ParamCache {
    cap: usize,
    entries: VecDeque<((String, u64), Vec<NamedParam>)>,
}

impl ParamCache {
    fn new(cap: usize) -> ParamCache {
        ParamCache {
            cap: cap.max(1),
            entries: VecDeque::new(),
        }
    }

    /// Fetch (moving the entry to most-recent) or initialize the
    /// parameter set for `(model, seed)`.
    fn get_or_init(
        &mut self,
        spec: &crate::runtime::ArtifactSpec,
        model: &str,
        seed: u64,
        shared: &Shared,
    ) -> &Vec<NamedParam> {
        let key = (model.to_string(), seed);
        if let Some(i) =
            self.entries.iter().position(|(k, _)| *k == key)
        {
            let hit = self.entries.remove(i).unwrap();
            self.entries.push_back(hit);
        } else {
            while self.entries.len() >= self.cap {
                self.entries.pop_front();
                shared
                    .stats
                    .param_cache_evictions
                    .fetch_add(1, Ordering::Relaxed);
            }
            self.entries
                .push_back((key, init_params(spec, seed)));
        }
        &self.entries.back().unwrap().1
    }
}

/// Coalescing compatibility key: requests agreeing on all four
/// fields run as one union batch. Seed equality makes parameters
/// shared; key equality makes the Monte-Carlo draw stream shared.
#[derive(Clone, PartialEq, Eq)]
struct BatchKey {
    model: String,
    sig: Signature,
    seed: u64,
    key: Option<[u32; 2]>,
}

impl BatchKey {
    fn of(req: &ExtractRequest) -> BatchKey {
        BatchKey {
            model: req.model.clone(),
            sig: req.sig.clone(),
            seed: req.seed,
            key: req.key,
        }
    }

    fn matches(&self, req: &ExtractRequest) -> bool {
        self.model == req.model
            && self.sig == req.sig
            && self.seed == req.seed
            && self.key == req.key
    }
}

/// Scheduler entry point; runs until the queue closes *and* drains,
/// so a graceful shutdown still answers everything already queued.
pub(crate) fn run(shared: Arc<Shared>) {
    let backend = NativeBackend::with_threads(shared.cfg.threads);
    let mut plans: BTreeMap<String, Rc<dyn Exec>> = BTreeMap::new();
    let mut params = ParamCache::new(shared.cfg.param_cache);

    while let Some(mut first) = shared.queue.pop() {
        first.stamps.popped = Some(Instant::now());
        let Some(leader) = admit(&backend, first, &shared) else {
            continue;
        };
        let key = BatchKey::of(&leader.req);
        let mut total = leader.req.y.len();
        let mut batch = vec![leader];
        // Linger: scavenge compatible requests until the window
        // closes or the soft batch cap is reached. `max_batch` is a
        // soft cap -- one scavenge may overshoot it, but gathering
        // stops as soon as it is crossed.
        let deadline = Instant::now()
            + Duration::from_millis(shared.cfg.linger_ms);
        loop {
            for mut cand in
                shared.queue.take_where(|p| key.matches(&p.req))
            {
                cand.stamps.popped = Some(Instant::now());
                if let Some(p) = admit(&backend, cand, &shared) {
                    total += p.req.y.len();
                    batch.push(p);
                }
            }
            if total >= shared.cfg.max_batch
                || !shared.queue.wait_push_until(deadline)
            {
                break;
            }
        }
        // The union batch is final: stamp linger-close on every
        // participant with one shared instant.
        let closed = Instant::now();
        for p in &mut batch {
            p.stamps.closed = Some(closed);
        }
        run_batch(
            &backend,
            &mut plans,
            &mut params,
            &shared,
            batch,
            total,
        );
    }
}

/// Build the [`Access`] record for one batch participant.
fn access_of(
    p: &Pending,
    outcome: &'static str,
    batch_n: usize,
    batch_requests: usize,
) -> Access {
    Access {
        id: p.req.id,
        model: p.req.model.clone(),
        sig: p.req.sig.to_string(),
        n: p.req.y.len(),
        batch_n,
        batch_requests,
        outcome,
        stamps: p.stamps,
    }
}

/// Send one reply to its connection's writer thread. A failed send
/// means the session ended and its writer is gone: the disconnect
/// is counted here and the recovered access record finished
/// directly (there is no writer left to do it).
fn deliver(
    shared: &Shared,
    to: &mpsc::Sender<Reply>,
    frame: String,
    access: Access,
) {
    if let Err(e) = to.send(Reply { frame, access: Some(access) }) {
        shared.stats.disconnects.fetch_add(1, Ordering::Relaxed);
        let mut a = e.0.access.unwrap();
        a.outcome = "disconnect";
        shared.finish_request(a, None);
    }
}

/// Validate one request against the backend before it may join a
/// batch. On rejection the client gets an individual error reply
/// (with the typed API's nearest-match suggestions) and the batch
/// proceeds without it.
fn admit(
    backend: &NativeBackend,
    p: Pending,
    shared: &Shared,
) -> Option<Pending> {
    match check(backend, &p.req) {
        Ok(()) => Some(p),
        Err(e) => {
            shared.stats.errors.fetch_add(1, Ordering::Relaxed);
            let frame = error_reply(p.req.id, &format!("{e:#}"));
            let access = access_of(&p, "rejected", 0, 0);
            deliver(shared, &p.reply, frame, access);
            None
        }
    }
}

fn check(
    backend: &NativeBackend,
    req: &ExtractRequest,
) -> anyhow::Result<()> {
    let n = req.y.len();
    let id =
        ArtifactId::new(req.model.clone(), req.sig.clone(), n)?;
    // Resolves model + extensions with did-you-mean suggestions and
    // enforces the fully-connected-only restriction (footnote 5).
    let spec = backend.spec_id(&id)?;
    let in_numel: usize = spec.in_shape.iter().product();
    anyhow::ensure!(
        req.x.len() == n * in_numel,
        "x has {} values but {} samples of {} need {}",
        req.x.len(),
        n,
        spec.model,
        n * in_numel
    );
    for &l in &req.y {
        anyhow::ensure!(
            (0..spec.num_classes as i32).contains(&l),
            "label {l} is outside [0, {})",
            spec.num_classes
        );
    }
    if spec.has_key {
        anyhow::ensure!(
            req.key.is_some(),
            "signature {} draws Monte-Carlo samples; supply \
             \"key\": [a, b]",
            req.sig
        );
    }
    Ok(())
}

/// Execute one coalesced batch and split the results back per
/// client.
fn run_batch(
    backend: &NativeBackend,
    plans: &mut BTreeMap<String, Rc<dyn Exec>>,
    params: &mut ParamCache,
    shared: &Shared,
    mut batch: Vec<Pending>,
    total: usize,
) {
    let coalesced = batch.len();
    let result = execute(
        backend, plans, params, shared, &mut batch, total,
    );
    shared.record_batch(total, coalesced);
    match result {
        Ok(replies) => {
            for (p, reply) in batch.iter().zip(replies) {
                let access =
                    access_of(p, "ok", total, coalesced);
                deliver(shared, &p.reply, reply, access);
            }
        }
        Err(e) => {
            // A whole-batch failure (it passed admission, so this
            // is unexpected) errors every participant.
            let req0 = &batch[0].req;
            let msg = format!(
                "batch {}_{}_n{total} failed: {e:#}",
                req0.model, req0.sig
            );
            obs::progress(format_args!("serve: {msg}"));
            shared.stats.errors.fetch_add(1, Ordering::Relaxed);
            for p in &batch {
                let frame = error_reply(p.req.id, &msg);
                let access =
                    access_of(p, "error", total, coalesced);
                deliver(shared, &p.reply, frame, access);
            }
        }
    }
}

fn execute(
    backend: &NativeBackend,
    plans: &mut BTreeMap<String, Rc<dyn Exec>>,
    params: &mut ParamCache,
    shared: &Shared,
    batch: &mut [Pending],
    total: usize,
) -> anyhow::Result<Vec<String>> {
    let req0 = &batch[0].req;
    let id = ArtifactId::new(
        req0.model.clone(),
        req0.sig.clone(),
        total,
    )?;
    let name = id.to_string();

    // Per-signature plan cache: one compiled plan per (model, sig,
    // union batch size).
    let exe = match plans.get(&name) {
        Some(exe) => exe.clone(),
        None => {
            if plans.len() >= PLAN_CACHE_CAP {
                plans.clear();
            }
            let exe = backend.load_id(&id)?;
            plans.insert(name.clone(), exe.clone());
            exe
        }
    };
    let spec = exe.spec().clone();

    // Participants sharing a seed share parameters (LRU-bounded).
    let ps =
        params.get_or_init(&spec, &req0.model, req0.seed, shared);

    // Union batch, concatenated in arrival order.
    let in_numel: usize = spec.in_shape.iter().product();
    let mut xs = Vec::with_capacity(total * in_numel);
    let mut ys = Vec::with_capacity(total);
    for p in batch.iter() {
        xs.extend_from_slice(&p.req.x);
        ys.extend_from_slice(&p.req.y);
    }
    let mut x_shape = vec![total];
    x_shape.extend_from_slice(&spec.in_shape);
    let x = Tensor::from_f32(&x_shape, xs);
    let y = Tensor::from_i32(&[total], ys);
    // A key is forwarded only when the graph actually draws
    // Monte-Carlo samples; a client supplying one defensively for a
    // deterministic signature must not change the input layout.
    let key = if spec.has_key { req0.key } else { None };
    let inputs = build_inputs(ps, x, y, key);

    // Per-batch observability window. With `retain_trace` the CLI
    // owns a running recorder, so the window is a non-draining
    // mark/since pair; otherwise the scheduler runs its own
    // start/stop window per batch.
    let mark = if shared.cfg.retain_trace {
        Some(obs::mark())
    } else {
        obs::start();
        None
    };
    let t0 = Instant::now();
    let out = exe.run(&inputs);
    let wall = t0.elapsed().as_secs_f64();
    let trace = match &mark {
        Some(m) => obs::since(m),
        None => obs::stop(),
    };
    // Stamp extract-done before unwrapping, so a failed engine call
    // still times its extract stage.
    let done = Instant::now();
    for p in batch.iter_mut() {
        p.stamps.done = Some(done);
    }
    let out = out?;

    let agg = MetricsAgg::from_trace(&trace);
    shared.absorb_window(&agg, wall);
    let window = agg.to_json(wall);

    // Split per client: Concat-reduced keys by sample rows,
    // everything else broadcast. The rule per key comes from the
    // same [`ReducePlan`] that merges thread shards and worker
    // shards, so serve slicing can never disagree with the engine.
    let plan =
        crate::backend::extensions::ReducePlan::of(backend.extensions());
    let mut replies = Vec::with_capacity(batch.len());
    let mut off = 0usize;
    for p in batch.iter() {
        let n = p.req.y.len();
        let mut results = BTreeMap::new();
        for key in out.names() {
            let t = out.get(key)?;
            let per_sample = plan.is_concat(key)
                && t.shape.first() == Some(&total);
            let sliced = if per_sample {
                let rows = t.numel() / total;
                let data = t.f32s()?;
                let mut shape = t.shape.clone();
                shape[0] = n;
                Tensor::from_f32(
                    &shape,
                    data[off * rows..(off + n) * rows].to_vec(),
                )
            } else {
                t.clone()
            };
            results.insert(key.clone(), sliced);
        }
        let meta = BatchMeta {
            batch_n: total,
            coalesced: batch.len(),
            offset: off,
            n,
        };
        let metrics =
            p.req.want_metrics.then(|| window.clone());
        replies.push(extract_reply(
            p.req.id, &results, meta, metrics,
        ));
        off += n;
    }
    Ok(replies)
}
