//! `backpack loadgen`: a load generator for the serve daemon.
//!
//! Spawns N concurrent in-process clients, each driving one TCP
//! connection with a fixed extraction signature (clients are
//! assigned signatures round-robin from the requested mix, so
//! same-signature clients coalesce) for a fixed duration, then
//! emits a `backpack-servebench/v1` document: throughput, client
//! observed e2e latency percentiles (from a merged [`Histogram`]),
//! and the daemon's own `serve.latency` section fetched over the
//! `metrics` op. The document carries bench-style `cases[]` rows
//! (`name` + `p50_s`), so `backpack bench --compare` gates serve
//! latency regressions exactly like single-run p50s (see
//! `docs/bench.md`).
//!
//! Without `--addr` a daemon is spawned in-process on an ephemeral
//! port and shut down after the run, so one command is a complete
//! self-contained serve benchmark; with `--addr` an external daemon
//! is driven instead (its `serve.latency` section then spans that
//! daemon's whole lifetime, not just this run).

use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::backend::api::{ArtifactId, Signature};
use crate::backend::native::NativeBackend;
use crate::backend::Backend;
use crate::json::Json;
use crate::obs::Histogram;

use super::protocol::{
    read_frame, write_frame, ExtractReply, ExtractRequest,
};
use super::{ServeConfig, Server};

/// Schema identifier of the loadgen output document.
pub const SERVEBENCH_SCHEMA: &str = "backpack-servebench/v1";

/// Load-generator configuration; `Default` matches the CI smoke
/// setup (8 clients, grad + diag_ggn mix, self-spawned daemon).
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Target daemon address; `None` spawns one in-process on an
    /// ephemeral port for the duration of the run.
    pub addr: Option<String>,
    /// Concurrent client connections.
    pub clients: usize,
    /// How long clients keep sending, in seconds.
    pub duration_s: f64,
    /// Model every request asks for.
    pub model: String,
    /// Signature mix; client `c` uses `sigs[c % sigs.len()]`.
    pub sigs: Vec<Signature>,
    /// Samples per request (each client's slice of the union
    /// batch).
    pub per: usize,
    /// Parameter seed shared by every request (shared seed is what
    /// makes requests coalescible).
    pub seed: u64,
    /// Engine threads for the self-spawned daemon (0 = all cores).
    pub threads: usize,
    /// Linger window of the self-spawned daemon.
    pub linger_ms: u64,
    /// Union-batch soft cap of the self-spawned daemon.
    pub max_batch: usize,
}

impl Default for LoadgenConfig {
    fn default() -> LoadgenConfig {
        LoadgenConfig {
            addr: None,
            clients: 8,
            duration_s: 5.0,
            model: "logreg".to_string(),
            sigs: vec![
                Signature::grad(),
                "diag_ggn".parse().unwrap(),
            ],
            per: 4,
            seed: 0,
            threads: 0,
            linger_ms: 2,
            max_batch: 1024,
        }
    }
}

/// What one loadgen run measured.
pub struct LoadgenReport {
    pub clients: usize,
    /// Measured wall-clock of the client phase (not the requested
    /// duration).
    pub duration_s: f64,
    pub model: String,
    pub sigs: Vec<Signature>,
    pub per: usize,
    /// Successful extractions across all clients.
    pub requests: u64,
    /// Error replies and transport failures across all clients.
    pub errors: u64,
    pub throughput_rps: f64,
    /// Client-observed e2e latency (request written -> reply read),
    /// microseconds, merged over all clients.
    pub e2e_us: Histogram,
    /// The daemon's `serve` metrics section (counters + its own
    /// per-stage `latency` histograms), when it could be fetched.
    pub server: Option<Json>,
}

/// Per-signature request shape, resolved once before spawning.
#[derive(Clone)]
struct SigShape {
    sig: Signature,
    in_numel: usize,
    num_classes: usize,
    has_key: bool,
}

/// Run the load generator to completion.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    ensure!(cfg.clients > 0, "loadgen needs at least one client");
    ensure!(cfg.per > 0, "loadgen needs --per >= 1");
    ensure!(!cfg.sigs.is_empty(), "loadgen needs at least one sig");
    ensure!(
        cfg.duration_s > 0.0,
        "loadgen needs a positive --duration-s"
    );

    // Resolve every signature against the backend up front, so a
    // typo fails here with the typed API's suggestions instead of
    // as N * duration streaming error replies.
    let probe = NativeBackend::with_threads(1);
    let mut shapes = Vec::with_capacity(cfg.sigs.len());
    for sig in &cfg.sigs {
        let id = ArtifactId::new(
            cfg.model.clone(),
            sig.clone(),
            cfg.per,
        )?;
        let spec = probe.spec_id(&id)?;
        shapes.push(SigShape {
            sig: sig.clone(),
            in_numel: spec.in_shape.iter().product(),
            num_classes: spec.num_classes,
            has_key: spec.has_key,
        });
    }

    // Self-spawn a daemon unless an external one was named.
    let (addr, spawned) = match &cfg.addr {
        Some(a) => (a.clone(), None),
        None => {
            let server = Server::bind(ServeConfig {
                threads: cfg.threads,
                linger_ms: cfg.linger_ms,
                max_batch: cfg.max_batch,
                ..ServeConfig::default()
            })?;
            let addr = server.local_addr().to_string();
            let handle = server.handle();
            let join = std::thread::Builder::new()
                .name("backpack-loadgen-srv".to_string())
                .spawn(move || server.run())?;
            (addr, Some((handle, join)))
        }
    };

    // All clients connect first, then start together on a barrier
    // so the measured window has full concurrency from its first
    // request.
    let barrier = Arc::new(Barrier::new(cfg.clients + 1));
    let duration = Duration::from_secs_f64(cfg.duration_s);
    let mut workers = Vec::with_capacity(cfg.clients);
    for c in 0..cfg.clients {
        let shape = shapes[c % shapes.len()].clone();
        let stream = TcpStream::connect(&addr).with_context(|| {
            format!("loadgen client {c} cannot connect {addr}")
        })?;
        let barrier = Arc::clone(&barrier);
        let seed = cfg.seed;
        let per = cfg.per;
        let model = cfg.model.clone();
        workers.push(
            std::thread::Builder::new()
                .name(format!("backpack-loadgen-{c}"))
                .spawn(move || {
                    barrier.wait();
                    client_loop(
                        stream, c, &model, &shape, per, seed,
                        duration,
                    )
                })?,
        );
    }

    barrier.wait();
    let t0 = Instant::now();
    let mut e2e_us = Histogram::new();
    let mut requests = 0u64;
    let mut errors = 0u64;
    for w in workers {
        match w.join() {
            Ok(r) => {
                requests += r.requests;
                errors += r.errors;
                e2e_us.merge(&r.e2e_us);
            }
            Err(_) => errors += 1,
        }
    }
    let duration_s = t0.elapsed().as_secs_f64();

    // The daemon's own view (counters + per-stage latency) rides
    // along; a fetch failure degrades the report, not the run.
    let server = fetch_serve(&addr).ok();

    if let Some((handle, join)) = spawned {
        handle.shutdown();
        let _ = join.join();
    }

    Ok(LoadgenReport {
        clients: cfg.clients,
        duration_s,
        model: cfg.model.clone(),
        sigs: cfg.sigs.clone(),
        per: cfg.per,
        requests,
        errors,
        throughput_rps: requests as f64 / duration_s.max(1e-9),
        e2e_us,
        server,
    })
}

/// What one client measured.
struct ClientResult {
    requests: u64,
    errors: u64,
    e2e_us: Histogram,
}

/// One client's send/receive loop: synchronous request-response
/// until the deadline, timing each round-trip.
fn client_loop(
    mut stream: TcpStream,
    c: usize,
    model: &str,
    shape: &SigShape,
    per: usize,
    seed: u64,
    duration: Duration,
) -> ClientResult {
    let mut res = ClientResult {
        requests: 0,
        errors: 0,
        e2e_us: Histogram::new(),
    };
    let deadline = Instant::now() + duration;
    let mut j = 0u64;
    while Instant::now() < deadline {
        let req = request_for(c, j, model, shape, per, seed);
        j += 1;
        let t = Instant::now();
        if write_frame(&mut stream, &req.to_json()).is_err() {
            res.errors += 1;
            break;
        }
        let frame = match read_frame(&mut stream) {
            Ok(Some(f)) => f,
            _ => {
                res.errors += 1;
                break;
            }
        };
        match ExtractReply::parse(&frame) {
            Ok(r) if r.ok => {
                res.requests += 1;
                res.e2e_us
                    .record(t.elapsed().as_micros() as u64);
            }
            _ => res.errors += 1,
        }
    }
    res
}

/// Deterministic request `j` of client `c`: synthetic data, shared
/// seed/key so same-signature clients coalesce.
fn request_for(
    c: usize,
    j: u64,
    model: &str,
    shape: &SigShape,
    per: usize,
    seed: u64,
) -> ExtractRequest {
    let mut x = Vec::with_capacity(per * shape.in_numel);
    for k in 0..per * shape.in_numel {
        let v = (c * 131 + j as usize * 7 + k * 13) % 97;
        x.push(v as f32 / 97.0);
    }
    let y = (0..per)
        .map(|i| ((c + i) % shape.num_classes) as i32)
        .collect();
    ExtractRequest {
        id: c as u64 * 1_000_000 + j,
        model: model.to_string(),
        sig: shape.sig.clone(),
        seed,
        x,
        y,
        key: shape.has_key.then_some([seed as u32, 9]),
        want_metrics: false,
    }
}

/// Fetch the daemon's `serve` metrics section over one `metrics`
/// round-trip.
fn fetch_serve(addr: &str) -> Result<Json> {
    let mut c = TcpStream::connect(addr)
        .with_context(|| format!("cannot connect {addr}"))?;
    write_frame(&mut c, "{\"op\":\"metrics\",\"id\":1}")?;
    let Some(raw) = read_frame(&mut c)? else {
        bail!("daemon closed during the metrics fetch")
    };
    Ok(Json::parse(&raw)?.get("serve")?.clone())
}

impl LoadgenReport {
    /// A percentile of the merged client-observed e2e latency, in
    /// seconds.
    pub fn e2e_percentile_s(&self, q: f64) -> Option<f64> {
        self.e2e_us.percentile(q).map(|us| us / 1e6)
    }

    /// The daemon-side p50 of one latency stage, in seconds.
    fn stage_p50_s(&self, stage: &str) -> Option<f64> {
        self.server
            .as_ref()?
            .opt("latency")?
            .opt("stages")?
            .opt(stage)?
            .opt("p50")?
            .as_f64()
            .ok()
            .map(|us| us / 1e6)
    }

    /// The `backpack-servebench/v1` document. `cases[]` rows carry
    /// bench-style `name` + `p50_s` (seconds, smaller = better) so
    /// `bench --compare` gates them; throughput is encoded as its
    /// inverse for the same reason.
    pub fn to_json(&self) -> Json {
        let mut cases = Vec::new();
        let mut case = |name: String, p50_s: f64| {
            let mut c = std::collections::BTreeMap::new();
            c.insert("name".to_string(), Json::Str(name));
            c.insert("p50_s".to_string(), Json::Num(p50_s));
            cases.push(Json::Obj(c));
        };
        let m = &self.model;
        for (tag, q) in
            [("p50", 0.50), ("p95", 0.95), ("p99", 0.99)]
        {
            if let Some(s) = self.e2e_percentile_s(q) {
                case(format!("loadgen_{m}_e2e_{tag}"), s);
            }
        }
        if self.throughput_rps > 0.0 {
            case(
                format!("loadgen_{m}_inv_throughput"),
                1.0 / self.throughput_rps,
            );
        }
        if let Some(s) = self.stage_p50_s("extract") {
            case(format!("loadgen_{m}_stage_extract_p50"), s);
        }

        let mut root = std::collections::BTreeMap::new();
        root.insert(
            "schema".to_string(),
            Json::Str(SERVEBENCH_SCHEMA.to_string()),
        );
        root.insert(
            "rev".to_string(),
            Json::Str(crate::bench::git_rev()),
        );
        root.insert(
            "clients".to_string(),
            Json::Num(self.clients as f64),
        );
        root.insert(
            "duration_s".to_string(),
            Json::Num(self.duration_s),
        );
        root.insert("model".to_string(), Json::Str(m.clone()));
        root.insert(
            "sigs".to_string(),
            Json::Arr(
                self.sigs
                    .iter()
                    .map(|s| Json::Str(s.to_string()))
                    .collect(),
            ),
        );
        root.insert("per".to_string(), Json::Num(self.per as f64));
        root.insert(
            "requests".to_string(),
            Json::Num(self.requests as f64),
        );
        root.insert(
            "errors".to_string(),
            Json::Num(self.errors as f64),
        );
        root.insert(
            "throughput_rps".to_string(),
            Json::Num(self.throughput_rps),
        );
        root.insert("e2e_us".to_string(), self.e2e_us.to_json());
        root.insert(
            "server".to_string(),
            self.server.clone().unwrap_or(Json::Null),
        );
        root.insert("cases".to_string(), Json::Arr(cases));
        Json::Obj(root)
    }

    /// The human-readable run summary on stdout.
    pub fn print_table(&self) {
        let sigs: Vec<String> =
            self.sigs.iter().map(|s| s.to_string()).collect();
        println!(
            "== loadgen: {} clients x {:.1}s against {} [{}] ==",
            self.clients,
            self.duration_s,
            self.model,
            sigs.join(", ")
        );
        println!(
            "{:28} {} ok, {} errors ({:.0} req/s)",
            "requests",
            self.requests,
            self.errors,
            self.throughput_rps
        );
        let fmt = |q: f64| {
            self.e2e_percentile_s(q)
                .map(crate::bench::fmt_time)
                .unwrap_or_else(|| "-".to_string())
        };
        println!(
            "{:28} p50 {:>10}  p90 {:>10}  p95 {:>10}  p99 {:>10}",
            "e2e latency",
            fmt(0.50),
            fmt(0.90),
            fmt(0.95),
            fmt(0.99)
        );
        let Some(server) = &self.server else { return };
        for stage in ["queue", "linger", "extract", "reply"] {
            if let Some(s) = self.stage_p50_s(stage) {
                println!(
                    "{:28} p50 {:>10}",
                    format!("stage {stage} (server)"),
                    crate::bench::fmt_time(s)
                );
            }
        }
        let rate = server
            .opt("latency")
            .and_then(|l| l.opt("coalescing"))
            .and_then(|c| c.opt("rate"))
            .and_then(|r| r.as_f64().ok());
        if let Some(rate) = rate {
            println!(
                "{:28} {:.1}% of requests shared a call",
                "coalescing", rate * 100.0
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end smoke against a self-spawned daemon: short run,
    /// 8 clients, grad only. Pins the servebench schema, the
    /// bench-compatible cases, and that traffic actually flowed.
    #[test]
    fn loadgen_self_spawn_produces_a_servebench_document() {
        let report = run(&LoadgenConfig {
            clients: 8,
            duration_s: 0.3,
            sigs: vec![Signature::grad()],
            per: 2,
            threads: 1,
            linger_ms: 1,
            ..LoadgenConfig::default()
        })
        .unwrap();
        assert!(report.requests > 0, "no request succeeded");
        assert_eq!(report.errors, 0);
        assert_eq!(
            report.requests,
            report.e2e_us.count(),
            "every ok request is one e2e sample"
        );
        let v = Json::parse(&report.to_json().to_string_json())
            .unwrap();
        assert_eq!(
            v.get("schema").unwrap().as_str().unwrap(),
            SERVEBENCH_SCHEMA
        );
        assert_eq!(
            v.get("clients").unwrap().as_usize().unwrap(),
            8
        );
        let names: Vec<String> = v
            .get("cases")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|c| {
                c.get("name").unwrap().as_str().unwrap().to_string()
            })
            .collect();
        assert!(
            names.contains(&"loadgen_logreg_e2e_p50".to_string()),
            "{names:?}"
        );
        assert!(
            names
                .contains(&"loadgen_logreg_e2e_p99".to_string()),
            "{names:?}"
        );
        assert!(
            names.contains(
                &"loadgen_logreg_inv_throughput".to_string()
            ),
            "{names:?}"
        );
        for c in v.get("cases").unwrap().as_arr().unwrap() {
            assert!(
                c.get("p50_s").unwrap().as_f64().unwrap() > 0.0
            );
        }
        // The daemon's own latency section rode along and saw the
        // same traffic.
        let server = v.get("server").unwrap();
        let extracts =
            server.get("extracts").unwrap().as_f64().unwrap();
        assert!(extracts >= report.requests as f64);
        let e2e = server
            .get("latency")
            .unwrap()
            .get("e2e")
            .unwrap();
        assert!(
            e2e.get("count").unwrap().as_f64().unwrap() > 0.0
        );
        report.print_table();
    }
}
