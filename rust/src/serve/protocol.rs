//! The `backpack-serve/v1` wire protocol: length-prefixed JSON
//! frames carrying typed requests and replies.
//!
//! # Frame layout
//!
//! The frame codec is the crate-wide shared one in [`crate::wire`]
//! (length prefix + UTF-8 JSON payload, [`MAX_FRAME`] cap, clean-EOF
//! vs mid-frame-EOF contract) — re-exported here so protocol users
//! keep a single import path. See the [`crate::wire`] module docs
//! for the byte layout.
//!
//! # Requests
//!
//! The payload is a JSON object dispatched on `"op"`:
//!
//! * `extract` -- run one extraction ([`ExtractRequest`]); `sig` uses
//!   the [`Signature`] spelling (`"grad"`, `"eval"`,
//!   `"diag_ggn+kfac"`, ...), `x` is the row-major flat input batch,
//!   `y` the labels (the batch size is `y.len()`);
//! * `metrics` -- the live `backpack-metrics/v1` aggregates over
//!   everything served so far, plus serve counters;
//! * `ping` -- liveness probe;
//! * `shutdown` -- graceful stop: drains the queue, then the server
//!   exits.
//!
//! Replies always carry the request's `id` and `"ok"`; failures put
//! the message in `"error"`. Tensors serialize as
//! `{"shape": [...], "data": [...]}` with non-finite values encoded
//! as `null` (JSON has no NaN) and decoded back to NaN.
//!
//! docs/serve.md documents the protocol with an example session.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Context, Result};

use crate::backend::api::Signature;
use crate::json::Json;
use crate::runtime::Tensor;
use crate::wire::num_or_null;

pub use crate::wire::{
    read_frame, tensor_from_json, tensor_to_json, write_frame,
    MAX_FRAME,
};

/// Protocol identifier, echoed on the startup banner and in
/// `metrics` replies; bump on any breaking frame/layout change.
pub const PROTOCOL_SCHEMA: &str = "backpack-serve/v1";

/// Schema identifier of structured access-log records
/// (`backpack serve --access-log FILE`, one JSONL line per extract
/// request); bump on any breaking field change.
pub const ACCESS_SCHEMA: &str = "backpack-access/v1";

/// One extraction request: which graph to run and this client's
/// slice of data. Requests with the same `(model, sig, seed, key)`
/// are **compatible** and may be coalesced into one engine call; see
/// the batching semantics in `docs/serve.md`.
#[derive(Debug, Clone, PartialEq)]
pub struct ExtractRequest {
    /// Client-chosen correlation id, echoed on the reply.
    pub id: u64,
    /// Registered model name (`logreg`, `mlp`, `2c2d`, ...).
    pub model: String,
    /// Extension signature (`grad`, `eval`, `diag_ggn+kfac`, ...).
    pub sig: Signature,
    /// Parameter seed: participants sharing a seed share parameters
    /// (`init_params(spec, seed)`), which is what makes coalescing
    /// exact.
    pub seed: u64,
    /// Row-major flat input batch, `y.len() * in_numel` values.
    pub x: Vec<f32>,
    /// Labels in `[0, classes)`; the batch size is `y.len()`.
    pub y: Vec<i32>,
    /// PRNG key for Monte-Carlo signatures (`diag_ggn_mc`, `kfac`).
    pub key: Option<[u32; 2]>,
    /// When true the reply carries this batch's
    /// `backpack-metrics/v1` window under `"metrics"`.
    pub want_metrics: bool,
}

impl ExtractRequest {
    /// The wire form (`op: "extract"`); the client half of the
    /// round-trip [`Request::parse`] tests pin.
    pub fn to_json(&self) -> String {
        let mut o = BTreeMap::new();
        o.insert("op".into(), Json::Str("extract".into()));
        o.insert("id".into(), Json::Num(self.id as f64));
        o.insert("model".into(), Json::Str(self.model.clone()));
        o.insert("sig".into(), Json::Str(self.sig.to_string()));
        o.insert("seed".into(), Json::Num(self.seed as f64));
        o.insert(
            "x".into(),
            Json::Arr(
                self.x.iter().map(|v| num_or_null(*v as f64)).collect(),
            ),
        );
        o.insert(
            "y".into(),
            Json::Arr(
                self.y.iter().map(|v| Json::Num(*v as f64)).collect(),
            ),
        );
        if let Some([a, b]) = self.key {
            o.insert(
                "key".into(),
                Json::Arr(vec![
                    Json::Num(a as f64),
                    Json::Num(b as f64),
                ]),
            );
        }
        if self.want_metrics {
            o.insert("metrics".into(), Json::Bool(true));
        }
        Json::Obj(o).to_string_json()
    }
}

/// A parsed request frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Run one extraction.
    Extract(ExtractRequest),
    /// Live aggregates + serve counters.
    Metrics { id: u64 },
    /// Liveness probe.
    Ping { id: u64 },
    /// Graceful stop.
    Shutdown { id: u64 },
}

fn get_u64(v: &Json, key: &str) -> Result<u64> {
    let x = v.get(key)?.as_f64()?;
    ensure!(
        x >= 0.0 && x.fract() == 0.0 && x <= u64::MAX as f64,
        "{key} must be a non-negative integer, got {x}"
    );
    Ok(x as u64)
}

impl Request {
    /// Parse one request payload.
    pub fn parse(text: &str) -> Result<Request> {
        let v = Json::parse(text).context("request is not JSON")?;
        let op = v.get("op")?.as_str()?.to_string();
        let id = get_u64(&v, "id")?;
        match op.as_str() {
            "ping" => Ok(Request::Ping { id }),
            "metrics" => Ok(Request::Metrics { id }),
            "shutdown" => Ok(Request::Shutdown { id }),
            "extract" => {
                let model = v.get("model")?.as_str()?.to_string();
                let sig: Signature = v.get("sig")?.as_str()?.parse()?;
                let seed = match v.opt("seed") {
                    Some(_) => get_u64(&v, "seed")?,
                    None => 0,
                };
                let x = v
                    .get("x")?
                    .as_arr()?
                    .iter()
                    .map(|e| match e {
                        Json::Null => Ok(f32::NAN),
                        other => Ok(other.as_f64()? as f32),
                    })
                    .collect::<Result<Vec<f32>>>()?;
                let y = v
                    .get("y")?
                    .as_arr()?
                    .iter()
                    .map(|e| {
                        let l = e.as_f64()?;
                        ensure!(
                            l.fract() == 0.0
                                && (i32::MIN as f64..=i32::MAX as f64)
                                    .contains(&l),
                            "label {l} is not an i32"
                        );
                        Ok(l as i32)
                    })
                    .collect::<Result<Vec<i32>>>()?;
                let key = match v.opt("key") {
                    None | Some(Json::Null) => None,
                    Some(k) => {
                        let k = k.as_arr()?;
                        ensure!(
                            k.len() == 2,
                            "key must be a [u32, u32] pair"
                        );
                        let part = |e: &Json| -> Result<u32> {
                            let x = e.as_f64()?;
                            ensure!(
                                x >= 0.0
                                    && x.fract() == 0.0
                                    && x <= u32::MAX as f64,
                                "key part {x} is not a u32"
                            );
                            Ok(x as u32)
                        };
                        Some([part(&k[0])?, part(&k[1])?])
                    }
                };
                let want_metrics = match v.opt("metrics") {
                    None | Some(Json::Null) => false,
                    Some(m) => m.as_bool()?,
                };
                Ok(Request::Extract(ExtractRequest {
                    id,
                    model,
                    sig,
                    seed,
                    x,
                    y,
                    key,
                    want_metrics,
                }))
            }
            other => bail!(
                "unknown op {other:?} \
                 (extract|metrics|ping|shutdown)"
            ),
        }
    }
}

fn reply_base(id: u64, ok: bool) -> BTreeMap<String, Json> {
    let mut o = BTreeMap::new();
    o.insert("id".into(), Json::Num(id as f64));
    o.insert("ok".into(), Json::Bool(ok));
    o
}

/// `{"id", "ok": false, "error"}`.
pub fn error_reply(id: u64, msg: &str) -> String {
    let mut o = reply_base(id, false);
    o.insert("error".into(), Json::Str(msg.to_string()));
    Json::Obj(o).to_string_json()
}

/// The wire-level rejection frame a connection over `--max-conns`
/// receives before the socket is dropped: an ordinary error reply
/// (id 0 -- no request was read) whose message starts with
/// `server_busy`, so clients can distinguish load shedding from
/// request errors and retry with backoff.
pub fn busy_reply(max_conns: usize) -> String {
    error_reply(
        0,
        &format!(
            "server_busy: connection limit {max_conns} reached; \
             retry later"
        ),
    )
}

/// `{"id", "ok": true, "pong": true}`.
pub fn pong_reply(id: u64) -> String {
    let mut o = reply_base(id, true);
    o.insert("pong".into(), Json::Bool(true));
    Json::Obj(o).to_string_json()
}

/// `{"id", "ok": true, "shutdown": true}` -- acknowledged before the
/// drain begins.
pub fn shutdown_reply(id: u64) -> String {
    let mut o = reply_base(id, true);
    o.insert("shutdown".into(), Json::Bool(true));
    Json::Obj(o).to_string_json()
}

/// `{"id", "ok": true, "metrics": <backpack-metrics/v1>, "serve":
/// <counters>}`. The `metrics` object is schema-pure so existing
/// `backpack-metrics/v1` checkers validate it unchanged.
pub fn metrics_reply(id: u64, metrics: Json, serve: Json) -> String {
    let mut o = reply_base(id, true);
    o.insert("metrics".into(), metrics);
    o.insert("serve".into(), serve);
    Json::Obj(o).to_string_json()
}

/// Batch placement of one request inside a coalesced engine call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchMeta {
    /// Union batch size of the engine call.
    pub batch_n: usize,
    /// Number of client requests coalesced into the call.
    pub coalesced: usize,
    /// This request's first sample row in the union batch.
    pub offset: usize,
    /// This request's sample count.
    pub n: usize,
}

impl BatchMeta {
    fn to_json(self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("batch_n".into(), Json::Num(self.batch_n as f64));
        o.insert(
            "coalesced".into(),
            Json::Num(self.coalesced as f64),
        );
        o.insert("offset".into(), Json::Num(self.offset as f64));
        o.insert("n".into(), Json::Num(self.n as f64));
        Json::Obj(o)
    }
}

/// Successful extraction reply: per-key results (`Concat` keys
/// sliced to this client's rows, `Sum` keys broadcast), placement
/// meta, and optionally this batch's metrics window.
pub fn extract_reply(
    id: u64,
    results: &BTreeMap<String, Tensor>,
    meta: BatchMeta,
    metrics: Option<Json>,
) -> String {
    let mut o = reply_base(id, true);
    o.insert(
        "results".into(),
        Json::Obj(
            results
                .iter()
                .map(|(k, t)| (k.clone(), tensor_to_json(t)))
                .collect(),
        ),
    );
    o.insert("meta".into(), meta.to_json());
    if let Some(m) = metrics {
        o.insert("metrics".into(), m);
    }
    Json::Obj(o).to_string_json()
}

/// Client-side view of any reply frame (the test/scripting half of
/// the protocol).
#[derive(Debug, Clone)]
pub struct ExtractReply {
    /// Echoed request id.
    pub id: u64,
    /// Whether the request succeeded.
    pub ok: bool,
    /// Failure message when `ok` is false.
    pub error: Option<String>,
    /// Named result tensors (extraction replies).
    pub results: BTreeMap<String, Tensor>,
    /// Batch placement (extraction replies).
    pub meta: Option<BatchMeta>,
    /// `backpack-metrics/v1` window/aggregates, when requested.
    pub metrics: Option<Json>,
}

impl ExtractReply {
    /// Parse one reply payload.
    pub fn parse(text: &str) -> Result<ExtractReply> {
        let v = Json::parse(text).context("reply is not JSON")?;
        let id = get_u64(&v, "id")?;
        let ok = v.get("ok")?.as_bool()?;
        let error = match v.opt("error") {
            Some(e) => Some(e.as_str()?.to_string()),
            None => None,
        };
        let mut results = BTreeMap::new();
        if let Some(r) = v.opt("results") {
            for (k, t) in r.as_obj()? {
                results.insert(k.clone(), tensor_from_json(t)?);
            }
        }
        let meta = match v.opt("meta") {
            Some(m) => Some(BatchMeta {
                batch_n: m.get("batch_n")?.as_usize()?,
                coalesced: m.get("coalesced")?.as_usize()?,
                offset: m.get("offset")?.as_usize()?,
                n: m.get("n")?.as_usize()?,
            }),
            None => None,
        };
        let metrics = v.opt("metrics").cloned();
        Ok(ExtractReply { id, ok, error, results, meta, metrics })
    }
}

/// One structured access-log record ([`ACCESS_SCHEMA`]): the full
/// lifecycle of one extract request, written as a single JSON line
/// when the daemon runs with `--access-log FILE`.
///
/// Stage micros follow the request lifecycle
/// `accept -> queue-pop -> linger-close -> extract-done ->
/// reply-written`; a stage is `None` when the request never reached
/// it (a rejected request has no `extract_us`, a disconnected client
/// no `reply_us`). The access log is written regardless of
/// `--quiet`: it is the machine-readable channel, `stderr` the
/// human one.
#[derive(Debug, Clone, PartialEq)]
pub struct AccessRecord {
    /// Client correlation id.
    pub id: u64,
    /// Union artifact that ran (`{model}_{sig}_n{batch_n}`); `None`
    /// when the request never reached an engine call.
    pub artifact: Option<String>,
    /// Requested model.
    pub model: String,
    /// Requested signature spelling.
    pub sig: String,
    /// This client's sample count.
    pub n: usize,
    /// Union batch size of the engine call (0 when none ran).
    pub batch_n: usize,
    /// Requests coalesced into the call (0 when none ran).
    pub batch_requests: usize,
    /// True when the request shared its engine call with others.
    pub coalesced: bool,
    /// `ok` | `error` | `rejected` | `disconnect`.
    pub outcome: String,
    /// accept -> queue-pop (includes any backpressure wait).
    pub queue_us: Option<u64>,
    /// queue-pop -> linger-close (batch gathering window).
    pub linger_us: Option<u64>,
    /// linger-close -> extract-done (the engine call).
    pub extract_us: Option<u64>,
    /// extract-done -> reply-written (serialize + socket write).
    pub reply_us: Option<u64>,
    /// accept -> last observed stage.
    pub e2e_us: Option<u64>,
    /// Unix epoch milliseconds when the record was written.
    pub ts_ms: u64,
}

impl AccessRecord {
    /// One JSON object (a single access-log line, sans newline).
    pub fn to_json(&self) -> Json {
        let opt_u64 = |v: Option<u64>| match v {
            Some(x) => Json::Num(x as f64),
            None => Json::Null,
        };
        let mut o = BTreeMap::new();
        o.insert(
            "schema".into(),
            Json::Str(ACCESS_SCHEMA.to_string()),
        );
        o.insert("id".into(), Json::Num(self.id as f64));
        o.insert(
            "artifact".into(),
            match &self.artifact {
                Some(a) => Json::Str(a.clone()),
                None => Json::Null,
            },
        );
        o.insert("model".into(), Json::Str(self.model.clone()));
        o.insert("sig".into(), Json::Str(self.sig.clone()));
        o.insert("n".into(), Json::Num(self.n as f64));
        o.insert("batch_n".into(), Json::Num(self.batch_n as f64));
        o.insert(
            "batch_requests".into(),
            Json::Num(self.batch_requests as f64),
        );
        o.insert("coalesced".into(), Json::Bool(self.coalesced));
        o.insert(
            "outcome".into(),
            Json::Str(self.outcome.clone()),
        );
        o.insert("queue_us".into(), opt_u64(self.queue_us));
        o.insert("linger_us".into(), opt_u64(self.linger_us));
        o.insert("extract_us".into(), opt_u64(self.extract_us));
        o.insert("reply_us".into(), opt_u64(self.reply_us));
        o.insert("e2e_us".into(), opt_u64(self.e2e_us));
        o.insert("ts_ms".into(), Json::Num(self.ts_ms as f64));
        Json::Obj(o)
    }

    /// Parse one access-log line (validates the schema field).
    pub fn parse(text: &str) -> Result<AccessRecord> {
        let v = Json::parse(text)
            .context("access record is not JSON")?;
        let schema = v.get("schema")?.as_str()?;
        ensure!(
            schema == ACCESS_SCHEMA,
            "access record schema {schema:?} != {ACCESS_SCHEMA:?}"
        );
        let opt_u64 = |key: &str| -> Result<Option<u64>> {
            match v.opt(key) {
                None | Some(Json::Null) => Ok(None),
                Some(_) => Ok(Some(get_u64(&v, key)?)),
            }
        };
        Ok(AccessRecord {
            id: get_u64(&v, "id")?,
            artifact: match v.get("artifact")? {
                Json::Null => None,
                a => Some(a.as_str()?.to_string()),
            },
            model: v.get("model")?.as_str()?.to_string(),
            sig: v.get("sig")?.as_str()?.to_string(),
            n: v.get("n")?.as_usize()?,
            batch_n: v.get("batch_n")?.as_usize()?,
            batch_requests: v.get("batch_requests")?.as_usize()?,
            coalesced: v.get("coalesced")?.as_bool()?,
            outcome: v.get("outcome")?.as_str()?.to_string(),
            queue_us: opt_u64("queue_us")?,
            linger_us: opt_u64("linger_us")?,
            extract_us: opt_u64("extract_us")?,
            reply_us: opt_u64("reply_us")?,
            e2e_us: opt_u64("e2e_us")?,
            ts_ms: get_u64(&v, "ts_ms")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_round_trip_over_a_buffer() {
        let mut buf = Vec::new();
        write_frame(&mut buf, "{\"op\":\"ping\",\"id\":1}").unwrap();
        write_frame(&mut buf, "second").unwrap();
        assert_eq!(&buf[..4], &[0, 0, 0, 20]);
        let mut r = &buf[..];
        assert_eq!(
            read_frame(&mut r).unwrap().unwrap(),
            "{\"op\":\"ping\",\"id\":1}"
        );
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), "second");
        // Clean EOF between frames.
        assert!(read_frame(&mut r).unwrap().is_none());
        // EOF inside a frame errors.
        let mut r = &buf[..7];
        assert!(read_frame(&mut r).is_err());
        let mut r = &buf[..2];
        assert!(read_frame(&mut r).is_err());
        // Oversized length prefix rejected without allocating.
        let huge = (MAX_FRAME as u32 + 1).to_be_bytes();
        let mut r = &huge[..];
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn extract_request_round_trips() {
        let req = ExtractRequest {
            id: 7,
            model: "logreg".into(),
            sig: "batch_grad+diag_ggn".parse().unwrap(),
            seed: 3,
            x: vec![0.25, -1.5e-7, f32::NAN],
            y: vec![0, 9, 4],
            key: Some([11, 12]),
            want_metrics: true,
        };
        let parsed = Request::parse(&req.to_json()).unwrap();
        let Request::Extract(got) = parsed else {
            panic!("not an extract request")
        };
        assert_eq!(got.id, req.id);
        assert_eq!(got.model, req.model);
        assert_eq!(got.sig, req.sig);
        assert_eq!(got.seed, req.seed);
        assert_eq!(got.y, req.y);
        assert_eq!(got.key, req.key);
        assert!(got.want_metrics);
        // Finite values round-trip bitwise; NaN survives as NaN.
        assert_eq!(got.x[..2], req.x[..2]);
        assert!(got.x[2].is_nan());
    }

    #[test]
    fn control_requests_parse() {
        assert_eq!(
            Request::parse("{\"op\":\"ping\",\"id\":4}").unwrap(),
            Request::Ping { id: 4 }
        );
        assert_eq!(
            Request::parse("{\"op\":\"metrics\",\"id\":0}").unwrap(),
            Request::Metrics { id: 0 }
        );
        assert_eq!(
            Request::parse("{\"op\":\"shutdown\",\"id\":9}").unwrap(),
            Request::Shutdown { id: 9 }
        );
        assert!(Request::parse("{\"op\":\"nope\",\"id\":1}").is_err());
        assert!(Request::parse("{\"id\":1}").is_err());
        assert!(Request::parse("not json").is_err());
        // Bad signature strings fail at parse, not at serve time.
        assert!(Request::parse(
            "{\"op\":\"extract\",\"id\":1,\"model\":\"logreg\",\
             \"sig\":\"grad+\",\"x\":[],\"y\":[]}"
        )
        .is_err());
    }

    #[test]
    fn tensors_round_trip_bitwise() {
        let t = Tensor::from_f32(
            &[2, 3],
            vec![0.1, -2.5e-8, 3.0, f32::NAN, f32::INFINITY, 0.0],
        );
        let back =
            tensor_from_json(&tensor_to_json(&t)).unwrap();
        assert_eq!(back.shape, vec![2, 3]);
        let (a, b) = (t.f32s().unwrap(), back.f32s().unwrap());
        for (u, v) in a.iter().zip(b) {
            if u.is_finite() {
                assert_eq!(u.to_bits(), v.to_bits(), "{u} vs {v}");
            } else {
                // Non-finite flattens to null -> NaN.
                assert!(v.is_nan());
            }
        }
        // Shape/data mismatch rejected.
        assert!(tensor_from_json(
            &Json::parse("{\"shape\":[3],\"data\":[1,2]}").unwrap()
        )
        .is_err());
    }

    #[test]
    fn replies_round_trip() {
        let mut results = BTreeMap::new();
        results.insert(
            "grad/0/w".to_string(),
            Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]),
        );
        let meta =
            BatchMeta { batch_n: 16, coalesced: 4, offset: 4, n: 4 };
        let text = extract_reply(3, &results, meta, None);
        let r = ExtractReply::parse(&text).unwrap();
        assert!(r.ok && r.error.is_none());
        assert_eq!(r.id, 3);
        assert_eq!(r.meta, Some(meta));
        assert_eq!(r.results["grad/0/w"].shape, vec![2, 2]);
        assert!(r.metrics.is_none());

        let r =
            ExtractReply::parse(&error_reply(8, "nope")).unwrap();
        assert!(!r.ok);
        assert_eq!(r.id, 8);
        assert_eq!(r.error.as_deref(), Some("nope"));

        let r = ExtractReply::parse(&pong_reply(1)).unwrap();
        assert!(r.ok && r.results.is_empty());
    }

    #[test]
    fn busy_reply_is_a_parseable_error_frame() {
        let r =
            ExtractReply::parse(&busy_reply(4)).unwrap();
        assert!(!r.ok);
        assert_eq!(r.id, 0);
        let msg = r.error.unwrap();
        assert!(msg.contains("server_busy"), "{msg}");
        assert!(msg.contains('4'), "{msg}");
    }

    #[test]
    fn access_records_round_trip() {
        let rec = AccessRecord {
            id: 42,
            artifact: Some("logreg_grad_n16".to_string()),
            model: "logreg".to_string(),
            sig: "grad".to_string(),
            n: 4,
            batch_n: 16,
            batch_requests: 4,
            coalesced: true,
            outcome: "ok".to_string(),
            queue_us: Some(120),
            linger_us: Some(2000),
            extract_us: Some(850),
            reply_us: Some(40),
            e2e_us: Some(3010),
            ts_ms: 1_700_000_000_123,
        };
        let line = rec.to_json().to_string_json();
        assert_eq!(AccessRecord::parse(&line).unwrap(), rec);

        // A request that never ran: optional stages null out.
        let rejected = AccessRecord {
            artifact: None,
            batch_n: 0,
            batch_requests: 0,
            coalesced: false,
            outcome: "rejected".to_string(),
            linger_us: None,
            extract_us: None,
            reply_us: None,
            ..rec.clone()
        };
        let line = rejected.to_json().to_string_json();
        assert!(line.contains("\"extract_us\":null"), "{line}");
        assert_eq!(AccessRecord::parse(&line).unwrap(), rejected);

        // Wrong schema is refused.
        let other =
            line.replace(ACCESS_SCHEMA, "backpack-access/v0");
        assert!(AccessRecord::parse(&other).is_err());
    }
}
