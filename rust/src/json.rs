//! Minimal JSON parser + writer (substrate; no serde offline).
//!
//! Covers the full JSON grammar needed by `artifacts/manifest.json` and
//! the result files the coordinator writes: objects, arrays, strings
//! with escapes, numbers, booleans, null. Numbers are parsed as f64
//! (manifest shapes are small integers, exactly representable).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Serialize (compact). Strings are escaped per RFC 8259.
    pub fn to_string_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| {
            anyhow!("bad number {s:?} at byte {start}: {e}")
        })?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4])?;
                            self.i += 4;
                            let code = u32::from_str_radix(hex, 16)?;
                            s.push(
                                char::from_u32(code)
                                    .unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-sync to char boundary for multi-byte UTF-8.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(
                        &self.b[start..self.i])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' got {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' got {:?}", c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#)
            .unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize().unwrap(), 2);
        assert!(!arr[2].get("b").unwrap().as_bool().unwrap());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"x":[1,2.5,null,true,"s\"q"],"y":{"z":[]}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string_json()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse("\"caf\u{e9} \\u00e9\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café é");
    }
}
