//! Tiny CLI argument parser (substrate; clap is unavailable offline).
//!
//! Grammar: `binary SUBCOMMAND [--flag value] [--switch] [positional]`.
//! Values may also be attached as `--flag=value`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Flags that never take a value (needed to disambiguate
/// `--verbose positional` without clap-style per-command schemas).
const BOOL_SWITCHES: &[&str] = &[
    "verbose", "help", "force", "quiet", "quick", "metrics", "stdio",
    "kernels",
];

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    pub fn parse_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = it.into_iter().peekable();
        if let Some(first) = iter.peek() {
            if !first.starts_with("--") {
                args.subcommand = iter.next().unwrap();
            }
        }
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if BOOL_SWITCHES.contains(&name) {
                    args.switches.push(name.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.flags.insert(name.to_string(), v);
                } else {
                    args.switches.push(name.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    pub fn get_f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: bad float {v:?}")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: bad int {v:?}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: bad int {v:?}")),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
            || self.flags.contains_key(name)
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        match self.flag(name) {
            Some(v) => Ok(v),
            None => bail!("missing required flag --{name}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = parse(
            "train --problem mnist_logreg --lr 0.01 --verbose pos1",
        );
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.flag("problem"), Some("mnist_logreg"));
        assert_eq!(a.get_f32("lr", 0.0).unwrap(), 0.01);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("x --steps=40");
        assert_eq!(a.get_usize("steps", 0).unwrap(), 40);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("x --lr abc");
        assert_eq!(a.get_usize("steps", 7).unwrap(), 7);
        assert!(a.get_f32("lr", 0.0).is_err());
        assert!(a.require("missing").is_err());
    }
}
