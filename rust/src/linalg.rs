//! Dense linear-algebra substrate for the Kronecker-factored update
//! (paper Eq. 27-29): Cholesky factorization + triangular solves, used
//! to apply `(A + πγI)⁻¹ ⊗ (B + γ/π I)⁻¹` to gradients.
//!
//! Matrices are row-major `Vec<f32>`; sizes are the Kronecker-factor
//! dimensions (≤ ~1.7k for All-CNN-C), where a cache-blocked scalar
//! Cholesky is adequate. The dense `matmul*` kernels below dominate
//! the native backend's hot call sites; they are cache-blocked
//! (`BLOCK`) and have `*_par` row-split variants (see
//! `crate::parallel`) that are bit-for-bit equal to the serial
//! kernels for any thread count.

use anyhow::{bail, Result};

/// Row-major square matrix view helpers.
#[derive(Debug, Clone)]
pub struct SymMat {
    pub n: usize,
    pub a: Vec<f32>,
}

impl SymMat {
    pub fn new(n: usize, a: Vec<f32>) -> SymMat {
        assert_eq!(a.len(), n * n);
        SymMat { n, a }
    }

    pub fn identity(n: usize) -> SymMat {
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        SymMat { n, a }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.a[i * self.n + j]
    }

    pub fn trace(&self) -> f32 {
        (0..self.n).map(|i| self.at(i, i)).sum()
    }

    /// `self + d * I` (damping).
    pub fn add_diag(&self, d: f32) -> SymMat {
        let mut out = self.clone();
        for i in 0..self.n {
            out.a[i * self.n + i] += d;
        }
        out
    }
}

/// Lower-triangular Cholesky factor L with `L Lᵀ = A`.
#[derive(Debug, Clone)]
pub struct Cholesky {
    pub n: usize,
    l: Vec<f32>,
}

impl Cholesky {
    /// Factorize a symmetric positive-definite matrix. Fails (rather
    /// than silently regularizing) on non-PD input -- callers add the
    /// damping term first, which also guarantees PD for PSD curvature.
    pub fn factor(m: &SymMat) -> Result<Cholesky> {
        let n = m.n;
        let mut l = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..=i {
                // sum_{k<j} L[i,k] L[j,k] as a slice dot product --
                // LLVM auto-vectorizes this f32 loop (perf pass L3:
                // ~3.5x over the scalar f64-accumulating original on
                // the 784..1728 factor sizes; damped SPD curvature is
                // insensitive to f32 accumulation, cf. unit tests).
                let (ri, rj) = (i * n, j * n);
                let s: f32 = l[ri..ri + j]
                    .iter()
                    .zip(&l[rj..rj + j])
                    .map(|(a, b)| a * b)
                    .sum();
                let v = m.at(i, j) - s;
                if i == j {
                    if v <= 0.0 {
                        bail!(
                            "matrix not positive definite at pivot {i} \
                             (value {v:.3e}); increase damping"
                        );
                    }
                    l[ri + j] = v.sqrt();
                } else {
                    l[ri + j] = v / l[rj + j];
                }
            }
        }
        Ok(Cholesky { n, l })
    }

    /// Solve `A x = b` in place for one right-hand side.
    pub fn solve_vec(&self, b: &mut [f32]) {
        let (n, l) = (self.n, &self.l);
        assert_eq!(b.len(), n);
        // forward: L y = b
        for i in 0..n {
            let mut s = b[i] as f64;
            for k in 0..i {
                s -= l[i * n + k] as f64 * b[k] as f64;
            }
            b[i] = (s / l[i * n + i] as f64) as f32;
        }
        // backward: Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = b[i] as f64;
            for k in i + 1..n {
                s -= l[k * n + i] as f64 * b[k] as f64;
            }
            b[i] = (s / l[i * n + i] as f64) as f32;
        }
    }

    /// Solve `A X = B` where B is [n, m] row-major (columns are RHSs).
    pub fn solve_mat_left(&self, b: &mut [f32], m: usize) {
        let n = self.n;
        assert_eq!(b.len(), n * m);
        let l = &self.l;
        // forward, all columns at once (row-major friendly)
        for i in 0..n {
            for k in 0..i {
                let lik = l[i * n + k];
                if lik != 0.0 {
                    let (rk, ri) = (k * m, i * m);
                    for c in 0..m {
                        b[ri + c] -= lik * b[rk + c];
                    }
                }
            }
            let d = 1.0 / l[i * n + i];
            for c in 0..m {
                b[i * m + c] *= d;
            }
        }
        for i in (0..n).rev() {
            for k in i + 1..n {
                let lki = l[k * n + i];
                if lki != 0.0 {
                    let (rk, ri) = (k * m, i * m);
                    for c in 0..m {
                        b[ri + c] -= lki * b[rk + c];
                    }
                }
            }
            let d = 1.0 / l[i * n + i];
            for c in 0..m {
                b[i * m + c] *= d;
            }
        }
    }

    /// Solve `X A = B` for X, where B is [m, n] row-major (rows are
    /// RHSs of Aᵀ = A).
    pub fn solve_mat_right(&self, b: &mut [f32], m: usize) {
        let n = self.n;
        assert_eq!(b.len(), m * n);
        for r in 0..m {
            self.solve_vec(&mut b[r * n..(r + 1) * n]);
        }
    }
}

/// Cache-block edge for the dense kernels: 64x64 f32 tiles (16 KiB)
/// keep an output tile plus an operand panel L1/L2-resident at the
/// native backend's hot shapes (din up to 784, dout up to 128, batch
/// shards up to 128). Blocks are visited in index order, so per-element
/// accumulation order -- and therefore the f32 result -- is identical
/// to the unblocked kernels.
const BLOCK: usize = 64;

/// Work threshold (multiply-adds) below which the `*_par` kernels stay
/// serial: under ~1 Mflop the scoped-thread fork/join overhead beats
/// the speedup.
const PAR_MIN_MACS: usize = 1 << 20;

/// Credit one dense contraction (`macs` multiply-adds = 2x FLOPs) to
/// the observability counter. Every matmul entry point below reports
/// here exactly once: the serial functions at their head, the `*_par`
/// drivers only on the parallel path (their serial fallback delegates
/// to a counting function).
#[inline]
fn count_macs(macs: usize) {
    crate::obs::add(crate::obs::Counter::MatmulFlops, 2 * macs as u64);
}

/// Dense `C = Aᵀ B` with a shared leading (batch) axis: A is [n, p],
/// B is [n, q], C is [p, q] -- the contraction the native backend's
/// gradient/factor extractions reduce to (mirror of the Python
/// `ops.matmul_tn` kernel). Cache-blocked over all three axes; inner
/// loops stream rows of B and C.
pub fn matmul_tn(
    a: &[f32], b: &[f32], n: usize, p: usize, q: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), n * p);
    assert_eq!(b.len(), n * q);
    count_macs(n * p * q);
    let mut c = vec![0.0f32; p * q];
    matmul_tn_rows(a, b, n, p, q, 0..p, &mut c);
    c
}

/// Row slab `C[rows, :] = (Aᵀ B)[rows, :]` of [`matmul_tn`], written
/// into `c` (len `rows.len() * q`). The shared building block of the
/// serial and parallel drivers.
fn matmul_tn_rows(
    a: &[f32],
    b: &[f32],
    n: usize,
    p: usize,
    q: usize,
    rows: std::ops::Range<usize>,
    c: &mut [f32],
) {
    assert_eq!(c.len(), rows.len() * q);
    let i_off = rows.start;
    for s0 in (0..n).step_by(BLOCK) {
        let s1 = (s0 + BLOCK).min(n);
        for i0 in (rows.start..rows.end).step_by(BLOCK) {
            let i1 = (i0 + BLOCK).min(rows.end);
            for j0 in (0..q).step_by(BLOCK) {
                let j1 = (j0 + BLOCK).min(q);
                for s in s0..s1 {
                    let (ra, rb) = (s * p, s * q);
                    for i in i0..i1 {
                        let av = a[ra + i];
                        if av != 0.0 {
                            let rc = (i - i_off) * q;
                            for j in j0..j1 {
                                c[rc + j] += av * b[rb + j];
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Shared driver of the `*_par` kernels: split the `p` output rows
/// into per-thread slabs, run `kernel` on each slab's sub-buffer, and
/// concatenate in slab order. Each thread owns a disjoint row slab,
/// so the result is bit-for-bit identical to the serial kernel.
fn par_rows<K>(p: usize, q: usize, threads: usize, kernel: K) -> Vec<f32>
where
    K: Fn(std::ops::Range<usize>, &mut [f32]) + Sync,
{
    let slabs = crate::parallel::shards(p, threads);
    let parts = crate::parallel::par_map(&slabs, |rows| {
        let mut c = vec![0.0f32; rows.len() * q];
        kernel(rows, &mut c);
        c
    });
    let mut c = Vec::with_capacity(p * q);
    for part in parts {
        c.extend_from_slice(&part);
    }
    c
}

/// [`matmul_tn`] with the output rows split across `threads` scoped
/// threads (bit-for-bit identical to serial; serial below
/// `PAR_MIN_MACS`).
pub fn matmul_tn_par(
    a: &[f32], b: &[f32], n: usize, p: usize, q: usize, threads: usize,
) -> Vec<f32> {
    if threads <= 1 || n * p * q < PAR_MIN_MACS {
        return matmul_tn(a, b, n, p, q);
    }
    assert_eq!(a.len(), n * p);
    assert_eq!(b.len(), n * q);
    count_macs(n * p * q);
    par_rows(p, q, threads, |rows, c| {
        matmul_tn_rows(a, b, n, p, q, rows, c)
    })
}

/// Dense `C = A Bᵀ` (row-major, [p,n]x[q,n] -> [p,q]): rows of both
/// operands are contracted as dot products, tiled so a panel of B rows
/// stays cache-resident across the A rows of a block.
pub fn matmul_nt(
    a: &[f32], b: &[f32], p: usize, n: usize, q: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), p * n);
    assert_eq!(b.len(), q * n);
    count_macs(p * n * q);
    let mut c = vec![0.0f32; p * q];
    matmul_nt_rows(a, b, n, q, 0..p, &mut c);
    c
}

/// Row slab `C[rows, :] = (A Bᵀ)[rows, :]` of [`matmul_nt`].
fn matmul_nt_rows(
    a: &[f32],
    b: &[f32],
    n: usize,
    q: usize,
    rows: std::ops::Range<usize>,
    c: &mut [f32],
) {
    assert_eq!(c.len(), rows.len() * q);
    let i_off = rows.start;
    for i0 in (rows.start..rows.end).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(rows.end);
        for j0 in (0..q).step_by(BLOCK) {
            let j1 = (j0 + BLOCK).min(q);
            for i in i0..i1 {
                let ra = i * n;
                let rc = (i - i_off) * q;
                for j in j0..j1 {
                    let rb = j * n;
                    let s: f32 = a[ra..ra + n]
                        .iter()
                        .zip(&b[rb..rb + n])
                        .map(|(x, y)| x * y)
                        .sum();
                    c[rc + j] = s;
                }
            }
        }
    }
}

/// [`matmul_nt`] with the output rows split across scoped threads
/// (bit-for-bit identical to serial; serial below `PAR_MIN_MACS`).
pub fn matmul_nt_par(
    a: &[f32], b: &[f32], p: usize, n: usize, q: usize, threads: usize,
) -> Vec<f32> {
    if threads <= 1 || p * n * q < PAR_MIN_MACS {
        return matmul_nt(a, b, p, n, q);
    }
    assert_eq!(a.len(), p * n);
    assert_eq!(b.len(), q * n);
    count_macs(p * n * q);
    par_rows(p, q, threads, |rows, c| {
        matmul_nt_rows(a, b, n, q, rows, c)
    })
}

/// Dense `C = A B` (row-major, [p,q]x[q,r]), tiled so a panel of B
/// rows is reused across the A rows of a block.
pub fn matmul(a: &[f32], b: &[f32], p: usize, q: usize, r: usize) -> Vec<f32> {
    assert_eq!(a.len(), p * q);
    assert_eq!(b.len(), q * r);
    count_macs(p * q * r);
    let mut c = vec![0.0f32; p * r];
    matmul_rows(a, b, q, r, 0..p, &mut c);
    c
}

/// Row slab `C[rows, :] = (A B)[rows, :]` of [`matmul`].
fn matmul_rows(
    a: &[f32],
    b: &[f32],
    q: usize,
    r: usize,
    rows: std::ops::Range<usize>,
    c: &mut [f32],
) {
    assert_eq!(c.len(), rows.len() * r);
    let i_off = rows.start;
    for i0 in (rows.start..rows.end).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(rows.end);
        for k0 in (0..q).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(q);
            for i in i0..i1 {
                let crow = (i - i_off) * r;
                for k in k0..k1 {
                    let aik = a[i * q + k];
                    if aik != 0.0 {
                        let brow = k * r;
                        for j in 0..r {
                            c[crow + j] += aik * b[brow + j];
                        }
                    }
                }
            }
        }
    }
}

/// [`matmul`] with the output rows split across scoped threads
/// (bit-for-bit identical to serial; serial below `PAR_MIN_MACS`).
pub fn matmul_par(
    a: &[f32], b: &[f32], p: usize, q: usize, r: usize, threads: usize,
) -> Vec<f32> {
    if threads <= 1 || p * q * r < PAR_MIN_MACS {
        return matmul(a, b, p, q, r);
    }
    assert_eq!(a.len(), p * q);
    assert_eq!(b.len(), q * r);
    count_macs(p * q * r);
    par_rows(p, r, threads, |rows, c| {
        matmul_rows(a, b, q, r, rows, c)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    fn random_spd(n: usize, seed: u64) -> SymMat {
        let mut rng = Rng::new(seed);
        let g: Vec<f32> = (0..n * n).map(|_| rng.normal()).collect();
        // A = G Gᵀ / n + 0.5 I  (definitely SPD)
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += g[i * n + k] * g[j * n + k];
                }
                a[i * n + j] = s / n as f32;
            }
        }
        for i in 0..n {
            a[i * n + i] += 0.5;
        }
        SymMat::new(n, a)
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(12, 1);
        let ch = Cholesky::factor(&a).unwrap();
        for i in 0..a.n {
            for j in 0..a.n {
                let mut s = 0.0;
                for k in 0..a.n {
                    s += ch.l[i * a.n + k] * ch.l[j * a.n + k];
                }
                assert!((s - a.at(i, j)).abs() < 1e-4,
                        "LLᵀ[{i},{j}]={s} != {}", a.at(i, j));
            }
        }
    }

    #[test]
    fn solve_vec_correct() {
        let a = random_spd(15, 2);
        let ch = Cholesky::factor(&a).unwrap();
        let mut rng = Rng::new(3);
        let x_true: Vec<f32> = (0..15).map(|_| rng.normal()).collect();
        let mut b = vec![0.0f32; 15];
        for i in 0..15 {
            for j in 0..15 {
                b[i] += a.at(i, j) * x_true[j];
            }
        }
        ch.solve_vec(&mut b);
        for i in 0..15 {
            assert!((b[i] - x_true[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn solve_mat_left_matches_vec() {
        let a = random_spd(9, 4);
        let ch = Cholesky::factor(&a).unwrap();
        let mut rng = Rng::new(5);
        let b: Vec<f32> = (0..9 * 4).map(|_| rng.normal()).collect();
        let mut m = b.clone();
        ch.solve_mat_left(&mut m, 4);
        for c in 0..4 {
            let mut col: Vec<f32> = (0..9).map(|i| b[i * 4 + c]).collect();
            ch.solve_vec(&mut col);
            for i in 0..9 {
                assert!((m[i * 4 + c] - col[i]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn solve_mat_right_is_right_division() {
        // X A = B  =>  X = B A⁻¹; verify X A ≈ B.
        let a = random_spd(7, 6);
        let ch = Cholesky::factor(&a).unwrap();
        let mut rng = Rng::new(7);
        let b: Vec<f32> = (0..3 * 7).map(|_| rng.normal()).collect();
        let mut x = b.clone();
        ch.solve_mat_right(&mut x, 3);
        let back = matmul(&x, &a.a, 3, 7, 7);
        for (u, v) in back.iter().zip(&b) {
            assert!((u - v).abs() < 1e-3);
        }
    }

    #[test]
    fn matmul_variants_agree_with_plain_matmul() {
        let mut rng = Rng::new(9);
        let (n, p, q) = (5, 3, 4);
        let a: Vec<f32> = (0..n * p).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..n * q).map(|_| rng.normal()).collect();
        // Aᵀ B via explicit transpose + matmul.
        let mut at = vec![0.0f32; p * n];
        for s in 0..n {
            for i in 0..p {
                at[i * n + s] = a[s * p + i];
            }
        }
        let want = matmul(&at, &b, p, n, q);
        let got = matmul_tn(&a, &b, n, p, q);
        for (u, v) in got.iter().zip(&want) {
            assert!((u - v).abs() < 1e-5);
        }
        // A Bᵀ via explicit transpose + matmul.
        let c: Vec<f32> = (0..p * n).map(|_| rng.normal()).collect();
        let d: Vec<f32> = (0..q * n).map(|_| rng.normal()).collect();
        let mut dt = vec![0.0f32; n * q];
        for j in 0..q {
            for s in 0..n {
                dt[s * q + j] = d[j * n + s];
            }
        }
        let want = matmul(&c, &dt, p, n, q);
        let got = matmul_nt(&c, &d, p, n, q);
        for (u, v) in got.iter().zip(&want) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    /// Unblocked reference kernels: the shapes in
    /// `blocked_kernels_match_reference` cross the 64-wide BLOCK edge,
    /// so any tiling mistake (wrong offset, dropped remainder tile)
    /// shows up against these.
    fn ref_tn(a: &[f32], b: &[f32], n: usize, p: usize, q: usize)
        -> Vec<f32> {
        let mut c = vec![0.0f32; p * q];
        for s in 0..n {
            for i in 0..p {
                for j in 0..q {
                    c[i * q + j] += a[s * p + i] * b[s * q + j];
                }
            }
        }
        c
    }

    fn ref_nn(a: &[f32], b: &[f32], p: usize, q: usize, r: usize)
        -> Vec<f32> {
        let mut c = vec![0.0f32; p * r];
        for i in 0..p {
            for j in 0..r {
                for k in 0..q {
                    c[i * r + j] += a[i * q + k] * b[k * r + j];
                }
            }
        }
        c
    }

    #[test]
    fn blocked_kernels_match_reference_across_block_edges() {
        let mut rng = Rng::new(11);
        // Deliberately awkward sizes: 1 under, on, and over BLOCK.
        let (n, p, q) = (67, 65, 130);
        let a: Vec<f32> = (0..n * p).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..n * q).map(|_| rng.normal()).collect();
        let want = ref_tn(&a, &b, n, p, q);
        for (u, v) in matmul_tn(&a, &b, n, p, q).iter().zip(&want) {
            assert!((u - v).abs() < 1e-3 * (1.0 + v.abs()));
        }
        let c: Vec<f32> = (0..p * n).map(|_| rng.normal()).collect();
        let d: Vec<f32> = (0..n * q).map(|_| rng.normal()).collect();
        let want = ref_nn(&c, &d, p, n, q);
        for (u, v) in matmul(&c, &d, p, n, q).iter().zip(&want) {
            assert!((u - v).abs() < 1e-3 * (1.0 + v.abs()));
        }
        // A Bᵀ against A (Bᵀ) via the plain kernel.
        let e: Vec<f32> = (0..q * n).map(|_| rng.normal()).collect();
        let mut et = vec![0.0f32; n * q];
        for j in 0..q {
            for s in 0..n {
                et[s * q + j] = e[j * n + s];
            }
        }
        let want = ref_nn(&c, &et, p, n, q);
        for (u, v) in matmul_nt(&c, &e, p, n, q).iter().zip(&want) {
            assert!((u - v).abs() < 1e-3 * (1.0 + v.abs()));
        }
    }

    #[test]
    fn par_kernels_are_bitwise_equal_to_serial() {
        let mut rng = Rng::new(13);
        // Big enough to clear PAR_MIN_MACS (130*129*131 > 2^20).
        let (n, p, q) = (130, 129, 131);
        let a: Vec<f32> = (0..n * p).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..n * q).map(|_| rng.normal()).collect();
        for t in [1usize, 2, 3, 5] {
            assert_eq!(
                matmul_tn_par(&a, &b, n, p, q, t),
                matmul_tn(&a, &b, n, p, q),
                "tn t={t}"
            );
        }
        let c: Vec<f32> = (0..p * n).map(|_| rng.normal()).collect();
        let d: Vec<f32> = (0..q * n).map(|_| rng.normal()).collect();
        assert_eq!(
            matmul_nt_par(&c, &d, p, n, q, 3),
            matmul_nt(&c, &d, p, n, q)
        );
        let e: Vec<f32> = (0..n * q).map(|_| rng.normal()).collect();
        assert_eq!(
            matmul_par(&c, &e, p, n, q, 3),
            matmul(&c, &e, p, n, q)
        );
    }

    #[test]
    fn non_pd_rejected() {
        let m = SymMat::new(2, vec![1.0, 2.0, 2.0, 1.0]); // eig -1, 3
        assert!(Cholesky::factor(&m).is_err());
    }

    #[test]
    fn add_diag_and_trace() {
        let m = SymMat::identity(3).add_diag(2.0);
        assert_eq!(m.trace(), 9.0);
    }
}
