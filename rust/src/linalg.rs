//! Dense linear-algebra substrate for the Kronecker-factored update
//! (paper Eq. 27-29): Cholesky factorization + triangular solves, used
//! to apply `(A + πγI)⁻¹ ⊗ (B + γ/π I)⁻¹` to gradients.
//!
//! Matrices are row-major `Vec<f32>`; sizes are the Kronecker-factor
//! dimensions (≤ ~1.7k for All-CNN-C), where a cache-blocked scalar
//! Cholesky is adequate. The dense `matmul*` kernels below dominate
//! the native backend's hot call sites; they are cache-blocked
//! (`BLOCK`) with an explicit 8-lane SIMD inner microkernel on
//! x86_64 (AVX2 + FMA, selected once at runtime with a scalar
//! fallback — see [`simd_active`]) and have `*_par` row-split
//! variants (see `crate::parallel`) that are bit-for-bit equal to the
//! serial kernels for any thread count.
//!
//! Numerical contract of the SIMD path (DESIGN.md §14): the axpy-form
//! kernels (`matmul`, `matmul_tn`) keep the per-element accumulation
//! *order* of the scalar kernels and differ only by FMA's single
//! rounding, and the dot-form kernel (`matmul_nt`) reduces in 8
//! interleaved lanes; both are within ~1e-5 relative of the retained
//! scalar reference (`matmul_scalar` & friends, pinned by
//! `tests/proptests.rs`), and every kernel is deterministic: the same
//! inputs give bit-identical outputs on every call.

use anyhow::{bail, Result};
use std::ops::Range;

/// Row-major square matrix view helpers.
#[derive(Debug, Clone)]
pub struct SymMat {
    pub n: usize,
    pub a: Vec<f32>,
}

impl SymMat {
    pub fn new(n: usize, a: Vec<f32>) -> SymMat {
        assert_eq!(a.len(), n * n);
        SymMat { n, a }
    }

    pub fn identity(n: usize) -> SymMat {
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        SymMat { n, a }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.a[i * self.n + j]
    }

    pub fn trace(&self) -> f32 {
        (0..self.n).map(|i| self.at(i, i)).sum()
    }

    /// `self + d * I` (damping).
    pub fn add_diag(&self, d: f32) -> SymMat {
        let mut out = self.clone();
        for i in 0..self.n {
            out.a[i * self.n + i] += d;
        }
        out
    }
}

/// Lower-triangular Cholesky factor L with `L Lᵀ = A`.
#[derive(Debug, Clone)]
pub struct Cholesky {
    pub n: usize,
    l: Vec<f32>,
}

impl Cholesky {
    /// Factorize a symmetric positive-definite matrix. Fails (rather
    /// than silently regularizing) on non-PD input -- callers add the
    /// damping term first, which also guarantees PD for PSD curvature.
    pub fn factor(m: &SymMat) -> Result<Cholesky> {
        let n = m.n;
        let mut l = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..=i {
                // sum_{k<j} L[i,k] L[j,k] as a slice dot product --
                // LLVM auto-vectorizes this f32 loop (perf pass L3:
                // ~3.5x over the scalar f64-accumulating original on
                // the 784..1728 factor sizes; damped SPD curvature is
                // insensitive to f32 accumulation, cf. unit tests).
                let (ri, rj) = (i * n, j * n);
                let s: f32 = l[ri..ri + j]
                    .iter()
                    .zip(&l[rj..rj + j])
                    .map(|(a, b)| a * b)
                    .sum();
                let v = m.at(i, j) - s;
                if i == j {
                    if v <= 0.0 {
                        bail!(
                            "matrix not positive definite at pivot {i} \
                             (value {v:.3e}); increase damping"
                        );
                    }
                    l[ri + j] = v.sqrt();
                } else {
                    l[ri + j] = v / l[rj + j];
                }
            }
        }
        Ok(Cholesky { n, l })
    }

    /// Solve `A x = b` in place for one right-hand side.
    pub fn solve_vec(&self, b: &mut [f32]) {
        let (n, l) = (self.n, &self.l);
        assert_eq!(b.len(), n);
        // forward: L y = b
        for i in 0..n {
            let mut s = b[i] as f64;
            for k in 0..i {
                s -= l[i * n + k] as f64 * b[k] as f64;
            }
            b[i] = (s / l[i * n + i] as f64) as f32;
        }
        // backward: Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = b[i] as f64;
            for k in i + 1..n {
                s -= l[k * n + i] as f64 * b[k] as f64;
            }
            b[i] = (s / l[i * n + i] as f64) as f32;
        }
    }

    /// Solve `A X = B` where B is [n, m] row-major (columns are RHSs).
    pub fn solve_mat_left(&self, b: &mut [f32], m: usize) {
        let n = self.n;
        assert_eq!(b.len(), n * m);
        let l = &self.l;
        // forward, all columns at once (row-major friendly)
        for i in 0..n {
            for k in 0..i {
                let lik = l[i * n + k];
                if lik != 0.0 {
                    let (rk, ri) = (k * m, i * m);
                    for c in 0..m {
                        b[ri + c] -= lik * b[rk + c];
                    }
                }
            }
            let d = 1.0 / l[i * n + i];
            for c in 0..m {
                b[i * m + c] *= d;
            }
        }
        for i in (0..n).rev() {
            for k in i + 1..n {
                let lki = l[k * n + i];
                if lki != 0.0 {
                    let (rk, ri) = (k * m, i * m);
                    for c in 0..m {
                        b[ri + c] -= lki * b[rk + c];
                    }
                }
            }
            let d = 1.0 / l[i * n + i];
            for c in 0..m {
                b[i * m + c] *= d;
            }
        }
    }

    /// Solve `X A = B` for X, where B is [m, n] row-major (rows are
    /// RHSs of Aᵀ = A).
    pub fn solve_mat_right(&self, b: &mut [f32], m: usize) {
        let n = self.n;
        assert_eq!(b.len(), m * n);
        for r in 0..m {
            self.solve_vec(&mut b[r * n..(r + 1) * n]);
        }
    }
}

/// Cache-block edge for the dense kernels: 64x64 f32 tiles (16 KiB)
/// keep an output tile plus an operand panel L1/L2-resident at the
/// native backend's hot shapes (din up to 784, dout up to 128, batch
/// shards up to 128). Blocks are visited in index order, so per-element
/// accumulation order -- and therefore the f32 result up to FMA
/// contraction on the SIMD path -- is identical to the unblocked
/// kernels.
const BLOCK: usize = 64;

/// Work threshold (multiply-adds) below which the `*_par` kernels stay
/// serial: under ~1 Mflop handing shards to the worker pool costs more
/// than the speedup.
const PAR_MIN_MACS: usize = 1 << 20;

/// Credit one dense contraction (`macs` multiply-adds = 2x FLOPs) to
/// the observability counter. Every matmul entry point below reports
/// here exactly once: the serial functions at their head, the `*_par`
/// drivers only on the parallel path (their serial fallback delegates
/// to a counting function).
#[inline]
fn count_macs(macs: usize) {
    crate::obs::add(crate::obs::Counter::MatmulFlops, 2 * macs as u64);
}

/// True when the runtime-dispatched matmul kernels run the AVX2+FMA
/// 8-lane microkernels; false on non-x86_64 targets, on CPUs without
/// AVX2/FMA, and when the `BACKPACK_SIMD=0` environment override is
/// set. Decided once on first use and cached for the process (the
/// override is read at that moment, not per call), so serial and
/// pooled callers always agree on the kernel — which is what keeps
/// the `*_par` variants bit-for-bit equal to serial.
pub fn simd_active() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        use std::sync::OnceLock;
        static ACTIVE: OnceLock<bool> = OnceLock::new();
        *ACTIVE.get_or_init(|| {
            let off = std::env::var("BACKPACK_SIMD")
                .map(|v| v.trim() == "0")
                .unwrap_or(false);
            !off && is_x86_feature_detected!("avx2")
                && is_x86_feature_detected!("fma")
        })
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// AVX2 + FMA microkernels (x86_64 only). Each `*_rows` kernel below
/// mirrors its scalar twin's blocked loop nest exactly; only the
/// innermost contraction is replaced by an 8-lane body with a scalar
/// remainder tail. Everything is `#[target_feature]`-gated and only
/// reached through the [`simd_active`] runtime check.
#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::BLOCK;
    use std::arch::x86_64::*;
    use std::ops::Range;

    /// `c[0..len] += av * b[0..len]`: the axpy microkernel shared by
    /// the NN and TN kernels. FMA fuses the multiply-add per element;
    /// accumulation order per output element is unchanged.
    ///
    /// # Safety
    /// `b` and `c` must be valid for `len` reads/writes; caller must
    /// have verified AVX2+FMA support.
    #[target_feature(enable = "avx2", enable = "fma")]
    #[inline]
    unsafe fn axpy(av: f32, b: *const f32, c: *mut f32, len: usize) {
        let va = _mm256_set1_ps(av);
        let mut j = 0;
        while j + 8 <= len {
            let vb = _mm256_loadu_ps(b.add(j));
            let vc = _mm256_loadu_ps(c.add(j));
            _mm256_storeu_ps(c.add(j), _mm256_fmadd_ps(va, vb, vc));
            j += 8;
        }
        while j < len {
            *c.add(j) += av * *b.add(j);
            j += 1;
        }
    }

    /// 8-lane FMA dot product with a horizontal sum at the end (this
    /// *does* re-associate the reduction relative to the scalar zip
    /// sum — hence the 1e-5 property-test tolerance on `matmul_nt`).
    ///
    /// # Safety
    /// `a` and `b` must be valid for `len` reads; caller must have
    /// verified AVX2+FMA support.
    #[target_feature(enable = "avx2", enable = "fma")]
    #[inline]
    unsafe fn dot(a: *const f32, b: *const f32, len: usize) -> f32 {
        let mut acc = _mm256_setzero_ps();
        let mut j = 0;
        while j + 8 <= len {
            let va = _mm256_loadu_ps(a.add(j));
            let vb = _mm256_loadu_ps(b.add(j));
            acc = _mm256_fmadd_ps(va, vb, acc);
            j += 8;
        }
        let lo = _mm256_castps256_ps128(acc);
        let hi = _mm256_extractf128_ps(acc, 1);
        let s4 = _mm_add_ps(lo, hi);
        let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
        let s1 = _mm_add_ss(s2, _mm_shuffle_ps(s2, s2, 1));
        let mut s = _mm_cvtss_f32(s1);
        while j < len {
            s += *a.add(j) * *b.add(j);
            j += 1;
        }
        s
    }

    /// SIMD twin of `matmul_tn_rows_scalar`.
    ///
    /// # Safety
    /// Caller must have verified AVX2+FMA support and the slice-shape
    /// invariants of the dispatching wrapper.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matmul_tn_rows(
        a: &[f32],
        b: &[f32],
        n: usize,
        p: usize,
        q: usize,
        rows: Range<usize>,
        c: &mut [f32],
    ) {
        let i_off = rows.start;
        for s0 in (0..n).step_by(BLOCK) {
            let s1 = (s0 + BLOCK).min(n);
            for i0 in (rows.start..rows.end).step_by(BLOCK) {
                let i1 = (i0 + BLOCK).min(rows.end);
                for j0 in (0..q).step_by(BLOCK) {
                    let j1 = (j0 + BLOCK).min(q);
                    for s in s0..s1 {
                        let (ra, rb) = (s * p, s * q);
                        for i in i0..i1 {
                            let av = a[ra + i];
                            if av != 0.0 {
                                let rc = (i - i_off) * q;
                                axpy(
                                    av,
                                    b.as_ptr().add(rb + j0),
                                    c.as_mut_ptr().add(rc + j0),
                                    j1 - j0,
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    /// SIMD twin of `matmul_nt_rows_scalar` (`acc` selects `+=` over
    /// `=` for the output element, exactly as in the scalar twin).
    ///
    /// # Safety
    /// Caller must have verified AVX2+FMA support and the slice-shape
    /// invariants of the dispatching wrapper.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matmul_nt_rows(
        a: &[f32],
        b: &[f32],
        n: usize,
        q: usize,
        rows: Range<usize>,
        c: &mut [f32],
        acc: bool,
    ) {
        let i_off = rows.start;
        for i0 in (rows.start..rows.end).step_by(BLOCK) {
            let i1 = (i0 + BLOCK).min(rows.end);
            for j0 in (0..q).step_by(BLOCK) {
                let j1 = (j0 + BLOCK).min(q);
                for i in i0..i1 {
                    let ra = i * n;
                    let rc = (i - i_off) * q;
                    for j in j0..j1 {
                        let rb = j * n;
                        let s =
                            dot(a.as_ptr().add(ra), b.as_ptr().add(rb), n);
                        if acc {
                            c[rc + j] += s;
                        } else {
                            c[rc + j] = s;
                        }
                    }
                }
            }
        }
    }

    /// SIMD twin of `matmul_rows_scalar`.
    ///
    /// # Safety
    /// Caller must have verified AVX2+FMA support and the slice-shape
    /// invariants of the dispatching wrapper.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matmul_rows(
        a: &[f32],
        b: &[f32],
        q: usize,
        r: usize,
        rows: Range<usize>,
        c: &mut [f32],
    ) {
        let i_off = rows.start;
        for i0 in (rows.start..rows.end).step_by(BLOCK) {
            let i1 = (i0 + BLOCK).min(rows.end);
            for k0 in (0..q).step_by(BLOCK) {
                let k1 = (k0 + BLOCK).min(q);
                for i in i0..i1 {
                    let crow = (i - i_off) * r;
                    for k in k0..k1 {
                        let aik = a[i * q + k];
                        if aik != 0.0 {
                            let brow = k * r;
                            axpy(
                                aik,
                                b.as_ptr().add(brow),
                                c.as_mut_ptr().add(crow),
                                r,
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Dense `C = Aᵀ B` with a shared leading (batch) axis: A is [n, p],
/// B is [n, q], C is [p, q] -- the contraction the native backend's
/// gradient/factor extractions reduce to (mirror of the Python
/// `ops.matmul_tn` kernel). Cache-blocked over all three axes; inner
/// loops stream rows of B and C through the dispatched microkernel.
pub fn matmul_tn(
    a: &[f32], b: &[f32], n: usize, p: usize, q: usize,
) -> Vec<f32> {
    let mut c = vec![0.0f32; p * q];
    matmul_tn_into(a, b, n, p, q, &mut c);
    c
}

/// [`matmul_tn`] writing into a caller-provided buffer (overwritten),
/// so tile-streaming callers (the fused conv path) can reuse one
/// allocation across tiles.
pub fn matmul_tn_into(
    a: &[f32], b: &[f32], n: usize, p: usize, q: usize, c: &mut [f32],
) {
    assert_eq!(a.len(), n * p);
    assert_eq!(b.len(), n * q);
    count_macs(n * p * q);
    c.fill(0.0);
    matmul_tn_rows(a, b, n, p, q, 0..p, c);
}

/// [`matmul_tn`] forced onto the blocked *scalar* inner loops,
/// bypassing runtime SIMD dispatch. This is the retained reference
/// the property suite and the kernel microbench compare against.
pub fn matmul_tn_scalar(
    a: &[f32], b: &[f32], n: usize, p: usize, q: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), n * p);
    assert_eq!(b.len(), n * q);
    count_macs(n * p * q);
    let mut c = vec![0.0f32; p * q];
    matmul_tn_rows_scalar(a, b, n, p, q, 0..p, &mut c);
    c
}

/// Row slab `C[rows, :] = (Aᵀ B)[rows, :]` of [`matmul_tn`], written
/// into `c` (len `rows.len() * q`). The shared building block of the
/// serial and parallel drivers; picks the SIMD or scalar inner kernel
/// once per slab.
fn matmul_tn_rows(
    a: &[f32],
    b: &[f32],
    n: usize,
    p: usize,
    q: usize,
    rows: Range<usize>,
    c: &mut [f32],
) {
    assert_eq!(c.len(), rows.len() * q);
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: AVX2+FMA presence checked by `simd_active`; shapes
        // checked by the assert above and the public entry points.
        unsafe { x86::matmul_tn_rows(a, b, n, p, q, rows, c) };
        return;
    }
    matmul_tn_rows_scalar(a, b, n, p, q, rows, c);
}

/// Scalar inner loops of [`matmul_tn_rows`].
fn matmul_tn_rows_scalar(
    a: &[f32],
    b: &[f32],
    n: usize,
    p: usize,
    q: usize,
    rows: Range<usize>,
    c: &mut [f32],
) {
    assert_eq!(c.len(), rows.len() * q);
    let i_off = rows.start;
    for s0 in (0..n).step_by(BLOCK) {
        let s1 = (s0 + BLOCK).min(n);
        for i0 in (rows.start..rows.end).step_by(BLOCK) {
            let i1 = (i0 + BLOCK).min(rows.end);
            for j0 in (0..q).step_by(BLOCK) {
                let j1 = (j0 + BLOCK).min(q);
                for s in s0..s1 {
                    let (ra, rb) = (s * p, s * q);
                    for i in i0..i1 {
                        let av = a[ra + i];
                        if av != 0.0 {
                            let rc = (i - i_off) * q;
                            for j in j0..j1 {
                                c[rc + j] += av * b[rb + j];
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Shared driver of the `*_par` kernels: split the `p` output rows
/// into per-shard slabs, run `kernel` on each slab's sub-buffer on the
/// persistent worker pool, and concatenate in slab order. Each shard
/// owns a disjoint row slab and both sides of the pool run the same
/// dispatched microkernel, so the result is bit-for-bit identical to
/// the serial kernel.
fn par_rows<K>(p: usize, q: usize, threads: usize, kernel: K) -> Vec<f32>
where
    K: Fn(Range<usize>, &mut [f32]) + Sync,
{
    let slabs = crate::parallel::shards(p, threads);
    let parts = crate::parallel::par_map(&slabs, |rows| {
        let mut c = vec![0.0f32; rows.len() * q];
        kernel(rows, &mut c);
        c
    });
    let mut c = Vec::with_capacity(p * q);
    for part in parts {
        c.extend_from_slice(&part);
    }
    c
}

/// [`matmul_tn`] with the output rows split across the worker pool
/// (bit-for-bit identical to serial; serial below `PAR_MIN_MACS`).
pub fn matmul_tn_par(
    a: &[f32], b: &[f32], n: usize, p: usize, q: usize, threads: usize,
) -> Vec<f32> {
    if threads <= 1 || n * p * q < PAR_MIN_MACS {
        return matmul_tn(a, b, n, p, q);
    }
    assert_eq!(a.len(), n * p);
    assert_eq!(b.len(), n * q);
    count_macs(n * p * q);
    par_rows(p, q, threads, |rows, c| {
        matmul_tn_rows(a, b, n, p, q, rows, c)
    })
}

/// Dense `C = A Bᵀ` (row-major, [p,n]x[q,n] -> [p,q]): rows of both
/// operands are contracted as dot products, tiled so a panel of B rows
/// stays cache-resident across the A rows of a block.
pub fn matmul_nt(
    a: &[f32], b: &[f32], p: usize, n: usize, q: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), p * n);
    assert_eq!(b.len(), q * n);
    count_macs(p * n * q);
    let mut c = vec![0.0f32; p * q];
    matmul_nt_rows(a, b, n, q, 0..p, &mut c, false);
    c
}

/// `C += A Bᵀ` accumulated into a caller-provided [p, q] buffer — the
/// contraction shape of the fused conv path, which sums one `A Bᵀ`
/// product per streamed column tile into a single accumulator.
pub fn matmul_nt_acc(
    a: &[f32], b: &[f32], p: usize, n: usize, q: usize, c: &mut [f32],
) {
    assert_eq!(a.len(), p * n);
    assert_eq!(b.len(), q * n);
    assert_eq!(c.len(), p * q);
    count_macs(p * n * q);
    matmul_nt_rows(a, b, n, q, 0..p, c, true);
}

/// [`matmul_nt`] forced onto the blocked *scalar* inner loops,
/// bypassing runtime SIMD dispatch (reference for tests/microbench).
pub fn matmul_nt_scalar(
    a: &[f32], b: &[f32], p: usize, n: usize, q: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), p * n);
    assert_eq!(b.len(), q * n);
    count_macs(p * n * q);
    let mut c = vec![0.0f32; p * q];
    matmul_nt_rows_scalar(a, b, n, q, 0..p, &mut c, false);
    c
}

/// Row slab `C[rows, :] = (A Bᵀ)[rows, :]` of [`matmul_nt`] (`acc`
/// accumulates instead of overwriting); picks the SIMD or scalar
/// inner kernel once per slab.
fn matmul_nt_rows(
    a: &[f32],
    b: &[f32],
    n: usize,
    q: usize,
    rows: Range<usize>,
    c: &mut [f32],
    acc: bool,
) {
    assert_eq!(c.len(), rows.len() * q);
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: AVX2+FMA presence checked by `simd_active`; shapes
        // checked by the assert above and the public entry points.
        unsafe { x86::matmul_nt_rows(a, b, n, q, rows, c, acc) };
        return;
    }
    matmul_nt_rows_scalar(a, b, n, q, rows, c, acc);
}

/// Scalar inner loops of [`matmul_nt_rows`].
fn matmul_nt_rows_scalar(
    a: &[f32],
    b: &[f32],
    n: usize,
    q: usize,
    rows: Range<usize>,
    c: &mut [f32],
    acc: bool,
) {
    assert_eq!(c.len(), rows.len() * q);
    let i_off = rows.start;
    for i0 in (rows.start..rows.end).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(rows.end);
        for j0 in (0..q).step_by(BLOCK) {
            let j1 = (j0 + BLOCK).min(q);
            for i in i0..i1 {
                let ra = i * n;
                let rc = (i - i_off) * q;
                for j in j0..j1 {
                    let rb = j * n;
                    let s: f32 = a[ra..ra + n]
                        .iter()
                        .zip(&b[rb..rb + n])
                        .map(|(x, y)| x * y)
                        .sum();
                    if acc {
                        c[rc + j] += s;
                    } else {
                        c[rc + j] = s;
                    }
                }
            }
        }
    }
}

/// [`matmul_nt`] with the output rows split across the worker pool
/// (bit-for-bit identical to serial; serial below `PAR_MIN_MACS`).
pub fn matmul_nt_par(
    a: &[f32], b: &[f32], p: usize, n: usize, q: usize, threads: usize,
) -> Vec<f32> {
    if threads <= 1 || p * n * q < PAR_MIN_MACS {
        return matmul_nt(a, b, p, n, q);
    }
    assert_eq!(a.len(), p * n);
    assert_eq!(b.len(), q * n);
    count_macs(p * n * q);
    par_rows(p, q, threads, |rows, c| {
        matmul_nt_rows(a, b, n, q, rows, c, false)
    })
}

/// Dense `C = A B` (row-major, [p,q]x[q,r]), tiled so a panel of B
/// rows is reused across the A rows of a block.
pub fn matmul(a: &[f32], b: &[f32], p: usize, q: usize, r: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; p * r];
    matmul_into(a, b, p, q, r, &mut c);
    c
}

/// [`matmul`] writing into a caller-provided buffer (overwritten), so
/// tile-streaming callers can reuse one allocation across tiles.
pub fn matmul_into(
    a: &[f32], b: &[f32], p: usize, q: usize, r: usize, c: &mut [f32],
) {
    assert_eq!(a.len(), p * q);
    assert_eq!(b.len(), q * r);
    count_macs(p * q * r);
    c.fill(0.0);
    matmul_rows(a, b, q, r, 0..p, c);
}

/// [`matmul`] forced onto the blocked *scalar* inner loops, bypassing
/// runtime SIMD dispatch (reference for tests/microbench).
pub fn matmul_scalar(
    a: &[f32], b: &[f32], p: usize, q: usize, r: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), p * q);
    assert_eq!(b.len(), q * r);
    count_macs(p * q * r);
    let mut c = vec![0.0f32; p * r];
    matmul_rows_scalar(a, b, q, r, 0..p, &mut c);
    c
}

/// Row slab `C[rows, :] = (A B)[rows, :]` of [`matmul`]; picks the
/// SIMD or scalar inner kernel once per slab.
fn matmul_rows(
    a: &[f32],
    b: &[f32],
    q: usize,
    r: usize,
    rows: Range<usize>,
    c: &mut [f32],
) {
    assert_eq!(c.len(), rows.len() * r);
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: AVX2+FMA presence checked by `simd_active`; shapes
        // checked by the assert above and the public entry points.
        unsafe { x86::matmul_rows(a, b, q, r, rows, c) };
        return;
    }
    matmul_rows_scalar(a, b, q, r, rows, c);
}

/// Scalar inner loops of [`matmul_rows`].
fn matmul_rows_scalar(
    a: &[f32],
    b: &[f32],
    q: usize,
    r: usize,
    rows: Range<usize>,
    c: &mut [f32],
) {
    assert_eq!(c.len(), rows.len() * r);
    let i_off = rows.start;
    for i0 in (rows.start..rows.end).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(rows.end);
        for k0 in (0..q).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(q);
            for i in i0..i1 {
                let crow = (i - i_off) * r;
                for k in k0..k1 {
                    let aik = a[i * q + k];
                    if aik != 0.0 {
                        let brow = k * r;
                        for j in 0..r {
                            c[crow + j] += aik * b[brow + j];
                        }
                    }
                }
            }
        }
    }
}

/// [`matmul`] with the output rows split across the worker pool
/// (bit-for-bit identical to serial; serial below `PAR_MIN_MACS`).
pub fn matmul_par(
    a: &[f32], b: &[f32], p: usize, q: usize, r: usize, threads: usize,
) -> Vec<f32> {
    if threads <= 1 || p * q * r < PAR_MIN_MACS {
        return matmul(a, b, p, q, r);
    }
    assert_eq!(a.len(), p * q);
    assert_eq!(b.len(), q * r);
    count_macs(p * q * r);
    par_rows(p, r, threads, |rows, c| {
        matmul_rows(a, b, q, r, rows, c)
    })
}

/// Unblocked, unvectorized triple-loop kernels: the ground-truth
/// oracles the property suite (`tests/proptests.rs`) and the unit
/// tests below compare every production kernel against. Deliberately
/// naive — no tiling, no zero-skip, no SIMD, no obs counting — so a
/// bug in the fast paths cannot be mirrored here.
pub mod reference {
    /// `C = A B`, A [p,q] x B [q,r].
    pub fn matmul(
        a: &[f32], b: &[f32], p: usize, q: usize, r: usize,
    ) -> Vec<f32> {
        let mut c = vec![0.0f32; p * r];
        for i in 0..p {
            for k in 0..q {
                for j in 0..r {
                    c[i * r + j] += a[i * q + k] * b[k * r + j];
                }
            }
        }
        c
    }

    /// `C = Aᵀ B`, A [n,p] x B [n,q] sharing the leading axis.
    pub fn matmul_tn(
        a: &[f32], b: &[f32], n: usize, p: usize, q: usize,
    ) -> Vec<f32> {
        let mut c = vec![0.0f32; p * q];
        for s in 0..n {
            for i in 0..p {
                for j in 0..q {
                    c[i * q + j] += a[s * p + i] * b[s * q + j];
                }
            }
        }
        c
    }

    /// `C = A Bᵀ`, A [p,n] x B [q,n] contracting the trailing axis.
    pub fn matmul_nt(
        a: &[f32], b: &[f32], p: usize, n: usize, q: usize,
    ) -> Vec<f32> {
        let mut c = vec![0.0f32; p * q];
        for i in 0..p {
            for j in 0..q {
                for s in 0..n {
                    c[i * q + j] += a[i * n + s] * b[j * n + s];
                }
            }
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    fn random_spd(n: usize, seed: u64) -> SymMat {
        let mut rng = Rng::new(seed);
        let g: Vec<f32> = (0..n * n).map(|_| rng.normal()).collect();
        // A = G Gᵀ / n + 0.5 I  (definitely SPD)
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += g[i * n + k] * g[j * n + k];
                }
                a[i * n + j] = s / n as f32;
            }
        }
        for i in 0..n {
            a[i * n + i] += 0.5;
        }
        SymMat::new(n, a)
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(12, 1);
        let ch = Cholesky::factor(&a).unwrap();
        for i in 0..a.n {
            for j in 0..a.n {
                let mut s = 0.0;
                for k in 0..a.n {
                    s += ch.l[i * a.n + k] * ch.l[j * a.n + k];
                }
                assert!((s - a.at(i, j)).abs() < 1e-4,
                        "LLᵀ[{i},{j}]={s} != {}", a.at(i, j));
            }
        }
    }

    #[test]
    fn solve_vec_correct() {
        let a = random_spd(15, 2);
        let ch = Cholesky::factor(&a).unwrap();
        let mut rng = Rng::new(3);
        let x_true: Vec<f32> = (0..15).map(|_| rng.normal()).collect();
        let mut b = vec![0.0f32; 15];
        for i in 0..15 {
            for j in 0..15 {
                b[i] += a.at(i, j) * x_true[j];
            }
        }
        ch.solve_vec(&mut b);
        for i in 0..15 {
            assert!((b[i] - x_true[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn solve_mat_left_matches_vec() {
        let a = random_spd(9, 4);
        let ch = Cholesky::factor(&a).unwrap();
        let mut rng = Rng::new(5);
        let b: Vec<f32> = (0..9 * 4).map(|_| rng.normal()).collect();
        let mut m = b.clone();
        ch.solve_mat_left(&mut m, 4);
        for c in 0..4 {
            let mut col: Vec<f32> = (0..9).map(|i| b[i * 4 + c]).collect();
            ch.solve_vec(&mut col);
            for i in 0..9 {
                assert!((m[i * 4 + c] - col[i]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn solve_mat_right_is_right_division() {
        // X A = B  =>  X = B A⁻¹; verify X A ≈ B.
        let a = random_spd(7, 6);
        let ch = Cholesky::factor(&a).unwrap();
        let mut rng = Rng::new(7);
        let b: Vec<f32> = (0..3 * 7).map(|_| rng.normal()).collect();
        let mut x = b.clone();
        ch.solve_mat_right(&mut x, 3);
        let back = matmul(&x, &a.a, 3, 7, 7);
        for (u, v) in back.iter().zip(&b) {
            assert!((u - v).abs() < 1e-3);
        }
    }

    #[test]
    fn matmul_variants_agree_with_plain_matmul() {
        let mut rng = Rng::new(9);
        let (n, p, q) = (5, 3, 4);
        let a: Vec<f32> = (0..n * p).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..n * q).map(|_| rng.normal()).collect();
        // Aᵀ B via explicit transpose + matmul.
        let mut at = vec![0.0f32; p * n];
        for s in 0..n {
            for i in 0..p {
                at[i * n + s] = a[s * p + i];
            }
        }
        let want = matmul(&at, &b, p, n, q);
        let got = matmul_tn(&a, &b, n, p, q);
        for (u, v) in got.iter().zip(&want) {
            assert!((u - v).abs() < 1e-5);
        }
        // A Bᵀ via explicit transpose + matmul.
        let c: Vec<f32> = (0..p * n).map(|_| rng.normal()).collect();
        let d: Vec<f32> = (0..q * n).map(|_| rng.normal()).collect();
        let mut dt = vec![0.0f32; n * q];
        for j in 0..q {
            for s in 0..n {
                dt[s * q + j] = d[j * n + s];
            }
        }
        let want = matmul(&c, &dt, p, n, q);
        let got = matmul_nt(&c, &d, p, n, q);
        for (u, v) in got.iter().zip(&want) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn blocked_kernels_match_reference_across_block_edges() {
        let mut rng = Rng::new(11);
        // Deliberately awkward sizes: 1 under, on, and over BLOCK.
        let (n, p, q) = (67, 65, 130);
        let a: Vec<f32> = (0..n * p).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..n * q).map(|_| rng.normal()).collect();
        let want = reference::matmul_tn(&a, &b, n, p, q);
        for (u, v) in matmul_tn(&a, &b, n, p, q).iter().zip(&want) {
            assert!((u - v).abs() < 1e-3 * (1.0 + v.abs()));
        }
        let c: Vec<f32> = (0..p * n).map(|_| rng.normal()).collect();
        let d: Vec<f32> = (0..n * q).map(|_| rng.normal()).collect();
        let want = reference::matmul(&c, &d, p, n, q);
        for (u, v) in matmul(&c, &d, p, n, q).iter().zip(&want) {
            assert!((u - v).abs() < 1e-3 * (1.0 + v.abs()));
        }
        // A Bᵀ against A (Bᵀ) via the plain kernel.
        let e: Vec<f32> = (0..q * n).map(|_| rng.normal()).collect();
        let mut et = vec![0.0f32; n * q];
        for j in 0..q {
            for s in 0..n {
                et[s * q + j] = e[j * n + s];
            }
        }
        let want = reference::matmul(&c, &et, p, n, q);
        for (u, v) in matmul_nt(&c, &e, p, n, q).iter().zip(&want) {
            assert!((u - v).abs() < 1e-3 * (1.0 + v.abs()));
        }
    }

    #[test]
    fn par_kernels_are_bitwise_equal_to_serial() {
        let mut rng = Rng::new(13);
        // Big enough to clear PAR_MIN_MACS (130*129*131 > 2^20).
        let (n, p, q) = (130, 129, 131);
        let a: Vec<f32> = (0..n * p).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..n * q).map(|_| rng.normal()).collect();
        for t in [1usize, 2, 3, 5] {
            assert_eq!(
                matmul_tn_par(&a, &b, n, p, q, t),
                matmul_tn(&a, &b, n, p, q),
                "tn t={t}"
            );
        }
        let c: Vec<f32> = (0..p * n).map(|_| rng.normal()).collect();
        let d: Vec<f32> = (0..q * n).map(|_| rng.normal()).collect();
        assert_eq!(
            matmul_nt_par(&c, &d, p, n, q, 3),
            matmul_nt(&c, &d, p, n, q)
        );
        let e: Vec<f32> = (0..n * q).map(|_| rng.normal()).collect();
        assert_eq!(
            matmul_par(&c, &e, p, n, q, 3),
            matmul(&c, &e, p, n, q)
        );
    }

    #[test]
    fn into_and_acc_variants_match_allocating_kernels() {
        let mut rng = Rng::new(17);
        let (n, p, q) = (23, 9, 11);
        let a: Vec<f32> = (0..n * p).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..n * q).map(|_| rng.normal()).collect();
        let mut c = vec![7.0f32; p * q]; // stale garbage: must be overwritten
        matmul_tn_into(&a, &b, n, p, q, &mut c);
        assert_eq!(c, matmul_tn(&a, &b, n, p, q));

        let e: Vec<f32> = (0..p * n).map(|_| rng.normal()).collect();
        let f: Vec<f32> = (0..q * n).map(|_| rng.normal()).collect();
        // Two accumulations = 2x the plain product.
        let mut acc = vec![0.0f32; p * q];
        matmul_nt_acc(&e, &f, p, n, q, &mut acc);
        matmul_nt_acc(&e, &f, p, n, q, &mut acc);
        let once = matmul_nt(&e, &f, p, n, q);
        for (u, v) in acc.iter().zip(&once) {
            assert!((u - 2.0 * v).abs() < 1e-5 * (1.0 + v.abs()));
        }

        let g: Vec<f32> = (0..p * q).map(|_| rng.normal()).collect();
        let h: Vec<f32> = (0..q * n).map(|_| rng.normal()).collect();
        let mut c2 = vec![3.0f32; p * n];
        matmul_into(&g, &h, p, q, n, &mut c2);
        assert_eq!(c2, matmul(&g, &h, p, q, n));
    }

    #[test]
    fn scalar_kernels_match_dispatched_kernels() {
        // Shapes straddle both the 8-lane SIMD width and the 64-wide
        // cache block; 1e-5 covers FMA/reassociation differences when
        // the dispatched path is vectorized.
        let mut rng = Rng::new(19);
        let (n, p, q) = (67, 17, 70);
        let a: Vec<f32> = (0..n * p).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..n * q).map(|_| rng.normal()).collect();
        for (got, want) in matmul_tn(&a, &b, n, p, q)
            .iter()
            .zip(&matmul_tn_scalar(&a, &b, n, p, q))
        {
            assert!((got - want).abs() < 1e-5 * (1.0 + want.abs()));
        }
        let c: Vec<f32> = (0..p * n).map(|_| rng.normal()).collect();
        let d: Vec<f32> = (0..q * n).map(|_| rng.normal()).collect();
        for (got, want) in matmul_nt(&c, &d, p, n, q)
            .iter()
            .zip(&matmul_nt_scalar(&c, &d, p, n, q))
        {
            assert!((got - want).abs() < 1e-5 * (1.0 + want.abs()));
        }
        let e: Vec<f32> = (0..n * q).map(|_| rng.normal()).collect();
        for (got, want) in matmul(&c, &e, p, n, q)
            .iter()
            .zip(&matmul_scalar(&c, &e, p, n, q))
        {
            assert!((got - want).abs() < 1e-5 * (1.0 + want.abs()));
        }
    }

    #[test]
    fn non_pd_rejected() {
        let m = SymMat::new(2, vec![1.0, 2.0, 2.0, 1.0]); // eig -1, 3
        assert!(Cholesky::factor(&m).is_err());
    }

    #[test]
    fn add_diag_and_trace() {
        let m = SymMat::identity(3).add_diag(2.0);
        assert_eq!(m.trace(), 9.0);
    }
}
