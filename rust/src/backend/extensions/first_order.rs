//! First-order extensions (paper Table 1 / Appendix A.1): quantities
//! extracted from the per-sample output gradients `g [N, F]` that the
//! engine propagates anyway (Eq. 3, [`Walk::Grad`]) — individual
//! gradients, their L2 norms, the second moment, and the variance.
//!
//! Conventions (DESIGN.md §4; the loss is the batch **mean**):
//!
//! * [`BatchGrad`]: individual gradients `(1/N) ∇ℓ_n`, batch axis
//!   kept — shapes `[N, …w]` / `[N, dout]`;
//! * [`BatchL2`]: `‖(1/N) ∇ℓ_n‖²` per sample, one scalar per block;
//! * [`SqMoment`]: `(1/N) Σ_n [∇ℓ_n]²`, parameter-shaped;
//! * [`Variance`]: `(1/N) Σ_n [∇ℓ_n]² − [∇L]²`, derived **after** the
//!   shard reduction from the merged moments (exactly — not a
//!   per-shard approximation).
//!
//! For `Linear` layers the per-sample gradient is the rank-1 outer
//! product `g_n x_nᵀ`, so `batch_l2`/`sq_moment` use the factored
//! shortcuts (`‖g_n x_nᵀ‖² = ‖g_n‖²·‖x_n‖²`) without materializing
//! individual gradients. Convolutions have no rank-1 shortcut
//! (spatial positions sum into the per-sample gradient), so the conv
//! rules share one materialized `G_n ⟦x⟧_nᵀ` product per sample via
//! [`LayerCtx::per_sample_grads`].

use crate::linalg::matmul_tn;
use crate::runtime::{Tensor, TensorSpec};

use super::{
    f32_spec, Extension, FinishCtx, LayerCtx, LayerOp, Quantities,
    Reduce, Walk,
};
use crate::backend::model::Model;

/// Individual gradients `(1/N) ∇ℓ_n` with the batch axis kept
/// (`batch_grad`, Table 1 row 1).
pub struct BatchGrad;

impl Extension for BatchGrad {
    fn name(&self) -> &str {
        "batch_grad"
    }

    fn walk(&self) -> Walk {
        Walk::Grad
    }

    fn first_order(
        &self,
        ctx: &LayerCtx,
        g: &[f32],
        out: &mut Quantities,
    ) {
        let (li, n, nf) = (ctx.li, ctx.n, ctx.norm);
        match ctx.op {
            LayerOp::Linear { din, dout, .. } => {
                // (1/N) ∇ℓ_n: rank-1 outer products per sample.
                let inp = ctx.input;
                let mut bw = vec![0.0f32; n * dout * din];
                for s in 0..n {
                    for o in 0..dout {
                        let gv = g[s * dout + o] / nf;
                        let row = (s * dout + o) * din;
                        for i in 0..din {
                            bw[row + i] = gv * inp[s * din + i];
                        }
                    }
                }
                out.insert(
                    format!("batch_grad/{li}/w"),
                    Tensor::from_f32(&[n, dout, din], bw),
                );
                let bb: Vec<f32> = g.iter().map(|v| v / nf).collect();
                out.insert(
                    format!("batch_grad/{li}/b"),
                    Tensor::from_f32(&[n, dout], bb),
                );
            }
            LayerOp::Conv { .. } => {
                let ps = ctx.per_sample_grads(g);
                let mut bshape = vec![n];
                bshape.extend(ctx.op.w_shape());
                out.insert(
                    format!("batch_grad/{li}/w"),
                    Tensor::from_f32(
                        &bshape,
                        ps.w.iter().map(|v| v / nf).collect(),
                    ),
                );
                out.insert(
                    format!("batch_grad/{li}/b"),
                    Tensor::from_f32(
                        &[n, ctx.op.dout()],
                        ps.b.iter().map(|v| v / nf).collect(),
                    ),
                );
            }
        }
    }

    fn reduce(&self, key: &str) -> Option<Reduce> {
        key.starts_with("batch_grad/").then_some(Reduce::Concat)
    }

    fn output_specs(&self, model: &Model, batch: usize) -> Vec<TensorSpec> {
        let mut specs = Vec::new();
        for blk in model.param_blocks() {
            let mut bsh = vec![batch];
            bsh.extend(&blk.w_shape);
            specs.push(f32_spec(format!("batch_grad/{}/w", blk.li), bsh));
            specs.push(f32_spec(
                format!("batch_grad/{}/b", blk.li),
                vec![batch, blk.dout],
            ));
        }
        specs
    }
}

/// Per-sample gradient L2 norms `‖(1/N) ∇ℓ_n‖²` (`batch_l2`,
/// Appendix A.1): one scalar per sample per parameter block.
pub struct BatchL2;

impl Extension for BatchL2 {
    fn name(&self) -> &str {
        "batch_l2"
    }

    fn walk(&self) -> Walk {
        Walk::Grad
    }

    fn first_order(
        &self,
        ctx: &LayerCtx,
        g: &[f32],
        out: &mut Quantities,
    ) {
        let (li, n, nf) = (ctx.li, ctx.n, ctx.norm);
        let (mut l2w, mut l2b) = (vec![0.0f32; n], vec![0.0f32; n]);
        match ctx.op {
            LayerOp::Linear { din, dout, .. } => {
                // The rank-1 structure gives ‖g_n x_nᵀ‖² =
                // ‖g_n‖²·‖x_n‖² without materializing the individual
                // gradients.
                let inp = ctx.input;
                for s in 0..n {
                    let g2: f32 = g[s * dout..(s + 1) * dout]
                        .iter()
                        .map(|v| v * v)
                        .sum();
                    let x2: f32 = inp[s * din..(s + 1) * din]
                        .iter()
                        .map(|v| v * v)
                        .sum();
                    l2w[s] = g2 * x2 / (nf * nf);
                    l2b[s] = g2 / (nf * nf);
                }
            }
            LayerOp::Conv { .. } => {
                let ps = ctx.per_sample_grads(g);
                let (dout, j) = (ctx.op.dout(), ctx.op.a_dim());
                for s in 0..n {
                    let g2: f32 = ps.w[s * dout * j..(s + 1) * dout * j]
                        .iter()
                        .map(|v| v * v)
                        .sum();
                    let b2: f32 = ps.b[s * dout..(s + 1) * dout]
                        .iter()
                        .map(|v| v * v)
                        .sum();
                    l2w[s] = g2 / (nf * nf);
                    l2b[s] = b2 / (nf * nf);
                }
            }
        }
        out.insert(
            format!("batch_l2/{li}/w"),
            Tensor::from_f32(&[n], l2w),
        );
        out.insert(
            format!("batch_l2/{li}/b"),
            Tensor::from_f32(&[n], l2b),
        );
    }

    fn reduce(&self, key: &str) -> Option<Reduce> {
        key.starts_with("batch_l2/").then_some(Reduce::Concat)
    }

    fn output_specs(&self, model: &Model, batch: usize) -> Vec<TensorSpec> {
        let mut specs = Vec::new();
        for blk in model.param_blocks() {
            for part in ["w", "b"] {
                specs.push(f32_spec(
                    format!("batch_l2/{}/{part}", blk.li),
                    vec![batch],
                ));
            }
        }
        specs
    }
}

/// Emit `sq_moment/{li}/{w,b}` for one layer unless another
/// first-order module already did (the moments are shared between
/// [`SqMoment`] and [`Variance`], whichever hook runs first).
fn sq_moment_at(ctx: &LayerCtx, g: &[f32], out: &mut Quantities) {
    let (li, n, nf) = (ctx.li, ctx.n, ctx.norm);
    if out.contains_key(&format!("sq_moment/{li}/w")) {
        return;
    }
    match ctx.op {
        LayerOp::Linear { din, dout, .. } => {
            // (1/N) Σ_n [∇ℓ_n]² = (1/N) (g²)ᵀ (x²), again rank-1.
            let g2: Vec<f32> = g.iter().map(|v| v * v).collect();
            let x2: Vec<f32> =
                ctx.input.iter().map(|v| v * v).collect();
            let mut sqw = matmul_tn(&g2, &x2, n, dout, din);
            for v in &mut sqw {
                *v /= nf;
            }
            let mut sqb = vec![0.0f32; dout];
            for s in 0..n {
                for o in 0..dout {
                    sqb[o] += g2[s * dout + o];
                }
            }
            for v in &mut sqb {
                *v /= nf;
            }
            out.insert(
                format!("sq_moment/{li}/w"),
                Tensor::from_f32(&[dout, din], sqw),
            );
            out.insert(
                format!("sq_moment/{li}/b"),
                Tensor::from_f32(&[dout], sqb),
            );
        }
        LayerOp::Conv { .. } => {
            let ps = ctx.per_sample_grads(g);
            let (dout, j) = (ctx.op.dout(), ctx.op.a_dim());
            let mut sqw = vec![0.0f32; dout * j];
            let mut sqb = vec![0.0f32; dout];
            for s in 0..n {
                for (acc, v) in
                    sqw.iter_mut().zip(&ps.w[s * dout * j..])
                {
                    *acc += v * v;
                }
                for (acc, v) in sqb.iter_mut().zip(&ps.b[s * dout..]) {
                    *acc += v * v;
                }
            }
            for v in sqw.iter_mut().chain(sqb.iter_mut()) {
                *v /= nf;
            }
            out.insert(
                format!("sq_moment/{li}/w"),
                Tensor::from_f32(&ctx.op.w_shape(), sqw),
            );
            out.insert(
                format!("sq_moment/{li}/b"),
                Tensor::from_f32(&[dout], sqb),
            );
        }
    }
}

/// Parameter-shaped `sq_moment/{li}/{w,b}` specs for every block.
fn moment_specs(name: &str, model: &Model) -> Vec<TensorSpec> {
    let mut specs = Vec::new();
    for blk in model.param_blocks() {
        specs.push(f32_spec(
            format!("{name}/{}/w", blk.li),
            blk.w_shape.clone(),
        ));
        specs.push(f32_spec(
            format!("{name}/{}/b", blk.li),
            vec![blk.dout],
        ));
    }
    specs
}

/// Second moment of the individual gradients `(1/N) Σ_n [∇ℓ_n]²`
/// (`sq_moment`, Table 1 row 2).
pub struct SqMoment;

impl Extension for SqMoment {
    fn name(&self) -> &str {
        "sq_moment"
    }

    fn walk(&self) -> Walk {
        Walk::Grad
    }

    fn first_order(
        &self,
        ctx: &LayerCtx,
        g: &[f32],
        out: &mut Quantities,
    ) {
        sq_moment_at(ctx, g, out);
    }

    fn output_specs(&self, model: &Model, _batch: usize) -> Vec<TensorSpec> {
        moment_specs("sq_moment", model)
    }
}

/// Gradient variance `(1/N) Σ_n [∇ℓ_n]² − [∇L]²` (`variance`,
/// Table 1 row 3).
///
/// The shard phase emits the second moments (`sq_moment_at`, shared
/// with [`SqMoment`]); the variance itself is derived in
/// [`Extension::finish`] from the **merged** `grad`/`sq_moment` —
/// exactly, because both moments sum-reduce across shards. The
/// intermediate moments are dropped unless `sq_moment` was also
/// requested.
pub struct Variance;

impl Extension for Variance {
    fn name(&self) -> &str {
        "variance"
    }

    fn walk(&self) -> Walk {
        Walk::Grad
    }

    fn first_order(
        &self,
        ctx: &LayerCtx,
        g: &[f32],
        out: &mut Quantities,
    ) {
        sq_moment_at(ctx, g, out);
    }

    fn finish(
        &self,
        ctx: &FinishCtx,
        out: &mut Quantities,
    ) -> anyhow::Result<()> {
        for blk in ctx.model.param_blocks() {
            let li = blk.li;
            for part in ["w", "b"] {
                let gname = format!("grad/{li}/{part}");
                let sname = format!("sq_moment/{li}/{part}");
                let (shape, var) = {
                    let g = out[&gname].f32s()?;
                    let sq = out[&sname].f32s()?;
                    let var: Vec<f32> = sq
                        .iter()
                        .zip(g)
                        .map(|(s2, g1)| s2 - g1 * g1)
                        .collect();
                    (out[&sname].shape.clone(), var)
                };
                out.insert(
                    format!("variance/{li}/{part}"),
                    Tensor::from_f32(&shape, var),
                );
                if !ctx.requested("sq_moment") {
                    out.remove(&sname);
                }
            }
        }
        Ok(())
    }

    fn output_specs(&self, model: &Model, _batch: usize) -> Vec<TensorSpec> {
        moment_specs("variance", model)
    }
}
