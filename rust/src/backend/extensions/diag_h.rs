//! Exact Hessian diagonal (`diag_h`, paper Fig. 9 / Appendix A.3) via
//! second-order residual propagation.
//!
//! The full Hessian of the batch-mean loss decomposes per sample into
//! the GGN plus one residual term per module (the HBP recursion,
//! DESIGN.md §11):
//!
//! ```text
//! ∇²_x ℓ = Jᵀ (∇²_z ℓ) J + Σ_k (∇²_x z_k) · (∇_z ℓ)_k
//! ```
//!
//! Affine maps (`Linear`, `Conv2d`), `Flatten`, the pooling layers and
//! ReLU are (piecewise) linear — their residual vanishes — so the only
//! residual terms are the elementwise `diag(σ''(x) ⊙ g)` of curved
//! activations (sigmoid). Each such term is an indefinite diagonal
//! matrix; writing it as a signed square `diag(√|r|) · diag(sign r) ·
//! diag(√|r|)ᵀ` lets the engine push it through the *same* transposed
//! Jacobians as the exact square-root GGN, one column per feature,
//! carrying the per-(sample, column) sign on the side
//! ([`Extension::residual`]).
//!
//! `DiagH` therefore declares [`Walk::SqrtGgn`] (its GGN part shares
//! the exact-`S` propagation with `diag_ggn`/`kflr` — one walk, no
//! duplicate work) and [`Extension::needs_residual`]; its two hooks
//! accumulate into the same `diag_h/{layer}/{w|b}` keys:
//!
//! * [`Extension::sqrt_ggn`] — the PSD part, the DiagGGN contraction
//!   (Eq. 19) written into `diag_h/*`;
//! * [`Extension::residual`] — the same contraction per signed factor,
//!   with each squared column weighted by its sign.
//!
//! Both hooks funnel into one extraction per layer family, shared
//! with `diag_ggn` so the Eq.-19 rules live in exactly one place:
//! `diag_ggn::linear_diag_sqrt_signed` for `Linear`,
//! [`conv2d::diag_sqrt_signed`] for `Conv2d`.
//!
//! Convention (DESIGN.md §4): `diag(H)` with `H = (1/N) Σ_n ∇²ℓ_n` —
//! the `1/N` inside, matching `diag_ggn`, so shard outputs sum-reduce
//! (DESIGN.md §9). On all-ReLU networks every residual is zero and
//! `diag_h` coincides with `diag_ggn` (asserted in
//! `tests/conv_native.rs`); the Fig. 9 cost gap appears exactly when a
//! sigmoid inserts factors whose column count is the activation width.

use crate::runtime::{Tensor, TensorSpec};

use super::{
    diag_ggn, f32_spec, Extension, LayerCtx, LayerOp, Quantities,
    Walk,
};
use crate::backend::conv::conv2d;
use crate::backend::model::Model;

/// Exact Hessian diagonal: GGN part + signed residual recursion.
pub struct DiagH;

/// `out[key] += vals`, inserting on first touch — the GGN hook fires
/// before the residual hooks at each layer, so both accumulate into
/// one tensor.
fn accumulate(
    out: &mut Quantities,
    key: String,
    shape: &[usize],
    vals: Vec<f32>,
) {
    match out.get_mut(&key) {
        Some(acc) => {
            for (a, v) in acc
                .f32s_mut()
                .expect("diag_h tensors are f32")
                .iter_mut()
                .zip(&vals)
            {
                *a += v;
            }
        }
        None => {
            out.insert(key, Tensor::from_f32(shape, vals));
        }
    }
}

impl DiagH {
    /// Shared extraction of one propagated factor: column-squared
    /// contraction against the layer input, each column weighted by
    /// `signs` (`None` = all `+1`, the PSD main walk).
    fn contract(
        &self,
        ctx: &LayerCtx,
        s: &[f32],
        cols: usize,
        signs: Option<&[f32]>,
        out: &mut Quantities,
    ) {
        let (li, n, nf) = (ctx.li, ctx.n, ctx.norm);
        match ctx.op {
            LayerOp::Conv { geom, .. } => {
                let (dw, db) = conv2d::diag_sqrt_signed(
                    geom, ctx.input, s, n, cols, nf, signs,
                );
                accumulate(
                    out,
                    format!("diag_h/{li}/w"),
                    &geom.w_shape(),
                    dw,
                );
                accumulate(
                    out,
                    format!("diag_h/{li}/b"),
                    &[geom.out_shape.c],
                    db,
                );
            }
            LayerOp::Linear { din, dout, .. } => {
                let (dw, db) = diag_ggn::linear_diag_sqrt_signed(
                    ctx.input, s, n, din, dout, cols, nf, signs,
                );
                accumulate(
                    out,
                    format!("diag_h/{li}/w"),
                    &[dout, din],
                    dw,
                );
                accumulate(out, format!("diag_h/{li}/b"), &[dout], db);
            }
        }
    }
}

impl Extension for DiagH {
    fn name(&self) -> &str {
        "diag_h"
    }

    fn walk(&self) -> Walk {
        Walk::SqrtGgn
    }

    fn needs_residual(&self) -> bool {
        true
    }

    fn sqrt_ggn(
        &self,
        ctx: &LayerCtx,
        s: &[f32],
        cols: usize,
        out: &mut Quantities,
    ) {
        self.contract(ctx, s, cols, None, out);
    }

    fn residual(
        &self,
        ctx: &LayerCtx,
        s: &[f32],
        cols: usize,
        signs: &[f32],
        out: &mut Quantities,
    ) {
        self.contract(ctx, s, cols, Some(signs), out);
    }

    fn output_specs(&self, model: &Model, _batch: usize) -> Vec<TensorSpec> {
        let mut specs = Vec::new();
        for blk in model.param_blocks() {
            specs.push(f32_spec(
                format!("diag_h/{}/w", blk.li),
                blk.w_shape.clone(),
            ));
            specs.push(f32_spec(
                format!("diag_h/{}/b", blk.li),
                vec![blk.dout],
            ));
        }
        specs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulate_inserts_then_adds() {
        let mut out = Quantities::new();
        accumulate(&mut out, "diag_h/0/b".into(), &[2], vec![1.0, 2.0]);
        accumulate(
            &mut out,
            "diag_h/0/b".into(),
            &[2],
            vec![0.5, -2.5],
        );
        let t = out.get("diag_h/0/b").unwrap();
        assert_eq!(t.shape, vec![2]);
        // The residual part may drive entries negative: the full
        // Hessian is indefinite.
        assert_eq!(t.f32s().unwrap(), &[1.5, -0.5]);
    }

    #[test]
    fn linear_contraction_matches_dense_reference_with_signs() {
        // 2 samples, dout=2, cols=2, din=3: check the signed s2
        // contraction against explicit loops.
        let ctx_input = [
            1.0f32, -2.0, 0.5, // sample 0
            0.0, 1.0, 2.0, // sample 1
        ];
        let op = LayerOp::Linear {
            din: 3,
            dout: 2,
            w: &[0.0; 6],
            b: &[0.0; 2],
        };
        let ctx = LayerCtx::new(4, op, &ctx_input, 2, 2.0);
        let s = [
            0.3f32, -0.1, // s0 o0
            0.2, 0.4, // s0 o1
            -0.5, 0.6, // s1 o0
            0.1, 0.0, // s1 o1
        ];
        let signs = [1.0f32, -1.0, -1.0, 1.0];
        let mut out = Quantities::new();
        DiagH.residual(&ctx, &s, 2, &signs, &mut out);
        let dw = out.get("diag_h/4/w").unwrap().f32s().unwrap();
        let db = out.get("diag_h/4/b").unwrap().f32s().unwrap();
        // Dense reference.
        let mut want_w = vec![0.0f32; 6];
        let mut want_b = vec![0.0f32; 2];
        for smp in 0..2usize {
            for o in 0..2usize {
                let s2: f32 = (0..2)
                    .map(|c| {
                        signs[smp * 2 + c]
                            * s[(smp * 2 + o) * 2 + c].powi(2)
                    })
                    .sum();
                want_b[o] += s2 / 2.0;
                for i in 0..3usize {
                    want_w[o * 3 + i] +=
                        s2 * ctx_input[smp * 3 + i].powi(2) / 2.0;
                }
            }
        }
        for (got, want) in dw.iter().zip(&want_w) {
            assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        }
        for (got, want) in db.iter().zip(&want_b) {
            assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        }
    }
}
