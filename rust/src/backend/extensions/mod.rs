//! The extension engine: BackPACK quantities as pluggable modules.
//!
//! The paper's core architectural claim (§3) is that every extra
//! quantity — individual gradients, their statistics, curvature
//! proxies — is a *module* hooked into backpropagation, so a new
//! quantity never requires engine surgery. This module is the Rust
//! realization of that claim: the generalized backward pass in
//! [`Model`] walks the network **once per propagated quantity** and
//! dispatches, at every parameterized layer, to the [`Extension`]
//! implementations registered in an [`ExtensionSet`].
//!
//! An extension declares
//!
//! * which backward [`Walk`] feeds it (the per-sample output
//!   gradients `g [N, F]` of Eq. 3, the exact or Monte-Carlo
//!   square-root GGN `S [N, F, C]` of Eqs. 18/20, or KFRA's
//!   whole-shard batch averages of Eq. 24) — and, via
//!   [`Extension::needs_residual`], whether the exact walk should
//!   additionally carry the full Hessian's signed residual factors
//!   (`diag_h`, DESIGN.md §11), delivered per layer through the
//!   [`Extension::residual`] hook;
//! * a per-layer hook ([`Extension::first_order`] /
//!   [`Extension::sqrt_ggn`]) receiving a [`LayerCtx`] — the layer's
//!   operator view, its saved forward input, and the shard/global
//!   batch sizes — plus the incoming walk quantity;
//! * a shard-reduction rule ([`Extension::reduce`]) telling the
//!   batch-parallel engine (DESIGN.md §9) how its output keys merge
//!   across shards: [`ReduceRule::Sum`] for averaged quantities,
//!   [`ReduceRule::Concat`] for per-sample ones — applied by the
//!   crate-wide merge authority, [`ReducePlan`];
//! * an optional post-merge [`Extension::finish`] hook for quantities
//!   that are nonlinear in the merged averages (variance, KFRA's `Ḡ`
//!   recursion).
//!
//! # Quantity conventions (DESIGN.md §4)
//!
//! The loss is the **mean** over the batch (Eq. 1), and every
//! built-in follows Table 1's scalings:
//!
//! | quantity ([`Extension::name`]) | module | convention |
//! |---|---|---|
//! | `batch_grad` | [`first_order`] | individual gradients `(1/N)∇ℓ_n` |
//! | `batch_l2`   | [`first_order`] | `‖(1/N)∇ℓ_n‖²` per sample |
//! | `sq_moment`  | [`first_order`] | `(1/N)Σ_n [∇ℓ_n]²` |
//! | `variance`   | [`first_order`] | `(1/N)Σ_n [∇ℓ_n]² − [∇L]²` |
//! | `diag_ggn`   | [`diag_ggn`]    | `diag(G)`, `G = (1/N)Σ JᵀHJ` (Eq. 19) |
//! | `diag_ggn_mc`| [`diag_ggn`]    | Monte-Carlo `diag(G)` (Eq. 20) |
//! | `diag_h`     | [`diag_h`]      | `diag(H)`, `H = (1/N)Σ ∇²ℓ_n` (Fig. 9) |
//! | `kfac`       | [`kron`]        | `G ≈ A ⊗ B`, MC-sampled `B` (Eq. 23) |
//! | `kflr`       | [`kron`]        | `G ≈ A ⊗ B`, exact full-rank `B` |
//! | `kfra`       | [`kron`]        | batch-averaged `Ḡ` recursion (Eq. 24) |
//!
//! Kronecker blocks keep the `1/N` inside the factors and bias blocks
//! carry their full GGN (paper footnotes 7/8); `kfra` is restricted
//! to fully-connected models (footnote 5, enforced by
//! [`Extension::fully_connected_only`]).
//!
//! # Registering a user-defined extension
//!
//! New quantities drop in without touching the engine. A per-sample
//! bias-gradient L2 norm, end to end:
//!
//! ```
//! use backpack_rs::backend::extensions::{
//!     Extension, ExtensionSet, LayerCtx, Quantities, Reduce, Walk,
//! };
//! use backpack_rs::backend::model::{
//!     ExtractOptions, Model, Topology,
//! };
//! use backpack_rs::runtime::Tensor;
//!
//! /// `‖(1/N) ∇_b ℓ_n‖²` per sample — a quantity the engine has
//! /// never heard of.
//! struct BiasL2;
//!
//! impl Extension for BiasL2 {
//!     fn name(&self) -> &str {
//!         "bias_l2"
//!     }
//!
//!     fn walk(&self) -> Walk {
//!         Walk::Grad
//!     }
//!
//!     fn first_order(
//!         &self,
//!         ctx: &LayerCtx,
//!         g: &[f32],
//!         out: &mut Quantities,
//!     ) {
//!         let dout = ctx.op.dout();
//!         let ps = ctx.per_sample_grads(g);
//!         let l2: Vec<f32> = (0..ctx.n)
//!             .map(|s| {
//!                 ps.b[s * dout..(s + 1) * dout]
//!                     .iter()
//!                     .map(|v| (v / ctx.norm) * (v / ctx.norm))
//!                     .sum()
//!             })
//!             .collect();
//!         out.insert(
//!             format!("bias_l2/{}/b", ctx.li),
//!             Tensor::from_f32(&[ctx.n], l2),
//!         );
//!     }
//!
//!     /// Per-sample outputs concatenate across batch shards.
//!     fn reduce(&self, key: &str) -> Option<Reduce> {
//!         key.starts_with("bias_l2/").then_some(Reduce::Concat)
//!     }
//! }
//!
//! let mut set = ExtensionSet::builtin();
//! set.register(BiasL2);
//!
//! let m = Model::logreg();
//! let params: Vec<Tensor> = m
//!     .param_specs()
//!     .iter()
//!     .map(|t| Tensor::zeros(&t.shape))
//!     .collect();
//! let x = Tensor::from_f32(&[4, 784], vec![0.1; 4 * 784]);
//! let y = Tensor::from_i32(&[4], vec![0, 1, 2, 3]);
//! let out = m
//!     .extended_backward(
//!         &params,
//!         &x,
//!         &y,
//!         &["bias_l2".to_string()],
//!         &ExtractOptions {
//!             registry: Some(set.clone()),
//!             // sharded: Reduce::Concat applies
//!             topology: Topology::local(2),
//!             ..ExtractOptions::default()
//!         },
//!     )
//!     .unwrap();
//! assert_eq!(out["bias_l2/0/b"].shape, vec![4]);
//! ```
//!
//! The same object can be served through the full backend path with
//! [`crate::backend::native::NativeBackend::register_extension`],
//! which makes `{model}_bias_l2_n{batch}` a resolvable artifact name.

use std::cell::{Ref, RefCell};
use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use super::conv::{conv2d, ConvGeom};
use super::model::Model;
use crate::runtime::{Tensor, TensorData, TensorSpec};

pub mod diag_ggn;
pub mod diag_h;
pub mod first_order;
pub mod kron;

pub use diag_ggn::DiagGgn;
pub use diag_h::DiagH;
pub use first_order::{BatchGrad, BatchL2, SqMoment, Variance};
pub use kron::{Kfac, Kflr, Kfra};

/// Named output map of one engine call: `loss`, `grad/*`, and every
/// requested `{extension}/{layer}/{part}` quantity.
pub type Quantities = BTreeMap<String, Tensor>;

/// Extension names built into [`ExtensionSet::builtin`] — the paper's
/// ten quantities, in registry (hook-dispatch) order. `diag_h` rides
/// the exact square-root-GGN walk and additionally consumes the signed
/// residual factors of the full-Hessian recursion (DESIGN.md §11).
pub const BUILTIN_NAMES: &[&str] = &[
    "batch_grad", "batch_l2", "sq_moment", "variance",
    "diag_ggn", "diag_ggn_mc", "diag_h", "kfac", "kflr", "kfra",
];

/// Which propagated backward quantity feeds an extension's layer
/// hook. The engine runs one walk per variant that has at least one
/// active user, so e.g. `diag_ggn` and `kflr` share a single exact-`S`
/// propagation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Walk {
    /// Per-sample output gradients `g [N, F]` (Eq. 3); always
    /// propagated — the engine extracts `grad/*` from it.
    Grad,
    /// Exact square-root GGN `S [N, F, C]` (Eq. 18, `C` = classes).
    SqrtGgn,
    /// Monte-Carlo square-root GGN `S [N, F, M]` (Eq. 20, `M` =
    /// [`crate::backend::model::MC_SAMPLES`]); draws are keyed by each
    /// sample's global batch index, so results are shard-layout
    /// invariant.
    SqrtGgnMc,
    /// No propagated quantity: the extension consumes whole-shard
    /// batch averages through [`Extension::batch_averages`] (KFRA).
    Shard,
}

/// How one output key merges across batch shards (DESIGN.md §9) —
/// the rule half of the crate's public reduce contract.
///
/// Every consumer of shard outputs — the thread-shard merge in
/// [`Model::extended_backward`], the serve scheduler's per-client
/// slicing, and the process-parallel coordinator in [`crate::dist`] —
/// derives its behavior from this rule via [`ReducePlan`]; there is
/// deliberately no other reduce authority in the crate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceRule {
    /// Elementwise sum — correct for every quantity already
    /// normalized by the global batch size.
    Sum,
    /// Concatenate along the leading (batch) axis, in shard (= sample)
    /// order — for per-sample quantities.
    Concat,
}

/// Back-compat alias for [`ReduceRule`] (the pre-distributed name).
/// Enum variants resolve through the alias, so existing
/// `Reduce::Sum` / `Reduce::Concat` spellings keep compiling.
pub type Reduce = ReduceRule;

/// Operator view of one parameterized layer, bound from the input
/// parameter tensors for the duration of one engine call.
#[derive(Clone, Copy)]
pub enum LayerOp<'a> {
    /// `z = x Wᵀ + b` with `w [dout, din]` row-major, `b [dout]`.
    Linear {
        /// Input feature count.
        din: usize,
        /// Output feature count.
        dout: usize,
        /// Weight matrix, `[dout, din]` row-major.
        w: &'a [f32],
        /// Bias vector, `[dout]`.
        b: &'a [f32],
    },
    /// im2col-lowered convolution (DESIGN.md §6): `w` is the
    /// `[c_out, J]` matrix view of the `[c_out, c_in, k, k]` parameter
    /// tensor (`J = c_in·k²`).
    Conv {
        /// Resolved spatial geometry of the convolution.
        geom: &'a ConvGeom,
        /// Weight matrix view, `[c_out, J]` row-major.
        w: &'a [f32],
        /// Bias vector, `[c_out]`.
        b: &'a [f32],
    },
}

impl<'a> LayerOp<'a> {
    /// The weight matrix view (`[dout, a_dim]` row-major).
    pub fn w(&self) -> &'a [f32] {
        match *self {
            LayerOp::Linear { w, .. } | LayerOp::Conv { w, .. } => w,
        }
    }

    /// The bias vector (`[dout]`).
    pub fn b(&self) -> &'a [f32] {
        match *self {
            LayerOp::Linear { b, .. } | LayerOp::Conv { b, .. } => b,
        }
    }

    /// Kronecker `A`-side dimension: `din` for `Linear`, the im2col
    /// patch length `J = c_in·k²` for `Conv2d`.
    pub fn a_dim(&self) -> usize {
        match self {
            LayerOp::Linear { din, .. } => *din,
            LayerOp::Conv { geom, .. } => geom.patch_len(),
        }
    }

    /// Kronecker `B`-side dimension: output features for `Linear`,
    /// output channels for `Conv2d`.
    pub fn dout(&self) -> usize {
        match self {
            LayerOp::Linear { dout, .. } => *dout,
            LayerOp::Conv { geom, .. } => geom.out_shape.c,
        }
    }

    /// Parameter-tensor shape of the weight: `[dout, din]` for
    /// `Linear`, `[c_out, c_in, k, k]` for `Conv2d`.
    pub fn w_shape(&self) -> Vec<usize> {
        match self {
            LayerOp::Linear { din, dout, .. } => vec![*dout, *din],
            LayerOp::Conv { geom, .. } => geom.w_shape(),
        }
    }
}

/// Unnormalized per-sample parameter gradients of one layer — the
/// shared intermediate of the first-order extraction rules, computed
/// at most once per layer via [`LayerCtx::per_sample_grads`].
pub struct PerSampleGrads {
    /// `[n, dout, a_dim]` row-major: sample `s`'s weight gradient
    /// `g_s x_sᵀ` (`Linear`) or `G_s ⟦x⟧_sᵀ` (`Conv2d`), **not** yet
    /// divided by the global batch size.
    pub w: Vec<f32>,
    /// `[n, dout]`: per-sample bias gradients (position-summed for
    /// `Conv2d`), unnormalized.
    pub b: Vec<f32>,
}

/// Everything an [`Extension`] layer hook sees at one parameterized
/// layer of one batch shard.
pub struct LayerCtx<'a> {
    /// Index of the layer in [`Model::layers`].
    pub li: usize,
    /// The layer's bound operator (weights, bias, geometry).
    pub op: LayerOp<'a>,
    /// Saved forward input of this layer, `[n * in_features]`
    /// row-major (paper Fig. 2: the module input stored by the
    /// forward pass).
    pub input: &'a [f32],
    /// Sample count of this shard.
    pub n: usize,
    /// The **global** batch size, as `f32` — averaged quantities
    /// divide by this so shard outputs sum-reduce exactly
    /// (DESIGN.md §9).
    pub norm: f32,
    psg: RefCell<Option<PerSampleGrads>>,
}

impl<'a> LayerCtx<'a> {
    /// Context for one layer of one shard (engine-internal; public so
    /// tests and doctests can drive hooks directly).
    pub fn new(
        li: usize,
        op: LayerOp<'a>,
        input: &'a [f32],
        n: usize,
        norm: f32,
    ) -> LayerCtx<'a> {
        LayerCtx { li, op, input, n, norm, psg: RefCell::new(None) }
    }

    /// Unnormalized per-sample parameter gradients for the incoming
    /// output gradients `g [n, dout_features]`, materialized lazily
    /// and cached for the layer — so the engine's `grad` reduction
    /// and every first-order extension share one `G_n ⟦x⟧_nᵀ` product
    /// per sample instead of each recomputing it.
    ///
    /// **Contract:** `g` must be the walk's propagated output
    /// gradient for this layer — the exact slice the
    /// [`Extension::first_order`] hook received. The first call fills
    /// the cache; repeated calls (even with a previous [`Ref`] still
    /// alive) return the cached value *without* re-reading `g`, so
    /// passing a transformed gradient here returns stale data. An
    /// extension that backpropagates its own modified quantity must
    /// compute from `ctx.input` directly instead.
    ///
    /// The cache trades `O(n·dout·a_dim)` shard-local memory
    /// for that sharing; an extension that only needs a streaming
    /// fold over samples is free to compute from `ctx.input` and `g`
    /// directly instead.
    pub fn per_sample_grads(&self, g: &[f32]) -> Ref<'_, PerSampleGrads> {
        if self.psg.borrow().is_none() {
            let mut slot = self.psg.borrow_mut();
            if slot.is_none() {
                *slot = Some(match self.op {
                    LayerOp::Linear { din, dout, .. } => {
                        let mut w = vec![0.0f32; self.n * dout * din];
                        for s in 0..self.n {
                            for o in 0..dout {
                                let gv = g[s * dout + o];
                                let row = (s * dout + o) * din;
                                for i in 0..din {
                                    w[row + i] =
                                        gv * self.input[s * din + i];
                                }
                            }
                        }
                        PerSampleGrads { w, b: g.to_vec() }
                    }
                    LayerOp::Conv { geom, .. } => {
                        let (w, b) = conv2d::per_sample_grads(
                            geom, self.input, g, self.n,
                        );
                        PerSampleGrads { w, b }
                    }
                });
            }
        }
        Ref::map(self.psg.borrow(), |o| {
            o.as_ref().expect("filled above")
        })
    }
}

/// Whole-shard view for [`Walk::Shard`] extensions (KFRA): the model,
/// every bound layer operator, and all stored forward activations.
pub struct ShardCtx<'a> {
    /// The model being differentiated.
    pub model: &'a Model,
    /// Bound operator per layer (`None` for parameter-free layers),
    /// aligned with [`Model::layers`].
    pub ops: &'a [Option<LayerOp<'a>>],
    /// Stored forward activations, `acts[li]` = input of layer `li`,
    /// `acts.last()` = logits (`len = layers.len() + 1`).
    pub acts: &'a [Vec<f32>],
    /// Flat feature dimension before each layer (`dims[li]`).
    pub dims: &'a [usize],
    /// Sample count of this shard.
    pub n: usize,
    /// Global batch size normalizer (see [`LayerCtx::norm`]).
    pub norm: f32,
}

/// Post-merge view for [`Extension::finish`]: runs once, after the
/// shard outputs were reduced, with the layer operators still bound.
pub struct FinishCtx<'a> {
    /// The model being differentiated.
    pub model: &'a Model,
    /// Bound operator per layer, aligned with [`Model::layers`].
    pub ops: &'a [Option<LayerOp<'a>>],
    /// Flat feature dimension before each layer.
    pub dims: &'a [usize],
    /// Worker count of the engine call (for parallel post-merge
    /// linear algebra, e.g. KFRA's `Wᵀ Ḡ W`).
    pub threads: usize,
    /// The extension names requested for this engine call.
    pub extensions: &'a [String],
}

impl FinishCtx<'_> {
    /// True when `name` was explicitly requested — lets an extension
    /// drop intermediates another quantity only computed on its
    /// behalf (variance drops `sq_moment/*` unless also requested).
    pub fn requested(&self, name: &str) -> bool {
        self.extensions.iter().any(|e| e == name)
    }
}

/// One BackPACK quantity as a backprop module (paper §3).
///
/// Implementations declare which [`Walk`] feeds them, extract their
/// quantity in a per-layer hook, and describe how their outputs merge
/// across batch shards. All hooks default to no-ops so an extension
/// only implements the walk it consumes. See the
/// [module docs](crate::backend::extensions) for a complete
/// user-defined example.
pub trait Extension: Send + Sync {
    /// Manifest name: output keys are `{name}/{layer}/{part}` and the
    /// artifact signature joins names with `+`.
    fn name(&self) -> &str;

    /// Which propagated backward quantity feeds this extension.
    fn walk(&self) -> Walk;

    /// True when the extension is only defined for fully-connected
    /// models (paper footnote 5: KFRA).
    fn fully_connected_only(&self) -> bool {
        false
    }

    /// True when the extension consumes Monte-Carlo draws and thus
    /// needs a PRNG key input.
    fn needs_key(&self) -> bool {
        self.walk() == Walk::SqrtGgnMc
    }

    /// True when the extension consumes the signed residual factors of
    /// the full-Hessian recursion (`diag_h`, DESIGN.md §11). Only
    /// meaningful for [`Walk::SqrtGgn`] extensions: the engine then
    /// records `σ''(x) ⊙ g` at every curved activation during the
    /// first-order walk, propagates one signed diagonal square-root
    /// factor per such layer alongside the exact `S`, and delivers
    /// each factor through [`Extension::residual`].
    fn needs_residual(&self) -> bool {
        false
    }

    /// Layer hook for [`Walk::Grad`] extensions: `g [n, dout_feat]`
    /// are the (unnormalized) per-sample gradients of the loss w.r.t.
    /// this layer's output.
    fn first_order(
        &self,
        ctx: &LayerCtx,
        g: &[f32],
        out: &mut Quantities,
    ) {
        let _ = (ctx, g, out);
    }

    /// Layer hook for [`Walk::SqrtGgn`] / [`Walk::SqrtGgnMc`]
    /// extensions: `s [n, dout_feat, cols]` is the propagated
    /// square-root GGN at this layer's output.
    fn sqrt_ggn(
        &self,
        ctx: &LayerCtx,
        s: &[f32],
        cols: usize,
        out: &mut Quantities,
    ) {
        let _ = (ctx, s, cols, out);
    }

    /// Layer hook for [`Extension::needs_residual`] extensions: one
    /// signed residual factor of the full-Hessian recursion, in the
    /// same `[n, dout_feat, cols]` layout as [`Extension::sqrt_ggn`]'s
    /// `s`, plus the per-(sample, column) sign weights
    /// `signs [n · cols]` (±1; the factor value already carries
    /// `√|σ''(x) ⊙ g|`). Called once per live factor per parameterized
    /// layer, *after* `sqrt_ggn` at the same layer, so implementations
    /// accumulate into the keys the main walk created.
    fn residual(
        &self,
        ctx: &LayerCtx,
        s: &[f32],
        cols: usize,
        signs: &[f32],
        out: &mut Quantities,
    ) {
        let _ = (ctx, s, cols, signs, out);
    }

    /// Whole-shard hook for [`Walk::Shard`] extensions, called once
    /// per shard after the forward pass (KFRA emits the batch
    /// averages its post-merge recursion consumes).
    fn batch_averages(&self, ctx: &ShardCtx, out: &mut Quantities) {
        let _ = (ctx, out);
    }

    /// Shard-reduction rule for one output key this extension emitted
    /// (the PR-2 parallel semantics, DESIGN.md §9). Return `None` for
    /// keys this extension does not own; unclaimed keys sum-reduce.
    /// The default claims `{name}/…` as [`ReduceRule::Sum`].
    fn reduce(&self, key: &str) -> Option<Reduce> {
        key.strip_prefix(self.name())
            .is_some_and(|rest| rest.starts_with('/'))
            .then_some(Reduce::Sum)
    }

    /// Post-merge hook, run once after the shard reduction with the
    /// layer operators still bound — for quantities that are
    /// nonlinear in the merged averages.
    fn finish(&self, ctx: &FinishCtx, out: &mut Quantities) -> Result<()> {
        let _ = (ctx, out);
        Ok(())
    }

    /// Output tensor specs for artifact synthesis
    /// (`NativeBackend::spec`). Only consulted when the extension is
    /// served through a [`crate::backend::Backend`]; extensions driven
    /// directly through [`Model::extended_backward_with`] may keep the
    /// default (empty).
    fn output_specs(&self, model: &Model, batch: usize) -> Vec<TensorSpec> {
        let _ = (model, batch);
        Vec::new()
    }
}

/// An `f32` output spec with no init rule (the shape declarations
/// extensions hand to artifact synthesis).
pub(crate) fn f32_spec(name: String, shape: Vec<usize>) -> TensorSpec {
    TensorSpec { name, shape, dtype: "f32".to_string(), init: None }
}

/// Open an observability span for one hook dispatch, named
/// `{quantity}/{hook}` (e.g. `diag_ggn/sqrt_ggn`) under
/// [`crate::obs::CAT_EXT`] — the engine wraps every [`Extension`]
/// hook call in one of these, which is what makes per-quantity time
/// attribution possible. Free when the recorder is disabled.
pub(crate) fn hook_span(
    e: &dyn Extension,
    hook: &'static str,
) -> crate::obs::Span {
    crate::obs::span_with(crate::obs::CAT_EXT, || {
        format!("{}/{hook}", e.name())
    })
}

/// A registry of [`Extension`] modules, dispatched through by the
/// engine ([`Model::extended_backward_with`]) and by artifact
/// synthesis ([`crate::backend::native::NativeBackend`]).
///
/// Cloning is cheap (the modules are shared), so a backend and every
/// computation it loads can hold the same registry.
#[derive(Clone, Default)]
pub struct ExtensionSet {
    exts: Vec<Arc<dyn Extension>>,
}

impl ExtensionSet {
    /// An empty registry (engine runs extract `loss` + `grad/*` only).
    pub fn empty() -> ExtensionSet {
        ExtensionSet { exts: Vec::new() }
    }

    /// The paper's ten quantities ([`BUILTIN_NAMES`], in that order).
    pub fn builtin() -> ExtensionSet {
        let mut set = ExtensionSet::empty();
        set.register(BatchGrad);
        set.register(BatchL2);
        set.register(SqMoment);
        set.register(Variance);
        set.register(DiagGgn::exact());
        set.register(DiagGgn::mc());
        set.register(DiagH);
        set.register(Kfac);
        set.register(Kflr);
        set.register(Kfra);
        set
    }

    /// Register an extension. A module with the same
    /// [`Extension::name`] is replaced in place, so built-ins can be
    /// overridden; new names append in registration order (which is
    /// also hook-dispatch order).
    ///
    /// # Panics
    ///
    /// Panics on names the output-key and artifact-name grammars
    /// cannot represent ([`Signature::check_part`], the single
    /// grammar authority): empty, containing `+` (the signature
    /// separator), `/` (the output-key separator) or whitespace, the
    /// reserved words `grad` / `eval`, or a trailing `_n<digits>`
    /// (the batch suffix [`ArtifactId::split_batch`] would strip).
    ///
    /// [`Signature::check_part`]: crate::backend::api::Signature::check_part
    /// [`ArtifactId::split_batch`]: crate::backend::api::ArtifactId::split_batch
    pub fn register(&mut self, ext: impl Extension + 'static) {
        let ext: Arc<dyn Extension> = Arc::new(ext);
        if let Err(e) =
            crate::backend::api::Signature::check_part(ext.name())
        {
            panic!("{e}");
        }
        if let Some(slot) =
            self.exts.iter_mut().find(|e| e.name() == ext.name())
        {
            *slot = ext;
        } else {
            self.exts.push(ext);
        }
    }

    /// Registered extension names, in dispatch order.
    pub fn names(&self) -> Vec<&str> {
        self.exts.iter().map(|e| e.name()).collect()
    }

    /// True when an extension with this name is registered.
    pub fn contains(&self, name: &str) -> bool {
        self.exts.iter().any(|e| e.name() == name)
    }

    /// Look up one registered extension by name.
    pub fn get(&self, name: &str) -> Option<&dyn Extension> {
        self.exts
            .iter()
            .find(|e| e.name() == name)
            .map(|e| e.as_ref())
    }

    /// Resolve requested names to modules (in dispatch order,
    /// duplicates collapsed); errors on any unregistered name.
    pub fn select(&self, requested: &[String]) -> Result<Vec<&dyn Extension>> {
        for name in requested {
            ensure!(
                self.contains(name),
                "extension {name:?} is not supported by the native \
                 backend (registered: {:?}){}",
                self.names(),
                crate::backend::api::did_you_mean(
                    &crate::backend::api::suggest(name, self.names())
                )
            );
        }
        Ok(self
            .exts
            .iter()
            .filter(|e| requested.iter().any(|r| r == e.name()))
            .map(|e| e.as_ref())
            .collect())
    }

    /// Shard-reduction rule for an output key: the first registered
    /// extension claiming the key decides; unclaimed keys (`loss`,
    /// `grad/*`, internal partials) sum-reduce.
    pub fn reduce(&self, key: &str) -> Reduce {
        self.exts
            .iter()
            .find_map(|e| e.reduce(key))
            .unwrap_or(Reduce::Sum)
    }
}

impl std::fmt::Debug for ExtensionSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("ExtensionSet").field(&self.names()).finish()
    }
}

/// The crate's single shard-merge authority: per-key [`ReduceRule`]
/// lookup plus the merge primitives every consumer of shard outputs
/// shares — the thread-shard merge in
/// [`Model::extended_backward`](crate::backend::model::Model::extended_backward),
/// the serve scheduler's per-client Concat slicing, and the
/// process-parallel coordinator in [`crate::dist`].
///
/// A plan is built from an [`ExtensionSet`]; the rule for a key is
/// whatever the first registered extension claiming it declares
/// through [`Extension::reduce`], with unclaimed keys (`loss`,
/// `grad/*`, internal partials like `__kfra/*`) defaulting to
/// [`ReduceRule::Sum`]. Cloning is cheap (the underlying modules are
/// `Arc`-shared).
///
/// Shard parts handed to [`ReducePlan::merge`] must arrive in global
/// sample order — Concat keys gather by simple append, which is what
/// makes thread-shard, serve-client, and worker-process merges
/// bitwise identical for per-sample quantities.
///
/// A user-defined extension opts into the contract by declaring its
/// rule; the plan then merges its keys with no engine changes:
///
/// ```
/// use backpack_rs::backend::extensions::{
///     Extension, ExtensionSet, Quantities, ReducePlan, ReduceRule,
///     Walk,
/// };
/// use backpack_rs::runtime::Tensor;
///
/// struct RowStat;
/// impl Extension for RowStat {
///     fn name(&self) -> &str {
///         "row_stat"
///     }
///     fn walk(&self) -> Walk {
///         Walk::Grad
///     }
///     /// Per-sample rows concatenate across shards.
///     fn reduce(&self, key: &str) -> Option<ReduceRule> {
///         key.starts_with("row_stat/")
///             .then_some(ReduceRule::Concat)
///     }
/// }
///
/// let mut set = ExtensionSet::builtin();
/// set.register(RowStat);
/// let plan = ReducePlan::of(&set);
/// assert_eq!(plan.rule("row_stat/0/w"), ReduceRule::Concat);
/// assert_eq!(plan.rule("grad/0/w"), ReduceRule::Sum);
///
/// // Two shards in sample order: Concat keys gather, Sum keys add.
/// let shard = |lo: f32| {
///     let mut q = Quantities::new();
///     q.insert(
///         "row_stat/0/w".to_string(),
///         Tensor::from_f32(&[2], vec![lo, lo + 1.0]),
///     );
///     q.insert(
///         "grad/0/w".to_string(),
///         Tensor::from_f32(&[2], vec![0.5, 0.25]),
///     );
///     q
/// };
/// let merged = plan.merge(vec![shard(0.0), shard(2.0)]).unwrap();
/// assert_eq!(
///     merged["row_stat/0/w"].f32s().unwrap(),
///     &[0.0, 1.0, 2.0, 3.0]
/// );
/// assert_eq!(merged["grad/0/w"].f32s().unwrap(), &[1.0, 0.5]);
/// ```
#[derive(Clone, Debug)]
pub struct ReducePlan {
    set: ExtensionSet,
}

impl ReducePlan {
    /// Build the plan for a registry (cheap: shares the modules).
    pub fn of(set: &ExtensionSet) -> ReducePlan {
        ReducePlan { set: set.clone() }
    }

    /// The merge rule for one output key (see [`ExtensionSet::reduce`]).
    pub fn rule(&self, key: &str) -> ReduceRule {
        self.set.reduce(key)
    }

    /// True when `key` carries per-sample rows (a [`ReduceRule::Concat`]
    /// key) — the predicate behind per-client slicing in the serve
    /// scheduler and per-worker gathering in the coordinator.
    pub fn is_concat(&self, key: &str) -> bool {
        self.rule(key) == ReduceRule::Concat
    }

    /// Fold one shard's output into the accumulator. `part` must come
    /// from the sample range immediately following everything already
    /// merged into `acc` (Concat keys append in order). The key sets
    /// must match exactly — a drift between shard outputs is a bug,
    /// not a mergeable state.
    pub fn merge_into(
        &self,
        acc: &mut Quantities,
        part: Quantities,
    ) -> Result<()> {
        ensure!(
            part.len() == acc.len(),
            "shard output key sets differ"
        );
        for (k, v) in part {
            let Some(slot) = acc.get_mut(&k) else {
                bail!("shard output key mismatch: {k:?}")
            };
            match self.rule(&k) {
                ReduceRule::Concat => append_rows(slot, v)?,
                ReduceRule::Sum => add_into(slot, &v)?,
            }
        }
        Ok(())
    }

    /// Merge shard outputs arriving in global sample order:
    /// [`ReduceRule::Concat`] keys concatenate along the batch axis,
    /// [`ReduceRule::Sum`] keys — already normalized by the global
    /// batch size — sum elementwise.
    pub fn merge(&self, parts: Vec<Quantities>) -> Result<Quantities> {
        let mut it = parts.into_iter();
        let Some(mut out) = it.next() else {
            bail!("merge of zero shard outputs")
        };
        for part in it {
            self.merge_into(&mut out, part)?;
        }
        Ok(out)
    }
}

/// Concatenate `more` onto `acc` along the leading (batch) axis.
fn append_rows(acc: &mut Tensor, more: Tensor) -> Result<()> {
    ensure!(
        acc.shape.len() == more.shape.len()
            && acc.shape[1..] == more.shape[1..],
        "batch concat shape mismatch: {:?} vs {:?}",
        acc.shape,
        more.shape
    );
    let add = more.shape.first().copied().unwrap_or(0);
    match (&mut acc.data, more.data) {
        (TensorData::F32(a), TensorData::F32(b)) => a.extend(b),
        _ => bail!("batch concat expects f32 tensors"),
    }
    acc.shape[0] += add;
    Ok(())
}

/// Elementwise `acc += more` (same shape).
fn add_into(acc: &mut Tensor, more: &Tensor) -> Result<()> {
    ensure!(
        acc.shape == more.shape,
        "sum-reduce shape mismatch: {:?} vs {:?}",
        acc.shape,
        more.shape
    );
    let b = more.f32s()?;
    for (x, y) in acc.f32s_mut()?.iter_mut().zip(b) {
        *x += *y;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_set_matches_the_published_name_list() {
        let set = ExtensionSet::builtin();
        assert_eq!(set.names(), BUILTIN_NAMES.to_vec());
        assert!(set.contains("kfac"));
        assert!(set.contains("diag_h"));
        assert!(set.get("kfra").unwrap().fully_connected_only());
        assert!(set.get("kfac").unwrap().needs_key());
        assert!(set.get("diag_ggn_mc").unwrap().needs_key());
        assert!(!set.get("diag_ggn").unwrap().needs_key());
        assert!(!set.get("batch_grad").unwrap().needs_key());
        // diag_h: exact walk + residual factors, no MC key.
        let dh = set.get("diag_h").unwrap();
        assert_eq!(dh.walk(), Walk::SqrtGgn);
        assert!(dh.needs_residual());
        assert!(!dh.needs_key());
        assert!(!dh.fully_connected_only());
        assert!(!set.get("diag_ggn").unwrap().needs_residual());
    }

    #[test]
    fn select_validates_and_keeps_dispatch_order() {
        let set = ExtensionSet::builtin();
        let req =
            vec!["kfac".to_string(), "batch_grad".to_string()];
        let picked = set.select(&req).unwrap();
        // Dispatch order is registry order, not request order.
        assert_eq!(
            picked.iter().map(|e| e.name()).collect::<Vec<_>>(),
            vec!["batch_grad", "kfac"]
        );
        let err = set
            .select(&["hessian".to_string()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("not supported"), "{err}");
    }

    #[test]
    fn reduction_rules_follow_table_1() {
        let set = ExtensionSet::builtin();
        assert_eq!(set.reduce("batch_grad/0/w"), Reduce::Concat);
        assert_eq!(set.reduce("batch_l2/2/b"), Reduce::Concat);
        assert_eq!(set.reduce("grad/0/w"), Reduce::Sum);
        assert_eq!(set.reduce("sq_moment/0/w"), Reduce::Sum);
        assert_eq!(set.reduce("diag_h/0/w"), Reduce::Sum);
        assert_eq!(set.reduce("kfac/0/A"), Reduce::Sum);
        assert_eq!(set.reduce("__kfra/h"), Reduce::Sum);
        assert_eq!(set.reduce("loss"), Reduce::Sum);
        // Prefix matching is exact up to the separator: the
        // "diag_ggn" claim must not swallow "diag_ggn_mc/…".
        assert_eq!(set.reduce("diag_ggn_mc/0/w"), Reduce::Sum);
        // A name that prefixes another without the separator is not
        // claimed ("batch_grad" vs "batch_gradx/…").
        assert_eq!(set.reduce("batch_gradx/0/w"), Reduce::Sum);
    }

    #[test]
    #[should_panic(expected = "not a valid signature part")]
    fn register_rejects_names_the_artifact_grammar_cannot_parse() {
        struct Bad;
        impl Extension for Bad {
            fn name(&self) -> &str {
                "a+b"
            }
            fn walk(&self) -> Walk {
                Walk::Grad
            }
        }
        ExtensionSet::empty().register(Bad);
    }

    #[test]
    #[should_panic(expected = "batch suffix")]
    fn register_rejects_names_with_a_batch_suffix() {
        struct Bad;
        impl Extension for Bad {
            fn name(&self) -> &str {
                "mine_n64"
            }
            fn walk(&self) -> Walk {
                Walk::Grad
            }
        }
        ExtensionSet::empty().register(Bad);
    }

    #[test]
    fn register_accepts_underscore_n_when_not_a_batch_suffix() {
        struct Fine;
        impl Extension for Fine {
            fn name(&self) -> &str {
                "my_norm"
            }
            fn walk(&self) -> Walk {
                Walk::Grad
            }
        }
        let mut set = ExtensionSet::empty();
        set.register(Fine);
        assert!(set.contains("my_norm"));
    }

    #[test]
    fn register_replaces_same_name_in_place() {
        struct Fake;
        impl Extension for Fake {
            fn name(&self) -> &str {
                "batch_l2"
            }
            fn walk(&self) -> Walk {
                Walk::Grad
            }
        }
        let mut set = ExtensionSet::builtin();
        let before = set.names().len();
        set.register(Fake);
        assert_eq!(set.names().len(), before);
        // Replacement keeps the slot but swaps the module: the fake
        // inherits the default prefix rule (Sum), dropping the
        // built-in's Concat override.
        assert_eq!(set.reduce("batch_l2/0/w"), Reduce::Sum);
    }
}
