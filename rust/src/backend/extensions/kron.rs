//! Kronecker-factored curvature extensions (paper Eqs. 23–24):
//! `kfac`, `kflr`, and `kfra`.
//!
//! Convention (DESIGN.md §4): each parameter block's GGN is
//! approximated as `G^(i) ≈ A^(i) ⊗ B^(i)` with the `1/N` **inside**
//! the factors, and bias blocks carry their full GGN (`bias_ggn`,
//! paper footnotes 7/8):
//!
//! * `A = (1/N) Σ_n x_n x_nᵀ` for `Linear`; the unfolded-input factor
//!   `(1/N) Σ_n ⟦x⟧_n ⟦x⟧_nᵀ` (positions folded into the contraction)
//!   for `Conv2d` — the Grosse & Martens (2016) KFC convention
//!   (DESIGN.md §6);
//! * `B = (1/N) Σ_n S_n S_nᵀ` from the propagated square root — exact
//!   (`kflr`, [`Walk::SqrtGgn`]) or Monte-Carlo (`kfac`,
//!   [`Walk::SqrtGgnMc`]); conv `B` is additionally
//!   position-averaged (`1/(N·P)`), reducing exactly to the `Linear`
//!   factor at `P = 1`;
//! * `kfra` instead propagates the **batch-averaged** curvature `Ḡ`
//!   (Eq. 24). The recursion is nonlinear in the batch averages, so
//!   the shard phase ([`Extension::batch_averages`]) emits the
//!   averages it consumes — `A` per `Linear`, activation second
//!   moments `(1/N) Σ_n m_n m_nᵀ` (`m = σ'(x)`) under internal
//!   `__kfra/…` keys, and the output-Hessian mean — and the recursion
//!   runs once on the merged values in [`Extension::finish`]. KFRA is
//!   fully-connected-only (paper footnote 5): weight sharing makes
//!   the conv `Ḡ` both enormous and structurally wrong to average.

use anyhow::{bail, Result};

use crate::linalg::{matmul_nt, matmul_par, matmul_tn, matmul_tn_par};
use crate::runtime::{Tensor, TensorSpec};

use super::{
    f32_spec, Extension, FinishCtx, LayerCtx, LayerOp, Quantities,
    ShardCtx, Walk,
};
use crate::backend::conv::conv2d;
use crate::backend::loss::CrossEntropy;
use crate::backend::model::Model;

/// `A`/`B`/`bias_ggn` factor extraction shared by [`Kfac`] and
/// [`Kflr`] (they differ only in which square root is propagated).
fn kron_factors_at(
    name: &str,
    ctx: &LayerCtx,
    s: &[f32],
    cols: usize,
    out: &mut Quantities,
) {
    let (li, n, nf) = (ctx.li, ctx.n, ctx.norm);
    match ctx.op {
        LayerOp::Conv { geom, .. } => {
            let (a, b, bias) =
                conv2d::kron_factors(geom, ctx.input, s, n, cols, nf);
            let (j, co) = (geom.patch_len(), geom.out_shape.c);
            out.insert(
                format!("{name}/{li}/A"),
                Tensor::from_f32(&[j, j], a),
            );
            out.insert(
                format!("{name}/{li}/bias_ggn"),
                Tensor::from_f32(&[co, co], bias),
            );
            out.insert(
                format!("{name}/{li}/B"),
                Tensor::from_f32(&[co, co], b),
            );
        }
        LayerOp::Linear { din, dout, .. } => {
            let inp = ctx.input;
            let mut a = matmul_tn(inp, inp, n, din, din);
            for v in &mut a {
                *v /= nf;
            }
            let mut b = vec![0.0f32; dout * dout];
            for smp in 0..n {
                let blk =
                    &s[smp * dout * cols..(smp + 1) * dout * cols];
                let bb = matmul_nt(blk, blk, dout, cols, dout);
                for (acc, v) in b.iter_mut().zip(&bb) {
                    *acc += v;
                }
            }
            for v in &mut b {
                *v /= nf;
            }
            out.insert(
                format!("{name}/{li}/A"),
                Tensor::from_f32(&[din, din], a),
            );
            out.insert(
                format!("{name}/{li}/bias_ggn"),
                Tensor::from_f32(&[dout, dout], b.clone()),
            );
            out.insert(
                format!("{name}/{li}/B"),
                Tensor::from_f32(&[dout, dout], b),
            );
        }
    }
}

/// `A`/`B`/`bias_ggn` spec triple per parameter block.
fn kron_specs(name: &str, model: &Model) -> Vec<TensorSpec> {
    let mut specs = Vec::new();
    for blk in model.param_blocks() {
        specs.push(f32_spec(
            format!("{name}/{}/A", blk.li),
            vec![blk.a_dim, blk.a_dim],
        ));
        specs.push(f32_spec(
            format!("{name}/{}/B", blk.li),
            vec![blk.dout, blk.dout],
        ));
        specs.push(f32_spec(
            format!("{name}/{}/bias_ggn", blk.li),
            vec![blk.dout, blk.dout],
        ));
    }
    specs
}

/// KFAC (Eq. 23 with the Monte-Carlo square root): `A ⊗ B` with a
/// rank-`M` sampled `B`.
pub struct Kfac;

impl Extension for Kfac {
    fn name(&self) -> &str {
        "kfac"
    }

    fn walk(&self) -> Walk {
        Walk::SqrtGgnMc
    }

    fn sqrt_ggn(
        &self,
        ctx: &LayerCtx,
        s: &[f32],
        cols: usize,
        out: &mut Quantities,
    ) {
        kron_factors_at("kfac", ctx, s, cols, out);
    }

    fn output_specs(&self, model: &Model, _batch: usize) -> Vec<TensorSpec> {
        kron_specs("kfac", model)
    }
}

/// KFLR (Eq. 23 with the exact square root): `A ⊗ B` with the
/// full-rank `B = (1/N) Σ S Sᵀ`.
pub struct Kflr;

impl Extension for Kflr {
    fn name(&self) -> &str {
        "kflr"
    }

    fn walk(&self) -> Walk {
        Walk::SqrtGgn
    }

    fn sqrt_ggn(
        &self,
        ctx: &LayerCtx,
        s: &[f32],
        cols: usize,
        out: &mut Quantities,
    ) {
        kron_factors_at("kflr", ctx, s, cols, out);
    }

    fn output_specs(&self, model: &Model, _batch: usize) -> Vec<TensorSpec> {
        kron_specs("kflr", model)
    }
}

/// KFRA (Eq. 24): `A ⊗ B` with `B` from the batch-averaged curvature
/// recursion. Fully-connected models only (paper footnote 5).
pub struct Kfra;

impl Extension for Kfra {
    fn name(&self) -> &str {
        "kfra"
    }

    fn walk(&self) -> Walk {
        Walk::Shard
    }

    fn fully_connected_only(&self) -> bool {
        true
    }

    /// Shard phase: emit the batch averages the `Ḡ` recursion
    /// consumes, each normalized by the **global** batch size so
    /// shards sum-reduce exactly. Internal quantities go under
    /// `__kfra/…` keys, consumed (and removed) by the
    /// [`Extension::finish`] pass below.
    fn batch_averages(&self, ctx: &ShardCtx, out: &mut Quantities) {
        let ce = CrossEntropy;
        let (n, norm) = (ctx.n, ctx.norm);
        let c = ctx.model.classes;
        let logits = ctx.acts.last().expect("non-empty");
        // hessian_mean averages over the shard; reweigh to n/norm so
        // the full-range (serial) call scales by exactly 1.0.
        let mut h = ce.hessian_mean(logits, n, c);
        let w = n as f32 / norm;
        for v in &mut h {
            *v *= w;
        }
        out.insert(
            "__kfra/h".to_string(),
            Tensor::from_f32(&[c, c], h),
        );
        for (li, layer) in ctx.model.layers.iter().enumerate() {
            if let Some(op) = ctx.ops[li].as_ref() {
                let din = op.a_dim();
                let mut a = matmul_tn(
                    &ctx.acts[li], &ctx.acts[li], n, din, din,
                );
                for v in &mut a {
                    *v /= norm;
                }
                out.insert(
                    format!("kfra/{li}/A"),
                    Tensor::from_f32(&[din, din], a),
                );
            } else if li > 0 {
                let f = ctx.dims[li];
                let m = layer.d_act(&ctx.acts[li]); // [n, f]
                let mut mm = matmul_tn(&m, &m, n, f, f);
                for v in &mut mm {
                    *v /= norm;
                }
                out.insert(
                    format!("__kfra/mm/{li}"),
                    Tensor::from_f32(&[f, f], mm),
                );
            }
        }
    }

    /// Merge phase: propagate `Ḡ` (Eq. 24) through the layers on the
    /// merged batch averages — `Linear` maps `Ḡ → Wᵀ Ḡ W`
    /// (row-parallel matmuls), activations `Ḡ → Ḡ ∘ (1/N Σ m mᵀ)` —
    /// extracting `B`/`bias_ggn` at every `Linear`.
    fn finish(&self, ctx: &FinishCtx, out: &mut Quantities) -> Result<()> {
        let Some(h) = out.remove("__kfra/h") else {
            bail!("kfra reduction is missing the output-Hessian mean")
        };
        let mut gbar = h.f32s()?.to_vec();
        for li in (0..ctx.model.layers.len()).rev() {
            if let Some(op) = ctx.ops[li].as_ref() {
                let dout = op.dout();
                out.insert(
                    format!("kfra/{li}/B"),
                    Tensor::from_f32(&[dout, dout], gbar.clone()),
                );
                out.insert(
                    format!("kfra/{li}/bias_ggn"),
                    Tensor::from_f32(&[dout, dout], gbar.clone()),
                );
            }
            if li > 0 {
                gbar = match ctx.ops[li].as_ref() {
                    Some(LayerOp::Linear { din, dout, w, .. }) => {
                        let (din, dout) = (*din, *dout);
                        // Wᵀ Ḡ W: [din, dout] x [dout, dout] x
                        // [dout, din]
                        let wt_g = matmul_tn_par(
                            w, &gbar, dout, din, dout, ctx.threads,
                        );
                        matmul_par(
                            &wt_g, w, din, dout, din, ctx.threads,
                        )
                    }
                    Some(LayerOp::Conv { .. }) => {
                        bail!(
                            "kfra is restricted to fully-connected \
                             models (paper footnote 5)"
                        )
                    }
                    None => {
                        let f = ctx.dims[li];
                        let mm = out
                            .remove(&format!("__kfra/mm/{li}"))
                            .expect("kfra activation moment partial");
                        debug_assert_eq!(mm.shape, vec![f, f]);
                        gbar.iter()
                            .zip(mm.f32s()?)
                            .map(|(gv, mv)| gv * mv)
                            .collect()
                    }
                };
            }
        }
        Ok(())
    }

    fn output_specs(&self, model: &Model, _batch: usize) -> Vec<TensorSpec> {
        kron_specs("kfra", model)
    }
}
