//! Diagonal GGN extensions (paper Eqs. 18–20): `diag_ggn` (exact) and
//! `diag_ggn_mc` (Monte-Carlo), extracted from the propagated
//! square-root GGN `S [N, F, cols]`.
//!
//! Convention (DESIGN.md §4): `diag(G)` with `G = (1/N) Σ_n JᵀH_nJ`
//! — the `1/N` is inside, matching the batch-mean loss. The exact
//! variant propagates `cols = C` (class count) columns, the MC
//! variant `cols = M` ([`crate::backend::model::MC_SAMPLES`]) columns
//! drawn per sample from a counter-mode stream keyed by the step key
//! and the sample's **global** batch index, so the result is
//! invariant to the shard layout (DESIGN.md §9).
//!
//! Extraction at a `Linear` layer squares the propagated columns
//! (`Σ_c (Jᵀ S)²` reduces to `(Σ_c S²)ᵀ (x²)` by the rank-1 Jacobian
//! structure, Eq. 19); convolutions contract the transposed `S`
//! against the unfolded input (`conv2d::diag_sqrt`, DESIGN.md §6).

use crate::linalg::matmul_tn;
use crate::runtime::{Tensor, TensorSpec};

use super::{f32_spec, Extension, LayerCtx, LayerOp, Quantities, Walk};
use crate::backend::conv::conv2d;
use crate::backend::model::Model;

/// The `Linear` diagonal extraction shared by `diag_ggn`(-`_mc`) and
/// `diag_h` — the FC twin of [`conv2d::diag_sqrt_signed`]: with the
/// rank-1 Jacobian structure (Eq. 19) the weight diagonal is
/// `s2ᵀ x² / N` where `s2[n, o] = Σ_c w_c · S[n, o, c]²`, and the
/// bias diagonal the column sum of `s2 / N`. The per-(sample, column)
/// weights `signs [n · cols]` carry the residual factors' signs
/// (DESIGN.md §11); `None` weights every column `+1` (the PSD
/// square-root-GGN case).
#[allow(clippy::too_many_arguments)]
pub(crate) fn linear_diag_sqrt_signed(
    input: &[f32],
    s: &[f32],
    n: usize,
    din: usize,
    dout: usize,
    cols: usize,
    norm: f32,
    signs: Option<&[f32]>,
) -> (Vec<f32>, Vec<f32>) {
    debug_assert_eq!(s.len(), n * dout * cols);
    if let Some(sg) = signs {
        debug_assert_eq!(sg.len(), n * cols);
    }
    // s2[n, o] = Σ_c w_c · S[n, o, c]²
    let mut s2 = vec![0.0f32; n * dout];
    for (row, v) in s2.iter_mut().enumerate() {
        let base = row * cols;
        *v = match signs {
            None => {
                s[base..base + cols].iter().map(|u| u * u).sum()
            }
            Some(sg) => {
                let smp = row / dout;
                (0..cols)
                    .map(|c| {
                        sg[smp * cols + c]
                            * s[base + c]
                            * s[base + c]
                    })
                    .sum()
            }
        };
    }
    let x2: Vec<f32> = input.iter().map(|v| v * v).collect();
    let mut dw = matmul_tn(&s2, &x2, n, dout, din);
    for v in &mut dw {
        *v /= norm;
    }
    let mut db = vec![0.0f32; dout];
    for smp in 0..n {
        for o in 0..dout {
            db[o] += s2[smp * dout + o];
        }
    }
    for v in &mut db {
        *v /= norm;
    }
    (dw, db)
}

/// Exact (`diag_ggn`) or Monte-Carlo (`diag_ggn_mc`) GGN diagonal.
pub struct DiagGgn {
    mc: bool,
}

impl DiagGgn {
    /// The exact variant: propagates the full `[N, F, C]` square root
    /// (Eq. 18).
    pub fn exact() -> DiagGgn {
        DiagGgn { mc: false }
    }

    /// The Monte-Carlo variant: propagates the rank-`M` sampled
    /// square root (Eq. 20); needs a PRNG key.
    pub fn mc() -> DiagGgn {
        DiagGgn { mc: true }
    }
}

impl Extension for DiagGgn {
    fn name(&self) -> &str {
        if self.mc {
            "diag_ggn_mc"
        } else {
            "diag_ggn"
        }
    }

    fn walk(&self) -> Walk {
        if self.mc {
            Walk::SqrtGgnMc
        } else {
            Walk::SqrtGgn
        }
    }

    fn sqrt_ggn(
        &self,
        ctx: &LayerCtx,
        s: &[f32],
        cols: usize,
        out: &mut Quantities,
    ) {
        let (li, n, nf) = (ctx.li, ctx.n, ctx.norm);
        let name = self.name();
        match ctx.op {
            LayerOp::Conv { geom, .. } => {
                let (dw, db) = conv2d::diag_sqrt(
                    geom, ctx.input, s, n, cols, nf,
                );
                out.insert(
                    format!("{name}/{li}/w"),
                    Tensor::from_f32(&geom.w_shape(), dw),
                );
                out.insert(
                    format!("{name}/{li}/b"),
                    Tensor::from_f32(&[geom.out_shape.c], db),
                );
            }
            LayerOp::Linear { din, dout, .. } => {
                let (dw, db) = linear_diag_sqrt_signed(
                    ctx.input, s, n, din, dout, cols, nf, None,
                );
                out.insert(
                    format!("{name}/{li}/w"),
                    Tensor::from_f32(&[dout, din], dw),
                );
                out.insert(
                    format!("{name}/{li}/b"),
                    Tensor::from_f32(&[dout], db),
                );
            }
        }
    }

    fn output_specs(&self, model: &Model, _batch: usize) -> Vec<TensorSpec> {
        let mut specs = Vec::new();
        for blk in model.param_blocks() {
            specs.push(f32_spec(
                format!("{}/{}/w", self.name(), blk.li),
                blk.w_shape.clone(),
            ));
            specs.push(f32_spec(
                format!("{}/{}/b", self.name(), blk.li),
                vec![blk.dout],
            ));
        }
        specs
    }
}
