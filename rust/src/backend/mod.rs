//! Pluggable execution backends.
//!
//! A *backend* resolves artifact names (the manifest naming scheme the
//! whole coordinator speaks: `{model}_{ext-signature}_n{batch}` for
//! training graphs, `{model}_eval_n{batch}` for evaluation graphs) to
//! runnable computations. Two implementations exist:
//!
//! * [`native::NativeBackend`] -- forward + generalized backward pass
//!   (paper Figs. 4-5) in pure Rust on the host [`Tensor`] type, for
//!   the paper's full layer set ([`layers::Layer`]): the affine maps
//!   `Linear` and `Conv2d` (im2col lowering in [`conv`]), the pooling
//!   layers `MaxPool2d` / `GlobalAvgPool`, `Flatten`, and the `ReLU` /
//!   `Sigmoid` activations. Every quantity is an
//!   [`Extension`](extensions::Extension) module dispatched through
//!   an [`ExtensionSet`](extensions::ExtensionSet) registry --
//!   user-defined quantities drop in without engine changes. Every
//!   problem in
//!   `coordinator::problems::PROBLEMS` and all ten paper quantities
//!   (including `diag_h`'s second-order residual propagation,
//!   DESIGN.md §11) are servable. Zero external dependencies; the
//!   default.
//! * `runtime::Runtime` (behind the `pjrt` cargo feature) -- executes
//!   AOT-lowered HLO artifacts through the PJRT C API; a cross-check
//!   path for the same quantity grid.
//!
//! Both return the same named [`Outputs`]: `loss`, `grad/*`, and the
//! extension quantities (`batch_grad/*`, `sq_moment/*`, `variance/*`,
//! `diag_ggn/*`, `kfac/*`, ...) the optimizers in `crate::optim`
//! consume, so everything above this layer (training loop, grid
//! search, figures, CLI) is backend-agnostic.

pub mod api;
pub mod conv;
pub mod extensions;
pub mod layers;
pub mod loss;
pub mod model;
pub mod native;

use std::collections::BTreeMap;
use std::rc::Rc;
use std::str::FromStr;
use std::time::Duration;

use anyhow::{bail, Context, Result};

pub use api::{ArtifactId, Signature};

use crate::runtime::{ArtifactSpec, Tensor};

/// Named outputs of one computation execution.
#[derive(Debug)]
pub struct Outputs {
    map: BTreeMap<String, Tensor>,
    /// Wall-clock of the execute call (excludes input staging).
    pub exec_time: Duration,
}

impl Outputs {
    pub fn new(map: BTreeMap<String, Tensor>, exec_time: Duration)
        -> Outputs {
        Outputs { map, exec_time }
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .with_context(|| format!("no output {name:?}"))
    }

    pub fn loss(&self) -> Result<f32> {
        self.get("loss")?.item_f32()
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    /// All outputs under a `prefix/` (e.g. "grad", "kfac"), keyed by the
    /// remainder of the name.
    pub fn with_prefix(&self, prefix: &str) -> BTreeMap<&str, &Tensor> {
        let pat = format!("{prefix}/");
        self.map
            .iter()
            .filter(|(k, _)| k.starts_with(&pat))
            .map(|(k, v)| (&k[pat.len()..], v))
            .collect()
    }
}

/// One loaded computation: a training or evaluation graph bound to its
/// spec, executable on host tensors.
pub trait Exec {
    fn spec(&self) -> &ArtifactSpec;

    /// Execute with inputs in spec order; returns named outputs.
    fn run(&self, inputs: &[Tensor]) -> Result<Outputs>;
}

/// An execution backend: resolves artifact names to computations.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Describe an artifact without loading/compiling it.
    fn spec(&self, artifact: &str) -> Result<ArtifactSpec>;

    /// Load (or fetch from cache) a runnable computation by name.
    fn load(&self, artifact: &str) -> Result<Rc<dyn Exec>>;

    /// Resolve the training artifact *name* for (model, input side,
    /// extension signature, batch size). The signature is the
    /// optimizer's `ext_signature()` ("grad", "diag_ggn", "kfac",
    /// ...). Pass the name to `load` / `spec`.
    fn find_train(
        &self,
        model: &str,
        side: usize,
        ext_sig: &str,
        batch: usize,
    ) -> Result<String>;

    /// Artifact names this backend can serve (representative set for
    /// backends that synthesize graphs on demand).
    fn artifact_names(&self) -> Vec<String>;

    /// Typed [`spec`](Backend::spec): describe an artifact by
    /// [`ArtifactId`] instead of its string spelling.
    fn spec_id(&self, id: &ArtifactId) -> Result<ArtifactSpec> {
        self.spec(&id.to_string())
    }

    /// Typed [`load`](Backend::load): resolve an [`ArtifactId`]
    /// directly, skipping the string round-trip for backends that
    /// don't override it.
    fn load_id(&self, id: &ArtifactId) -> Result<Rc<dyn Exec>> {
        self.load(&id.to_string())
    }
}

/// Validate an input vector against a spec (count + per-input shape);
/// the shared front door of every `Exec::run` implementation.
pub fn validate_inputs(spec: &ArtifactSpec, inputs: &[Tensor])
    -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!(
            "artifact {}: got {} inputs, expected {}",
            spec.name,
            inputs.len(),
            spec.inputs.len()
        );
    }
    for (t, ts) in inputs.iter().zip(&spec.inputs) {
        if t.shape != ts.shape {
            bail!(
                "artifact {} input {}: shape {:?} != expected {:?}",
                spec.name, ts.name, t.shape, ts.shape
            );
        }
    }
    Ok(())
}

/// The set of compiled-in backends, the typed form of the CLI's
/// `--backend native|pjrt` string. [`open`]/[`open_with`] remain as
/// thin string-keyed wrappers for callers that haven't migrated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust graphs synthesized on demand (the default).
    Native,
    /// AOT HLO artifacts through the PJRT C API (`pjrt` feature).
    Pjrt,
}

impl FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<BackendKind> {
        match s {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => bail!("unknown backend {other:?} (native|pjrt)"),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>)
        -> std::fmt::Result {
        f.write_str(match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        })
    }
}

/// Construct a backend from its typed kind with an explicit
/// batch-parallel worker count (`0` = auto, `1` = serial). The pjrt
/// runtime schedules its own intra-op parallelism, so `threads` only
/// shapes the native backend.
pub fn open_kind(
    kind: BackendKind,
    threads: usize,
) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Native => {
            Ok(Box::new(native::NativeBackend::with_threads(threads)))
        }
        BackendKind::Pjrt => {
            #[cfg(feature = "pjrt")]
            {
                let _ = threads;
                Ok(Box::new(crate::runtime::Runtime::open_default()?))
            }
            #[cfg(not(feature = "pjrt"))]
            {
                bail!(
                    "the pjrt backend is not compiled in; rebuild with \
                     `cargo build --features pjrt` (needs AOT artifacts \
                     from `make artifacts`)"
                )
            }
        }
    }
}

/// Construct a backend by CLI name (`--backend native|pjrt`) with
/// auto-sized batch parallelism (all cores, `BACKPACK_THREADS`
/// override). Thin string-keyed wrapper over [`open_kind`]; prefer
/// the typed entry point in new code.
pub fn open(kind: &str) -> Result<Box<dyn Backend>> {
    open_with(kind, 0)
}

/// [`open`] with an explicit batch-parallel worker count. Thin
/// string-keyed wrapper over [`open_kind`].
pub fn open_with(kind: &str, threads: usize) -> Result<Box<dyn Backend>> {
    open_kind(kind.parse()?, threads)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outputs_lookup_and_prefix() {
        let mut map = BTreeMap::new();
        map.insert("loss".to_string(), Tensor::scalar_f32(1.5));
        map.insert("grad/0/w".to_string(), Tensor::zeros(&[2, 3]));
        map.insert("grad/0/b".to_string(), Tensor::zeros(&[2]));
        let out = Outputs::new(map, Duration::from_millis(1));
        assert_eq!(out.loss().unwrap(), 1.5);
        assert!(out.get("nope").is_err());
        let grads = out.with_prefix("grad");
        assert_eq!(grads.len(), 2);
        assert!(grads.contains_key("0/w"));
    }

    #[test]
    fn open_native_works_and_unknown_fails() {
        assert!(open("native").is_ok());
        assert!(open("tpu").is_err());
    }

    #[test]
    fn backend_kind_round_trips() {
        for kind in [BackendKind::Native, BackendKind::Pjrt] {
            let s = kind.to_string();
            assert_eq!(s.parse::<BackendKind>().unwrap(), kind);
        }
        assert!("tpu".parse::<BackendKind>().is_err());
        assert!(open_kind(BackendKind::Native, 1).is_ok());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn open_pjrt_errors_without_feature() {
        let err = open("pjrt").unwrap_err().to_string();
        assert!(err.contains("--features pjrt"), "{err}");
    }
}
