//! Native sequential models + the generalized backward pass.
//!
//! `extended_backward` is the Rust twin of the Python extension engine
//! (`python/compile/extensions.py`): ONE forward pass storing module
//! inputs, then one backward walk per propagated quantity, with every
//! extraction rule living in a pluggable [`Extension`] module
//! ([`crate::backend::extensions`]) rather than in this engine:
//!
//! 1. a **first-order** backward walk (paper Fig. 4) propagating the
//!    per-sample output gradients `g [N, F]` (Eq. 3); at every
//!    parameterized layer (`Linear`, `Conv2d`) the engine extracts
//!    the averaged gradient and dispatches to the active
//!    [`Walk::Grad`] extensions (individual gradients, L2 norms, 2nd
//!    moment, variance -- Table 1 / Appendix A.1);
//! 2. **second-order** backward walks (Fig. 5) propagating the
//!    symmetric loss-Hessian factorization `S [N, F, C]` (Eq. 18) --
//!    exact ([`Walk::SqrtGgn`]: DiagGGN, KFLR, DiagH) or Monte-Carlo
//!    ([`Walk::SqrtGgnMc`]: DiagGGN-MC, KFAC), one shared propagation
//!    per variant -- and a whole-shard hook for KFRA's batch-averaged
//!    curvature `Ḡ [h, h]` (Eq. 24, [`Walk::Shard`]). When a
//!    [`Extension::needs_residual`] module is active (`diag_h`), the
//!    exact walk additionally carries the full Hessian's signed
//!    residual factors: the first-order walk records `σ''(x) ⊙ g` at
//!    every curved activation, each such layer births one signed
//!    diagonal square-root factor, and the factors ride the same
//!    transposed Jacobians as `S` (DESIGN.md §11).
//!
//! Convolutions lower to the linear case by im2col
//! (`backend/conv/`, DESIGN.md §6); pooling layers propagate by index
//! routing / broadcast. KFRA stays fully-connected-only (paper
//! footnote 5): the engine rejects any
//! [`Extension::fully_connected_only`] module on a model with conv or
//! pool layers.
//!
//! All quantities follow Table 1's scaling conventions (the loss is
//! the *mean* over the batch; DESIGN.md §4); the Rust integration
//! tests assert the same identities the Python test-suite checks
//! against autodiff.
//!
//! **Batch parallelism.** Every quantity above is a sum or a
//! concatenation over the batch axis, so the engine shards the batch
//! into contiguous ranges (`crate::parallel`) and runs the *whole*
//! forward + backward per shard, normalizing by the **global** batch
//! size. Reduction is extension-aware -- each module declares its own
//! rule through [`Extension::reduce`] (DESIGN.md §9):
//!
//! * `loss`, `grad/*`, `sq_moment/*`, `diag_ggn*/*` and the
//!   KFAC/KFLR/KFRA factors sum-reduce across shards;
//! * `batch_grad/*` / `batch_l2/*` concatenate in shard (= sample)
//!   order;
//! * `variance/*` is computed exactly from the merged first and
//!   second moments after the reduction ([`Extension::finish`]);
//! * KFRA's nonlinear `Ḡ` recursion runs once on the merged batch
//!   averages (`A`, activation second moments, output Hessian mean);
//! * MC draws are keyed by each sample's global index, so
//!   `diag_ggn_mc`/`kfac` are invariant to the shard layout.
//!
//! Results are bit-for-bit deterministic for a fixed thread count
//! (shards reduce in index order) and agree across thread counts to
//! f32 summation-reordering error (≤ 1e-5; asserted by
//! `tests/parallel_equiv.rs`).

use std::collections::BTreeMap;
use std::ops::Range;

use anyhow::{bail, ensure, Result};

use super::conv::{conv2d, pool, ConvGeom, PoolGeom, Shape};
use super::extensions::{
    self as extensions_mod, Extension, ExtensionSet, FinishCtx,
    LayerCtx, LayerOp, Quantities, ReducePlan, ShardCtx, Walk,
};
use super::layers::Layer;
use super::loss::CrossEntropy;
use crate::linalg::{matmul, matmul_nt, matmul_tn};
use crate::obs;
use crate::parallel;
use crate::runtime::{Init, Tensor, TensorSpec};

/// Monte-Carlo rank of the DiagGGN-MC / KFAC factorization (paper: 1).
pub const MC_SAMPLES: usize = 1;

/// Extensions the native engine ships out of the box — all ten paper
/// quantities, including `diag_h`'s signed residual-factor
/// propagation (DESIGN.md §11). `kfra` is restricted to
/// fully-connected models (paper footnote 5). The canonical list
/// lives in the extension registry
/// ([`super::extensions::BUILTIN_NAMES`]); user-defined quantities
/// register through [`ExtensionSet`] / `NativeBackend`.
pub use super::extensions::BUILTIN_NAMES as NATIVE_EXTENSIONS;

/// A sequential model with a cross-entropy loss. `in_shape` carries
/// the image geometry for convolutional models; activations are
/// stored flat (`in_dim = in_shape.flat()` features per sample).
#[derive(Debug, Clone)]
pub struct Model {
    /// Registry name (`logreg`, `mlp`, `2c2d`, ...).
    pub name: String,
    /// Flat input feature count (`in_shape.flat()`).
    pub in_dim: usize,
    /// Input activation geometry (`Shape::flat_vec` for vector
    /// models).
    pub in_shape: Shape,
    /// Output class count (the last layer's flat dimension).
    pub classes: usize,
    /// The module sequence.
    pub layers: Vec<Layer>,
}

/// One parameterized block of a model: layer index, weight tensor
/// dims, Kronecker factor dimensions. For `Linear` the weight is
/// `[dout, a_dim]`; for `Conv2d` it is `[out_ch, in_ch, k, k]` with
/// `a_dim = in_ch·k²` (the im2col patch length).
#[derive(Debug, Clone)]
pub struct ParamBlock {
    /// Index of the layer in [`Model::layers`].
    pub li: usize,
    /// Parameter-tensor shape of the weight.
    pub w_shape: Vec<usize>,
    /// Kronecker `A`-side dimension (`din` / patch length).
    pub a_dim: usize,
    /// Kronecker `B`-side dimension (output features / channels).
    pub dout: usize,
}

/// Where one engine call executes: in-process batch-parallel threads
/// or a fleet of `backpack worker` processes.
///
/// The reduce contract ([`crate::backend::extensions::ReducePlan`])
/// makes the two indistinguishable in results: shard layout is
/// invariant, so `Local { threads: 4 }` and `Workers { n: 4, .. }`
/// agree to f32 summation-reordering error (bitwise for per-sample
/// Concat quantities).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Topology {
    /// In-process batch parallelism over scoped threads; `0` and `1`
    /// both mean the serial reference path. (Resolve "all cores" with
    /// [`crate::parallel::resolve_threads`] before constructing the
    /// options -- the engine does not consult the environment.)
    Local {
        /// Batch-parallel worker-thread count.
        threads: usize,
    },
    /// Process-parallel extraction: the call is delegated to the
    /// [`crate::dist`] coordinator, which partitions the batch into
    /// `n` contiguous global-index slices, one per worker process,
    /// and merges per-key via the shared reduce contract. Requires
    /// the built-in registry (worker processes cannot reconstruct
    /// user-defined extension objects).
    Workers {
        /// Worker-process count (each runs one contiguous slice).
        n: usize,
        /// `host:port` addresses of already-running `backpack worker`
        /// processes to connect to. Empty = spawn `n` workers from
        /// the current executable and reap them on completion. When
        /// non-empty, `len()` must equal `n`.
        addrs: Vec<String>,
    },
}

impl Default for Topology {
    /// The serial reference configuration (`Local { threads: 0 }`).
    fn default() -> Topology {
        Topology::Local { threads: 0 }
    }
}

impl Topology {
    /// In-process topology with `threads` workers.
    pub fn local(threads: usize) -> Topology {
        Topology::Local { threads }
    }

    /// Process topology spawning `n` workers from the current
    /// executable.
    pub fn workers(n: usize) -> Topology {
        Topology::Workers { n, addrs: Vec::new() }
    }

    /// Thread count of the in-process engine path, resolved (`>= 1`).
    /// For [`Topology::Workers`] this is 1: the coordinator process
    /// does no walking of its own.
    pub fn threads(&self) -> usize {
        match self {
            Topology::Local { threads } => (*threads).max(1),
            Topology::Workers { .. } => 1,
        }
    }
}

/// Options for one [`Model::extended_backward`] engine call. The
/// defaults are the serial reference configuration: built-in
/// registry, local single-thread topology, no PRNG key, no engine
/// span. Construct with struct-update syntax over
/// [`ExtractOptions::default`]:
///
/// ```
/// use backpack_rs::{ExtractOptions, Topology};
///
/// let opts = ExtractOptions {
///     topology: Topology::local(4),
///     key: Some([7, 9]),
///     ..ExtractOptions::default()
/// };
/// assert!(opts.registry.is_none()); // None = built-in registry
/// ```
#[derive(Clone, Default)]
pub struct ExtractOptions {
    /// Extension registry to dispatch through; `None` selects
    /// [`ExtensionSet::builtin`] (the ten paper quantities). Note
    /// `Some(ExtensionSet::default())` is the *empty* registry, which
    /// rejects every extension name -- always spell "the default
    /// modules" as `None`.
    pub registry: Option<ExtensionSet>,
    /// Execution topology: in-process threads ([`Topology::Local`],
    /// the default) or worker processes ([`Topology::Workers`]).
    pub topology: Topology,
    /// PRNG key for Monte-Carlo extensions (`diag_ggn_mc`, `kfac`);
    /// draws are keyed by global sample index, so results are
    /// invariant to the topology.
    pub key: Option<[u32; 2]>,
    /// When set, the whole engine call is wrapped in a named
    /// `engine`-category span -- how the serve daemon attributes
    /// batches in `--trace` output.
    pub trace_label: Option<String>,
}

impl ExtractOptions {
    /// Pre-topology shim: options with a bare thread count. Kept so
    /// callers written against the old `threads: usize` field have a
    /// one-line migration; new code should spell the topology out.
    #[deprecated(
        note = "use `ExtractOptions { topology: Topology::local(threads), \
                ..ExtractOptions::default() }`"
    )]
    pub fn with_threads(threads: usize) -> ExtractOptions {
        ExtractOptions {
            topology: Topology::local(threads),
            ..ExtractOptions::default()
        }
    }
}

/// Per-layer spatial geometry, resolved once per engine call.
enum Geom {
    None,
    Conv(ConvGeom),
    Pool(PoolGeom),
    Gap { c: usize, hw: usize },
}

impl Model {
    /// Build and validate a model with a flat input vector.
    pub fn new(name: &str, in_dim: usize, layers: Vec<Layer>)
        -> Result<Model> {
        Model::with_input(name, Shape::flat_vec(in_dim), layers)
    }

    /// Build and validate a model (shapes must chain; the last
    /// layer's flattened output dimension is the class count).
    pub fn with_input(name: &str, in_shape: Shape, layers: Vec<Layer>)
        -> Result<Model> {
        ensure!(!layers.is_empty(), "model {name} has no layers");
        let mut s = in_shape;
        for layer in &layers {
            s = layer.out_shape(s)?;
        }
        Ok(Model {
            name: name.to_string(),
            in_dim: in_shape.flat(),
            in_shape,
            classes: s.flat(),
            layers,
        })
    }

    /// The paper's linear model: `Linear(784, 10)` (7,850 parameters).
    pub fn logreg() -> Model {
        Model::new(
            "logreg",
            784,
            vec![Layer::Linear { in_dim: 784, out_dim: 10 }],
        )
        .expect("static model")
    }

    /// A ReLU+sigmoid MLP on MNIST shapes: exercises the full native
    /// fully-connected layer set (109,386 parameters).
    pub fn mlp() -> Model {
        Model::new(
            "mlp",
            784,
            vec![
                Layer::Linear { in_dim: 784, out_dim: 128 },
                Layer::Relu,
                Layer::Linear { in_dim: 128, out_dim: 64 },
                Layer::Sigmoid,
                Layer::Linear { in_dim: 64, out_dim: 10 },
            ],
        )
        .expect("static model")
    }

    /// DeepOBS 2c2d on Fashion-MNIST shapes (paper Table 3:
    /// 3,274,634 parameters): two 5x5 'same' conv + 2x2 max-pool
    /// blocks, then a 1024-unit dense head.
    pub fn conv_2c2d() -> Model {
        Model::with_input(
            "2c2d",
            Shape::new(1, 28, 28),
            vec![
                Layer::Conv2d {
                    in_ch: 1, out_ch: 32, kernel: 5, stride: 1, pad: 2,
                },
                Layer::Relu,
                Layer::MaxPool2d { kernel: 2, stride: 2, ceil: false },
                Layer::Conv2d {
                    in_ch: 32, out_ch: 64, kernel: 5, stride: 1, pad: 2,
                },
                Layer::Relu,
                Layer::MaxPool2d { kernel: 2, stride: 2, ceil: false },
                Layer::Flatten,
                Layer::Linear { in_dim: 3136, out_dim: 1024 },
                Layer::Relu,
                Layer::Linear { in_dim: 1024, out_dim: 10 },
            ],
        )
        .expect("static model")
    }

    /// DeepOBS 3c3d on CIFAR-10 (895,210 parameters): three
    /// conv + max-pool blocks (valid 5x5, valid 3x3, 'same' 3x3;
    /// 3x3 stride-2 ceil-mode pools: 32 → 14 → 6 → 3) and a
    /// 512-256-10 dense head.
    pub fn conv_3c3d() -> Model {
        Model::with_input(
            "3c3d",
            Shape::new(3, 32, 32),
            vec![
                Layer::Conv2d {
                    in_ch: 3, out_ch: 64, kernel: 5, stride: 1, pad: 0,
                },
                Layer::Relu,
                Layer::MaxPool2d { kernel: 3, stride: 2, ceil: true },
                Layer::Conv2d {
                    in_ch: 64, out_ch: 96, kernel: 3, stride: 1, pad: 0,
                },
                Layer::Relu,
                Layer::MaxPool2d { kernel: 3, stride: 2, ceil: true },
                Layer::Conv2d {
                    in_ch: 96, out_ch: 128, kernel: 3, stride: 1, pad: 1,
                },
                Layer::Relu,
                Layer::MaxPool2d { kernel: 3, stride: 2, ceil: true },
                Layer::Flatten,
                Layer::Linear { in_dim: 1152, out_dim: 512 },
                Layer::Relu,
                Layer::Linear { in_dim: 512, out_dim: 256 },
                Layer::Relu,
                Layer::Linear { in_dim: 256, out_dim: 10 },
            ],
        )
        .expect("static model")
    }

    /// The paper's Fig. 9 variant of 3c3d: "a single sigmoid
    /// activation function before the last classification layer"
    /// (same 895,210 parameters; the ReLU after `Linear(512, 256)`
    /// becomes `Sigmoid`). The sigmoid's nonzero second derivative is
    /// what makes DiagH propagate residual factors — on the all-ReLU
    /// `3c3d`, DiagH and DiagGGN coincide.
    pub fn conv_3c3d_sigmoid() -> Model {
        let base = Model::conv_3c3d();
        let mut layers = base.layers;
        // The activation between the last two Linear layers.
        let pos = layers.len() - 2;
        assert_eq!(layers[pos], Layer::Relu);
        layers[pos] = Layer::Sigmoid;
        Model::with_input("3c3d_sigmoid", Shape::new(3, 32, 32), layers)
            .expect("static model")
    }

    /// All-CNN-C on CIFAR-100 (1,387,108 parameters at any input
    /// side, paper Table 3): nine convolutions with pooling replaced
    /// by stride-2 convs, a valid 3x3 + two 1x1 head, and globally
    /// average-pooled logits. `side` scales the spatial input (paper:
    /// 32; the CPU-scaled cifar100 problem: 16); registered as
    /// `allcnnc{side}`.
    pub fn allcnnc(side: usize) -> Model {
        let c3 = |i, o, s| Layer::Conv2d {
            in_ch: i, out_ch: o, kernel: 3, stride: s, pad: 1,
        };
        Model::with_input(
            &format!("allcnnc{side}"),
            Shape::new(3, side, side),
            vec![
                c3(3, 96, 1),
                Layer::Relu,
                c3(96, 96, 1),
                Layer::Relu,
                c3(96, 96, 2),
                Layer::Relu,
                c3(96, 192, 1),
                Layer::Relu,
                c3(192, 192, 1),
                Layer::Relu,
                c3(192, 192, 2),
                Layer::Relu,
                Layer::Conv2d {
                    in_ch: 192, out_ch: 192, kernel: 3, stride: 1,
                    pad: 0,
                },
                Layer::Relu,
                Layer::Conv2d {
                    in_ch: 192, out_ch: 192, kernel: 1, stride: 1,
                    pad: 0,
                },
                Layer::Relu,
                Layer::Conv2d {
                    in_ch: 192, out_ch: 100, kernel: 1, stride: 1,
                    pad: 0,
                },
                Layer::GlobalAvgPool,
            ],
        )
        .expect("static model")
    }

    /// Activation shape before each layer plus the final one
    /// (`len = layers.len() + 1`).
    pub fn shapes(&self) -> Vec<Shape> {
        let mut shapes = Vec::with_capacity(self.layers.len() + 1);
        let mut s = self.in_shape;
        shapes.push(s);
        for layer in &self.layers {
            s = layer.out_shape(s).expect("validated at construction");
            shapes.push(s);
        }
        shapes
    }

    /// Flat feature dimension before each layer plus the final one.
    pub fn dims(&self) -> Vec<usize> {
        self.shapes().iter().map(|s| s.flat()).collect()
    }

    /// True when the model contains only `Linear` layers and
    /// elementwise activations -- the class KFRA is defined for
    /// (paper footnote 5).
    pub fn is_fully_connected(&self) -> bool {
        self.layers.iter().all(|l| {
            matches!(l, Layer::Linear { .. } | Layer::Relu
                     | Layer::Sigmoid)
        })
    }

    /// Validate a batch input tensor -- `[N, in_dim]` (flat) or
    /// `[N, c, h, w]` (the image layout the data pipeline ships for
    /// non-flat datasets; identical row-major data) -- returning `N`.
    fn check_x(&self, x: &Tensor) -> Result<usize> {
        let n = *x.shape.first().unwrap_or(&0);
        let mut img = vec![n];
        img.extend(self.in_shape.dims());
        ensure!(
            x.shape == [n, self.in_dim] || x.shape == img,
            "x shape {:?} != [{n}, {}] or {img:?}",
            x.shape,
            self.in_dim
        );
        Ok(n)
    }

    /// Per-layer spatial geometry (conv/pool lowering parameters),
    /// aligned with `layers`.
    fn geoms(&self) -> Vec<Geom> {
        let mut s = self.in_shape;
        self.layers
            .iter()
            .map(|layer| {
                let g = match *layer {
                    Layer::Conv2d {
                        out_ch, kernel, stride, pad, ..
                    } => Geom::Conv(
                        ConvGeom::new(s, out_ch, kernel, stride, pad)
                            .expect("validated at construction"),
                    ),
                    Layer::MaxPool2d { kernel, stride, ceil } => {
                        Geom::Pool(
                            PoolGeom::new(s, kernel, stride, ceil)
                                .expect("validated at construction"),
                        )
                    }
                    Layer::GlobalAvgPool => {
                        Geom::Gap { c: s.c, hw: s.h * s.w }
                    }
                    _ => Geom::None,
                };
                s = layer
                    .out_shape(s)
                    .expect("validated at construction");
                g
            })
            .collect()
    }

    /// `(layer index, weight dims, Kronecker dims)` of every
    /// parameterized layer, in layer order.
    pub fn param_blocks(&self) -> Vec<ParamBlock> {
        self.layers
            .iter()
            .enumerate()
            .filter_map(|(li, layer)| match *layer {
                Layer::Linear { in_dim, out_dim } => Some(ParamBlock {
                    li,
                    w_shape: vec![out_dim, in_dim],
                    a_dim: in_dim,
                    dout: out_dim,
                }),
                Layer::Conv2d { in_ch, out_ch, kernel, .. } => {
                    Some(ParamBlock {
                        li,
                        w_shape: vec![out_ch, in_ch, kernel, kernel],
                        a_dim: in_ch * kernel * kernel,
                        dout: out_ch,
                    })
                }
                _ => None,
            })
            .collect()
    }

    /// Parameter tensor specs in artifact-input order
    /// (`param/{layer}/{w|b}`, PyTorch fan-in init -- the same rules
    /// aot.py records in the manifest, so `init_params` is shared).
    pub fn param_specs(&self) -> Vec<TensorSpec> {
        let mut specs = Vec::new();
        for blk in self.param_blocks() {
            let bound = 1.0 / (blk.a_dim as f32).sqrt();
            specs.push(TensorSpec {
                name: format!("param/{}/w", blk.li),
                shape: blk.w_shape.clone(),
                dtype: "f32".to_string(),
                init: Some(Init::Uniform { bound }),
            });
            specs.push(TensorSpec {
                name: format!("param/{}/b", blk.li),
                shape: vec![blk.dout],
                dtype: "f32".to_string(),
                init: Some(Init::Zeros),
            });
        }
        specs
    }

    /// Total parameter count across all blocks.
    pub fn num_params(&self) -> usize {
        self.param_specs()
            .iter()
            .map(|t| t.shape.iter().product::<usize>())
            .sum()
    }

    /// `(layer index, in features, out features)` of every `Linear`,
    /// in layer order (the fully-connected blocks of the model).
    pub fn linear_dims(&self) -> Vec<(usize, usize, usize)> {
        self.layers
            .iter()
            .enumerate()
            .filter_map(|(li, layer)| match *layer {
                Layer::Linear { in_dim, out_dim } => {
                    Some((li, in_dim, out_dim))
                }
                _ => None,
            })
            .collect()
    }

    /// Resolve the flat parameter-tensor list (w, b per parameterized
    /// layer, in layer order) into per-layer [`LayerOp`] views,
    /// validating shapes. For `Conv2d`, the weight view is the
    /// `[dout, din]` im2col matrix of the `[out_ch, in_ch, k, k]`
    /// tensor (`din = in_ch·k²`).
    fn bind<'a>(
        &self,
        params: &'a [Tensor],
        geoms: &'a [Geom],
    ) -> Result<Vec<Option<LayerOp<'a>>>> {
        let blocks: BTreeMap<usize, ParamBlock> = self
            .param_blocks()
            .into_iter()
            .map(|b| (b.li, b))
            .collect();
        let mut out = Vec::with_capacity(self.layers.len());
        let mut it = params.iter();
        for (li, layer) in self.layers.iter().enumerate() {
            if !layer.has_params() {
                out.push(None);
                continue;
            }
            let blk = blocks.get(&li).expect("block per param layer");
            let (Some(w), Some(b)) = (it.next(), it.next()) else {
                bail!("model {}: missing params for layer {li}",
                      self.name)
            };
            ensure!(
                w.shape == blk.w_shape,
                "param/{li}/w: shape {:?} != {:?}",
                w.shape,
                blk.w_shape
            );
            ensure!(
                b.shape == [blk.dout],
                "param/{li}/b: shape {:?} != [{}]", b.shape, blk.dout
            );
            let (wf, bf) = (w.f32s()?, b.f32s()?);
            out.push(Some(match &geoms[li] {
                Geom::Conv(geom) => {
                    LayerOp::Conv { geom, w: wf, b: bf }
                }
                _ => LayerOp::Linear {
                    din: blk.a_dim,
                    dout: blk.dout,
                    w: wf,
                    b: bf,
                },
            }));
        }
        ensure!(
            it.next().is_none(),
            "model {}: too many parameter tensors", self.name
        );
        Ok(out)
    }

    /// Forward pass storing every module input (paper Fig. 2):
    /// returns `layers.len() + 1` activations, `acts[0] = x`,
    /// `acts.last() = logits`.
    fn forward_acts(
        &self,
        ops: &[Option<LayerOp>],
        geoms: &[Geom],
        x: &[f32],
        n: usize,
    ) -> Vec<Vec<f32>> {
        let _fwd = obs::span(obs::CAT_PHASE, "forward");
        let mut acts = Vec::with_capacity(self.layers.len() + 1);
        acts.push(x.to_vec());
        for (li, layer) in self.layers.iter().enumerate() {
            let _layer =
                obs::span_with(obs::CAT_LAYER, || format!("fwd/{li}"));
            let inp = acts.last().expect("non-empty");
            let z = match (layer, &geoms[li]) {
                (Layer::Linear { .. }, _) => {
                    let op = ops[li].expect("bound");
                    let (din, dout) = (op.a_dim(), op.dout());
                    let b = op.b();
                    let mut z = matmul_nt(inp, op.w(), n, din, dout);
                    for s in 0..n {
                        for o in 0..dout {
                            z[s * dout + o] += b[o];
                        }
                    }
                    z
                }
                (Layer::Conv2d { .. }, Geom::Conv(geom)) => {
                    let op = ops[li].expect("bound");
                    conv2d::forward(geom, op.w(), op.b(), inp, n)
                }
                (Layer::MaxPool2d { .. }, Geom::Pool(geom)) => {
                    geom.forward(inp, n)
                }
                (Layer::GlobalAvgPool, Geom::Gap { c, hw }) => {
                    pool::gap_forward(*c, *hw, inp, n)
                }
                (Layer::Flatten, _) => inp.clone(),
                (act, _) => act.act(inp),
            };
            acts.push(z);
        }
        acts
    }

    /// Logits for a batch (test/diagnostic entry point).
    pub fn forward(&self, params: &[Tensor], x: &Tensor)
        -> Result<Tensor> {
        self.forward_threads(params, x, 1)
    }

    /// [`Model::forward`] sharded over the batch axis across
    /// `threads` scoped threads; shard logits concatenate in sample
    /// order.
    pub fn forward_threads(
        &self,
        params: &[Tensor],
        x: &Tensor,
        threads: usize,
    ) -> Result<Tensor> {
        let n = self.check_x(x)?;
        let geoms = self.geoms();
        let ops = self.bind(params, &geoms)?;
        let xs = x.f32s()?;
        let work = parallel::shards(n, threads);
        if work.len() <= 1 {
            let mut acts = self.forward_acts(&ops, &geoms, xs, n);
            return Ok(Tensor::from_f32(
                &[n, self.classes],
                acts.pop().expect("non-empty"),
            ));
        }
        let parts = parallel::par_map(&work, |r| {
            let mut acts = self.forward_acts(
                &ops,
                &geoms,
                &xs[r.start * self.in_dim..r.end * self.in_dim],
                r.len(),
            );
            acts.pop().expect("non-empty")
        });
        let mut logits = Vec::with_capacity(n * self.classes);
        for p in parts {
            logits.extend_from_slice(&p);
        }
        Ok(Tensor::from_f32(&[n, self.classes], logits))
    }

    /// Evaluation graph payload: mean loss + accuracy.
    pub fn evaluate(
        &self,
        params: &[Tensor],
        x: &Tensor,
        y: &Tensor,
    ) -> Result<BTreeMap<String, Tensor>> {
        self.evaluate_threads(params, x, y, 1)
    }

    /// [`Model::evaluate`] sharded over the batch axis: shards return
    /// (NLL sum, hit count) pairs, which reduce exactly.
    pub fn evaluate_threads(
        &self,
        params: &[Tensor],
        x: &Tensor,
        y: &Tensor,
        threads: usize,
    ) -> Result<BTreeMap<String, Tensor>> {
        let n = self.check_x(x)?;
        ensure!(y.shape == [n], "y shape {:?} != [{n}]", y.shape);
        let ys = y.i32s()?;
        let xs = x.f32s()?;
        let geoms = self.geoms();
        let ops = self.bind(params, &geoms)?;
        let c = self.classes;
        let ce = CrossEntropy;
        let parts =
            parallel::par_map(&parallel::shards(n, threads), |r| {
                let ns = r.len();
                let acts = self.forward_acts(
                    &ops,
                    &geoms,
                    &xs[r.start * self.in_dim..r.end * self.in_dim],
                    ns,
                );
                let logits = acts.last().expect("non-empty");
                let yr = &ys[r.start..r.end];
                (
                    ce.nll_sum(logits, yr, ns, c),
                    ce.correct(logits, yr, ns, c),
                )
            });
        let (mut nll, mut hits) = (0.0f64, 0usize);
        for (l, h) in parts {
            nll += l;
            hits += h;
        }
        let mut out = BTreeMap::new();
        out.insert(
            "loss".to_string(),
            Tensor::scalar_f32((nll / n as f64) as f32),
        );
        out.insert(
            "accuracy".to_string(),
            Tensor::scalar_f32(hits as f32 / n as f32),
        );
        Ok(out)
    }

    /// The single engine entry point: run the generalized backward
    /// pass, returning `loss`, `grad/*`, and every requested
    /// extension quantity under the manifest naming
    /// (`{extension}/{layer}/{param-or-factor}`).
    ///
    /// `extensions` names the registered modules to activate; the
    /// engine runs one backward walk per propagated quantity with at
    /// least one user, shards the batch over
    /// [`ExtractOptions::threads`] workers, and merges shard outputs
    /// by each module's [`Extension::reduce`] rule before the
    /// post-merge [`Extension::finish`] hooks run. Everything else --
    /// registry, PRNG key, tracing -- rides in the options struct:
    ///
    /// ```ignore
    /// // Serial, built-in registry, gradient-only:
    /// model.extended_backward(&params, &x, &y, &[],
    ///                         &ExtractOptions::default())?;
    /// // Sharded with an MC key:
    /// model.extended_backward(&params, &x, &y, &exts,
    ///     &ExtractOptions {
    ///         topology: Topology::local(8),
    ///         key: Some([7, 9]),
    ///         ..ExtractOptions::default()
    ///     })?;
    /// ```
    pub fn extended_backward(
        &self,
        params: &[Tensor],
        x: &Tensor,
        y: &Tensor,
        extensions: &[String],
        opts: &ExtractOptions,
    ) -> Result<Quantities> {
        if let Topology::Workers { .. } = opts.topology {
            return crate::dist::coordinate(
                self, params, x, y, extensions, opts,
            );
        }
        let builtin;
        let set = match &opts.registry {
            Some(set) => set,
            None => {
                builtin = ExtensionSet::builtin();
                &builtin
            }
        };
        let key = opts.key;
        let threads = opts.topology.threads();
        let _engine: Option<obs::Span> =
            opts.trace_label.as_ref().map(|label| {
                let label = label.clone();
                obs::span_with(obs::CAT_ENGINE, move || label)
            });
        let setup = obs::span(obs::CAT_PHASE, "setup");
        let active = set.select(extensions)?;
        self.check_active(&active, key)?;

        let n = self.check_x(x)?;
        ensure!(n > 0, "empty batch");
        ensure!(y.shape == [n], "y shape {:?} != [{n}]", y.shape);
        let ys = y.i32s()?;
        let xs = x.f32s()?;
        let geoms = self.geoms();
        let ops = self.bind(params, &geoms)?;
        let dims = self.dims();
        drop(setup);

        let mut out = self.prefinish(
            set, &ops, &geoms, &dims, xs, ys, n, threads, &active,
            key, 0, n,
        )?;
        let _finish = obs::span(obs::CAT_PHASE, "finish");
        let fctx = FinishCtx {
            model: self,
            ops: &ops,
            dims: &dims,
            threads,
            extensions,
        };
        for e in &active {
            let _hook = extensions_mod::hook_span(*e, "finish");
            e.finish(&fctx, &mut out)?;
        }
        Ok(out)
    }

    /// Validate the active extension selection against this model and
    /// the PRNG key (shared by every engine entry point).
    fn check_active(
        &self,
        active: &[&dyn Extension],
        key: Option<[u32; 2]>,
    ) -> Result<()> {
        for e in active {
            ensure!(
                !e.fully_connected_only() || self.is_fully_connected(),
                "{} is restricted to fully-connected models (paper \
                 footnote 5); model {:?} contains conv/pool layers",
                e.name(),
                self.name
            );
        }
        if active.iter().any(|e| e.needs_key()) && key.is_none() {
            bail!("MC extensions require a PRNG key input");
        }
        Ok(())
    }

    /// Run the pre-finish engine over one in-process slice: shard
    /// `[0, n)` across `threads`, walk each shard with global
    /// normalization (`global_n`) and global MC keying
    /// (`global_base + shard offset`), and merge shard outputs by the
    /// reduce contract.
    #[allow(clippy::too_many_arguments)]
    fn prefinish(
        &self,
        set: &ExtensionSet,
        ops: &[Option<LayerOp>],
        geoms: &[Geom],
        dims: &[usize],
        xs: &[f32],
        ys: &[i32],
        n: usize,
        threads: usize,
        active: &[&dyn Extension],
        key: Option<[u32; 2]>,
        global_base: usize,
        global_n: usize,
    ) -> Result<Quantities> {
        let work = parallel::shards(n, threads);
        if work.len() <= 1 {
            return self.backward_range(
                ops, geoms, dims, xs, ys, 0..n, global_n,
                global_base, active, key,
            );
        }
        let fork = obs::span(obs::CAT_ENGINE, "fork_join");
        let parts = parallel::par_map(&work, |r| {
            self.backward_range(
                ops, geoms, dims, xs, ys, r, global_n, global_base,
                active, key,
            )
        });
        drop(fork);
        let mut done = Vec::with_capacity(parts.len());
        for p in parts {
            done.push(p?);
        }
        let _reduce = obs::span(obs::CAT_PHASE, "reduce");
        ReducePlan::of(set).merge(done)
    }

    /// The worker half of process-parallel extraction: run the full
    /// pre-finish engine on one contiguous slice of a larger global
    /// batch. `x`/`y` hold only this slice's rows; `global_offset`
    /// is the slice's first global sample index and `global_n` the
    /// global batch size. Averaged quantities normalize by
    /// `global_n` and MC draws are keyed by global sample index, so
    /// slice outputs merge across processes exactly as thread shards
    /// merge ([`ReducePlan::merge`], in slice order).
    ///
    /// The post-merge [`Extension::finish`] hooks do NOT run here:
    /// they are nonlinear in the merged averages (variance from
    /// moments, KFRA's Ḡ recursion) and must run exactly once, after
    /// all slices merged. Internal pre-finish keys (`sq_moment/*`,
    /// `__kfra/*`) are therefore present in the output — feed the
    /// merged result through [`Model::finish_merged`].
    #[allow(clippy::too_many_arguments)]
    pub fn extended_backward_slice(
        &self,
        params: &[Tensor],
        x: &Tensor,
        y: &Tensor,
        extensions: &[String],
        opts: &ExtractOptions,
        global_offset: usize,
        global_n: usize,
    ) -> Result<Quantities> {
        ensure!(
            matches!(opts.topology, Topology::Local { .. }),
            "extended_backward_slice shards in-process only; a \
             Workers topology cannot nest"
        );
        let builtin;
        let set = match &opts.registry {
            Some(set) => set,
            None => {
                builtin = ExtensionSet::builtin();
                &builtin
            }
        };
        let key = opts.key;
        let threads = opts.topology.threads();
        let _engine: Option<obs::Span> =
            opts.trace_label.as_ref().map(|label| {
                let label = label.clone();
                obs::span_with(obs::CAT_ENGINE, move || label)
            });
        let setup = obs::span(obs::CAT_PHASE, "setup");
        let active = set.select(extensions)?;
        self.check_active(&active, key)?;

        let n = self.check_x(x)?;
        ensure!(n > 0, "empty slice");
        ensure!(y.shape == [n], "y shape {:?} != [{n}]", y.shape);
        ensure!(
            global_offset + n <= global_n,
            "slice [{global_offset}, {}) exceeds the global batch \
             size {global_n}",
            global_offset + n
        );
        let ys = y.i32s()?;
        let xs = x.f32s()?;
        let geoms = self.geoms();
        let ops = self.bind(params, &geoms)?;
        let dims = self.dims();
        drop(setup);

        self.prefinish(
            set, &ops, &geoms, &dims, xs, ys, n, threads, &active,
            key, global_offset, global_n,
        )
    }

    /// The coordinator half of process-parallel extraction: run the
    /// post-merge [`Extension::finish`] hooks once over merged slice
    /// outputs, with the layer operators re-bound from `params`.
    /// This is the exact finish stage [`Model::extended_backward`]
    /// runs after its thread-shard merge — variance materializes
    /// from the merged moments, KFRA's Ḡ recursion runs, and
    /// intermediates that were not explicitly requested are dropped.
    pub fn finish_merged(
        &self,
        params: &[Tensor],
        extensions: &[String],
        opts: &ExtractOptions,
        out: &mut Quantities,
    ) -> Result<()> {
        let builtin;
        let set = match &opts.registry {
            Some(set) => set,
            None => {
                builtin = ExtensionSet::builtin();
                &builtin
            }
        };
        let active = set.select(extensions)?;
        let geoms = self.geoms();
        let ops = self.bind(params, &geoms)?;
        let dims = self.dims();
        let _finish = obs::span(obs::CAT_PHASE, "finish");
        let fctx = FinishCtx {
            model: self,
            ops: &ops,
            dims: &dims,
            threads: opts.topology.threads(),
            extensions,
        };
        for e in &active {
            let _hook = extensions_mod::hook_span(*e, "finish");
            e.finish(&fctx, out)?;
        }
        Ok(())
    }

    /// Soft-deprecated positional-argument shim over
    /// [`Model::extended_backward`]: built-in registry, explicit
    /// `threads`. Prefer the options-struct entry point in new code.
    pub fn extended_backward_threads(
        &self,
        params: &[Tensor],
        x: &Tensor,
        y: &Tensor,
        extensions: &[String],
        key: Option<[u32; 2]>,
        threads: usize,
    ) -> Result<Quantities> {
        self.extended_backward(
            params,
            x,
            y,
            extensions,
            &ExtractOptions {
                topology: Topology::local(threads),
                key,
                ..ExtractOptions::default()
            },
        )
    }

    /// Soft-deprecated positional-argument shim over
    /// [`Model::extended_backward`] with an explicit registry -- the
    /// hook for user-defined quantities (see the registry docs in
    /// [`crate::backend::extensions`] for a complete example).
    /// Equivalent to passing `registry: Some(set.clone())` in
    /// [`ExtractOptions`]; registry clones are cheap (shared `Arc`
    /// modules).
    #[allow(clippy::too_many_arguments)]
    pub fn extended_backward_with(
        &self,
        set: &ExtensionSet,
        params: &[Tensor],
        x: &Tensor,
        y: &Tensor,
        extensions: &[String],
        key: Option<[u32; 2]>,
        threads: usize,
    ) -> Result<Quantities> {
        self.extended_backward(
            params,
            x,
            y,
            extensions,
            &ExtractOptions {
                registry: Some(set.clone()),
                topology: Topology::local(threads),
                key,
                trace_label: None,
            },
        )
    }

    /// Forward + backward over one contiguous sample range, with every
    /// averaged quantity normalized by the **global** batch size
    /// `total_n` (so shard outputs sum-reduce exactly) and per-sample
    /// quantities covering only the range (so shard outputs
    /// concatenate). `global_base` is the global sample index of
    /// `xs[0]` — nonzero when `xs` itself is a slice of a larger
    /// batch (process-parallel workers) — and offsets the MC-draw
    /// keying so draws stay tied to global sample indices. The
    /// full-range call `backward_range(.., 0..n, n, 0, ..)` is the
    /// serial engine. Extraction dispatches to the active extensions'
    /// hooks, one walk per propagated quantity.
    #[allow(clippy::too_many_arguments)]
    fn backward_range(
        &self,
        ops: &[Option<LayerOp>],
        geoms: &[Geom],
        dims: &[usize],
        xs: &[f32],
        ys: &[i32],
        range: Range<usize>,
        total_n: usize,
        global_base: usize,
        active: &[&dyn Extension],
        key: Option<[u32; 2]>,
    ) -> Result<Quantities> {
        let ns = range.len();
        let norm = total_n as f32;
        let c = self.classes;
        let ce = CrossEntropy;
        let x = &xs[range.start * self.in_dim..range.end * self.in_dim];
        let y = &ys[range.start..range.end];

        // ---- forward pass, storing every module input --------------
        let acts = self.forward_acts(ops, geoms, x, ns);
        let logits = acts.last().expect("non-empty");

        let mut out = Quantities::new();
        let loss_span = obs::span(obs::CAT_PHASE, "loss");
        out.insert(
            "loss".to_string(),
            Tensor::scalar_f32(
                (ce.nll_sum(logits, y, ns, c) / total_n as f64) as f32,
            ),
        );
        drop(loss_span);

        // ---- first-order backward walk (Eq. 3 + Fig. 4) ------------
        let fo: Vec<&dyn Extension> = active
            .iter()
            .copied()
            .filter(|e| e.walk() == Walk::Grad)
            .collect();
        // Residual seeds of the full-Hessian recursion (diag_h,
        // DESIGN.md §11): at every curved activation record
        // r = σ''(x) ⊙ g, where g is the loss gradient w.r.t. the
        // activation *output* (the walk state at the top of the
        // iteration) and σ'' is evaluated at its input. The exact
        // walk below births one signed factor per recorded layer.
        let need_res = active.iter().any(|e| e.needs_residual());
        let mut res_seeds: Vec<Option<Vec<f32>>> =
            vec![None; self.layers.len()];
        let grad_span = obs::span(obs::CAT_PHASE, "grad_walk");
        let mut g = ce.grad(logits, y, ns, c); // ∇_f ℓ_n, [ns, C]
        for li in (0..self.layers.len()).rev() {
            if need_res && self.layers[li].has_curvature() {
                let d2 = self.layers[li].d2_act(&acts[li]);
                res_seeds[li] = Some(
                    d2.iter().zip(&g).map(|(a, b)| a * b).collect(),
                );
            }
            if let Some(op) = &ops[li] {
                let ctx = LayerCtx::new(li, *op, &acts[li], ns, norm);
                self.grad_at(&ctx, &g, !fo.is_empty(), &mut out);
                for e in &fo {
                    let _hook =
                        extensions_mod::hook_span(*e, "first_order");
                    e.first_order(&ctx, &g, &mut out);
                }
            }
            if li > 0 {
                g = self.vjp_input(li, ops, geoms, &acts, g, ns);
            }
        }
        drop(grad_span);

        // ---- second-order backward walks (Eq. 18 / Fig. 5) ---------
        // One shared propagation per square-root variant: e.g.
        // diag_ggn, kflr and diag_h's GGN part extract from the same
        // exact-S walk. Residual factors (diag_h) ride the exact walk
        // only: they are born at curved activations from the recorded
        // seeds and propagate through the same transposed Jacobians.
        for (walk, exact) in
            [(Walk::SqrtGgn, true), (Walk::SqrtGgnMc, false)]
        {
            let users: Vec<&dyn Extension> = active
                .iter()
                .copied()
                .filter(|e| e.walk() == walk)
                .collect();
            if users.is_empty() {
                continue;
            }
            let res_users: Vec<&dyn Extension> = if exact {
                users
                    .iter()
                    .copied()
                    .filter(|e| e.needs_residual())
                    .collect()
            } else {
                Vec::new()
            };
            let _walk = obs::span(
                obs::CAT_PHASE,
                if exact { "sqrt_exact_walk" } else { "sqrt_mc_walk" },
            );
            let mut extras: Vec<ResidualFactor> = Vec::new();
            let (mut s, cols) = self.init_sqrt(
                &ce, logits, ns, exact, key, global_base + range.start,
            );
            for li in (0..self.layers.len()).rev() {
                if let Some(op) = &ops[li] {
                    let ctx =
                        LayerCtx::new(li, *op, &acts[li], ns, norm);
                    for e in &users {
                        let _hook =
                            extensions_mod::hook_span(*e, "sqrt_ggn");
                        e.sqrt_ggn(&ctx, &s, cols, &mut out);
                    }
                    for e in &res_users {
                        let _hook =
                            extensions_mod::hook_span(*e, "residual");
                        for f in &extras {
                            e.residual(
                                &ctx, &f.s, f.cols, &f.signs, &mut out,
                            );
                        }
                    }
                }
                if li > 0 {
                    s = self.mat_vjp_input(
                        li, ops, geoms, &acts, dims, s, ns, cols,
                    );
                    if !extras.is_empty() {
                        let _prop = obs::span(
                            obs::CAT_DETAIL,
                            "residual/propagate",
                        );
                        for f in &mut extras {
                            let fs = std::mem::take(&mut f.s);
                            f.s = self.mat_vjp_input(
                                li, ops, geoms, &acts, dims, fs, ns,
                                f.cols,
                            );
                        }
                    }
                    if !res_users.is_empty() {
                        if let Some(r) = &res_seeds[li] {
                            // Born at the activation's *input* — the
                            // coordinates the walk state now lives in.
                            extras.push(ResidualFactor::diag(
                                r, ns, dims[li],
                            ));
                        }
                    }
                }
            }
        }

        // ---- whole-shard hooks (Eq. 24: KFRA batch averages) -------
        let shard_exts: Vec<&dyn Extension> = active
            .iter()
            .copied()
            .filter(|e| e.walk() == Walk::Shard)
            .collect();
        if !shard_exts.is_empty() {
            let _shard = obs::span(obs::CAT_PHASE, "shard_hooks");
            let sctx = ShardCtx {
                model: self,
                ops,
                acts: &acts,
                dims,
                n: ns,
                norm,
            };
            for e in &shard_exts {
                let _hook =
                    extensions_mod::hook_span(*e, "batch_averages");
                e.batch_averages(&sctx, &mut out);
            }
        }
        Ok(out)
    }

    /// Averaged gradient of one parameterized layer (engine-core —
    /// the extension quantities extract through [`Extension`] hooks).
    /// When first-order extensions are active at a conv layer, the
    /// gradient reduces over the shared [`LayerCtx::per_sample_grads`]
    /// cache so the per-sample `G_n ⟦x⟧_nᵀ` products are computed
    /// once; otherwise it streams without materializing them.
    fn grad_at(
        &self,
        ctx: &LayerCtx,
        g: &[f32],
        share_per_sample: bool,
        out: &mut Quantities,
    ) {
        let (li, n, nf) = (ctx.li, ctx.n, ctx.norm);
        match ctx.op {
            LayerOp::Linear { din, dout, .. } => {
                // (1/N) gᵀ x and (1/N) Σ_n g_n.
                let mut gw = matmul_tn(g, ctx.input, n, dout, din);
                for v in &mut gw {
                    *v /= nf;
                }
                let mut gb = vec![0.0f32; dout];
                for s in 0..n {
                    for o in 0..dout {
                        gb[o] += g[s * dout + o];
                    }
                }
                for v in &mut gb {
                    *v /= nf;
                }
                out.insert(
                    format!("grad/{li}/w"),
                    Tensor::from_f32(&[dout, din], gw),
                );
                out.insert(
                    format!("grad/{li}/b"),
                    Tensor::from_f32(&[dout], gb),
                );
            }
            LayerOp::Conv { geom, .. } => {
                let (gw, gb) = if share_per_sample {
                    let ps = ctx.per_sample_grads(g);
                    let (co, j) =
                        (geom.out_shape.c, geom.patch_len());
                    let mut gw = vec![0.0f32; co * j];
                    let mut gb = vec![0.0f32; co];
                    for smp in 0..n {
                        for (acc, v) in
                            gw.iter_mut().zip(&ps.w[smp * co * j..])
                        {
                            *acc += v;
                        }
                        for (acc, v) in
                            gb.iter_mut().zip(&ps.b[smp * co..])
                        {
                            *acc += v;
                        }
                    }
                    for v in gw.iter_mut().chain(gb.iter_mut()) {
                        *v /= nf;
                    }
                    (gw, gb)
                } else {
                    conv2d::grad(geom, ctx.input, g, n, nf)
                };
                out.insert(
                    format!("grad/{li}/w"),
                    Tensor::from_f32(&geom.w_shape(), gw),
                );
                out.insert(
                    format!("grad/{li}/b"),
                    Tensor::from_f32(&[geom.out_shape.c], gb),
                );
            }
        }
    }

    /// Apply (J_x z)ᵀ per sample: g [N, out] -> [N, in] (Eq. 3).
    fn vjp_input(
        &self,
        li: usize,
        ops: &[Option<LayerOp>],
        geoms: &[Geom],
        acts: &[Vec<f32>],
        g: Vec<f32>,
        n: usize,
    ) -> Vec<f32> {
        match (&self.layers[li], &geoms[li]) {
            (Layer::Linear { .. }, _) => {
                let op = ops[li].expect("bound");
                // [N, out] x [out, in] -> [N, in]
                matmul(&g, op.w(), n, op.dout(), op.a_dim())
            }
            (Layer::Conv2d { .. }, Geom::Conv(geom)) => {
                let op = ops[li].expect("bound");
                conv2d::vjp_input(geom, op.w(), &g, n)
            }
            (Layer::MaxPool2d { .. }, Geom::Pool(geom)) => {
                geom.vjp(&acts[li], &g, n, 1)
            }
            (Layer::GlobalAvgPool, Geom::Gap { c, hw }) => {
                pool::gap_vjp(*c, *hw, &g, n, 1)
            }
            (Layer::Flatten, _) => g,
            (act, _) => {
                let d = act.d_act(&acts[li]);
                g.iter().zip(&d).map(|(gv, dv)| gv * dv).collect()
            }
        }
    }

    /// Apply (J_x z)ᵀ columnwise: S [N, out, cols] -> [N, in, cols]
    /// (Eq. 18).
    #[allow(clippy::too_many_arguments)]
    fn mat_vjp_input(
        &self,
        li: usize,
        ops: &[Option<LayerOp>],
        geoms: &[Geom],
        acts: &[Vec<f32>],
        dims: &[usize],
        s: Vec<f32>,
        n: usize,
        cols: usize,
    ) -> Vec<f32> {
        match (&self.layers[li], &geoms[li]) {
            (Layer::Linear { .. }, _) => {
                let op = ops[li].expect("bound");
                let (din, dout) = (op.a_dim(), op.dout());
                let w = op.w();
                let mut out = vec![0.0f32; n * din * cols];
                for smp in 0..n {
                    let blk =
                        &s[smp * dout * cols..(smp + 1) * dout * cols];
                    let t = matmul_tn(w, blk, dout, din, cols);
                    out[smp * din * cols..(smp + 1) * din * cols]
                        .copy_from_slice(&t);
                }
                out
            }
            (Layer::Conv2d { .. }, Geom::Conv(geom)) => {
                let op = ops[li].expect("bound");
                conv2d::mat_vjp_input(geom, op.w(), &s, n, cols)
            }
            (Layer::MaxPool2d { .. }, Geom::Pool(geom)) => {
                geom.vjp(&acts[li], &s, n, cols)
            }
            (Layer::GlobalAvgPool, Geom::Gap { c, hw }) => {
                pool::gap_vjp(*c, *hw, &s, n, cols)
            }
            (Layer::Flatten, _) => s,
            (act, _) => {
                let f = dims[li];
                let d = act.d_act(&acts[li]); // [N * f]
                let mut s = s;
                for (idx, dv) in d.iter().enumerate() {
                    debug_assert!(idx < n * f);
                    let base = idx * cols;
                    for col in 0..cols {
                        s[base + col] *= dv;
                    }
                }
                s
            }
        }
    }

    /// Initial loss-Hessian square root at the logits: exact
    /// `[N, C, C]` or Monte-Carlo `[N, C, M]` (Eq. 15 / 20). `base` is
    /// the shard's global sample offset, keying the MC draws so they
    /// are invariant to the shard layout.
    #[allow(clippy::too_many_arguments)]
    fn init_sqrt(
        &self,
        ce: &CrossEntropy,
        logits: &[f32],
        n: usize,
        exact: bool,
        key: Option<[u32; 2]>,
        base: usize,
    ) -> (Vec<f32>, usize) {
        if exact {
            (ce.sqrt_hessian(logits, n, self.classes), self.classes)
        } else {
            let key = key.expect("checked by extended_backward");
            (
                ce.sqrt_hessian_mc(
                    logits, n, self.classes, key, MC_SAMPLES, base,
                ),
                MC_SAMPLES,
            )
        }
    }
}

/// One signed residual factor of the full-Hessian recursion
/// (DESIGN.md §11), in flight during the exact square-root walk: the
/// factor matrix `s [n, F, cols]` (layout identical to the propagated
/// `S`) and the per-(sample, column) signs it was born with. The
/// represented Hessian component is
/// `Σ_c signs[n,c] · s[n,·,c] s[n,·,c]ᵀ`; transposed Jacobians act on
/// `s` columnwise and never mix columns, so the signs are invariant
/// along the walk.
struct ResidualFactor {
    s: Vec<f32>,
    cols: usize,
    signs: Vec<f32>,
}

impl ResidualFactor {
    /// Factor for one curved activation's residual `diag(r)` with
    /// `r = σ''(x) ⊙ g [ns·f]`: a diagonal square root `√|r|` with
    /// `cols = f` columns plus the signs of `r` (`signum`; zero
    /// entries keep a zero factor value, so their sign is inert).
    fn diag(r: &[f32], ns: usize, f: usize) -> ResidualFactor {
        debug_assert_eq!(r.len(), ns * f);
        let mut s = vec![0.0f32; ns * f * f];
        let mut signs = vec![0.0f32; ns * f];
        for (idx, &rv) in r.iter().enumerate() {
            s[idx * f + idx % f] = rv.abs().sqrt();
            signs[idx] = rv.signum();
        }
        ResidualFactor { s, cols: f, signs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::train::init_params;
    use crate::data::Rng;

    fn tiny() -> Model {
        Model::new(
            "tiny",
            5,
            vec![
                Layer::Linear { in_dim: 5, out_dim: 4 },
                Layer::Sigmoid,
                Layer::Linear { in_dim: 4, out_dim: 3 },
            ],
        )
        .unwrap()
    }

    fn tiny_params(m: &Model, seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        m.param_specs()
            .iter()
            .map(|t| {
                let k: usize = t.shape.iter().product();
                Tensor::from_f32(
                    &t.shape,
                    (0..k).map(|_| rng.normal() * 0.4).collect(),
                )
            })
            .collect()
    }

    fn batch(m: &Model, n: usize, seed: u64) -> (Tensor, Tensor) {
        let mut rng = Rng::new(seed ^ 0xBA7);
        let x: Vec<f32> =
            (0..n * m.in_dim).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..n)
            .map(|_| rng.below(m.classes) as i32)
            .collect();
        (
            Tensor::from_f32(&[n, m.in_dim], x),
            Tensor::from_i32(&[n], y),
        )
    }

    #[test]
    fn registry_models_validate() {
        assert_eq!(Model::logreg().num_params(), 7_850);
        assert_eq!(Model::mlp().num_params(), 109_386);
        assert_eq!(Model::mlp().classes, 10);
        assert!(Model::new(
            "bad",
            5,
            vec![Layer::Linear { in_dim: 6, out_dim: 2 }]
        )
        .is_err());
    }

    #[test]
    fn conv_registry_models_match_paper_counts() {
        // Paper Table 3 parameter checksums.
        let m = Model::conv_2c2d();
        assert_eq!(m.num_params(), 3_274_634);
        assert_eq!((m.classes, m.in_dim), (10, 784));
        let m = Model::conv_3c3d();
        assert_eq!(m.num_params(), 895_210);
        assert_eq!((m.classes, m.in_dim), (10, 3072));
        // The Fig. 9 variant swaps one activation, not one parameter.
        let m = Model::conv_3c3d_sigmoid();
        assert_eq!(m.num_params(), 895_210);
        assert_eq!(m.name, "3c3d_sigmoid");
        let pos = m.layers.len() - 2;
        assert_eq!(m.layers[pos], Layer::Sigmoid);
        assert_eq!(
            m.layers.iter().filter(|l| l.has_curvature()).count(),
            1,
            "exactly one sigmoid (Fig. 9 configuration)"
        );
        // All-CNN-C's count is spatial-size-invariant.
        for side in [16usize, 32] {
            let m = Model::allcnnc(side);
            assert_eq!(m.num_params(), 1_387_108, "side {side}");
            assert_eq!(m.classes, 100);
            assert_eq!(m.in_dim, 3 * side * side);
            assert!(!m.is_fully_connected());
        }
        assert!(Model::logreg().is_fully_connected());
        assert!(!Model::conv_2c2d().is_fully_connected());
    }

    #[test]
    fn conv_3c3d_shape_chain() {
        // The DeepOBS trace behind the 1152-dim flatten.
        let shapes = Model::conv_3c3d().shapes();
        assert_eq!(shapes[1], Shape::new(64, 28, 28)); // conv1 valid
        assert_eq!(shapes[3], Shape::new(64, 14, 14)); // pool ceil
        assert_eq!(shapes[6], Shape::new(96, 6, 6));
        assert_eq!(shapes[9], Shape::new(128, 3, 3));
        assert_eq!(shapes[10].flat(), 1152); // flatten
    }

    #[test]
    fn dims_chain_through_activations() {
        assert_eq!(tiny().dims(), vec![5, 4, 4, 3]);
    }

    #[test]
    fn kfra_rejected_on_conv_models() {
        let m = Model::with_input(
            "tinyconv",
            Shape::new(1, 4, 4),
            vec![
                Layer::Conv2d {
                    in_ch: 1, out_ch: 2, kernel: 3, stride: 1, pad: 1,
                },
                Layer::Relu,
                Layer::Flatten,
                Layer::Linear { in_dim: 32, out_dim: 3 },
            ],
        )
        .unwrap();
        let params = tiny_params(&m, 1);
        let (x, y) = batch(&m, 4, 1);
        let exts = vec!["kfra".to_string()];
        let err = m
            .extended_backward(&params, &x, &y, &exts, &ExtractOptions::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("fully-connected"), "{err}");
    }

    #[test]
    fn loss_at_init_is_near_log_c() {
        let m = tiny();
        // Manifest-style fan-in init via the shared init_params path
        // keeps logits small: loss ≈ ln(3).
        let specs = m.param_specs();
        let mut rng = Rng::new(3);
        let params: Vec<Tensor> = specs
            .iter()
            .map(|t| {
                let k: usize = t.shape.iter().product();
                let data = match t.init.as_ref().unwrap() {
                    Init::Zeros => vec![0.0; k],
                    Init::Uniform { bound } => (0..k)
                        .map(|_| rng.uniform_in(-bound, *bound))
                        .collect(),
                };
                Tensor::from_f32(&t.shape, data)
            })
            .collect();
        let (x, y) = batch(&m, 16, 0);
        let out = m
            .extended_backward(&params, &x, &y, &[], &ExtractOptions::default())
            .unwrap();
        let loss = out.get("loss").unwrap().item_f32().unwrap();
        assert!((0.7..1.6).contains(&loss), "loss {loss}");
    }

    #[test]
    fn grad_matches_central_finite_differences() {
        let m = tiny();
        let mut params = tiny_params(&m, 1);
        let (x, y) = batch(&m, 6, 1);
        let out = m
            .extended_backward(&params, &x, &y, &[], &ExtractOptions::default())
            .unwrap();
        let eps = 1e-2f32;
        for (pi, spec) in m.param_specs().iter().enumerate() {
            let (prefix, _) = spec.name.split_at(6); // "param/"
            assert_eq!(prefix, "param/");
            let gname = format!("grad/{}", &spec.name[6..]);
            let g = out.get(&gname).unwrap().f32s().unwrap().to_vec();
            let k = params[pi].numel();
            for idx in (0..k).step_by(3) {
                let orig = params[pi].f32s().unwrap()[idx];
                params[pi].f32s_mut().unwrap()[idx] = orig + eps;
                let lp = m
                    .extended_backward(&params, &x, &y, &[], &ExtractOptions::default())
                    .unwrap()
                    .get("loss")
                    .unwrap()
                    .item_f32()
                    .unwrap();
                params[pi].f32s_mut().unwrap()[idx] = orig - eps;
                let lm = m
                    .extended_backward(&params, &x, &y, &[], &ExtractOptions::default())
                    .unwrap()
                    .get("loss")
                    .unwrap()
                    .item_f32()
                    .unwrap();
                params[pi].f32s_mut().unwrap()[idx] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let tol = 1e-3 * (1.0 + fd.abs().max(g[idx].abs()));
                assert!(
                    (g[idx] - fd).abs() < tol,
                    "{gname}[{idx}]: {} vs fd {fd}", g[idx]
                );
            }
        }
    }

    #[test]
    fn mc_requires_key() {
        let m = tiny();
        let params = tiny_params(&m, 2);
        let (x, y) = batch(&m, 4, 2);
        let exts = vec!["diag_ggn_mc".to_string()];
        assert!(m
            .extended_backward(&params, &x, &y, &exts, &ExtractOptions::default())
            .is_err());
        assert!(m
            .extended_backward(
                &params,
                &x,
                &y,
                &exts,
                &ExtractOptions {
                    key: Some([1, 2]),
                    ..ExtractOptions::default()
                },
            )
            .is_ok());
    }

    #[test]
    fn unknown_extension_rejected() {
        let m = tiny();
        let params = tiny_params(&m, 2);
        let (x, y) = batch(&m, 4, 2);
        let exts = vec!["hessian".to_string()];
        let err = m
            .extended_backward(&params, &x, &y, &exts, &ExtractOptions::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("not supported"), "{err}");
    }

    #[test]
    fn diag_h_equals_diag_ggn_on_piecewise_linear_models() {
        // ReLU's σ'' is identically zero, so no residual factors are
        // born and the Hessian diagonal IS the GGN diagonal — the
        // identity DESIGN.md §11 documents.
        let m = Model::new(
            "tinyrelu",
            5,
            vec![
                Layer::Linear { in_dim: 5, out_dim: 4 },
                Layer::Relu,
                Layer::Linear { in_dim: 4, out_dim: 3 },
            ],
        )
        .unwrap();
        let params = tiny_params(&m, 21);
        let (x, y) = batch(&m, 6, 21);
        let exts =
            vec!["diag_h".to_string(), "diag_ggn".to_string()];
        let out = m
            .extended_backward(&params, &x, &y, &exts, &ExtractOptions::default())
            .unwrap();
        for li in [0usize, 2] {
            for part in ["w", "b"] {
                let h = out[&format!("diag_h/{li}/{part}")]
                    .f32s()
                    .unwrap();
                let g = out[&format!("diag_ggn/{li}/{part}")]
                    .f32s()
                    .unwrap();
                for (u, v) in h.iter().zip(g) {
                    assert!(
                        (u - v).abs() <= 1e-6 * (1.0 + u.abs()),
                        "diag_h/{li}/{part}: {u} vs diag_ggn {v}"
                    );
                }
            }
        }
    }

    #[test]
    fn diag_h_differs_from_diag_ggn_past_a_sigmoid() {
        // Below tiny()'s sigmoid the residual term is active: layers
        // 0's Hessian diagonal must NOT equal its GGN diagonal, while
        // layer 2 (above the sigmoid, linear in its own weights) must
        // agree exactly.
        let m = tiny();
        let params = tiny_params(&m, 22);
        let (x, y) = batch(&m, 6, 22);
        let exts =
            vec!["diag_h".to_string(), "diag_ggn".to_string()];
        let out = m
            .extended_backward(&params, &x, &y, &exts, &ExtractOptions::default())
            .unwrap();
        let h0 = out["diag_h/0/w"].f32s().unwrap();
        let g0 = out["diag_ggn/0/w"].f32s().unwrap();
        let max_rel = h0
            .iter()
            .zip(g0)
            .map(|(u, v)| (u - v).abs() / (1.0 + v.abs()))
            .fold(0.0f32, f32::max);
        assert!(
            max_rel > 1e-4,
            "residual term had no effect below the sigmoid \
             (max rel diff {max_rel})"
        );
        let h2 = out["diag_h/2/w"].f32s().unwrap();
        let g2 = out["diag_ggn/2/w"].f32s().unwrap();
        for (u, v) in h2.iter().zip(g2) {
            assert!(
                (u - v).abs() <= 1e-6 * (1.0 + u.abs()),
                "above the sigmoid diag_h must equal diag_ggn: \
                 {u} vs {v}"
            );
        }
    }

    #[test]
    fn threaded_backward_matches_serial_on_tiny() {
        let m = tiny();
        let params = tiny_params(&m, 9);
        let (x, y) = batch(&m, 7, 9); // 7 samples: uneven shards
        let exts: Vec<String> =
            ["batch_grad", "batch_l2", "variance", "diag_ggn_mc",
             "diag_h", "kfac", "kfra"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let key = Some([3, 4]);
        let serial = m
            .extended_backward(
                &params,
                &x,
                &y,
                &exts,
                &ExtractOptions { key, ..ExtractOptions::default() },
            )
            .unwrap();
        // variance was requested without sq_moment: the intermediate
        // moments must not leak, nor the internal __kfra partials.
        assert!(serial.keys().all(|k| {
            !k.starts_with("sq_moment/") && !k.starts_with("__kfra")
        }));
        for t in [2usize, 3, 5, 16] {
            let par = m
                .extended_backward_threads(&params, &x, &y, &exts, key, t)
                .unwrap();
            assert_eq!(
                serial.keys().collect::<Vec<_>>(),
                par.keys().collect::<Vec<_>>(),
                "threads={t}"
            );
            for (k, want) in &serial {
                let got = par.get(k).unwrap();
                assert_eq!(want.shape, got.shape, "{k} threads={t}");
                for (u, v) in want
                    .f32s()
                    .unwrap()
                    .iter()
                    .zip(got.f32s().unwrap())
                {
                    assert!(
                        (u - v).abs() <= 1e-5 * (1.0 + u.abs()),
                        "{k} threads={t}: {u} vs {v}"
                    );
                }
            }
        }
    }

    #[test]
    fn threaded_forward_and_evaluate_match_serial() {
        let m = tiny();
        let params = tiny_params(&m, 11);
        let (x, y) = batch(&m, 9, 11);
        let logits = m.forward(&params, &x).unwrap();
        for t in [2usize, 4, 9] {
            let lt = m.forward_threads(&params, &x, t).unwrap();
            assert_eq!(logits.shape, lt.shape);
            for (u, v) in
                logits.f32s().unwrap().iter().zip(lt.f32s().unwrap())
            {
                assert!((u - v).abs() <= 1e-6, "threads={t}");
            }
            let es = m.evaluate(&params, &x, &y).unwrap();
            let ep = m.evaluate_threads(&params, &x, &y, t).unwrap();
            for k in ["loss", "accuracy"] {
                let a = es[k].item_f32().unwrap();
                let b = ep[k].item_f32().unwrap();
                assert!((a - b).abs() <= 1e-6, "{k} threads={t}");
            }
        }
    }

    #[test]
    fn init_params_integration() {
        // The shared init path (manifest Init rules) produces the right
        // shapes for a synthesized native spec.
        use crate::backend::Backend;
        let be = crate::backend::native::NativeBackend::new();
        let spec = be.spec("logreg_grad_n8").unwrap();
        let params = init_params(&spec, 0);
        assert_eq!(params.len(), 2);
        assert_eq!(params[0].name, "param/0/w");
        assert_eq!(params[0].tensor.shape, vec![10, 784]);
        assert_eq!(params[1].tensor.f32s().unwrap(), &[0.0; 10]);
    }
}
