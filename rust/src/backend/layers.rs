//! Native layer set (paper Sec. 2's modular feed-forward setting).
//!
//! The native backend covers the fully-connected slice of the paper's
//! model zoo: affine maps plus elementwise activations, the layers for
//! which every BackPACK quantity has a closed-form extraction rule
//! (Table 1 / Eq. 19 / Eq. 23). Convolutions stay on the PJRT backend.
//!
//! Activations here are stateless; the engine in `model.rs` owns the
//! stored forward activations and calls back into these rules, exactly
//! like the Python layer framework (`python/compile/layers.py`) whose
//! conventions this mirrors: activations `[N, features]` row-major,
//! `Linear: w [out, in], b [out]`, weight and bias as separate blocks
//! (paper footnote 7).

use anyhow::{ensure, Result};

/// One module of a native sequential model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layer {
    /// `z = x Wᵀ + b` with `w [out, in]`, `b [out]`.
    Linear { in_dim: usize, out_dim: usize },
    Relu,
    Sigmoid,
}

impl Layer {
    pub fn has_params(&self) -> bool {
        matches!(self, Layer::Linear { .. })
    }

    /// Output feature dimension given the input dimension; checks the
    /// chain for `Linear`.
    pub fn out_dim(&self, in_dim: usize) -> Result<usize> {
        match *self {
            Layer::Linear { in_dim: d, out_dim } => {
                ensure!(
                    d == in_dim,
                    "Linear expects {d} input features, got {in_dim}"
                );
                Ok(out_dim)
            }
            Layer::Relu | Layer::Sigmoid => Ok(in_dim),
        }
    }

    /// Elementwise activation σ(x); `Linear` is handled by the engine.
    pub fn act(&self, x: &[f32]) -> Vec<f32> {
        match self {
            Layer::Relu => x.iter().map(|&v| v.max(0.0)).collect(),
            Layer::Sigmoid => x.iter().map(|&v| sigmoid(v)).collect(),
            Layer::Linear { .. } => {
                unreachable!("Linear forward lives in the engine")
            }
        }
    }

    /// Elementwise derivative σ'(x) at the layer *input*.
    pub fn d_act(&self, x: &[f32]) -> Vec<f32> {
        match self {
            Layer::Relu => x
                .iter()
                .map(|&v| if v > 0.0 { 1.0 } else { 0.0 })
                .collect(),
            Layer::Sigmoid => x
                .iter()
                .map(|&v| {
                    let s = sigmoid(v);
                    s * (1.0 - s)
                })
                .collect(),
            Layer::Linear { .. } => {
                unreachable!("Linear has no activation derivative")
            }
        }
    }
}

#[inline]
fn sigmoid(v: f32) -> f32 {
    1.0 / (1.0 + (-v).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_chain() {
        let l = Layer::Linear { in_dim: 4, out_dim: 3 };
        assert_eq!(l.out_dim(4).unwrap(), 3);
        assert!(l.out_dim(5).is_err());
        assert_eq!(Layer::Relu.out_dim(7).unwrap(), 7);
    }

    #[test]
    fn relu_act_and_derivative() {
        let x = [-1.0, 0.0, 2.0];
        assert_eq!(Layer::Relu.act(&x), vec![0.0, 0.0, 2.0]);
        assert_eq!(Layer::Relu.d_act(&x), vec![0.0, 0.0, 1.0]);
    }

    #[test]
    fn sigmoid_matches_finite_difference() {
        let x = [-2.0f32, -0.3, 0.0, 1.7];
        let s = Layer::Sigmoid.act(&x);
        let d = Layer::Sigmoid.d_act(&x);
        let eps = 1e-3f32;
        for (i, &v) in x.iter().enumerate() {
            assert!((0.0..=1.0).contains(&s[i]));
            let fd = (sigmoid(v + eps) - sigmoid(v - eps)) / (2.0 * eps);
            assert!((d[i] - fd).abs() < 1e-4, "σ'({v}): {} vs {fd}", d[i]);
        }
    }
}
