//! Native layer set (paper Sec. 2's modular feed-forward setting).
//!
//! The native backend covers the paper's full model zoo: affine maps
//! (`Linear`, `Conv2d` via the im2col lowering in `backend/conv/`),
//! the pooling layers (`MaxPool2d`, `GlobalAvgPool`), `Flatten`, and
//! elementwise activations — the layers for which every BackPACK
//! quantity has a closed-form extraction rule (Table 1 / Eq. 19 /
//! Eq. 23; DESIGN.md §6 for the conv conventions).
//!
//! Activations here are stateless; the engine in `model.rs` owns the
//! stored forward activations and calls back into these rules, exactly
//! like the Python layer framework (`python/compile/layers.py`) whose
//! conventions this mirrors: activations `[N, features]` row-major
//! with image features flattened `[c][h][w]`, `Linear: w [out, in],
//! b [out]`, `Conv2d: w [out_ch, in_ch, k, k], b [out_ch]`, weight
//! and bias as separate blocks (paper footnote 7).

use anyhow::{ensure, Result};

use super::conv::{ConvGeom, PoolGeom, Shape};

/// One module of a native sequential model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layer {
    /// `z = x Wᵀ + b` with `w [out, in]`, `b [out]`; expects the
    /// flattened feature dimension to match `in_dim`.
    Linear { in_dim: usize, out_dim: usize },
    /// Square-kernel 2-D convolution, symmetric zero padding
    /// (`w [out_ch, in_ch, k, k]`, `b [out_ch]`).
    Conv2d {
        in_ch: usize,
        out_ch: usize,
        kernel: usize,
        stride: usize,
        pad: usize,
    },
    /// Square max pooling with border clipping; `ceil` selects the
    /// TF-style output-size rule `⌈(in − k)/stride⌉ + 1`.
    MaxPool2d { kernel: usize, stride: usize, ceil: bool },
    /// Global average pool `(c, h, w) -> (c, 1, 1)` (All-CNN-C head).
    GlobalAvgPool,
    /// `(c, h, w) -> (c·h·w, 1, 1)`; a no-op on the flat storage, it
    /// marks the conv→dense transition for shape validation.
    Flatten,
    Relu,
    Sigmoid,
}

impl Layer {
    pub fn has_params(&self) -> bool {
        matches!(self, Layer::Linear { .. } | Layer::Conv2d { .. })
    }

    /// Output activation shape given the input shape; validates the
    /// chain (feature dims for `Linear`, channel/window geometry for
    /// the spatial layers).
    pub fn out_shape(&self, s: Shape) -> Result<Shape> {
        match *self {
            Layer::Linear { in_dim, out_dim } => {
                ensure!(
                    s.flat() == in_dim,
                    "Linear expects {in_dim} input features, got {}",
                    s.flat()
                );
                Ok(Shape::flat_vec(out_dim))
            }
            Layer::Conv2d { in_ch, out_ch, kernel, stride, pad } => {
                ensure!(
                    s.c == in_ch,
                    "Conv2d expects {in_ch} input channels, got {}",
                    s.c
                );
                Ok(ConvGeom::new(s, out_ch, kernel, stride, pad)?
                    .out_shape)
            }
            Layer::MaxPool2d { kernel, stride, ceil } => {
                Ok(PoolGeom::new(s, kernel, stride, ceil)?.out_shape)
            }
            Layer::GlobalAvgPool => {
                ensure!(
                    s.h * s.w >= 1,
                    "GlobalAvgPool needs a spatial extent"
                );
                Ok(Shape::new(s.c, 1, 1))
            }
            Layer::Flatten => Ok(Shape::flat_vec(s.flat())),
            Layer::Relu | Layer::Sigmoid => Ok(s),
        }
    }

    /// Elementwise activation σ(x); every other layer is handled by
    /// the engine.
    pub fn act(&self, x: &[f32]) -> Vec<f32> {
        match self {
            Layer::Relu => x.iter().map(|&v| v.max(0.0)).collect(),
            Layer::Sigmoid => x.iter().map(|&v| sigmoid(v)).collect(),
            _ => unreachable!("only activations have σ"),
        }
    }

    /// Elementwise derivative σ'(x) at the layer *input*.
    pub fn d_act(&self, x: &[f32]) -> Vec<f32> {
        match self {
            Layer::Relu => x
                .iter()
                .map(|&v| if v > 0.0 { 1.0 } else { 0.0 })
                .collect(),
            Layer::Sigmoid => x
                .iter()
                .map(|&v| {
                    let s = sigmoid(v);
                    s * (1.0 - s)
                })
                .collect(),
            _ => unreachable!("only activations have σ'"),
        }
    }

    /// True when σ''(x) is not identically zero — the layers whose
    /// residual term `diag(σ''(x) ⊙ g)` feeds the full-Hessian
    /// recursion behind `diag_h` (DESIGN.md §11). ReLU is piecewise
    /// linear (σ'' = 0 almost everywhere, the autodiff convention), so
    /// on all-ReLU networks DiagH coincides with DiagGGN.
    pub fn has_curvature(&self) -> bool {
        matches!(self, Layer::Sigmoid)
    }

    /// Elementwise second derivative σ''(x) at the layer *input*.
    pub fn d2_act(&self, x: &[f32]) -> Vec<f32> {
        match self {
            Layer::Relu => vec![0.0; x.len()],
            Layer::Sigmoid => x
                .iter()
                .map(|&v| {
                    let s = sigmoid(v);
                    s * (1.0 - s) * (1.0 - 2.0 * s)
                })
                .collect(),
            _ => unreachable!("only activations have σ''"),
        }
    }
}

#[inline]
fn sigmoid(v: f32) -> f32 {
    1.0 / (1.0 + (-v).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_chain() {
        let l = Layer::Linear { in_dim: 4, out_dim: 3 };
        assert_eq!(
            l.out_shape(Shape::flat_vec(4)).unwrap(),
            Shape::flat_vec(3)
        );
        assert!(l.out_shape(Shape::flat_vec(5)).is_err());
        // Linear accepts any geometry with the right flat dim.
        assert_eq!(
            l.out_shape(Shape::new(1, 2, 2)).unwrap(),
            Shape::flat_vec(3)
        );
        assert_eq!(
            Layer::Relu.out_shape(Shape::flat_vec(7)).unwrap(),
            Shape::flat_vec(7)
        );
    }

    #[test]
    fn spatial_chain() {
        let s = Shape::new(1, 28, 28);
        let c = Layer::Conv2d {
            in_ch: 1, out_ch: 32, kernel: 5, stride: 1, pad: 2,
        };
        let s = c.out_shape(s).unwrap();
        assert_eq!(s, Shape::new(32, 28, 28));
        let p = Layer::MaxPool2d { kernel: 2, stride: 2, ceil: false };
        let s = p.out_shape(s).unwrap();
        assert_eq!(s, Shape::new(32, 14, 14));
        assert_eq!(
            Layer::Flatten.out_shape(s).unwrap(),
            Shape::flat_vec(32 * 14 * 14)
        );
        assert_eq!(
            Layer::GlobalAvgPool.out_shape(s).unwrap(),
            Shape::new(32, 1, 1)
        );
        // Channel mismatch rejected.
        let bad = Layer::Conv2d {
            in_ch: 3, out_ch: 8, kernel: 3, stride: 1, pad: 1,
        };
        assert!(bad.out_shape(s).is_err());
    }

    #[test]
    fn relu_act_and_derivative() {
        let x = [-1.0, 0.0, 2.0];
        assert_eq!(Layer::Relu.act(&x), vec![0.0, 0.0, 2.0]);
        assert_eq!(Layer::Relu.d_act(&x), vec![0.0, 0.0, 1.0]);
    }

    #[test]
    fn sigmoid_matches_finite_difference() {
        let x = [-2.0f32, -0.3, 0.0, 1.7];
        let s = Layer::Sigmoid.act(&x);
        let d = Layer::Sigmoid.d_act(&x);
        let eps = 1e-3f32;
        for (i, &v) in x.iter().enumerate() {
            assert!((0.0..=1.0).contains(&s[i]));
            let fd = (sigmoid(v + eps) - sigmoid(v - eps)) / (2.0 * eps);
            assert!((d[i] - fd).abs() < 1e-4, "σ'({v}): {} vs {fd}", d[i]);
        }
    }

    #[test]
    fn second_derivatives_match_finite_differences_of_the_first() {
        let x = [-2.0f32, -0.3, 0.4, 1.7];
        let d2 = Layer::Sigmoid.d2_act(&x);
        let eps = 1e-3f32;
        for (i, &v) in x.iter().enumerate() {
            let sp = Layer::Sigmoid.d_act(&[v + eps])[0];
            let sm = Layer::Sigmoid.d_act(&[v - eps])[0];
            let fd = (sp - sm) / (2.0 * eps);
            assert!(
                (d2[i] - fd).abs() < 1e-4,
                "σ''({v}): {} vs fd {fd}",
                d2[i]
            );
        }
        // σ'' changes sign at 0 — the reason diag_h factors are signed.
        assert!(d2[0] > 0.0 && d2[3] < 0.0);
        assert!(Layer::Sigmoid.has_curvature());
        // ReLU is piecewise linear: zero curvature everywhere.
        assert!(!Layer::Relu.has_curvature());
        assert_eq!(Layer::Relu.d2_act(&x), vec![0.0; 4]);
    }
}
