//! Typed artifact addressing: [`Signature`] + [`ArtifactId`].
//!
//! The whole coordinator historically spoke artifact names as raw
//! strings (`"mnist_mlp_diag_ggn+kfac_n128"`), with the grammar
//! scattered across private helpers (`parse_sig`, `split_batch`).
//! This module promotes the two halves of that grammar to public
//! types with `FromStr`/`Display` round-trips:
//!
//! * [`Signature`] -- what sits between the model name and the batch
//!   suffix: `eval`, `grad` (the empty extension list), or a
//!   `+`-joined list of extension names;
//! * [`ArtifactId`] -- the full address `{model}_{sig}_n{batch}`.
//!
//! The string forms remain the canonical wire/manifest spelling; the
//! typed forms are what the native backend, the CLI, the bench grid
//! and the `serve` daemon construct and pass around. Nothing here
//! consults an extension registry: [`Signature`] validates the
//! *grammar* (which names are representable), while registries
//! ([`crate::backend::extensions::ExtensionSet`],
//! [`crate::backend::native::NativeBackend`]) validate *membership*
//! and use [`suggest`] to offer nearest-match candidates on failure.
//!
//! ```
//! use backpack_rs::{ArtifactId, Signature};
//!
//! let sig: Signature = "diag_ggn+kfac".parse()?;
//! assert_eq!(sig.extensions(), ["diag_ggn", "kfac"]);
//!
//! let id = ArtifactId::new("mlp", sig, 128)?;
//! assert_eq!(id.to_string(), "mlp_diag_ggn+kfac_n128");
//!
//! // Round-trip: parsing the display form restores the id.
//! let back: ArtifactId = id.to_string().parse()?;
//! assert_eq!(back, id);
//! # Ok::<(), anyhow::Error>(())
//! ```

use std::fmt;
use std::str::FromStr;

use anyhow::{bail, ensure, Result};

use super::extensions::BUILTIN_NAMES;

/// The extension-signature position of an artifact name: `eval`,
/// `grad`, or a `+`-joined extension list.
///
/// `Extract(vec![])` is the gradient-only training graph and displays
/// as `"grad"`; [`Signature::Eval`] is the evaluation graph (`loss` +
/// `accuracy`). Parsing validates the grammar of each part (the same
/// rules [`ExtensionSet::register`] enforces), not registry
/// membership.
///
/// [`ExtensionSet::register`]: crate::backend::extensions::ExtensionSet::register
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Signature {
    /// Evaluation graph: `loss` + `accuracy`, no extensions.
    Eval,
    /// Training graph returning `loss`, `grad/*` and the listed
    /// extension quantities (empty list = gradient only, spelled
    /// `grad`).
    Extract(Vec<String>),
}

impl Signature {
    /// The gradient-only training signature (`"grad"`).
    pub fn grad() -> Signature {
        Signature::Extract(Vec::new())
    }

    /// A training signature over validated extension names.
    pub fn extract<I, S>(parts: I) -> Result<Signature>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let parts: Vec<String> =
            parts.into_iter().map(Into::into).collect();
        for p in &parts {
            Self::check_part(p)?;
        }
        Ok(Signature::Extract(parts))
    }

    /// The requested extension names (empty for `grad` and `eval`).
    pub fn extensions(&self) -> &[String] {
        match self {
            Signature::Eval => &[],
            Signature::Extract(parts) => parts,
        }
    }

    /// True for the evaluation signature.
    pub fn is_eval(&self) -> bool {
        matches!(self, Signature::Eval)
    }

    /// True for the gradient-only training signature.
    pub fn is_grad(&self) -> bool {
        matches!(self, Signature::Extract(p) if p.is_empty())
    }

    /// Validate one extension name against the signature/output-key
    /// grammar: non-empty, no `+` (the signature separator), no `/`
    /// (the output-key separator), no whitespace, not the reserved
    /// words `grad`/`eval`, and no trailing `_n<digits>` (the batch
    /// suffix [`ArtifactId::split_batch`] would strip). This is the
    /// single authority both [`Signature`] parsing and
    /// [`ExtensionSet::register`] consult.
    ///
    /// [`ExtensionSet::register`]: crate::backend::extensions::ExtensionSet::register
    pub fn check_part(name: &str) -> Result<()> {
        ensure!(
            !name.is_empty()
                && !name.contains('+')
                && !name.contains('/')
                && !name.contains(char::is_whitespace)
                && name != "grad"
                && name != "eval",
            "extension name {name:?} is not a valid signature part \
             (empty, reserved, or contains '+'/'/'/' ')"
        );
        if let Some(pos) = name.rfind("_n") {
            let digits = &name[pos + 2..];
            ensure!(
                digits.is_empty()
                    || !digits.bytes().all(|b| b.is_ascii_digit()),
                "extension name {name:?} ends in a _n<digits> batch \
                 suffix, which artifact-name parsing would strip"
            );
        }
        Ok(())
    }
}

impl fmt::Display for Signature {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Signature::Eval => f.write_str("eval"),
            Signature::Extract(parts) if parts.is_empty() => {
                f.write_str("grad")
            }
            Signature::Extract(parts) => {
                f.write_str(&parts.join("+"))
            }
        }
    }
}

impl FromStr for Signature {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Signature> {
        match s {
            "eval" => Ok(Signature::Eval),
            "grad" => Ok(Signature::grad()),
            _ => Signature::extract(s.split('+')),
        }
    }
}

/// A fully qualified artifact address: `{model}_{sig}_n{batch}`.
///
/// `Display` produces the canonical manifest/wire spelling; `FromStr`
/// parses it back against the built-in extension vocabulary (see
/// [`ArtifactId::parse_with`] for custom vocabularies, and
/// [`NativeBackend`]'s registry-aware resolution for the
/// authoritative model split).
///
/// [`NativeBackend`]: crate::backend::native::NativeBackend
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ArtifactId {
    /// Registered model name (may itself contain `_`, e.g.
    /// `3c3d_sigmoid`).
    pub model: String,
    /// The extension-signature position (`eval`, `grad`, or a
    /// `+`-joined list).
    pub sig: Signature,
    /// Batch size (> 0).
    pub batch: usize,
}

impl ArtifactId {
    /// A validated id. The model name must be representable in the
    /// artifact grammar: non-empty, no `+`/`/`/whitespace, not a
    /// reserved word, and no trailing `_n<digits>` (which the batch
    /// split would swallow).
    pub fn new(
        model: impl Into<String>,
        sig: Signature,
        batch: usize,
    ) -> Result<ArtifactId> {
        let model = model.into();
        ensure!(batch > 0, "artifact batch must be > 0");
        ensure!(
            !model.contains('_') || Self::split_batch(&model).is_none(),
            "model name {model:?} ends in a _n<digits> batch suffix"
        );
        ensure!(
            !model.is_empty()
                && !model.contains('+')
                && !model.contains('/')
                && !model.contains(char::is_whitespace)
                && model != "grad"
                && model != "eval",
            "model name {model:?} is not representable in the \
             artifact grammar"
        );
        Ok(ArtifactId { model, sig, batch })
    }

    /// Split the trailing batch suffix:
    /// `"logreg_grad_n64"` -> `("logreg_grad", 64)`.
    pub fn split_batch(artifact: &str) -> Option<(&str, usize)> {
        let pos = artifact.rfind("_n")?;
        let digits = &artifact[pos + 2..];
        if digits.is_empty()
            || !digits.bytes().all(|b| b.is_ascii_digit())
        {
            return None;
        }
        Some((&artifact[..pos], digits.parse().ok()?))
    }

    /// Parse an artifact name against an explicit extension
    /// vocabulary. Model names and extension names may both contain
    /// `_`, so the model/signature split is resolved by scanning `_`
    /// boundaries left to right and taking the first split whose
    /// remainder is a valid signature over `is_part` -- i.e. the
    /// **longest signature** wins (`"mlp_batch_grad_n8"` splits as
    /// `mlp` + `batch_grad`, never `mlp_batch` + `grad`). Backends
    /// that know their registered models resolve the split
    /// authoritatively instead (longest registered model-name prefix);
    /// this parse is the registry-free fallback used by `FromStr`.
    pub fn parse_with(
        artifact: &str,
        is_part: &dyn Fn(&str) -> bool,
    ) -> Result<ArtifactId> {
        let Some((stem, batch)) = Self::split_batch(artifact) else {
            bail!(
                "artifact name {artifact:?} does not end in _n<batch>"
            )
        };
        ensure!(batch > 0, "artifact {artifact:?}: batch must be > 0");
        for (i, b) in stem.bytes().enumerate() {
            if b != b'_' || i == 0 || i + 1 == stem.len() {
                continue;
            }
            let (model, rest) = (&stem[..i], &stem[i + 1..]);
            let Ok(sig) = rest.parse::<Signature>() else {
                continue;
            };
            if sig.extensions().iter().all(|p| is_part(p)) {
                return ArtifactId::new(model, sig, batch);
            }
        }
        bail!(
            "artifact name {artifact:?} has no model_signature split \
             over the known extension vocabulary"
        )
    }
}

impl fmt::Display for ArtifactId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}_{}_n{}", self.model, self.sig, self.batch)
    }
}

impl FromStr for ArtifactId {
    type Err = anyhow::Error;

    /// Parse against the built-in extension vocabulary
    /// ([`BUILTIN_NAMES`]). Backends with user-registered extensions
    /// or ambiguous model names should use [`ArtifactId::parse_with`]
    /// or their registry-aware resolution.
    fn from_str(s: &str) -> Result<ArtifactId> {
        ArtifactId::parse_with(s, &|p| BUILTIN_NAMES.contains(&p))
    }
}

/// Nearest-match candidates for an unknown name: every candidate
/// within a small edit distance of `target`, closest first (ties
/// alphabetical), capped at three. Powers the "did you mean ...?"
/// suffix of the resolver's error messages.
pub fn suggest<I, S>(target: &str, candidates: I) -> Vec<String>
where
    I: IntoIterator<Item = S>,
    S: AsRef<str>,
{
    let cutoff = 2.max(target.len() / 3);
    let mut scored: Vec<(usize, String)> = candidates
        .into_iter()
        .filter_map(|c| {
            let c = c.as_ref();
            let d = levenshtein(target, c);
            (d <= cutoff).then(|| (d, c.to_string()))
        })
        .collect();
    scored.sort();
    scored.dedup();
    scored.truncate(3);
    scored.into_iter().map(|(_, c)| c).collect()
}

/// Format a suggestion list as an error-message suffix:
/// `"" | " (did you mean \"kfac\"?)" | " (did you mean one of ...)"`.
pub(crate) fn did_you_mean(suggestions: &[String]) -> String {
    match suggestions {
        [] => String::new(),
        [one] => format!(" (did you mean {one:?}?)"),
        many => format!(" (did you mean one of {many:?}?)"),
    }
}

/// Classic two-row Levenshtein edit distance.
fn levenshtein(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) =
        (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signature_parse_display_round_trip() {
        for s in [
            "grad", "eval", "batch_grad", "diag_ggn_mc",
            "diag_ggn+kfac", "batch_grad+batch_l2+sq_moment+variance",
        ] {
            let sig: Signature = s.parse().unwrap();
            assert_eq!(sig.to_string(), s, "round trip of {s:?}");
            let again: Signature = sig.to_string().parse().unwrap();
            assert_eq!(again, sig);
        }
        assert!("eval".parse::<Signature>().unwrap().is_eval());
        assert!("grad".parse::<Signature>().unwrap().is_grad());
        assert_eq!(
            "diag_ggn+kfac"
                .parse::<Signature>()
                .unwrap()
                .extensions(),
            ["diag_ggn", "kfac"]
        );
    }

    #[test]
    fn signature_rejects_grammar_violations() {
        for bad in [
            "", "+", "a++b", "grad+kfac", "kfac+grad", "a b",
            "a/b", "kfac+eval", "mine_n64",
        ] {
            assert!(
                bad.parse::<Signature>().is_err(),
                "{bad:?} must not parse"
            );
        }
        // `_n` without a digit tail is fine (not a batch suffix).
        assert!("my_norm".parse::<Signature>().is_ok());
        assert!(Signature::check_part("diag_h").is_ok());
        assert!(Signature::check_part("grad").is_err());
    }

    #[test]
    fn artifact_id_round_trips_builtin_grid() {
        let models =
            ["logreg", "mlp", "2c2d", "3c3d", "3c3d_sigmoid",
             "allcnnc16"];
        let sigs = [
            "grad", "eval", "batch_grad", "diag_ggn", "diag_ggn_mc",
            "diag_h", "kfac", "kflr", "kfra",
            "batch_grad+batch_l2+sq_moment+variance",
        ];
        for m in models {
            for s in sigs {
                for batch in [1usize, 8, 128] {
                    let id = ArtifactId::new(
                        m,
                        s.parse().unwrap(),
                        batch,
                    )
                    .unwrap();
                    let name = id.to_string();
                    assert_eq!(
                        name,
                        format!("{m}_{s}_n{batch}")
                    );
                    let back: ArtifactId = name.parse().unwrap();
                    assert_eq!(back, id, "round trip of {name:?}");
                }
            }
        }
    }

    #[test]
    fn artifact_id_split_prefers_the_longest_signature() {
        // "mlp_batch_grad_n8" must split mlp + batch_grad, not
        // mlp_batch + grad.
        let id: ArtifactId = "mlp_batch_grad_n8".parse().unwrap();
        assert_eq!(id.model, "mlp");
        assert_eq!(id.sig.extensions(), ["batch_grad"]);
        // Fig. 9 model: the underscore belongs to the model.
        let id: ArtifactId =
            "3c3d_sigmoid_diag_h_n8".parse().unwrap();
        assert_eq!(id.model, "3c3d_sigmoid");
        assert_eq!(id.sig.extensions(), ["diag_h"]);
    }

    #[test]
    fn artifact_id_rejects_malformed_names() {
        assert!("logreg_grad".parse::<ArtifactId>().is_err());
        assert!("logreg_grad_nX".parse::<ArtifactId>().is_err());
        assert!("logreg_grad_n0".parse::<ArtifactId>().is_err());
        assert!("grad_n8".parse::<ArtifactId>().is_err());
        // Unknown extension vocabulary: no valid split exists.
        assert!("logreg_hessian_n8".parse::<ArtifactId>().is_err());
        assert!(ArtifactId::new("", Signature::grad(), 8).is_err());
        assert!(
            ArtifactId::new("m+x", Signature::grad(), 8).is_err()
        );
        assert!(
            ArtifactId::new("mlp", Signature::grad(), 0).is_err()
        );
        assert!(
            ArtifactId::new("mlp_n64", Signature::grad(), 8).is_err()
        );
    }

    #[test]
    fn parse_with_honors_custom_vocabularies() {
        let vocab = |p: &str| p == "bias_l2" || p == "diag_ggn";
        let id = ArtifactId::parse_with(
            "tiny_mlp_bias_l2+diag_ggn_n4",
            &vocab,
        )
        .unwrap();
        assert_eq!(id.model, "tiny_mlp");
        assert_eq!(id.sig.extensions(), ["bias_l2", "diag_ggn"]);
        assert!(ArtifactId::parse_with(
            "tiny_mlp_kfac_n4",
            &vocab
        )
        .is_err());
    }

    #[test]
    fn split_batch_matches_the_historical_grammar() {
        assert_eq!(
            ArtifactId::split_batch("logreg_grad_n64"),
            Some(("logreg_grad", 64))
        );
        assert_eq!(
            ArtifactId::split_batch("logreg_batch_grad+variance_n8"),
            Some(("logreg_batch_grad+variance", 8))
        );
        assert_eq!(ArtifactId::split_batch("logreg_grad"), None);
        assert_eq!(ArtifactId::split_batch("logreg_grad_nX"), None);
    }

    #[test]
    fn suggest_ranks_by_edit_distance() {
        let names = BUILTIN_NAMES;
        assert_eq!(suggest("diag_gnn", names), ["diag_ggn"]);
        // "kfca" is edit-1 from "kfra" (c->r) but edit-2 from
        // "kfac" (plain Levenshtein counts a transposition as 2).
        assert_eq!(suggest("kfca", names)[0], "kfra");
        let s = suggest("kfc", names);
        assert_eq!(s[0], "kfac", "{s:?}");
        // Hopeless inputs suggest nothing.
        assert!(suggest(
            "completely_unrelated_quantity",
            names
        )
        .is_empty());
        assert_eq!(
            suggest("logrge", ["logreg", "mlp", "2c2d"]),
            ["logreg"]
        );
    }

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein("", ""), 0);
        assert_eq!(levenshtein("abc", "abc"), 0);
        assert_eq!(levenshtein("abc", ""), 3);
        assert_eq!(levenshtein("kitten", "sitting"), 3);
        assert_eq!(levenshtein("kfac", "kfca"), 2);
    }

    #[test]
    fn did_you_mean_formats() {
        assert_eq!(did_you_mean(&[]), "");
        assert_eq!(
            did_you_mean(&["kfac".to_string()]),
            " (did you mean \"kfac\"?)"
        );
        assert!(did_you_mean(&[
            "kfac".to_string(),
            "kfra".to_string()
        ])
        .contains("one of"));
    }
}
