//! Softmax cross-entropy with the derivative interfaces BackPACK needs
//! (mirror of `python/compile/losses.py`, same conventions).
//!
//! Per sample n (batch axis kept; the engine applies Table 1's 1/N):
//!
//! * `value`           -- mean loss over the batch (Eq. 1),
//! * `grad`            -- ∇_f ℓ_n = p − e_y (unnormalized),
//! * `sqrt_hessian`    -- exact S [N, C, C] with S Sᵀ = ∇²_f ℓ_n:
//!                        `S = diag(√p) − p √pᵀ` (Eq. 15),
//! * `sqrt_hessian_mc` -- rank-M Monte-Carlo S̃ [N, C, M] with
//!                        E[S̃ S̃ᵀ] = ∇²_f ℓ_n: ŷ ~ Cat(p),
//!                        s̃ = (p − e_ŷ)/√M (Eq. 20-21),
//! * `hessian_mean`    -- 1/N Σ_n ∇²_f ℓ_n (Eq. 24b, KFRA's Ḡ^(L)).
//!
//! `sqrt_hessian` is also the root of the full-Hessian (`diag_h`)
//! recursion (DESIGN.md §11): softmax cross-entropy is twice
//! differentiable in the logits and `S Sᵀ` *is* its complete second
//! derivative -- the loss contributes no residual term of its own, so
//! the exact square-root walk seeds DiagH and the only signed residual
//! factors are born at curved activations
//! ([`crate::backend::layers::Layer::d2_act`]).

use crate::data::{splitmix64, Rng};

/// Softmax cross-entropy over logits `[N, C]`, labels `[N]`.
pub struct CrossEntropy;

impl CrossEntropy {
    /// Softmax probabilities p [N, C] (max-subtracted, stable).
    pub fn probs(&self, logits: &[f32], n: usize, c: usize) -> Vec<f32> {
        let mut p = vec![0.0f32; n * c];
        for s in 0..n {
            let row = &logits[s * c..(s + 1) * c];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for (j, &v) in row.iter().enumerate() {
                let e = (v - m).exp();
                p[s * c + j] = e;
                z += e;
            }
            for j in 0..c {
                p[s * c + j] /= z;
            }
        }
        p
    }

    /// Summed (unnormalized) negative log-likelihood, f64-accumulated.
    /// The batch-parallel engine computes this per shard and divides by
    /// the *global* batch size, so shard results sum-reduce exactly.
    pub fn nll_sum(&self, logits: &[f32], y: &[i32], n: usize, c: usize)
        -> f64 {
        let mut total = 0.0f64;
        for s in 0..n {
            let row = &logits[s * c..(s + 1) * c];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 = row.iter().map(|&v| (v - m).exp()).sum();
            let lse = m + lse.ln();
            total += (lse - row[y[s] as usize]) as f64;
        }
        total
    }

    /// Mean negative log-likelihood over the batch.
    pub fn value(&self, logits: &[f32], y: &[i32], n: usize, c: usize)
        -> f32 {
        (self.nll_sum(logits, y, n, c) / n as f64) as f32
    }

    /// Per-sample output gradient ∇_f ℓ_n = p − e_y, [N, C].
    pub fn grad(&self, logits: &[f32], y: &[i32], n: usize, c: usize)
        -> Vec<f32> {
        let mut g = self.probs(logits, n, c);
        for s in 0..n {
            g[s * c + y[s] as usize] -= 1.0;
        }
        g
    }

    /// Exact symmetric Hessian factorization S [N, C, C] (row-major
    /// `[n, a, b]`): `S[a,b] = δ_ab √p_b − p_a √p_b`.
    pub fn sqrt_hessian(&self, logits: &[f32], n: usize, c: usize)
        -> Vec<f32> {
        let p = self.probs(logits, n, c);
        let mut s = vec![0.0f32; n * c * c];
        for i in 0..n {
            let pr = &p[i * c..(i + 1) * c];
            for a in 0..c {
                for b in 0..c {
                    let sq = pr[b].max(0.0).sqrt();
                    let mut v = -pr[a] * sq;
                    if a == b {
                        v += sq;
                    }
                    s[(i * c + a) * c + b] = v;
                }
            }
        }
        s
    }

    /// Monte-Carlo factorization S̃ [N, C, M]: ŷ ~ Cat(p) per column,
    /// `s̃ = (p − e_ŷ)/√M`. Deterministic in `key` and in each sample's
    /// *global* batch index `base + i`: every sample owns a counter-mode
    /// RNG stream derived from (key, index), so the draws -- and hence
    /// every MC quantity -- are identical no matter how the batch is
    /// sharded across threads.
    pub fn sqrt_hessian_mc(
        &self,
        logits: &[f32],
        n: usize,
        c: usize,
        key: [u32; 2],
        samples: usize,
        base: usize,
    ) -> Vec<f32> {
        let p = self.probs(logits, n, c);
        let keyed = splitmix64(((key[0] as u64) << 32) | key[1] as u64);
        let scale = 1.0 / (samples as f32).sqrt();
        let mut s = vec![0.0f32; n * c * samples];
        for i in 0..n {
            let pr = &p[i * c..(i + 1) * c];
            let mut rng = Rng::new(splitmix64(
                keyed ^ splitmix64(0x5EED ^ (base + i) as u64),
            ));
            for m in 0..samples {
                let u = rng.uniform();
                let mut cum = 0.0f32;
                let mut yhat = c - 1;
                for (j, &pj) in pr.iter().enumerate() {
                    cum += pj;
                    if u < cum {
                        yhat = j;
                        break;
                    }
                }
                for a in 0..c {
                    let mut v = pr[a];
                    if a == yhat {
                        v -= 1.0;
                    }
                    s[(i * c + a) * samples + m] = v * scale;
                }
            }
        }
        s
    }

    /// Batch-averaged output Hessian Ḡ^(L) [C, C] (Eq. 24b):
    /// `1/N Σ_n diag(p_n) − p_n p_nᵀ`.
    pub fn hessian_mean(&self, logits: &[f32], n: usize, c: usize)
        -> Vec<f32> {
        let p = self.probs(logits, n, c);
        let mut h = vec![0.0f32; c * c];
        for i in 0..n {
            let pr = &p[i * c..(i + 1) * c];
            for a in 0..c {
                for b in 0..c {
                    let mut v = -pr[a] * pr[b];
                    if a == b {
                        v += pr[a];
                    }
                    h[a * c + b] += v;
                }
            }
        }
        let nf = n as f32;
        for v in &mut h {
            *v /= nf;
        }
        h
    }

    /// Number of top-1 hits (the shard-reducible numerator of
    /// [`Self::accuracy`]).
    pub fn correct(&self, logits: &[f32], y: &[i32], n: usize, c: usize)
        -> usize {
        let mut hits = 0usize;
        for s in 0..n {
            let row = &logits[s * c..(s + 1) * c];
            let mut best = 0usize;
            for j in 1..c {
                if row[j] > row[best] {
                    best = j;
                }
            }
            if best == y[s] as usize {
                hits += 1;
            }
        }
        hits
    }

    /// Top-1 accuracy.
    pub fn accuracy(&self, logits: &[f32], y: &[i32], n: usize, c: usize)
        -> f32 {
        self.correct(logits, y, n, c) as f32 / n as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const LOGITS: [f32; 6] = [0.5, -1.0, 2.0, 0.0, 0.0, 0.0];
    const Y: [i32; 2] = [2, 0];

    #[test]
    fn probs_normalize_and_grad_rows_sum_to_zero() {
        let ce = CrossEntropy;
        let p = ce.probs(&LOGITS, 2, 3);
        for s in 0..2 {
            let sum: f32 = p[s * 3..(s + 1) * 3].iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
        let g = ce.grad(&LOGITS, &Y, 2, 3);
        for s in 0..2 {
            let sum: f32 = g[s * 3..(s + 1) * 3].iter().sum();
            assert!(sum.abs() < 1e-6, "grad row {s} sums to {sum}");
        }
    }

    #[test]
    fn value_matches_uniform_logits() {
        let ce = CrossEntropy;
        // Sample 1 has uniform logits: nll = ln(3).
        let v = ce.value(&LOGITS[3..], &Y[1..], 1, 3);
        assert!((v - 3.0f32.ln()).abs() < 1e-5, "{v}");
    }

    #[test]
    fn sqrt_hessian_reconstructs_softmax_hessian() {
        // S Sᵀ must equal diag(p) − p pᵀ per sample.
        let ce = CrossEntropy;
        let (n, c) = (2, 3);
        let p = ce.probs(&LOGITS, n, c);
        let s = ce.sqrt_hessian(&LOGITS, n, c);
        for i in 0..n {
            for a in 0..c {
                for b in 0..c {
                    let mut got = 0.0f32;
                    for k in 0..c {
                        got += s[(i * c + a) * c + k]
                            * s[(i * c + b) * c + k];
                    }
                    let pa = p[i * c + a];
                    let pb = p[i * c + b];
                    let want =
                        if a == b { pa - pa * pb } else { -pa * pb };
                    assert!(
                        (got - want).abs() < 1e-5,
                        "H[{i}][{a}{b}] {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn mc_factor_is_deterministic_per_key_and_key_sensitive() {
        let ce = CrossEntropy;
        let a = ce.sqrt_hessian_mc(&LOGITS, 2, 3, [1, 1], 1, 0);
        let b = ce.sqrt_hessian_mc(&LOGITS, 2, 3, [1, 1], 1, 0);
        assert_eq!(a, b);
        // Many samples: astronomically unlikely to draw identically.
        let big: Vec<f32> = (0..300).map(|i| (i % 7) as f32 * 0.3).collect();
        let y = ce.sqrt_hessian_mc(&big, 100, 3, [2, 2], 1, 0);
        let z = ce.sqrt_hessian_mc(&big, 100, 3, [3, 3], 1, 0);
        assert_ne!(y, z);
    }

    #[test]
    fn mc_factor_draws_are_shard_invariant() {
        // Computing a sub-range with the matching base offset must
        // reproduce the full-batch draws exactly -- the property the
        // batch-parallel engine relies on for MC extensions.
        let ce = CrossEntropy;
        let big: Vec<f32> =
            (0..60).map(|i| ((i % 11) as f32 - 5.0) * 0.2).collect();
        let full = ce.sqrt_hessian_mc(&big, 20, 3, [4, 9], 2, 0);
        let shard = ce.sqrt_hessian_mc(&big[7 * 3..15 * 3], 8, 3,
                                       [4, 9], 2, 7);
        assert_eq!(&full[7 * 3 * 2..15 * 3 * 2], &shard[..]);
    }

    #[test]
    fn mc_factor_is_unbiased_for_the_hessian() {
        // Average S̃ S̃ᵀ over many keys ≈ diag(p) − p pᵀ.
        let ce = CrossEntropy;
        let logits = [1.0f32, 0.0, -0.5];
        let p = ce.probs(&logits, 1, 3);
        let draws: u32 = 4000;
        let mut acc = vec![0.0f64; 9];
        for k in 0..draws {
            let s = ce.sqrt_hessian_mc(&logits, 1, 3, [k, 7], 1, 0);
            for a in 0..3 {
                for b in 0..3 {
                    acc[a * 3 + b] +=
                        (s[a] * s[b]) as f64 / draws as f64;
                }
            }
        }
        for a in 0..3 {
            for b in 0..3 {
                let want = if a == b {
                    p[a] - p[a] * p[b]
                } else {
                    -p[a] * p[b]
                };
                let want = want as f64;
                assert!(
                    (acc[a * 3 + b] - want).abs() < 0.03,
                    "E[SSᵀ][{a}{b}] {} vs {want}",
                    acc[a * 3 + b]
                );
            }
        }
    }

    #[test]
    fn accuracy_counts_argmax() {
        let ce = CrossEntropy;
        // Argmaxes are class 2 (sample 0) and class 0 (uniform ties
        // break to the first index, sample 1).
        assert_eq!(ce.accuracy(&LOGITS, &[2, 0], 2, 3), 1.0);
        assert_eq!(ce.accuracy(&LOGITS, &[0, 2], 2, 3), 0.0);
        assert_eq!(ce.accuracy(&LOGITS, &[2, 1], 2, 3), 0.5);
    }
}
