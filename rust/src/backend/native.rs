//! The native execution backend: pure-Rust training graphs.
//!
//! Instead of loading pre-compiled HLO artifacts, this backend
//! *synthesizes* the artifact on demand from its name -- the same
//! naming scheme `python/compile/aot.py` records in the manifest:
//!
//! * `{model}_{ext-signature}_n{batch}` -- training graph returning
//!   `loss`, `grad/*` and the signature's extension quantities
//!   (signature = extensions joined with `+`, or `grad` for none);
//! * `{model}_eval_n{batch}` -- evaluation graph returning `loss` and
//!   `accuracy`.
//!
//! Because graphs are synthesized, *any* batch size works and there is
//! no compile step: `load` is O(1) and `run` does the actual math via
//! `model::Model::extended_backward`. The registry ships the paper's
//! full model zoo: the fully-connected `logreg` and `mlp`, the
//! convolutional `2c2d`, `3c3d` and `allcnnc{16,32}` (im2col lowering
//! in `backend/conv/`; side-parameterized models are keyed
//! `{model}{side}`), and the Fig. 9 variant `3c3d_sigmoid`. Every
//! problem in `coordinator/problems.rs` and every one of the ten
//! paper quantities — including `diag_h`'s residual recursion — is
//! servable here with zero external dependencies; `kfra` stays
//! fully-connected-only (paper footnote 5).
//! Extraction rules live in the extension registry
//! (`backend/extensions/`): a signature part is valid exactly when an
//! [`Extension`] with that name is registered, and its output shapes
//! come from [`Extension::output_specs`]. Tests (and library users)
//! can [`NativeBackend::register`] additional models and
//! [`NativeBackend::register_extension`] additional quantities.

use std::collections::BTreeMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use super::api::{did_you_mean, suggest, ArtifactId, Signature};
use super::extensions::{f32_spec, Extension, ExtensionSet};
use super::model::{ExtractOptions, Model, Topology};
use super::{Backend, Exec, Outputs};
use crate::runtime::{ArtifactSpec, Tensor, TensorSpec};

/// Extension signatures advertised by `artifact_names` (single
/// extensions plus the Fig. 1 combined first-order graph).
const LISTED_SIGS: &[&str] = &[
    "grad", "batch_grad", "batch_l2", "sq_moment", "variance",
    "diag_ggn", "diag_ggn_mc", "diag_h", "kfac", "kflr", "kfra",
    "batch_grad+batch_l2+sq_moment+variance",
];

/// A registry of native models, serving synthesized artifacts.
pub struct NativeBackend {
    models: BTreeMap<String, Model>,
    /// Batch-parallel worker count every loaded [`NativeExec`]
    /// inherits (resolved: >= 1).
    threads: usize,
    /// Extension registry every loaded [`NativeExec`] dispatches
    /// through; starts as [`ExtensionSet::builtin`] and grows via
    /// [`NativeBackend::register_extension`].
    extensions: ExtensionSet,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeBackend {
    /// Registry with the built-in fully-connected models, auto-sized
    /// batch parallelism (all cores; `BACKPACK_THREADS` overrides).
    pub fn new() -> NativeBackend {
        Self::with_threads(0)
    }

    /// Registry with an explicit worker count (`0` = auto). `1` is
    /// the serial reference configuration.
    pub fn with_threads(threads: usize) -> NativeBackend {
        let mut b = NativeBackend {
            models: BTreeMap::new(),
            threads: crate::parallel::resolve_threads(threads),
            extensions: ExtensionSet::builtin(),
        };
        b.register(Model::logreg());
        b.register(Model::mlp());
        b.register(Model::conv_2c2d());
        b.register(Model::conv_3c3d());
        b.register(Model::conv_3c3d_sigmoid()); // Fig. 9 (diag_h)
        b.register(Model::allcnnc(16)); // CPU-scaled cifar100 problem
        b.register(Model::allcnnc(32)); // paper-sized overhead benches
        b
    }

    /// The resolved batch-parallel worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Register an additional model (used by tests to serve tiny MLPs
    /// through the full backend path).
    pub fn register(&mut self, model: Model) {
        self.models.insert(model.name.clone(), model);
    }

    /// Register a user-defined [`Extension`]: its
    /// [`Extension::name`] becomes a valid signature part of every
    /// model's artifact names (`{model}_{name}_n{batch}`, `+`-joined
    /// with others) and computations loaded afterwards dispatch to
    /// its hooks. Registering a built-in name replaces that module.
    pub fn register_extension(&mut self, ext: impl Extension + 'static) {
        self.extensions.register(ext);
    }

    /// The extension registry this backend serves.
    pub fn extensions(&self) -> &ExtensionSet {
        &self.extensions
    }

    /// Look up one registered model by name (how `backpack worker`
    /// resolves the model a coordinator's shard plan names).
    pub fn model(&self, name: &str) -> Result<&Model> {
        self.models.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "model {name:?} is not in the native registry {:?}{}",
                self.model_names(),
                did_you_mean(&suggest(name, self.model_names()))
            )
        })
    }

    fn model_names(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    /// Parse a signature string against this backend's extension
    /// registry, with nearest-match suggestions on unknown parts.
    fn parse_signature(&self, sig: &str) -> Result<Signature> {
        let sig: Signature = sig.parse()?;
        for part in sig.extensions() {
            ensure!(
                self.extensions.contains(part),
                "extension {part:?} is not supported by the native \
                 backend (registered: {:?}){}",
                self.extensions.names(),
                did_you_mean(&suggest(part, self.extensions.names()))
            );
        }
        Ok(sig)
    }

    /// Parse an artifact name to a typed [`ArtifactId`] against this
    /// backend's registered models and extension registry -- the
    /// authoritative model/signature split (registered model names
    /// decide where the model ends, unlike the vocabulary-only
    /// [`ArtifactId::from_str`](std::str::FromStr)). On failure the
    /// error names the nearest registered model or extension.
    pub fn parse_artifact(&self, artifact: &str) -> Result<ArtifactId> {
        let Some((stem, batch)) = ArtifactId::split_batch(artifact)
        else {
            bail!(
                "artifact name {artifact:?} does not end in _n<batch>"
            )
        };
        ensure!(batch > 0, "artifact {artifact:?}: batch must be > 0");
        // A registered model name may be a '_'-delimited prefix of
        // another registered name ("tiny" / "tiny_mlp"), so a failed
        // signature parse falls through to the next candidate; the
        // error is only surfaced when no model matches.
        let mut sig_err = None;
        for name in self.models.keys() {
            let Some(rest) = stem
                .strip_prefix(name.as_str())
                .and_then(|r| r.strip_prefix('_'))
            else {
                continue;
            };
            match self.parse_signature(rest) {
                Ok(sig) => {
                    return ArtifactId::new(name.as_str(), sig, batch)
                }
                Err(e) => sig_err = Some(e),
            }
        }
        if let Some(e) = sig_err {
            return Err(e);
        }
        // No registered model prefixes the stem. Isolate the most
        // plausible model head -- the leftmost '_'-split whose tail
        // is a valid signature -- and suggest nearest models.
        let mut head = stem;
        for (i, b) in stem.bytes().enumerate() {
            if b == b'_'
                && i > 0
                && i + 1 < stem.len()
                && self.parse_signature(&stem[i + 1..]).is_ok()
            {
                head = &stem[..i];
                break;
            }
        }
        bail!(
            "native backend has no model serving artifact {artifact:?} \
             (native models: {:?}){}",
            self.model_names(),
            did_you_mean(&suggest(head, self.model_names()))
        )
    }

    /// Resolve a typed id to (model, request): registry lookup plus
    /// the per-model constraints a bare parse cannot check.
    fn resolve_id(&self, id: &ArtifactId) -> Result<(&Model, Request)> {
        let Some(model) = self.models.get(&id.model) else {
            bail!(
                "model {:?} is not in the native registry {:?}{}",
                id.model,
                self.model_names(),
                did_you_mean(&suggest(&id.model, self.model_names()))
            )
        };
        ensure!(id.batch > 0, "artifact {id}: batch must be > 0");
        if id.sig.is_eval() {
            return Ok((model, Request::Eval { batch: id.batch }));
        }
        let mut extensions = Vec::new();
        for part in id.sig.extensions() {
            let Some(ext) = self.extensions.get(part) else {
                bail!(
                    "extension {part:?} is not supported by the \
                     native backend (registered: {:?}){}",
                    self.extensions.names(),
                    did_you_mean(&suggest(
                        part,
                        self.extensions.names()
                    ))
                )
            };
            // Paper footnote 5: KFRA's averaged recursion is only
            // defined for fully-connected networks; any registered
            // extension can claim the same guard.
            ensure!(
                !ext.fully_connected_only()
                    || model.is_fully_connected(),
                "{part} is restricted to fully-connected models \
                 (paper footnote 5); {} has conv/pool layers",
                id.model
            );
            extensions.push(part.clone());
        }
        Ok((model, Request::Train { extensions, batch: id.batch }))
    }

    /// Resolve an artifact name to (model, parsed request). Thin
    /// string-keyed wrapper over [`NativeBackend::parse_artifact`] +
    /// the typed resolution.
    fn resolve(&self, artifact: &str) -> Result<(&Model, Request)> {
        self.resolve_id(&self.parse_artifact(artifact)?)
    }

    fn synthesize_id(
        &self,
        id: &ArtifactId,
    ) -> Result<(ArtifactSpec, Model)> {
        let (model, req) = self.resolve_id(id)?;
        let artifact = id.to_string();
        let spec = match &req {
            Request::Eval { batch } => {
                eval_spec(model, &artifact, *batch)
            }
            Request::Train { extensions, batch } => train_spec(
                model, &artifact, extensions, *batch, &self.extensions,
            ),
        };
        Ok((spec, model.clone()))
    }

    fn synthesize(&self, artifact: &str) -> Result<(ArtifactSpec, Model)> {
        self.synthesize_id(&self.parse_artifact(artifact)?)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn spec(&self, artifact: &str) -> Result<ArtifactSpec> {
        Ok(self.synthesize(artifact)?.0)
    }

    fn load(&self, artifact: &str) -> Result<Rc<dyn Exec>> {
        self.load_id(&self.parse_artifact(artifact)?)
    }

    fn spec_id(&self, id: &ArtifactId) -> Result<ArtifactSpec> {
        Ok(self.synthesize_id(id)?.0)
    }

    fn load_id(&self, id: &ArtifactId) -> Result<Rc<dyn Exec>> {
        let (spec, model) = self.synthesize_id(id)?;
        Ok(Rc::new(NativeExec {
            spec,
            model,
            extensions: self.extensions.clone(),
            threads: self.threads,
        }))
    }

    fn find_train(
        &self,
        model: &str,
        side: usize,
        ext_sig: &str,
        batch: usize,
    ) -> Result<String> {
        // Side-parameterized models are registered as "{model}{side}"
        // (e.g. allcnnc at side 16 -> "allcnnc16"); fixed-size models
        // use side 0.
        let key = if side > 0 {
            format!("{model}{side}")
        } else {
            model.to_string()
        };
        ensure!(
            self.models.contains_key(&key),
            "model {key:?} is not in the native registry {:?}{}",
            self.model_names(),
            did_you_mean(&suggest(&key, self.model_names()))
        );
        let sig = self.parse_signature(ext_sig)?;
        ensure!(
            !sig.is_eval(),
            "find_train resolves training graphs; load \
             {key}_eval_n{batch} directly for evaluation"
        );
        let id = ArtifactId::new(key, sig, batch)?;
        self.resolve_id(&id)?; // per-model constraints (footnote 5)
        Ok(id.to_string())
    }

    fn artifact_names(&self) -> Vec<String> {
        let mut names = Vec::new();
        for (m, model) in &self.models {
            names.push(format!("{m}_eval_n256"));
            for sig in LISTED_SIGS {
                // Paper footnote 5: fully-connected-only extensions
                // (kfra) are never advertised for conv models.
                let fc_only = sig.split('+').any(|part| {
                    self.extensions
                        .get(part)
                        .is_some_and(|e| e.fully_connected_only())
                });
                if fc_only && !model.is_fully_connected() {
                    continue;
                }
                names.push(format!("{m}_{sig}_n64"));
            }
        }
        names
    }
}

enum Request {
    Eval { batch: usize },
    Train { extensions: Vec<String>, batch: usize },
}

/// Data/key inputs appended after the parameter specs. `x` uses the
/// layout the data pipeline ships: flat `[batch, d]` for vector
/// models, `[batch, c, h, w]` for image models (the engine accepts
/// either; the row-major data is identical).
fn data_inputs(
    model: &Model,
    batch: usize,
    has_key: bool,
) -> Vec<TensorSpec> {
    let mut x_shape = vec![batch];
    x_shape.extend(model.in_shape.dims());
    let mut inputs = vec![
        f32_spec("x".to_string(), x_shape),
        TensorSpec {
            name: "y".to_string(),
            shape: vec![batch],
            dtype: "i32".to_string(),
            init: None,
        },
    ];
    if has_key {
        inputs.push(TensorSpec {
            name: "key".to_string(),
            shape: vec![2],
            dtype: "u32".to_string(),
            init: None,
        });
    }
    inputs
}

fn train_spec(
    model: &Model,
    artifact: &str,
    extensions: &[String],
    batch: usize,
    set: &ExtensionSet,
) -> ArtifactSpec {
    let has_key = extensions
        .iter()
        .any(|e| set.get(e).is_some_and(|x| x.needs_key()));
    let mut inputs = model.param_specs();
    inputs.extend(data_inputs(model, batch, has_key));

    let mut outputs = vec![f32_spec("loss".to_string(), vec![])];
    for blk in model.param_blocks() {
        let wsh = &blk.w_shape; // [out, in] or [out_ch, in_ch, k, k]
        outputs
            .push(f32_spec(format!("grad/{}/w", blk.li), wsh.clone()));
        outputs
            .push(f32_spec(format!("grad/{}/b", blk.li), vec![blk.dout]));
    }
    // Every extension declares its own output shapes — the engine
    // never needs per-quantity knowledge here.
    for ext in extensions {
        let e = set.get(ext).expect("validated by resolve_id");
        outputs.extend(e.output_specs(model, batch));
    }

    ArtifactSpec {
        name: artifact.to_string(),
        file: format!("native://{artifact}"),
        model: model.name.clone(),
        side: 0,
        batch_size: batch,
        extensions: extensions.to_vec(),
        kind: "train".to_string(),
        has_key,
        num_classes: model.classes,
        in_shape: model.in_shape.dims(),
        inputs,
        outputs,
    }
}

fn eval_spec(model: &Model, artifact: &str, batch: usize)
    -> ArtifactSpec {
    let mut inputs = model.param_specs();
    inputs.extend(data_inputs(model, batch, false));
    ArtifactSpec {
        name: artifact.to_string(),
        file: format!("native://{artifact}"),
        model: model.name.clone(),
        side: 0,
        batch_size: batch,
        extensions: Vec::new(),
        kind: "eval".to_string(),
        has_key: false,
        num_classes: model.classes,
        in_shape: model.in_shape.dims(),
        inputs,
        outputs: vec![
            f32_spec("loss".to_string(), vec![]),
            f32_spec("accuracy".to_string(), vec![]),
        ],
    }
}

/// A synthesized computation bound to its model and extension
/// registry, executing batch-parallel over `threads` scoped workers.
pub struct NativeExec {
    spec: ArtifactSpec,
    model: Model,
    extensions: ExtensionSet,
    threads: usize,
}

/// Minimum multiply-adds a shard must carry before it is worth a
/// scoped-thread spawn (mirrors `linalg::PAR_MIN_MACS` at the batch
/// level).
const MIN_SHARD_MACS: usize = 1 << 18;

impl NativeExec {
    /// Effective worker count for one execution: the configured count,
    /// capped so every shard carries at least [`MIN_SHARD_MACS`] of
    /// work. The per-sample cost estimate is a conservative lower
    /// bound -- params for a forward-only eval graph, 2 x params
    /// (forward + first-order backward) for training graphs, valid
    /// for every extension signature -- so cheap small-batch runs
    /// collapse to serial while expensive signatures keep full
    /// parallelism. `Model::*_threads` itself honors the count
    /// verbatim: this resource policy lives at the backend layer.
    fn effective_threads(&self) -> usize {
        let passes = if self.spec.kind == "eval" { 1 } else { 2 };
        let per_sample = passes * self.model.num_params().max(1);
        let max_shards =
            (self.spec.batch_size * per_sample / MIN_SHARD_MACS).max(1);
        self.threads.min(max_shards)
    }
}

impl Exec for NativeExec {
    fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    fn run(&self, inputs: &[Tensor]) -> Result<Outputs> {
        let _root = crate::obs::span_with(crate::obs::CAT_ENGINE, || {
            format!("run/{}", self.spec.name)
        });
        super::validate_inputs(&self.spec, inputs)?;
        let p = self.spec.param_inputs().len();
        let params = &inputs[..p];
        let (x, y) = (&inputs[p], &inputs[p + 1]);
        let key = if self.spec.has_key {
            let k = inputs[p + 2].u32s()?;
            Some([k[0], k[1]])
        } else {
            None
        };
        let start = Instant::now();
        let threads = self.effective_threads();
        let map = match self.spec.kind.as_str() {
            "eval" => {
                self.model.evaluate_threads(params, x, y, threads)?
            }
            _ => self.model.extended_backward(
                params,
                x,
                y,
                &self.spec.extensions,
                &ExtractOptions {
                    registry: Some(self.extensions.clone()),
                    topology: Topology::local(threads),
                    key,
                    trace_label: None,
                },
            )?,
        };
        Ok(Outputs::new(map, start.elapsed()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::train::{build_inputs, init_params};
    use crate::data::{DatasetSpec, Synthetic};

    fn logreg_batch(n: usize, seed: u64) -> (Tensor, Tensor) {
        let ds = Synthetic::new(
            DatasetSpec::by_name("mnist").unwrap(), seed);
        let idx: Vec<usize> = (0..n).collect();
        let (x, y) = ds.batch(0, &idx);
        (Tensor::from_f32(&[n, 784], x), Tensor::from_i32(&[n], y))
    }

    #[test]
    fn name_parsing() {
        let be = NativeBackend::new();
        let id = be.parse_artifact("logreg_grad_n64").unwrap();
        assert_eq!(id.model, "logreg");
        assert!(id.sig.is_grad());
        assert_eq!(id.batch, 64);
        assert_eq!(id.to_string(), "logreg_grad_n64");
        let id = be
            .parse_artifact("logreg_batch_grad+variance_n8")
            .unwrap();
        assert_eq!(id.sig.extensions(), ["batch_grad", "variance"]);
        let id = be.parse_artifact("3c3d_sigmoid_diag_h_n8").unwrap();
        assert_eq!(id.model, "3c3d_sigmoid");
        assert_eq!(id.sig.extensions(), ["diag_h"]);
        let id = be.parse_artifact("mlp_eval_n256").unwrap();
        assert!(id.sig.is_eval());
        assert!(be.parse_artifact("logreg_grad").is_err());
        assert!(be.parse_artifact("logreg_grad_nX").is_err());
        assert!(be.parse_artifact("logreg_hessian_n8").is_err());
        assert!(be.parse_artifact("logreg_grad+bogus_n8").is_err());
    }

    #[test]
    fn resolve_errors_suggest_nearest_matches() {
        let be = NativeBackend::new();
        // Unknown model, one transposition away from "logreg".
        let err =
            be.spec("logrge_grad_n64").unwrap_err().to_string();
        assert!(
            err.contains("did you mean") && err.contains("logreg"),
            "{err}"
        );
        // Unknown extension, one substitution from "diag_ggn".
        let err =
            be.spec("mlp_diag_gnn_n8").unwrap_err().to_string();
        assert!(
            err.contains("did you mean") && err.contains("diag_ggn"),
            "{err}"
        );
        // find_train surfaces the same suggestions.
        let err = be
            .find_train("logrge", 0, "grad", 8)
            .unwrap_err()
            .to_string();
        assert!(err.contains("\"logreg\""), "{err}");
        let err = be
            .find_train("mlp", 0, "kfca", 8)
            .unwrap_err()
            .to_string();
        assert!(err.contains("did you mean"), "{err}");
        // Hopeless names still error cleanly, without a suggestion.
        let err = be
            .spec("zzzzzz_grad_n8")
            .unwrap_err()
            .to_string();
        assert!(!err.contains("did you mean"), "{err}");
    }

    #[test]
    fn typed_load_matches_string_load() {
        let be = NativeBackend::new();
        let id: ArtifactId = "logreg_diag_ggn_n16".parse().unwrap();
        let a = be.spec_id(&id).unwrap();
        let b = be.spec("logreg_diag_ggn_n16").unwrap();
        assert_eq!(a.name, b.name);
        assert_eq!(a.outputs.len(), b.outputs.len());
        assert!(be.load_id(&id).is_ok());
        // Typed resolution enforces footnote 5 like the string path.
        let conv: ArtifactId = "2c2d_kfra_n8".parse().unwrap();
        let err = be.spec_id(&conv).unwrap_err().to_string();
        assert!(err.contains("footnote 5"), "{err}");
    }

    #[test]
    fn resolves_registry_and_rejects_unknown() {
        let be = NativeBackend::new();
        assert!(be.spec("logreg_grad_n64").is_ok());
        assert!(be.spec("mlp_diag_ggn_n32").is_ok());
        assert!(be.spec("mlp_eval_n256").is_ok());
        // Conv models are first-class citizens of the registry.
        assert!(be.spec("2c2d_grad_n32").is_ok());
        assert!(be.spec("3c3d_kfac_n64").is_ok());
        assert!(be.spec("3c3d_eval_n128").is_ok());
        assert!(be.spec("allcnnc16_diag_ggn_mc_n8").is_ok());
        assert!(be.spec("allcnnc32_grad_n4").is_ok());
        // diag_h is a native quantity on every model, and the Fig. 9
        // model resolves through the "3c3d"-prefix fallthrough.
        assert!(be.spec("logreg_diag_h_n8").is_ok());
        assert!(be.spec("mlp_diag_h_n8").is_ok());
        assert!(be.spec("3c3d_sigmoid_diag_h_n8").is_ok());
        assert!(be.spec("3c3d_sigmoid_grad_n8").is_ok());
        assert!(be.spec("4c4d_grad_n64").is_err());
        assert!(be.spec("logreg_hessian_n8").is_err());
    }

    #[test]
    fn kfra_is_fully_connected_only() {
        // Paper footnote 5: kfra resolves on FC models, never on conv.
        let be = NativeBackend::new();
        assert!(be.spec("mlp_kfra_n16").is_ok());
        for model in ["2c2d", "3c3d", "allcnnc16"] {
            let err = be
                .spec(&format!("{model}_kfra_n16"))
                .unwrap_err()
                .to_string();
            assert!(err.contains("footnote 5"), "{model}: {err}");
            assert!(be
                .find_train(model, 0, "kfra", 16)
                .is_err());
        }
        // Conv models never advertise a kfra artifact.
        assert!(be
            .artifact_names()
            .iter()
            .all(|n| !n.contains("kfra")
                || n.starts_with("logreg") || n.starts_with("mlp")));
    }

    #[test]
    fn find_train_builds_the_manifest_name() {
        let be = NativeBackend::new();
        let name = be.find_train("logreg", 0, "kfac", 16).unwrap();
        assert_eq!(name, "logreg_kfac_n16");
        let spec = be.spec(&name).unwrap();
        assert!(spec.has_key);
        assert_eq!(spec.batch_size, 16);
        // Side-parameterized models resolve to their "{model}{side}"
        // registry key.
        let name = be.find_train("allcnnc", 16, "grad", 8).unwrap();
        assert_eq!(name, "allcnnc16_grad_n8");
        assert!(be.find_train("logreg", 16, "grad", 16).is_err());
        assert!(be.find_train("allcnnc", 0, "grad", 16).is_err());
        assert_eq!(
            be.find_train("3c3d_sigmoid", 0, "diag_h", 8).unwrap(),
            "3c3d_sigmoid_diag_h_n8"
        );
        assert!(be.find_train("logreg", 0, "hessian", 16).is_err());
    }

    #[test]
    fn conv_spec_shapes_follow_the_parameter_layout() {
        let be = NativeBackend::new();
        let spec = be.spec("2c2d_batch_grad+kfac_n8").unwrap();
        assert!(spec.has_key);
        assert_eq!(spec.in_shape, vec![1, 28, 28]);
        let find = |n: &str| {
            spec.outputs
                .iter()
                .find(|t| t.name == n)
                .unwrap_or_else(|| panic!("missing output {n}"))
                .shape
                .clone()
        };
        assert_eq!(find("grad/0/w"), vec![32, 1, 5, 5]);
        assert_eq!(find("batch_grad/0/w"), vec![8, 32, 1, 5, 5]);
        assert_eq!(find("kfac/0/A"), vec![25, 25]);
        assert_eq!(find("kfac/3/A"), vec![32 * 25, 32 * 25]);
        assert_eq!(find("kfac/3/B"), vec![64, 64]);
        assert_eq!(find("grad/7/w"), vec![1024, 3136]);
    }

    #[test]
    fn spec_shapes_are_consistent() {
        let be = NativeBackend::new();
        let spec = be.spec("mlp_diag_ggn_n32").unwrap();
        // 3 linear layers: 6 params + x + y (exact ext: no key).
        assert_eq!(spec.inputs.len(), 8);
        assert!(!spec.has_key);
        // loss + per-layer (grad w/b + diag w/b).
        assert_eq!(spec.outputs.len(), 1 + 3 * 4);
        let spec = be.spec("mlp_kfac_n32").unwrap();
        assert!(spec.has_key);
        assert_eq!(spec.inputs.len(), 9);
        assert_eq!(spec.outputs.len(), 1 + 3 * 5);
    }

    #[test]
    fn exec_runs_and_validates_inputs() {
        let be = NativeBackend::new();
        let exe = be.load("logreg_grad_n16").unwrap();
        let params = init_params(exe.spec(), 0);
        let (x, y) = logreg_batch(16, 0);
        let out =
            exe.run(&build_inputs(&params, x.clone(), y, None)).unwrap();
        let loss = out.loss().unwrap();
        // Random init on 10 classes: loss near ln(10) ≈ 2.30.
        assert!((1.8..3.2).contains(&loss), "loss {loss}");
        let g = out.get("grad/0/w").unwrap();
        assert_eq!(g.shape, vec![10, 784]);
        assert!(g.f32s().unwrap().iter().all(|v| v.is_finite()));

        // Wrong batch size rejected.
        let (x8, y8) = logreg_batch(8, 0);
        assert!(exe
            .run(&build_inputs(&params, x8, y8, None))
            .is_err());
        // Wrong input count rejected.
        let only_params: Vec<Tensor> =
            params.iter().map(|p| p.tensor.clone()).collect();
        assert!(exe.run(&only_params).is_err());
    }

    #[test]
    fn diag_h_serves_natively_and_matches_diag_ggn_on_logreg() {
        // logreg is purely linear: the Hessian IS the GGN, so the two
        // quantities must agree through the full backend path.
        let be = NativeBackend::new();
        let exe = be.load("logreg_diag_h+diag_ggn_n16").unwrap();
        assert!(!exe.spec().has_key);
        let params = init_params(exe.spec(), 3);
        let (x, y) = logreg_batch(16, 3);
        let out =
            exe.run(&build_inputs(&params, x, y, None)).unwrap();
        for part in ["0/w", "0/b"] {
            let h = out
                .get(&format!("diag_h/{part}"))
                .unwrap()
                .f32s()
                .unwrap();
            let g = out
                .get(&format!("diag_ggn/{part}"))
                .unwrap()
                .f32s()
                .unwrap();
            for (u, v) in h.iter().zip(g) {
                assert!(
                    (u - v).abs() <= 1e-6 * (1.0 + u.abs()),
                    "diag_h/{part}: {u} vs diag_ggn {v}"
                );
            }
        }
    }

    #[test]
    fn eval_graph_reports_chance_accuracy_at_init() {
        let be = NativeBackend::new();
        let exe = be.load("logreg_eval_n128").unwrap();
        let params = init_params(exe.spec(), 4);
        let (x, y) = logreg_batch(128, 4);
        let out = exe.run(&build_inputs(&params, x, y, None)).unwrap();
        let acc = out.get("accuracy").unwrap().item_f32().unwrap();
        assert!((0.0..0.35).contains(&acc), "chance-ish, got {acc}");
    }

    #[test]
    fn small_batches_fall_back_to_serial_sharding() {
        let be = NativeBackend::with_threads(16);
        // logreg at batch 8: 8 x 2 x 7,850 MACs < MIN_SHARD_MACS --
        // a thread spawn would cost more than the shard's work.
        let (spec, model) = be.synthesize("logreg_grad_n8").unwrap();
        let exe = NativeExec {
            spec,
            model,
            extensions: ExtensionSet::builtin(),
            threads: 16,
        };
        assert_eq!(exe.effective_threads(), 1);
        // mlp at batch 128 carries ~28M MACs: full parallelism.
        let (spec, model) = be.synthesize("mlp_grad_n128").unwrap();
        let exe = NativeExec {
            spec,
            model,
            extensions: ExtensionSet::builtin(),
            threads: 16,
        };
        assert_eq!(exe.effective_threads(), 16);
    }

    #[test]
    fn mc_key_changes_mc_quantities_only() {
        let be = NativeBackend::new();
        let exe = be.load("logreg_diag_ggn_mc_n64").unwrap();
        let params = init_params(exe.spec(), 2);
        let (x, y) = logreg_batch(64, 2);
        let out1 = exe
            .run(&build_inputs(
                &params, x.clone(), y.clone(), Some([1, 1])))
            .unwrap();
        let out2 = exe
            .run(&build_inputs(&params, x, y, Some([2, 2])))
            .unwrap();
        assert_eq!(
            out1.get("grad/0/w").unwrap(),
            out2.get("grad/0/w").unwrap()
        );
        assert_ne!(
            out1.get("diag_ggn_mc/0/w").unwrap(),
            out2.get("diag_ggn_mc/0/w").unwrap()
        );
    }
}
