//! `Conv2d` forward, VJPs, and the im2col math kernels behind the
//! conv extraction rules (DESIGN.md §6) — called by the engine walks
//! in `backend/model.rs` and by the `Conv2d` arms of the extension
//! modules in `backend/extensions/`. All functions operate on one
//! contiguous batch shard and normalize averaged quantities by the
//! **global** batch size `norm`, so shard outputs sum-reduce exactly
//! like the `Linear` rules.
//!
//! Conventions (weight `W [c_out, J]` with `J = c_in·k·k`, unfolded
//! input `U = ⟦x⟧ [J, P]`, per-sample output gradient `G [c_out, P]`,
//! square-root GGN `S [c_out·P, cols]`):
//!
//! * gradient         `(1/N) Σ_n G_n U_nᵀ`, bias `(1/N) Σ_n G_n 1`;
//! * DiagGGN          `(1/N) Σ_{n,c} (Jᵀ S)²` with
//!                    `(Jᵀ S)[o,j,c] = Σ_p U[j,p] S[(o,p),c]`;
//! * DiagH residual   the same contraction with a per-(sample,
//!                    column) sign weight (`diag_sqrt_signed`): the
//!                    full Hessian's residual factors are indefinite,
//!                    so each squared column carries the sign of the
//!                    `σ''(x) ⊙ g` entry it was born from
//!                    (DESIGN.md §11);
//! * KFAC/KFLR        `A = (1/N) Σ_n U_n U_nᵀ` (positions folded into
//!                    the contraction), `B = (1/(N·P)) Σ_n S_n S_nᵀ`
//!                    (position-averaged), bias GGN from the
//!                    position-summed `S̄ [c_out, cols]` — the Grosse
//!                    & Martens (2016) KFC convention, which reduces
//!                    exactly to the `Linear` factors at `P = 1`.
//!
//! ## Fused unfold (DESIGN.md §14)
//!
//! No phase materializes the full `⟦x⟧ [J, P]` anymore: every driver
//! below streams [`COL_TILE`]-wide *position tiles* through
//! `ConvGeom::im2col_range` into one reusable `[J, COL_TILE]` buffer
//! and feeds each tile straight to the matmul microkernel
//! (`matmul_into` / `matmul_tn_into` / `matmul_nt_acc`). Because the
//! contraction axis is never tiled — only output positions are — the
//! forward and VJP *products* are bit-identical to the materialized
//! path (`COL_TILE` is a multiple of the 8-lane SIMD width, so the
//! vector-body/scalar-tail split also lines up), while accumulating
//! reductions (grad, diag, Kron `A`, the col2im scatter) re-associate
//! the position sum across tiles and agree to f32 round-off
//! (`tests/conv_native.rs` pins both). Shard-local unfold memory
//! drops from one `[J, P]` matrix per sample to one `[J, COL_TILE]`
//! tile per driver call, which is also what the `Im2colBytes` counter
//! now reports (bytes charged at tile-buffer allocation; the
//! materialized `ConvGeom::im2col` reference still charges its full
//! buffer).

use crate::linalg::{
    matmul_into, matmul_nt, matmul_nt_acc, matmul_tn_into,
};

use super::im2col::ConvGeom;

/// Positions per streamed column tile. A multiple of the 8-lane SIMD
/// width (so per-column vector/tail classification matches the
/// full-width kernels, keeping forward/VJP bitwise) and small enough
/// that the `[J, COL_TILE]` tile plus the weight panel stay
/// cache-resident at the registry shapes (J ≤ 1728 ⇒ ≤ 864 KiB).
pub const COL_TILE: usize = 128;

/// Allocate the reusable `[j, tile]` unfold buffer for one driver
/// call and charge its bytes to the `Im2colBytes` counter — the
/// fused path's entire unfold footprint, reused across tiles and
/// samples.
fn alloc_tile(j: usize, tile: usize) -> Vec<f32> {
    crate::obs::add(
        crate::obs::Counter::Im2colBytes,
        (j * tile * std::mem::size_of::<f32>()) as u64,
    );
    vec![0.0f32; j * tile]
}

/// Forward over a shard: `z = W ⟦x⟧ + b 1ᵀ` per sample, streaming
/// position tiles (bit-identical to the materialized product).
pub fn forward(
    geom: &ConvGeom,
    w: &[f32],
    b: &[f32],
    inp: &[f32],
    ns: usize,
) -> Vec<f32> {
    let (fin, fout) = (geom.in_shape.flat(), geom.out_shape.flat());
    let (j, p) = (geom.patch_len(), geom.positions());
    let c_out = geom.out_shape.c;
    let tile = COL_TILE.min(p);
    let mut u = alloc_tile(j, tile);
    let mut zt = vec![0.0f32; c_out * tile];
    let mut z = vec![0.0f32; ns * fout];
    for smp in 0..ns {
        let xs = &inp[smp * fin..(smp + 1) * fin];
        let dst = &mut z[smp * fout..(smp + 1) * fout];
        for q0 in (0..p).step_by(tile) {
            let q1 = (q0 + tile).min(p);
            let tw = q1 - q0;
            geom.im2col_range(xs, q0, q1, &mut u[..j * tw]);
            matmul_into(w, &u[..j * tw], c_out, j, tw, &mut zt[..c_out * tw]);
            for o in 0..c_out {
                dst[o * p + q0..o * p + q1]
                    .copy_from_slice(&zt[o * tw..(o + 1) * tw]);
            }
        }
        for o in 0..c_out {
            for q in 0..p {
                dst[o * p + q] += b[o];
            }
        }
    }
    z
}

/// First-order VJP w.r.t. the input: `G ↦ col2im(Wᵀ G)` per sample.
pub fn vjp_input(
    geom: &ConvGeom,
    w: &[f32],
    g: &[f32],
    ns: usize,
) -> Vec<f32> {
    mat_vjp_input(geom, w, g, ns, 1)
}

/// Square-root-GGN VJP: `S [ns, c_out·P, cols] -> [ns, c_in·h·w,
/// cols]` — `Wᵀ S` one position tile at a time (positions and columns
/// share the minor axis), each tile scattered through the range
/// col2im before the next is computed, so the full `[J, P·cols]`
/// cotangent is never held.
pub fn mat_vjp_input(
    geom: &ConvGeom,
    w: &[f32],
    s: &[f32],
    ns: usize,
    cols: usize,
) -> Vec<f32> {
    let (fin, fout) = (geom.in_shape.flat(), geom.out_shape.flat());
    let (j, p) = (geom.patch_len(), geom.positions());
    let c_out = geom.out_shape.c;
    debug_assert_eq!(s.len(), ns * fout * cols);
    let tile = COL_TILE.min(p);
    // S tile gather [c_out, tw·cols] + cotangent tile [J, tw·cols].
    let mut sb = vec![0.0f32; c_out * tile * cols];
    let mut t = vec![0.0f32; j * tile * cols];
    let mut out = vec![0.0f32; ns * fin * cols];
    for smp in 0..ns {
        let blk = &s[smp * fout * cols..(smp + 1) * fout * cols];
        let dst = &mut out[smp * fin * cols..(smp + 1) * fin * cols];
        for q0 in (0..p).step_by(tile) {
            let q1 = (q0 + tile).min(p);
            let tw = q1 - q0;
            for o in 0..c_out {
                sb[o * tw * cols..(o + 1) * tw * cols].copy_from_slice(
                    &blk[o * p * cols + q0 * cols
                        ..o * p * cols + q1 * cols],
                );
            }
            // [c_out, tw·cols] -> [J, tw·cols]
            matmul_tn_into(
                w,
                &sb[..c_out * tw * cols],
                c_out,
                j,
                tw * cols,
                &mut t[..j * tw * cols],
            );
            geom.col2im_range_acc(&t[..j * tw * cols], cols, q0, q1, dst);
        }
    }
    out
}

/// Norm-averaged gradient of one conv layer over a shard, streaming:
/// per sample and position tile, one `G_tile U_tileᵀ` product
/// accumulated straight into the shared `[c_out, J]` gradient
/// (`matmul_nt_acc`) — neither the per-sample gradients nor the full
/// unfold are materialized. This is the plain-`grad` path; when
/// first-order extensions are active the engine shares one
/// materialized [`per_sample_grads`] instead.
pub fn grad(
    geom: &ConvGeom,
    inp: &[f32],
    g: &[f32],
    ns: usize,
    norm: f32,
) -> (Vec<f32>, Vec<f32>) {
    let (fin, fout) = (geom.in_shape.flat(), geom.out_shape.flat());
    let (j, p) = (geom.patch_len(), geom.positions());
    let c_out = geom.out_shape.c;
    let tile = COL_TILE.min(p);
    let mut u = alloc_tile(j, tile);
    let mut gt = vec![0.0f32; c_out * tile];
    let mut gw = vec![0.0f32; c_out * j];
    let mut gb = vec![0.0f32; c_out];
    for smp in 0..ns {
        let xs = &inp[smp * fin..(smp + 1) * fin];
        let gs = &g[smp * fout..(smp + 1) * fout];
        for q0 in (0..p).step_by(tile) {
            let q1 = (q0 + tile).min(p);
            let tw = q1 - q0;
            geom.im2col_range(xs, q0, q1, &mut u[..j * tw]);
            for o in 0..c_out {
                gt[o * tw..(o + 1) * tw]
                    .copy_from_slice(&gs[o * p + q0..o * p + q1]);
            }
            // gw += G_tile U_tileᵀ [c_out, J]
            matmul_nt_acc(
                &gt[..c_out * tw],
                &u[..j * tw],
                c_out,
                tw,
                j,
                &mut gw,
            );
        }
        // Per-sample bias gradient: position sums of G_n.
        for o in 0..c_out {
            gb[o] += gs[o * p..(o + 1) * p].iter().sum::<f32>();
        }
    }
    for v in gw.iter_mut().chain(gb.iter_mut()) {
        *v /= norm;
    }
    (gw, gb)
}

/// Unnormalized per-sample parameter gradients over a shard, in
/// sample order: `(w [ns, c_out, J], b [ns, c_out])` with
/// `w_n = G_n U_nᵀ` and `b_n` the position sums of `G_n`. The shared
/// intermediate of the first-order extension rules — unlike `Linear`,
/// the conv per-sample gradient is not rank-1 (spatial positions sum
/// into it), so `batch_l2`/`sq_moment` consume this materialized
/// product instead of a factored shortcut. Position tiles stream into
/// each sample's block; only the output itself is materialized.
pub fn per_sample_grads(
    geom: &ConvGeom,
    inp: &[f32],
    g: &[f32],
    ns: usize,
) -> (Vec<f32>, Vec<f32>) {
    let (fin, fout) = (geom.in_shape.flat(), geom.out_shape.flat());
    let (j, p) = (geom.patch_len(), geom.positions());
    let c_out = geom.out_shape.c;
    let tile = COL_TILE.min(p);
    let mut u = alloc_tile(j, tile);
    let mut gt = vec![0.0f32; c_out * tile];
    let mut w = vec![0.0f32; ns * c_out * j];
    let mut b = Vec::with_capacity(ns * c_out);
    for smp in 0..ns {
        let xs = &inp[smp * fin..(smp + 1) * fin];
        let gs = &g[smp * fout..(smp + 1) * fout];
        let ws = &mut w[smp * c_out * j..(smp + 1) * c_out * j];
        for q0 in (0..p).step_by(tile) {
            let q1 = (q0 + tile).min(p);
            let tw = q1 - q0;
            geom.im2col_range(xs, q0, q1, &mut u[..j * tw]);
            for o in 0..c_out {
                gt[o * tw..(o + 1) * tw]
                    .copy_from_slice(&gs[o * p + q0..o * p + q1]);
            }
            matmul_nt_acc(
                &gt[..c_out * tw],
                &u[..j * tw],
                c_out,
                tw,
                j,
                ws,
            );
        }
        for o in 0..c_out {
            b.push(gs[o * p..(o + 1) * p].iter().sum::<f32>());
        }
    }
    (w, b)
}

/// DiagGGN extraction (Eq. 19 through the unfolded view): per sample,
/// transpose `S` to `[(o,c), P]`, contract against `U [J, P]`, square
/// and accumulate. Returns `(diag_w [c_out·J], diag_b [c_out])`,
/// norm-averaged.
pub fn diag_sqrt(
    geom: &ConvGeom,
    inp: &[f32],
    s: &[f32],
    ns: usize,
    cols: usize,
    norm: f32,
) -> (Vec<f32>, Vec<f32>) {
    diag_sqrt_signed(geom, inp, s, ns, cols, norm, None)
}

/// [`diag_sqrt`] with an optional per-(sample, column) sign weight
/// `signs [ns · cols]` — the conv extraction rule of `diag_h`'s
/// residual factors (DESIGN.md §11). Each squared column contributes
/// `signs[smp·cols + c] · (Jᵀ S)²`; `None` weights every column `+1`
/// (the PSD square-root-GGN case). The signed sum can be negative:
/// the full Hessian is indefinite.
///
/// The position contraction `V[(o,c), j] = Σ_p S[(o,p),c] U[j,p]`
/// accumulates tile by tile into one `[c_out·cols, J]` buffer; the
/// squaring happens only once `V` is complete (squares do not
/// distribute over the tile sum).
pub fn diag_sqrt_signed(
    geom: &ConvGeom,
    inp: &[f32],
    s: &[f32],
    ns: usize,
    cols: usize,
    norm: f32,
    signs: Option<&[f32]>,
) -> (Vec<f32>, Vec<f32>) {
    let (fin, fout) = (geom.in_shape.flat(), geom.out_shape.flat());
    let (j, p) = (geom.patch_len(), geom.positions());
    let c_out = geom.out_shape.c;
    debug_assert_eq!(s.len(), ns * fout * cols);
    if let Some(sg) = signs {
        debug_assert_eq!(sg.len(), ns * cols);
    }
    let tile = COL_TILE.min(p);
    let mut u = alloc_tile(j, tile);
    let mut st = vec![0.0f32; c_out * cols * tile];
    let mut v = vec![0.0f32; c_out * cols * j];
    let mut dw = vec![0.0f32; c_out * j];
    let mut db = vec![0.0f32; c_out];
    for smp in 0..ns {
        let xs = &inp[smp * fin..(smp + 1) * fin];
        let blk = &s[smp * fout * cols..(smp + 1) * fout * cols];
        v.fill(0.0);
        for q0 in (0..p).step_by(tile) {
            let q1 = (q0 + tile).min(p);
            let tw = q1 - q0;
            geom.im2col_range(xs, q0, q1, &mut u[..j * tw]);
            // S [(o,p), c] -> St tile [(o,c), tw]
            for o in 0..c_out {
                for q in q0..q1 {
                    let src = (o * p + q) * cols;
                    for cc in 0..cols {
                        st[(o * cols + cc) * tw + (q - q0)] =
                            blk[src + cc];
                    }
                }
            }
            // V[(o,c), j] += Σ_{p ∈ tile} S[(o,p),c] U[j,p]
            matmul_nt_acc(
                &st[..c_out * cols * tw],
                &u[..j * tw],
                c_out * cols,
                tw,
                j,
                &mut v,
            );
        }
        for o in 0..c_out {
            for cc in 0..cols {
                let w = signs
                    .map_or(1.0, |sg| sg[smp * cols + cc]);
                let row = &v[(o * cols + cc) * j..(o * cols + cc + 1) * j];
                let dst = &mut dw[o * j..(o + 1) * j];
                for (acc, x) in dst.iter_mut().zip(row) {
                    *acc += w * x * x;
                }
                // Bias Jacobian sums S over positions.
                let sbar: f32 = (0..p)
                    .map(|q| blk[(o * p + q) * cols + cc])
                    .sum();
                db[o] += w * sbar * sbar;
            }
        }
    }
    for v in dw.iter_mut().chain(db.iter_mut()) {
        *v /= norm;
    }
    (dw, db)
}

/// KFAC/KFLR Kronecker factors of one conv layer over a shard:
/// `(A [J,J], B [c_out,c_out], bias_ggn [c_out,c_out])`, normalized so
/// shard outputs sum-reduce. `A` streams position tiles
/// (`A += U_tile U_tileᵀ` per tile); `B` and the bias GGN contract the
/// `S` block directly and never touch the unfold.
pub fn kron_factors(
    geom: &ConvGeom,
    inp: &[f32],
    s: &[f32],
    ns: usize,
    cols: usize,
    norm: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (fin, fout) = (geom.in_shape.flat(), geom.out_shape.flat());
    let (j, p) = (geom.patch_len(), geom.positions());
    let c_out = geom.out_shape.c;
    debug_assert_eq!(s.len(), ns * fout * cols);
    let tile = COL_TILE.min(p);
    let mut u = alloc_tile(j, tile);
    let mut a = vec![0.0f32; j * j];
    let mut bf = vec![0.0f32; c_out * c_out];
    let mut bias = vec![0.0f32; c_out * c_out];
    let mut srow = vec![0.0f32; c_out * cols];
    for smp in 0..ns {
        let xs = &inp[smp * fin..(smp + 1) * fin];
        // A += U Uᵀ (spatial positions folded into the contraction,
        // accumulated tile by tile).
        for q0 in (0..p).step_by(tile) {
            let q1 = (q0 + tile).min(p);
            let tw = q1 - q0;
            geom.im2col_range(xs, q0, q1, &mut u[..j * tw]);
            matmul_nt_acc(&u[..j * tw], &u[..j * tw], j, tw, j, &mut a);
        }
        // B += S Sᵀ, contracting positions AND columns (rows of the
        // sample block are [P·cols] long).
        let blk = &s[smp * fout * cols..(smp + 1) * fout * cols];
        let ss = matmul_nt(blk, blk, c_out, p * cols, c_out);
        for (acc, v) in bf.iter_mut().zip(&ss) {
            *acc += v;
        }
        // bias GGN from the position-summed S̄ [c_out, cols].
        for o in 0..c_out {
            for cc in 0..cols {
                srow[o * cols + cc] = (0..p)
                    .map(|q| blk[(o * p + q) * cols + cc])
                    .sum();
            }
        }
        let bb = matmul_nt(&srow, &srow, c_out, cols, c_out);
        for (acc, v) in bias.iter_mut().zip(&bb) {
            *acc += v;
        }
    }
    for v in a.iter_mut() {
        *v /= norm;
    }
    // Position-averaged B (KFC): reduces to the Linear 1/N Σ S Sᵀ at
    // P = 1.
    let pf = norm * p as f32;
    for v in bf.iter_mut() {
        *v /= pf;
    }
    for v in bias.iter_mut() {
        *v /= norm;
    }
    (a, bf, bias)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::conv::Shape;
    use crate::data::Rng;

    /// 1x1 conv on a 1x1 image is exactly a Linear layer: every
    /// extraction rule must reduce to the FC formulas.
    #[test]
    fn one_by_one_conv_reduces_to_linear() {
        let geom =
            ConvGeom::new(Shape::new(4, 1, 1), 3, 1, 1, 0).unwrap();
        assert_eq!(geom.patch_len(), 4);
        assert_eq!(geom.positions(), 1);
        let mut rng = Rng::new(5);
        let w: Vec<f32> = (0..12).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..3).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..8).map(|_| rng.normal()).collect(); // 2 samples
        let z = forward(&geom, &w, &b, &x, 2);
        for s in 0..2 {
            for o in 0..3 {
                let want: f32 = (0..4)
                    .map(|i| w[o * 4 + i] * x[s * 4 + i])
                    .sum::<f32>()
                    + b[o];
                assert!((z[s * 3 + o] - want).abs() < 1e-5);
            }
        }
        // Gradient = (1/N) Σ g_n x_nᵀ, the Linear rule.
        let g: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
        let (gw, _gb) = grad(&geom, &x, &g, 2, 2.0);
        for o in 0..3 {
            for i in 0..4 {
                let want: f32 = (0..2)
                    .map(|s| g[s * 3 + o] * x[s * 4 + i])
                    .sum::<f32>()
                    / 2.0;
                assert!((gw[o * 4 + i] - want).abs() < 1e-5);
            }
        }
        // Per-sample gradients at P = 1 are the rank-1 outer
        // products, unnormalized; the bias rows are g itself.
        let (psw, psb) = per_sample_grads(&geom, &x, &g, 2);
        for s in 0..2 {
            for o in 0..3 {
                for i in 0..4 {
                    let want = g[s * 3 + o] * x[s * 4 + i];
                    let got = psw[(s * 3 + o) * 4 + i];
                    assert!((got - want).abs() < 1e-6);
                }
            }
        }
        assert_eq!(psb, g);
        // Kron factors: A = (1/N) Σ x xᵀ, B = (1/N) Σ s sᵀ (P = 1).
        let s: Vec<f32> = (0..2 * 3 * 2).map(|_| rng.normal()).collect();
        let (a, bf, bias) = kron_factors(&geom, &x, &s, 2, 2, 2.0);
        for i in 0..4 {
            for k in 0..4 {
                let want: f32 = (0..2)
                    .map(|smp| x[smp * 4 + i] * x[smp * 4 + k])
                    .sum::<f32>()
                    / 2.0;
                assert!((a[i * 4 + k] - want).abs() < 1e-5);
            }
        }
        // At P = 1 the position-summed S̄ equals S: B == bias_ggn.
        for (u, v) in bf.iter().zip(&bias) {
            assert!((u - v).abs() < 1e-6);
        }
    }

    #[test]
    fn vjp_input_matches_finite_differences_of_forward() {
        let geom =
            ConvGeom::new(Shape::new(2, 4, 4), 3, 3, 1, 1).unwrap();
        let mut rng = Rng::new(9);
        let w: Vec<f32> =
            (0..3 * geom.patch_len()).map(|_| rng.normal()).collect();
        let b = vec![0.0f32; 3];
        let x: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
        let g: Vec<f32> = (0..geom.out_shape.flat())
            .map(|_| rng.normal())
            .collect();
        let dx = vjp_input(&geom, &w, &g, 1);
        let eps = 1e-2f32;
        let dot = |z: &[f32]| -> f32 {
            z.iter().zip(&g).map(|(a, b)| a * b).sum()
        };
        for idx in [0usize, 5, 13, 31] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let fd = (dot(&forward(&geom, &w, &b, &xp, 1))
                - dot(&forward(&geom, &w, &b, &xm, 1)))
                / (2.0 * eps);
            assert!(
                (dx[idx] - fd).abs() < 1e-3 * (1.0 + fd.abs()),
                "dx[{idx}] {} vs fd {fd}",
                dx[idx]
            );
        }
    }
}
