//! im2col / col2im: the patch-extraction lowering every conv
//! extraction rule in this subsystem is built on (DESIGN.md §6).
//!
//! `im2col` unfolds one sample `x [c_in, h, w]` into
//! `⟦x⟧ [J, P]` with `J = c_in·k·k` patch rows and `P = out_h·out_w`
//! position columns; out-of-bounds (padding) taps stay zero.
//! `col2im_acc` is its exact adjoint, scattering a `[J, P]`-shaped
//! cotangent back onto the input grid — the pair satisfies
//! `⟨im2col(x), T⟩ = ⟨x, col2im(T)⟩`, which is what makes the
//! conv backward pass a matmul + scatter.
//!
//! Both operations also come in *position-range* form
//! ([`ConvGeom::im2col_range`], [`ConvGeom::col2im_range_acc`])
//! covering columns `[q0, q1)` only: the fused conv path
//! (`conv2d`, DESIGN.md §14) streams fixed-width column tiles through
//! these into the matmul microkernels instead of materializing the
//! full `[J, P]` unfold. The full-width functions delegate to the
//! range forms with `[0, P)`, so there is exactly one indexing
//! implementation to get right. Only `im2col` (the full materialized
//! unfold) charges the `Im2colBytes` counter; tile-streaming callers
//! charge their (much smaller) reusable buffer at allocation.

use anyhow::{ensure, Result};

use super::Shape;

/// Geometry of one `Conv2d` application: square `kernel`, symmetric
/// zero `pad`, uniform `stride`. Output dims use the floor rule
/// `out = (in + 2·pad − k)/stride + 1`, validated at construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvGeom {
    pub in_shape: Shape,
    pub out_shape: Shape,
    pub kernel: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvGeom {
    pub fn new(
        in_shape: Shape,
        out_ch: usize,
        kernel: usize,
        stride: usize,
        pad: usize,
    ) -> Result<ConvGeom> {
        ensure!(
            kernel >= 1 && stride >= 1 && out_ch >= 1,
            "Conv2d: kernel/stride/out_ch must be >= 1"
        );
        ensure!(
            pad < kernel,
            "Conv2d: pad {pad} >= kernel {kernel} creates all-zero \
             patches"
        );
        ensure!(
            in_shape.h + 2 * pad >= kernel
                && in_shape.w + 2 * pad >= kernel,
            "Conv2d: kernel {kernel} exceeds padded input {}x{} (+{pad})",
            in_shape.h,
            in_shape.w
        );
        let oh = (in_shape.h + 2 * pad - kernel) / stride + 1;
        let ow = (in_shape.w + 2 * pad - kernel) / stride + 1;
        Ok(ConvGeom {
            in_shape,
            out_shape: Shape::new(out_ch, oh, ow),
            kernel,
            stride,
            pad,
        })
    }

    /// Patch length `J = c_in·k·k` — the A-factor / weight-column dim.
    pub fn patch_len(&self) -> usize {
        self.in_shape.c * self.kernel * self.kernel
    }

    /// Spatial output positions `P = out_h·out_w`.
    pub fn positions(&self) -> usize {
        self.out_shape.h * self.out_shape.w
    }

    /// Weight tensor shape `[out_ch, in_ch, k, k]` (row-major flat
    /// equals the `[out_ch, J]` matrix the lowering multiplies by).
    pub fn w_shape(&self) -> Vec<usize> {
        vec![
            self.out_shape.c,
            self.in_shape.c,
            self.kernel,
            self.kernel,
        ]
    }

    /// Unfold one sample `x [c_in·h·w]` into `⟦x⟧ [J, P]` — the fully
    /// materialized reference unfold (charges `Im2colBytes` for the
    /// whole buffer).
    pub fn im2col(&self, x: &[f32]) -> Vec<f32> {
        let p = self.positions();
        crate::obs::add(
            crate::obs::Counter::Im2colBytes,
            (self.patch_len() * p * std::mem::size_of::<f32>()) as u64,
        );
        let mut u = vec![0.0f32; self.patch_len() * p];
        self.im2col_range(x, 0, p, &mut u);
        u
    }

    /// Unfold the position columns `[q0, q1)` of `⟦x⟧` into
    /// `u [J, q1-q0]` (overwritten, padding taps zeroed). Tiling the
    /// position axis leaves each column untouched, so the values are
    /// identical to the corresponding columns of the full unfold.
    pub fn im2col_range(
        &self,
        x: &[f32],
        q0: usize,
        q1: usize,
        u: &mut [f32],
    ) {
        let Shape { c, h, w } = self.in_shape;
        debug_assert_eq!(x.len(), self.in_shape.flat());
        debug_assert!(q0 <= q1 && q1 <= self.positions());
        let ow = self.out_shape.w;
        let tw = q1 - q0;
        let k = self.kernel;
        debug_assert_eq!(u.len(), self.patch_len() * tw);
        u.fill(0.0);
        if tw == 0 {
            return;
        }
        let (oy0, oy1) = (q0 / ow, (q1 - 1) / ow);
        for ci in 0..c {
            for ki in 0..k {
                for kj in 0..k {
                    let j = (ci * k + ki) * k + kj;
                    let row = &mut u[j * tw..(j + 1) * tw];
                    for oy in oy0..=oy1 {
                        let Some(iy) = (oy * self.stride + ki)
                            .checked_sub(self.pad)
                            .filter(|&iy| iy < h)
                        else {
                            continue;
                        };
                        let src = (ci * h + iy) * w;
                        // Clip the first/last output row to the tile.
                        let x0 = if oy == oy0 { q0 - oy0 * ow } else { 0 };
                        let x1 = if oy == oy1 { q1 - oy1 * ow } else { ow };
                        for ox in x0..x1 {
                            let Some(ix) = (ox * self.stride + kj)
                                .checked_sub(self.pad)
                                .filter(|&ix| ix < w)
                            else {
                                continue;
                            };
                            row[oy * ow + ox - q0] = x[src + ix];
                        }
                    }
                }
            }
        }
    }

    /// Adjoint scatter: accumulate `t [J, P·cols]` (a `[J, P]`
    /// cotangent carrying `cols` trailing channels per position, as
    /// the square-root-GGN propagation produces) onto
    /// `out [c_in·h·w · cols]`. `cols = 1` is the plain first-order
    /// col2im.
    pub fn col2im_acc(&self, t: &[f32], cols: usize, out: &mut [f32]) {
        self.col2im_range_acc(t, cols, 0, self.positions(), out);
    }

    /// Adjoint scatter of the position columns `[q0, q1)` only:
    /// `t [J, (q1-q0)·cols]` is a tile of the full cotangent, and its
    /// contributions accumulate onto the (full-sized) `out`. Scattering
    /// a partition of `[0, P)` tile by tile computes the same sum as
    /// the full scatter, re-associated per input pixel (positions from
    /// different tiles land in tile order instead of interleaved), so
    /// the fused path agrees with the materialized one to f32
    /// round-off — and exactly when a single tile covers all of `P`.
    pub fn col2im_range_acc(
        &self,
        t: &[f32],
        cols: usize,
        q0: usize,
        q1: usize,
        out: &mut [f32],
    ) {
        let Shape { c, h, w } = self.in_shape;
        debug_assert!(q0 <= q1 && q1 <= self.positions());
        let ow = self.out_shape.w;
        let tw = q1 - q0;
        let k = self.kernel;
        debug_assert_eq!(t.len(), self.patch_len() * tw * cols);
        debug_assert_eq!(out.len(), self.in_shape.flat() * cols);
        if tw == 0 {
            return;
        }
        let (oy0, oy1) = (q0 / ow, (q1 - 1) / ow);
        for ci in 0..c {
            for ki in 0..k {
                for kj in 0..k {
                    let j = (ci * k + ki) * k + kj;
                    let row = &t[j * tw * cols..(j + 1) * tw * cols];
                    for oy in oy0..=oy1 {
                        let Some(iy) = (oy * self.stride + ki)
                            .checked_sub(self.pad)
                            .filter(|&iy| iy < h)
                        else {
                            continue;
                        };
                        let x0 = if oy == oy0 { q0 - oy0 * ow } else { 0 };
                        let x1 = if oy == oy1 { q1 - oy1 * ow } else { ow };
                        for ox in x0..x1 {
                            let Some(ix) = (ox * self.stride + kj)
                                .checked_sub(self.pad)
                                .filter(|&ix| ix < w)
                            else {
                                continue;
                            };
                            let dst = ((ci * h + iy) * w + ix) * cols;
                            let src = (oy * ow + ox - q0) * cols;
                            for cc in 0..cols {
                                out[dst + cc] += row[src + cc];
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    #[test]
    fn output_shape_rules() {
        // 3c3d chain on 32x32 (DESIGN.md §6 trace).
        let g = ConvGeom::new(Shape::new(3, 32, 32), 64, 5, 1, 0)
            .unwrap();
        assert_eq!(g.out_shape, Shape::new(64, 28, 28));
        assert_eq!(g.patch_len(), 75);
        // 'same' 1x1 and stride-2 'same' (All-CNN-C at side 16).
        let g = ConvGeom::new(Shape::new(96, 16, 16), 96, 3, 2, 1)
            .unwrap();
        assert_eq!(g.out_shape, Shape::new(96, 8, 8));
        assert!(ConvGeom::new(Shape::new(1, 2, 2), 4, 5, 1, 0).is_err());
        assert!(ConvGeom::new(Shape::new(1, 8, 8), 4, 3, 1, 3).is_err());
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1x1 kernel, stride 1: ⟦x⟧ is x itself, row per channel.
        let g = ConvGeom::new(Shape::new(2, 2, 2), 3, 1, 1, 0).unwrap();
        let x: Vec<f32> = (0..8).map(|v| v as f32).collect();
        assert_eq!(g.im2col(&x), x);
    }

    #[test]
    fn im2col_padding_and_stride() {
        // 1 channel 3x3, k=3, pad=1, stride=2 -> P = 2x2 corners.
        let g = ConvGeom::new(Shape::new(1, 3, 3), 1, 3, 2, 1).unwrap();
        assert_eq!(g.out_shape, Shape::new(1, 2, 2));
        let x: Vec<f32> =
            (1..=9).map(|v| v as f32).collect(); // 1..9 row-major
        let u = g.im2col(&x);
        assert_eq!(u.len(), 9 * 4);
        // Center tap j = ki*k + kj = 4; its row starts at 4*P = 16.
        // Position (0,0) reads x[0][0] = 1.
        assert_eq!(u[16], 1.0);
        // Top-left tap of position (0,0) is padding: 0.
        assert_eq!(u[0], 0.0);
        // Center tap of position (1,1) is x[2][2] = 9.
        assert_eq!(u[16 + 3], 9.0);
    }

    #[test]
    fn col2im_is_the_adjoint_of_im2col() {
        // <im2col(x), T> == <x, col2im(T)> for random x, T -- the
        // identity the conv backward pass rests on. Checked across
        // padding/stride/clipping variants.
        let mut rng = Rng::new(3);
        for (c, h, w, oc, k, s, p) in [
            (2usize, 5usize, 5usize, 3usize, 3usize, 1usize, 1usize),
            (3, 6, 4, 2, 3, 2, 1),
            (1, 7, 7, 2, 5, 1, 0),
            (2, 4, 4, 2, 1, 1, 0),
        ] {
            let g =
                ConvGeom::new(Shape::new(c, h, w), oc, k, s, p).unwrap();
            let x: Vec<f32> =
                (0..c * h * w).map(|_| rng.normal()).collect();
            let t: Vec<f32> = (0..g.patch_len() * g.positions())
                .map(|_| rng.normal())
                .collect();
            let u = g.im2col(&x);
            let fwd: f64 = u
                .iter()
                .zip(&t)
                .map(|(a, b)| (a * b) as f64)
                .sum();
            let mut back = vec![0.0f32; c * h * w];
            g.col2im_acc(&t, 1, &mut back);
            let adj: f64 = x
                .iter()
                .zip(&back)
                .map(|(a, b)| (a * b) as f64)
                .sum();
            assert!(
                (fwd - adj).abs() < 1e-3 * (1.0 + fwd.abs()),
                "adjoint mismatch k={k} s={s} p={p}: {fwd} vs {adj}"
            );
        }
    }

    #[test]
    fn range_unfold_tiles_reassemble_the_full_unfold() {
        // Any partition of [0, P) into ranges reproduces the full
        // unfold column-for-column — including tiles that split an
        // output row mid-way (the x0/x1 clipping).
        let mut rng = Rng::new(21);
        for (c, h, w, k, s, p) in [
            (2usize, 5usize, 5usize, 3usize, 1usize, 1usize),
            (3, 6, 4, 3, 2, 1),
            (1, 7, 7, 5, 1, 0),
            (2, 4, 4, 1, 1, 0),
        ] {
            let g =
                ConvGeom::new(Shape::new(c, h, w), 2, k, s, p).unwrap();
            let x: Vec<f32> =
                (0..c * h * w).map(|_| rng.normal()).collect();
            let full = g.im2col(&x);
            let (jn, pn) = (g.patch_len(), g.positions());
            for tile in [1usize, 3, 7, pn] {
                let mut q0 = 0;
                while q0 < pn {
                    let q1 = (q0 + tile).min(pn);
                    let tw = q1 - q0;
                    let mut u = vec![9.9f32; jn * tw]; // stale garbage
                    g.im2col_range(&x, q0, q1, &mut u);
                    for j in 0..jn {
                        for q in q0..q1 {
                            assert_eq!(
                                u[j * tw + (q - q0)],
                                full[j * pn + q],
                                "j={j} q={q} tile={tile} k={k}"
                            );
                        }
                    }
                    q0 = q1;
                }
            }
        }
    }

    #[test]
    fn range_scatter_tiles_sum_to_the_full_scatter() {
        let mut rng = Rng::new(23);
        let g = ConvGeom::new(Shape::new(2, 5, 4), 2, 3, 1, 1).unwrap();
        let (jn, pn) = (g.patch_len(), g.positions());
        let cols = 2;
        let t: Vec<f32> =
            (0..jn * pn * cols).map(|_| rng.normal()).collect();
        let mut full = vec![0.0f32; g.in_shape.flat() * cols];
        g.col2im_acc(&t, cols, &mut full);
        let mut tiled = vec![0.0f32; g.in_shape.flat() * cols];
        let tile = 7; // does not divide P, splits output rows
        let mut q0 = 0;
        while q0 < pn {
            let q1 = (q0 + tile).min(pn);
            let tw = q1 - q0;
            // Gather the [J, tw·cols] tile of t.
            let mut tt = vec![0.0f32; jn * tw * cols];
            for j in 0..jn {
                tt[j * tw * cols..(j + 1) * tw * cols].copy_from_slice(
                    &t[j * pn * cols + q0 * cols
                        ..j * pn * cols + q1 * cols],
                );
            }
            g.col2im_range_acc(&tt, cols, q0, q1, &mut tiled);
            q0 = q1;
        }
        for (a, b) in tiled.iter().zip(&full) {
            assert!(
                (a - b).abs() < 1e-5 * (1.0 + b.abs()),
                "{a} vs {b}"
            );
        }
    }

    #[test]
    fn col2im_cols_routes_trailing_channels_together() {
        let g = ConvGeom::new(Shape::new(1, 2, 2), 1, 1, 1, 0).unwrap();
        // J = 1, P = 4, cols = 2: scatter is the identity per column.
        let t: Vec<f32> = (0..8).map(|v| v as f32).collect();
        let mut out = vec![0.0f32; 4 * 2];
        g.col2im_acc(&t, 2, &mut out);
        assert_eq!(out, t);
    }
}
