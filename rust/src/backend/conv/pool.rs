//! Pooling layers: `MaxPool2d` and the global average pool.
//!
//! Max pooling's Jacobian is a per-sample selection matrix (one 1 per
//! output at the window argmax), so every propagation the engine needs
//! — first-order VJP and the column-carrying matrix VJPs (square-root
//! GGN, and `diag_h`'s signed residual factors, which ride the same
//! `cols` axis) — is index routing via [`PoolGeom::for_each_max`].
//! Both pooling layers are piecewise linear, so they contribute no
//! residual term of their own to the full-Hessian recursion
//! (DESIGN.md §11); they only route factors born above them. Windows *clip* at the
//! borders instead of padding (equivalent to −∞ padding; TF "same"
//! pooling), and `ceil` selects the TF/ceil output-size rule
//! `out = ⌈(in − k)/stride⌉ + 1` the 3c3d net relies on. Ties resolve
//! to the first element in row-major scan order, deterministically, so
//! shard layout can never change the routing.
//!
//! The global average pool (`GlobalAvgPool`, All-CNN-C's head) is a
//! fixed linear map: every propagation is a broadcast scaled by
//! `1/(h·w)`.

use anyhow::{ensure, Result};

use super::Shape;

/// Geometry of one `MaxPool2d` application (square window, uniform
/// stride, clipped borders).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolGeom {
    pub in_shape: Shape,
    pub out_shape: Shape,
    pub kernel: usize,
    pub stride: usize,
}

impl PoolGeom {
    pub fn new(
        in_shape: Shape,
        kernel: usize,
        stride: usize,
        ceil: bool,
    ) -> Result<PoolGeom> {
        ensure!(
            kernel >= 1 && stride >= 1,
            "MaxPool2d: kernel/stride must be >= 1"
        );
        ensure!(
            !ceil || stride <= kernel,
            "MaxPool2d: ceil mode with stride {stride} > kernel \
             {kernel} would start windows outside the input"
        );
        ensure!(
            in_shape.h >= kernel && in_shape.w >= kernel,
            "MaxPool2d: window {kernel} exceeds input {}x{}",
            in_shape.h,
            in_shape.w
        );
        let out = |d: usize| {
            if ceil {
                (d - kernel).div_ceil(stride) + 1
            } else {
                (d - kernel) / stride + 1
            }
        };
        Ok(PoolGeom {
            in_shape,
            out_shape: Shape::new(
                in_shape.c,
                out(in_shape.h),
                out(in_shape.w),
            ),
            kernel,
            stride,
        })
    }

    /// Visit every (output index, input argmax index) pair of one
    /// sample `x [c·h·w]`, in output order.
    pub fn for_each_max<F: FnMut(usize, usize)>(
        &self,
        x: &[f32],
        mut f: F,
    ) {
        let Shape { c, h, w } = self.in_shape;
        debug_assert_eq!(x.len(), self.in_shape.flat());
        let (oh, ow) = (self.out_shape.h, self.out_shape.w);
        for ch in 0..c {
            let plane = ch * h * w;
            for oy in 0..oh {
                let y0 = oy * self.stride;
                let y1 = (y0 + self.kernel).min(h);
                for ox in 0..ow {
                    let x0 = ox * self.stride;
                    let x1 = (x0 + self.kernel).min(w);
                    let mut best = plane + y0 * w + x0;
                    for iy in y0..y1 {
                        let row = plane + iy * w;
                        for ix in x0..x1 {
                            if x[row + ix] > x[best] {
                                best = row + ix;
                            }
                        }
                    }
                    f((ch * oh + oy) * ow + ox, best);
                }
            }
        }
    }

    /// Forward over a shard `inp [ns · c·h·w]`.
    pub fn forward(&self, inp: &[f32], ns: usize) -> Vec<f32> {
        let (fin, fout) = (self.in_shape.flat(), self.out_shape.flat());
        let mut z = vec![0.0f32; ns * fout];
        for s in 0..ns {
            let x = &inp[s * fin..(s + 1) * fin];
            let dst = &mut z[s * fout..(s + 1) * fout];
            self.for_each_max(x, |o, i| dst[o] = x[i]);
        }
        z
    }

    /// Transposed-Jacobian routing with `cols` trailing channels per
    /// feature: `g [ns, F_out, cols] -> [ns, F_in, cols]`. `cols = 1`
    /// is the first-order VJP; larger `cols` carries the square-root
    /// GGN columns. Overlapping windows (k > stride) accumulate.
    pub fn vjp(
        &self,
        inp: &[f32],
        g: &[f32],
        ns: usize,
        cols: usize,
    ) -> Vec<f32> {
        let (fin, fout) = (self.in_shape.flat(), self.out_shape.flat());
        debug_assert_eq!(g.len(), ns * fout * cols);
        let mut out = vec![0.0f32; ns * fin * cols];
        for s in 0..ns {
            let x = &inp[s * fin..(s + 1) * fin];
            let gs = &g[s * fout * cols..(s + 1) * fout * cols];
            let dst = &mut out[s * fin * cols..(s + 1) * fin * cols];
            self.for_each_max(x, |o, i| {
                for cc in 0..cols {
                    dst[i * cols + cc] += gs[o * cols + cc];
                }
            });
        }
        out
    }
}

/// Global average pool forward: `[ns, c·hw] -> [ns, c]`.
pub fn gap_forward(c: usize, hw: usize, inp: &[f32], ns: usize)
    -> Vec<f32> {
    debug_assert_eq!(inp.len(), ns * c * hw);
    let inv = 1.0 / hw as f32;
    let mut z = vec![0.0f32; ns * c];
    for s in 0..ns {
        for ch in 0..c {
            let src = (s * c + ch) * hw;
            z[s * c + ch] =
                inp[src..src + hw].iter().sum::<f32>() * inv;
        }
    }
    z
}

/// Global average pool transposed Jacobian with `cols` trailing
/// channels: broadcast each pooled feature back over its `hw`
/// positions, scaled by `1/hw`.
pub fn gap_vjp(c: usize, hw: usize, g: &[f32], ns: usize, cols: usize)
    -> Vec<f32> {
    debug_assert_eq!(g.len(), ns * c * cols);
    let inv = 1.0 / hw as f32;
    let mut out = vec![0.0f32; ns * c * hw * cols];
    for s in 0..ns {
        for ch in 0..c {
            let src = (s * c + ch) * cols;
            let base = (s * c + ch) * hw * cols;
            for p in 0..hw {
                for cc in 0..cols {
                    out[base + p * cols + cc] = g[src + cc] * inv;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_size_floor_and_ceil() {
        // 3c3d's 3x3 stride-2 'same' pools: 28->14, 12->6, 6->3.
        for (d, want) in [(28usize, 14usize), (12, 6), (6, 3)] {
            let g = PoolGeom::new(Shape::new(1, d, d), 3, 2, true)
                .unwrap();
            assert_eq!(g.out_shape.h, want, "ceil in={d}");
        }
        // 2c2d's 2x2 stride-2 pools: 28->14, 14->7.
        let g = PoolGeom::new(Shape::new(1, 14, 14), 2, 2, false)
            .unwrap();
        assert_eq!(g.out_shape.h, 7);
        assert!(PoolGeom::new(Shape::new(1, 2, 2), 3, 2, true).is_err());
    }

    #[test]
    fn forward_takes_window_max_with_clipping() {
        // 1 channel 3x3, k=2, s=2, ceil: out 2x2, last windows clip.
        let g = PoolGeom::new(Shape::new(1, 3, 3), 2, 2, true).unwrap();
        #[rustfmt::skip]
        let x = vec![
            1.0, 5.0, 2.0,
            0.0, 3.0, 8.0,
            7.0, 4.0, 6.0,
        ];
        assert_eq!(g.forward(&x, 1), vec![5.0, 8.0, 7.0, 6.0]);
    }

    #[test]
    fn vjp_routes_to_argmax_and_accumulates_overlaps() {
        // k=3 > stride=2: overlapping (and clipped) windows can pick
        // the same input. On 4x4, starts {0, 2}: all four windows
        // contain cell (2, 2).
        let g = PoolGeom::new(Shape::new(1, 4, 4), 3, 2, true).unwrap();
        assert_eq!(g.out_shape, Shape::new(1, 2, 2));
        let mut x = vec![0.0f32; 16];
        x[2 * 4 + 2] = 9.0; // dominates every window
        let grad = g.vjp(&x, &[1.0, 2.0, 3.0, 4.0], 1, 1);
        let mut want = vec![0.0f32; 16];
        want[2 * 4 + 2] = 10.0;
        assert_eq!(grad, want);
    }

    #[test]
    fn ties_resolve_to_first_in_scan_order() {
        let g = PoolGeom::new(Shape::new(1, 2, 2), 2, 2, false).unwrap();
        let x = vec![3.0f32, 3.0, 3.0, 3.0];
        let grad = g.vjp(&x, &[1.0], 1, 1);
        assert_eq!(grad, vec![1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn gap_forward_and_vjp_are_adjoint() {
        let (c, hw, ns) = (2usize, 4usize, 3usize);
        let x: Vec<f32> = (0..ns * c * hw).map(|v| v as f32).collect();
        let z = gap_forward(c, hw, &x, ns);
        assert_eq!(z.len(), ns * c);
        assert_eq!(z[0], (0.0 + 1.0 + 2.0 + 3.0) / 4.0);
        let g: Vec<f32> = (0..ns * c).map(|v| v as f32 + 1.0).collect();
        let back = gap_vjp(c, hw, &g, ns, 1);
        // <gap(x), g> == <x, gap_vjp(g)>
        let fwd: f32 = z.iter().zip(&g).map(|(a, b)| a * b).sum();
        let adj: f32 = x.iter().zip(&back).map(|(a, b)| a * b).sum();
        assert!((fwd - adj).abs() < 1e-4 * (1.0 + fwd.abs()));
    }
}
