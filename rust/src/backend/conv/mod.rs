//! Native convolution subsystem (DESIGN.md §6).
//!
//! Convolutions reduce to the fully-connected case by *patch
//! extraction*: `im2col` unfolds each sample into a matrix
//! `⟦x⟧ [c_in·k·k, P]` whose columns are the receptive fields of the
//! `P = out_h·out_w` output positions, turning `Conv2d` into the
//! matrix product `z = W ⟦x⟧ + b 1ᵀ` on the cache-blocked `linalg`
//! kernels. Every BackPACK extraction rule then follows the `Linear`
//! derivations of `backend/model.rs` with the unfolded input in place
//! of `x` and spatial positions folded into the contraction:
//!
//! * the averaged gradient and the per-sample `G ⟦x⟧ᵀ` products the
//!   first-order extension modules share ([`conv2d::grad`],
//!   [`conv2d::per_sample_grads`]),
//! * DiagGGN via the square-root propagation `S ↦ Wᵀ S` + `col2im`
//!   ([`conv2d::mat_vjp_input`], [`conv2d::diag_sqrt`]),
//! * KFAC/KFLR Kronecker factors from the unfolded input's
//!   self-outer-product and the position-averaged `S Sᵀ`
//!   ([`conv2d::kron_factors`]; Grosse & Martens 2016).
//!
//! KFRA is *not* lowered: its batch-averaged `Ḡ` recursion does not
//! scale to weight-shared layers (paper footnote 5), and the engine
//! rejects it on any model containing conv/pool layers.
//!
//! [`pool`] implements `MaxPool2d` (clipped windows = TF "same"
//! pooling; the Jacobian is a selection matrix, so all propagations
//! are index routing) and the global average pool All-CNN-C ends in.

pub mod conv2d;
pub mod im2col;
pub mod pool;

pub use im2col::ConvGeom;
pub use pool::PoolGeom;

/// Channels × height × width of one activation. Flat (vector) features
/// are `[d, 1, 1]`; activations are stored row-major `[c][h][w]` per
/// sample, so `flat()` is the feature dimension the engine's
/// `[N, features]` buffers use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shape {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl Shape {
    pub fn new(c: usize, h: usize, w: usize) -> Shape {
        Shape { c, h, w }
    }

    /// A flat feature vector of dimension `d`.
    pub fn flat_vec(d: usize) -> Shape {
        Shape { c: d, h: 1, w: 1 }
    }

    /// Total feature count.
    pub fn flat(&self) -> usize {
        self.c * self.h * self.w
    }

    /// Manifest-style dims: `[c, h, w]` for images, `[d]` for flat
    /// vectors.
    pub fn dims(&self) -> Vec<usize> {
        if self.h == 1 && self.w == 1 {
            vec![self.c]
        } else {
            vec![self.c, self.h, self.w]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_flat_and_dims() {
        let s = Shape::new(3, 4, 5);
        assert_eq!(s.flat(), 60);
        assert_eq!(s.dims(), vec![3, 4, 5]);
        let f = Shape::flat_vec(784);
        assert_eq!(f.flat(), 784);
        assert_eq!(f.dims(), vec![784]);
    }
}
