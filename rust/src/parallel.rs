//! Batch-parallel substrate on a persistent worker pool (zero
//! dependencies).
//!
//! The native backend's hot loops are all "per-sample work, then a
//! reduction" (paper Table 1: every BackPACK quantity is a sum or a
//! concatenation over the batch axis). This module provides the two
//! pieces needed to exploit that:
//!
//! * [`shards`] -- split `0..n` into at most `t` contiguous,
//!   nearly-equal ranges, deterministically;
//! * [`par_map`] -- run one closure per shard on the process-wide
//!   worker pool and return the results *in shard order*, so
//!   reductions are deterministic for a fixed thread count regardless
//!   of OS scheduling.
//!
//! ## Pool lifecycle (DESIGN.md §14)
//!
//! Workers are spawned lazily on the first `par_map` that needs them
//! and then live for the rest of the process, parked on a condvar —
//! the per-call `thread::scope` fork/join this module used through
//! PR 8 paid a spawn+join for every `par_map`, which dominated small
//! extractions. A call publishes one *ticket* per non-caller shard
//! into a shared injector queue; the caller and any woken workers
//! then claim shard indices from a single atomic counter on the job
//! (work stealing at shard granularity: whoever is free takes the
//! next undone shard), so an OS-preempted worker never strands work.
//! The caller participates too and blocks only until every claimed
//! shard has completed, which also makes nested `par_map` calls safe:
//! a worker that re-enters `par_map` drains its own inner job instead
//! of waiting on a queue.
//!
//! Shard `i` always runs under `obs::shard_scope(i, ..)` regardless
//! of which pool thread executes it, so `shard/{i}` trace lanes stay
//! keyed by shard index exactly as with scoped threads (shard 0 is no
//! longer guaranteed to run on the calling thread — lanes never
//! depended on that). Single-shard work runs inline on the caller
//! with no pool round-trip and no shard span (the serial guard).
//!
//! A panicking shard closure does not poison the pool: the panic is
//! caught on the worker, carried back, and resumed on the caller with
//! its original payload once the remaining shards finish; workers
//! stay parked for the next job.
//!
//! Thread-count resolution ([`resolve_threads`]): an explicit request
//! wins, then the `BACKPACK_THREADS` environment variable, then
//! `std::thread::available_parallelism()`.

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Environment variable overriding the auto-detected thread count.
pub const THREADS_ENV: &str = "BACKPACK_THREADS";

/// Detected hardware parallelism (1 if detection fails).
pub fn available_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a requested thread count: `0` means auto (`BACKPACK_THREADS`
/// if set to a positive integer, else all cores); any positive request
/// is taken verbatim. A malformed `BACKPACK_THREADS` value falls back
/// to auto-detect with a one-time stderr warning.
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Ok(v) = std::env::var(THREADS_ENV) {
        match parse_threads(&v) {
            Some(n) => return n,
            None => warn_bad_threads(&v),
        }
    }
    available_threads()
}

/// Parse a `BACKPACK_THREADS` value: a positive integer, or `None` for
/// anything else (empty, zero, negative, non-numeric).
fn parse_threads(v: &str) -> Option<usize> {
    v.trim().parse::<usize>().ok().filter(|n| *n > 0)
}

/// Warn (once per process) that `BACKPACK_THREADS` was ignored.
fn warn_bad_threads(v: &str) {
    static WARNED: std::sync::Once = std::sync::Once::new();
    WARNED.call_once(|| {
        eprintln!(
            "warning: ignoring {THREADS_ENV}={v:?} \
             (expected a positive integer); auto-detecting threads"
        );
    });
}

/// Split `0..n` into at most `threads` contiguous shards whose lengths
/// differ by at most one, in index order. Returns fewer shards when
/// `n < threads` (never an empty shard) and an empty vec for `n = 0`.
pub fn shards(n: usize, threads: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let t = threads.clamp(1, n);
    let (base, rem) = (n / t, n % t);
    let mut out = Vec::with_capacity(t);
    let mut start = 0;
    for i in 0..t {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// One job submitted to the pool: a type-erased view of the caller's
/// stack frame (closure, shard table, result slots) plus the claim /
/// completion state. Workers reach the frame only through `run`, and
/// only for a successfully claimed shard index, which is what makes
/// the raw pointer sound — see the safety argument on [`par_map`].
struct JobCore {
    /// Type-erased `&Payload<T, F>` on the calling thread's stack.
    data: *const (),
    /// Monomorphized shard runner for that payload type.
    run: unsafe fn(*const (), usize),
    /// Next shard index to claim; claims at or past `shards` are
    /// no-ops, so stale tickets are harmless.
    next: AtomicUsize,
    shards: usize,
    /// Shards not yet completed; guarded decrement + condvar is what
    /// the caller blocks on. User code never runs under this lock, so
    /// it cannot be poisoned.
    pending: Mutex<usize>,
    done: Condvar,
}

// SAFETY: `data` is only dereferenced via `run` between a successful
// shard claim and the matching `pending` decrement; the caller keeps
// the referent alive until `pending == 0` (see `par_map`).
unsafe impl Send for JobCore {}
unsafe impl Sync for JobCore {}

impl JobCore {
    /// Claim-and-run loop shared by the caller and pool workers:
    /// every participant pulls the next undone shard until none are
    /// left. Completion of each shard is published under `pending`.
    fn work(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.shards {
                return;
            }
            // SAFETY: shard `i` was claimed exactly once, and the
            // caller cannot return (freeing the payload) while this
            // shard's `pending` contribution is outstanding.
            unsafe { (self.run)(self.data, i) };
            let mut pending = self.pending.lock().unwrap();
            *pending -= 1;
            if *pending == 0 {
                self.done.notify_all();
            }
        }
    }
}

/// "Come help with this job" marker in the injector queue.
struct Ticket {
    job: Arc<JobCore>,
}

/// Result slot for one shard, written by its claimant, read by the
/// caller after the job completes.
struct Slot<T>(UnsafeCell<Option<std::thread::Result<T>>>);

// SAFETY: exactly one claimant writes each slot (the claim counter
// hands out each index once), and the caller reads only after the
// `pending`-mutex handshake has ordered every write before the read.
unsafe impl<T: Send> Sync for Slot<T> {}

/// Typed view of one `par_map` activation, borrowed from the caller's
/// stack for the duration of the job.
struct Payload<'a, T, F> {
    f: &'a F,
    work: &'a [Range<usize>],
    slots: &'a [Slot<T>],
}

/// Run shard `i` of the payload behind `data`: shard-scoped for obs
/// lane accounting, panic-caught so a worker survives a panicking
/// closure (the caught payload is resumed on the caller). The catch
/// sits *inside* `shard_scope` so lane restore + local-buffer flush
/// run even for a panicked shard.
unsafe fn run_shard<T, F>(data: *const (), i: usize)
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    let p = &*(data as *const Payload<'_, T, F>);
    let r = p.work[i].clone();
    let result = crate::obs::shard_scope(i, || {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            (p.f)(r)
        }))
    });
    *p.slots[i].0.get() = Some(result);
}

/// The process-wide pool: an injector queue of tickets plus the
/// worker park/wake condvar. Workers never hold the queue lock while
/// running user code.
struct PoolShared {
    inject: Mutex<VecDeque<Ticket>>,
    available: Condvar,
    spawned: Mutex<usize>,
}

impl PoolShared {
    /// Lazily grow the pool to at least `want` workers (detached,
    /// process-lived). Spawn failure degrades gracefully: the caller
    /// of `par_map` always self-drains its job, so fewer workers only
    /// costs parallelism, never correctness.
    fn ensure_workers(&self, want: usize) {
        let mut n = self.spawned.lock().unwrap();
        while *n < want {
            let name = format!("backpack-pool-{}", *n);
            match std::thread::Builder::new().name(name).spawn(worker_loop)
            {
                Ok(_) => *n += 1,
                Err(_) => break,
            }
        }
    }
}

fn pool() -> &'static PoolShared {
    static POOL: OnceLock<PoolShared> = OnceLock::new();
    POOL.get_or_init(|| PoolShared {
        inject: Mutex::new(VecDeque::new()),
        available: Condvar::new(),
        spawned: Mutex::new(0),
    })
}

/// Pool worker body: park on the condvar until a ticket arrives, then
/// help the ticket's job until its shards are exhausted. A ticket
/// whose job already finished (the caller and friends drained it) is
/// simply dropped — the claim counter makes over-delivery harmless.
fn worker_loop() {
    let pool = pool();
    let mut q = pool.inject.lock().unwrap();
    loop {
        match q.pop_front() {
            Some(t) => {
                drop(q);
                t.job.work();
                drop(t);
                q = pool.inject.lock().unwrap();
            }
            None => q = pool.available.wait(q).unwrap(),
        }
    }
}

/// Pre-spawn pool workers for `threads`-way parallelism so the first
/// real extraction doesn't pay thread-spawn latency. The serve daemon
/// calls this at bind time; it is idempotent and never shrinks the
/// pool.
pub fn warm(threads: usize) {
    pool().ensure_workers(threads.saturating_sub(1));
}

/// Number of pool workers spawned so far (diagnostic; the pool only
/// grows).
pub fn pool_workers() -> usize {
    *pool().spawned.lock().unwrap()
}

/// Pool-backed map: run `f` once per shard across the persistent
/// worker pool (the caller participates) and return the results in
/// shard order, so downstream reductions see a fixed order for a
/// fixed shard layout (bit-for-bit deterministic per thread count).
/// A panic in any shard closure is re-raised on the caller with its
/// original payload after the remaining shards finish; the pool
/// itself survives. Single-shard work runs inline (serial guard).
///
/// # Safety argument
///
/// The job hands workers a raw pointer to this activation's stack
/// frame (`Payload`). That is sound because (a) a shard claim past
/// `work.len()` never touches the pointer, so stale tickets are inert;
/// (b) each successful claim holds up one unit of `pending`, and this
/// function does not return before `pending == 0`, so every
/// dereference happens while the frame is live; (c) the `pending`
/// mutex orders all slot writes before the caller's reads.
pub fn par_map<T, F>(work: &[Range<usize>], f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    if work.len() <= 1 {
        return work.iter().cloned().map(f).collect();
    }
    let pool = pool();
    pool.ensure_workers(work.len() - 1);
    let slots: Vec<Slot<T>> =
        (0..work.len()).map(|_| Slot(UnsafeCell::new(None))).collect();
    let payload = Payload { f: &f, work, slots: &slots };
    let job = Arc::new(JobCore {
        data: &payload as *const Payload<'_, T, F> as *const (),
        run: run_shard::<T, F>,
        next: AtomicUsize::new(0),
        shards: work.len(),
        pending: Mutex::new(work.len()),
        done: Condvar::new(),
    });
    {
        let mut q = pool.inject.lock().unwrap();
        for _ in 1..work.len() {
            q.push_back(Ticket { job: Arc::clone(&job) });
        }
        pool.available.notify_all();
    }
    // The caller steals shards like any worker, then waits out the
    // stragglers other threads claimed.
    job.work();
    {
        let mut pending = job.pending.lock().unwrap();
        while *pending > 0 {
            pending = job.done.wait(pending).unwrap();
        }
    }
    // Sweep tickets nobody consumed (the job drained before every
    // ticket was popped) so the queue doesn't accumulate dead entries.
    {
        let mut q = pool.inject.lock().unwrap();
        q.retain(|t| !Arc::ptr_eq(&t.job, &job));
    }
    let mut out = Vec::with_capacity(work.len());
    for slot in slots {
        match slot.0.into_inner().expect("pool shard never ran") {
            Ok(v) => out.push(v),
            Err(e) => std::panic::resume_unwind(e),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_cover_and_balance() {
        for n in [0usize, 1, 2, 7, 64, 65, 1000] {
            for t in [1usize, 2, 3, 8, 200] {
                let sh = shards(n, t);
                assert_eq!(sh.len(), t.clamp(1, n.max(1)).min(n));
                let total: usize = sh.iter().map(|r| r.len()).sum();
                assert_eq!(total, n, "n={n} t={t}");
                let mut next = 0;
                for r in &sh {
                    assert_eq!(r.start, next, "contiguous in order");
                    assert!(!r.is_empty());
                    next = r.end;
                }
                if let (Some(max), Some(min)) = (
                    sh.iter().map(|r| r.len()).max(),
                    sh.iter().map(|r| r.len()).min(),
                ) {
                    assert!(max - min <= 1, "balanced: {max} vs {min}");
                }
            }
        }
    }

    #[test]
    fn par_map_returns_in_shard_order() {
        let sh = shards(100, 7);
        let got = par_map(&sh, |r| (r.start, r.len()));
        let want: Vec<(usize, usize)> =
            sh.iter().map(|r| (r.start, r.len())).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn par_map_matches_serial_reduction() {
        let xs: Vec<f64> = (0..997).map(|i| (i as f64).sqrt()).collect();
        let serial: f64 = xs.iter().sum();
        for t in [1usize, 2, 3, 5, 16] {
            let sh = shards(xs.len(), t);
            let partial = par_map(&sh, |r| xs[r].iter().sum::<f64>());
            let total: f64 = partial.iter().sum();
            assert!((total - serial).abs() < 1e-9, "t={t}");
        }
    }

    #[test]
    fn pool_is_reused_across_calls() {
        let sh = shards(64, 4);
        let _ = par_map(&sh, |r| r.len());
        let after_first = pool_workers();
        assert!(after_first >= 3, "4-shard job wants >= 3 workers");
        for i in 0..20 {
            let got: usize =
                par_map(&sh, |r| r.len()).into_iter().sum();
            assert_eq!(got, 64, "call {i}");
        }
        // The pool only ever grows on demand; repeating the same
        // shard count adds nothing (other tests may grow it further
        // concurrently, hence >= on the floor rather than equality).
        assert!(pool_workers() >= after_first);
    }

    #[test]
    fn nested_par_map_completes() {
        let outer = shards(8, 4);
        let got = par_map(&outer, |r| {
            let inner = shards(r.len() * 10, 3);
            par_map(&inner, |ir| ir.len()).into_iter().sum::<usize>()
        });
        let want: Vec<usize> = outer.iter().map(|r| r.len() * 10).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn resolve_threads_prefers_explicit_request() {
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1);
    }

    #[test]
    fn parse_threads_accepts_only_positive_integers() {
        assert_eq!(parse_threads("4"), Some(4));
        assert_eq!(parse_threads(" 12\n"), Some(12));
        for bad in ["", "0", "-2", "2.5", "two", "4x", "18446744073709551616"]
        {
            assert_eq!(parse_threads(bad), None, "{bad:?}");
        }
    }

    #[test]
    fn malformed_threads_env_falls_back_to_auto_detect() {
        // The env var is process-global, so exercise the same
        // fallback logic resolve_threads() applies to it.
        let fallback = match parse_threads("not-a-number") {
            Some(n) => n,
            None => {
                warn_bad_threads("not-a-number");
                available_threads()
            }
        };
        assert_eq!(fallback, available_threads());
        assert!(fallback >= 1);
    }
}
