//! Batch-parallel fork/join substrate (zero dependencies).
//!
//! The native backend's hot loops are all "per-sample work, then a
//! reduction" (paper Table 1: every BackPACK quantity is a sum or a
//! concatenation over the batch axis). This module provides the two
//! pieces needed to exploit that with `std::thread::scope` alone:
//!
//! * [`shards`] -- split `0..n` into at most `t` contiguous,
//!   nearly-equal ranges, deterministically;
//! * [`par_map`] -- run one closure per shard on scoped threads
//!   (shard 0 runs on the calling thread) and return the results *in
//!   shard order*, so reductions are deterministic for a fixed thread
//!   count regardless of OS scheduling.
//!
//! Thread-count resolution ([`resolve_threads`]): an explicit request
//! wins, then the `BACKPACK_THREADS` environment variable, then
//! `std::thread::available_parallelism()`.

use std::ops::Range;

/// Environment variable overriding the auto-detected thread count.
pub const THREADS_ENV: &str = "BACKPACK_THREADS";

/// Detected hardware parallelism (1 if detection fails).
pub fn available_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a requested thread count: `0` means auto (`BACKPACK_THREADS`
/// if set to a positive integer, else all cores); any positive request
/// is taken verbatim. A malformed `BACKPACK_THREADS` value falls back
/// to auto-detect with a one-time stderr warning.
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Ok(v) = std::env::var(THREADS_ENV) {
        match parse_threads(&v) {
            Some(n) => return n,
            None => warn_bad_threads(&v),
        }
    }
    available_threads()
}

/// Parse a `BACKPACK_THREADS` value: a positive integer, or `None` for
/// anything else (empty, zero, negative, non-numeric).
fn parse_threads(v: &str) -> Option<usize> {
    v.trim().parse::<usize>().ok().filter(|n| *n > 0)
}

/// Warn (once per process) that `BACKPACK_THREADS` was ignored.
fn warn_bad_threads(v: &str) {
    static WARNED: std::sync::Once = std::sync::Once::new();
    WARNED.call_once(|| {
        eprintln!(
            "warning: ignoring {THREADS_ENV}={v:?} \
             (expected a positive integer); auto-detecting threads"
        );
    });
}

/// Split `0..n` into at most `threads` contiguous shards whose lengths
/// differ by at most one, in index order. Returns fewer shards when
/// `n < threads` (never an empty shard) and an empty vec for `n = 0`.
pub fn shards(n: usize, threads: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let t = threads.clamp(1, n);
    let (base, rem) = (n / t, n % t);
    let mut out = Vec::with_capacity(t);
    let mut start = 0;
    for i in 0..t {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Fork/join map: run `f` once per shard, spawning scoped threads for
/// shards `1..` while the calling thread computes shard `0`. Results
/// come back in shard order, so downstream reductions see a fixed
/// order for a fixed shard layout (bit-for-bit deterministic per
/// thread count). Panics in workers propagate to the caller.
pub fn par_map<T, F>(work: &[Range<usize>], f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    if work.len() <= 1 {
        return work.iter().cloned().map(f).collect();
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = work[1..]
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let (f, r) = (&f, r.clone());
                scope.spawn(move || {
                    crate::obs::shard_scope(i + 1, || f(r))
                })
            })
            .collect();
        let mut out = Vec::with_capacity(work.len());
        out.push(crate::obs::shard_scope(0, || f(work[0].clone())));
        out.extend(
            handles
                .into_iter()
                .map(|h| h.join().expect("parallel worker panicked")),
        );
        out
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_cover_and_balance() {
        for n in [0usize, 1, 2, 7, 64, 65, 1000] {
            for t in [1usize, 2, 3, 8, 200] {
                let sh = shards(n, t);
                assert_eq!(sh.len(), t.clamp(1, n.max(1)).min(n));
                let total: usize = sh.iter().map(|r| r.len()).sum();
                assert_eq!(total, n, "n={n} t={t}");
                let mut next = 0;
                for r in &sh {
                    assert_eq!(r.start, next, "contiguous in order");
                    assert!(!r.is_empty());
                    next = r.end;
                }
                if let (Some(max), Some(min)) = (
                    sh.iter().map(|r| r.len()).max(),
                    sh.iter().map(|r| r.len()).min(),
                ) {
                    assert!(max - min <= 1, "balanced: {max} vs {min}");
                }
            }
        }
    }

    #[test]
    fn par_map_returns_in_shard_order() {
        let sh = shards(100, 7);
        let got = par_map(&sh, |r| (r.start, r.len()));
        let want: Vec<(usize, usize)> =
            sh.iter().map(|r| (r.start, r.len())).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn par_map_matches_serial_reduction() {
        let xs: Vec<f64> = (0..997).map(|i| (i as f64).sqrt()).collect();
        let serial: f64 = xs.iter().sum();
        for t in [1usize, 2, 3, 5, 16] {
            let sh = shards(xs.len(), t);
            let partial = par_map(&sh, |r| xs[r].iter().sum::<f64>());
            let total: f64 = partial.iter().sum();
            assert!((total - serial).abs() < 1e-9, "t={t}");
        }
    }

    #[test]
    fn resolve_threads_prefers_explicit_request() {
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1);
    }

    #[test]
    fn parse_threads_accepts_only_positive_integers() {
        assert_eq!(parse_threads("4"), Some(4));
        assert_eq!(parse_threads(" 12\n"), Some(12));
        for bad in ["", "0", "-2", "2.5", "two", "4x", "18446744073709551616"]
        {
            assert_eq!(parse_threads(bad), None, "{bad:?}");
        }
    }

    #[test]
    fn malformed_threads_env_falls_back_to_auto_detect() {
        // The env var is process-global, so exercise the same
        // fallback logic resolve_threads() applies to it.
        let fallback = match parse_threads("not-a-number") {
            Some(n) => n,
            None => {
                warn_bad_threads("not-a-number");
                available_threads()
            }
        };
        assert_eq!(fallback, available_threads());
        assert!(fallback >= 1);
    }
}
