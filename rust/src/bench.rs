//! Micro-benchmark harness (substrate; criterion is unavailable
//! offline). Warmup + fixed-count sampling, robust summary statistics,
//! criterion-like console output, and CSV export for the figure
//! regenerators.

use std::time::{Duration, Instant};

/// Summary statistics over the sampled iteration times.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub samples: Vec<f64>, // seconds
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Stats {
    fn from_samples(name: &str, mut s: Vec<f64>) -> Stats {
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len() as f64;
        let mean = s.iter().sum::<f64>() / n;
        let var =
            s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        let pct = |q: f64| -> f64 {
            let pos = q * (s.len() - 1) as f64;
            let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
            if lo == hi {
                s[lo]
            } else {
                s[lo] * (hi as f64 - pos) + s[hi] * (pos - lo as f64)
            }
        };
        Stats {
            name: name.to_string(),
            mean,
            std: var.sqrt(),
            min: s[0],
            p50: pct(0.5),
            p95: pct(0.95),
            samples: s,
        }
    }

    pub fn print_line(&self) {
        println!(
            "{:42} mean {:>10}  p50 {:>10}  p95 {:>10}  (±{:>8}, n={})",
            self.name,
            fmt_time(self.mean),
            fmt_time(self.p50),
            fmt_time(self.p95),
            fmt_time(self.std),
            self.samples.len()
        );
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Benchmark a closure: `warmup` unmeasured runs, then up to `iters`
/// measured runs, but stop early once `budget` wall-clock is spent
/// (long-running artifacts get fewer samples, never zero).
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    budget: Duration,
    mut f: F,
) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    let start = Instant::now();
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
        if start.elapsed() > budget && !samples.is_empty() {
            break;
        }
    }
    let s = Stats::from_samples(name, samples);
    s.print_line();
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_summary() {
        let s = Stats::from_samples(
            "t",
            vec![1.0, 2.0, 3.0, 4.0, 5.0],
        );
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.min, 1.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
    }

    #[test]
    fn bench_runs_and_respects_budget() {
        let mut count = 0;
        let s = bench("noop", 1, 1000, Duration::from_millis(20), || {
            count += 1;
            std::thread::sleep(Duration::from_millis(1));
        });
        assert!(!s.samples.is_empty());
        assert!(count < 1000, "budget should stop early");
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-5).ends_with("µs"));
        assert!(fmt_time(2e-2).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with('s'));
    }
}
