//! Micro-benchmark harness (substrate; criterion is unavailable
//! offline). Warmup + fixed-count sampling, robust summary statistics,
//! criterion-like console output, CSV export for the figure
//! regenerators, the machine-readable perf baseline
//! ([`perf_baseline`] -> `BENCH_native.json`) that CI uploads on every
//! push so the repo carries a perf trajectory, and the regression gate
//! ([`compare_baselines`] / `bench --compare`) the CI `bench` job runs
//! against the baseline committed at the repo root (`docs/bench.md`).

use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::backend::Backend;
use crate::json::Json;

/// Summary statistics over the sampled iteration times.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub samples: Vec<f64>, // seconds
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    /// Wall-clock of the measured sampling loop (>= the sample sum;
    /// reveals when a time budget truncated the requested iteration
    /// count).
    pub total_s: f64,
    /// Per-phase p50 seconds from a traced side-measurement
    /// ([`phase_breakdown`]); empty unless the harness filled it. The
    /// headline numbers above always come from untraced iterations.
    pub phase_p50_s: std::collections::BTreeMap<String, f64>,
}

impl Stats {
    fn from_samples(name: &str, mut s: Vec<f64>) -> Stats {
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len() as f64;
        let mean = s.iter().sum::<f64>() / n;
        let var =
            s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        let pct = |q: f64| -> f64 {
            let pos = q * (s.len() - 1) as f64;
            let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
            if lo == hi {
                s[lo]
            } else {
                s[lo] * (hi as f64 - pos) + s[hi] * (pos - lo as f64)
            }
        };
        Stats {
            name: name.to_string(),
            mean,
            std: var.sqrt(),
            min: s[0],
            p50: pct(0.5),
            p95: pct(0.95),
            total_s: s.iter().sum(),
            samples: s,
            phase_p50_s: std::collections::BTreeMap::new(),
        }
    }

    pub fn print_line(&self) {
        println!(
            "{:42} mean {:>10}  p50 {:>10}  p95 {:>10}  (±{:>8}, n={})",
            self.name,
            fmt_time(self.mean),
            fmt_time(self.p50),
            fmt_time(self.p95),
            fmt_time(self.std),
            self.samples.len()
        );
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Benchmark a closure: `warmup` unmeasured runs, then up to `iters`
/// measured runs, but stop early once `budget` wall-clock is spent.
/// The budget check sits after the `push`, so a long-running artifact
/// gets fewer samples but never zero. `Stats::total_s` records the
/// measured loop's wall-clock, making budget truncation visible in
/// the exported numbers.
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    budget: Duration,
    mut f: F,
) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    let start = Instant::now();
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
        if start.elapsed() > budget {
            break;
        }
    }
    let total_s = start.elapsed().as_secs_f64();
    let mut s = Stats::from_samples(name, samples);
    s.total_s = total_s;
    s.print_line();
    s
}

/// Measure a closure's per-phase p50 over a few *traced* iterations:
/// enables the observability recorder (without clearing a surrounding
/// `--trace` collection), reads the [`crate::obs::CAT_PHASE`] totals
/// of each iteration via [`crate::obs::mark`]/[`crate::obs::since`],
/// and returns the per-phase medians in seconds. The recorder is
/// restored to its prior state, so the untraced headline sampling
/// around this call stays unmeasured.
pub fn phase_breakdown<F: FnMut()>(
    mut f: F,
    iters: usize,
) -> std::collections::BTreeMap<String, f64> {
    let was_enabled = crate::obs::enabled();
    if !was_enabled {
        crate::obs::resume();
    }
    let mut per: std::collections::BTreeMap<String, Vec<f64>> =
        std::collections::BTreeMap::new();
    for _ in 0..iters.max(1) {
        let m = crate::obs::mark();
        f();
        for (name, (_count, total_s)) in
            crate::obs::since(&m).phase_totals()
        {
            per.entry(name).or_default().push(total_s);
        }
    }
    if !was_enabled {
        let _ = crate::obs::stop(); // drop the side-measurement events
    }
    per.into_iter()
        .map(|(name, mut v)| {
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mid = v.len() / 2;
            let p50 = if v.len() % 2 == 1 {
                v[mid]
            } else {
                0.5 * (v[mid - 1] + v[mid])
            };
            (name, p50)
        })
        .collect()
}

/// JSON schema identifier written into the baseline file; bump on any
/// breaking change to the layout below.
pub const BENCH_SCHEMA: &str = "backpack-bench/v1";

/// Measure the machine-speed calibration constant recorded into every
/// baseline document (`calib_s`): the p50 seconds of one fixed
/// workload -- a naive 96x96x96 [`crate::linalg::reference::matmul`],
/// which never changes with the crate's optimization work (the
/// reference kernels exist precisely to stay frozen). When both sides
/// of a comparison carry `calib_s`, [`compare_report`] divides it out,
/// so a uniformly slower machine does not read as a code regression
/// and the gate can afford to be tight (1.5x) instead of generous
/// (3x). See `docs/bench.md`.
pub fn measure_calibration() -> f64 {
    const N: usize = 96;
    let a: Vec<f32> = (0..N * N)
        .map(|i| (i % 17) as f32 * 0.25 - 2.0)
        .collect();
    let b: Vec<f32> = (0..N * N)
        .map(|i| (i % 13) as f32 * 0.5 - 3.0)
        .collect();
    let mut samples = Vec::new();
    let mut sink = 0.0f32;
    // 2 unmeasured warmup runs, then 9 samples; the workload is
    // ~1.8 MFLOP so the whole probe stays well under 50ms.
    for it in 0..11 {
        let t = Instant::now();
        let c = crate::linalg::reference::matmul(&a, &b, N, N, N);
        let dt = t.elapsed().as_secs_f64();
        sink += c[N * N - 1];
        if it >= 2 {
            samples.push(dt);
        }
    }
    std::hint::black_box(sink);
    samples.sort_by(|x, y| x.partial_cmp(y).unwrap());
    samples[samples.len() / 2]
}

/// One perf-baseline case: model x extension signature, bound to the
/// dataset whose sample dim the model consumes. `batch_div` scales
/// the requested batch down for the expensive conv graphs (min 4) so
/// `--quick` stays CI-sized while the recorded `batch` field keeps
/// the baseline comparable run-to-run.
#[derive(Debug, Clone, Copy)]
pub struct BaselineCase {
    pub model: &'static str,
    pub dataset: &'static str,
    pub signature: &'static str,
    pub batch_div: usize,
}

/// The perf-baseline grid: the paper's native problems under the
/// plain gradient plus every applicable extension signature (Fig. 6's
/// overhead story, on this backend). Fully-connected models carry all
/// ten extensions -- `diag_h` included, whose residual walk fires on
/// `mlp` (it has a sigmoid); the conv models drop `kfra` (paper
/// footnote 5) and run at `batch / 8` -- the conv overhead
/// *trajectory* is what the baseline records, not paper-scale
/// absolute cost. One dedicated `3c3d_sigmoid` diag_h case (at
/// `batch / 32`: the factor born at the sigmoid carries 256 columns
/// through the whole conv stack, making this by far the most
/// expensive walk in the grid -- the Fig. 9 story) keeps the conv
/// residual path in the recorded trajectory too.
pub fn baseline_cases() -> Vec<BaselineCase> {
    let grid = [
        ("logreg", "mnist", 1usize),
        ("mlp", "mnist", 1),
        ("2c2d", "fmnist", 8),
        ("3c3d", "cifar10", 8),
    ];
    let mut cases = Vec::new();
    for (model, dataset, batch_div) in grid {
        for sig in ["grad"]
            .into_iter()
            .chain(crate::backend::model::NATIVE_EXTENSIONS.iter()
                   .copied())
        {
            if sig == "kfra" && batch_div > 1 {
                continue; // conv models: fully-connected only
            }
            cases.push(BaselineCase {
                model,
                dataset,
                signature: sig,
                batch_div,
            });
        }
    }
    cases.push(BaselineCase {
        model: "3c3d_sigmoid",
        dataset: "cifar10",
        signature: "diag_h",
        batch_div: 32,
    });
    cases
}

/// Run the perf baseline through a backend and write the
/// machine-readable summary (`BENCH_native.json` by default).
///
/// Schema (`backpack-bench/v1`): top-level `schema`, `backend`,
/// `threads`, `workers`, `git_rev`, `quick`, `batch`, `unit`
/// ("seconds"), `calib_s` (machine-speed probe,
/// [`measure_calibration`]), `total_wall_s`, and `cases[]` with
/// `name`, `model`, `signature`, `batch`, `samples`, `mean_s`,
/// `p50_s`, `p95_s`, `min_s`, `std_s`, `total_s`, and `phases`
/// (per-phase p50 seconds from a traced side-measurement; additive
/// -- the headline numbers stay untraced).
///
/// `workers > 0` benches the process-parallel path instead: the
/// cases run through [`crate::dist::coordinate`] against `workers`
/// shard workers served on in-process threads (same wire protocol
/// and merge as real `backpack worker` processes, minus the spawn
/// cost -- steady-state shard overhead is what the dimension
/// records; the workers share this process's thread pool). Models
/// whose parameter set exceeds the shard frame cap (2c2d) are
/// skipped with a printed note rather than failing the grid.
pub fn perf_baseline(
    be: &dyn Backend,
    threads: usize,
    workers: usize,
    quick: bool,
    batch: usize,
    out: &Path,
) -> Result<()> {
    perf_baseline_with(
        be,
        threads,
        workers,
        quick,
        batch,
        &baseline_cases(),
        out,
    )
}

/// [`perf_baseline`] over an explicit case list (tests use a reduced
/// grid; the CLI always runs [`baseline_cases`]).
pub fn perf_baseline_with(
    be: &dyn Backend,
    threads: usize,
    workers: usize,
    quick: bool,
    batch: usize,
    grid: &[BaselineCase],
    out: &Path,
) -> Result<()> {
    let (iters, budget_s) = if quick { (5, 0.5) } else { (30, 3.0) };
    let calib_s = measure_calibration();
    println!(
        "== perf baseline: backend={} threads={threads} \
         workers={workers} batch={batch} iters<={iters} calib={} ==",
        be.name(),
        fmt_time(calib_s)
    );
    // The --workers dimension: stand up the shard workers once (they
    // are stateless between sessions, so every case reuses them) and
    // route each case through the coordinator instead of a direct
    // artifact run.
    let nb = (workers > 0)
        .then(crate::backend::native::NativeBackend::new);
    let dist_addrs: Vec<String> = if workers > 0 {
        anyhow::ensure!(
            be.name() == "native",
            "--workers benches the native shard path; backend {:?} \
             has no workers",
            be.name()
        );
        let mut addrs = Vec::with_capacity(workers);
        for _ in 0..workers {
            let w = crate::dist::Worker::bind("127.0.0.1:0", threads)?;
            addrs.push(w.local_addr().to_string());
            std::thread::spawn(move || {
                let _ = w.run();
            });
        }
        addrs
    } else {
        Vec::new()
    };
    let start = Instant::now();
    let mut cases = Vec::new();
    for case in grid.iter().copied() {
        // The min-4 floor belongs to the conv down-scaling only; an
        // explicitly requested tiny --batch is honored for FC cases.
        let case_batch = if case.batch_div > 1 {
            (batch / case.batch_div).max(4)
        } else {
            batch
        };
        // Typed construction validates the case grid (model grammar,
        // signature spelling) before any timing runs.
        let id = crate::backend::api::ArtifactId::new(
            case.model,
            case.signature.parse()?,
            case_batch,
        )?;
        let name = id.to_string();
        if let Some(nb) = &nb {
            // backpack-shard/v1 moves the full parameter set in one
            // frame (~21 JSON bytes per f32), so models over the
            // 64 MiB cap (2c2d) sit out the --workers dimension
            // instead of erroring mid-grid — docs/distributed.md.
            let numel: usize = nb
                .spec_id(&id)?
                .param_inputs()
                .iter()
                .map(|t| t.shape.iter().product::<usize>())
                .sum();
            if numel.saturating_mul(21) > crate::wire::MAX_FRAME {
                println!(
                    "  skip {name}: {numel} params exceed the \
                     shard frame cap"
                );
                continue;
            }
        }
        let stats = if let Some(nb) = &nb {
            crate::figures::timing::time_dist_artifact(
                nb,
                case.model,
                case.signature,
                case_batch,
                case.dataset,
                &dist_addrs,
                iters,
                budget_s,
            )
        } else {
            crate::figures::timing::time_artifact(
                be, &name, case.dataset, iters, budget_s,
            )
        }
        .with_context(|| format!("bench case {name}"))?;
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("name".to_string(), Json::Str(name));
        obj.insert(
            "model".to_string(),
            Json::Str(case.model.to_string()),
        );
        obj.insert(
            "signature".to_string(),
            Json::Str(case.signature.to_string()),
        );
        obj.insert("batch".to_string(), Json::Num(case_batch as f64));
        obj.insert(
            "samples".to_string(),
            Json::Num(stats.samples.len() as f64),
        );
        obj.insert("mean_s".to_string(), Json::Num(stats.mean));
        obj.insert("p50_s".to_string(), Json::Num(stats.p50));
        obj.insert("p95_s".to_string(), Json::Num(stats.p95));
        obj.insert("min_s".to_string(), Json::Num(stats.min));
        obj.insert("std_s".to_string(), Json::Num(stats.std));
        obj.insert("total_s".to_string(), Json::Num(stats.total_s));
        obj.insert(
            "phases".to_string(),
            Json::Obj(
                stats
                    .phase_p50_s
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Num(*v)))
                    .collect(),
            ),
        );
        cases.push(Json::Obj(obj));
    }
    let mut root = std::collections::BTreeMap::new();
    root.insert(
        "schema".to_string(),
        Json::Str(BENCH_SCHEMA.to_string()),
    );
    root.insert(
        "backend".to_string(),
        Json::Str(be.name().to_string()),
    );
    root.insert("threads".to_string(), Json::Num(threads as f64));
    root.insert("workers".to_string(), Json::Num(workers as f64));
    root.insert("git_rev".to_string(), Json::Str(git_rev()));
    root.insert("quick".to_string(), Json::Bool(quick));
    root.insert("batch".to_string(), Json::Num(batch as f64));
    root.insert(
        "unit".to_string(),
        Json::Str("seconds".to_string()),
    );
    root.insert("calib_s".to_string(), Json::Num(calib_s));
    root.insert(
        "total_wall_s".to_string(),
        Json::Num(start.elapsed().as_secs_f64()),
    );
    root.insert("cases".to_string(), Json::Arr(cases));
    let text = Json::Obj(root).to_string_json();
    if let Some(dir) = out.parent().filter(|d| !d.as_os_str().is_empty())
    {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(out, text + "\n")
        .with_context(|| format!("write {}", out.display()))?;
    // Workers are external to the coordinator (connected by address,
    // not spawned), so sessions never stop them -- send each the
    // protocol's shutdown so the serving threads exit cleanly.
    for addr in &dist_addrs {
        if let Ok(mut s) = std::net::TcpStream::connect(addr) {
            let _ = crate::wire::write_frame(
                &mut s,
                &crate::dist::protocol::shutdown(),
            );
            let _ = crate::wire::read_frame(&mut s);
        }
    }
    println!(
        "wrote {} ({} cases, {:.1}s)",
        out.display(),
        grid.len(),
        start.elapsed().as_secs_f64()
    );
    Ok(())
}

/// Schema identifier of the machine-readable compare result
/// ([`CompareReport::to_json`], uploaded as a CI artifact next to
/// `BENCH_native.json`); bump on any breaking layout change.
pub const COMPARE_SCHEMA: &str = "backpack-bench-compare/v1";

/// One row of a [`CompareReport`]: a case of the current run matched
/// (by name) against the baseline.
#[derive(Debug, Clone)]
pub struct CompareCase {
    pub name: String,
    /// Baseline p50; `None` for a case new in the current run.
    pub base_p50_s: Option<f64>,
    pub current_p50_s: f64,
    /// `current / baseline`; `None` for new cases.
    pub ratio: Option<f64>,
    /// True when `ratio` exceeded the gate's `max_ratio`.
    pub regressed: bool,
}

/// The full result of one baseline comparison, separated from the
/// pass/fail decision so callers get the per-case table (sorted worst
/// ratio first) and a machine-readable JSON artifact even when the
/// gate fails.
#[derive(Debug, Clone)]
pub struct CompareReport {
    pub max_ratio: f64,
    /// Machine-speed normalization applied to every ratio:
    /// `baseline.calib_s / current.calib_s`, present only when both
    /// documents carry a positive `calib_s`
    /// ([`measure_calibration`]). A uniformly 2x-slower machine has
    /// `calib_scale = 0.5`, cancelling the raw 2x per-case slowdown;
    /// a genuine code regression leaves `calib_s` unchanged and is
    /// not forgiven. `None` means raw ratios were gated (pre-calib
    /// baselines).
    pub calib_scale: Option<f64>,
    /// Every case of the current run, sorted by ratio descending
    /// (worst regression first); new cases without a baseline sort
    /// after all matched ones.
    pub cases: Vec<CompareCase>,
    /// Baseline case names absent from the current run (grid
    /// shrinkage -- always a gate failure).
    pub missing: Vec<String>,
}

impl CompareReport {
    /// Whether the gate passes: no missing cases, no regressions.
    pub fn passed(&self) -> bool {
        self.missing.is_empty()
            && !self.cases.iter().any(|c| c.regressed)
    }

    /// The sorted per-case ratio table on stdout (worst first).
    pub fn print_table(&self) {
        match self.calib_scale {
            Some(s) => println!(
                "machine calibration: ratios scaled by {s:.3} \
                 (baseline calib / current calib)"
            ),
            None => println!(
                "machine calibration: absent on one side; gating raw \
                 ratios"
            ),
        }
        for c in &self.cases {
            match (c.base_p50_s, c.ratio) {
                (Some(b), Some(ratio)) => {
                    let flag = if c.regressed { "  << REGRESSED" }
                               else { "" };
                    println!(
                        "{:42} {:>10} vs {:>10}  ({ratio:5.2}x){flag}",
                        c.name,
                        fmt_time(c.current_p50_s),
                        fmt_time(b)
                    );
                }
                _ => println!(
                    "{:42} {:>10}  (new case, no baseline)",
                    c.name,
                    fmt_time(c.current_p50_s)
                ),
            }
        }
        for name in &self.missing {
            println!("{name:42}  MISSING from the current run");
        }
    }

    /// Machine-readable result ([`COMPARE_SCHEMA`]): `schema`,
    /// `max_ratio`, `calib_scale` (null when either side lacks a
    /// `calib_s`), `passed`, `missing[]`, and `cases[]` rows with
    /// `name` / `base_p50_s` / `current_p50_s` / `ratio` (null for
    /// new cases) / `regressed`, in table order (worst first).
    pub fn to_json(&self) -> Json {
        let cases: Vec<Json> = self
            .cases
            .iter()
            .map(|c| {
                let opt =
                    |v: Option<f64>| v.map_or(Json::Null, Json::Num);
                let mut o = std::collections::BTreeMap::new();
                o.insert("name".to_string(), Json::Str(c.name.clone()));
                o.insert("base_p50_s".to_string(), opt(c.base_p50_s));
                o.insert(
                    "current_p50_s".to_string(),
                    Json::Num(c.current_p50_s),
                );
                o.insert("ratio".to_string(), opt(c.ratio));
                o.insert(
                    "regressed".to_string(),
                    Json::Bool(c.regressed),
                );
                Json::Obj(o)
            })
            .collect();
        let mut root = std::collections::BTreeMap::new();
        root.insert(
            "schema".to_string(),
            Json::Str(COMPARE_SCHEMA.to_string()),
        );
        root.insert("max_ratio".to_string(), Json::Num(self.max_ratio));
        root.insert(
            "calib_scale".to_string(),
            self.calib_scale.map_or(Json::Null, Json::Num),
        );
        root.insert("passed".to_string(), Json::Bool(self.passed()));
        root.insert(
            "missing".to_string(),
            Json::Arr(
                self.missing
                    .iter()
                    .map(|n| Json::Str(n.clone()))
                    .collect(),
            ),
        );
        root.insert("cases".to_string(), Json::Arr(cases));
        Json::Obj(root)
    }

    /// Turn the result into the gate decision (the errors CI greps
    /// for: grid shrinkage, then the regression list).
    pub fn gate(&self) -> Result<()> {
        anyhow::ensure!(
            self.missing.is_empty(),
            "baseline cases missing from the current run (grid \
             shrinkage needs a baseline refresh): {:?}",
            self.missing
        );
        let offenders: Vec<String> = self
            .cases
            .iter()
            .filter(|c| c.regressed)
            .map(|c| {
                format!(
                    "{}: p50 {} vs baseline {} ({:.2}x > {}x)",
                    c.name,
                    fmt_time(c.current_p50_s),
                    fmt_time(c.base_p50_s.unwrap_or(0.0)),
                    c.ratio.unwrap_or(f64::INFINITY),
                    self.max_ratio
                )
            })
            .collect();
        anyhow::ensure!(
            offenders.is_empty(),
            "perf regression gate failed ({} case(s) past {}x):\n  {}",
            offenders.len(),
            self.max_ratio,
            offenders.join("\n  ")
        );
        Ok(())
    }
}

/// Compare two `backpack-bench/v1` files on disk: fail when any case
/// shared by both regressed past `max_ratio`, or when a baseline case
/// vanished from `current` (silent coverage loss). When `report_out`
/// is set, the machine-readable [`CompareReport`] JSON is written
/// there *before* gating, so a failing run still produces the CI
/// artifact. See [`compare_baselines`] for the exact rule;
/// `docs/bench.md` for the CI recipe.
pub fn compare_files(
    baseline: &Path,
    current: &Path,
    max_ratio: f64,
    report_out: Option<&Path>,
) -> Result<()> {
    let read = |p: &Path| -> Result<Json> {
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("read {}", p.display()))?;
        Json::parse(&text)
            .with_context(|| format!("parse {}", p.display()))
    };
    println!(
        "== bench compare: {} (baseline) vs {} (current), \
         max p50 regression {max_ratio}x ==",
        baseline.display(),
        current.display()
    );
    let report =
        compare_report(&read(baseline)?, &read(current)?, max_ratio)?;
    report.print_table();
    if let Some(out) = report_out {
        std::fs::write(out, report.to_json().to_string_json() + "\n")
            .with_context(|| format!("write {}", out.display()))?;
        println!("wrote {}", out.display());
    }
    report.gate()?;
    println!("bench compare OK ({} cases)", report.cases.len());
    Ok(())
}

/// The perf regression gate: for every case of `baseline` (matched to
/// `current` by `name`), fail when the calibration-normalized
/// `current_p50 / baseline_p50` exceeds `max_ratio`. With the
/// machine-speed probe (`calib_s`, [`measure_calibration`]) on both
/// sides, host-speed differences divide out and the gate can sit at
/// the CI default of 1.5x -- tight enough to catch a lost SIMD
/// dispatch or a de-fused conv path, while a uniformly slower runner
/// still passes. Pre-calibration baselines degrade to raw ratios
/// (pick a generous factor by hand for those). Cases only present in
/// `current` are reported but never fail (the grid may grow ahead of
/// a baseline refresh); cases missing *from* `current` fail, so grid
/// shrinkage needs an explicit baseline update.
///
/// This is [`compare_report`] + [`CompareReport::print_table`] +
/// [`CompareReport::gate`]; use the pieces directly to also get the
/// machine-readable result.
pub fn compare_baselines(
    baseline: &Json,
    current: &Json,
    max_ratio: f64,
) -> Result<()> {
    let report = compare_report(baseline, current, max_ratio)?;
    report.print_table();
    report.gate()?;
    println!("bench compare OK ({} cases)", report.cases.len());
    Ok(())
}

/// Build the [`CompareReport`] for two parsed `backpack-bench/v1`
/// documents (no printing, no gating). Errors only on malformed
/// documents or a `--batch` mismatch -- regressions are recorded in
/// the report for [`CompareReport::gate`] to decide on.
pub fn compare_report(
    baseline: &Json,
    current: &Json,
    max_ratio: f64,
) -> Result<CompareReport> {
    // Two comparable document kinds: single-run bench baselines and
    // loadgen serve benchmarks. Both carry `cases[]` rows with
    // `name` + `p50_s`, so the gate logic is shared; mixing the two
    // kinds is refused up front.
    let allowed =
        [BENCH_SCHEMA, crate::serve::SERVEBENCH_SCHEMA];
    let base_schema = baseline.get("schema")?.as_str()?.to_string();
    anyhow::ensure!(
        allowed.contains(&base_schema.as_str()),
        "baseline schema {base_schema:?} is neither \
         {BENCH_SCHEMA:?} nor {:?}",
        crate::serve::SERVEBENCH_SCHEMA
    );
    let cur_schema = current.get("schema")?.as_str()?;
    anyhow::ensure!(
        cur_schema == base_schema,
        "current schema {cur_schema:?} != baseline schema \
         {base_schema:?}; compare like with like"
    );
    // Case names embed the batch (`{model}_{sig}_n{batch}`), so runs
    // at different --batch values share no names; fail that up front
    // with the real cause instead of a misleading per-case
    // missing-from-run error.
    if let (Some(b), Some(c)) =
        (baseline.opt("batch"), current.opt("batch"))
    {
        let (b, c) = (b.as_f64()?, c.as_f64()?);
        anyhow::ensure!(
            b == c,
            "baseline was recorded at --batch {b} but the current \
             run used --batch {c}; rerun with a matching --batch or \
             refresh the baseline (docs/bench.md)"
        );
    }
    // Same idea for loadgen documents: latency percentiles at
    // different client counts are not comparable.
    if let (Some(b), Some(c)) =
        (baseline.opt("clients"), current.opt("clients"))
    {
        let (b, c) = (b.as_f64()?, c.as_f64()?);
        anyhow::ensure!(
            b == c,
            "baseline was recorded at --clients {b} but the \
             current run used --clients {c}; rerun with a matching \
             --clients or refresh the baseline (docs/bench.md)"
        );
    }
    // Machine-speed normalization: when both documents carry the
    // calibration probe ([`measure_calibration`]), divide it out so
    // the gate measures *code* slowdown, not *machine* slowdown.
    //   effective = (cur_p50 / cur_calib) / (base_p50 / base_calib)
    //             = raw_ratio * (base_calib / cur_calib)
    // Either side missing (or non-positive) degrades to raw ratios.
    let calib = |d: &Json| -> Option<f64> {
        d.opt("calib_s")
            .and_then(|v| v.as_f64().ok())
            .filter(|s| *s > 0.0)
    };
    let calib_scale = match (calib(baseline), calib(current)) {
        (Some(b), Some(c)) => Some(b / c),
        _ => None,
    };
    let scale = calib_scale.unwrap_or(1.0);
    let mut base = std::collections::BTreeMap::new();
    for c in baseline.get("cases")?.as_arr()? {
        base.insert(
            c.get("name")?.as_str()?.to_string(),
            c.get("p50_s")?.as_f64()?,
        );
    }
    let mut cases = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    for c in current.get("cases")?.as_arr()? {
        let name = c.get("name")?.as_str()?.to_string();
        let p50 = c.get("p50_s")?.as_f64()?;
        seen.insert(name.clone());
        let base_p50 = base.get(&name).copied();
        let ratio = base_p50.map(|b| p50 / b.max(1e-12) * scale);
        cases.push(CompareCase {
            name,
            base_p50_s: base_p50,
            current_p50_s: p50,
            ratio,
            regressed: ratio.is_some_and(|r| r > max_ratio),
        });
    }
    // Worst ratio first; new cases (no ratio) sort after all matched.
    cases.sort_by(|a, b| {
        let key = |c: &CompareCase| {
            c.ratio.unwrap_or(f64::NEG_INFINITY)
        };
        key(b)
            .partial_cmp(&key(a))
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.name.cmp(&b.name))
    });
    let missing: Vec<String> = base
        .keys()
        .filter(|k| !seen.contains(*k))
        .cloned()
        .collect();
    Ok(CompareReport { max_ratio, calib_scale, cases, missing })
}

/// Schema identifier of the kernel microbench document
/// ([`kernel_microbench`] -> `KERNELBENCH.json`, a CI artifact next
/// to `BENCH_native.json`); bump on any breaking layout change.
pub const KERNELBENCH_SCHEMA: &str = "backpack-kernelbench/v1";

/// One kernel-microbench row: dispatched vs scalar p50 of one matmul
/// variant at one shape.
fn kernel_case(
    kernel: &str,
    n: usize,
    p: usize,
    q: usize,
    dispatched: &Stats,
    scalar: &Stats,
) -> Json {
    let mut o = std::collections::BTreeMap::new();
    o.insert(
        "name".to_string(),
        Json::Str(format!("{kernel}_{n}x{p}x{q}")),
    );
    o.insert("kernel".to_string(), Json::Str(kernel.to_string()));
    o.insert("n".to_string(), Json::Num(n as f64));
    o.insert("p".to_string(), Json::Num(p as f64));
    o.insert("q".to_string(), Json::Num(q as f64));
    o.insert("p50_s".to_string(), Json::Num(dispatched.p50));
    o.insert("scalar_p50_s".to_string(), Json::Num(scalar.p50));
    o.insert(
        "speedup".to_string(),
        Json::Num(scalar.p50 / dispatched.p50.max(1e-12)),
    );
    o.insert(
        "samples".to_string(),
        Json::Num(dispatched.samples.len() as f64),
    );
    Json::Obj(o)
}

/// Time the dispatched inner kernels (SIMD where the host supports
/// it, [`crate::linalg::simd_active`]) against their retained scalar
/// twins over a few shapes that exercise both the 8-lane vector body
/// and the remainder tails, and write the machine-readable summary
/// ([`KERNELBENCH_SCHEMA`]). On a scalar-fallback host the speedups
/// hover around 1.0 -- the document records `simd: false` so the CI
/// artifact stays interpretable; there is deliberately no gate on the
/// speedup (microbench noise on shared runners is not a correctness
/// signal -- the property suite owns correctness, `bench --compare`
/// owns end-to-end perf).
pub fn kernel_microbench(out: &Path) -> Result<()> {
    let simd = crate::linalg::simd_active();
    println!(
        "== kernel microbench: dispatched ({}) vs scalar ==",
        if simd { "simd" } else { "scalar fallback" }
    );
    let start = Instant::now();
    let budget = Duration::from_millis(250);
    // Shapes: one cache-resident cube, one past the 64-wide tile with
    // odd remainders on every axis, one wide-output case stressing
    // the axpy row kernel.
    let shapes = [(64usize, 64usize, 64usize), (96, 83, 70), (40, 33, 200)];
    let fill = |len: usize, m: usize| -> Vec<f32> {
        (0..len).map(|i| (i % m) as f32 * 0.03 - 1.0).collect()
    };
    let mut cases = Vec::new();
    for (n, p, q) in shapes {
        {
            let a = fill(n * p, 17);
            let b = fill(n * q, 13);
            let d = bench(
                &format!("matmul_tn_{n}x{p}x{q}"),
                2,
                200,
                budget,
                || {
                    std::hint::black_box(crate::linalg::matmul_tn(
                        &a, &b, n, p, q,
                    ));
                },
            );
            let s = bench(
                &format!("matmul_tn_{n}x{p}x{q}_scalar"),
                2,
                200,
                budget,
                || {
                    std::hint::black_box(
                        crate::linalg::matmul_tn_scalar(&a, &b, n, p, q),
                    );
                },
            );
            cases.push(kernel_case("matmul_tn", n, p, q, &d, &s));
        }
        {
            let a = fill(p * n, 17);
            let b = fill(q * n, 13);
            let d = bench(
                &format!("matmul_nt_{n}x{p}x{q}"),
                2,
                200,
                budget,
                || {
                    std::hint::black_box(crate::linalg::matmul_nt(
                        &a, &b, p, n, q,
                    ));
                },
            );
            let s = bench(
                &format!("matmul_nt_{n}x{p}x{q}_scalar"),
                2,
                200,
                budget,
                || {
                    std::hint::black_box(
                        crate::linalg::matmul_nt_scalar(&a, &b, p, n, q),
                    );
                },
            );
            cases.push(kernel_case("matmul_nt", n, p, q, &d, &s));
        }
        {
            let a = fill(n * p, 17);
            let b = fill(p * q, 13);
            let d = bench(
                &format!("matmul_{n}x{p}x{q}"),
                2,
                200,
                budget,
                || {
                    std::hint::black_box(crate::linalg::matmul(
                        &a, &b, n, p, q,
                    ));
                },
            );
            let s = bench(
                &format!("matmul_{n}x{p}x{q}_scalar"),
                2,
                200,
                budget,
                || {
                    std::hint::black_box(crate::linalg::matmul_scalar(
                        &a, &b, n, p, q,
                    ));
                },
            );
            cases.push(kernel_case("matmul", n, p, q, &d, &s));
        }
    }
    let n_cases = cases.len();
    let mut root = std::collections::BTreeMap::new();
    root.insert(
        "schema".to_string(),
        Json::Str(KERNELBENCH_SCHEMA.to_string()),
    );
    root.insert("simd".to_string(), Json::Bool(simd));
    root.insert("git_rev".to_string(), Json::Str(git_rev()));
    root.insert(
        "unit".to_string(),
        Json::Str("seconds".to_string()),
    );
    root.insert("calib_s".to_string(), Json::Num(measure_calibration()));
    root.insert(
        "total_wall_s".to_string(),
        Json::Num(start.elapsed().as_secs_f64()),
    );
    root.insert("cases".to_string(), Json::Arr(cases));
    if let Some(dir) = out.parent().filter(|d| !d.as_os_str().is_empty())
    {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(out, Json::Obj(root).to_string_json() + "\n")
        .with_context(|| format!("write {}", out.display()))?;
    println!(
        "wrote {} ({n_cases} cases, {:.1}s)",
        out.display(),
        start.elapsed().as_secs_f64()
    );
    Ok(())
}

/// Git revision for the baseline provenance: `GITHUB_SHA` when CI
/// sets it, else `git rev-parse`, else `"unknown"`. Always truncated
/// to 12 hex chars so CI- and locally-produced baselines compare
/// equal on this field.
pub(crate) fn git_rev() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        let sha = sha.trim();
        if !sha.is_empty() {
            return sha.chars().take(12).collect();
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "--short=12", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_summary() {
        let s = Stats::from_samples(
            "t",
            vec![1.0, 2.0, 3.0, 4.0, 5.0],
        );
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.min, 1.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
    }

    #[test]
    fn bench_runs_and_respects_budget() {
        let mut count = 0;
        let s = bench("noop", 1, 1000, Duration::from_millis(20), || {
            count += 1;
            std::thread::sleep(Duration::from_millis(1));
        });
        assert!(!s.samples.is_empty());
        assert!(count < 1000, "budget should stop early");
    }

    #[test]
    fn budget_truncation_is_visible_in_total() {
        // The budget stops sampling early; total_s must cover the
        // whole measured loop so the truncation is honest in exports.
        let s = bench("b", 0, 1000, Duration::from_millis(10), || {
            std::thread::sleep(Duration::from_millis(4));
        });
        assert!(s.samples.len() < 1000);
        let sum: f64 = s.samples.iter().sum();
        assert!(s.total_s >= sum, "{} < {sum}", s.total_s);
    }

    #[test]
    fn baseline_grid_covers_all_models_and_signatures() {
        let cases = baseline_cases();
        // FC: grad + 10 extensions; conv: grad + 9 (no kfra); plus
        // the dedicated conv-residual case (3c3d_sigmoid diag_h).
        assert_eq!(cases.len(), 2 * 11 + 2 * 10 + 1);
        let has = |m: &str, s: &str| {
            cases
                .iter()
                .any(|c| c.model == m && c.signature == s)
        };
        assert!(has("mlp", "grad"));
        assert!(has("logreg", "kfra"));
        assert!(has("2c2d", "kfac"));
        assert!(has("3c3d", "diag_ggn"));
        // diag_h enters the recorded trajectory on every model.
        assert!(has("logreg", "diag_h"));
        assert!(has("mlp", "diag_h"));
        assert!(has("2c2d", "diag_h"));
        assert!(has("3c3d", "diag_h"));
        assert!(!has("2c2d", "kfra"), "kfra is FC-only");
        assert!(!has("3c3d", "kfra"), "kfra is FC-only");
        // The conv residual path (Fig. 9 walk) is in the trajectory:
        // one 3c3d_sigmoid case, diag_h only, deeply batch-reduced.
        assert!(has("3c3d_sigmoid", "diag_h"));
        assert_eq!(
            cases
                .iter()
                .filter(|c| c.model == "3c3d_sigmoid")
                .count(),
            1
        );
        // Conv cases scale the batch down; their datasets match the
        // model input dims.
        for c in &cases {
            let want = match c.model {
                "2c2d" | "3c3d" => 8,
                "3c3d_sigmoid" => 32,
                _ => 1,
            };
            assert_eq!(c.batch_div, want, "{c:?}");
        }
    }

    #[test]
    fn perf_baseline_writes_parseable_json() {
        let be = crate::backend::native::NativeBackend::with_threads(2);
        let path = std::env::temp_dir()
            .join("backpack_bench_test")
            .join("BENCH_test.json");
        // Reduced grid (full conv cases are release-bench material,
        // not debug-test material); one conv case keeps the
        // dataset-routing + batch_div path covered.
        let grid = [
            BaselineCase {
                model: "logreg",
                dataset: "mnist",
                signature: "grad",
                batch_div: 1,
            },
            BaselineCase {
                model: "mlp",
                dataset: "mnist",
                signature: "variance",
                batch_div: 1,
            },
            BaselineCase {
                model: "2c2d",
                dataset: "fmnist",
                signature: "grad",
                batch_div: 8,
            },
        ];
        perf_baseline_with(&be, 2, 0, true, 8, &grid, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.get("schema").unwrap().as_str().unwrap(),
                   BENCH_SCHEMA);
        assert_eq!(v.get("backend").unwrap().as_str().unwrap(),
                   "native");
        assert_eq!(v.get("threads").unwrap().as_usize().unwrap(), 2);
        assert_eq!(v.get("workers").unwrap().as_usize().unwrap(), 0);
        assert!(v.get("calib_s").unwrap().as_f64().unwrap() > 0.0);
        let cases = v.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), grid.len());
        for c in cases {
            assert!(c.get("mean_s").unwrap().as_f64().unwrap() > 0.0);
            assert!(c.get("p95_s").unwrap().as_f64().unwrap()
                    >= c.get("p50_s").unwrap().as_f64().unwrap()
                       - 1e-12);
            assert!(c.get("samples").unwrap().as_usize().unwrap() >= 1);
            // Every case carries the per-phase p50 breakdown object.
            assert!(c.get("phases").unwrap().as_obj().is_ok());
        }
        // The conv case records its scaled batch (8 / 8 -> min 4).
        let conv = cases
            .iter()
            .find(|c| {
                c.get("model").unwrap().as_str().unwrap() == "2c2d"
            })
            .unwrap();
        assert_eq!(conv.get("batch").unwrap().as_usize().unwrap(), 4);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn perf_baseline_workers_dimension_runs_the_shard_path() {
        let be = crate::backend::native::NativeBackend::with_threads(1);
        let path = std::env::temp_dir()
            .join("backpack_bench_test")
            .join("BENCH_dist_test.json");
        let grid = [BaselineCase {
            model: "logreg",
            dataset: "mnist",
            signature: "batch_grad",
            batch_div: 1,
        }];
        perf_baseline_with(&be, 1, 2, true, 8, &grid, &path).unwrap();
        let v = Json::parse(&std::fs::read_to_string(&path).unwrap())
            .unwrap();
        assert_eq!(v.get("workers").unwrap().as_usize().unwrap(), 2);
        let cases = v.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 1);
        assert!(
            cases[0].get("mean_s").unwrap().as_f64().unwrap() > 0.0
        );
        let _ = std::fs::remove_file(&path);
    }

    /// A minimal `backpack-bench/v1` document for the compare tests.
    fn doc(cases: &[(&str, f64)]) -> Json {
        let mut arr = Vec::new();
        for (name, p50) in cases {
            let mut c = std::collections::BTreeMap::new();
            c.insert("name".to_string(), Json::Str(name.to_string()));
            c.insert("p50_s".to_string(), Json::Num(*p50));
            arr.push(Json::Obj(c));
        }
        let mut root = std::collections::BTreeMap::new();
        root.insert(
            "schema".to_string(),
            Json::Str(BENCH_SCHEMA.to_string()),
        );
        root.insert("cases".to_string(), Json::Arr(arr));
        Json::Obj(root)
    }

    #[test]
    fn compare_passes_within_the_noise_factor() {
        let base = doc(&[("a_grad_n8", 0.010), ("b_grad_n8", 0.020)]);
        // 2x slower and 10x faster both sit inside a 3x gate; a new
        // case without a baseline is reported, not failed.
        let cur = doc(&[
            ("a_grad_n8", 0.020),
            ("b_grad_n8", 0.002),
            ("c_grad_n8", 9.000),
        ]);
        compare_baselines(&base, &cur, 3.0).unwrap();
    }

    #[test]
    fn compare_fails_on_a_synthetic_10x_slowdown() {
        // The acceptance scenario: scale every p50 of the baseline by
        // 10 and present it as the current run -- the 3x gate must
        // trip and name the offender.
        let base = doc(&[("a_grad_n8", 0.010), ("b_kfac_n8", 0.050)]);
        let slow =
            doc(&[("a_grad_n8", 0.100), ("b_kfac_n8", 0.500)]);
        let err = compare_baselines(&base, &slow, 3.0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("regression gate failed"), "{err}");
        assert!(err.contains("a_grad_n8"), "{err}");
        assert!(err.contains("b_kfac_n8"), "{err}");
    }

    #[test]
    fn compare_fails_when_a_baseline_case_vanishes() {
        let base = doc(&[("a_grad_n8", 0.010), ("b_kfac_n8", 0.050)]);
        let cur = doc(&[("a_grad_n8", 0.010)]);
        let err = compare_baselines(&base, &cur, 3.0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("missing from the current run"), "{err}");
        assert!(err.contains("b_kfac_n8"), "{err}");
    }

    #[test]
    fn compare_rejects_mismatched_base_batches_up_front() {
        // Case names embed the batch, so a --batch mismatch would
        // otherwise surface as a bogus "grid shrinkage" failure.
        let with_batch = |batch: f64, p50: f64| -> Json {
            let Json::Obj(mut root) = doc(&[("a_grad_n8", p50)])
            else {
                unreachable!()
            };
            root.insert("batch".to_string(), Json::Num(batch));
            Json::Obj(root)
        };
        let err = compare_baselines(
            &with_batch(128.0, 0.01),
            &with_batch(64.0, 0.01),
            3.0,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("--batch"), "{err}");
        assert!(!err.contains("missing from the current run"), "{err}");
        compare_baselines(
            &with_batch(128.0, 0.01),
            &with_batch(128.0, 0.01),
            3.0,
        )
        .unwrap();
    }

    #[test]
    fn compare_rejects_foreign_schemas() {
        let base = doc(&[("a_grad_n8", 0.010)]);
        let mut bad = std::collections::BTreeMap::new();
        bad.insert(
            "schema".to_string(),
            Json::Str("backpack-bench/v0".to_string()),
        );
        bad.insert("cases".to_string(), Json::Arr(Vec::new()));
        assert!(
            compare_baselines(&base, &Json::Obj(bad), 3.0).is_err()
        );
    }

    /// Rebrand a bench doc as a `backpack-servebench/v1` one.
    fn as_servebench(v: Json) -> Json {
        let Json::Obj(mut root) = v else { unreachable!() };
        root.insert(
            "schema".to_string(),
            Json::Str(crate::serve::SERVEBENCH_SCHEMA.to_string()),
        );
        Json::Obj(root)
    }

    #[test]
    fn compare_gates_servebench_documents_too() {
        // Loadgen documents carry the same cases[] rows, so the
        // gate applies unchanged: within-noise passes, a synthetic
        // 10x latency regression trips it.
        let base =
            as_servebench(doc(&[("loadgen_logreg_e2e_p50", 0.002)]));
        let ok =
            as_servebench(doc(&[("loadgen_logreg_e2e_p50", 0.003)]));
        compare_baselines(&base, &ok, 3.0).unwrap();
        let slow =
            as_servebench(doc(&[("loadgen_logreg_e2e_p50", 0.020)]));
        let err = compare_baselines(&base, &slow, 3.0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("regression gate failed"), "{err}");
    }

    #[test]
    fn compare_rejects_mixed_bench_and_servebench_schemas() {
        let bench = doc(&[("a_grad_n8", 0.010)]);
        let serve = as_servebench(doc(&[("a_grad_n8", 0.010)]));
        let err = compare_baselines(&bench, &serve, 3.0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("like with like"), "{err}");
    }

    #[test]
    fn compare_rejects_mismatched_client_counts_up_front() {
        let with_clients = |n: f64| -> Json {
            let Json::Obj(mut root) = as_servebench(doc(&[(
                "loadgen_logreg_e2e_p50",
                0.002,
            )])) else {
                unreachable!()
            };
            root.insert("clients".to_string(), Json::Num(n));
            Json::Obj(root)
        };
        let err = compare_baselines(
            &with_clients(8.0),
            &with_clients(16.0),
            3.0,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("--clients"), "{err}");
        compare_baselines(
            &with_clients(8.0),
            &with_clients(8.0),
            3.0,
        )
        .unwrap();
    }

    #[test]
    fn compare_files_round_trips_through_disk() {
        let dir = std::env::temp_dir().join("backpack_bench_cmp");
        std::fs::create_dir_all(&dir).unwrap();
        let bp = dir.join("base.json");
        let cp = dir.join("cur.json");
        let base = doc(&[("a_grad_n8", 0.010)]);
        std::fs::write(&bp, base.to_string_json()).unwrap();
        std::fs::write(
            &cp,
            doc(&[("a_grad_n8", 0.012)]).to_string_json(),
        )
        .unwrap();
        compare_files(&bp, &cp, 3.0, None).unwrap();
        std::fs::write(
            &cp,
            doc(&[("a_grad_n8", 0.200)]).to_string_json(),
        )
        .unwrap();
        assert!(compare_files(&bp, &cp, 3.0, None).is_err());
        assert!(compare_files(
            &dir.join("nope.json"), &cp, 3.0, None
        )
        .is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compare_report_sorts_worst_ratio_first() {
        let base = doc(&[
            ("a_grad_n8", 0.010),
            ("b_grad_n8", 0.010),
            ("c_grad_n8", 0.010),
        ]);
        let cur = doc(&[
            ("a_grad_n8", 0.015), // 1.5x
            ("b_grad_n8", 0.040), // 4.0x -> regressed at 3x
            ("c_grad_n8", 0.005), // 0.5x
            ("d_grad_n8", 0.001), // new, no baseline
        ]);
        let r = compare_report(&base, &cur, 3.0).unwrap();
        let order: Vec<&str> =
            r.cases.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(
            order,
            ["b_grad_n8", "a_grad_n8", "c_grad_n8", "d_grad_n8"]
        );
        assert!(r.cases[0].regressed);
        assert!(!r.passed());
        assert!(r.missing.is_empty());
        // New case carries no ratio and never regresses.
        assert_eq!(r.cases[3].ratio, None);
        assert!(!r.cases[3].regressed);
        r.print_table();
    }

    #[test]
    fn compare_report_json_shape() {
        let base = doc(&[("a_grad_n8", 0.010), ("gone_n8", 0.010)]);
        let cur = doc(&[("a_grad_n8", 0.050)]);
        let r = compare_report(&base, &cur, 3.0).unwrap();
        let v = Json::parse(&r.to_json().to_string_json()).unwrap();
        assert_eq!(
            v.get("schema").unwrap().as_str().unwrap(),
            COMPARE_SCHEMA
        );
        assert!(!v.get("passed").unwrap().as_bool().unwrap());
        assert_eq!(
            v.get("missing").unwrap().as_arr().unwrap()[0]
                .as_str()
                .unwrap(),
            "gone_n8"
        );
        let c = &v.get("cases").unwrap().as_arr().unwrap()[0];
        assert_eq!(c.get("name").unwrap().as_str().unwrap(),
                   "a_grad_n8");
        assert!(c.get("regressed").unwrap().as_bool().unwrap());
        assert!(
            (c.get("ratio").unwrap().as_f64().unwrap() - 5.0).abs()
                < 1e-9
        );
        // A passing report says so.
        let ok = compare_report(
            &doc(&[("a_grad_n8", 0.010)]),
            &doc(&[("a_grad_n8", 0.010)]),
            3.0,
        )
        .unwrap();
        assert!(ok.passed());
        assert!(ok
            .to_json()
            .get("passed")
            .unwrap()
            .as_bool()
            .unwrap());
    }

    #[test]
    fn compare_files_writes_report_even_on_failure() {
        let dir = std::env::temp_dir().join("backpack_bench_report");
        std::fs::create_dir_all(&dir).unwrap();
        let bp = dir.join("base.json");
        let cp = dir.join("cur.json");
        let rp = dir.join("compare.json");
        std::fs::write(
            &bp,
            doc(&[("a_grad_n8", 0.010)]).to_string_json(),
        )
        .unwrap();
        std::fs::write(
            &cp,
            doc(&[("a_grad_n8", 0.200)]).to_string_json(),
        )
        .unwrap();
        assert!(compare_files(&bp, &cp, 3.0, Some(&rp)).is_err());
        let v =
            Json::parse(&std::fs::read_to_string(&rp).unwrap())
                .unwrap();
        assert!(!v.get("passed").unwrap().as_bool().unwrap());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn phase_breakdown_reports_phase_medians() {
        let p50s = phase_breakdown(
            || {
                let _sp = crate::obs::span(
                    crate::obs::CAT_PHASE,
                    "forward",
                );
                std::hint::black_box(
                    (0..512).map(|i| i as f64).sum::<f64>(),
                );
            },
            3,
        );
        let fwd = *p50s.get("forward").expect("phase recorded");
        assert!(fwd >= 0.0);
        // (No stronger shape assertion: other tests in this binary
        // may trace engine runs concurrently through the same global
        // recorder, adding phases of their own to the window.)
    }

    /// Attach a `calib_s` machine-speed probe to a bench document.
    fn with_calib(v: Json, calib: f64) -> Json {
        let Json::Obj(mut root) = v else { unreachable!() };
        root.insert("calib_s".to_string(), Json::Num(calib));
        Json::Obj(root)
    }

    #[test]
    fn calibration_probe_is_positive_and_quick() {
        let t = Instant::now();
        let c = measure_calibration();
        assert!(c > 0.0, "{c}");
        // 11 naive 96^3 matmuls; generous ceiling even for debug
        // builds on a loaded runner.
        assert!(t.elapsed().as_secs_f64() < 30.0);
    }

    #[test]
    fn compare_divides_out_a_uniform_machine_slowdown() {
        // Everything doubled -- the per-case p50s AND the calibration
        // probe. That is a slower machine, not slower code; the tight
        // 1.5x gate must pass and the report must say how.
        let base =
            with_calib(doc(&[("a_grad_n8", 0.010)]), 0.001);
        let cur =
            with_calib(doc(&[("a_grad_n8", 0.020)]), 0.002);
        let r = compare_report(&base, &cur, 1.5).unwrap();
        assert_eq!(r.calib_scale, Some(0.5));
        assert!((r.cases[0].ratio.unwrap() - 1.0).abs() < 1e-9);
        assert!(r.passed());
        compare_baselines(&base, &cur, 1.5).unwrap();
    }

    #[test]
    fn calibration_does_not_forgive_code_regressions() {
        // The acceptance self-test scenario with calib on both
        // sides: the p50s scale 10x but the probe does not (same
        // machine, slower code) -- the gate must still trip.
        let base =
            with_calib(doc(&[("a_grad_n8", 0.010)]), 0.001);
        let slow =
            with_calib(doc(&[("a_grad_n8", 0.100)]), 0.001);
        let r = compare_report(&base, &slow, 1.5).unwrap();
        assert_eq!(r.calib_scale, Some(1.0));
        assert!(!r.passed());
        assert!(compare_baselines(&base, &slow, 3.0).is_err());
    }

    #[test]
    fn compare_without_calibration_gates_raw_ratios() {
        // A pre-calibration baseline (or a hand-built document)
        // degrades to raw ratios instead of erroring out.
        let base = doc(&[("a_grad_n8", 0.010)]);
        let cur = with_calib(doc(&[("a_grad_n8", 0.020)]), 0.002);
        let r = compare_report(&base, &cur, 1.5).unwrap();
        assert_eq!(r.calib_scale, None);
        assert!(!r.passed(), "raw 2x must trip a 1.5x gate");
        let v = Json::parse(&r.to_json().to_string_json()).unwrap();
        assert!(matches!(
            v.get("calib_scale").unwrap(),
            Json::Null
        ));
    }

    #[test]
    fn compare_report_json_carries_the_calib_scale() {
        let base =
            with_calib(doc(&[("a_grad_n8", 0.010)]), 0.002);
        let cur =
            with_calib(doc(&[("a_grad_n8", 0.010)]), 0.001);
        let r = compare_report(&base, &cur, 1.5).unwrap();
        let v = Json::parse(&r.to_json().to_string_json()).unwrap();
        assert!(
            (v.get("calib_scale").unwrap().as_f64().unwrap() - 2.0)
                .abs()
                < 1e-9
        );
        // Current machine is 2x faster; raw 1.0x becomes 2.0x and
        // trips the gate -- calibration cuts both ways, which is what
        // keeps a fast dev box from laundering a regression into a
        // baseline refresh.
        assert!(!r.passed());
    }

    #[test]
    fn kernel_microbench_writes_parseable_json() {
        let path = std::env::temp_dir()
            .join("backpack_kernelbench_test")
            .join("KERNELBENCH_test.json");
        kernel_microbench(&path).unwrap();
        let v = Json::parse(&std::fs::read_to_string(&path).unwrap())
            .unwrap();
        assert_eq!(
            v.get("schema").unwrap().as_str().unwrap(),
            KERNELBENCH_SCHEMA
        );
        // simd is an honest bool either way; the artifact stays
        // interpretable on scalar-fallback hosts.
        let _ = v.get("simd").unwrap().as_bool().unwrap();
        assert!(v.get("calib_s").unwrap().as_f64().unwrap() > 0.0);
        let cases = v.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 9, "3 kernels x 3 shapes");
        for c in cases {
            assert!(c.get("p50_s").unwrap().as_f64().unwrap() > 0.0);
            assert!(
                c.get("scalar_p50_s").unwrap().as_f64().unwrap() > 0.0
            );
            assert!(c.get("speedup").unwrap().as_f64().unwrap() > 0.0);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-5).ends_with("µs"));
        assert!(fmt_time(2e-2).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with('s'));
    }
}
