//! Walk-level observability: spans, counters, structured progress
//! (zero dependencies; DESIGN.md §12).
//!
//! The bench layer times a whole [`crate::backend::Exec::run`] from
//! outside; this module sees *inside* it. Three pieces:
//!
//! * a **thread-aware span recorder** -- [`span`] / [`span_with`]
//!   record complete intervals into a per-thread (lock-free) buffer;
//!   [`shard_scope`] tags a `par_map` worker's events with its shard
//!   lane, times the shard's wall-clock, and flushes the worker's
//!   buffer into the global sink at the fork/join boundary, so
//!   recording itself never takes a lock;
//! * **named counters** ([`add`] / [`Counter`]) -- matmul FLOPs,
//!   im2col bytes materialized, per-shard wall-clock, training
//!   divergences, grid-search progress;
//! * a **structured progress helper** ([`progress`] / [`set_quiet`])
//!   replacing the coordinator's ad-hoc `eprintln!` diagnostics, so
//!   serving-mode callers can suppress or scrape them.
//!
//! **Disabled-path cost.** Everything is gated on one relaxed atomic
//! load ([`enabled`]): a disabled [`span`] allocates nothing and
//! returns an inert guard, a disabled [`add`] is a load + branch, and
//! [`shard_scope`] collapses to a direct call. The engine therefore
//! stays instrumented permanently; `--trace FILE` / `--metrics` turn
//! collection on per process (see `main.rs`).
//!
//! Span **categories** keep aggregation honest:
//!
//! * [`CAT_PHASE`] -- non-overlapping engine sections (`setup`,
//!   `forward`, `loss`, `grad_walk`, `sqrt_exact_walk`,
//!   `sqrt_mc_walk`, `shard_hooks`, `reduce`, `finish`). Per lane
//!   they tile the run, so their per-lane sum is comparable to the
//!   measured wall-clock;
//! * [`CAT_EXT`] -- one span per [`crate::Extension`] hook dispatch,
//!   named `{quantity}/{hook}`;
//! * [`CAT_LAYER`] -- per-layer forward spans (`fwd/{li}`), nested
//!   inside the `forward` phase;
//! * [`CAT_DETAIL`] -- nested fine-grain sections (the diag_h
//!   residual-factor propagation), inside a walk phase;
//! * [`CAT_SHARD`] -- one span per `par_map` worker (`shard/{i}`),
//!   the load-imbalance signal;
//! * [`CAT_ENGINE`] -- structural spans that contain others
//!   (`run/{artifact}`, `fork_join`), excluded from totals.

pub mod report;

pub use report::{
    Histogram, MetricsAgg, Trace, HIST_BUCKETS, METRICS_SCHEMA,
    TRACE_SCHEMA,
};

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Non-overlapping engine phases; per-lane sums tile the run.
pub const CAT_PHASE: &str = "phase";
/// Extension hook dispatches, named `{quantity}/{hook}`.
pub const CAT_EXT: &str = "ext";
/// Per-layer forward spans, nested inside the `forward` phase.
pub const CAT_LAYER: &str = "layer";
/// Fine-grain sections nested inside a phase (residual propagation).
pub const CAT_DETAIL: &str = "detail";
/// One span per `par_map` worker: per-shard wall-clock.
pub const CAT_SHARD: &str = "shard";
/// Structural container spans (whole runs, fork/join regions).
pub const CAT_ENGINE: &str = "engine";

/// One recorded complete span (Chrome trace-event `ph: "X"`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Event {
    /// Span name (e.g. `forward`, `diag_ggn/sqrt_ggn`, `shard/2`).
    pub name: String,
    /// Category constant (`CAT_*`), driving aggregation rules.
    pub cat: &'static str,
    /// Worker lane: the `par_map` shard index, 0 on the caller.
    pub lane: usize,
    /// Start offset from the process trace epoch, nanoseconds.
    pub start_ns: u64,
    /// Duration, nanoseconds.
    pub dur_ns: u64,
}

/// Named monotonic counters, accumulated while the recorder is
/// enabled. Fixed set: the hot paths add by enum index, never by
/// string lookup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Counter {
    /// Floating-point operations issued by the `linalg` matmul
    /// kernels (2 x multiply-adds).
    MatmulFlops = 0,
    /// Bytes of unfolded-patch buffer materialized for conv lowering:
    /// the full `[J, P]` matrix for each `im2col` call, or — on the
    /// fused tile-streaming path (DESIGN.md §14), which is what the
    /// conv drivers use — one reusable `[J, COL_TILE]` tile per
    /// driver call, charged at allocation. Fusion therefore shows up
    /// as a large *drop* in this counter for the same workload.
    Im2colBytes = 1,
    /// Summed `par_map` worker wall-clock, nanoseconds.
    ShardNs = 2,
    /// Training runs aborted on a non-finite loss.
    TrainDivergences = 3,
    /// Hyperparameter grid points evaluated.
    GridPoints = 4,
    /// Grid points whose training run returned an error.
    GridFailures = 5,
}

/// Counter names, indexed by the [`Counter`] discriminant -- the keys
/// of the `counters` object in both output schemas.
pub const COUNTER_NAMES: [&str; COUNTER_COUNT] = [
    "matmul_flops",
    "im2col_bytes",
    "shard_ns",
    "train_divergences",
    "grid_points",
    "grid_failures",
];

/// Number of named counters.
pub const COUNTER_COUNT: usize = 6;

static ENABLED: AtomicBool = AtomicBool::new(false);
static QUIET: AtomicBool = AtomicBool::new(false);

struct Sink {
    epoch: Instant,
    events: Mutex<Vec<Event>>,
    counters: [AtomicU64; COUNTER_COUNT],
}

fn sink() -> &'static Sink {
    static SINK: OnceLock<Sink> = OnceLock::new();
    SINK.get_or_init(|| Sink {
        epoch: Instant::now(),
        events: Mutex::new(Vec::new()),
        counters: std::array::from_fn(|_| AtomicU64::new(0)),
    })
}

thread_local! {
    /// Per-thread span buffer: recording pushes here without locking;
    /// [`flush_local`] moves it into the global sink (at `par_map`
    /// join for workers, at drain points for the caller).
    static LOCAL: RefCell<Vec<Event>> = const { RefCell::new(Vec::new()) };
    /// The worker lane events on this thread are tagged with.
    static LANE: Cell<usize> = const { Cell::new(0) };
}

/// Whether the recorder is collecting. One relaxed atomic load: the
/// instrumented hot paths branch on this and nothing else when
/// tracing is off.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Enable collection after clearing previously recorded events and
/// counters: begins a fresh collection region ([`stop`] ends it).
pub fn start() {
    let s = sink();
    flush_local();
    s.events.lock().expect("obs sink").clear();
    for c in &s.counters {
        c.store(0, Ordering::Relaxed);
    }
    ENABLED.store(true, Ordering::Relaxed);
}

/// Enable collection *without* clearing -- for nested measurement
/// regions (the bench per-phase breakdown) that must not destroy a
/// surrounding `--trace` collection. Use [`mark`]/[`since`] to read
/// deltas.
pub fn resume() {
    sink(); // pin the epoch before the first span lands
    ENABLED.store(true, Ordering::Relaxed);
}

/// Disable collection and drain everything recorded since [`start`].
pub fn stop() -> Trace {
    ENABLED.store(false, Ordering::Relaxed);
    flush_local();
    let s = sink();
    let events = std::mem::take(&mut *s.events.lock().expect("obs sink"));
    let counters =
        std::array::from_fn(|i| s.counters[i].load(Ordering::Relaxed));
    Trace { events, counters }
}

/// A position in the recorded stream; [`since`] reads the delta.
pub struct Mark {
    idx: usize,
    counters: [u64; COUNTER_COUNT],
}

/// Snapshot the current recording position (flushes this thread's
/// buffer first). Valid on a disabled recorder: the later [`since`]
/// then returns an empty [`Trace`].
pub fn mark() -> Mark {
    flush_local();
    let s = sink();
    Mark {
        idx: s.events.lock().expect("obs sink").len(),
        counters: std::array::from_fn(|i| {
            s.counters[i].load(Ordering::Relaxed)
        }),
    }
}

/// Everything recorded since `m` (events copied, counters as deltas).
/// All `par_map` forks started after `m` must have joined, so their
/// buffers are already merged. Robust against a concurrent [`stop`]
/// having drained the sink (returns what remains instead of
/// panicking).
pub fn since(m: &Mark) -> Trace {
    flush_local();
    let s = sink();
    let events = s
        .events
        .lock()
        .expect("obs sink")
        .get(m.idx..)
        .map(<[Event]>::to_vec)
        .unwrap_or_default();
    let counters = std::array::from_fn(|i| {
        s.counters[i]
            .load(Ordering::Relaxed)
            .saturating_sub(m.counters[i])
    });
    Trace { events, counters }
}

/// Move this thread's span buffer into the global sink.
pub(crate) fn flush_local() {
    LOCAL.with(|b| {
        let mut b = b.borrow_mut();
        if !b.is_empty() {
            sink()
                .events
                .lock()
                .expect("obs sink")
                .append(&mut b);
        }
    });
}

/// An in-flight span; records one [`Event`] when dropped. Inert (no
/// allocation, no clock read) when the recorder was disabled at
/// creation.
#[must_use = "a span records its interval when dropped"]
pub struct Span(Option<SpanInner>);

struct SpanInner {
    name: String,
    cat: &'static str,
    start: Instant,
}

/// Open a span with a static name. Disabled recorder: returns an
/// inert guard after the single atomic branch.
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> Span {
    if !enabled() {
        return Span(None);
    }
    Span(Some(SpanInner {
        name: name.to_string(),
        cat,
        start: Instant::now(),
    }))
}

/// Open a span whose name is built lazily -- the closure (and its
/// allocation) only runs when the recorder is enabled.
#[inline]
pub fn span_with<F: FnOnce() -> String>(cat: &'static str, f: F) -> Span {
    if !enabled() {
        return Span(None);
    }
    Span(Some(SpanInner { name: f(), cat, start: Instant::now() }))
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(inner) = self.0.take() else { return };
        let s = sink();
        // `duration_since` saturates to zero, so a span opened before
        // the lazily pinned epoch cannot panic.
        let start_ns =
            inner.start.duration_since(s.epoch).as_nanos() as u64;
        let dur_ns = inner.start.elapsed().as_nanos() as u64;
        LOCAL.with(|b| {
            b.borrow_mut().push(Event {
                name: inner.name,
                cat: inner.cat,
                lane: LANE.with(|l| l.get()),
                start_ns,
                dur_ns,
            })
        });
    }
}

/// Add `v` to a named counter (no-op when disabled).
#[inline]
pub fn add(c: Counter, v: u64) {
    if !enabled() {
        return;
    }
    sink().counters[c as usize].fetch_add(v, Ordering::Relaxed);
}

/// Run `f` as `par_map` shard `i`: tag the thread's events with lane
/// `i`, record a `shard/{i}` wall-clock span plus the
/// [`Counter::ShardNs`] total, and flush the thread-local buffer into
/// the global sink on return -- the "merge at join" half of the
/// lock-free recording scheme. Disabled recorder: a direct call.
pub fn shard_scope<T>(i: usize, f: impl FnOnce() -> T) -> T {
    if !enabled() {
        return f();
    }
    let prev = LANE.with(|l| l.replace(i));
    let start = Instant::now();
    let sp = span_with(CAT_SHARD, || format!("shard/{i}"));
    let out = f();
    drop(sp);
    add(Counter::ShardNs, start.elapsed().as_nanos() as u64);
    LANE.with(|l| l.set(prev));
    flush_local();
    out
}

/// Suppress (`true`) or restore (`false`) [`progress`] output.
pub fn set_quiet(q: bool) {
    QUIET.store(q, Ordering::Relaxed);
}

/// Whether progress output is suppressed (`--quiet`).
pub fn quiet() -> bool {
    QUIET.load(Ordering::Relaxed)
}

/// One structured progress line on stderr, suppressed by `--quiet`.
/// The coordinator's diagnostics route through here (paired with a
/// [`Counter`] where the event matters machine-side), so serving-mode
/// callers can silence the human stream without losing the signal.
pub fn progress(args: std::fmt::Arguments<'_>) {
    if !quiet() {
        eprintln!("{args}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_names_cover_every_discriminant() {
        assert_eq!(COUNTER_NAMES.len(), COUNTER_COUNT);
        for (i, c) in [
            Counter::MatmulFlops,
            Counter::Im2colBytes,
            Counter::ShardNs,
            Counter::TrainDivergences,
            Counter::GridPoints,
            Counter::GridFailures,
        ]
        .into_iter()
        .enumerate()
        {
            assert_eq!(c as usize, i);
        }
    }

    #[test]
    fn disabled_span_is_inert() {
        // Other tests may race the global flag; only assert when this
        // thread observes the recorder off for the whole window.
        if enabled() {
            return;
        }
        let sp = span(CAT_PHASE, "nothing");
        assert!(sp.0.is_none());
        drop(sp);
        let sp = span_with(CAT_EXT, || unreachable!("must stay lazy"));
        assert!(sp.0.is_none());
    }

    #[test]
    fn quiet_gates_progress() {
        set_quiet(true);
        assert!(quiet());
        progress(format_args!("suppressed"));
        set_quiet(false);
        assert!(!quiet());
    }
}
