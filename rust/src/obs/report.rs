//! Trace aggregation + the two output schemas
//! (`backpack-trace/v1`, `backpack-metrics/v1`).
//!
//! A [`Trace`] is the drained result of one collection region
//! ([`super::stop`] / [`super::since`]): the recorded events plus the
//! counter deltas. It serializes two ways:
//!
//! * [`Trace::chrome_trace`] -- Chrome trace-event JSON (`ph: "X"`
//!   complete events, microsecond timestamps, one `tid` per worker
//!   lane), loadable in Perfetto / `chrome://tracing`;
//! * [`Trace::metrics`] -- an aggregated per-phase / per-quantity
//!   summary with the paper's Fig.-6-style overhead-vs-grad ratio
//!   attributed to phases.
//!
//! `docs/observability.md` documents both schemas and how to read
//! them; phase spans never overlap within a lane, so per-phase totals
//! are additive (multi-lane runs sum CPU-time-like across shards).

use std::collections::BTreeMap;

use super::{
    Counter, Event, CAT_DETAIL, CAT_EXT, CAT_PHASE, CAT_SHARD,
    COUNTER_COUNT, COUNTER_NAMES,
};
use crate::json::Json;

/// Schema identifier of [`Trace::chrome_trace`] output (stored in
/// `otherData.schema`); bump on any breaking layout change.
pub const TRACE_SCHEMA: &str = "backpack-trace/v1";

/// Schema identifier of [`Trace::metrics`] output; bump on any
/// breaking layout change.
pub const METRICS_SCHEMA: &str = "backpack-metrics/v1";

/// Everything recorded in one collection region.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    /// Recorded spans, in sink (flush) order.
    pub events: Vec<Event>,
    /// Counter values, indexed by the [`Counter`] discriminant.
    pub counters: [u64; COUNTER_COUNT],
}

/// `(count, total seconds)` aggregate of one span name.
pub type SpanTotal = (usize, f64);

impl Trace {
    /// No spans recorded and every counter zero.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.counters.iter().all(|c| *c == 0)
    }

    /// One counter's value.
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    /// Per-phase `(count, total_s)` over [`CAT_PHASE`] spans. Phases
    /// never overlap within a lane, so the totals are additive; on a
    /// multi-lane run they sum across shards (CPU-time-like).
    pub fn phase_totals(&self) -> BTreeMap<String, SpanTotal> {
        self.totals_by(|e| {
            (e.cat == CAT_PHASE).then(|| e.name.clone())
        })
    }

    /// Per-quantity `(count, total_s)` over [`CAT_EXT`] hook spans,
    /// grouped by the quantity name before the `/{hook}` suffix.
    pub fn quantity_totals(&self) -> BTreeMap<String, SpanTotal> {
        self.totals_by(|e| {
            (e.cat == CAT_EXT).then(|| {
                e.name
                    .split_once('/')
                    .map_or(e.name.as_str(), |(q, _)| q)
                    .to_string()
            })
        })
    }

    /// Per-name `(count, total_s)` over [`CAT_DETAIL`] spans (nested
    /// sections like the residual-factor propagation).
    pub fn detail_totals(&self) -> BTreeMap<String, SpanTotal> {
        self.totals_by(|e| {
            (e.cat == CAT_DETAIL).then(|| e.name.clone())
        })
    }

    fn totals_by<F: Fn(&Event) -> Option<String>>(
        &self,
        key: F,
    ) -> BTreeMap<String, SpanTotal> {
        let mut out: BTreeMap<String, SpanTotal> = BTreeMap::new();
        for e in &self.events {
            if let Some(k) = key(e) {
                let t = out.entry(k).or_insert((0, 0.0));
                t.0 += 1;
                t.1 += e.dur_ns as f64 * 1e-9;
            }
        }
        out
    }

    /// Durations (seconds) of every [`CAT_SHARD`] span -- the raw
    /// load-imbalance signal.
    pub fn shard_durations(&self) -> Vec<f64> {
        self.events
            .iter()
            .filter(|e| e.cat == CAT_SHARD)
            .map(|e| e.dur_ns as f64 * 1e-9)
            .collect()
    }

    /// Chrome trace-event JSON ([`TRACE_SCHEMA`]): complete (`"X"`)
    /// events with microsecond `ts`/`dur`, `pid` 1, and the worker
    /// lane as `tid`; counters ride in `otherData`. Load the written
    /// file directly in <https://ui.perfetto.dev> or
    /// `chrome://tracing`.
    pub fn chrome_trace(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                let mut o = BTreeMap::new();
                o.insert("name".into(), Json::Str(e.name.clone()));
                o.insert("cat".into(), Json::Str(e.cat.to_string()));
                o.insert("ph".into(), Json::Str("X".into()));
                o.insert("pid".into(), Json::Num(1.0));
                o.insert("tid".into(), Json::Num(e.lane as f64));
                o.insert(
                    "ts".into(),
                    Json::Num(e.start_ns as f64 * 1e-3),
                );
                o.insert(
                    "dur".into(),
                    Json::Num(e.dur_ns as f64 * 1e-3),
                );
                Json::Obj(o)
            })
            .collect();
        let mut other = BTreeMap::new();
        other.insert(
            "schema".into(),
            Json::Str(TRACE_SCHEMA.to_string()),
        );
        other.insert("counters".into(), self.counters_json());
        let mut root = BTreeMap::new();
        root.insert(
            "displayTimeUnit".into(),
            Json::Str("ms".into()),
        );
        root.insert("otherData".into(), Json::Obj(other));
        root.insert("traceEvents".into(), Json::Arr(events));
        Json::Obj(root)
    }

    /// Aggregated summary ([`METRICS_SCHEMA`]): per-phase and
    /// per-quantity totals, counters, shard balance, and the
    /// Fig.-6-style overhead attribution. `wall_s` is the measured
    /// wall-clock of the collection region (the caller owns that
    /// clock); phase sums on a multi-lane run exceed it by up to the
    /// worker-lane count, like CPU time vs wall time.
    ///
    /// `overhead.grad_s` is the gradient's own pipeline (`forward` +
    /// `loss` + `grad_walk`); `overhead.vs_grad` divides the total
    /// phase time by it -- the in-run analogue of the paper's
    /// "extension time / gradient time" ratio, now attributed to
    /// phases instead of inferred from two separate timings.
    ///
    /// Equivalent to `MetricsAgg::from_trace(self).to_json(wall_s)`
    /// -- long-running callers (the serve daemon) aggregate through
    /// [`MetricsAgg`] instead so events never accumulate.
    pub fn metrics(&self, wall_s: f64) -> Json {
        MetricsAgg::from_trace(self).to_json(wall_s)
    }

    fn counters_json(&self) -> Json {
        counters_json(&self.counters)
    }
}

/// Event-free aggregate of one or more collection regions -- the
/// state behind the [`METRICS_SCHEMA`] summary, separated from the
/// events so a long-running process (the `serve` daemon) can absorb
/// each request's window and drop its events instead of retaining an
/// unbounded span log.
///
/// [`MetricsAgg::from_trace`] aggregates one [`Trace`];
/// [`MetricsAgg::absorb`] merges aggregates (totals add, shard
/// extrema widen); [`MetricsAgg::to_json`] emits the same
/// `backpack-metrics/v1` document as [`Trace::metrics`].
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsAgg {
    /// Per-phase `(count, total_s)` ([`Trace::phase_totals`]).
    pub phases: BTreeMap<String, SpanTotal>,
    /// Per-quantity `(count, total_s)` ([`Trace::quantity_totals`]).
    pub quantities: BTreeMap<String, SpanTotal>,
    /// Per-detail `(count, total_s)` ([`Trace::detail_totals`]).
    pub details: BTreeMap<String, SpanTotal>,
    /// Counter sums, indexed by the [`Counter`] discriminant.
    pub counters: [u64; COUNTER_COUNT],
    /// Number of shard spans observed.
    pub shard_count: usize,
    /// Total seconds across shard spans.
    pub shard_total_s: f64,
    /// Longest shard span (0 when none observed).
    pub shard_max_s: f64,
    /// Shortest shard span (+inf when none observed).
    pub shard_min_s: f64,
}

impl Default for MetricsAgg {
    fn default() -> MetricsAgg {
        MetricsAgg {
            phases: BTreeMap::new(),
            quantities: BTreeMap::new(),
            details: BTreeMap::new(),
            counters: [0; COUNTER_COUNT],
            shard_count: 0,
            shard_total_s: 0.0,
            shard_max_s: 0.0,
            shard_min_s: f64::INFINITY,
        }
    }
}

impl MetricsAgg {
    /// Aggregate one collection region's trace.
    pub fn from_trace(t: &Trace) -> MetricsAgg {
        let shards = t.shard_durations();
        MetricsAgg {
            phases: t.phase_totals(),
            quantities: t.quantity_totals(),
            details: t.detail_totals(),
            counters: t.counters,
            shard_count: shards.len(),
            shard_total_s: shards.iter().sum(),
            shard_max_s: shards.iter().cloned().fold(0.0, f64::max),
            shard_min_s: shards
                .iter()
                .cloned()
                .fold(f64::INFINITY, f64::min),
        }
    }

    /// Nothing observed yet.
    pub fn is_empty(&self) -> bool {
        self.phases.is_empty()
            && self.quantities.is_empty()
            && self.details.is_empty()
            && self.shard_count == 0
            && self.counters.iter().all(|c| *c == 0)
    }

    /// Merge another aggregate into this one: counts and totals add,
    /// shard extrema widen. The daemon calls this once per served
    /// batch, so the running totals stay O(distinct span names).
    pub fn absorb(&mut self, other: &MetricsAgg) {
        let merge = |into: &mut BTreeMap<String, SpanTotal>,
                     from: &BTreeMap<String, SpanTotal>| {
            for (k, (count, total_s)) in from {
                let t = into.entry(k.clone()).or_insert((0, 0.0));
                t.0 += count;
                t.1 += total_s;
            }
        };
        merge(&mut self.phases, &other.phases);
        merge(&mut self.quantities, &other.quantities);
        merge(&mut self.details, &other.details);
        for (c, o) in self.counters.iter_mut().zip(&other.counters) {
            *c += o;
        }
        self.shard_count += other.shard_count;
        self.shard_total_s += other.shard_total_s;
        self.shard_max_s = self.shard_max_s.max(other.shard_max_s);
        self.shard_min_s = self.shard_min_s.min(other.shard_min_s);
    }

    /// The `backpack-metrics/v1` document (see [`Trace::metrics`] for
    /// the field semantics).
    pub fn to_json(&self, wall_s: f64) -> Json {
        let totals_json = |m: &BTreeMap<String, SpanTotal>| {
            Json::Obj(
                m.iter()
                    .map(|(k, (count, total_s))| {
                        let mut o = BTreeMap::new();
                        o.insert(
                            "count".into(),
                            Json::Num(*count as f64),
                        );
                        o.insert(
                            "total_s".into(),
                            Json::Num(*total_s),
                        );
                        (k.clone(), Json::Obj(o))
                    })
                    .collect(),
            )
        };
        let grad_s: f64 = ["forward", "loss", "grad_walk"]
            .iter()
            .filter_map(|p| self.phases.get(*p))
            .map(|t| t.1)
            .sum();
        let total_s: f64 =
            self.phases.values().map(|t| t.1).sum();
        let mut overhead = BTreeMap::new();
        overhead.insert("grad_s".into(), Json::Num(grad_s));
        overhead.insert("total_s".into(), Json::Num(total_s));
        overhead.insert(
            "vs_grad".into(),
            if grad_s > 0.0 {
                Json::Num(total_s / grad_s)
            } else {
                Json::Null
            },
        );

        let mut sh = BTreeMap::new();
        sh.insert(
            "count".into(),
            Json::Num(self.shard_count as f64),
        );
        sh.insert("total_s".into(), Json::Num(self.shard_total_s));
        if self.shard_count > 0 {
            let mean = self.shard_total_s / self.shard_count as f64;
            sh.insert("max_s".into(), Json::Num(self.shard_max_s));
            sh.insert("min_s".into(), Json::Num(self.shard_min_s));
            sh.insert(
                "imbalance".into(),
                if mean > 0.0 {
                    Json::Num(self.shard_max_s / mean)
                } else {
                    Json::Null
                },
            );
        }

        let mut root = BTreeMap::new();
        root.insert(
            "schema".into(),
            Json::Str(METRICS_SCHEMA.to_string()),
        );
        root.insert("wall_s".into(), Json::Num(wall_s));
        root.insert("phases".into(), totals_json(&self.phases));
        root.insert(
            "quantities".into(),
            totals_json(&self.quantities),
        );
        root.insert("details".into(), totals_json(&self.details));
        root.insert("counters".into(), counters_json(&self.counters));
        root.insert("shards".into(), Json::Obj(sh));
        root.insert("overhead".into(), Json::Obj(overhead));
        Json::Obj(root)
    }
}

fn counters_json(counters: &[u64; COUNTER_COUNT]) -> Json {
    Json::Obj(
        COUNTER_NAMES
            .iter()
            .zip(counters.iter())
            .map(|(n, v)| (n.to_string(), Json::Num(*v as f64)))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{CAT_ENGINE, CAT_LAYER};

    fn ev(
        name: &str,
        cat: &'static str,
        lane: usize,
        start_ns: u64,
        dur_ns: u64,
    ) -> Event {
        Event { name: name.to_string(), cat, lane, start_ns, dur_ns }
    }

    /// A deterministic hand-built trace: one two-lane engine call.
    fn sample_trace() -> Trace {
        let mut counters = [0u64; COUNTER_COUNT];
        counters[Counter::MatmulFlops as usize] = 4096;
        counters[Counter::Im2colBytes as usize] = 512;
        counters[Counter::ShardNs as usize] = 9_000;
        Trace {
            events: vec![
                ev("run/mlp_diag_ggn_n8", CAT_ENGINE, 0, 0, 10_000),
                ev("shard/0", CAT_SHARD, 0, 500, 5_000),
                ev("shard/1", CAT_SHARD, 1, 500, 4_000),
                ev("forward", CAT_PHASE, 0, 600, 1_000),
                ev("fwd/0", CAT_LAYER, 0, 650, 400),
                ev("forward", CAT_PHASE, 1, 600, 800),
                ev("loss", CAT_PHASE, 0, 1_700, 200),
                ev("grad_walk", CAT_PHASE, 0, 2_000, 1_800),
                ev("sqrt_exact_walk", CAT_PHASE, 0, 4_000, 1_500),
                ev("residual/propagate", CAT_DETAIL, 0, 4_200, 300),
                ev("diag_ggn/sqrt_ggn", CAT_EXT, 0, 4_400, 250),
                ev("diag_ggn/sqrt_ggn", CAT_EXT, 1, 4_400, 350),
                ev("reduce", CAT_PHASE, 0, 6_000, 400),
                ev("diag_ggn/finish", CAT_EXT, 0, 6_500, 100),
                ev("finish", CAT_PHASE, 0, 6_450, 200),
            ],
            counters,
        }
    }

    #[test]
    fn phase_totals_sum_per_name_across_lanes() {
        let t = sample_trace();
        let p = t.phase_totals();
        let fwd = p.get("forward").unwrap();
        assert_eq!(fwd.0, 2);
        assert!((fwd.1 - 1.8e-6).abs() < 1e-12);
        assert_eq!(p.get("loss").unwrap().0, 1);
        // Layer/detail/ext/engine spans never leak into phases.
        assert!(!p.contains_key("fwd/0"));
        assert!(!p.contains_key("residual/propagate"));
        assert!(p.keys().all(|k| !k.contains('/')), "{p:?}");
    }

    #[test]
    fn quantity_totals_group_hooks_by_extension_name() {
        let t = sample_trace();
        let q = t.quantity_totals();
        let d = q.get("diag_ggn").unwrap();
        assert_eq!(d.0, 3, "sqrt_ggn x2 + finish");
        assert!((d.1 - 700e-9).abs() < 1e-12);
    }

    #[test]
    fn shard_durations_and_counters() {
        let t = sample_trace();
        let sh = t.shard_durations();
        assert_eq!(sh.len(), 2);
        assert_eq!(t.counter(Counter::MatmulFlops), 4096);
        assert_eq!(t.counter(Counter::GridPoints), 0);
        assert!(!t.is_empty());
        assert!(Trace::default().is_empty());
    }

    #[test]
    fn chrome_trace_is_valid_perfetto_json() {
        let t = sample_trace();
        let doc = t.chrome_trace();
        // Round-trips through the parser (what the CI smoke checks).
        let doc =
            Json::parse(&doc.to_string_json()).unwrap();
        assert_eq!(
            doc.get("otherData")
                .unwrap()
                .get("schema")
                .unwrap()
                .as_str()
                .unwrap(),
            TRACE_SCHEMA
        );
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), t.events.len());
        for e in evs {
            assert_eq!(e.get("ph").unwrap().as_str().unwrap(), "X");
            assert_eq!(e.get("pid").unwrap().as_usize().unwrap(), 1);
            assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
            assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            e.get("name").unwrap().as_str().unwrap();
            e.get("cat").unwrap().as_str().unwrap();
            e.get("tid").unwrap().as_usize().unwrap();
        }
        // Microsecond unit: the 10µs engine span serializes as 10.
        let run = evs
            .iter()
            .find(|e| {
                e.get("name").unwrap().as_str().unwrap()
                    == "run/mlp_diag_ggn_n8"
            })
            .unwrap();
        assert!(
            (run.get("dur").unwrap().as_f64().unwrap() - 10.0).abs()
                < 1e-9
        );
    }

    /// Golden shape of the `backpack-metrics/v1` summary: pins the
    /// top-level keys, the per-entry layout, and the overhead
    /// attribution arithmetic.
    #[test]
    fn metrics_summary_golden_shape() {
        let t = sample_trace();
        let m = Json::parse(
            &t.metrics(12.5e-6).to_string_json(),
        )
        .unwrap();
        assert_eq!(
            m.get("schema").unwrap().as_str().unwrap(),
            METRICS_SCHEMA
        );
        let keys: Vec<&str> =
            m.as_obj().unwrap().keys().map(|k| k.as_str()).collect();
        assert_eq!(
            keys,
            [
                "counters", "details", "overhead", "phases",
                "quantities", "schema", "shards", "wall_s"
            ]
        );
        // Per-entry layout: {count, total_s}.
        let fwd = m.get("phases").unwrap().get("forward").unwrap();
        assert_eq!(fwd.get("count").unwrap().as_usize().unwrap(), 2);
        assert!(fwd.get("total_s").unwrap().as_f64().unwrap() > 0.0);
        // Every counter name appears, even when zero.
        let counters = m.get("counters").unwrap().as_obj().unwrap();
        for name in COUNTER_NAMES {
            assert!(counters.contains_key(name), "{name}");
        }
        // Overhead attribution: grad_s = forward + loss + grad_walk
        // = (1000 + 800 + 200 + 1800) ns; total adds the exact walk,
        // reduce and finish.
        let ov = m.get("overhead").unwrap();
        let grad_s = ov.get("grad_s").unwrap().as_f64().unwrap();
        assert!((grad_s - 3.8e-6).abs() < 1e-12);
        let total_s = ov.get("total_s").unwrap().as_f64().unwrap();
        assert!((total_s - 5.9e-6).abs() < 1e-12);
        let ratio = ov.get("vs_grad").unwrap().as_f64().unwrap();
        assert!((ratio - total_s / grad_s).abs() < 1e-12);
        // Shard balance: max 5µs over mean 4.5µs.
        let sh = m.get("shards").unwrap();
        assert_eq!(sh.get("count").unwrap().as_usize().unwrap(), 2);
        let imb = sh.get("imbalance").unwrap().as_f64().unwrap();
        assert!((imb - 5.0 / 4.5).abs() < 1e-9);
        // The event-free aggregate emits the identical document.
        assert_eq!(
            MetricsAgg::from_trace(&t).to_json(12.5e-6).to_string_json(),
            t.metrics(12.5e-6).to_string_json()
        );
        // Empty trace: overhead ratio is null, shards carry count 0.
        let empty = Trace::default().metrics(0.0);
        assert_eq!(empty.get("overhead").unwrap().get("vs_grad")
                       .unwrap(), &Json::Null);
        assert_eq!(
            empty
                .get("shards")
                .unwrap()
                .get("count")
                .unwrap()
                .as_usize()
                .unwrap(),
            0
        );
    }

    /// Window-by-window aggregation (how the serve daemon keeps
    /// totals) must match one big-window aggregation exactly.
    #[test]
    fn metrics_agg_absorb_matches_single_window() {
        let t = sample_trace();
        // Split the trace in two arbitrary windows.
        let (a_ev, b_ev) = t.events.split_at(7);
        let mut ca = [0u64; COUNTER_COUNT];
        ca[Counter::MatmulFlops as usize] = 4000;
        let mut cb = t.counters;
        cb[Counter::MatmulFlops as usize] -= 4000;
        let a = Trace { events: a_ev.to_vec(), counters: ca };
        let b = Trace { events: b_ev.to_vec(), counters: cb };

        let mut agg = MetricsAgg::default();
        assert!(agg.is_empty());
        agg.absorb(&MetricsAgg::from_trace(&a));
        agg.absorb(&MetricsAgg::from_trace(&b));
        assert!(!agg.is_empty());

        let whole = MetricsAgg::from_trace(&t);
        assert_eq!(agg.phases, whole.phases);
        assert_eq!(agg.quantities, whole.quantities);
        assert_eq!(agg.details, whole.details);
        assert_eq!(agg.counters, whole.counters);
        assert_eq!(agg.shard_count, whole.shard_count);
        assert!((agg.shard_total_s - whole.shard_total_s).abs()
            < 1e-15);
        assert_eq!(agg.shard_max_s, whole.shard_max_s);
        assert_eq!(agg.shard_min_s, whole.shard_min_s);
    }
}
