//! Trace aggregation + the two output schemas
//! (`backpack-trace/v1`, `backpack-metrics/v1`).
//!
//! A [`Trace`] is the drained result of one collection region
//! ([`super::stop`] / [`super::since`]): the recorded events plus the
//! counter deltas. It serializes two ways:
//!
//! * [`Trace::chrome_trace`] -- Chrome trace-event JSON (`ph: "X"`
//!   complete events, microsecond timestamps, one `tid` per worker
//!   lane), loadable in Perfetto / `chrome://tracing`;
//! * [`Trace::metrics`] -- an aggregated per-phase / per-quantity
//!   summary with the paper's Fig.-6-style overhead-vs-grad ratio
//!   attributed to phases.
//!
//! `docs/observability.md` documents both schemas and how to read
//! them; phase spans never overlap within a lane, so per-phase totals
//! are additive (multi-lane runs sum CPU-time-like across shards).

use std::collections::BTreeMap;

use super::{
    Counter, Event, CAT_DETAIL, CAT_EXT, CAT_PHASE, CAT_SHARD,
    COUNTER_COUNT, COUNTER_NAMES,
};
use crate::json::Json;

/// Schema identifier of [`Trace::chrome_trace`] output (stored in
/// `otherData.schema`); bump on any breaking layout change.
pub const TRACE_SCHEMA: &str = "backpack-trace/v1";

/// Schema identifier of [`Trace::metrics`] output; bump on any
/// breaking layout change.
pub const METRICS_SCHEMA: &str = "backpack-metrics/v1";

/// Everything recorded in one collection region.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    /// Recorded spans, in sink (flush) order.
    pub events: Vec<Event>,
    /// Counter values, indexed by the [`Counter`] discriminant.
    pub counters: [u64; COUNTER_COUNT],
}

/// `(count, total seconds)` aggregate of one span name.
pub type SpanTotal = (usize, f64);

impl Trace {
    /// No spans recorded and every counter zero.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.counters.iter().all(|c| *c == 0)
    }

    /// One counter's value.
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    /// Per-phase `(count, total_s)` over [`CAT_PHASE`] spans. Phases
    /// never overlap within a lane, so the totals are additive; on a
    /// multi-lane run they sum across shards (CPU-time-like).
    pub fn phase_totals(&self) -> BTreeMap<String, SpanTotal> {
        self.totals_by(|e| {
            (e.cat == CAT_PHASE).then(|| e.name.clone())
        })
    }

    /// Per-quantity `(count, total_s)` over [`CAT_EXT`] hook spans,
    /// grouped by the quantity name before the `/{hook}` suffix.
    pub fn quantity_totals(&self) -> BTreeMap<String, SpanTotal> {
        self.totals_by(|e| {
            (e.cat == CAT_EXT).then(|| {
                e.name
                    .split_once('/')
                    .map_or(e.name.as_str(), |(q, _)| q)
                    .to_string()
            })
        })
    }

    /// Per-name `(count, total_s)` over [`CAT_DETAIL`] spans (nested
    /// sections like the residual-factor propagation).
    pub fn detail_totals(&self) -> BTreeMap<String, SpanTotal> {
        self.totals_by(|e| {
            (e.cat == CAT_DETAIL).then(|| e.name.clone())
        })
    }

    fn totals_by<F: Fn(&Event) -> Option<String>>(
        &self,
        key: F,
    ) -> BTreeMap<String, SpanTotal> {
        let mut out: BTreeMap<String, SpanTotal> = BTreeMap::new();
        for e in &self.events {
            if let Some(k) = key(e) {
                let t = out.entry(k).or_insert((0, 0.0));
                t.0 += 1;
                t.1 += e.dur_ns as f64 * 1e-9;
            }
        }
        out
    }

    /// Durations (seconds) of every [`CAT_SHARD`] span -- the raw
    /// load-imbalance signal.
    pub fn shard_durations(&self) -> Vec<f64> {
        self.events
            .iter()
            .filter(|e| e.cat == CAT_SHARD)
            .map(|e| e.dur_ns as f64 * 1e-9)
            .collect()
    }

    /// Chrome trace-event JSON ([`TRACE_SCHEMA`]): complete (`"X"`)
    /// events with microsecond `ts`/`dur`, `pid` 1, and the worker
    /// lane as `tid`; counters ride in `otherData`. Load the written
    /// file directly in <https://ui.perfetto.dev> or
    /// `chrome://tracing`.
    pub fn chrome_trace(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                let mut o = BTreeMap::new();
                o.insert("name".into(), Json::Str(e.name.clone()));
                o.insert("cat".into(), Json::Str(e.cat.to_string()));
                o.insert("ph".into(), Json::Str("X".into()));
                o.insert("pid".into(), Json::Num(1.0));
                o.insert("tid".into(), Json::Num(e.lane as f64));
                o.insert(
                    "ts".into(),
                    Json::Num(e.start_ns as f64 * 1e-3),
                );
                o.insert(
                    "dur".into(),
                    Json::Num(e.dur_ns as f64 * 1e-3),
                );
                Json::Obj(o)
            })
            .collect();
        let mut other = BTreeMap::new();
        other.insert(
            "schema".into(),
            Json::Str(TRACE_SCHEMA.to_string()),
        );
        other.insert("counters".into(), self.counters_json());
        let mut root = BTreeMap::new();
        root.insert(
            "displayTimeUnit".into(),
            Json::Str("ms".into()),
        );
        root.insert("otherData".into(), Json::Obj(other));
        root.insert("traceEvents".into(), Json::Arr(events));
        Json::Obj(root)
    }

    /// Aggregated summary ([`METRICS_SCHEMA`]): per-phase and
    /// per-quantity totals, counters, shard balance, and the
    /// Fig.-6-style overhead attribution. `wall_s` is the measured
    /// wall-clock of the collection region (the caller owns that
    /// clock); phase sums on a multi-lane run exceed it by up to the
    /// worker-lane count, like CPU time vs wall time.
    ///
    /// `overhead.grad_s` is the gradient's own pipeline (`forward` +
    /// `loss` + `grad_walk`); `overhead.vs_grad` divides the total
    /// phase time by it -- the in-run analogue of the paper's
    /// "extension time / gradient time" ratio, now attributed to
    /// phases instead of inferred from two separate timings.
    ///
    /// Equivalent to `MetricsAgg::from_trace(self).to_json(wall_s)`
    /// -- long-running callers (the serve daemon) aggregate through
    /// [`MetricsAgg`] instead so events never accumulate.
    pub fn metrics(&self, wall_s: f64) -> Json {
        MetricsAgg::from_trace(self).to_json(wall_s)
    }

    fn counters_json(&self) -> Json {
        counters_json(&self.counters)
    }
}

/// Event-free aggregate of one or more collection regions -- the
/// state behind the [`METRICS_SCHEMA`] summary, separated from the
/// events so a long-running process (the `serve` daemon) can absorb
/// each request's window and drop its events instead of retaining an
/// unbounded span log.
///
/// [`MetricsAgg::from_trace`] aggregates one [`Trace`];
/// [`MetricsAgg::absorb`] merges aggregates (totals add, shard
/// extrema widen); [`MetricsAgg::to_json`] emits the same
/// `backpack-metrics/v1` document as [`Trace::metrics`].
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsAgg {
    /// Per-phase `(count, total_s)` ([`Trace::phase_totals`]).
    pub phases: BTreeMap<String, SpanTotal>,
    /// Per-quantity `(count, total_s)` ([`Trace::quantity_totals`]).
    pub quantities: BTreeMap<String, SpanTotal>,
    /// Per-detail `(count, total_s)` ([`Trace::detail_totals`]).
    pub details: BTreeMap<String, SpanTotal>,
    /// Counter sums, indexed by the [`Counter`] discriminant.
    pub counters: [u64; COUNTER_COUNT],
    /// Number of shard spans observed.
    pub shard_count: usize,
    /// Total seconds across shard spans.
    pub shard_total_s: f64,
    /// Longest shard span (0 when none observed).
    pub shard_max_s: f64,
    /// Shortest shard span (+inf when none observed).
    pub shard_min_s: f64,
}

impl Default for MetricsAgg {
    fn default() -> MetricsAgg {
        MetricsAgg {
            phases: BTreeMap::new(),
            quantities: BTreeMap::new(),
            details: BTreeMap::new(),
            counters: [0; COUNTER_COUNT],
            shard_count: 0,
            shard_total_s: 0.0,
            shard_max_s: 0.0,
            shard_min_s: f64::INFINITY,
        }
    }
}

impl MetricsAgg {
    /// Aggregate one collection region's trace.
    pub fn from_trace(t: &Trace) -> MetricsAgg {
        let shards = t.shard_durations();
        MetricsAgg {
            phases: t.phase_totals(),
            quantities: t.quantity_totals(),
            details: t.detail_totals(),
            counters: t.counters,
            shard_count: shards.len(),
            shard_total_s: shards.iter().sum(),
            shard_max_s: shards.iter().cloned().fold(0.0, f64::max),
            shard_min_s: shards
                .iter()
                .cloned()
                .fold(f64::INFINITY, f64::min),
        }
    }

    /// Nothing observed yet.
    pub fn is_empty(&self) -> bool {
        self.phases.is_empty()
            && self.quantities.is_empty()
            && self.details.is_empty()
            && self.shard_count == 0
            && self.counters.iter().all(|c| *c == 0)
    }

    /// Merge another aggregate into this one: counts and totals add,
    /// shard extrema widen. The daemon calls this once per served
    /// batch, so the running totals stay O(distinct span names).
    pub fn absorb(&mut self, other: &MetricsAgg) {
        let merge = |into: &mut BTreeMap<String, SpanTotal>,
                     from: &BTreeMap<String, SpanTotal>| {
            for (k, (count, total_s)) in from {
                let t = into.entry(k.clone()).or_insert((0, 0.0));
                t.0 += count;
                t.1 += total_s;
            }
        };
        merge(&mut self.phases, &other.phases);
        merge(&mut self.quantities, &other.quantities);
        merge(&mut self.details, &other.details);
        for (c, o) in self.counters.iter_mut().zip(&other.counters) {
            *c += o;
        }
        self.shard_count += other.shard_count;
        self.shard_total_s += other.shard_total_s;
        self.shard_max_s = self.shard_max_s.max(other.shard_max_s);
        self.shard_min_s = self.shard_min_s.min(other.shard_min_s);
    }

    /// The `backpack-metrics/v1` document (see [`Trace::metrics`] for
    /// the field semantics).
    pub fn to_json(&self, wall_s: f64) -> Json {
        let totals_json = |m: &BTreeMap<String, SpanTotal>| {
            Json::Obj(
                m.iter()
                    .map(|(k, (count, total_s))| {
                        let mut o = BTreeMap::new();
                        o.insert(
                            "count".into(),
                            Json::Num(*count as f64),
                        );
                        o.insert(
                            "total_s".into(),
                            Json::Num(*total_s),
                        );
                        (k.clone(), Json::Obj(o))
                    })
                    .collect(),
            )
        };
        let grad_s: f64 = ["forward", "loss", "grad_walk"]
            .iter()
            .filter_map(|p| self.phases.get(*p))
            .map(|t| t.1)
            .sum();
        let total_s: f64 =
            self.phases.values().map(|t| t.1).sum();
        let mut overhead = BTreeMap::new();
        overhead.insert("grad_s".into(), Json::Num(grad_s));
        overhead.insert("total_s".into(), Json::Num(total_s));
        overhead.insert(
            "vs_grad".into(),
            if grad_s > 0.0 {
                Json::Num(total_s / grad_s)
            } else {
                Json::Null
            },
        );

        let mut sh = BTreeMap::new();
        sh.insert(
            "count".into(),
            Json::Num(self.shard_count as f64),
        );
        sh.insert("total_s".into(), Json::Num(self.shard_total_s));
        if self.shard_count > 0 {
            let mean = self.shard_total_s / self.shard_count as f64;
            sh.insert("max_s".into(), Json::Num(self.shard_max_s));
            sh.insert("min_s".into(), Json::Num(self.shard_min_s));
            sh.insert(
                "imbalance".into(),
                if mean > 0.0 {
                    Json::Num(self.shard_max_s / mean)
                } else {
                    Json::Null
                },
            );
        }

        let mut root = BTreeMap::new();
        root.insert(
            "schema".into(),
            Json::Str(METRICS_SCHEMA.to_string()),
        );
        root.insert("wall_s".into(), Json::Num(wall_s));
        root.insert("phases".into(), totals_json(&self.phases));
        root.insert(
            "quantities".into(),
            totals_json(&self.quantities),
        );
        root.insert("details".into(), totals_json(&self.details));
        root.insert("counters".into(), counters_json(&self.counters));
        root.insert("shards".into(), Json::Obj(sh));
        root.insert("overhead".into(), Json::Obj(overhead));
        Json::Obj(root)
    }
}

fn counters_json(counters: &[u64; COUNTER_COUNT]) -> Json {
    Json::Obj(
        COUNTER_NAMES
            .iter()
            .zip(counters.iter())
            .map(|(n, v)| (n.to_string(), Json::Num(*v as f64)))
            .collect(),
    )
}

/// Number of fixed buckets in a [`Histogram`]: 8 exact unit buckets
/// for values `0..8`, then 8 sub-buckets per power of two up to the
/// full `u64` range (`(64 - 3) * 8`), so recording never saturates.
pub const HIST_BUCKETS: usize = 8 + 61 * 8;

/// Sub-bucket resolution: values `>= 8` land in buckets of relative
/// width `1 / (8 + m) <= 12.5%`, which bounds the percentile error.
const HIST_SUB_BITS: u32 = 3;

/// A fixed-bucket log-scale histogram of `u64` values (the serve
/// daemon records request-stage latencies in microseconds; the
/// batch-size distribution reuses it with sample counts).
///
/// Design goals, in order:
///
/// * **exact counts** -- every recorded value increments exactly one
///   bucket, plus exact `count`/`sum`/`min`/`max`, so merged and
///   windowed histograms agree to the last event;
/// * **mergeable** -- [`Histogram::merge`] adds bucket counts
///   elementwise and widens the extrema, and is associative and
///   commutative (all-integer state), so per-client histograms fold
///   into fleet totals in any order;
/// * **bounded error percentiles** -- buckets are log-spaced with
///   [`HIST_SUB_BITS`] sub-buckets per octave (values below 8 are
///   exact), so [`Histogram::percentile`] is within 12.5% relative
///   error of the exact order statistic at any rank.
///
/// The rank convention matches
/// [`crate::coordinator::metrics::percentile`]: the target rank is
/// `q * (count - 1)` with linear interpolation, which the tests pin
/// against exact sorts.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

/// Bucket index of a value: identity below 8, then
/// `(octave, 3 mantissa bits)`.
fn bucket_of(v: u64) -> usize {
    if v < 1u64 << HIST_SUB_BITS {
        return v as usize;
    }
    let e = 63 - v.leading_zeros(); // 2^e <= v, e >= 3
    let m = (v >> (e - HIST_SUB_BITS)) & 0x7;
    ((e - HIST_SUB_BITS) as usize) * 8 + m as usize + 8
}

/// Half-open value range `[lo, hi)` of a bucket; the final bucket's
/// upper bound saturates at `u64::MAX` (inclusive there).
fn bucket_bounds(b: usize) -> (u64, u64) {
    if b < 8 {
        return (b as u64, b as u64 + 1);
    }
    let e = (b - 8) as u32 / 8 + HIST_SUB_BITS;
    let m = (b - 8) as u64 % 8;
    let lo = (8 + m) << (e - HIST_SUB_BITS);
    (lo, lo.saturating_add(1u64 << (e - HIST_SUB_BITS)))
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            counts: vec![0; HIST_BUCKETS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Record one value.
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_of(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// No values recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact sum of recorded values (saturating on u64 overflow).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Exact smallest recorded value.
    pub fn min(&self) -> Option<u64> {
        (self.count > 0).then_some(self.min)
    }

    /// Exact largest recorded value.
    pub fn max(&self) -> Option<u64> {
        (self.count > 0).then_some(self.max)
    }

    /// Exact mean of recorded values.
    pub fn mean(&self) -> Option<f64> {
        (self.count > 0)
            .then(|| self.sum as f64 / self.count as f64)
    }

    /// Fold another histogram into this one: bucket counts add,
    /// extrema widen. Associative and commutative.
    pub fn merge(&mut self, other: &Histogram) {
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Approximate percentile at quantile `q` in `[0, 1]`, following
    /// the `coordinator::metrics::percentile` rank convention
    /// (`rank = q * (count - 1)`, linear interpolation). The result
    /// interpolates within the bucket holding the target rank and is
    /// clamped to the exact recorded `[min, max]`, so it is within
    /// one bucket width (<= 12.5% relative) of the exact order
    /// statistic. `None` when empty.
    pub fn percentile(&self, q: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let rank = q.clamp(0.0, 1.0) * (self.count - 1) as f64;
        // The extreme ranks are known exactly.
        if rank <= 0.0 {
            return Some(self.min as f64);
        }
        if rank >= (self.count - 1) as f64 {
            return Some(self.max as f64);
        }
        let mut cum = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if (cum + c) as f64 > rank {
                let (lo, hi) = bucket_bounds(b);
                let frac = (rank - cum as f64) / c as f64;
                let v = lo as f64 + (hi - lo) as f64 * frac;
                return Some(
                    v.clamp(self.min as f64, self.max as f64),
                );
            }
            cum += c;
        }
        Some(self.max as f64)
    }

    /// JSON form: exact `count`/`sum`/`min`/`max`, sparse non-empty
    /// `buckets` as `[index, count]` pairs (ascending), plus derived
    /// `p50`/`p90`/`p95`/`p99` for direct consumption (ignored by
    /// [`Histogram::from_json`], which recomputes them).
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, c)| **c > 0)
            .map(|(b, c)| {
                Json::Arr(vec![
                    Json::Num(b as f64),
                    Json::Num(*c as f64),
                ])
            })
            .collect();
        let opt_num = |v: Option<f64>| match v {
            Some(x) => Json::Num(x),
            None => Json::Null,
        };
        let mut o = BTreeMap::new();
        o.insert("buckets".into(), Json::Arr(buckets));
        o.insert("count".into(), Json::Num(self.count as f64));
        o.insert("sum".into(), Json::Num(self.sum as f64));
        o.insert(
            "min".into(),
            opt_num(self.min().map(|v| v as f64)),
        );
        o.insert(
            "max".into(),
            opt_num(self.max().map(|v| v as f64)),
        );
        o.insert("p50".into(), opt_num(self.percentile(0.50)));
        o.insert("p90".into(), opt_num(self.percentile(0.90)));
        o.insert("p95".into(), opt_num(self.percentile(0.95)));
        o.insert("p99".into(), opt_num(self.percentile(0.99)));
        Json::Obj(o)
    }

    /// Parse the [`Histogram::to_json`] form back; validates bucket
    /// indices and that bucket counts sum to `count`.
    pub fn from_json(v: &Json) -> anyhow::Result<Histogram> {
        use anyhow::ensure;
        let as_u64 = |x: &Json| -> anyhow::Result<u64> {
            let x = x.as_f64()?;
            ensure!(
                x >= 0.0 && x.fract() == 0.0,
                "not a non-negative integer: {x}"
            );
            Ok(x as u64)
        };
        let mut h = Histogram::new();
        for pair in v.get("buckets")?.as_arr()? {
            let pair = pair.as_arr()?;
            ensure!(
                pair.len() == 2,
                "bucket entry must be [index, count]"
            );
            let b = pair[0].as_usize()?;
            ensure!(
                b < HIST_BUCKETS,
                "bucket index {b} out of range"
            );
            h.counts[b] += as_u64(&pair[1])?;
        }
        h.count = as_u64(v.get("count")?)?;
        ensure!(
            h.counts.iter().sum::<u64>() == h.count,
            "bucket counts do not sum to count"
        );
        h.sum = as_u64(v.get("sum")?)?;
        h.min = match v.get("min")? {
            Json::Null => u64::MAX,
            m => as_u64(m)?,
        };
        h.max = match v.get("max")? {
            Json::Null => 0,
            m => as_u64(m)?,
        };
        Ok(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{CAT_ENGINE, CAT_LAYER};

    fn ev(
        name: &str,
        cat: &'static str,
        lane: usize,
        start_ns: u64,
        dur_ns: u64,
    ) -> Event {
        Event { name: name.to_string(), cat, lane, start_ns, dur_ns }
    }

    /// A deterministic hand-built trace: one two-lane engine call.
    fn sample_trace() -> Trace {
        let mut counters = [0u64; COUNTER_COUNT];
        counters[Counter::MatmulFlops as usize] = 4096;
        counters[Counter::Im2colBytes as usize] = 512;
        counters[Counter::ShardNs as usize] = 9_000;
        Trace {
            events: vec![
                ev("run/mlp_diag_ggn_n8", CAT_ENGINE, 0, 0, 10_000),
                ev("shard/0", CAT_SHARD, 0, 500, 5_000),
                ev("shard/1", CAT_SHARD, 1, 500, 4_000),
                ev("forward", CAT_PHASE, 0, 600, 1_000),
                ev("fwd/0", CAT_LAYER, 0, 650, 400),
                ev("forward", CAT_PHASE, 1, 600, 800),
                ev("loss", CAT_PHASE, 0, 1_700, 200),
                ev("grad_walk", CAT_PHASE, 0, 2_000, 1_800),
                ev("sqrt_exact_walk", CAT_PHASE, 0, 4_000, 1_500),
                ev("residual/propagate", CAT_DETAIL, 0, 4_200, 300),
                ev("diag_ggn/sqrt_ggn", CAT_EXT, 0, 4_400, 250),
                ev("diag_ggn/sqrt_ggn", CAT_EXT, 1, 4_400, 350),
                ev("reduce", CAT_PHASE, 0, 6_000, 400),
                ev("diag_ggn/finish", CAT_EXT, 0, 6_500, 100),
                ev("finish", CAT_PHASE, 0, 6_450, 200),
            ],
            counters,
        }
    }

    #[test]
    fn phase_totals_sum_per_name_across_lanes() {
        let t = sample_trace();
        let p = t.phase_totals();
        let fwd = p.get("forward").unwrap();
        assert_eq!(fwd.0, 2);
        assert!((fwd.1 - 1.8e-6).abs() < 1e-12);
        assert_eq!(p.get("loss").unwrap().0, 1);
        // Layer/detail/ext/engine spans never leak into phases.
        assert!(!p.contains_key("fwd/0"));
        assert!(!p.contains_key("residual/propagate"));
        assert!(p.keys().all(|k| !k.contains('/')), "{p:?}");
    }

    #[test]
    fn quantity_totals_group_hooks_by_extension_name() {
        let t = sample_trace();
        let q = t.quantity_totals();
        let d = q.get("diag_ggn").unwrap();
        assert_eq!(d.0, 3, "sqrt_ggn x2 + finish");
        assert!((d.1 - 700e-9).abs() < 1e-12);
    }

    #[test]
    fn shard_durations_and_counters() {
        let t = sample_trace();
        let sh = t.shard_durations();
        assert_eq!(sh.len(), 2);
        assert_eq!(t.counter(Counter::MatmulFlops), 4096);
        assert_eq!(t.counter(Counter::GridPoints), 0);
        assert!(!t.is_empty());
        assert!(Trace::default().is_empty());
    }

    #[test]
    fn chrome_trace_is_valid_perfetto_json() {
        let t = sample_trace();
        let doc = t.chrome_trace();
        // Round-trips through the parser (what the CI smoke checks).
        let doc =
            Json::parse(&doc.to_string_json()).unwrap();
        assert_eq!(
            doc.get("otherData")
                .unwrap()
                .get("schema")
                .unwrap()
                .as_str()
                .unwrap(),
            TRACE_SCHEMA
        );
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), t.events.len());
        for e in evs {
            assert_eq!(e.get("ph").unwrap().as_str().unwrap(), "X");
            assert_eq!(e.get("pid").unwrap().as_usize().unwrap(), 1);
            assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
            assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            e.get("name").unwrap().as_str().unwrap();
            e.get("cat").unwrap().as_str().unwrap();
            e.get("tid").unwrap().as_usize().unwrap();
        }
        // Microsecond unit: the 10µs engine span serializes as 10.
        let run = evs
            .iter()
            .find(|e| {
                e.get("name").unwrap().as_str().unwrap()
                    == "run/mlp_diag_ggn_n8"
            })
            .unwrap();
        assert!(
            (run.get("dur").unwrap().as_f64().unwrap() - 10.0).abs()
                < 1e-9
        );
    }

    /// Golden shape of the `backpack-metrics/v1` summary: pins the
    /// top-level keys, the per-entry layout, and the overhead
    /// attribution arithmetic.
    #[test]
    fn metrics_summary_golden_shape() {
        let t = sample_trace();
        let m = Json::parse(
            &t.metrics(12.5e-6).to_string_json(),
        )
        .unwrap();
        assert_eq!(
            m.get("schema").unwrap().as_str().unwrap(),
            METRICS_SCHEMA
        );
        let keys: Vec<&str> =
            m.as_obj().unwrap().keys().map(|k| k.as_str()).collect();
        assert_eq!(
            keys,
            [
                "counters", "details", "overhead", "phases",
                "quantities", "schema", "shards", "wall_s"
            ]
        );
        // Per-entry layout: {count, total_s}.
        let fwd = m.get("phases").unwrap().get("forward").unwrap();
        assert_eq!(fwd.get("count").unwrap().as_usize().unwrap(), 2);
        assert!(fwd.get("total_s").unwrap().as_f64().unwrap() > 0.0);
        // Every counter name appears, even when zero.
        let counters = m.get("counters").unwrap().as_obj().unwrap();
        for name in COUNTER_NAMES {
            assert!(counters.contains_key(name), "{name}");
        }
        // Overhead attribution: grad_s = forward + loss + grad_walk
        // = (1000 + 800 + 200 + 1800) ns; total adds the exact walk,
        // reduce and finish.
        let ov = m.get("overhead").unwrap();
        let grad_s = ov.get("grad_s").unwrap().as_f64().unwrap();
        assert!((grad_s - 3.8e-6).abs() < 1e-12);
        let total_s = ov.get("total_s").unwrap().as_f64().unwrap();
        assert!((total_s - 5.9e-6).abs() < 1e-12);
        let ratio = ov.get("vs_grad").unwrap().as_f64().unwrap();
        assert!((ratio - total_s / grad_s).abs() < 1e-12);
        // Shard balance: max 5µs over mean 4.5µs.
        let sh = m.get("shards").unwrap();
        assert_eq!(sh.get("count").unwrap().as_usize().unwrap(), 2);
        let imb = sh.get("imbalance").unwrap().as_f64().unwrap();
        assert!((imb - 5.0 / 4.5).abs() < 1e-9);
        // The event-free aggregate emits the identical document.
        assert_eq!(
            MetricsAgg::from_trace(&t).to_json(12.5e-6).to_string_json(),
            t.metrics(12.5e-6).to_string_json()
        );
        // Empty trace: overhead ratio is null, shards carry count 0.
        let empty = Trace::default().metrics(0.0);
        assert_eq!(empty.get("overhead").unwrap().get("vs_grad")
                       .unwrap(), &Json::Null);
        assert_eq!(
            empty
                .get("shards")
                .unwrap()
                .get("count")
                .unwrap()
                .as_usize()
                .unwrap(),
            0
        );
    }

    /// Window-by-window aggregation (how the serve daemon keeps
    /// totals) must match one big-window aggregation exactly.
    #[test]
    fn metrics_agg_absorb_matches_single_window() {
        let t = sample_trace();
        // Split the trace in two arbitrary windows.
        let (a_ev, b_ev) = t.events.split_at(7);
        let mut ca = [0u64; COUNTER_COUNT];
        ca[Counter::MatmulFlops as usize] = 4000;
        let mut cb = t.counters;
        cb[Counter::MatmulFlops as usize] -= 4000;
        let a = Trace { events: a_ev.to_vec(), counters: ca };
        let b = Trace { events: b_ev.to_vec(), counters: cb };

        let mut agg = MetricsAgg::default();
        assert!(agg.is_empty());
        agg.absorb(&MetricsAgg::from_trace(&a));
        agg.absorb(&MetricsAgg::from_trace(&b));
        assert!(!agg.is_empty());

        let whole = MetricsAgg::from_trace(&t);
        assert_eq!(agg.phases, whole.phases);
        assert_eq!(agg.quantities, whole.quantities);
        assert_eq!(agg.details, whole.details);
        assert_eq!(agg.counters, whole.counters);
        assert_eq!(agg.shard_count, whole.shard_count);
        assert!((agg.shard_total_s - whole.shard_total_s).abs()
            < 1e-15);
        assert_eq!(agg.shard_max_s, whole.shard_max_s);
        assert_eq!(agg.shard_min_s, whole.shard_min_s);
    }

    #[test]
    fn histogram_buckets_bound_their_values() {
        // Every probe value must land in a bucket whose [lo, hi)
        // range contains it, and the bucket's own lower bound must
        // map back to the same bucket.
        let probes = [
            0u64,
            1,
            7,
            8,
            9,
            15,
            16,
            17,
            255,
            256,
            1_000,
            123_456,
            u32::MAX as u64,
            1u64 << 50,
            u64::MAX - 1,
            u64::MAX,
        ];
        for &v in &probes {
            let b = bucket_of(v);
            assert!(b < HIST_BUCKETS, "{v} -> bucket {b}");
            let (lo, hi) = bucket_bounds(b);
            assert!(
                lo <= v && (v < hi || hi == u64::MAX),
                "{v} outside bucket {b} = [{lo}, {hi})"
            );
            assert_eq!(bucket_of(lo), b, "lo of bucket {b}");
            // Relative bucket width stays under 12.5% above the
            // exact region.
            if v >= 8 && hi != u64::MAX {
                assert!(
                    (hi - lo) as f64 / lo as f64 <= 0.125 + 1e-12,
                    "bucket {b} too wide: [{lo}, {hi})"
                );
            }
        }
        // Exact region + continuity: 0..16 are one-value buckets.
        for v in 0..16u64 {
            assert_eq!(bucket_bounds(bucket_of(v)), (v, v + 1));
        }
    }

    /// Deterministic log-uniform-ish samples for the histogram
    /// tests (SplitMix64, the repo's stateless PRNG substrate).
    fn hist_samples(seed: u64, n: usize) -> Vec<u64> {
        use crate::data::rng::splitmix64;
        (0..n)
            .map(|i| {
                let r = splitmix64(seed ^ (i as u64).wrapping_mul(31));
                // Spread over ~20 octaves: 1 .. 2^20.
                let octave = r % 20;
                1 + (splitmix64(r) & ((1u64 << octave) | 0xf))
            })
            .collect()
    }

    #[test]
    fn histogram_merge_is_associative_and_commutative() {
        let parts: Vec<Histogram> = (0..3)
            .map(|k| {
                let mut h = Histogram::new();
                for v in hist_samples(k, 257) {
                    h.record(v);
                }
                h
            })
            .collect();
        // (a + b) + c == a + (b + c), exactly (integer state).
        let mut left = parts[0].clone();
        left.merge(&parts[1]);
        left.merge(&parts[2]);
        let mut bc = parts[1].clone();
        bc.merge(&parts[2]);
        let mut right = parts[0].clone();
        right.merge(&bc);
        assert_eq!(left, right);
        // Commutative too.
        let mut swapped = parts[2].clone();
        swapped.merge(&parts[1]);
        swapped.merge(&parts[0]);
        assert_eq!(left, swapped);
        assert_eq!(left.count(), 3 * 257);
        // Merging an empty histogram is the identity.
        let mut id = left.clone();
        id.merge(&Histogram::new());
        assert_eq!(id, left);
    }

    #[test]
    fn histogram_percentiles_track_exact_sort() {
        // The exact reference follows the
        // coordinator::metrics::percentile convention: sort, rank
        // q * (n - 1), linear interpolation between order stats.
        let exact = |sorted: &[u64], q: f64| -> f64 {
            let pos = q * (sorted.len() - 1) as f64;
            let (lo, hi) =
                (pos.floor() as usize, pos.ceil() as usize);
            let (a, b) = (sorted[lo] as f64, sorted[hi] as f64);
            a + (b - a) * (pos - lo as f64)
        };
        for seed in [1u64, 2, 3] {
            let mut vs = hist_samples(seed, 1000);
            let mut h = Histogram::new();
            for &v in &vs {
                h.record(v);
            }
            vs.sort_unstable();
            for q in [0.0, 0.5, 0.9, 0.95, 0.99, 1.0] {
                let want = exact(&vs, q);
                let got = h.percentile(q).unwrap();
                // Bucket width bounds the error at 12.5%; allow a
                // little extra for the cross-bucket interpolation
                // of the exact reference.
                assert!(
                    (got - want).abs() <= 0.2 * want.max(1.0),
                    "seed {seed} q {q}: got {got}, exact {want}"
                );
            }
            assert_eq!(h.percentile(0.0).unwrap(), vs[0] as f64);
            assert_eq!(
                h.percentile(1.0).unwrap(),
                vs[vs.len() - 1] as f64
            );
        }
        // Values below 8 sit in unit buckets: percentiles match the
        // exact convention to the decimal.
        let mut h = Histogram::new();
        for v in 0..8u64 {
            h.record(v);
        }
        assert_eq!(h.percentile(0.5).unwrap(), 3.5);
        assert_eq!(h.percentile(1.0).unwrap(), 7.0);
        // A constant distribution is exact at every quantile.
        let mut c = Histogram::new();
        for _ in 0..100 {
            c.record(4096);
        }
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(c.percentile(q).unwrap(), 4096.0);
        }
        assert!(Histogram::new().percentile(0.5).is_none());
    }

    #[test]
    fn histogram_json_round_trips_through_the_parser() {
        let mut h = Histogram::new();
        for v in hist_samples(7, 500) {
            h.record(v);
        }
        let text = h.to_json().to_string_json();
        let back =
            Histogram::from_json(&Json::parse(&text).unwrap())
                .unwrap();
        assert_eq!(back, h);
        // The serialized form carries usable derived percentiles.
        let v = Json::parse(&text).unwrap();
        assert_eq!(
            v.get("count").unwrap().as_usize().unwrap(),
            500
        );
        let p50 = v.get("p50").unwrap().as_f64().unwrap();
        let p99 = v.get("p99").unwrap().as_f64().unwrap();
        assert!(p50 <= p99);
        // Empty histogram: null extrema/percentiles, still
        // round-trips.
        let empty = Histogram::new();
        let text = empty.to_json().to_string_json();
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.get("min").unwrap(), &Json::Null);
        assert_eq!(v.get("p50").unwrap(), &Json::Null);
        assert_eq!(
            Histogram::from_json(&v).unwrap(),
            empty
        );
        // Corrupt documents are rejected.
        let bad = Json::parse(
            "{\"buckets\":[[0,2]],\"count\":1,\"sum\":0,\
             \"min\":0,\"max\":0}",
        )
        .unwrap();
        assert!(Histogram::from_json(&bad).is_err());
    }
}
