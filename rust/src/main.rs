//! `backpack` -- the Layer-3 coordinator CLI.
//!
//! Subcommands:
//!   list                         show artifacts the backend serves
//!   train    --problem P --opt O train one configuration
//!   serve    [--addr A] [--stdio] batching extraction daemon
//!   worker   [--addr A]          backpack-shard/v1 extraction worker
//!   extract  --problem P [--workers N] one extraction, any topology
//!   bench    [--quick]           machine-readable perf baseline
//!   fig3|fig6|fig8|fig9          timing figure regenerators
//!   fig7a|fig7b|fig10|fig11      optimizer-comparison figures
//!   table3                       problem zoo + parameter checksums
//!   table4   --problem P         grid-search best hyperparameters
//!
//! Everything executes through a pluggable backend (`--backend
//! native|pjrt`, default `native`): the native backend synthesizes
//! pure-Rust training graphs on demand; the pjrt backend (cargo
//! feature `pjrt`) runs AOT artifacts from `artifacts/` (see `make
//! artifacts`). Results land in `results/*.csv`.

use std::path::Path;
use std::time::Instant;

use anyhow::{Context as _, Result};

use backpack_rs::cli::Args;
use backpack_rs::coordinator::gridsearch::GridPreset;
use backpack_rs::coordinator::metrics::write_csv;
use backpack_rs::coordinator::{problems, train, TrainConfig};
use backpack_rs::figures::{curves, tables, timing};
use backpack_rs::optim::Hyper;
use backpack_rs::{open_with, Backend};

const USAGE: &str = "\
usage: backpack SUBCOMMAND [--backend native|pjrt] [--threads N]
                [--trace FILE] [--metrics] [--quiet] [flags]
  list
  train  --problem mnist_logreg --optimizer kfac [--lr 0.01]
         [--damping 0.01] [--steps 200] [--seed 0] [--eval-every 25]
         [--inv-every 1] [--verbose]
  serve  [--addr 127.0.0.1:4417] [--stdio] [--queue-cap 64]
         [--linger-ms 2] [--max-batch 1024] [--max-conns N]
         [--param-cache 16] [--access-log FILE]
  worker [--addr 127.0.0.1:0]
  extract [--problem mnist_logreg] [--extensions grad|a+b+c]
         [--n 32] [--seed 0] [--key A,B] [--workers N]
         [--addrs HOST:PORT,...] [--out EXTRACT.json]
  loadgen [--addr HOST:PORT] [--clients 8] [--duration-s 5]
         [--model logreg] [--sigs grad,diag_ggn] [--per 4]
         [--seed 0] [--linger-ms 2] [--max-batch 1024]
         [--out SERVEBENCH.json]
  bench  [--quick] [--batch 128] [--workers 0]
         [--out BENCH_native.json]
         [--compare BASELINE.json [--current RUN.json]]
         [--compare-out COMPARE.json] [--max-regression 1.5]
         [--kernels [--out KERNELBENCH.json]]
  fig3 | fig6 | fig8 | fig9      [--iters 10]
  fig7a | fig7b | fig10 | fig11  [--grid small|paper]
         [--search-steps N] [--steps N] [--seeds K] [--verbose]
  table3
  table4 --problem mnist_logreg  [--grid paper|small] [...]

The default `native` backend serves every registered problem --
fully-connected (mnist_logreg, mnist_mlp) and convolutional
(fmnist_2c2d, cifar10_3c3d, cifar100_allcnnc) -- and all ten paper
quantities, including fig9's diag_h residual propagation, with zero
external dependencies; it runs batch-parallel on all cores
(`--threads N` or BACKPACK_THREADS=N override; `--threads 1` is the
serial reference). `bench` writes the machine-readable perf baseline
CI uploads on every push; `bench --compare BASELINE.json` gates the
fresh run against a committed baseline (fail when any case's
machine-calibrated p50 ratio passes --max-regression, default 1.5x;
both documents carry a `calib_s` probe so host-speed differences
divide out -- docs/bench.md), adding `--current RUN.json` compares
two existing files without re-running, and `--compare-out
COMPARE.json` writes the machine-readable compare result (written
even when the gate fails). `bench --workers N` routes the cases
through the shard coordinator against N in-process workers, so the
baseline document records the process-parallel overhead trajectory
too. `bench --kernels` times the dispatched
SIMD inner kernels against their retained scalar twins and writes
KERNELBENCH.json (no gate; CI artifact).

`serve` runs the batching extraction daemon (protocol
backpack-serve/v1; docs/serve.md): length-prefixed JSON frames over
TCP (or stdin/stdout with --stdio), coalescing compatible concurrent
requests -- same model, signature, seed, key -- into one sharded
extended-backward call, with a bounded request queue (--queue-cap)
for backpressure and a `metrics` request serving live
backpack-metrics/v1 aggregates plus per-stage latency histograms
(serve.latency). --max-conns caps concurrent connections (rejects
get a server_busy error frame), --access-log appends one
backpack-access/v1 JSON line per request (per-stage micros,
outcome; never silenced by --quiet). Port 0 binds an ephemeral
port; the bound address is printed on the first stdout line. Stop
it with a `shutdown` request or SIGTERM.

`worker` + `extract --workers N` run one extraction data-parallel
across processes (protocol backpack-shard/v1; docs/distributed.md):
the coordinator slices the batch contiguously, each worker runs the
pre-finish engine on its slice, and per-key results merge by the
public reduce contract (Sum accumulate, order-preserving Concat
gather) before `finish` runs once on the coordinator. Without
--addrs the coordinator spawns its workers from this binary and
shuts them down afterwards; with --addrs it drives pre-started
`backpack worker` processes (each prints `backpack-shard/v1
listening on ADDR` on its first stdout line) and leaves them
running. `extract` without --workers runs the same extraction
in-process on --threads.

`loadgen` drives a daemon with N concurrent clients for a fixed
duration and writes a backpack-servebench/v1 document (throughput,
e2e + per-stage latency percentiles, coalescing stats; docs/bench.md).
Without --addr it spawns its own daemon on an ephemeral port. The
output gates under `bench --compare BASELINE.json --current RUN.json`
exactly like single-run baselines.

Observability (any subcommand; docs/observability.md):
  --trace FILE   record walk-level spans and write Chrome trace-event
                 JSON (backpack-trace/v1; load in ui.perfetto.dev)
  --metrics      print an aggregated backpack-metrics/v1 summary
                 (per-phase/per-quantity totals, counters, shard
                 balance, overhead-vs-grad ratio) on stdout
  --quiet        suppress progress diagnostics on stderr
";

fn grid_preset(args: &Args) -> Result<GridPreset> {
    Ok(match args.get_or("grid", "small") {
        "paper" => GridPreset::Paper,
        "small" => GridPreset::Small,
        "tiny" => GridPreset::Tiny,
        other => {
            anyhow::bail!("--grid must be tiny|small|paper, got {other}")
        }
    })
}

fn main() -> Result<()> {
    let args = Args::parse_env()?;
    let out_dir = Path::new("results");
    if args.subcommand.is_empty() || args.has("help") {
        print!("{USAGE}");
        return Ok(());
    }
    let threads = backpack_rs::parallel::resolve_threads(
        args.get_usize("threads", 0)?,
    );
    backpack_rs::obs::set_quiet(args.has("quiet"));
    let trace_path = args.flag("trace").map(std::path::PathBuf::from);
    let want_metrics = args.has("metrics");
    let collecting = trace_path.is_some() || want_metrics;
    if collecting {
        backpack_rs::obs::start();
    }
    let run_started = Instant::now();
    let be = open_with(args.get_or("backend", "native"), threads)?;
    // The subcommand runs through `dispatch` so the trace/metrics
    // below are emitted even when it errors (a partial trace of a
    // failing run is exactly when you want one).
    let outcome = dispatch(&args, be.as_ref(), threads, out_dir);
    if !collecting {
        return outcome;
    }
    let wall_s = run_started.elapsed().as_secs_f64();
    let trace = backpack_rs::obs::stop();
    let emit =
        emit_trace(&trace, trace_path.as_deref(), want_metrics, wall_s);
    outcome.and(emit)
}

/// Write `--trace` / print `--metrics` output from a stopped
/// recording. Runs after `dispatch` even when it errored.
fn emit_trace(
    trace: &backpack_rs::Trace,
    trace_path: Option<&Path>,
    want_metrics: bool,
    wall_s: f64,
) -> Result<()> {
    if let Some(path) = trace_path {
        std::fs::write(
            path,
            trace.chrome_trace().to_string_json() + "\n",
        )?;
        println!(
            "wrote trace {} ({} events)",
            path.display(),
            trace.events.len()
        );
    }
    if want_metrics {
        println!("{}", trace.metrics(wall_s).to_string_json());
    }
    Ok(())
}

fn dispatch(
    args: &Args,
    be: &dyn Backend,
    threads: usize,
    out_dir: &Path,
) -> Result<()> {
    match args.subcommand.as_str() {
        "list" => {
            for name in be.artifact_names() {
                let a = be.spec(&name)?;
                println!(
                    "{name:48} kind={:5} n={:3} outputs={}",
                    a.kind, a.batch_size, a.outputs.len()
                );
            }
        }
        "train" => {
            let problem = problems::by_name(
                args.get_or("problem", "mnist_logreg"))?;
            let optimizer = args
                .flag("optimizer")
                .or_else(|| args.flag("opt"))
                .unwrap_or("sgd");
            let cfg = TrainConfig {
                problem: problem.codename.into(),
                optimizer: optimizer.into(),
                hyper: Hyper {
                    lr: args.get_f32("lr", 0.01)?,
                    damping: args.get_f32("damping", 0.01)?,
                    l2: args.get_f32("l2", 0.0)?,
                },
                steps: args.get_usize("steps", 200)?,
                seed: args.get_u64("seed", 0)?,
                eval_every: args.get_usize("eval-every", 25)?,
                inv_every: args.get_usize("inv-every", 1)?,
                log_every: args.get_usize("log-every", 5)?,
                verbose: args.has("verbose"),
            };
            let log = train::train(be, problem, &cfg)?;
            println!(
                "final train loss {:.4}, test acc {:.3}, \
                 {:.1}s total, {:.1}ms/step exec{}",
                log.final_train_loss(),
                log.final_accuracy(),
                log.wall_time_s,
                log.step_time_s * 1e3,
                if log.diverged { " [DIVERGED]" } else { "" },
            );
            let rows: Vec<Vec<String>> = log
                .train_loss
                .iter()
                .map(|(s, l)| vec![s.to_string(), l.to_string()])
                .collect();
            let path = out_dir.join(format!(
                "train_{}_{}_seed{}.csv",
                cfg.problem, cfg.optimizer, cfg.seed
            ));
            write_csv(&path, "step,train_loss", &rows)?;
            println!("wrote {}", path.display());
        }
        "serve" => {
            // The daemon's scheduler thread owns its own native
            // backend (compiled plans are deliberately not Send);
            // the CLI-opened backend is not used.
            anyhow::ensure!(
                args.get_or("backend", "native") == "native",
                "serve supports the native backend only"
            );
            let cfg = backpack_rs::serve::ServeConfig {
                addr: args
                    .get_or("addr", "127.0.0.1:4417")
                    .to_string(),
                threads,
                queue_cap: args.get_usize("queue-cap", 64)?,
                linger_ms: args.get_u64("linger-ms", 2)?,
                max_batch: args.get_usize("max-batch", 1024)?,
                // When the CLI records (--trace/--metrics), batch
                // windows must not drain the global recorder.
                retain_trace: args.flag("trace").is_some()
                    || args.has("metrics"),
                max_conns: args.get_usize("max-conns", 0)?,
                param_cache: args.get_usize("param-cache", 16)?,
                access_log: args
                    .flag("access-log")
                    .map(std::path::PathBuf::from),
            };
            if args.has("stdio") {
                backpack_rs::serve::run_stdio(cfg)?;
            } else {
                let server = backpack_rs::serve::Server::bind(cfg)?;
                println!(
                    "{} listening on {}",
                    backpack_rs::serve::PROTOCOL_SCHEMA,
                    server.local_addr()
                );
                use std::io::Write as _;
                std::io::stdout().flush()?;
                server.run()?;
            }
        }
        "worker" => {
            anyhow::ensure!(
                args.get_or("backend", "native") == "native",
                "worker supports the native backend only"
            );
            let w = backpack_rs::dist::Worker::bind(
                args.get_or("addr", "127.0.0.1:0"),
                threads,
            )?;
            // The banner is the spawn contract: a coordinator
            // spawning this process parses the address off this
            // line (dist::coordinator).
            println!(
                "{} listening on {}",
                backpack_rs::dist::protocol::SHARD_SCHEMA,
                w.local_addr()
            );
            use std::io::Write as _;
            std::io::stdout().flush()?;
            w.run()?;
        }
        "extract" => {
            anyhow::ensure!(
                args.get_or("backend", "native") == "native",
                "extract supports the native backend only"
            );
            let problem = problems::by_name(
                args.get_or("problem", "mnist_logreg"))?;
            let sig: backpack_rs::Signature =
                args.get_or("extensions", "grad").parse()?;
            let backpack_rs::Signature::Extract(extensions) =
                sig.clone()
            else {
                anyhow::bail!(
                    "--extensions takes extraction quantities \
                     (e.g. batch_grad+variance), not eval"
                );
            };
            let n = args.get_usize("n", 32)?;
            let seed = args.get_u64("seed", 0)?;
            let key = match args.flag("key") {
                Some(v) => {
                    let (a, b) =
                        v.split_once(',').ok_or_else(|| {
                            anyhow::anyhow!("--key takes A,B")
                        })?;
                    Some([a.trim().parse()?, b.trim().parse()?])
                }
                None => None,
            };
            let addrs: Vec<String> = args
                .flag("addrs")
                .map(|s| {
                    s.split(',')
                        .map(|a| a.trim().to_string())
                        .filter(|a| !a.is_empty())
                        .collect()
                })
                .unwrap_or_default();
            let mut workers = args.get_usize("workers", 0)?;
            if workers == 0 && !addrs.is_empty() {
                workers = addrs.len();
            }
            let topology = if workers > 0 {
                backpack_rs::Topology::Workers {
                    n: workers,
                    addrs,
                }
            } else {
                backpack_rs::Topology::local(threads)
            };

            // Spec-derived parameters and a synthetic batch: the
            // same initialization serve and the test suites use, so
            // extractions are comparable across entry points.
            let nb =
                backpack_rs::NativeBackend::with_threads(threads);
            let id = backpack_rs::ArtifactId::new(
                problem.model,
                sig,
                n,
            )?;
            let spec = nb.spec_id(&id)?;
            let params: Vec<backpack_rs::Tensor> =
                train::init_params(&spec, seed)
                    .into_iter()
                    .map(|p| p.tensor)
                    .collect();
            let ds = problem.make_dataset(seed)?;
            let idx: Vec<usize> = (0..n).collect();
            let (xv, yv) = ds.batch(0, &idx);
            let mut x_shape = vec![n];
            x_shape.extend_from_slice(&spec.in_shape);
            let x = backpack_rs::Tensor::from_f32(&x_shape, xv);
            let y = backpack_rs::Tensor::from_i32(&[n], yv);

            let model = nb.model(problem.model)?;
            let opts = backpack_rs::ExtractOptions {
                topology,
                key,
                ..backpack_rs::ExtractOptions::default()
            };
            let t0 = Instant::now();
            let out = model.extended_backward(
                &params, &x, &y, &extensions, &opts,
            )?;
            let wall_s = t0.elapsed().as_secs_f64();
            let loss = out
                .get("loss")
                .and_then(|t| t.f32s().ok())
                .and_then(|v| v.first().copied())
                .unwrap_or(f32::NAN);
            println!(
                "{id}: loss {loss:.4}, {} quantities in {:.1} ms \
                 ({})",
                out.len(),
                wall_s * 1e3,
                if workers > 0 {
                    format!("{workers} worker processes")
                } else {
                    format!("{threads} threads")
                },
            );
            if let Some(path) = args.flag("out") {
                let mut doc = std::collections::BTreeMap::new();
                let s = |v: &str| {
                    backpack_rs::Json::Str(v.to_string())
                };
                doc.insert(
                    "schema".to_string(),
                    s("backpack-extract/v1"),
                );
                doc.insert(
                    "problem".to_string(),
                    s(problem.codename),
                );
                doc.insert("model".to_string(), s(problem.model));
                doc.insert(
                    "artifact".to_string(),
                    s(&id.to_string()),
                );
                doc.insert(
                    "n".to_string(),
                    backpack_rs::Json::Num(n as f64),
                );
                doc.insert(
                    "workers".to_string(),
                    backpack_rs::Json::Num(workers as f64),
                );
                doc.insert(
                    "wall_s".to_string(),
                    backpack_rs::Json::Num(wall_s),
                );
                doc.insert(
                    "quantities".to_string(),
                    backpack_rs::dist::protocol::quantities_to_json(
                        &out,
                    ),
                );
                std::fs::write(
                    path,
                    backpack_rs::Json::Obj(doc).to_string_json()
                        + "\n",
                )?;
                println!("wrote {path}");
            }
        }
        "loadgen" => {
            // The self-spawned daemon (and the probe resolving the
            // signature mix) are native-only, like serve.
            anyhow::ensure!(
                args.get_or("backend", "native") == "native",
                "loadgen supports the native backend only"
            );
            let mut sigs = Vec::new();
            for s in args.get_or("sigs", "grad,diag_ggn").split(',')
            {
                sigs.push(s.trim().parse().with_context(|| {
                    format!("bad --sigs entry {s:?}")
                })?);
            }
            let cfg = backpack_rs::serve::LoadgenConfig {
                addr: args.flag("addr").map(str::to_string),
                clients: args.get_usize("clients", 8)?,
                duration_s: args.get_f32("duration-s", 5.0)? as f64,
                model: args.get_or("model", "logreg").to_string(),
                sigs,
                per: args.get_usize("per", 4)?,
                seed: args.get_u64("seed", 0)?,
                threads,
                linger_ms: args.get_u64("linger-ms", 2)?,
                max_batch: args.get_usize("max-batch", 1024)?,
            };
            let report = backpack_rs::serve::loadgen::run(&cfg)?;
            report.print_table();
            let out = args.get_or("out", "SERVEBENCH.json");
            std::fs::write(
                out,
                report.to_json().to_string_json() + "\n",
            )?;
            println!("wrote {out}");
        }
        "bench" => {
            if args.has("kernels") {
                // Kernel microbench: dispatched (SIMD) vs scalar
                // inner kernels; no gate, artifact only.
                let out = args.get_or("out", "KERNELBENCH.json");
                backpack_rs::bench::kernel_microbench(
                    Path::new(out),
                )?;
                return Ok(());
            }
            let default_out = format!("BENCH_{}.json", be.name());
            let out = args.get_or("out", &default_out);
            let max_ratio =
                args.get_f32("max-regression", 1.5)? as f64;
            let compare_out =
                args.flag("compare-out").map(Path::new);
            if let Some(current) = args.flag("current") {
                // Pure file-vs-file mode: no fresh run.
                let baseline = args.flag("compare").ok_or_else(|| {
                    anyhow::anyhow!(
                        "--current requires --compare BASELINE.json"
                    )
                })?;
                backpack_rs::bench::compare_files(
                    Path::new(baseline),
                    Path::new(current),
                    max_ratio,
                    compare_out,
                )?;
            } else {
                backpack_rs::bench::perf_baseline(
                    be,
                    threads,
                    args.get_usize("workers", 0)?,
                    args.has("quick"),
                    args.get_usize("batch", 128)?,
                    Path::new(out),
                )?;
                if let Some(baseline) = args.flag("compare") {
                    backpack_rs::bench::compare_files(
                        Path::new(baseline),
                        Path::new(out),
                        max_ratio,
                        compare_out,
                    )?;
                }
            }
        }
        "fig3" => timing::fig3(
            be, args.get_usize("iters", 10)?, out_dir)?,
        "fig6" => timing::fig6(
            be, args.get_usize("iters", 10)?, out_dir)?,
        "fig8" => timing::fig8(
            be, args.get_usize("iters", 5)?, out_dir)?,
        "fig9" => timing::fig9(
            be, args.get_usize("iters", 5)?, out_dir)?,
        fig @ ("fig7a" | "fig7b" | "fig10" | "fig11") => {
            let (problem, opts) = curves::figure_spec(fig).unwrap();
            let heavy = fig == "fig7b";
            let budget = curves::CurveBudget {
                preset: grid_preset(args)?,
                search_steps: args.get_usize(
                    "search-steps", if heavy { 30 } else { 60 })?,
                final_steps: args.get_usize(
                    "steps", if heavy { 120 } else { 250 })?,
                seeds: args.get_usize("seeds", if heavy { 2 } else { 3 })?,
                inv_every: args.get_usize(
                    "inv-every", if fig == "fig10" { 1 } else { 10 })?,
            };
            curves::run_curves(be, fig, problem, opts, budget, out_dir,
                               args.has("verbose"))?;
        }
        "table3" => tables::table3(be, out_dir)?,
        "table4" => {
            let problem = args.get_or("problem", "mnist_logreg");
            tables::table4(
                be,
                problem,
                grid_preset(args)?,
                args.get_usize("search-steps", 80)?,
                args.get_usize("steps", 250)?,
                args.get_usize("seeds", 3)?,
                args.get_usize("inv-every", 1)?,
                out_dir,
                args.has("verbose"),
            )?;
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
