//! Diagonal-curvature preconditioned gradient descent (paper Eq. 7):
//!
//!   θ ← θ − α (diag(G) + (λ+η) I)⁻¹ (∇L + ηθ)
//!
//! with diag(G) either the exact GGN diagonal (DiagGGN) or its
//! Monte-Carlo estimate (DiagGGN-MC) -- the elementwise inversion the
//! paper calls "straightforward for the diagonal curvature".

use anyhow::Result;

use super::{Hyper, NamedParam, Optimizer};
use crate::backend::Outputs;

pub struct DiagPrecond {
    h: Hyper,
    curvature: &'static str,
}

impl DiagPrecond {
    pub fn new(h: Hyper, curvature: &'static str) -> DiagPrecond {
        DiagPrecond { h, curvature }
    }
}

impl Optimizer for DiagPrecond {
    fn step(&mut self, params: &mut [NamedParam], out: &Outputs)
        -> Result<()> {
        let damp = self.h.damping + self.h.l2;
        for p in params.iter_mut() {
            let g = out.get(&p.under("grad"))?.f32s()?.to_vec();
            let c = out.get(&p.under(self.curvature))?.f32s()?.to_vec();
            let t = p.tensor.f32s_mut()?;
            for i in 0..t.len() {
                let step = (g[i] + self.h.l2 * t[i])
                    / (c[i].max(0.0) + damp);
                t[i] -= self.h.lr * step;
            }
        }
        Ok(())
    }

    fn ext_signature(&self) -> &'static str {
        self.curvature
    }

    fn name(&self) -> String {
        self.curvature.into()
    }
}
