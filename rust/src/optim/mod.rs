//! Optimizers consuming BackPACK quantities (paper Sec. 4, Appx C.3).
//!
//! Baselines (momentum SGD, Adam) use only the averaged gradient; the
//! preconditioned optimizers implement the paper's naive damped update
//! (Eq. 27) with diagonal curvature (DiagGGN / DiagGGN-MC) or
//! Kronecker-factored curvature (KFAC / KFLR / KFRA) inverted with the
//! Martens-Grosse π-split damping (Eq. 28-29).
pub mod first_order;
pub mod kron;
pub mod precond;

use anyhow::Result;

use crate::backend::Outputs;
use crate::runtime::Tensor;

/// A model parameter: manifest name ("param/{layer}/{w|b}") + value.
#[derive(Debug, Clone)]
pub struct NamedParam {
    pub name: String,
    pub tensor: Tensor,
}

impl NamedParam {
    /// "param/3/w" -> ("3", "w")
    pub fn layer_and_kind(&self) -> (&str, &str) {
        let mut it = self.name.splitn(3, '/');
        let _ = it.next();
        (it.next().unwrap_or(""), it.next().unwrap_or(""))
    }

    /// Matching output name under another prefix, e.g. "grad".
    pub fn under(&self, prefix: &str) -> String {
        let (layer, kind) = self.layer_and_kind();
        format!("{prefix}/{layer}/{kind}")
    }
}

/// Common interface: consume one step's outputs, update parameters.
pub trait Optimizer {
    fn step(&mut self, params: &mut [NamedParam], out: &Outputs)
        -> Result<()>;

    /// Extension signature of the training artifact this optimizer
    /// needs ("grad", "diag_ggn", "kfac", ...).
    fn ext_signature(&self) -> &'static str;

    fn name(&self) -> String;
}

/// Shared hyperparameters (paper Appx C.2 grid tunes lr and damping).
#[derive(Debug, Clone, Copy)]
pub struct Hyper {
    pub lr: f32,
    pub damping: f32,
    /// L2 regularization strength η (Eq. 27); 0 in our runs.
    pub l2: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper { lr: 0.01, damping: 0.01, l2: 0.0 }
    }
}

/// Construct an optimizer by DeepOBS-style name.
pub fn build(name: &str, hyper: Hyper, inv_every: usize)
    -> Result<Box<dyn Optimizer>> {
    Ok(match name {
        "sgd" => Box::new(first_order::Sgd::new(hyper)),
        "momentum" => Box::new(first_order::Momentum::new(hyper, 0.9)),
        "adam" => Box::new(first_order::Adam::new(hyper)),
        "diag_ggn" => Box::new(precond::DiagPrecond::new(
            hyper, "diag_ggn")),
        "diag_ggn_mc" => Box::new(precond::DiagPrecond::new(
            hyper, "diag_ggn_mc")),
        "kfac" => Box::new(kron::KronPrecond::new(hyper, "kfac",
                                                  inv_every)),
        "kflr" => Box::new(kron::KronPrecond::new(hyper, "kflr",
                                                  inv_every)),
        "kfra" => Box::new(kron::KronPrecond::new(hyper, "kfra",
                                                  inv_every)),
        other => anyhow::bail!("unknown optimizer {other:?}"),
    })
}

/// All optimizer names, baselines first (Fig. 7 legend order).
pub const ALL_OPTIMIZERS: &[&str] = &[
    "momentum", "adam", "diag_ggn", "diag_ggn_mc", "kfac", "kflr", "kfra",
];
