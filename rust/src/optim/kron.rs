//! Kronecker-factored preconditioning (KFAC / KFLR / KFRA).
//!
//! Weight blocks use the Martens-Grosse approximate inversion
//! (paper Eq. 28-29): with γ = √(λ+η) and π = √(tr(A)·dim(B) /
//! (dim(A)·tr(B))),
//!
//!   (A ⊗ B + (λ+η) I)⁻¹ ≈ (A + πγ I)⁻¹ ⊗ (B + γ/π I)⁻¹,
//!
//! applied to the weight gradient G_w [out, in·] as
//! `V = (B + γ/π I)⁻¹ · G_w · (A + πγ I)⁻¹` via Cholesky solves.
//! Bias blocks carry their full (small) GGN matrix and are solved
//! exactly: `(B_bias + (λ+η) I)⁻¹ g_b` (paper footnote 7/8).
//!
//! Cholesky factors are recomputed every `inv_every` steps (1 =
//! paper-faithful; the ablation bench measures the tradeoff).

use std::collections::HashMap;

use anyhow::{Context, Result};

use super::{Hyper, NamedParam, Optimizer};
use crate::linalg::{Cholesky, SymMat};
use crate::backend::Outputs;

/// Cholesky with escalating jitter: PSD curvature + damping is PD in
/// exact arithmetic, but f32 accumulation error on near-singular
/// factors (dead units zeroing √GGN rows) can push a pivot to ≤ 0;
/// retrying with 10x/100x/1000x the damping preserves the update's
/// semantics (it interpolates toward plain gradient descent) instead
/// of aborting the run.
fn factor_with_jitter(m: &SymMat, damp: f32) -> Result<Cholesky> {
    let base = damp.max(1e-8);
    let mut last = None;
    for mult in [1.0f32, 10.0, 100.0, 1000.0] {
        match Cholesky::factor(&m.add_diag(base * mult)) {
            Ok(c) => return Ok(c),
            Err(e) => last = Some(e),
        }
    }
    Err(last.expect("at least one attempt"))
}

struct LayerFactors {
    chol_a: Cholesky,
    chol_b: Cholesky,
    chol_bias: Cholesky,
}

pub struct KronPrecond {
    h: Hyper,
    curvature: &'static str,
    inv_every: usize,
    step_count: usize,
    cache: HashMap<String, LayerFactors>,
}

impl KronPrecond {
    pub fn new(h: Hyper, curvature: &'static str, inv_every: usize)
        -> KronPrecond {
        KronPrecond {
            h,
            curvature,
            inv_every: inv_every.max(1),
            step_count: 0,
            cache: HashMap::new(),
        }
    }

    fn refresh_factors(&mut self, layer: &str, out: &Outputs)
        -> Result<()> {
        let gamma = (self.h.damping + self.h.l2).sqrt();
        let a_t = out.get(&format!("{}/{layer}/A", self.curvature))?;
        let b_t = out.get(&format!("{}/{layer}/B", self.curvature))?;
        let bias_t =
            out.get(&format!("{}/{layer}/bias_ggn", self.curvature))?;
        let da = a_t.shape[0];
        let db = b_t.shape[0];
        let a = SymMat::new(da, a_t.f32s()?.to_vec());
        let b = SymMat::new(db, b_t.f32s()?.to_vec());
        // Eq. 29, trace norm. π is clamped: a collapsed factor (e.g.
        // dead ReLUs zeroing the exact √GGN rows) drives tr(B) -> 0,
        // π -> ∞ and the B-side damping γ/π -> 0, which would make the
        // Cholesky fail on an exactly singular matrix. Standard KFAC
        // implementations clamp π the same way.
        let tr_a = a.trace().max(1e-12);
        let tr_b = b.trace().max(1e-12);
        let pi = ((tr_a * db as f32) / (da as f32 * tr_b))
            .sqrt()
            .clamp(1e-3, 1e3);
        let chol_a = factor_with_jitter(&a, pi * gamma)
            .with_context(|| format!("A factor, layer {layer}"))?;
        let chol_b = factor_with_jitter(&b, gamma / pi)
            .with_context(|| format!("B factor, layer {layer}"))?;
        let bias = SymMat::new(bias_t.shape[0], bias_t.f32s()?.to_vec());
        let chol_bias =
            factor_with_jitter(&bias, self.h.damping + self.h.l2)
                .with_context(|| format!("bias GGN, layer {layer}"))?;
        self.cache.insert(
            layer.to_string(),
            LayerFactors { chol_a, chol_b, chol_bias },
        );
        Ok(())
    }
}

impl Optimizer for KronPrecond {
    fn step(&mut self, params: &mut [NamedParam], out: &Outputs)
        -> Result<()> {
        let refresh = self.step_count % self.inv_every == 0;
        self.step_count += 1;
        for p in params.iter_mut() {
            let (layer, kind) = {
                let (l, k) = p.layer_and_kind();
                (l.to_string(), k.to_string())
            };
            if kind == "w" && (refresh || !self.cache.contains_key(&layer))
            {
                self.refresh_factors(&layer, out)?;
            }
            let g = out.get(&p.under("grad"))?.f32s()?.to_vec();
            let factors = self
                .cache
                .get(&layer)
                .context("factors must exist after refresh")?;
            let t = p.tensor.f32s_mut()?;
            // regularized gradient
            let mut v: Vec<f32> = g
                .iter()
                .zip(t.iter())
                .map(|(gi, wi)| gi + self.h.l2 * wi)
                .collect();
            if kind == "w" {
                // weight [out, a_dim...] flattened row-major: rows = out
                let db = factors.chol_b.n;
                let da = factors.chol_a.n;
                anyhow::ensure!(
                    v.len() == db * da,
                    "weight grad {} != {}x{}", v.len(), db, da
                );
                factors.chol_b.solve_mat_left(&mut v, da);
                factors.chol_a.solve_mat_right(&mut v, db);
            } else {
                factors.chol_bias.solve_vec(&mut v);
            }
            for i in 0..t.len() {
                t[i] -= self.h.lr * v[i];
            }
        }
        Ok(())
    }

    fn ext_signature(&self) -> &'static str {
        self.curvature
    }

    fn name(&self) -> String {
        self.curvature.into()
    }
}
