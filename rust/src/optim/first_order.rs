//! First-order baselines: SGD, momentum SGD, Adam (the DeepOBS
//! baselines of Figs. 7, 10, 11).

use anyhow::Result;

use super::{Hyper, NamedParam, Optimizer};
use crate::backend::Outputs;

/// Plain SGD: θ ← θ − α(∇L + ηθ).
pub struct Sgd {
    h: Hyper,
}

impl Sgd {
    pub fn new(h: Hyper) -> Sgd {
        Sgd { h }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [NamedParam], out: &Outputs)
        -> Result<()> {
        for p in params.iter_mut() {
            let g = out.get(&p.under("grad"))?.f32s()?.to_vec();
            let t = p.tensor.f32s_mut()?;
            for (w, gi) in t.iter_mut().zip(&g) {
                *w -= self.h.lr * (gi + self.h.l2 * *w);
            }
        }
        Ok(())
    }

    fn ext_signature(&self) -> &'static str {
        "grad"
    }

    fn name(&self) -> String {
        "sgd".into()
    }
}

/// Heavy-ball momentum (DeepOBS baseline, ρ = 0.9).
pub struct Momentum {
    h: Hyper,
    rho: f32,
    velocity: Vec<Vec<f32>>,
}

impl Momentum {
    pub fn new(h: Hyper, rho: f32) -> Momentum {
        Momentum { h, rho, velocity: Vec::new() }
    }
}

impl Optimizer for Momentum {
    fn step(&mut self, params: &mut [NamedParam], out: &Outputs)
        -> Result<()> {
        if self.velocity.is_empty() {
            self.velocity = params
                .iter()
                .map(|p| vec![0.0; p.tensor.numel()])
                .collect();
        }
        for (p, v) in params.iter_mut().zip(&mut self.velocity) {
            let g = out.get(&p.under("grad"))?.f32s()?.to_vec();
            let t = p.tensor.f32s_mut()?;
            for i in 0..t.len() {
                v[i] = self.rho * v[i] + g[i] + self.h.l2 * t[i];
                t[i] -= self.h.lr * v[i];
            }
        }
        Ok(())
    }

    fn ext_signature(&self) -> &'static str {
        "grad"
    }

    fn name(&self) -> String {
        "momentum".into()
    }
}

/// Adam (Kingma & Ba, 2015) with the DeepOBS default
/// (β₁, β₂) = (0.9, 0.999), ε = 1e-8.
pub struct Adam {
    h: Hyper,
    beta1: f32,
    beta2: f32,
    eps: f32,
    t: i32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(h: Hyper) -> Adam {
        Adam {
            h,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [NamedParam], out: &Outputs)
        -> Result<()> {
        if self.m.is_empty() {
            self.m = params
                .iter()
                .map(|p| vec![0.0; p.tensor.numel()])
                .collect();
            self.v = self.m.clone();
        }
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t);
        let bc2 = 1.0 - self.beta2.powi(self.t);
        for (pi, p) in params.iter_mut().enumerate() {
            let g = out.get(&p.under("grad"))?.f32s()?.to_vec();
            let t = p.tensor.f32s_mut()?;
            let (m, v) = (&mut self.m[pi], &mut self.v[pi]);
            for i in 0..t.len() {
                let gi = g[i] + self.h.l2 * t[i];
                m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * gi;
                v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * gi * gi;
                let mhat = m[i] / bc1;
                let vhat = v[i] / bc2;
                t[i] -= self.h.lr * mhat / (vhat.sqrt() + self.eps);
            }
        }
        Ok(())
    }

    fn ext_signature(&self) -> &'static str {
        "grad"
    }

    fn name(&self) -> String {
        "adam".into()
    }
}
