//! Shared length-prefix frame codec — the one wire substrate every
//! channel in this crate speaks.
//!
//! Three protocols ride this codec today, each with its own
//! version-tagged schema name announced in its startup banner /
//! record header:
//!
//! * `backpack-serve/v1` — the extraction daemon
//!   ([`crate::serve::protocol`]);
//! * `backpack-access/v1` — its structured access log (JSONL, no
//!   frames, but the same tensor encoding);
//! * `backpack-shard/v1` — the process-parallel shard channel between
//!   the distributed coordinator and `backpack worker` processes
//!   ([`crate::dist::protocol`]).
//!
//! Keeping the codec here means serve and the shard protocol cannot
//! drift: one frame layout, one size cap, one EOF contract.
//!
//! # Frame layout
//!
//! Every message — both directions — is one frame:
//!
//! ```text
//! +----+----+----+----+----------------------+
//! | length (u32, big-endian)  | payload      |
//! +----+----+----+----+----------------------+
//!   4 bytes                     `length` bytes, UTF-8 JSON
//! ```
//!
//! Frames larger than [`MAX_FRAME`] are rejected **before** the
//! payload allocation (a malformed length prefix must not make a
//! server allocate gigabytes). A clean EOF *between* frames — zero
//! bytes read before any length byte — is `Ok(None)`: the peer closed
//! the session. EOF *inside* a frame (mid-prefix or mid-payload) is
//! always an error; a half-written frame is corruption, not a close.
//!
//! # Tensor encoding
//!
//! Tensors cross every channel as `{"shape": [...], "data": [...]}`
//! with non-finite values encoded as `null` (JSON has no NaN) and
//! decoded back to NaN. Finite `f32` payloads survive the
//! f32 → f64 → shortest-decimal → f64 → f32 round trip bitwise (the
//! widening is exact and Rust prints shortest-round-trip decimals) —
//! which is what lets the distributed equivalence suite demand
//! bitwise Concat rows across process boundaries.

use std::collections::BTreeMap;
use std::io::{ErrorKind, Read, Write};

use anyhow::{bail, ensure, Context, Result};

use crate::json::Json;
use crate::runtime::Tensor;

/// Maximum frame payload size (64 MiB): caps the allocation a length
/// prefix can demand.
pub const MAX_FRAME: usize = 1 << 26;

/// Read one frame. `Ok(None)` is a clean EOF before any length byte
/// (the peer closed between frames); EOF inside a frame errors.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<String>> {
    let mut len = [0u8; 4];
    let mut got = 0usize;
    while got < len.len() {
        match r.read(&mut len[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => bail!("EOF inside a frame length prefix"),
            Ok(k) => got += k,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    let n = u32::from_be_bytes(len) as usize;
    ensure!(
        n <= MAX_FRAME,
        "frame of {n} bytes exceeds the {MAX_FRAME}-byte limit"
    );
    let mut payload = vec![0u8; n];
    r.read_exact(&mut payload)
        .context("EOF inside a frame payload")?;
    Ok(Some(String::from_utf8(payload).context("frame is not UTF-8")?))
}

/// Write one frame (length prefix + payload) and flush.
pub fn write_frame<W: Write>(w: &mut W, payload: &str) -> Result<()> {
    ensure!(
        payload.len() <= MAX_FRAME,
        "frame of {} bytes exceeds the {MAX_FRAME}-byte limit",
        payload.len()
    );
    w.write_all(&(payload.len() as u32).to_be_bytes())?;
    w.write_all(payload.as_bytes())?;
    w.flush()?;
    Ok(())
}

/// f64 -> JSON number, with non-finite values as `null` (decoded
/// back to NaN). f32 payloads survive the f32 -> f64 -> shortest
/// decimal -> f64 -> f32 round trip bitwise (the widening is exact
/// and Rust prints shortest-round-trip decimals).
pub fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

/// `{"shape": [...], "data": [...]}` for an output tensor.
pub fn tensor_to_json(t: &Tensor) -> Json {
    let mut o = BTreeMap::new();
    o.insert(
        "shape".into(),
        Json::Arr(
            t.shape.iter().map(|d| Json::Num(*d as f64)).collect(),
        ),
    );
    let data: Vec<Json> = if let Ok(f) = t.f32s() {
        f.iter().map(|v| num_or_null(*v as f64)).collect()
    } else if let Ok(i) = t.i32s() {
        i.iter().map(|v| Json::Num(*v as f64)).collect()
    } else {
        t.u32s()
            .expect("f32|i32|u32 tensor")
            .iter()
            .map(|v| Json::Num(*v as f64))
            .collect()
    };
    o.insert("data".into(), Json::Arr(data));
    Json::Obj(o)
}

/// Parse a `{"shape": [...], "data": [...]}` tensor (always f32 on
/// the way back in; every wire-crossing output is f32).
pub fn tensor_from_json(v: &Json) -> Result<Tensor> {
    let shape: Vec<usize> = v
        .get("shape")?
        .as_arr()?
        .iter()
        .map(|d| d.as_usize())
        .collect::<Result<_>>()?;
    let data: Vec<f32> = v
        .get("data")?
        .as_arr()?
        .iter()
        .map(|e| match e {
            Json::Null => Ok(f32::NAN),
            other => Ok(other.as_f64()? as f32),
        })
        .collect::<Result<_>>()?;
    ensure!(
        shape.iter().product::<usize>() == data.len(),
        "tensor data length {} does not match shape {shape:?}",
        data.len()
    );
    Ok(Tensor::from_f32(&shape, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_round_trip_and_preserve_eof_contract() {
        let mut buf = Vec::new();
        write_frame(&mut buf, "{\"op\":\"handshake\"}").unwrap();
        write_frame(&mut buf, "x").unwrap();
        assert_eq!(&buf[..4], &[0, 0, 0, 18]);
        let mut r = &buf[..];
        assert_eq!(
            read_frame(&mut r).unwrap().unwrap(),
            "{\"op\":\"handshake\"}"
        );
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), "x");
        // Clean EOF between frames is None, not an error.
        assert!(read_frame(&mut r).unwrap().is_none());
        // EOF inside the payload errors.
        let mut r = &buf[..9];
        let err = read_frame(&mut r).unwrap_err().to_string();
        assert!(err.contains("payload"), "{err}");
        // EOF inside the length prefix errors.
        let mut r = &buf[..3];
        let err = read_frame(&mut r).unwrap_err().to_string();
        assert!(err.contains("length prefix"), "{err}");
    }

    #[test]
    fn oversized_frames_are_rejected_on_both_sides() {
        // A hostile length prefix is refused before allocating.
        let huge = (MAX_FRAME as u32 + 1).to_be_bytes();
        let mut r = &huge[..];
        assert!(read_frame(&mut r).is_err());
        // An exactly-at-cap prefix passes the cap check (then fails
        // only because the payload is absent).
        let atcap = (MAX_FRAME as u32).to_be_bytes();
        let mut r = &atcap[..];
        let err = read_frame(&mut r).unwrap_err().to_string();
        assert!(err.contains("payload"), "{err}");
    }

    #[test]
    fn tensors_round_trip_bitwise_through_json() {
        let t = Tensor::from_f32(
            &[5],
            vec![1.5, -3.25e-8, f32::NAN, f32::NEG_INFINITY, 0.0],
        );
        let back = tensor_from_json(&tensor_to_json(&t)).unwrap();
        assert_eq!(back.shape, vec![5]);
        for (u, v) in
            t.f32s().unwrap().iter().zip(back.f32s().unwrap())
        {
            if u.is_finite() {
                assert_eq!(u.to_bits(), v.to_bits());
            } else {
                assert!(v.is_nan());
            }
        }
        assert!(tensor_from_json(
            &Json::parse("{\"shape\":[2],\"data\":[1]}").unwrap()
        )
        .is_err());
    }
}
