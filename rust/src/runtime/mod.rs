//! PJRT runtime layer: manifest-described AOT artifacts, compiled once,
//! executed from the training/benchmark hot path.
pub mod client;
pub mod manifest;
pub mod tensor;

pub use client::{Executable, Outputs, Runtime};
pub use manifest::{ArtifactSpec, Init, Manifest, TensorSpec};
pub use tensor::{numel, Tensor, TensorData};
