//! Runtime substrate: host tensors, the artifact manifest contract,
//! and (behind the `pjrt` cargo feature) the PJRT execution client.
//!
//! `manifest` and `tensor` are backend-agnostic -- the native backend
//! synthesizes `ArtifactSpec`s with the same schema aot.py records --
//! so they build with zero external dependencies. Only `client`
//! touches the `xla` crate.
#[cfg(feature = "pjrt")]
pub mod client;
pub mod manifest;
pub mod tensor;

#[cfg(feature = "pjrt")]
pub use client::{Executable, Runtime};
pub use crate::backend::Outputs;
pub use manifest::{ArtifactSpec, Init, Manifest, TensorSpec};
pub use tensor::{numel, Tensor, TensorData};
