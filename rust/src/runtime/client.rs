//! PJRT runtime: load HLO-text artifacts, compile once, execute many.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `client.compile` -> `execute`.
//! Compiled executables are cached per artifact name for the process
//! lifetime; artifacts are compiled lazily on first use.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::Tensor;

/// Named outputs of one artifact execution.
#[derive(Debug)]
pub struct Outputs {
    map: BTreeMap<String, Tensor>,
    /// Device wall-clock of the execute call (excludes literal upload).
    pub exec_time: Duration,
}

impl Outputs {
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .with_context(|| format!("no output {name:?}"))
    }

    pub fn loss(&self) -> Result<f32> {
        self.get("loss")?.item_f32()
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    /// All outputs under a `prefix/` (e.g. "grad", "kfac"), keyed by the
    /// remainder of the name.
    pub fn with_prefix(&self, prefix: &str) -> BTreeMap<&str, &Tensor> {
        let pat = format!("{prefix}/");
        self.map
            .iter()
            .filter(|(k, _)| k.starts_with(&pat))
            .map(|(k, v)| (&k[pat.len()..], v))
            .collect()
    }
}

/// A compiled artifact bound to its spec.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with inputs in manifest order; returns named outputs.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Outputs> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "artifact {}: got {} inputs, expected {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape != spec.shape {
                bail!(
                    "artifact {} input {}: shape {:?} != expected {:?}",
                    self.spec.name, spec.name, t.shape, spec.shape
                );
            }
            lits.push(t.to_literal()?);
        }
        let start = Instant::now();
        let result = self.exe.execute::<xla::Literal>(&lits)?;
        let root = result[0][0].to_literal_sync()?;
        let exec_time = start.elapsed();
        // aot.py lowers with return_tuple=True: always a tuple root.
        let parts = root.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "artifact {}: got {} outputs, expected {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut map = BTreeMap::new();
        for (lit, spec) in parts.iter().zip(&self.spec.outputs) {
            map.insert(
                spec.name.clone(),
                Tensor::from_literal(lit, &spec.shape, &spec.dtype)?,
            );
        }
        Ok(Outputs { map, exec_time })
    }
}

/// The process-wide runtime: PJRT CPU client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// Open the artifact directory (default: `artifacts/` next to the
    /// workspace root, overridable with `BACKPACK_ARTIFACTS`).
    pub fn open_default() -> Result<Runtime> {
        let dir = std::env::var("BACKPACK_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::open(Path::new(&dir))
    }

    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let executable = Rc::new(Executable { spec, exe });
        self.cache
            .borrow_mut()
            .insert(name.to_string(), executable.clone());
        Ok(executable)
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest.artifacts.keys().cloned().collect()
    }
}
