//! PJRT runtime: load HLO-text artifacts, compile once, execute many.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `client.compile` -> `execute`.
//! Compiled executables are cached per artifact name for the process
//! lifetime; artifacts are compiled lazily on first use.
//!
//! This whole module sits behind the `pjrt` cargo feature; it is one
//! of the two implementations of the `backend::Backend` /
//! `backend::Exec` trait pair (the other is the dependency-free
//! `backend::native`).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::Tensor;
use crate::backend::{Backend, Exec, Outputs};

/// A compiled artifact bound to its spec.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with inputs in manifest order; returns named outputs.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Outputs> {
        crate::backend::validate_inputs(&self.spec, inputs)?;
        let mut lits = Vec::with_capacity(inputs.len());
        for t in inputs {
            lits.push(t.to_literal()?);
        }
        let start = Instant::now();
        let result = self.exe.execute::<xla::Literal>(&lits)?;
        let root = result[0][0].to_literal_sync()?;
        let exec_time = start.elapsed();
        // aot.py lowers with return_tuple=True: always a tuple root.
        let parts = root.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "artifact {}: got {} outputs, expected {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut map = BTreeMap::new();
        for (lit, spec) in parts.iter().zip(&self.spec.outputs) {
            map.insert(
                spec.name.clone(),
                Tensor::from_literal(lit, &spec.shape, &spec.dtype)?,
            );
        }
        Ok(Outputs::new(map, exec_time))
    }
}

impl Exec for Executable {
    fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    fn run(&self, inputs: &[Tensor]) -> Result<Outputs> {
        Executable::run(self, inputs)
    }
}

/// The process-wide runtime: PJRT CPU client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// Open the artifact directory (default: `artifacts/` next to the
    /// workspace root, overridable with `BACKPACK_ARTIFACTS`).
    pub fn open_default() -> Result<Runtime> {
        let dir = std::env::var("BACKPACK_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::open(Path::new(&dir))
    }

    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let executable = Rc::new(Executable { spec, exe });
        self.cache
            .borrow_mut()
            .insert(name.to_string(), executable.clone());
        Ok(executable)
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest.artifacts.keys().cloned().collect()
    }
}

impl Backend for Runtime {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn spec(&self, artifact: &str) -> Result<ArtifactSpec> {
        Ok(self.manifest.get(artifact)?.clone())
    }

    fn load(&self, artifact: &str) -> Result<Rc<dyn Exec>> {
        let exe: Rc<dyn Exec> = Runtime::load(self, artifact)?;
        Ok(exe)
    }

    fn find_train(
        &self,
        model: &str,
        side: usize,
        ext_sig: &str,
        batch: usize,
    ) -> Result<String> {
        Ok(self
            .manifest
            .find_train(model, side, ext_sig, batch)?
            .name
            .clone())
    }

    fn artifact_names(&self) -> Vec<String> {
        Runtime::artifact_names(self)
    }
}
