//! Host-side tensors and conversion to/from PJRT literals.
//!
//! The coordinator keeps model parameters and batches as plain
//! row-major buffers; these cross into XLA as `xla::Literal`s at every
//! `execute` call (the copy is inherent to the PJRT C API on CPU).

use anyhow::{bail, Result};

/// Element payload of a host tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

/// A host tensor: shape + row-major data.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: TensorData::F32(vec![0.0; numel(shape)]),
        }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: TensorData::F32(data) }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(numel(shape), data.len());
        Tensor { shape: shape.to_vec(), data: TensorData::I32(data) }
    }

    pub fn from_u32(shape: &[usize], data: Vec<u32>) -> Tensor {
        assert_eq!(numel(shape), data.len());
        Tensor { shape: shape.to_vec(), data: TensorData::U32(data) }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::from_f32(&[], vec![v])
    }

    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }

    pub fn f32s(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            other => bail!("expected f32 tensor, got {other:?}"),
        }
    }

    pub fn f32s_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn i32s(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            other => bail!("expected i32 tensor, got {other:?}"),
        }
    }

    pub fn u32s(&self) -> Result<&[u32]> {
        match &self.data {
            TensorData::U32(v) => Ok(v),
            other => bail!("expected u32 tensor, got {other:?}"),
        }
    }

    /// Scalar convenience (0-d or 1-element tensors).
    pub fn item_f32(&self) -> Result<f32> {
        let v = self.f32s()?;
        if v.len() != 1 {
            bail!("item_f32 on tensor with {} elements", v.len());
        }
        Ok(v[0])
    }

    /// Convert to an XLA literal of matching element type and shape.
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v),
            TensorData::I32(v) => xla::Literal::vec1(v),
            TensorData::U32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Read back from an XLA literal.
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal, shape: &[usize],
                        dtype: &str) -> Result<Tensor> {
        Ok(match dtype {
            "f32" => Tensor::from_f32(shape, lit.to_vec::<f32>()?),
            "i32" => Tensor::from_i32(shape, lit.to_vec::<i32>()?),
            "u32" => Tensor::from_u32(shape, lit.to_vec::<u32>()?),
            other => bail!("unsupported dtype {other}"),
        })
    }
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel_and_accessors() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.f32s().unwrap().len(), 6);
        assert!(t.i32s().is_err());
    }

    #[test]
    fn scalar_item() {
        assert_eq!(Tensor::scalar_f32(2.5).item_f32().unwrap(), 2.5);
        assert!(Tensor::zeros(&[3]).item_f32().is_err());
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn mismatched_shape_panics() {
        Tensor::from_f32(&[2, 2], vec![1.0; 3]);
    }
}
