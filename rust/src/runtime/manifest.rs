//! Artifact manifest: the contract between `python/compile/aot.py` and
//! this runtime. Parsed with the in-repo JSON substrate.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::json::Json;

/// How a parameter tensor is initialized (mirrors the layer init rules
/// recorded by aot.py so any seed can be materialized Rust-side).
#[derive(Debug, Clone, PartialEq)]
pub enum Init {
    Zeros,
    /// Uniform(-bound, bound) -- PyTorch-style fan-in scaling.
    Uniform { bound: f32 },
}

/// One input or output tensor of an artifact graph.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
    pub init: Option<Init>,
}

/// One AOT-compiled computation (a `<name>.hlo.txt` file).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub model: String,
    pub side: usize,
    pub batch_size: usize,
    pub extensions: Vec<String>,
    pub kind: String,
    pub has_key: bool,
    pub num_classes: usize,
    pub in_shape: Vec<usize>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    /// Input specs that are model parameters (name starts with "param/").
    pub fn param_inputs(&self) -> Vec<&TensorSpec> {
        self.inputs
            .iter()
            .filter(|t| t.name.starts_with("param/"))
            .collect()
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|t| t.name == name)
            .with_context(|| {
                format!("artifact {} has no output {name:?}", self.name)
            })
    }
}

/// The whole manifest.
#[derive(Debug)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub source_hash: String,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "cannot read {} -- run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text)?;
        let mut artifacts = BTreeMap::new();
        for (name, spec) in root.get("artifacts")?.as_obj()? {
            artifacts.insert(name.clone(), parse_artifact(name, spec)?);
        }
        Ok(Manifest {
            artifacts,
            source_hash: root.get("source_hash")?.as_str()?.to_string(),
        })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).with_context(|| {
            format!("no artifact {name:?} (run `make artifacts`?)")
        })
    }

    /// Find the training artifact for (model, input side, extension
    /// signature, batch size). `side` disambiguates the 16x16 vs 32x32
    /// All-CNN-C graphs; it is 0 for models with a fixed input size.
    pub fn find_train(
        &self,
        model: &str,
        side: usize,
        ext_sig: &str,
        batch: usize,
    ) -> Result<&ArtifactSpec> {
        for a in self.artifacts.values() {
            let sig = if a.extensions.is_empty() {
                "grad".to_string()
            } else {
                a.extensions.join("+")
            };
            if a.model == model
                && a.side == side
                && a.kind == "train"
                && sig == ext_sig
                && a.batch_size == batch
            {
                return Ok(a);
            }
        }
        bail!(
            "no train artifact for model={model} side={side} \
             ext={ext_sig} n={batch}"
        )
    }
}

fn parse_tensor_spec(j: &Json) -> Result<TensorSpec> {
    let shape = j
        .get("shape")?
        .as_arr()?
        .iter()
        .map(|d| d.as_usize())
        .collect::<Result<Vec<_>>>()?;
    let init = match j.opt("init") {
        None => None,
        Some(spec) => Some(match spec.get("kind")?.as_str()? {
            "zeros" => Init::Zeros,
            "uniform" => Init::Uniform {
                bound: spec.get("bound")?.as_f64()? as f32,
            },
            other => bail!("unknown init kind {other:?}"),
        }),
    };
    Ok(TensorSpec {
        name: j.get("name")?.as_str()?.to_string(),
        shape,
        dtype: j.get("dtype")?.as_str()?.to_string(),
        init,
    })
}

fn parse_artifact(name: &str, j: &Json) -> Result<ArtifactSpec> {
    Ok(ArtifactSpec {
        name: name.to_string(),
        file: j.get("file")?.as_str()?.to_string(),
        model: j.get("model")?.as_str()?.to_string(),
        side: j.get("side")?.as_usize()?,
        batch_size: j.get("batch_size")?.as_usize()?,
        extensions: j
            .get("extensions")?
            .as_arr()?
            .iter()
            .map(|e| Ok(e.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?,
        kind: j.get("kind")?.as_str()?.to_string(),
        has_key: j.get("has_key")?.as_bool()?,
        num_classes: j.get("num_classes")?.as_usize()?,
        in_shape: j
            .get("in_shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<Vec<_>>>()?,
        inputs: j
            .get("inputs")?
            .as_arr()?
            .iter()
            .map(parse_tensor_spec)
            .collect::<Result<Vec<_>>>()?,
        outputs: j
            .get("outputs")?
            .as_arr()?
            .iter()
            .map(parse_tensor_spec)
            .collect::<Result<Vec<_>>>()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "toy_grad_n4": {
          "file": "toy_grad_n4.hlo.txt", "model": "toy", "side": 0,
          "batch_size": 4, "extensions": [], "kind": "train",
          "has_key": false, "num_classes": 3, "in_shape": [5],
          "inputs": [
            {"name": "param/0/w", "shape": [3, 5], "dtype": "f32",
             "init": {"kind": "uniform", "bound": 0.4}},
            {"name": "param/0/b", "shape": [3], "dtype": "f32",
             "init": {"kind": "zeros"}},
            {"name": "x", "shape": [4, 5], "dtype": "f32"},
            {"name": "y", "shape": [4], "dtype": "i32"}
          ],
          "outputs": [
            {"name": "grad/0/w", "shape": [3, 5], "dtype": "f32"},
            {"name": "loss", "shape": [], "dtype": "f32"}
          ]
        }
      },
      "source_hash": "abc"
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.get("toy_grad_n4").unwrap();
        assert_eq!(a.batch_size, 4);
        assert_eq!(a.param_inputs().len(), 2);
        assert_eq!(
            a.param_inputs()[0].init,
            Some(Init::Uniform { bound: 0.4 })
        );
        assert_eq!(a.output_index("loss").unwrap(), 1);
        assert!(a.output_index("nope").is_err());
        assert!(m.find_train("toy", 0, "grad", 4).is_ok());
        assert!(m.find_train("toy", 0, "kfac", 4).is_err());
        assert!(m.find_train("toy", 16, "grad", 4).is_err());
    }
}
