//! Synthetic dataset substrate (DESIGN.md §3).
//!
//! The paper evaluates on MNIST / Fashion-MNIST / CIFAR-10 / CIFAR-100,
//! which are not available here; we substitute deterministic synthetic
//! datasets with the same shapes and class counts. Each class owns a
//! smooth "prototype" field (a sum of random low-frequency 2-D
//! sinusoids -- convnets must exploit spatial structure to separate
//! them) and each sample is `prototype + per-sample deformation +
//! pixel noise`, making the task learnable but not trivial: the
//! optimizer comparisons (Figs. 7, 10, 11) exercise the same
//! loss-geometry code paths, and the cost benchmarks (Figs. 3, 6, 8, 9)
//! are data-independent.
//!
//! Every sample is a pure function of (dataset seed, split, index).

use super::rng::{splitmix64, Rng};

/// Shape and size description of a synthetic dataset.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// Channels, height, width; flat datasets use (1, 1, dim).
    pub channels: usize,
    pub height: usize,
    pub width: usize,
    pub classes: usize,
    pub train_size: usize,
    pub test_size: usize,
    /// True when the model consumes flat vectors ([N, dim]).
    pub flat: bool,
}

impl DatasetSpec {
    pub fn sample_dim(&self) -> usize {
        self.channels * self.height * self.width
    }

    /// The four evaluation datasets (paper Table 3), by DeepOBS name.
    pub fn by_name(name: &str) -> Option<DatasetSpec> {
        Some(match name {
            "mnist" => DatasetSpec {
                name: "mnist", channels: 1, height: 28, width: 28,
                classes: 10, train_size: 4096, test_size: 1024,
                flat: true,
            },
            "fmnist" => DatasetSpec {
                name: "fmnist", channels: 1, height: 28, width: 28,
                classes: 10, train_size: 4096, test_size: 1024,
                flat: false,
            },
            "cifar10" => DatasetSpec {
                name: "cifar10", channels: 3, height: 32, width: 32,
                classes: 10, train_size: 4096, test_size: 1024,
                flat: false,
            },
            // CPU-scaled CIFAR-100 substitute: 16x16 (All-CNN-C's
            // parameter count is spatial-size-invariant; DESIGN.md §3).
            "cifar100" => DatasetSpec {
                name: "cifar100", channels: 3, height: 16, width: 16,
                classes: 100, train_size: 4096, test_size: 1024,
                flat: false,
            },
            // Full-size CIFAR-100 for the overhead benches.
            "cifar100_32" => DatasetSpec {
                name: "cifar100_32", channels: 3, height: 32, width: 32,
                classes: 100, train_size: 512, test_size: 128,
                flat: false,
            },
            _ => return None,
        })
    }
}

/// Number of sinusoidal components per class prototype.
const WAVES: usize = 6;
/// Amplitude of the class signal relative to unit pixel noise.
const SIGNAL: f32 = 1.2;
/// Per-sample smooth deformation amplitude (within-class variability).
const DEFORM: f32 = 0.55;

/// One low-frequency sinusoid: amplitude, frequencies, phase.
#[derive(Debug, Clone, Copy)]
struct Wave {
    amp: f32,
    fx: f32,
    fy: f32,
    phase: f32,
}

impl Wave {
    fn sample(rng: &mut Rng, amp: f32) -> Wave {
        Wave {
            amp: amp * rng.uniform_in(0.5, 1.0),
            fx: rng.uniform_in(0.5, 3.0),
            fy: rng.uniform_in(0.5, 3.0),
            phase: rng.uniform_in(0.0, 2.0 * std::f32::consts::PI),
        }
    }

    #[inline]
    fn eval(&self, u: f32, v: f32) -> f32 {
        self.amp
            * (2.0 * std::f32::consts::PI * (self.fx * u + self.fy * v)
                + self.phase)
                .sin()
    }
}

/// Deterministic synthetic classification dataset.
pub struct Synthetic {
    pub spec: DatasetSpec,
    seed: u64,
    /// [classes][channels][WAVES] prototype fields.
    prototypes: Vec<Vec<Vec<Wave>>>,
}

impl Synthetic {
    pub fn new(spec: DatasetSpec, seed: u64) -> Synthetic {
        let mut prototypes = Vec::with_capacity(spec.classes);
        for c in 0..spec.classes {
            let mut per_channel = Vec::with_capacity(spec.channels);
            for ch in 0..spec.channels {
                let mut rng =
                    Rng::new(seed).fork(0xC1A55 ^ (c as u64) << 16)
                        .fork(ch as u64);
                per_channel.push(
                    (0..WAVES)
                        .map(|_| Wave::sample(&mut rng, SIGNAL))
                        .collect(),
                );
            }
            prototypes.push(per_channel);
        }
        Synthetic { spec, seed, prototypes }
    }

    /// Label of sample `index` in `split` (0=train, 1=test): balanced,
    /// deterministic assignment.
    pub fn label(&self, split: u32, index: usize) -> usize {
        let h = splitmix64(
            self.seed ^ splitmix64((split as u64) << 32 | index as u64),
        );
        (h % self.spec.classes as u64) as usize
    }

    /// Write sample `index` of `split` into `out` (sample_dim() floats).
    pub fn fill_sample(&self, split: u32, index: usize, out: &mut [f32]) {
        let spec = &self.spec;
        assert_eq!(out.len(), spec.sample_dim());
        let label = self.label(split, index);
        let key = splitmix64(
            self.seed
                ^ splitmix64(0xDA7A ^ (split as u64) << 40
                    | index as u64),
        );
        let mut rng = Rng::new(key);
        // Smooth per-sample deformation: shifts + its own weak field.
        let du = rng.uniform_in(-0.15, 0.15);
        let dv = rng.uniform_in(-0.15, 0.15);
        let deform: Vec<Wave> = (0..3)
            .map(|_| Wave::sample(&mut rng, DEFORM))
            .collect();
        let (h, w) = (spec.height, spec.width);
        for ch in 0..spec.channels {
            let waves = &self.prototypes[label][ch];
            for yy in 0..h {
                let v = yy as f32 / h as f32 + dv;
                for xx in 0..w {
                    let u = xx as f32 / w as f32 + du;
                    let mut val = 0.0;
                    for wv in waves {
                        val += wv.eval(u, v);
                    }
                    for wv in &deform {
                        val += wv.eval(u, v);
                    }
                    val += rng.normal() * 0.6; // pixel noise
                    out[(ch * h + yy) * w + xx] = val * 0.5;
                }
            }
        }
    }

    /// Materialize a batch of samples: (x [n * dim], y [n]).
    pub fn batch(&self, split: u32, indices: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let dim = self.spec.sample_dim();
        let mut x = vec![0.0f32; indices.len() * dim];
        let mut y = Vec::with_capacity(indices.len());
        for (i, &idx) in indices.iter().enumerate() {
            self.fill_sample(split, idx, &mut x[i * dim..(i + 1) * dim]);
            y.push(self.label(split, idx) as i32);
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Synthetic {
        let spec = DatasetSpec {
            name: "t", channels: 2, height: 8, width: 8, classes: 4,
            train_size: 64, test_size: 16, flat: false,
        };
        Synthetic::new(spec, 42)
    }

    #[test]
    fn deterministic_samples() {
        let d = tiny();
        let mut a = vec![0.0; d.spec.sample_dim()];
        let mut b = vec![0.0; d.spec.sample_dim()];
        d.fill_sample(0, 3, &mut a);
        d.fill_sample(0, 3, &mut b);
        assert_eq!(a, b);
        d.fill_sample(0, 4, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn splits_differ() {
        let d = tiny();
        let mut a = vec![0.0; d.spec.sample_dim()];
        let mut b = vec![0.0; d.spec.sample_dim()];
        d.fill_sample(0, 3, &mut a);
        d.fill_sample(1, 3, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn labels_roughly_balanced() {
        let d = tiny();
        let mut counts = vec![0usize; 4];
        for i in 0..1000 {
            counts[d.label(0, i)] += 1;
        }
        for &c in &counts {
            assert!(c > 150, "unbalanced: {counts:?}");
        }
    }

    #[test]
    fn same_class_closer_than_cross_class() {
        // The class signal must dominate the noise on average:
        // intra-class distance < inter-class distance.
        let d = tiny();
        let dim = d.spec.sample_dim();
        let mut by_class: Vec<Vec<Vec<f32>>> = vec![vec![]; 4];
        for i in 0..200 {
            let mut s = vec![0.0; dim];
            d.fill_sample(0, i, &mut s);
            by_class[d.label(0, i)].push(s);
        }
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum()
        };
        let intra = dist(&by_class[0][0], &by_class[0][1]);
        let inter = dist(&by_class[0][0], &by_class[1][0]);
        assert!(
            intra < inter,
            "class structure too weak: intra {intra} inter {inter}"
        );
    }

    #[test]
    fn known_specs_exist() {
        for name in ["mnist", "fmnist", "cifar10", "cifar100",
                     "cifar100_32"] {
            assert!(DatasetSpec::by_name(name).is_some(), "{name}");
        }
        assert!(DatasetSpec::by_name("imagenet").is_none());
    }
}
