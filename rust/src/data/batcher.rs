//! Epoch-shuffling mini-batch iterator over a synthetic dataset.

use super::rng::Rng;
use super::synthetic::Synthetic;
use crate::runtime::Tensor;

/// Yields training batches as (x, y) host tensors shaped for a model
/// (flat [N, D] or image [N, C, H, W] per the dataset spec).
pub struct Batcher {
    data: Synthetic,
    batch_size: usize,
    order: Vec<usize>,
    cursor: usize,
    epoch_rng: Rng,
    pub epoch: usize,
}

impl Batcher {
    pub fn new(data: Synthetic, batch_size: usize, seed: u64) -> Batcher {
        let order: Vec<usize> = (0..data.spec.train_size).collect();
        let mut b = Batcher {
            data,
            batch_size,
            order,
            cursor: 0,
            epoch_rng: Rng::new(seed ^ 0xBA7C4),
            epoch: 0,
        };
        b.reshuffle();
        b
    }

    fn reshuffle(&mut self) {
        let mut rng = self.epoch_rng.fork(self.epoch as u64);
        rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    fn x_shape(&self, n: usize) -> Vec<usize> {
        let s = &self.data.spec;
        if s.flat {
            vec![n, s.sample_dim()]
        } else {
            vec![n, s.channels, s.height, s.width]
        }
    }

    /// Next training batch; wraps (and reshuffles) at epoch boundaries.
    pub fn next_batch(&mut self) -> (Tensor, Tensor) {
        let n = self.batch_size;
        if self.cursor + n > self.order.len() {
            self.epoch += 1;
            self.reshuffle();
        }
        let idx = &self.order[self.cursor..self.cursor + n];
        self.cursor += n;
        let (x, y) = self.data.batch(0, idx);
        (
            Tensor::from_f32(&self.x_shape(n), x),
            Tensor::from_i32(&[n], y),
        )
    }

    /// A fixed evaluation batch from the test split (deterministic).
    pub fn eval_batch(&self, n: usize, offset: usize) -> (Tensor, Tensor) {
        let idx: Vec<usize> = (0..n)
            .map(|i| (offset + i) % self.data.spec.test_size)
            .collect();
        let (x, y) = self.data.batch(1, &idx);
        (
            Tensor::from_f32(&self.x_shape(n), x),
            Tensor::from_i32(&[n], y),
        )
    }

    pub fn spec(&self) -> &super::synthetic::DatasetSpec {
        &self.data.spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::DatasetSpec;

    fn mk() -> Batcher {
        let spec = DatasetSpec {
            name: "t", channels: 1, height: 4, width: 4, classes: 3,
            train_size: 10, test_size: 6, flat: false,
        };
        Batcher::new(Synthetic::new(spec, 1), 4, 7)
    }

    #[test]
    fn batch_shapes() {
        let mut b = mk();
        let (x, y) = b.next_batch();
        assert_eq!(x.shape, vec![4, 1, 4, 4]);
        assert_eq!(y.shape, vec![4]);
    }

    #[test]
    fn epoch_advances_and_reshuffles() {
        let mut b = mk();
        let first: Vec<_> = (0..2).map(|_| b.next_batch().1).collect();
        assert_eq!(b.epoch, 0);
        let _ = b.next_batch(); // 12 > 10 -> wraps
        assert_eq!(b.epoch, 1);
        // With a different permutation the next epoch's first labels
        // will (almost surely) differ from epoch 0's.
        let second = b.next_batch().1;
        assert!(first.iter().any(|t| t != &second));
    }

    #[test]
    fn eval_batch_deterministic() {
        let b = mk();
        let (x1, _) = b.eval_batch(3, 0);
        let (x2, _) = b.eval_batch(3, 0);
        assert_eq!(x1, x2);
        let (x3, _) = b.eval_batch(3, 3);
        assert_ne!(x1, x3);
    }
}
