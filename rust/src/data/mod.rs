//! Dataset substrate: deterministic PRNG, synthetic datasets with the
//! paper's shapes/class counts, and an epoch-shuffling batcher.
pub mod batcher;
pub mod rng;
pub mod synthetic;

pub use batcher::Batcher;
pub use rng::{splitmix64, Rng};
pub use synthetic::{DatasetSpec, Synthetic};
