//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! SplitMix64 for seeding / stateless index hashing, xoshiro256++ for
//! streams, Box-Muller for normals. Every dataset sample is a pure
//! function of (dataset seed, sample index), so synthetic data is
//! reproducible across runs, seeds and languages.

/// SplitMix64 step: the standard 64-bit finalizer. Stateless helper.
#[inline]
pub fn splitmix64(state: u64) -> u64 {
    let mut z = state.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// xoshiro256++ stream PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // Seed the state via SplitMix64 per the xoshiro authors' advice.
        let mut s = [0u64; 4];
        let mut x = seed;
        for slot in &mut s {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            *slot = splitmix64(x);
        }
        Rng { s }
    }

    /// Derive an independent stream (e.g. per epoch / per class).
    pub fn fork(&self, tag: u64) -> Rng {
        Rng::new(splitmix64(self.s[0] ^ splitmix64(tag)))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let res = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        res
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-12 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut v: Vec<usize> = (0..100).collect();
        Rng::new(5).shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_independent() {
        let base = Rng::new(6);
        assert_ne!(base.fork(1).next_u64(), base.fork(2).next_u64());
    }
}
