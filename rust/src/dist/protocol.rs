//! `backpack-shard/v1`: the coordinator ↔ worker op set.
//!
//! Every message is one frame of the shared codec ([`crate::wire`]:
//! u32 big-endian length prefix, UTF-8 JSON payload, 64 MiB cap) and
//! every request object carries an `"op"` discriminator:
//!
//! | op              | direction     | payload                                                 | reply                          |
//! |-----------------|---------------|---------------------------------------------------------|--------------------------------|
//! | `handshake`     | coord → worker| `schema`                                                | `ok`, `schema`, `threads`      |
//! | `plan`          | coord → worker| `model`, `extensions`, `global_n`, `key`, `params`      | `ok`                           |
//! | `extract_slice` | coord → worker| `offset`, `x` (tensor), `y` (labels)                    | `ok`, `quantities`             |
//! | `merge`         | coord → worker| `parts` (list of quantity maps)                         | `ok`, `quantities`             |
//! | `shutdown`      | coord → worker| —                                                       | `ok`, then the worker exits    |
//!
//! Error replies are `{"ok": false, "error": "..."}`; the session
//! survives them (a rejected op does not poison the connection).
//!
//! Tensors cross as `{"shape": [...], "data": [...]}`
//! ([`crate::wire::tensor_to_json`]) — finite f32 values round-trip
//! bitwise, which is what lets the equivalence suite demand bitwise
//! `Concat` rows across process boundaries. `params` ships the full
//! parameter set explicitly (workers never re-derive parameters from
//! a seed), so coordinator and workers agree by construction.
//!
//! `merge` is the hierarchical-reduction hook: it applies the same
//! [`ReducePlan`](crate::backend::extensions::ReducePlan) merge the
//! coordinator runs, letting a tree of workers fold partial results
//! before they reach the root. The flat coordinator in this crate
//! does not use it, but it is part of the versioned surface.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Context, Result};

use crate::backend::extensions::Quantities;
use crate::json::Json;
use crate::runtime::Tensor;
use crate::wire::{tensor_from_json, tensor_to_json};

/// Version-tagged schema name, announced in the worker banner and
/// checked by the handshake on both sides.
pub const SHARD_SCHEMA: &str = "backpack-shard/v1";

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
    )
}

/// `handshake` request: schema negotiation, no state.
pub fn handshake() -> String {
    obj(vec![
        ("op", Json::Str("handshake".into())),
        ("schema", Json::Str(SHARD_SCHEMA.into())),
    ])
    .to_string_json()
}

/// `plan` request: everything slice-independent about the extraction
/// — model name, extension names, global batch size, MC key, and the
/// full parameter set.
pub fn plan(
    model: &str,
    extensions: &[String],
    global_n: usize,
    key: Option<[u32; 2]>,
    params: &[Tensor],
) -> String {
    let key_json = match key {
        Some([a, b]) => Json::Arr(vec![
            Json::Num(a as f64),
            Json::Num(b as f64),
        ]),
        None => Json::Null,
    };
    obj(vec![
        ("op", Json::Str("plan".into())),
        ("model", Json::Str(model.to_string())),
        (
            "extensions",
            Json::Arr(
                extensions
                    .iter()
                    .map(|e| Json::Str(e.clone()))
                    .collect(),
            ),
        ),
        ("global_n", Json::Num(global_n as f64)),
        ("key", key_json),
        (
            "params",
            Json::Arr(params.iter().map(tensor_to_json).collect()),
        ),
    ])
    .to_string_json()
}

/// `extract_slice` request: one contiguous slice, addressed by its
/// **global** sample offset (the invariant every worker-count
/// equivalence rests on).
pub fn extract_slice(offset: usize, x: &Tensor, y: &[i32]) -> String {
    obj(vec![
        ("op", Json::Str("extract_slice".into())),
        ("offset", Json::Num(offset as f64)),
        ("x", tensor_to_json(x)),
        (
            "y",
            Json::Arr(
                y.iter().map(|l| Json::Num(*l as f64)).collect(),
            ),
        ),
    ])
    .to_string_json()
}

/// `merge` request: fold pre-finish quantity maps by the reduce
/// contract, worker-side.
pub fn merge(parts: &[Quantities]) -> String {
    obj(vec![
        ("op", Json::Str("merge".into())),
        (
            "parts",
            Json::Arr(parts.iter().map(quantities_to_json).collect()),
        ),
    ])
    .to_string_json()
}

/// `shutdown` request: ack, then exit the worker process.
pub fn shutdown() -> String {
    obj(vec![("op", Json::Str("shutdown".into()))]).to_string_json()
}

/// Bare success reply.
pub fn ok_reply() -> String {
    ok_reply_with(Vec::new())
}

/// Success reply with extra fields.
pub fn ok_reply_with(fields: Vec<(&str, Json)>) -> String {
    let mut all = vec![("ok", Json::Bool(true))];
    all.extend(fields);
    obj(all).to_string_json()
}

/// Error reply; the session continues after it.
pub fn error_reply(msg: &str) -> String {
    obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.to_string())),
    ])
    .to_string_json()
}

/// Quantity map → JSON object of wire tensors.
pub fn quantities_to_json(q: &Quantities) -> Json {
    Json::Obj(
        q.iter()
            .map(|(k, t)| (k.clone(), tensor_to_json(t)))
            .collect(),
    )
}

/// JSON object of wire tensors → quantity map.
pub fn quantities_from_json(v: &Json) -> Result<Quantities> {
    let mut out: Quantities = BTreeMap::new();
    for (k, t) in v.as_obj()? {
        out.insert(
            k.clone(),
            tensor_from_json(t)
                .with_context(|| format!("quantity {k:?}"))?,
        );
    }
    Ok(out)
}

/// Parse an optional `[a, b]` Monte-Carlo key.
pub fn parse_key(v: &Json) -> Result<Option<[u32; 2]>> {
    match v {
        Json::Null => Ok(None),
        other => {
            let a = other.as_arr()?;
            ensure!(a.len() == 2, "key must be [a, b]");
            Ok(Some([
                u32::try_from(a[0].as_usize()?)
                    .context("key word out of u32 range")?,
                u32::try_from(a[1].as_usize()?)
                    .context("key word out of u32 range")?,
            ]))
        }
    }
}

/// Parse one reply frame: the parsed object on `"ok": true`, the
/// worker's own error message surfaced as the failure otherwise.
pub fn expect_ok(frame: &str) -> Result<Json> {
    let v = Json::parse(frame).context("malformed shard reply")?;
    if v.get("ok")?.as_bool()? {
        return Ok(v);
    }
    let msg = v
        .opt("error")
        .and_then(|e| e.as_str().ok().map(str::to_string))
        .unwrap_or_else(|| "unspecified worker error".to_string());
    bail!("{msg}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_round_trips_all_fields() {
        let params = vec![Tensor::from_f32(&[2, 2], vec![
            1.0, -2.5, 3.0, 4.25,
        ])];
        let frame = plan(
            "logreg",
            &["batch_grad".to_string(), "variance".to_string()],
            32,
            Some([7, 9]),
            &params,
        );
        let v = Json::parse(&frame).unwrap();
        assert_eq!(v.get("op").unwrap().as_str().unwrap(), "plan");
        assert_eq!(
            v.get("model").unwrap().as_str().unwrap(),
            "logreg"
        );
        assert_eq!(
            v.get("global_n").unwrap().as_usize().unwrap(),
            32
        );
        assert_eq!(
            parse_key(v.get("key").unwrap()).unwrap(),
            Some([7, 9])
        );
        let back = tensor_from_json(
            &v.get("params").unwrap().as_arr().unwrap()[0],
        )
        .unwrap();
        assert_eq!(back.shape, vec![2, 2]);
        assert_eq!(back.f32s().unwrap(), params[0].f32s().unwrap());
        // No key is null, round-trips to None.
        let frame = plan("mlp", &[], 4, None, &[]);
        let v = Json::parse(&frame).unwrap();
        assert_eq!(parse_key(v.get("key").unwrap()).unwrap(), None);
    }

    #[test]
    fn extract_slice_addresses_by_global_offset() {
        let x = Tensor::from_f32(&[2, 3], vec![0.; 6]);
        let frame = extract_slice(11, &x, &[1, 0]);
        let v = Json::parse(&frame).unwrap();
        assert_eq!(
            v.get("offset").unwrap().as_usize().unwrap(),
            11
        );
        let y: Vec<usize> = v
            .get("y")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.as_usize().unwrap())
            .collect();
        assert_eq!(y, vec![1, 0]);
    }

    #[test]
    fn quantities_round_trip() {
        let mut q: Quantities = BTreeMap::new();
        q.insert(
            "grad/0/w".to_string(),
            Tensor::from_f32(&[2], vec![1.5, -2.0]),
        );
        q.insert(
            "loss".to_string(),
            Tensor::from_f32(&[], vec![0.75]),
        );
        let back = quantities_from_json(&quantities_to_json(&q))
            .unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(
            back["grad/0/w"].f32s().unwrap(),
            q["grad/0/w"].f32s().unwrap()
        );
        assert_eq!(back["loss"].shape, Vec::<usize>::new());
    }

    #[test]
    fn expect_ok_surfaces_the_worker_error() {
        assert!(expect_ok(&ok_reply()).is_ok());
        let err = expect_ok(&error_reply("no such model"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("no such model"), "{err}");
        assert!(expect_ok("not json").is_err());
    }
}
