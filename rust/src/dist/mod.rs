//! Process-parallel extraction: `backpack worker` processes driven
//! by an in-process coordinator (DESIGN.md §15, docs/distributed.md).
//!
//! The native engine already shards one `extended_backward` call
//! across threads and merges per-key by the public reduce contract
//! ([`ReducePlan`](crate::backend::extensions::ReducePlan): `Sum`
//! accumulate, order-preserving `Concat` gather). This module lifts
//! the *same* contract one level up, across process boundaries:
//!
//! * a [`Worker`] serves `backpack-shard/v1` ([`protocol`]) over the
//!   shared length-prefix codec ([`crate::wire`]), running the
//!   pre-finish engine ([`Model::extended_backward_slice`]) on a
//!   contiguous slice of the global batch;
//! * the [`coordinate`] function — reached through
//!   [`Model::extended_backward`] when [`ExtractOptions`] carries a
//!   [`Topology::Workers`] — partitions `[0, N)` into contiguous
//!   slices ([`crate::parallel::shards`], the same splitter threads
//!   use), fans the slices out, merges the per-worker pre-finish
//!   outputs in worker-index order with `ReducePlan`, and runs the
//!   `finish` hooks **once** on the merged result
//!   ([`Model::finish_merged`]).
//!
//! # Why this is exact
//!
//! Worker slices carry their **global** sample offset: averaged
//! quantities are normalized by the global batch size inside each
//! worker (so `Sum` parts add to exactly the single-process value up
//! to f32 summation reordering, ≤ 1e-5), Monte-Carlo draws are keyed
//! by global sample index (so MC quantities are *bitwise* independent
//! of the worker count), and `Concat` rows are gathered in slice
//! order (so row `s` of a per-sample quantity is sample `s`,
//! bitwise, for any worker count). `finish` runs on the coordinator
//! only because it is the one non-linear step — variance from
//! moments, KFRA's backward Ḡ recursion — and running it per worker
//! then averaging would compute a different (wrong) quantity.
//!
//! # Failure semantics
//!
//! Every reply read carries a per-worker deadline
//! ([`OP_TIMEOUT`]); a worker that dies mid-extract surfaces as a
//! coordinator error naming the worker index (a closed socket is
//! *never* silent, because EOF between frames mid-protocol is a
//! protocol violation here even though the codec itself calls it
//! clean). Spawned workers are killed on coordinator drop; external
//! workers (connected by address) survive the session and accept the
//! next coordinator.
//!
//! [`Model::extended_backward`]: crate::backend::model::Model::extended_backward
//! [`Model::extended_backward_slice`]: crate::backend::model::Model::extended_backward_slice
//! [`Model::finish_merged`]: crate::backend::model::Model::finish_merged
//! [`ExtractOptions`]: crate::backend::model::ExtractOptions
//! [`Topology::Workers`]: crate::backend::model::Topology::Workers

pub mod protocol;

mod coordinator;
mod worker;

pub use coordinator::{coordinate, OP_TIMEOUT};
pub use worker::Worker;
