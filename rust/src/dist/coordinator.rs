//! The coordinator side: partition, fan out, all-reduce, finish.
//!
//! [`coordinate`] is reached through
//! [`Model::extended_backward`](crate::backend::model::Model::extended_backward)
//! when the options carry a
//! [`Topology::Workers`](crate::backend::model::Topology::Workers):
//! with an empty address list it spawns `n` `backpack worker`
//! child processes from the current executable (parsing each
//! worker's `backpack-shard/v1 listening on ADDR` banner); with
//! addresses it connects to externally-managed workers, one per
//! address. Either way the flow is
//!
//! 1. partition `[0, N)` into contiguous slices with
//!    [`crate::parallel::shards`] — the *same* splitter the
//!    in-process engine uses, so worker slice boundaries are the
//!    thread shard boundaries of a hypothetical `n`-thread run;
//! 2. pipeline `handshake` + `plan` + `extract_slice` writes to
//!    every worker, then collect replies in worker-index order
//!    (order-preserving for `Concat` rows);
//! 3. merge the pre-finish parts with
//!    [`ReducePlan`](crate::backend::extensions::ReducePlan) and run
//!    the `finish` hooks once, locally
//!    ([`Model::finish_merged`](crate::backend::model::Model::finish_merged)).
//!
//! Failure propagation: every reply read sits under [`OP_TIMEOUT`];
//! a worker that dies shows up as a named coordinator error (its
//! index and address), never a hang. Spawned children are killed
//! when their link drops, so an error path cannot leak worker
//! processes.

use std::io::{BufRead, BufReader};
use std::net::{TcpStream, ToSocketAddrs};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::backend::extensions::{
    ExtensionSet, Quantities, ReducePlan,
};
use crate::backend::model::{ExtractOptions, Model, Topology};
use crate::json::Json;
use crate::obs;
use crate::parallel;
use crate::runtime::Tensor;
use crate::wire::{read_frame, write_frame};

use super::protocol::{self, SHARD_SCHEMA};

/// Per-reply deadline on every worker read. Generous — a slice of a
/// debug-sized extraction finishes in milliseconds, an exact-GGN
/// sweep in minutes is out of scope for the shard channel's
/// defaults — but finite, so a wedged worker surfaces as an error
/// naming it instead of a silent hang.
pub const OP_TIMEOUT: Duration = Duration::from_secs(300);

/// Deadline for the initial TCP connect to each worker.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

/// One live worker connection; spawned children die with the link.
struct Link {
    index: usize,
    addr: String,
    stream: TcpStream,
    child: Option<Child>,
}

impl Drop for Link {
    fn drop(&mut self) {
        if let Some(child) = &mut self.child {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

impl Link {
    fn send(&mut self, frame: &str) -> Result<()> {
        write_frame(&mut self.stream, frame).with_context(|| {
            format!(
                "sending to shard worker {} ({})",
                self.index, self.addr
            )
        })
    }

    /// Read one reply under [`OP_TIMEOUT`] and unwrap its `ok`. A
    /// clean between-frames EOF is a protocol violation here — the
    /// worker owed a reply — and is reported as a death, which is
    /// exactly what it usually is.
    fn recv(&mut self) -> Result<Json> {
        match read_frame(&mut self.stream) {
            Ok(Some(frame)) => {
                protocol::expect_ok(&frame).with_context(|| {
                    format!(
                        "shard worker {} ({}) rejected the request",
                        self.index, self.addr
                    )
                })
            }
            Ok(None) => bail!(
                "shard worker {} ({}) closed the connection while a \
                 reply was owed (worker process died?)",
                self.index,
                self.addr
            ),
            Err(e) => Err(e).with_context(|| {
                format!(
                    "reading from shard worker {} ({})",
                    self.index, self.addr
                )
            }),
        }
    }
}

/// Spawn one `backpack worker` child from the current executable and
/// parse its banner for the ephemeral address it bound.
fn spawn_worker(index: usize) -> Result<(Child, String)> {
    let exe = std::env::current_exe().context(
        "cannot locate the running binary to spawn workers from; \
         use Topology::Workers { addrs } with pre-started workers",
    )?;
    let mut child = Command::new(&exe)
        .args(["worker", "--addr", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .spawn()
        .with_context(|| {
            format!(
                "spawning shard worker {index} from {}",
                exe.display()
            )
        })?;
    let stdout = child
        .stdout
        .take()
        .context("no stdout pipe on spawned worker")?;
    let mut lines = BufReader::new(stdout);
    let mut banner = String::new();
    loop {
        banner.clear();
        let got = lines.read_line(&mut banner).with_context(|| {
            format!("reading shard worker {index}'s banner")
        })?;
        if got == 0 {
            let _ = child.kill();
            let _ = child.wait();
            bail!(
                "shard worker {index} exited before announcing its \
                 address (is {:?} a backpack binary?)",
                exe.display()
            );
        }
        if banner.starts_with(SHARD_SCHEMA) {
            break;
        }
    }
    let addr = banner
        .trim()
        .rsplit(' ')
        .next()
        .unwrap_or("")
        .to_string();
    if !addr.contains(':') {
        let _ = child.kill();
        let _ = child.wait();
        bail!("malformed worker banner {banner:?}");
    }
    Ok((child, addr))
}

/// Run one extraction across worker processes. Called by
/// `Model::extended_backward` on a `Workers` topology; see the
/// module docs for the flow and docs/distributed.md for the wire
/// contract.
pub fn coordinate(
    model: &Model,
    params: &[Tensor],
    x: &Tensor,
    y: &Tensor,
    extensions: &[String],
    opts: &ExtractOptions,
) -> Result<Quantities> {
    let Topology::Workers { n, addrs } = &opts.topology else {
        bail!("dist::coordinate requires a Workers topology")
    };
    ensure!(*n >= 1, "a Workers topology needs at least one worker");
    ensure!(
        opts.registry.is_none(),
        "a custom extension registry cannot cross the process \
         boundary: workers rebuild the builtin registry from \
         extension names alone. Run user-defined extensions with \
         Topology::Local"
    );
    if !addrs.is_empty() {
        ensure!(
            addrs.len() == *n,
            "Workers {{ n: {n} }} with {} addresses; supply one \
             address per worker (or none, to spawn them)",
            addrs.len()
        );
    }
    // Validate the signature before any process is spawned, with
    // the registry's nearest-match suggestions.
    let set = ExtensionSet::builtin();
    set.select(extensions)?;

    let ys = y.i32s()?;
    let total = ys.len();
    ensure!(total > 0, "empty batch");
    ensure!(
        x.shape.first() == Some(&total),
        "x has {:?} rows but y has {total} labels",
        x.shape.first()
    );
    let xs = x.f32s()?;
    let row: usize = x.shape[1..].iter().product();

    let _engine: Option<obs::Span> =
        opts.trace_label.as_ref().map(|label| {
            let label = label.clone();
            obs::span_with(obs::CAT_ENGINE, move || label)
        });

    // Contiguous, nearly-equal slices in global index order — the
    // same split `threads = n` would produce in-process. Never more
    // links than slices: a 3-sample batch on 5 workers runs on 3.
    let slices = parallel::shards(total, *n);

    let connect = obs::span(obs::CAT_PHASE, "dist_connect");
    let mut links = Vec::with_capacity(slices.len());
    for i in 0..slices.len() {
        let (child, addr) = if addrs.is_empty() {
            let (c, a) = spawn_worker(i)?;
            (Some(c), a)
        } else {
            (None, addrs[i].clone())
        };
        let sa = addr
            .to_socket_addrs()
            .with_context(|| format!("bad worker address {addr:?}"))?
            .next()
            .with_context(|| {
                format!("worker address {addr:?} resolves to nothing")
            })?;
        let stream = TcpStream::connect_timeout(&sa, CONNECT_TIMEOUT)
            .with_context(|| {
                format!("connecting to shard worker {i} at {addr}")
            })?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(OP_TIMEOUT))?;
        links.push(Link { index: i, addr, stream, child });
    }
    drop(connect);

    // Handshake + plan, pipelined: write both frames to every
    // worker, then collect both acks per worker in order.
    let plan_span = obs::span(obs::CAT_PHASE, "dist_plan");
    let hs = protocol::handshake();
    let plan_frame = protocol::plan(
        &model.name,
        extensions,
        total,
        opts.key,
        params,
    );
    for link in &mut links {
        link.send(&hs)?;
        link.send(&plan_frame)?;
    }
    for link in &mut links {
        let ack = link.recv()?;
        let schema = ack.get("schema")?.as_str()?;
        ensure!(
            schema == SHARD_SCHEMA,
            "worker {} speaks {schema:?}, not {SHARD_SCHEMA:?}",
            link.index
        );
        link.recv()?; // plan ack
    }
    drop(plan_span);

    // Fan the slices out (writes first, so every worker computes
    // concurrently), then gather replies in worker-index order.
    let extract = obs::span(obs::CAT_PHASE, "dist_extract");
    for (link, r) in links.iter_mut().zip(&slices) {
        let mut shape = x.shape.clone();
        shape[0] = r.len();
        let xi = Tensor::from_f32(
            &shape,
            xs[r.start * row..r.end * row].to_vec(),
        );
        link.send(&protocol::extract_slice(
            r.start,
            &xi,
            &ys[r.clone()],
        ))?;
    }
    let mut parts = Vec::with_capacity(links.len());
    for link in &mut links {
        let reply = link.recv()?;
        parts.push(protocol::quantities_from_json(
            reply.get("quantities")?,
        )?);
    }
    drop(extract);

    // All-reduce by the public contract — Sum accumulate, Concat
    // gather in slice order — then finish once, locally.
    let reduce = obs::span(obs::CAT_PHASE, "dist_reduce");
    let mut out = ReducePlan::of(&set).merge(parts)?;
    drop(reduce);
    model.finish_merged(params, extensions, opts, &mut out)?;

    // Spawned children get a clean shutdown (Drop would kill them
    // regardless); external workers outlive the session and accept
    // the next coordinator when the stream drops.
    for link in &mut links {
        if link.child.is_some() {
            let _ = link.send(&protocol::shutdown());
            let _ = link.recv();
        }
    }
    Ok(out)
}
