//! The `backpack worker` loop: serve `backpack-shard/v1` sessions
//! until a coordinator says `shutdown`.
//!
//! A worker is deliberately stateless between sessions: all
//! extraction state (model, extensions, parameters, global batch
//! size, MC key) arrives in the session's `plan` op, so any worker
//! can serve any coordinator and a worker restarted mid-campaign
//! needs no warm-up protocol. Sessions are served one at a time —
//! the engine already saturates the cores via the in-process pool,
//! so concurrent coordinators would only fight over them.
//!
//! The [`Worker::bind`] / [`Worker::local_addr`] / [`Worker::run`]
//! split mirrors [`crate::serve::Server`]: tests run workers on
//! in-process threads and hand their ephemeral addresses to a
//! [`Topology::Workers`](crate::backend::model::Topology::Workers)
//! coordinator, while the CLI binds, prints the
//! `backpack-shard/v1 listening on ADDR` banner (which the spawning
//! coordinator parses), and blocks in `run`.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};

use anyhow::{bail, ensure, Context, Result};

use crate::backend::extensions::{ExtensionSet, ReducePlan};
use crate::backend::model::{ExtractOptions, Topology};
use crate::backend::native::NativeBackend;
use crate::json::Json;
use crate::obs;
use crate::runtime::Tensor;
use crate::wire::{read_frame, tensor_from_json, write_frame};

use super::protocol::{self, SHARD_SCHEMA};

/// A bound-but-not-yet-running shard worker.
pub struct Worker {
    listener: TcpListener,
    addr: SocketAddr,
    threads: usize,
    backend: NativeBackend,
}

impl Worker {
    /// Bind `addr` (port 0 binds an ephemeral port; read it back
    /// from [`Worker::local_addr`]) and warm the in-process pool to
    /// `threads` (0 = auto).
    pub fn bind(addr: &str, threads: usize) -> Result<Worker> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("cannot bind {addr}"))?;
        let addr = listener.local_addr()?;
        crate::parallel::warm(crate::parallel::resolve_threads(
            threads,
        ));
        Ok(Worker {
            listener,
            addr,
            threads,
            backend: NativeBackend::new(),
        })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve coordinator sessions, one at a time, until one sends
    /// `shutdown`. A session that ends in a transport error (a
    /// half-written frame, a vanished coordinator) is logged and the
    /// worker accepts the next session — only `shutdown` is final.
    pub fn run(self) -> Result<()> {
        for stream in self.listener.incoming() {
            let stream = match stream {
                Ok(s) => s,
                Err(e) => {
                    obs::progress(format_args!(
                        "worker: accept failed: {e}"
                    ));
                    continue;
                }
            };
            let _ = stream.set_nodelay(true);
            match serve_session(&self.backend, self.threads, stream)
            {
                Ok(true) => return Ok(()),
                Ok(false) => {}
                Err(e) => obs::progress(format_args!(
                    "worker: session ended: {e:#}"
                )),
            }
        }
        Ok(())
    }
}

/// Slice-independent extraction state, set by the session's `plan`
/// op and consumed by every subsequent `extract_slice`.
struct Plan {
    model: String,
    extensions: Vec<String>,
    global_n: usize,
    key: Option<[u32; 2]>,
    params: Vec<Tensor>,
}

/// One coordinator session: frames in, replies out, until EOF or
/// `shutdown` (returns `true` for shutdown). Op-level failures
/// become error replies and the session continues; only transport
/// failures propagate.
fn serve_session(
    backend: &NativeBackend,
    threads: usize,
    stream: TcpStream,
) -> Result<bool> {
    let mut rd = BufReader::new(stream.try_clone()?);
    let mut wr = stream;
    let mut plan: Option<Plan> = None;
    while let Some(frame) = read_frame(&mut rd)? {
        let (reply, shutdown) =
            match handle(backend, threads, &mut plan, &frame) {
                Ok(r) => r,
                Err(e) => {
                    (protocol::error_reply(&format!("{e:#}")), false)
                }
            };
        write_frame(&mut wr, &reply)?;
        if shutdown {
            return Ok(true);
        }
    }
    Ok(false)
}

/// Dispatch one request frame; returns the reply frame plus the
/// shutdown flag.
fn handle(
    backend: &NativeBackend,
    threads: usize,
    plan: &mut Option<Plan>,
    frame: &str,
) -> Result<(String, bool)> {
    let msg = Json::parse(frame).context("malformed shard frame")?;
    let op = msg.get("op")?.as_str()?;
    match op {
        "handshake" => {
            let schema = msg.get("schema")?.as_str()?;
            ensure!(
                schema == SHARD_SCHEMA,
                "schema mismatch: coordinator speaks {schema:?}, \
                 this worker speaks {SHARD_SCHEMA:?}"
            );
            Ok((
                protocol::ok_reply_with(vec![
                    ("schema", Json::Str(SHARD_SCHEMA.into())),
                    (
                        "threads",
                        Json::Num(crate::parallel::resolve_threads(
                            threads,
                        )
                            as f64),
                    ),
                ]),
                false,
            ))
        }
        "plan" => {
            let model = msg.get("model")?.as_str()?.to_string();
            // Resolve the model and the extension names now, so an
            // unknown name fails loudly at plan time (with the
            // registry's nearest-match suggestions), not on the
            // first slice.
            backend.model(&model)?;
            let extensions = msg
                .get("extensions")?
                .as_arr()?
                .iter()
                .map(|e| Ok(e.as_str()?.to_string()))
                .collect::<Result<Vec<String>>>()?;
            ExtensionSet::builtin().select(&extensions)?;
            let global_n = msg.get("global_n")?.as_usize()?;
            let key = protocol::parse_key(msg.get("key")?)?;
            let params = msg
                .get("params")?
                .as_arr()?
                .iter()
                .map(tensor_from_json)
                .collect::<Result<Vec<Tensor>>>()?;
            *plan = Some(Plan {
                model,
                extensions,
                global_n,
                key,
                params,
            });
            Ok((protocol::ok_reply(), false))
        }
        "extract_slice" => {
            let p = plan.as_ref().context(
                "extract_slice before plan: send a plan op first",
            )?;
            let model = backend.model(&p.model)?;
            let offset = msg.get("offset")?.as_usize()?;
            let x = tensor_from_json(msg.get("x")?)?;
            let y = msg
                .get("y")?
                .as_arr()?
                .iter()
                .map(|e| {
                    Ok(i32::try_from(e.as_usize()?)
                        .context("label out of i32 range")?)
                })
                .collect::<Result<Vec<i32>>>()?;
            let n = y.len();
            ensure!(
                x.shape.first() == Some(&n),
                "x has {:?} rows but the slice has {n} labels",
                x.shape.first()
            );
            let y = Tensor::from_i32(&[n], y);
            let opts = ExtractOptions {
                registry: None,
                topology: Topology::local(threads),
                key: p.key,
                trace_label: None,
            };
            let out = model.extended_backward_slice(
                &p.params,
                &x,
                &y,
                &p.extensions,
                &opts,
                offset,
                p.global_n,
            )?;
            Ok((
                protocol::ok_reply_with(vec![(
                    "quantities",
                    protocol::quantities_to_json(&out),
                )]),
                false,
            ))
        }
        "merge" => {
            let parts = msg
                .get("parts")?
                .as_arr()?
                .iter()
                .map(protocol::quantities_from_json)
                .collect::<Result<Vec<_>>>()?;
            let merged = ReducePlan::of(&ExtensionSet::builtin())
                .merge(parts)?;
            Ok((
                protocol::ok_reply_with(vec![(
                    "quantities",
                    protocol::quantities_to_json(&merged),
                )]),
                false,
            ))
        }
        "shutdown" => Ok((protocol::ok_reply(), true)),
        other => bail!("unknown shard op {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn be() -> NativeBackend {
        NativeBackend::new()
    }

    #[test]
    fn handshake_checks_the_schema() {
        let mut plan = None;
        let (reply, down) = handle(
            &be(),
            1,
            &mut plan,
            &protocol::handshake(),
        )
        .unwrap();
        assert!(!down);
        let v = protocol::expect_ok(&reply).unwrap();
        assert_eq!(
            v.get("schema").unwrap().as_str().unwrap(),
            SHARD_SCHEMA
        );
        assert!(
            v.get("threads").unwrap().as_usize().unwrap() >= 1
        );
        let err = handle(
            &be(),
            1,
            &mut plan,
            "{\"op\":\"handshake\",\"schema\":\"bogus/v9\"}",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("schema mismatch"), "{err}");
    }

    #[test]
    fn extract_before_plan_and_unknown_ops_are_rejected() {
        let mut plan = None;
        let err = handle(
            &be(),
            1,
            &mut plan,
            "{\"op\":\"extract_slice\",\"offset\":0,\
             \"x\":{\"shape\":[1,1],\"data\":[0]},\"y\":[0]}",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("before plan"), "{err}");
        assert!(handle(&be(), 1, &mut plan, "{\"op\":\"warp\"}")
            .is_err());
        // Op-level failures become error replies at the session
        // layer; shutdown is the only op that ends the loop.
        let (_, down) = handle(
            &be(),
            1,
            &mut plan,
            &protocol::shutdown(),
        )
        .unwrap();
        assert!(down);
    }

    #[test]
    fn plan_rejects_unknown_models_and_extensions() {
        let backend = be();
        let mut plan = None;
        let err = handle(
            &backend,
            1,
            &mut plan,
            &protocol::plan("logrej", &[], 4, None, &[]),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("logrej"), "{err}");
        let err = handle(
            &backend,
            1,
            &mut plan,
            &protocol::plan(
                "logreg",
                &["batch_gradd".to_string()],
                4,
                None,
                &[],
            ),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("batch_gradd"), "{err}");
        assert!(plan.is_none());
    }
}
