//! backpack-rs: reproduction of "BackPACK: Packing more into Backprop"
//! (Dangel, Kunstner & Hennig, ICLR 2020) on a Rust + JAX + Pallas
//! stack — usable as a library.
//!
//! Layer 3 of the three-layer architecture (see DESIGN.md): a training
//! and benchmarking coordinator that executes training graphs through
//! a pluggable [`Backend`]:
//!
//! * **native** (default) -- forward + generalized backward pass with
//!   every BackPACK first- and second-order quantity in pure Rust,
//!   zero external dependencies, batch-parallel over all cores. Each
//!   quantity is an [`Extension`] module dispatched through an
//!   [`ExtensionSet`] registry ([`backend::extensions`]), so new
//!   quantities drop in without engine surgery — the paper's §3
//!   architecture claim, realized;
//! * **pjrt** (cargo feature `pjrt`) -- AOT-lowered HLO artifacts
//!   (produced once by `python/compile/aot.py`) executed through the
//!   PJRT C API. Python never runs on the training path.
//!
//! # Quickstart
//!
//! The Rust analogue of the paper's Fig. 1: ONE extended backward
//! pass returns the gradient **and** every requested quantity.
//! Artifacts are addressed through the typed API ([`ArtifactId`] /
//! [`Signature`]), which round-trips with the string naming scheme
//! (`"logreg_batch_grad+variance_n32".parse()` works too).
//!
//! ```
//! use backpack_rs::{
//!     ArtifactId, Backend, Exec, NativeBackend, Signature,
//! };
//! use backpack_rs::coordinator::train::{build_inputs, init_params};
//! use backpack_rs::data::{DatasetSpec, Synthetic};
//! use backpack_rs::runtime::Tensor;
//!
//! # fn main() -> anyhow::Result<()> {
//! let be = NativeBackend::new();
//! // logreg (Linear(784, 10) + CrossEntropy) with every first-order
//! // extension in one synthesized graph; any batch size works.
//! let sig = Signature::extract([
//!     "batch_grad", "batch_l2", "sq_moment", "variance",
//! ])?;
//! let id = ArtifactId::new("logreg", sig, 32)?;
//! let exe = be.load_id(&id)?;
//!
//! // Synthetic MNIST batch (DESIGN.md §3) + fan-in initialized
//! // parameters from the artifact spec.
//! let ds = Synthetic::new(DatasetSpec::by_name("mnist").unwrap(), 0);
//! let idx: Vec<usize> = (0..32).collect();
//! let (xv, yv) = ds.batch(0, &idx);
//! let x = Tensor::from_f32(&[32, 784], xv);
//! let y = Tensor::from_i32(&[32], yv);
//! let params = init_params(exe.spec(), 0);
//!
//! // ONE extended backward pass.
//! let out = exe.run(&build_inputs(&params, x, y, None))?;
//!
//! // param.grad AND param.variance, like Fig. 1's print.
//! assert!(out.loss()? > 0.0);
//! assert_eq!(out.get("grad/0/w")?.shape, vec![10, 784]);
//! assert_eq!(out.get("variance/0/w")?.shape, vec![10, 784]);
//! assert_eq!(out.get("batch_l2/0/w")?.shape, vec![32]);
//! // Variance is non-negative by construction.
//! assert!(out.get("variance/0/w")?.f32s()?.iter().all(|v| *v >= -1e-6));
//! # Ok(()) }
//! ```
//!
//! Models come from the registry ([`Model::logreg`], [`Model::mlp`],
//! the conv zoo incl. the Fig. 9 [`Model::conv_3c3d_sigmoid`]) or
//! from [`Model::with_input`] over the [`Layer`] enum; quantities
//! beyond the built-in ten (which include `diag_h`'s full-Hessian
//! residual recursion, DESIGN.md §11) register through
//! [`ExtensionSet`] (direct engine calls) or
//! [`NativeBackend::register_extension`] (served as artifact names) —
//! see [`backend::extensions`] for a complete user-defined extension.
//!
//! Direct engine calls take [`ExtractOptions`] with an explicit
//! execution [`Topology`]: [`Topology::local`] shards the batch over
//! in-process threads, [`Topology::Workers`] fans it out to
//! `backpack worker` processes — same quantities, same
//! [`ReducePlan`] merge, different parallelism substrate:
//!
//! ```
//! use backpack_rs::{ExtractOptions, Model, Topology};
//! use backpack_rs::runtime::Tensor;
//!
//! # fn main() -> anyhow::Result<()> {
//! let m = Model::logreg();
//! let params: Vec<Tensor> = m
//!     .param_specs()
//!     .iter()
//!     .map(|t| {
//!         let k: usize = t.shape.iter().product();
//!         Tensor::from_f32(&t.shape, vec![0.01; k])
//!     })
//!     .collect();
//! let x = Tensor::from_f32(&[4, 784], vec![0.5; 4 * 784]);
//! let y = Tensor::from_i32(&[4], vec![0, 1, 2, 3]);
//! let opts = ExtractOptions {
//!     topology: Topology::local(2), // Topology::workers(2) for processes
//!     ..ExtractOptions::default()
//! };
//! let out = m.extended_backward(
//!     &params, &x, &y, &["variance".to_string()], &opts)?;
//! assert_eq!(out["variance/0/w"].shape, vec![10, 784]);
//! # Ok(()) }
//! ```
//!
//! For extraction as a *service* — many clients, one engine — the
//! [`serve`] module runs the same typed API behind a batching daemon
//! (`backpack serve`, protocol `backpack-serve/v1`, docs/serve.md).
//!
//! For extraction across *processes* — N `backpack worker` processes
//! each walking a contiguous slice of the batch, merged by a
//! coordinator exactly as thread shards merge ([`ReducePlan`]) — the
//! [`dist`] module speaks `backpack-shard/v1` over the shared
//! [`wire`] codec; select it with [`Topology::Workers`] in
//! [`ExtractOptions`] or `backpack extract --workers N`
//! (docs/distributed.md).

pub mod backend;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod figures;
pub mod json;
pub mod linalg;
pub mod obs;
pub mod optim;
pub mod parallel;
pub mod runtime;
pub mod serve;
pub mod wire;

pub use backend::api::{suggest, ArtifactId, Signature};
pub use backend::extensions::{
    Extension, ExtensionSet, FinishCtx, LayerCtx, LayerOp,
    PerSampleGrads, Quantities, Reduce, ReducePlan, ReduceRule,
    ShardCtx, Walk,
};
pub use backend::layers::Layer;
pub use backend::model::{
    ExtractOptions, Model, ParamBlock, Topology, NATIVE_EXTENSIONS,
};
pub use backend::native::NativeBackend;
pub use backend::{
    open, open_kind, open_with, Backend, BackendKind, Exec, Outputs,
};
pub use bench::{
    compare_baselines, compare_files, BaselineCase, CompareReport,
    Stats, BENCH_SCHEMA, COMPARE_SCHEMA,
};
pub use json::Json;
pub use obs::{
    Histogram, MetricsAgg, Trace, METRICS_SCHEMA, TRACE_SCHEMA,
};
pub use runtime::{ArtifactSpec, Tensor, TensorSpec};
pub use serve::{
    LoadgenConfig, LoadgenReport, ServeConfig, Server, ServerHandle,
    SERVEBENCH_SCHEMA,
};
