//! backpack-rs: reproduction of "BackPACK: Packing more into Backprop"
//! (Dangel, Kunstner & Hennig, ICLR 2020) on a Rust + JAX + Pallas stack.
//!
//! Layer 3 of the three-layer architecture (see DESIGN.md): a training
//! and benchmarking coordinator that executes training graphs through
//! a pluggable [`backend::Backend`]:
//!
//! * **native** (default) -- forward + generalized backward pass with
//!   every BackPACK first- and second-order extension in pure Rust,
//!   zero external dependencies;
//! * **pjrt** (cargo feature `pjrt`) -- AOT-lowered HLO artifacts
//!   (produced once by `python/compile/aot.py`) executed through the
//!   PJRT C API. Python never runs on the training path.
pub mod backend;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod json;
pub mod linalg;
pub mod optim;
pub mod parallel;
pub mod runtime;
pub mod figures;
