//! Run logs, cross-seed aggregation (median + quartiles, the paper's
//! Fig. 7 presentation) and CSV/markdown writers.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// One evaluation point during training.
#[derive(Debug, Clone, Copy)]
pub struct EvalPoint {
    pub step: usize,
    pub test_loss: f32,
    pub test_accuracy: f32,
}

/// Metrics of a single training run (one seed).
#[derive(Debug, Clone, Default)]
pub struct RunLog {
    /// (step, mini-batch training loss)
    pub train_loss: Vec<(usize, f32)>,
    pub evals: Vec<EvalPoint>,
    pub diverged: bool,
    pub wall_time_s: f64,
    /// Mean per-step execute time (seconds), averaged over the steps
    /// actually executed.
    pub step_time_s: f64,
    /// Steps actually executed (< the configured count on divergence).
    pub steps_run: usize,
}

impl RunLog {
    pub fn final_accuracy(&self) -> f32 {
        self.evals.last().map(|e| e.test_accuracy).unwrap_or(0.0)
    }

    pub fn final_train_loss(&self) -> f32 {
        self.train_loss.last().map(|(_, l)| *l).unwrap_or(f32::NAN)
    }
}

/// Percentile of a (small) slice; linear interpolation, q in [0,1].
pub fn percentile(values: &mut [f32], q: f32) -> f32 {
    assert!(!values.is_empty());
    values.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (values.len() - 1) as f32;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        values[lo]
    } else {
        let w = pos - lo as f32;
        values[lo] * (1.0 - w) + values[hi] * w
    }
}

/// Median + quartiles of per-seed series, aligned by position
/// (all seeds log at identical steps).
#[derive(Debug, Clone)]
pub struct Quartiles {
    pub steps: Vec<usize>,
    pub q25: Vec<f32>,
    pub q50: Vec<f32>,
    pub q75: Vec<f32>,
}

pub fn aggregate<F>(runs: &[RunLog], extract: F) -> Quartiles
where
    F: Fn(&RunLog) -> Vec<(usize, f32)>,
{
    let series: Vec<Vec<(usize, f32)>> =
        runs.iter().map(&extract).collect();
    let len = series.iter().map(|s| s.len()).min().unwrap_or(0);
    let mut out = Quartiles {
        steps: Vec::new(),
        q25: Vec::new(),
        q50: Vec::new(),
        q75: Vec::new(),
    };
    for i in 0..len {
        let mut vals: Vec<f32> =
            series.iter().map(|s| s[i].1).collect();
        out.steps.push(series[0][i].0);
        out.q25.push(percentile(&mut vals, 0.25));
        out.q50.push(percentile(&mut vals, 0.50));
        out.q75.push(percentile(&mut vals, 0.75));
    }
    out
}

/// Write a CSV file, creating parent directories.
pub fn write_csv(path: &Path, header: &str, rows: &[Vec<String>])
    -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    writeln!(f, "{header}")?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Render an aligned markdown table (printed to stdout by runners).
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> =
        headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| -> String {
        let padded: Vec<String> = cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
            .collect();
        format!("| {} |", padded.join(" | "))
    };
    let mut out = fmt_row(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
    );
    out.push('\n');
    out.push_str(&format!(
        "|{}|",
        widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("|")
    ));
    for row in rows {
        out.push('\n');
        out.push_str(&fmt_row(row));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let mut v = vec![3.0, 1.0, 2.0];
        assert_eq!(percentile(&mut v, 0.5), 2.0);
        assert_eq!(percentile(&mut v.clone(), 0.0), 1.0);
        assert_eq!(percentile(&mut v, 1.0), 3.0);
    }

    #[test]
    fn aggregate_median() {
        let mk = |l: f32| RunLog {
            train_loss: vec![(0, l), (10, l / 2.0)],
            ..Default::default()
        };
        let runs = vec![mk(1.0), mk(2.0), mk(3.0)];
        let q = aggregate(&runs, |r| r.train_loss.clone());
        assert_eq!(q.steps, vec![0, 10]);
        assert_eq!(q.q50, vec![2.0, 1.0]);
        assert_eq!(q.q25, vec![1.5, 0.75]);
    }

    #[test]
    fn markdown_renders() {
        let t = markdown_table(
            &["a", "bb"],
            &[vec!["1".into(), "2".into()]],
        );
        assert!(t.contains("| a | bb |"));
        assert!(t.lines().count() == 3);
    }
}
