//! The training loop: parameters live host-side; every step executes
//! one training graph (gradient + the optimizer's curvature
//! quantities) through the active [`Backend`] and applies the update
//! in Rust. Python is never on this path.

use std::time::Instant;

use anyhow::{Context, Result};

use super::metrics::{EvalPoint, RunLog};
use super::problems::Problem;
use crate::backend::{Backend, Exec};
use crate::data::{Batcher, Rng};
use crate::obs;
use crate::optim::{self, Hyper, NamedParam};
use crate::runtime::{ArtifactSpec, Init, Tensor};

/// Configuration of one training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub problem: String,
    pub optimizer: String,
    pub hyper: Hyper,
    pub steps: usize,
    pub seed: u64,
    pub eval_every: usize,
    /// Recompute Kronecker inverses every k steps (1 = paper-faithful).
    pub inv_every: usize,
    /// Log the training loss every k steps.
    pub log_every: usize,
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            problem: "mnist_logreg".into(),
            optimizer: "sgd".into(),
            hyper: Hyper::default(),
            steps: 200,
            seed: 0,
            eval_every: 25,
            inv_every: 1,
            log_every: 5,
            verbose: false,
        }
    }
}

/// Initialize parameters per the spec's recorded init rules (uniform
/// fan-in bounds for weights, zeros for biases), seeded.
pub fn init_params(spec: &ArtifactSpec, seed: u64) -> Vec<NamedParam> {
    let mut rng = Rng::new(seed ^ 0x1417);
    spec.param_inputs()
        .iter()
        .map(|t| {
            let n: usize = t.shape.iter().product();
            let data = match t.init.as_ref().expect("param init") {
                Init::Zeros => vec![0.0; n],
                Init::Uniform { bound } => (0..n)
                    .map(|_| rng.uniform_in(-bound, *bound))
                    .collect(),
            };
            NamedParam {
                name: t.name.clone(),
                tensor: Tensor::from_f32(&t.shape, data),
            }
        })
        .collect()
}

/// Assemble the artifact input vector: params, x, y, [key].
pub fn build_inputs(
    params: &[NamedParam],
    x: Tensor,
    y: Tensor,
    key: Option<[u32; 2]>,
) -> Vec<Tensor> {
    let mut inputs: Vec<Tensor> =
        params.iter().map(|p| p.tensor.clone()).collect();
    inputs.push(x);
    inputs.push(y);
    if let Some(k) = key {
        inputs.push(Tensor::from_u32(&[2], vec![k[0], k[1]]));
    }
    inputs
}

/// Run one training configuration; returns the metric log.
pub fn train(be: &dyn Backend, problem: &Problem, cfg: &TrainConfig)
    -> Result<RunLog> {
    let mut opt = optim::build(&cfg.optimizer, cfg.hyper, cfg.inv_every)?;
    let artifact = be.find_train(
        problem.model,
        problem.side,
        opt.ext_signature(),
        problem.train_batch,
    )?;
    let exe = be.load(&artifact)?;
    let eval_exe = be.load(problem.eval_artifact)?;
    let has_key = exe.spec().has_key;

    let mut params = init_params(exe.spec(), cfg.seed);
    let dataset = problem.make_dataset(0xDA7A5E_u64)?;
    let mut batcher =
        Batcher::new(dataset, problem.train_batch, cfg.seed);

    let mut log = RunLog::default();
    let start = Instant::now();
    let mut exec_total = 0.0f64;
    let mut steps_run = 0usize;

    for step in 0..cfg.steps {
        let (x, y) = batcher.next_batch();
        let key = has_key
            .then(|| [cfg.seed as u32 ^ 0x5EED, step as u32]);
        let inputs = build_inputs(&params, x, y, key);
        let out = exe.run(&inputs).context("train step")?;
        exec_total += out.exec_time.as_secs_f64();
        steps_run += 1;
        let loss = out.loss()?;
        if !loss.is_finite() {
            log.diverged = true;
            obs::add(obs::Counter::TrainDivergences, 1);
            if cfg.verbose {
                obs::progress(format_args!(
                    "  diverged at step {step} (loss={loss})"
                ));
            }
            break;
        }
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            log.train_loss.push((step, loss));
        }
        if step % cfg.eval_every == 0 || step + 1 == cfg.steps {
            let ev =
                evaluate(eval_exe.as_ref(), &params, &mut batcher, step)?;
            if cfg.verbose {
                obs::progress(format_args!(
                    "  step {step:4} loss {loss:.4} \
                     test_loss {:.4} test_acc {:.3}",
                    ev.test_loss, ev.test_accuracy
                ));
            }
            log.evals.push(ev);
        }
        opt.step(&mut params, &out)?;
    }
    log.wall_time_s = start.elapsed().as_secs_f64();
    // Average over the steps actually executed: an early divergence
    // break must not dilute the per-step time.
    log.steps_run = steps_run;
    log.step_time_s = exec_total / steps_run.max(1) as f64;
    Ok(log)
}

/// Held-out evaluation: average the eval graph over two windows of
/// the test split.
pub fn evaluate(
    eval_exe: &dyn Exec,
    params: &[NamedParam],
    batcher: &mut Batcher,
    step: usize,
) -> Result<EvalPoint> {
    let n = eval_exe.spec().batch_size;
    let mut loss = 0.0;
    let mut acc = 0.0;
    let windows = 2;
    for w in 0..windows {
        let (x, y) = batcher.eval_batch(n, w * n);
        let inputs = build_inputs(params, x, y, None);
        let out = eval_exe.run(&inputs)?;
        loss += out.get("loss")?.item_f32()?;
        acc += out.get("accuracy")?.item_f32()?;
    }
    Ok(EvalPoint {
        step,
        test_loss: loss / windows as f32,
        test_accuracy: acc / windows as f32,
    })
}
