//! Layer-3 coordination: problem registry, training loop, DeepOBS-style
//! tuning protocol, metrics aggregation.
pub mod gridsearch;
pub mod metrics;
pub mod problems;
pub mod train;

pub use gridsearch::{GridPreset, GridResult};
pub use metrics::{EvalPoint, Quartiles, RunLog};
pub use problems::{by_name, Problem, PROBLEMS};
pub use train::{train, TrainConfig};
