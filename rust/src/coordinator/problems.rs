//! DeepOBS-style test-problem registry (paper Table 3).
//!
//! Each problem binds a model, a synthetic dataset, the training batch
//! size and the evaluation artifact. Batch sizes are the CPU-scaled
//! values documented in DESIGN.md §3 (paper: 128, 256 for CIFAR-100).
//! Every problem -- fully-connected and convolutional -- is servable
//! by the default native backend (`tests::native_serves_every_problem`
//! pins this); the pjrt backend additionally serves the problems with
//! AOT artifacts.

use anyhow::{bail, Result};

use crate::data::{DatasetSpec, Synthetic};

/// One benchmark problem.
#[derive(Debug, Clone)]
pub struct Problem {
    /// DeepOBS codename, e.g. "cifar10_3c3d".
    pub codename: &'static str,
    /// Model key in the manifest ("logreg", "2c2d", "3c3d", "allcnnc").
    pub model: &'static str,
    /// Input side for side-parameterized models (0 otherwise).
    pub side: usize,
    pub dataset: &'static str,
    pub train_batch: usize,
    pub eval_artifact: &'static str,
    /// Optimizers that can run on this problem (paper Table 4: "-"
    /// entries are genuinely absent -- memory/scaling limits).
    pub optimizers: &'static [&'static str],
    /// True for problems only the native backend serves (no AOT
    /// artifacts exist for them; the pjrt integration suite skips
    /// these).
    pub native_only: bool,
}

pub const PROBLEMS: &[Problem] = &[
    Problem {
        codename: "mnist_logreg",
        model: "logreg",
        side: 0,
        dataset: "mnist",
        train_batch: 64,
        eval_artifact: "logreg_eval_n256",
        optimizers: &["momentum", "adam", "diag_ggn", "diag_ggn_mc",
                      "kfac", "kflr", "kfra"],
        native_only: false,
    },
    Problem {
        // Native-backend problem: the full fully-connected layer set
        // (Linear + ReLU + sigmoid) trainable without artifacts. KFRA
        // applies (paper footnote 5 only excludes large convolutions).
        codename: "mnist_mlp",
        model: "mlp",
        side: 0,
        dataset: "mnist",
        train_batch: 64,
        eval_artifact: "mlp_eval_n256",
        optimizers: &["momentum", "adam", "diag_ggn", "diag_ggn_mc",
                      "kfac", "kflr", "kfra"],
        native_only: true,
    },
    Problem {
        // Conv problem, native-servable since the im2col subsystem
        // (backend/conv/): KFRA stays absent (paper footnote 5).
        codename: "fmnist_2c2d",
        model: "2c2d",
        side: 0,
        dataset: "fmnist",
        train_batch: 32,
        eval_artifact: "2c2d_eval_n128",
        optimizers: &["momentum", "adam", "diag_ggn", "diag_ggn_mc",
                      "kfac", "kflr"],
        native_only: false,
    },
    Problem {
        // Conv problem, native-servable since the im2col subsystem.
        codename: "cifar10_3c3d",
        model: "3c3d",
        side: 0,
        dataset: "cifar10",
        train_batch: 32,
        eval_artifact: "3c3d_eval_n128",
        optimizers: &["momentum", "adam", "diag_ggn", "diag_ggn_mc",
                      "kfac", "kflr"],
        native_only: false,
    },
    Problem {
        codename: "cifar100_allcnnc",
        model: "allcnnc",
        side: 16,
        dataset: "cifar100",
        train_batch: 16,
        eval_artifact: "allcnnc16_eval_n64",
        optimizers: &["momentum", "adam", "diag_ggn_mc", "kfac"],
        native_only: false,
    },
];

pub fn by_name(codename: &str) -> Result<&'static Problem> {
    for p in PROBLEMS {
        if p.codename == codename {
            return Ok(p);
        }
    }
    bail!(
        "unknown problem {codename:?}; available: {:?}",
        PROBLEMS.iter().map(|p| p.codename).collect::<Vec<_>>()
    )
}

impl Problem {
    pub fn make_dataset(&self, seed: u64) -> Result<Synthetic> {
        let spec = DatasetSpec::by_name(self.dataset)
            .ok_or_else(|| anyhow::anyhow!("no dataset {}", self.dataset))?;
        Ok(Synthetic::new(spec, seed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves() {
        assert!(by_name("mnist_logreg").is_ok());
        assert!(by_name("cifar10_3c3d").is_ok());
        assert!(by_name("nope").is_err());
    }

    #[test]
    fn kfra_only_on_fully_connected_problems() {
        // Paper Table 4: KFRA's averaged backward does not scale to
        // the convolutional problems (footnote 5); it runs on the
        // fully-connected ones only.
        for p in PROBLEMS {
            let has = p.optimizers.contains(&"kfra");
            let fully_connected =
                matches!(p.codename, "mnist_logreg" | "mnist_mlp");
            assert_eq!(has, fully_connected, "{}", p.codename);
        }
    }

    #[test]
    fn datasets_exist() {
        for p in PROBLEMS {
            assert!(p.make_dataset(0).is_ok(), "{}", p.codename);
        }
    }

    #[test]
    fn native_serves_every_problem() {
        // The "flip" this registry relies on: all five problems --
        // including the conv ones -- resolve train artifacts for each
        // of their optimizers, plus the eval artifact, on the native
        // backend.
        use crate::backend::Backend;
        let be = crate::backend::native::NativeBackend::new();
        for p in PROBLEMS {
            assert!(
                be.spec(p.eval_artifact).is_ok(),
                "{}: eval {}", p.codename, p.eval_artifact
            );
            for opt in p.optimizers {
                let sig = match *opt {
                    "momentum" | "adam" | "sgd" => "grad",
                    other => other,
                };
                let name = be
                    .find_train(p.model, p.side, sig, p.train_batch)
                    .unwrap_or_else(|e| {
                        panic!("{}/{opt}: {e}", p.codename)
                    });
                assert!(be.spec(&name).is_ok(), "{name}");
            }
            // Dataset shape must match the model's input: the x spec
            // is [n, d] for flat models, [n, c, h, w] for image ones.
            let spec = be.spec(p.eval_artifact).unwrap();
            let ds = p.make_dataset(0).unwrap();
            let x_dim: usize = spec
                .inputs
                .iter()
                .find(|t| t.name == "x")
                .unwrap()
                .shape[1..]
                .iter()
                .product();
            assert_eq!(ds.spec.sample_dim(), x_dim, "{}", p.codename);
        }
    }
}
