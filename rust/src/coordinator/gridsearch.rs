//! DeepOBS tuning protocol (paper Appendix C.1/C.2):
//!
//! 1. grid-search (α, λ) with a single seed,
//! 2. select the run with the best final validation accuracy,
//! 3. rerun the winner with several seeds,
//! 4. report median + quartiles.
//!
//! The grid is Appendix C.2's; `GridPreset::Small` trims it for the
//! single-core budget (DESIGN.md §3).

use anyhow::Result;

use super::problems::Problem;
use super::train::{train, TrainConfig};
use crate::backend::Backend;
use crate::coordinator::metrics::RunLog;
use crate::obs;
use crate::optim::Hyper;

/// Appendix C.2 grids.
pub const PAPER_ALPHAS: &[f32] = &[1e-4, 1e-3, 1e-2, 1e-1, 1.0];
pub const PAPER_LAMBDAS: &[f32] = &[1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0];

/// Trimmed grids for expensive problems.
pub const SMALL_ALPHAS: &[f32] = &[1e-3, 1e-2, 1e-1];
pub const SMALL_LAMBDAS: &[f32] = &[1e-3, 1e-2, 1e-1];

/// Minimal grids for the CPU-heaviest problems (conv nets, 1 core).
pub const TINY_ALPHAS: &[f32] = &[1e-2, 1e-1];
pub const TINY_LAMBDAS: &[f32] = &[1e-2, 1e-1];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GridPreset {
    Paper,
    Small,
    Tiny,
}

impl GridPreset {
    pub fn alphas(&self) -> &'static [f32] {
        match self {
            GridPreset::Paper => PAPER_ALPHAS,
            GridPreset::Small => SMALL_ALPHAS,
            GridPreset::Tiny => TINY_ALPHAS,
        }
    }

    pub fn lambdas(&self, uses_damping: bool) -> Vec<f32> {
        if !uses_damping {
            return vec![0.0]; // baselines: only α is tuned
        }
        match self {
            GridPreset::Paper => PAPER_LAMBDAS.to_vec(),
            GridPreset::Small => SMALL_LAMBDAS.to_vec(),
            GridPreset::Tiny => TINY_LAMBDAS.to_vec(),
        }
    }
}

/// One grid point's outcome.
#[derive(Debug, Clone)]
pub struct GridPoint {
    pub lr: f32,
    pub damping: f32,
    pub final_accuracy: f32,
    pub final_train_loss: f32,
    pub diverged: bool,
}

/// Grid-search result: all points + the winner + its interior flag
/// (paper Table 4 marks whether the best setting is an interior point).
#[derive(Debug, Clone)]
pub struct GridResult {
    pub optimizer: String,
    pub points: Vec<GridPoint>,
    pub best: GridPoint,
    pub interior: bool,
    /// Seed reruns of the winner.
    pub reruns: Vec<RunLog>,
}

fn uses_damping(optimizer: &str) -> bool {
    !matches!(optimizer, "sgd" | "momentum" | "adam")
}

/// Run the full protocol for one (problem, optimizer).
#[allow(clippy::too_many_arguments)]
pub fn run_protocol(
    be: &dyn Backend,
    problem: &Problem,
    optimizer: &str,
    preset: GridPreset,
    search_steps: usize,
    final_steps: usize,
    seeds: usize,
    inv_every: usize,
    verbose: bool,
) -> Result<GridResult> {
    // Fail fast when the backend cannot serve this (model, optimizer)
    // at all -- e.g. a conv problem on the native backend. Without
    // this, every grid point's train() error would be recorded as a
    // bogus "diverged" run before the rerun stage surfaces it.
    let sig = crate::optim::build(optimizer, Hyper::default(), 1)?
        .ext_signature();
    be.find_train(
        problem.model, problem.side, sig, problem.train_batch,
    )?;

    let damped = uses_damping(optimizer);
    let mut points = Vec::new();
    for &lr in preset.alphas() {
        for &damping in &preset.lambdas(damped) {
            let cfg = TrainConfig {
                problem: problem.codename.into(),
                optimizer: optimizer.into(),
                hyper: Hyper { lr, damping, l2: 0.0 },
                steps: search_steps,
                seed: 0,
                eval_every: search_steps.max(1),
                log_every: (search_steps / 4).max(1),
                inv_every,
                ..Default::default()
            };
            // An optimizer failure at one grid point (e.g. a curvature
            // factor collapsing under an unstable (α, λ)) counts as a
            // diverged run, not a failed figure.
            obs::add(obs::Counter::GridPoints, 1);
            let pt = match train(be, problem, &cfg) {
                Ok(log) => GridPoint {
                    lr,
                    damping,
                    final_accuracy: if log.diverged {
                        0.0
                    } else {
                        log.final_accuracy()
                    },
                    final_train_loss: log.final_train_loss(),
                    diverged: log.diverged,
                },
                Err(e) => {
                    obs::add(obs::Counter::GridFailures, 1);
                    if verbose {
                        obs::progress(format_args!(
                            "  grid {optimizer} lr={lr:.0e} \
                             λ={damping:.0e} failed: {e}"
                        ));
                    }
                    GridPoint {
                        lr,
                        damping,
                        final_accuracy: 0.0,
                        final_train_loss: f32::NAN,
                        diverged: true,
                    }
                }
            };
            if verbose {
                obs::progress(format_args!(
                    "  grid {optimizer} lr={lr:.0e} λ={damping:.0e} \
                     acc={:.3}{}",
                    pt.final_accuracy,
                    if pt.diverged { " (diverged)" } else { "" }
                ));
            }
            points.push(pt);
        }
    }
    let best = points
        .iter()
        .cloned()
        .max_by(|a, b| {
            a.final_accuracy.partial_cmp(&b.final_accuracy).unwrap()
        })
        .expect("non-empty grid");
    let alphas = preset.alphas();
    let lambdas = preset.lambdas(damped);
    let interior = interior_point(&best, alphas, &lambdas, damped);

    let mut reruns = Vec::new();
    for seed in 0..seeds as u64 {
        let cfg = TrainConfig {
            problem: problem.codename.into(),
            optimizer: optimizer.into(),
            hyper: Hyper { lr: best.lr, damping: best.damping, l2: 0.0 },
            steps: final_steps,
            seed,
            eval_every: (final_steps / 8).max(1),
            log_every: (final_steps / 40).max(1),
            ..Default::default()
        };
        reruns.push(train(be, problem, &cfg)?);
    }
    Ok(GridResult {
        optimizer: optimizer.into(),
        points,
        best,
        interior,
        reruns,
    })
}

fn interior_point(
    best: &GridPoint,
    alphas: &[f32],
    lambdas: &[f32],
    damped: bool,
) -> bool {
    let a_in = best.lr > alphas[0] && best.lr < alphas[alphas.len() - 1];
    if !damped {
        return a_in;
    }
    let l_in = best.damping > lambdas[0]
        && best.damping < lambdas[lambdas.len() - 1];
    a_in && l_in
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(lr: f32, damping: f32) -> GridPoint {
        GridPoint {
            lr,
            damping,
            final_accuracy: 0.0,
            final_train_loss: 0.0,
            diverged: false,
        }
    }

    #[test]
    fn interior_detection() {
        let a = &[0.1f32, 0.2, 0.3];
        let l = &[1.0f32, 2.0, 3.0];
        assert!(interior_point(&pt(0.2, 2.0), a, l, true));
        assert!(!interior_point(&pt(0.1, 2.0), a, l, true));
        assert!(!interior_point(&pt(0.2, 3.0), a, l, true));
        assert!(interior_point(&pt(0.2, 3.0), a, l, false));
    }

    #[test]
    fn baselines_skip_damping_axis() {
        assert_eq!(GridPreset::Small.lambdas(false), vec![0.0]);
        assert_eq!(GridPreset::Small.lambdas(true).len(), 3);
    }
}
