//! Table regenerators: Table 3 (problem zoo + parameter counts, the
//! paper's checksums) and Table 4 (best hyperparameters per
//! optimizer x problem with interior-point flags).

use std::path::Path;

use anyhow::Result;

use crate::backend::Backend;
use crate::coordinator::gridsearch::{run_protocol, GridPreset};
use crate::coordinator::metrics::{markdown_table, write_csv};
use crate::coordinator::problems::{self, PROBLEMS};
use crate::runtime::numel;

/// Paper Table 3 parameter counts (reproduction checksums). The
/// `mnist_mlp` problem is a native-backend addition, not in the paper.
pub const PAPER_COUNTS: &[(&str, usize)] = &[
    ("mnist_logreg", 7_850),
    ("fmnist_2c2d", 3_274_634),
    ("cifar10_3c3d", 895_210),
    ("cifar100_allcnnc", 1_387_108),
];

/// Table 3: datasets, models, parameter counts -- verified against the
/// paper's numbers from the backend's specs alone. The native conv
/// subsystem serves every problem, so on the native backend an
/// unresolvable problem is a hard error; other backends (pjrt needs
/// `make artifacts`, and `native_only` problems never have artifacts)
/// degrade row-by-row.
pub fn table3(be: &dyn Backend, out_dir: &Path) -> Result<()> {
    println!("== Table 3: test problems ==");
    let mut rows = Vec::new();
    for p in PROBLEMS {
        let paper = PAPER_COUNTS
            .iter()
            .find(|(n, _)| *n == p.codename)
            .map(|(_, c)| *c);
        let (count, check) = match be
            .find_train(p.model, p.side, "grad", p.train_batch)
            .and_then(|name| be.spec(&name))
        {
            Ok(spec) => {
                let count: usize = spec
                    .param_inputs()
                    .iter()
                    .map(|t| numel(&t.shape))
                    .sum();
                let check = match paper {
                    Some(c) if c == count => "OK",
                    Some(_) => "MISMATCH",
                    None => "n/a",
                };
                (count.to_string(), check.to_string())
            }
            Err(_) if be.name() != "native" => (
                "-".to_string(),
                format!("unavailable on {}", be.name()),
            ),
            Err(e) => return Err(e),
        };
        rows.push(vec![
            p.codename.to_string(),
            p.model.to_string(),
            p.dataset.to_string(),
            count,
            paper.map(|c| c.to_string()).unwrap_or_default(),
            check,
        ]);
    }
    let headers = ["codename", "model", "dataset", "# params",
                   "paper", "check"];
    println!("{}", markdown_table(&headers, &rows));
    write_csv(&out_dir.join("table3_problems.csv"),
              &headers.join(","), &rows)?;
    Ok(())
}

/// Table 4: grid-search the requested problem and report the best
/// (α, λ) per optimizer with the interior flag.
#[allow(clippy::too_many_arguments)]
pub fn table4(
    be: &dyn Backend,
    problem_name: &str,
    preset: GridPreset,
    search_steps: usize,
    final_steps: usize,
    seeds: usize,
    inv_every: usize,
    out_dir: &Path,
    verbose: bool,
) -> Result<()> {
    let problem = problems::by_name(problem_name)?;
    println!("== Table 4: best hyperparameters, {problem_name} ==");
    let mut rows = Vec::new();
    for opt in problem.optimizers {
        let res = run_protocol(
            be, problem, opt, preset, search_steps, final_steps, seeds,
            inv_every, verbose,
        )?;
        rows.push(vec![
            opt.to_string(),
            format!("{:.0e}", res.best.lr),
            format!("{:.0e}", res.best.damping),
            if res.interior { "interior" } else { "boundary" }.into(),
            format!("{:.3}", res.best.final_accuracy),
            res.reruns
                .first()
                .map(|r| format!("{:.3}", r.final_accuracy()))
                .unwrap_or_default(),
        ]);
    }
    let headers = ["optimizer", "α", "λ", "grid position",
                   "search acc", "rerun acc"];
    println!("{}", markdown_table(&headers, &rows));
    write_csv(
        &out_dir.join(format!("table4_{problem_name}.csv")),
        &headers.join(","),
        &rows,
    )?;
    Ok(())
}
