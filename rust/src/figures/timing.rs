//! Timing figures: Fig. 3 (individual gradients), Fig. 6 (extension
//! overhead), Fig. 8 (exact-matrix propagation at C=100), Fig. 9
//! (Hessian diagonal vs GGN diagonal).
//!
//! The paper's claims are *relative* costs (extension time / gradient
//! time); we report the same ratios on this testbed. All four figures
//! run on the default native backend: the conv subsystem serves 3c3d
//! and allcnnc32, and Fig. 9's `diag_h` residual propagation runs
//! natively on the registered `3c3d_sigmoid` model (DESIGN.md §11) —
//! no pjrt fallback anywhere.

use std::path::Path;
use std::time::Duration;

use anyhow::Result;

use crate::backend::{Backend, Exec};
use crate::bench::{bench, fmt_time, Stats};
use crate::coordinator::metrics::{markdown_table, write_csv};
use crate::coordinator::train::{build_inputs, init_params};
use crate::data::{DatasetSpec, Synthetic};
use crate::runtime::Tensor;

/// Time one artifact on a fixed synthetic batch; returns stats.
pub fn time_artifact(
    be: &dyn Backend,
    name: &str,
    dataset: &str,
    iters: usize,
    budget_s: f64,
) -> Result<Stats> {
    let exe = be.load(name)?;
    let spec = exe.spec().clone();
    let n = spec.batch_size;
    let ds = Synthetic::new(
        DatasetSpec::by_name(dataset)
            .ok_or_else(|| anyhow::anyhow!("dataset {dataset}"))?,
        7,
    );
    let idx: Vec<usize> = (0..n).collect();
    let (xv, yv) = ds.batch(0, &idx);
    let x_shape: Vec<usize> = spec
        .inputs
        .iter()
        .find(|t| t.name == "x")
        .unwrap()
        .shape
        .clone();
    let x = Tensor::from_f32(&x_shape, xv);
    let y = Tensor::from_i32(&[n], yv);
    let params = init_params(&spec, 0);
    let key = spec.has_key.then_some([1u32, 2u32]);
    let inputs = build_inputs(&params, x, y, key);
    // compile+first-run outside the measurement
    exe.run(&inputs)?;
    let mut stats = bench(
        name,
        1,
        iters,
        Duration::from_secs_f64(budget_s),
        || {
            exe.run(&inputs).expect("execute");
        },
    );
    // Per-phase p50 from a few *traced* extra iterations, recorded as
    // additive fields: the untraced headline numbers above are what
    // the regression gate compares.
    stats.phase_p50_s = crate::bench::phase_breakdown(
        || {
            exe.run(&inputs).expect("execute");
        },
        (iters / 2).clamp(1, 3),
    );
    Ok(stats)
}

/// [`time_artifact`]'s process-parallel twin: time the same fixed
/// batch through [`crate::dist::coordinate`] against already-running
/// shard workers at `addrs` (the bench `--workers` dimension). The
/// phase breakdown picks up the coordinator's `dist_*` spans, so the
/// exported numbers split wire + merge overhead from compute.
pub fn time_dist_artifact(
    nb: &crate::backend::native::NativeBackend,
    model: &str,
    signature: &str,
    batch: usize,
    dataset: &str,
    addrs: &[String],
    iters: usize,
    budget_s: f64,
) -> Result<Stats> {
    use crate::backend::api::{ArtifactId, Signature};
    use crate::backend::model::{ExtractOptions, Topology};

    let sig: Signature = signature.parse()?;
    let Signature::Extract(extensions) = sig.clone() else {
        anyhow::bail!("the shard path extracts; {signature:?} is eval")
    };
    let id = ArtifactId::new(model, sig, batch)?;
    let name = id.to_string();
    let spec = nb.spec_id(&id)?;
    let n = spec.batch_size;
    let ds = Synthetic::new(
        DatasetSpec::by_name(dataset)
            .ok_or_else(|| anyhow::anyhow!("dataset {dataset}"))?,
        7,
    );
    let idx: Vec<usize> = (0..n).collect();
    let (xv, yv) = ds.batch(0, &idx);
    let x_shape: Vec<usize> = spec
        .inputs
        .iter()
        .find(|t| t.name == "x")
        .unwrap()
        .shape
        .clone();
    let x = Tensor::from_f32(&x_shape, xv);
    let y = Tensor::from_i32(&[n], yv);
    let params: Vec<Tensor> = init_params(&spec, 0)
        .into_iter()
        .map(|p| p.tensor)
        .collect();
    let opts = ExtractOptions {
        topology: Topology::Workers {
            n: addrs.len(),
            addrs: addrs.to_vec(),
        },
        key: spec.has_key.then_some([1u32, 2u32]),
        ..ExtractOptions::default()
    };
    let m = nb.model(model)?;
    // First run outside the measurement (pool warm-up worker-side).
    m.extended_backward(&params, &x, &y, &extensions, &opts)?;
    let mut stats = bench(
        &name,
        1,
        iters,
        Duration::from_secs_f64(budget_s),
        || {
            m.extended_backward(&params, &x, &y, &extensions, &opts)
                .expect("shard extract");
        },
    );
    stats.phase_p50_s = crate::bench::phase_breakdown(
        || {
            m.extended_backward(&params, &x, &y, &extensions, &opts)
                .expect("shard extract");
        },
        (iters / 2).clamp(1, 3),
    );
    Ok(stats)
}

/// Fig. 3: computing individual gradients -- for-loop (N separate
/// batch-1 passes) vs vectorized BatchGrad vs plain gradient.
pub fn fig3(be: &dyn Backend, iters: usize, out_dir: &Path) -> Result<()> {
    println!("== Fig. 3: individual gradients, 3c3d/CIFAR-10 ==");
    let loop1 = time_artifact(be, "3c3d_grad_n1", "cifar10", iters, 20.0)?;
    let mut rows = Vec::new();
    for n in [4usize, 16, 32] {
        let grad = time_artifact(
            be, &format!("3c3d_grad_n{n}"), "cifar10", iters, 20.0)?;
        let bg = time_artifact(
            be, &format!("3c3d_batch_grad_n{n}"), "cifar10", iters, 30.0)?;
        let forloop = loop1.p50 * n as f64;
        rows.push(vec![
            n.to_string(),
            fmt_time(grad.p50),
            fmt_time(bg.p50),
            fmt_time(forloop),
            format!("{:.2}", bg.p50 / grad.p50),
            format!("{:.2}", forloop / grad.p50),
            format!("{:.1}", forloop / bg.p50),
        ]);
    }
    let headers = [
        "N", "gradient", "BackPACK indiv", "for-loop indiv",
        "indiv/grad", "loop/grad", "speedup",
    ];
    println!("{}", markdown_table(&headers, &rows));
    write_csv(
        &out_dir.join("fig3_individual_gradients.csv"),
        &headers.join(","),
        &rows,
    )?;
    Ok(())
}

const FIG6_3C3D: &[(&str, &str)] = &[
    ("grad", "3c3d_grad_n64"),
    ("batch_grad", "3c3d_batch_grad_n64"),
    ("batch_l2", "3c3d_batch_l2_n64"),
    ("sq_moment", "3c3d_sq_moment_n64"),
    ("variance", "3c3d_variance_n64"),
    ("diag_ggn_mc", "3c3d_diag_ggn_mc_n64"),
    ("diag_ggn", "3c3d_diag_ggn_n64"),
    ("kfac", "3c3d_kfac_n64"),
    ("kflr", "3c3d_kflr_n64"),
];

const FIG6_ALLCNNC: &[(&str, &str)] = &[
    ("grad", "allcnnc32_grad_n16"),
    ("batch_grad", "allcnnc32_batch_grad_n16"),
    ("batch_l2", "allcnnc32_batch_l2_n16"),
    ("sq_moment", "allcnnc32_sq_moment_n16"),
    ("variance", "allcnnc32_variance_n16"),
    ("diag_ggn_mc", "allcnnc32_diag_ggn_mc_n16"),
    ("kfac", "allcnnc32_kfac_n16"),
];

/// Fig. 6: overhead of gradient + extension vs gradient alone.
pub fn fig6(be: &dyn Backend, iters: usize, out_dir: &Path) -> Result<()> {
    for (title, dataset, table) in [
        ("3c3d / CIFAR-10 (N=64)", "cifar10", FIG6_3C3D),
        ("All-CNN-C / CIFAR-100 32x32 (N=16)", "cifar100_32",
         FIG6_ALLCNNC),
    ] {
        println!("== Fig. 6: overhead, {title} ==");
        let mut rows = Vec::new();
        let mut grad_time = None;
        for (label, artifact) in table {
            let s = time_artifact(be, artifact, dataset, iters, 45.0)?;
            let g = *grad_time.get_or_insert(s.p50);
            rows.push(vec![
                label.to_string(),
                fmt_time(s.p50),
                format!("{:.2}", s.p50 / g),
            ]);
        }
        let headers = ["extension", "p50 time", "overhead vs grad"];
        println!("{}", markdown_table(&headers, &rows));
        let fname = format!(
            "fig6_overhead_{}.csv",
            title.split(' ').next().unwrap().to_lowercase()
        );
        write_csv(&out_dir.join(fname), &headers.join(","), &rows)?;
    }
    Ok(())
}

/// Fig. 8: KFLR / DiagGGN propagate C=100x more information than
/// KFAC / DiagGGN-MC on CIFAR-100 -- expect ~two orders of magnitude.
pub fn fig8(be: &dyn Backend, iters: usize, out_dir: &Path) -> Result<()> {
    println!("== Fig. 8: exact vs MC propagation, All-CNN-C C=100 (N=8) ==");
    let table = [
        ("grad", "allcnnc32_grad_n8"),
        ("diag_ggn_mc", "allcnnc32_diag_ggn_mc_n8"),
        ("kfac", "allcnnc32_kfac_n8"),
        ("diag_ggn", "allcnnc32_diag_ggn_n8"),
        ("kflr", "allcnnc32_kflr_n8"),
    ];
    let mut rows = Vec::new();
    let mut grad_time = None;
    let mut mc: Option<(String, f64)> = None;
    for (label, artifact) in table {
        let s = time_artifact(be, artifact, "cifar100_32", iters, 120.0)?;
        let g = *grad_time.get_or_insert(s.p50);
        let vs_mc = match (label, &mc) {
            ("diag_ggn", Some((_, t))) | ("kflr", Some((_, t))) => {
                format!("{:.0}x", s.p50 / t)
            }
            _ => "-".to_string(),
        };
        if label == "diag_ggn_mc" || label == "kfac" {
            mc = Some((label.to_string(), s.p50));
        }
        rows.push(vec![
            label.to_string(),
            fmt_time(s.p50),
            format!("{:.1}", s.p50 / g),
            vs_mc,
        ]);
    }
    let headers = ["method", "p50 time", "vs grad", "exact vs MC"];
    println!("{}", markdown_table(&headers, &rows));
    write_csv(&out_dir.join("fig8_large_output.csv"),
              &headers.join(","), &rows)?;
    Ok(())
}

/// Fig. 9: Hessian diagonal vs GGN diagonal when the network has one
/// sigmoid (residual propagation makes DiagH much more expensive: the
/// factor born at the sigmoid carries one column per activation
/// feature down the rest of the net). Runs on the native backend —
/// `3c3d_sigmoid` and `diag_h` are registry citizens like any other.
pub fn fig9(be: &dyn Backend, iters: usize, out_dir: &Path) -> Result<()> {
    println!("== Fig. 9: DiagH vs DiagGGN, 3c3d+sigmoid (N=8) ==");
    let table = [
        ("grad", "3c3d_sigmoid_grad_n8"),
        ("diag_ggn", "3c3d_sigmoid_diag_ggn_n8"),
        ("diag_h", "3c3d_sigmoid_diag_h_n8"),
    ];
    let mut rows = Vec::new();
    let mut grad_time = None;
    let mut ggn_time = None;
    for (label, artifact) in table {
        let s = time_artifact(be, artifact, "cifar10", iters, 120.0)?;
        let g = *grad_time.get_or_insert(s.p50);
        if label == "diag_ggn" {
            ggn_time = Some(s.p50);
        }
        let vs_ggn = match (label, ggn_time) {
            ("diag_h", Some(t)) => format!("{:.1}x", s.p50 / t),
            _ => "-".to_string(),
        };
        rows.push(vec![
            label.to_string(),
            fmt_time(s.p50),
            format!("{:.1}", s.p50 / g),
            vs_ggn,
        ]);
    }
    let headers = ["method", "p50 time", "vs grad", "DiagH vs DiagGGN"];
    println!("{}", markdown_table(&headers, &rows));
    write_csv(&out_dir.join("fig9_hessian_diag.csv"),
              &headers.join(","), &rows)?;
    Ok(())
}
