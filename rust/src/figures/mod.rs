//! Per-figure/table regenerators (paper evaluation section).
//!
//! Each runner produces the console table (same rows/series the paper
//! reports) and a CSV under `results/`. The mapping figure -> runner is
//! indexed in DESIGN.md §5.
pub mod curves;
pub mod tables;
pub mod timing;
