//! Optimizer-comparison figures (Figs. 7a, 7b, 10, 11): the DeepOBS
//! protocol -- grid-search, best-by-validation-accuracy, seed reruns,
//! median + quartiles -- per optimizer, on each test problem. All
//! four figures run on the default native backend, including the
//! convolutional 7a/7b/11 (im2col subsystem); the only remaining
//! skips are the paper's own Table 4 "-" entries (an optimizer that
//! does not apply to a problem, e.g. KFRA on conv nets).

use std::path::Path;

use anyhow::Result;

use crate::backend::Backend;
use crate::coordinator::gridsearch::{run_protocol, GridPreset};
use crate::coordinator::metrics::{
    aggregate, markdown_table, write_csv,
};
use crate::coordinator::problems;

/// Budget knobs for a curves figure (CPU-scaled; DESIGN.md §3).
#[derive(Debug, Clone, Copy)]
pub struct CurveBudget {
    pub preset: GridPreset,
    pub search_steps: usize,
    pub final_steps: usize,
    pub seeds: usize,
    /// Kronecker-inverse refresh interval (1 = paper-faithful; conv
    /// problems amortize on this testbed, see EXPERIMENTS.md §Perf).
    pub inv_every: usize,
}

/// Run one problem's optimizer comparison; writes
/// `results/<figure>_<optimizer>.csv` (training-loss and test-accuracy
/// quartile series) plus a summary table.
pub fn run_curves(
    be: &dyn Backend,
    figure: &str,
    problem_name: &str,
    optimizers: &[&str],
    budget: CurveBudget,
    out_dir: &Path,
    verbose: bool,
) -> Result<()> {
    let problem = problems::by_name(problem_name)?;
    println!(
        "== {figure}: {problem_name} (grid {:?}, search {} steps, \
         final {} steps, {} seeds) ==",
        budget.preset, budget.search_steps, budget.final_steps,
        budget.seeds
    );
    let mut summary = Vec::new();
    for opt in optimizers {
        if !problem.optimizers.contains(opt) {
            println!("  {opt}: skipped (unsupported on this problem, \
                      paper Table 4 '-')");
            continue;
        }
        let res = run_protocol(
            be, problem, opt, budget.preset, budget.search_steps,
            budget.final_steps, budget.seeds, budget.inv_every, verbose,
        )?;
        // quartile series over seeds
        let loss_q = aggregate(&res.reruns, |r| r.train_loss.clone());
        let acc_q = aggregate(&res.reruns, |r| {
            r.evals
                .iter()
                .map(|e| (e.step, e.test_accuracy))
                .collect()
        });
        let mut rows = Vec::new();
        for i in 0..loss_q.steps.len() {
            rows.push(vec![
                loss_q.steps[i].to_string(),
                "train_loss".into(),
                format!("{:.6}", loss_q.q25[i]),
                format!("{:.6}", loss_q.q50[i]),
                format!("{:.6}", loss_q.q75[i]),
            ]);
        }
        for i in 0..acc_q.steps.len() {
            rows.push(vec![
                acc_q.steps[i].to_string(),
                "test_accuracy".into(),
                format!("{:.6}", acc_q.q25[i]),
                format!("{:.6}", acc_q.q50[i]),
                format!("{:.6}", acc_q.q75[i]),
            ]);
        }
        write_csv(
            &out_dir.join(format!("{figure}_{opt}.csv")),
            "step,metric,q25,q50,q75",
            &rows,
        )?;
        let med_step = res
            .reruns
            .iter()
            .map(|r| r.step_time_s)
            .sum::<f64>()
            / res.reruns.len().max(1) as f64;
        summary.push(vec![
            opt.to_string(),
            format!("{:.0e}", res.best.lr),
            format!("{:.0e}", res.best.damping),
            if res.interior { "yes" } else { "no" }.into(),
            format!(
                "{:.4}",
                loss_q.q50.last().copied().unwrap_or(f32::NAN)
            ),
            format!(
                "{:.3}",
                acc_q.q50.last().copied().unwrap_or(f32::NAN)
            ),
            format!("{:.0}ms", med_step * 1e3),
        ]);
    }
    let headers = [
        "optimizer", "best α", "best λ", "interior", "final train loss",
        "final test acc", "step time",
    ];
    println!("{}", markdown_table(&headers, &summary));
    write_csv(
        &out_dir.join(format!("{figure}_summary.csv")),
        &headers.join(","),
        &summary,
    )?;
    Ok(())
}

/// The per-figure optimizer lists (paper legends).
pub fn figure_spec(figure: &str) -> Option<(&'static str,
                                            &'static [&'static str])> {
    Some(match figure {
        "fig7a" => ("cifar10_3c3d",
                    &["momentum", "adam", "diag_ggn", "diag_ggn_mc",
                      "kfac", "kflr"][..]),
        "fig7b" => ("cifar100_allcnnc",
                    &["momentum", "adam", "diag_ggn_mc", "kfac"][..]),
        "fig10" => ("mnist_logreg",
                    &["momentum", "adam", "diag_ggn", "diag_ggn_mc",
                      "kfac", "kflr", "kfra"][..]),
        "fig11" => ("fmnist_2c2d",
                    &["momentum", "adam", "diag_ggn", "diag_ggn_mc",
                      "kfac", "kflr"][..]),
        _ => return None,
    })
}
