//! Offline stub of the `xla` PJRT bindings.
//!
//! The BackPACK coordinator's PJRT runtime layer (`runtime/client.rs`,
//! behind the `pjrt` cargo feature) is written against the real `xla`
//! crate's API. That crate needs an XLA/PJRT toolchain that is not
//! available in this offline environment, so this stub mirrors the API
//! surface 1:1 and returns a descriptive error from every entry point:
//! the `pjrt` feature *compiles* everywhere, and *runs* once the real
//! bindings are substituted (swap the `xla` path dependency in
//! rust/Cargo.toml).
//!
//! Every method signature here is load-bearing: it is exercised by
//! `cargo check --features pjrt`, which keeps the runtime layer from
//! bit-rotting while the native backend is the default.

use std::fmt;

/// Error returned by every stub entry point.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "xla-stub: {what} requires the real XLA/PJRT bindings; this \
         build uses the offline stub (see rust/xla-stub/src/lib.rs). \
         Use `--backend native`, or link the real `xla` crate."
    ))
}

/// Element types a literal can hold (mirror of the real crate's trait).
pub trait NativeType: Copy + 'static {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u8 {}

/// Host-side literal (stub: carries no data).
#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation ready to compile (stub).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer returned by an execution (stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client (stub).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}
