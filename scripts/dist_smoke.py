#!/usr/bin/env python3
"""CI smoke test for process-parallel extraction (backpack-shard/v1).

Pure stdlib. Three scenarios against the release binary:

1. `backpack extract --workers 3` (three spawned worker processes)
   vs the same extraction on one local thread: identical key sets,
   Sum-reduced keys within 1e-5 relative, per-sample (Concat) keys
   **bitwise** identical — the equivalence docs/distributed.md
   promises.
2. The same extraction against an externally started
   `backpack worker` (banner-parsed address, --addrs), which must
   also match and must leave the worker alive afterwards
   (external workers are never shut down by a coordinator).
3. The failure path: a fake "worker" that accepts and immediately
   drops the connection must surface as a nonzero exit naming the
   shard worker — an error, not a hang.

Usage: python3 scripts/dist_smoke.py [path/to/backpack]
"""

import json
import os
import socket
import subprocess
import sys
import tempfile
import threading

PROBLEM = "mnist_logreg"
EXTENSIONS = "batch_grad+variance+diag_ggn"
N = 32
TIMEOUT_S = 120

CONCAT_PREFIXES = ("batch_grad/", "batch_l2/")


def run_extract(binary, extra, out):
    env = dict(os.environ, BACKPACK_THREADS="1")
    subprocess.run(
        [binary, "extract", "--problem", PROBLEM,
         "--extensions", EXTENSIONS, "--n", str(N), "--seed", "0",
         "--out", out, *extra],
        check=True, timeout=TIMEOUT_S, env=env,
    )
    with open(out) as f:
        doc = json.load(f)
    assert doc["schema"] == "backpack-extract/v1", doc["schema"]
    assert doc["n"] == N, doc["n"]
    return doc


def assert_equivalent(dist, local, label):
    dq, lq = dist["quantities"], local["quantities"]
    assert sorted(dq) == sorted(lq), (
        label, sorted(set(dq) ^ set(lq)))
    bitwise = close = 0
    for key in lq:
        a, b = dq[key], lq[key]
        assert a["shape"] == b["shape"], (label, key)
        assert len(a["data"]) == len(b["data"]), (label, key)
        if key.startswith(CONCAT_PREFIXES):
            # Per-sample rows: computed row-independently and
            # round-tripped bitwise by the wire codec.
            assert a["data"] == b["data"], (
                f"{label}: Concat key {key} not bitwise")
            bitwise += 1
        else:
            for u, v in zip(a["data"], b["data"]):
                assert u is not None and v is not None, (label, key)
                assert abs(u - v) <= 1e-5 * (1.0 + abs(v)), (
                    f"{label}: {key}: {u} vs {v}")
            close += 1
    assert bitwise >= 1, f"{label}: no Concat keys compared"
    assert close >= 3, f"{label}: too few Sum keys compared"
    print(f"{label}: {bitwise} keys bitwise, {close} keys <=1e-5 "
          f"({len(lq)} total), wall {dist['wall_s'] * 1e3:.1f} ms")


def main():
    binary = sys.argv[1] if len(sys.argv) > 1 else \
        "rust/target/release/backpack"
    tmp = tempfile.mkdtemp(prefix="backpack_dist_")
    a, b, c = (os.path.join(tmp, f) for f in
               ("workers.json", "local.json", "external.json"))

    # Reference: one process, one thread.
    local = run_extract(binary, ["--threads", "1"], b)
    assert local["workers"] == 0, local["workers"]

    # 1. Coordinator-spawned worker processes.
    dist = run_extract(binary, ["--workers", "3"], a)
    assert dist["workers"] == 3, dist["workers"]
    assert_equivalent(dist, local, "spawned workers=3 vs local")

    # 2. Externally started worker, address parsed off the banner.
    worker = subprocess.Popen(
        [binary, "worker", "--addr", "127.0.0.1:0",
         "--threads", "1"],
        stdout=subprocess.PIPE, text=True,
    )
    try:
        banner = worker.stdout.readline().strip()
        print(banner)
        assert banner.startswith(
            "backpack-shard/v1 listening on "), banner
        addr = banner.rsplit(" ", 1)[1]
        ext = run_extract(binary, ["--addrs", addr], c)
        assert ext["workers"] == 1, ext["workers"]
        assert_equivalent(ext, local, "external worker vs local")
        # External workers outlive the coordinator session.
        assert worker.poll() is None, \
            "coordinator shut down an external worker"
    finally:
        worker.kill()
        worker.wait()

    # 3. A dead "worker" is a named error, not a hang: accept and
    # immediately drop every connection.
    lst = socket.socket()
    lst.bind(("127.0.0.1", 0))
    lst.listen(4)
    dead_addr = "127.0.0.1:%d" % lst.getsockname()[1]
    stop = threading.Event()

    def reaper():
        lst.settimeout(0.2)
        while not stop.is_set():
            try:
                conn, _ = lst.accept()
                conn.close()
            except socket.timeout:
                continue
            except OSError:
                break

    t = threading.Thread(target=reaper)
    t.start()
    try:
        r = subprocess.run(
            [binary, "extract", "--problem", PROBLEM,
             "--extensions", "grad", "--n", "4",
             "--addrs", dead_addr],
            capture_output=True, text=True, timeout=TIMEOUT_S,
        )
        assert r.returncode != 0, \
            "extract succeeded against a dead worker"
        err = r.stderr
        assert "shard worker 0" in err, err
        assert "closed the connection" in err or \
            "sending to" in err, err
        print("dead-worker failure path OK: "
              + err.strip().splitlines()[0])
    finally:
        stop.set()
        t.join()
        lst.close()

    print("dist smoke OK")


if __name__ == "__main__":
    main()
