#!/usr/bin/env python3
"""Fill EXPERIMENTS.md placeholders from the campaign logs.

Extracts the markdown tables printed by the figure regenerators
(results/logs/*.log) and splices them into EXPERIMENTS.md at the
<!-- MARKER --> comments. Idempotent: markers are kept.
"""

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
LOGS = ROOT / "results" / "logs"


def tables_in(log_name: str) -> str:
    """All markdown tables (and their '== section ==' headers)."""
    path = LOGS / log_name
    if not path.exists():
        return f"*(pending: {log_name} not yet produced)*"
    out, keep = [], False
    for line in path.read_text().splitlines():
        if line.startswith("== "):
            out.append(f"**{line.strip('= ')}**\n")
            keep = False
        elif line.startswith("|"):
            out.append(line)
            keep = True
        elif keep and not line.startswith("|"):
            out.append("")
            keep = False
    return "\n".join(out).strip() or f"*(no tables in {log_name})*"


def e2e_summary() -> str:
    csv = ROOT / "results" / "e2e_train_cifar10.csv"
    if not csv.exists():
        return "*(pending: run `cargo run --release --example " \
               "train_cifar10`)*"
    rows = csv.read_text().splitlines()[1:]
    first = rows[0].split(",")
    last = rows[-1].split(",")
    every = max(1, len(rows) // 12)
    curve = "\n".join(
        f"| {r.split(',')[0]} | {float(r.split(',')[1]):.4f} |"
        for r in rows[::every])
    return (
        f"Loss {float(first[1]):.3f} (step {first[0]}) → "
        f"{float(last[1]):.3f} (step {last[0]}).\n\n"
        f"| step | train loss |\n|---|---|\n{curve}"
    )


MARKERS = {
    "FIG3_RESULTS": lambda: tables_in("fig3.log"),
    "FIG6_RESULTS": lambda: tables_in("fig6.log"),
    "FIG8_RESULTS": lambda: tables_in("fig8.log"),
    "FIG9_RESULTS": lambda: tables_in("fig9.log"),
    "CURVES_RESULTS": lambda: "\n\n".join(
        tables_in(f"{f}.log")
        for f in ["fig10", "fig11", "fig7a", "fig7b"]),
    "TABLE4_RESULTS": lambda: tables_in("table4.log"),
    "PERF_L3_RESULTS": lambda: tables_in("ablation.log"),
    "E2E_RESULTS": e2e_summary,
}


def main():
    path = ROOT / "EXPERIMENTS.md"
    text = path.read_text()
    for marker, fn in MARKERS.items():
        pat = re.compile(
            rf"<!-- {marker} -->.*?(?=\n## |\n### |\Z)", re.S)
        if f"<!-- {marker} -->" in text:
            replacement = f"<!-- {marker} -->\n\n{fn()}\n"
            text = pat.sub(lambda _: replacement, text, count=1)
            print(f"filled {marker}")
        else:
            print(f"marker {marker} missing", file=sys.stderr)
    path.write_text(text)


if __name__ == "__main__":
    main()
