#!/usr/bin/env python3
"""CI validator for `backpack loadgen` output (backpack-servebench/v1).

Pure stdlib. Checks the document written by the CI loadgen smoke
step: schema, client/traffic floors, a self-consistent e2e latency
histogram, bench-compatible cases[] rows (name + p50_s), the
daemon-side serve.latency section, and that coalescing actually
happened (the smoke runs >= 8 same-signature clients through a
generous linger window, so zero coalescing means batching broke).

Usage: python3 scripts/servebench_check.py SERVEBENCH.json
"""

import json
import sys


def check_histogram(h, label):
    assert h["count"] >= 1, (label, h)
    assert h["min"] is not None and h["max"] is not None, (label, h)
    assert h["min"] <= h["max"], (label, h)
    # Bucket counts sum to the total count.
    assert sum(c for _, c in h["buckets"]) == h["count"], (label, h)
    p50, p95, p99 = h["p50"], h["p95"], h["p99"]
    assert p50 is not None, (label, h)
    assert p50 <= p95 <= p99, (label, p50, p95, p99)
    assert h["min"] <= p50 and p99 <= h["max"], (label, h)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "SERVEBENCH.json"
    with open(path) as f:
        doc = json.load(f)

    assert doc["schema"] == "backpack-servebench/v1", doc["schema"]
    assert doc["clients"] >= 8, doc["clients"]
    assert doc["requests"] > 0, "no request succeeded"
    assert doc["errors"] == 0, f"{doc['errors']} errors"
    assert doc["throughput_rps"] > 0, doc["throughput_rps"]
    assert doc["duration_s"] > 0, doc["duration_s"]

    # Client-observed e2e latency histogram: one sample per request.
    e2e = doc["e2e_us"]
    check_histogram(e2e, "e2e_us")
    assert e2e["count"] == doc["requests"], (e2e["count"],
                                             doc["requests"])

    # Bench-compatible cases: the rows `bench --compare` gates on.
    cases = {c["name"]: c["p50_s"] for c in doc["cases"]}
    model = doc["model"]
    for want in (f"loadgen_{model}_e2e_p50",
                 f"loadgen_{model}_e2e_p95",
                 f"loadgen_{model}_e2e_p99",
                 f"loadgen_{model}_inv_throughput",
                 f"loadgen_{model}_stage_extract_p50"):
        assert want in cases, (want, sorted(cases))
    for name, p50_s in cases.items():
        assert p50_s > 0, (name, p50_s)
    assert cases[f"loadgen_{model}_e2e_p50"] <= \
        cases[f"loadgen_{model}_e2e_p99"], cases

    # The daemon's own view rode along: per-stage latency and real
    # coalescing under the concurrent-client load.
    server = doc["server"]
    assert server is not None, "no server metrics captured"
    lat = server["latency"]
    for stage in ("queue", "linger", "extract", "reply"):
        assert lat["stages"][stage]["count"] >= 1, (stage, lat)
    assert lat["coalescing"]["rate"] is not None, lat
    assert lat["coalescing"]["rate"] > 0, \
        f"no coalescing under load: {lat['coalescing']}"
    assert server["coalesced_max"] >= 2, server["coalesced_max"]

    print(f"servebench OK: {doc['clients']} clients, "
          f"{doc['requests']} requests "
          f"({doc['throughput_rps']:.0f} req/s), "
          f"e2e p50 {e2e['p50']:.0f}us p99 {e2e['p99']:.0f}us, "
          f"coalescing rate "
          f"{lat['coalescing']['rate'] * 100:.1f}%")


if __name__ == "__main__":
    main()
