#!/usr/bin/env python3
"""CI smoke test for `backpack serve` (protocol backpack-serve/v1).

Pure stdlib. Starts the daemon on an ephemeral port, fires 8
concurrent scripted clients at logreg grad+diag_ggn extractions
(the mnist_logreg problem's model), validates every reply and the
live metrics against the backpack-metrics/v1 schema, then checks a
clean SIGTERM shutdown.

Usage: python3 scripts/serve_smoke.py [path/to/backpack]
"""

import json
import os
import signal
import socket
import struct
import subprocess
import sys
import tempfile
import threading

CLIENTS = 8
PER = 4          # samples per client
IN_NUMEL = 784   # mnist 28*28
CLASSES = 10

METRICS_KEYS = [
    "counters", "details", "overhead", "phases",
    "quantities", "schema", "shards", "wall_s",
]


def send_frame(sock, payload):
    data = json.dumps(payload).encode()
    sock.sendall(struct.pack(">I", len(data)) + data)


def read_exact(sock, n):
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise EOFError("connection closed mid-frame")
        buf += chunk
    return buf


def read_frame(sock):
    (n,) = struct.unpack(">I", read_exact(sock, 4))
    return json.loads(read_exact(sock, n))


def check_metrics_object(m):
    assert sorted(m.keys()) == METRICS_KEYS, sorted(m.keys())
    assert m["schema"] == "backpack-metrics/v1", m["schema"]
    assert isinstance(m["phases"], dict)
    assert isinstance(m["counters"], dict)
    assert {"count", "total_s"} <= set(m["shards"].keys())


def client(addr, i, barrier, results):
    # Deterministic per-client batch: distinct data, shared seed so
    # requests are compatible and may coalesce.
    x = [((i * 131 + j * 7) % 97) / 97.0
         for j in range(PER * IN_NUMEL)]
    y = [(i + j) % CLASSES for j in range(PER)]
    with socket.create_connection(addr, timeout=30) as sock:
        barrier.wait()
        send_frame(sock, {
            "op": "extract", "id": i, "model": "logreg",
            "sig": "grad+diag_ggn", "seed": 0, "x": x, "y": y,
            "metrics": i == 0,
        })
        results[i] = read_frame(sock)


def main():
    binary = sys.argv[1] if len(sys.argv) > 1 else \
        "rust/target/release/backpack"
    access_log = tempfile.mktemp(
        prefix="backpack_access_", suffix=".jsonl")
    proc = subprocess.Popen(
        [binary, "serve", "--addr", "127.0.0.1:0",
         "--linger-ms", "300", "--max-batch", str(CLIENTS * PER),
         "--access-log", access_log],
        stdout=subprocess.PIPE, text=True,
    )
    try:
        banner = proc.stdout.readline().strip()
        print(banner)
        assert banner.startswith("backpack-serve/v1 listening on "), \
            banner
        host, port = banner.rsplit(" ", 1)[1].rsplit(":", 1)
        addr = (host, int(port))

        # 8 concurrent clients, rendezvousing so the linger window
        # can coalesce them.
        barrier = threading.Barrier(CLIENTS)
        results = {}
        threads = [
            threading.Thread(
                target=client, args=(addr, i, barrier, results))
            for i in range(CLIENTS)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
            assert not t.is_alive(), "client timed out"

        assert len(results) == CLIENTS
        for i, r in sorted(results.items()):
            assert r["ok"], (i, r.get("error"))
            assert r["id"] == i
            res = r["results"]
            assert res["grad/0/w"]["shape"] == [10, IN_NUMEL]
            assert res["grad/0/b"]["shape"] == [10]
            assert res["diag_ggn/0/w"]["shape"] == [10, IN_NUMEL]
            loss = res["loss"]["data"][0]
            assert loss is not None and loss > 0.0, loss
            meta = r["meta"]
            assert meta["n"] == PER
            assert meta["batch_n"] == meta["coalesced"] * PER
            assert 1 <= meta["coalesced"] <= CLIENTS
        # Every request rode in some batch; same-batch members agree
        # on broadcast aggregates.
        by_batch = {}
        for i, r in sorted(results.items()):
            key = json.dumps(r["results"]["grad/0/w"]["data"][:8])
            by_batch.setdefault(key, []).append(r["meta"])
        for metas in by_batch.values():
            offs = sorted(m["offset"] for m in metas)
            assert len(set(offs)) == len(offs), offs
        window = results[0].get("metrics")
        assert window is not None, "client 0 asked for metrics"
        check_metrics_object(window)

        # Aggregate metrics endpoint.
        with socket.create_connection(addr, timeout=30) as sock:
            send_frame(sock, {"op": "metrics", "id": 99})
            m = read_frame(sock)
        assert m["ok"] and m["id"] == 99
        check_metrics_object(m["metrics"])
        serve = m["serve"]
        assert serve["schema"] == "backpack-serve/v1"
        assert serve["extracts"] == CLIENTS, serve
        assert serve["batches"] >= 1, serve
        assert serve["coalesced_max"] >= 2, \
            f"no dynamic batching observed: {serve}"
        assert serve["errors"] == 0, serve

        # Per-stage latency section (serve.latency): every stage of
        # the 8 served requests was timed.
        lat = serve["latency"]
        assert lat["unit"] == "us", lat
        for stage in ("queue", "linger", "extract", "reply"):
            assert lat["stages"][stage]["count"] >= 1, (stage, lat)
        assert lat["e2e"]["count"] >= 1, lat
        assert lat["e2e"]["p50"] is not None, lat
        assert lat["coalescing"]["requests"] == CLIENTS, lat
        print("serve counters:", json.dumps(
            {k: v for k, v in serve.items() if k != "latency"}))

        # Clean SIGTERM shutdown.
        proc.send_signal(signal.SIGTERM)
        proc.wait(timeout=30)

        # The access log has one backpack-access/v1 line per served
        # request, with the full stage timing.
        with open(access_log) as f:
            records = [json.loads(line) for line in f]
        oks = [r for r in records if r["outcome"] == "ok"]
        assert len(oks) == CLIENTS, [r["outcome"] for r in records]
        for r in oks:
            assert r["schema"] == "backpack-access/v1", r
            assert r["model"] == "logreg" and r["n"] == PER, r
            assert r["artifact"].startswith("logreg_"), r
            assert r["batch_requests"] >= 1, r
            assert r["coalesced"] == (r["batch_requests"] > 1), r
            for stage in ("queue_us", "linger_us", "extract_us",
                          "reply_us", "e2e_us"):
                assert isinstance(r[stage], int), (stage, r)
        print("serve smoke OK "
              f"(coalesced_max={serve['coalesced_max']}, "
              f"batches={serve['batches']}, "
              f"access_records={len(records)})")
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()
        if os.path.exists(access_log):
            os.unlink(access_log)


if __name__ == "__main__":
    main()
